"""Quickstart: train a tiny LM with the full production stack in ~1 minute.

    PYTHONPATH=src python examples/quickstart.py

Uses the same Trainer / data pipeline / checkpointing code paths as the
multi-pod launcher — only the config size differs.
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.configs import get_config                      # noqa: E402
from repro.runtime import Trainer, TrainerConfig          # noqa: E402


def main():
    cfg = get_config("smollm-360m").reduced()
    tcfg = TrainerConfig(steps=100, batch=8, seq_len=64, base_lr=3e-3,
                         log_every=10)
    trainer = Trainer(cfg, tcfg)
    history = trainer.run()
    for h in history:
        print(f"step {h['step']:4d}  loss {h['loss']:8.4f}  "
              f"acc {h['accuracy']:5.3f}  {h['dt']*1e3:7.1f} ms/step")
    assert history[-1]["loss"] < history[0]["loss"], "training must learn"
    print("quickstart OK — loss went down on the synthetic affine stream")


if __name__ == "__main__":
    main()
