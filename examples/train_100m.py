"""End-to-end driver: train a ~100M-parameter llama-family model for a few
hundred steps with checkpointing, straggler telemetry, and (simulated)
failure recovery — the full production loop, shrunk to one CPU.

    PYTHONPATH=src python examples/train_100m.py [--steps 300]

The model is a real ~100M config (12 layers, d_model=512, GQA, SwiGLU, tied
embeddings, vocab 49152) — not a reduced() toy.  Expect a few seconds per
step on CPU.
"""
import argparse
import dataclasses
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.config import ArchConfig                        # noqa: E402
from repro.nn.module import count_params                   # noqa: E402
from repro.models import model_for                         # noqa: E402
from repro.runtime import Trainer, TrainerConfig           # noqa: E402

import jax                                                  # noqa: E402


def make_100m() -> ArchConfig:
    return ArchConfig(
        name="llama-100m", family="dense",
        num_layers=12, d_model=512, num_heads=8, num_kv_heads=4,
        head_dim=64, d_ff=1536, vocab_size=49_152,
        mlp_type="swiglu", norm_type="rmsnorm", tie_embeddings=True,
        dtype="float32", param_dtype="float32", remat=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    cfg = make_100m()
    mod = model_for(cfg)
    n = count_params(mod.init(jax.random.PRNGKey(0), cfg))
    print(f"model: {cfg.name}  params={n/1e6:.1f}M")

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="train100m_")
    fails = {args.steps // 2}        # simulate one node failure mid-run
    tcfg = TrainerConfig(steps=args.steps, batch=args.batch,
                         seq_len=args.seq_len, base_lr=6e-4, warmup=50,
                         log_every=20, ckpt_every=50, ckpt_dir=ckpt_dir,
                         keep=2)
    trainer = Trainer(cfg, tcfg,
                      failure_injector=lambda s: s in fails and
                      not fails.discard(s))
    if trainer.restore_latest():
        print(f"resumed from step {int(jax.device_get(trainer.state['step']))}")
    history = trainer.run()
    for h in history:
        print(f"step {h['step']:5d}  loss {h['loss']:8.4f}  "
              f"acc {h['accuracy']:5.3f}  gnorm {h['grad_norm']:7.3f}  "
              f"{h['dt']*1e3:8.1f} ms")
    print(f"recoveries: {trainer.events.recoveries}")
    print(f"stragglers flagged: {len(trainer.events.stragglers)}")
    print(f"checkpoints in {ckpt_dir}")
    assert history[-1]["loss"] < history[0]["loss"]
    print("train_100m OK")


if __name__ == "__main__":
    main()
