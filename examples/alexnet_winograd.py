"""The paper's own workload end-to-end: AlexNet with Winograd F(4,3) convs,
LRN, pooling, and batched FC layers — training on synthetic class blobs,
plus the per-layer Table-2-style accounting.

    PYTHONPATH=src python examples/alexnet_winograd.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import dataclasses                                         # noqa: E402

import jax                                                  # noqa: E402
import jax.numpy as jnp                                     # noqa: E402

from repro.configs import get_config                        # noqa: E402
from repro.core.dse import (ALEXNET_CONV, DLAConfig,        # noqa: E402
                            alexnet_throughput, conv_cycles)
from repro.data.pipeline import synthetic_images            # noqa: E402
from repro.models import alexnet                            # noqa: E402
from repro.optim import adamw_step, init_state              # noqa: E402


def main():
    # --- per-layer accounting (paper Table 2) -----------------------------
    r = alexnet_throughput(DLAConfig(c_vec=8, k_vec=48), system_overhead=.16)
    print("DLA analytical model @ 8x48 (paper: 1020 img/s measured):")
    print(f"  model system throughput: {r['img_per_s']:.0f} img/s")
    for l in r["layers"]:
        print(f"  {l['name']:6s} act={l['act_gflops']:6.0f} GFLOPS  "
              f"eff={l['dsp_eff']*100:5.1f}%")

    # --- real training steps on the reduced topology ----------------------
    cfg = get_config("alexnet").reduced()
    params = alexnet.init(jax.random.PRNGKey(0), cfg)
    state = init_state(params)
    data = synthetic_images(batch=16, image_size=cfg.image_size,
                            num_classes=cfg.num_classes, seed=0, steps=60)

    @jax.jit
    def step(state, batch):
        (loss, m), g = jax.value_and_grad(alexnet.loss_fn, has_aux=True)(
            state["params"], cfg, batch)
        state, om = adamw_step(state, g, lr=3e-3)
        return state, {**m, **om}

    first = last = None
    for i, b in enumerate(data):
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        state, m = step(state, batch)
        if i % 10 == 0:
            print(f"  step {i:3d} loss {float(m['loss']):.4f} "
                  f"acc {float(m['accuracy']):.3f}")
        first = first if first is not None else float(m["loss"])
        last = float(m["loss"])
    assert last < first, "AlexNet training must learn the blobs"

    # --- winograd == direct on the trained params --------------------------
    b = next(synthetic_images(batch=4, image_size=cfg.image_size,
                              num_classes=cfg.num_classes, seed=1, steps=1))
    imgs = jnp.asarray(b["images"])
    lw = alexnet.apply(state["params"], cfg, imgs)
    ld = alexnet.apply(state["params"],
                       dataclasses.replace(cfg, use_winograd=False), imgs)
    err = float(jnp.abs(lw - ld).max())
    print(f"winograd-vs-direct logits max err after training: {err:.2e}")
    assert err < 1e-3
    print("alexnet_winograd OK")


if __name__ == "__main__":
    main()
