"""Continuous-batching serving demo (paper §3.7 batching, both regimes).

    PYTHONPATH=src python examples/serve_batch.py [--arch llama3.2-3b]
    PYTHONPATH=src python examples/serve_batch.py --arch alexnet

LM archs submit a stream of mixed-length requests to the slot-based decode
engine and report the batching amortization (per-step decode time vs
occupancy) — the LM analogue of the paper's S_batch=96 FC batching.

``--arch alexnet`` serves image-classification requests through the
bucketed, double-buffered ``CnnEngine`` (the paper's actual workload) and
reports img/s + request latency percentiles (Tables 5-6).
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np                                         # noqa: E402

from repro.configs import ASSIGNED, CNN_ARCHS, get_config  # noqa: E402
from repro.launch.serve import CNN_ROUTES, serve_images    # noqa: E402
from repro.serving import Engine, Request, ServeConfig     # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b",
                    choices=ASSIGNED + CNN_ARCHS)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--data-parallel", action="store_true",
                    help="CNN path: shard buckets over all JAX devices")
    ap.add_argument("--route", default="auto", choices=CNN_ROUTES,
                    help="CNN path: conv route (pallas = stream-buffered "
                         "kernel end-to-end through CnnEngine)")
    ap.add_argument("--prefetch", default="on", choices=("on", "off"),
                    help="CNN path: Pallas weight stream — double-buffered "
                         "manual-DMA filter prefetch (on) vs synchronous "
                         "fetches (off; bit-equal)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    if cfg.family == "cnn":
        # one shared driver with the launcher (repro.launch.serve)
        done = serve_images(cfg, args)
        assert done == args.requests
        print("serve_batch OK")
        return

    scfg = ServeConfig(max_batch=args.max_batch, max_len=160,
                       prefill_bucket=16,
                       cross_len=64 if cfg.family == "audio" else 0)
    eng = Engine(cfg, scfg, seed=0)

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        plen = int(rng.integers(4, 48))
        req = Request(prompt=list(rng.integers(1, cfg.vocab_size, plen)),
                      max_new=args.max_new)
        if cfg.family == "audio":
            req.frames = (rng.standard_normal((64, cfg.d_model)) * 0.1
                          ).astype(np.float32)
        if cfg.family == "vlm":
            req.patches = (rng.standard_normal((cfg.num_patches, 1024)) * 0.1
                           ).astype(np.float32)
        reqs.append(req)
        eng.submit(req)

    t0 = time.perf_counter()
    eng.run_until_done()
    wall = time.perf_counter() - t0
    done = sum(r.done for r in reqs)
    print(f"arch={args.arch}  completed {done}/{len(reqs)} requests "
          f"in {wall:.1f}s")
    print(f"tokens generated: {eng.tokens_generated} "
          f"({eng.decode_steps} batched decode steps, "
          f"avg occupancy "
          f"{eng.tokens_generated/max(eng.decode_steps,1):.2f}/step)")
    print(f"decode throughput: {eng.decode_tokens_per_s:.1f} tok/s "
          f"(weight stream amortized over the batch — paper §3.7)")
    assert done == len(reqs)
    print("serve_batch OK")


if __name__ == "__main__":
    main()
