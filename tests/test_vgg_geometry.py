"""VGG-16-class geometry sweep: `auto_c_block` / `auto_pool_rows` off
AlexNet (paper-adjacent: the DLA's stream buffers are sized for AlexNet
planes; VGG's 224px maps are the case where whole-plane residency stops
fitting and the channel-block reduction has to earn its keep).

Two layers of validation:

* the *choices*: over the real VGG-16 conv table, the auto-sized blocks
  must respect the VMEM slab budget, keep every AlexNet-scale plane fully
  resident, and split channels on the big 224/112px planes (the re-fetch
  trade `conv2d_hbm_bytes` models);
* the *kernels*: VGG-proportioned geometries whose auto plan really does
  pick ``ncb > 1`` (several channel blocks) and partial pooled-row blocks
  must still be bit-faithful to the lax reference on both Pallas kernels.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.winograd import auto_c_block, auto_pool_rows
from repro.kernels.conv import direct as dk
from repro.kernels.conv import winograd as wk
from repro.kernels.conv.ref import conv2d_ref
from repro.nn.pooling import apply_epilogue

# the VGG-16 conv layers: (input extent, C_in, C_out); all 3x3 stride 1
VGG16_LAYERS = [
    (224, 3, 64), (224, 64, 64),
    (112, 64, 128), (112, 128, 128),
    (56, 128, 256), (56, 256, 256), (56, 256, 256),
    (28, 256, 512), (28, 512, 512), (28, 512, 512),
    (14, 512, 512), (14, 512, 512), (14, 512, 512),
]
# layers followed by the 2x2 s2 max-pool: (conv-out extent, C_out)
VGG16_POOLED = [(224, 64), (112, 128), (56, 256), (28, 512), (14, 512)]

SLAB_BUDGET = 8 * 2 ** 20
EPILOGUE_BUDGET = 4 * 2 ** 20


@pytest.mark.parametrize("batch", [1, 8])
def test_auto_c_block_respects_budget_over_vgg_table(batch):
    """Every auto-sized channel block keeps the whole resident
    (batch, Hp, Wp, Cb) input block within the slab budget (or full C when
    it fits; the floor of 1 channel can never be shrunk further)."""
    for h, c_in, _ in VGG16_LAYERS:
        hp = wp = h + 2                         # SAME halo for r=3
        cb = auto_c_block(hp, wp, c_in, batch=batch)
        assert 1 <= cb <= c_in, (h, c_in, cb)
        if cb < c_in:
            assert cb == 1 or batch * hp * wp * cb * 4 <= SLAB_BUDGET, (
                h, c_in, cb)


def test_auto_c_block_splits_vgg_but_not_alexnet():
    """At the filter-cache depth (batch=8) the big VGG planes must split
    channels while every AlexNet plane stays fully resident — the exact
    trade DESIGN.md documents."""
    # VGG 224px and 56px planes: whole-plane residency can't fit 8 deep
    assert auto_c_block(226, 226, 64, batch=8) < 64
    assert auto_c_block(114, 114, 128, batch=8) < 128
    assert auto_c_block(58, 58, 256, batch=8) < 256
    # AlexNet planes (Hp x Wp x C at the five layers) all stay resident
    for hp, c in ((227, 3), (31, 48), (15, 256), (13, 192), (13, 192)):
        assert auto_c_block(hp, hp, c, batch=8) == c, (hp, c)


@pytest.mark.parametrize("batch", [1, 8])
def test_auto_pool_rows_respects_budget_over_vgg_table(batch):
    """The pooled-row block keeps the full-channel epilogue scratch within
    its budget (or owns the whole pooled extent when that fits)."""
    for out_h, k in VGG16_POOLED:
        ph = out_h // 2
        Pb = auto_pool_rows(ph, 2, 2, cols=out_h, kfull=k, batch=batch)
        assert 1 <= Pb <= ph
        rows = 2 * (Pb - 1) + 2
        if Pb < ph:
            assert Pb == 1 or batch * rows * out_h * k * 4 <= \
                EPILOGUE_BUDGET, (out_h, k, Pb)


def _vgg_case(H, C, K, B, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((B, H, H, C)) * 0.5, jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 3, C, K)) * 0.1, jnp.float32)
    b = jnp.asarray(rng.standard_normal((K,)), jnp.float32)
    return x, w, b


def test_winograd_kernel_auto_c_block_splits_on_vgg_plane():
    """A VGG-proportioned plane (72px, C=128, batch 8) where the auto plan
    genuinely picks several channel blocks: the in-kernel channel-block
    reduction + DMA weight stream must be invisible in the output."""
    x, w, b = _vgg_case(72, 128, 8, 8, seed=0)
    p = wk.plan(x.shape, w.shape)
    assert p.ncb > 1, "geometry must force a multi-c-block plan"
    out = wk.conv2d_winograd(x, w, b, relu=True, interpret=True)
    ref = conv2d_ref(x, w, b, relu=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_winograd_fused_pool_auto_blocks_on_vgg_plane():
    """Same multi-c-block regime with the fused 2x2 s2 VGG pool epilogue
    (pool_row_block=None grows to the budgeted pooled-row block)."""
    x, w, b = _vgg_case(72, 96, 8, 8, seed=1)
    p = wk.plan(x.shape, w.shape, pool=(2, 2))
    assert p.ncb > 1, "geometry must force a multi-c-block plan"
    out = wk.conv2d_winograd(x, w, b, relu=True, pool=(2, 2),
                             interpret=True)
    ref = apply_epilogue(conv2d_ref(x, w, b, relu=True), None, (2, 2))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_direct_kernel_auto_c_block_splits_on_vgg_plane():
    """The strided direct kernel under the same auto multi-c-block regime
    (3x3 s1 runs on it too when routed explicitly)."""
    x, w, b = _vgg_case(72, 128, 8, 8, seed=2)
    p = dk.plan(x.shape, w.shape)
    assert p.ncb > 1, "geometry must force a multi-c-block plan"
    out = dk.conv2d_direct(x, w, b, relu=True, interpret=True)
    ref = conv2d_ref(x, w, b, relu=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("pool_row_block", [1, 3, None])
def test_pool_row_block_sweep_vgg_pool(pool_row_block):
    """pool_row_block sweep on the VGG 2x2 s2 pool: single-row blocks,
    a non-dividing partial block, and the auto (whole-extent) block must
    all agree with the reference on both kernels."""
    x, w, b = _vgg_case(28, 24, 12, 3, seed=3)
    ref = apply_epilogue(conv2d_ref(x, w, b, relu=True), None, (2, 2))
    out_w = wk.conv2d_winograd(x, w, b, relu=True, pool=(2, 2),
                               pool_row_block=pool_row_block,
                               interpret=True)
    np.testing.assert_allclose(np.asarray(out_w), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)
    out_d = dk.conv2d_direct(x, w, b, relu=True, pool=(2, 2),
                             pool_row_block=pool_row_block, interpret=True)
    np.testing.assert_allclose(np.asarray(out_d), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)
