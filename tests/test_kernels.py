"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.bfp_matmul import bfp_matmul as bfp_k
from repro.kernels.bfp_matmul import ops as bfp_ops
from repro.kernels.bfp_matmul import ref as bfp_ref
from repro.kernels.ssd import ref as ssd_ref
from repro.kernels.ssd import ssd as ssd_k
from repro.kernels.conv import ref as wg_ref
from repro.kernels.conv import winograd as wg_k


# --------------------------------------------------------------------------
# winograd conv kernels
# --------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("L,C,r", [(64, 8, 4), (100, 16, 3), (33, 5, 4),
                                   (7, 128, 4)])
def test_wino1d_kernel_sweep(L, C, r, dtype):
    rng = np.random.default_rng(L * 7 + C)
    x = jnp.asarray(rng.standard_normal((2, L, C)), dtype)
    w = jnp.asarray(rng.standard_normal((r, C)), dtype)
    b = jnp.asarray(rng.standard_normal((C,)), dtype)
    out = wg_k.conv1d_depthwise_causal(x, w, b, interpret=True)
    ref = wg_ref.conv1d_depthwise_causal_ref(x, w, b)
    tol = 1e-4 if dtype == jnp.float32 else 6e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("H,W,C,K,m", [(13, 13, 32, 24, 4), (8, 21, 7, 5, 2),
                                       (27, 27, 12, 16, 4)])
def test_wino2d_kernel_sweep(H, W, C, K, m, dtype):
    rng = np.random.default_rng(H + W)
    x = jnp.asarray(rng.standard_normal((2, H, W, C)), dtype)
    w = jnp.asarray(rng.standard_normal((3, 3, C, K)) * 0.2, dtype)
    out = wg_k.conv2d_winograd(x, w, m=m, interpret=True, row_block=2)
    ref = wg_ref.conv2d_ref(x, w)
    tol = 5e-4 if dtype == jnp.float32 else 1e-1
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_wino2d_kernel_takes_raw_input():
    """The Pallas path consumes the raw (B,H,W,C) array — the (n/m)^2
    overlapping-tile tensor is built in-kernel, never materialized host-side
    (stream-buffer dataflow, paper §3.5)."""
    import jax

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((1, 13, 13, 8)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 3, 8, 8)) * 0.2, jnp.float32)
    text = jax.make_jaxpr(
        lambda a, b: wg_k.conv2d_winograd(a, b, interpret=True))(x, w)
    assert "gather" not in str(text), "host-side tile gather crept back in"


@pytest.mark.parametrize("c_block,k_block,row_block", [(8, 8, 1), (16, 24, 2),
                                                       (32, 128, 8)])
def test_wino2d_kernel_channel_block_reduction(c_block, k_block, row_block):
    """c_block grid dim + in-kernel (VMEM scratch) accumulation: any blocking
    must give the same answer as one resident block."""
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((2, 13, 13, 32)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 3, 32, 24)) * 0.2, jnp.float32)
    out = wg_k.conv2d_winograd(x, w, m=4, interpret=True, c_block=c_block,
                               k_block=k_block, row_block=row_block)
    ref = wg_ref.conv2d_ref(x, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("padding", ["SAME", "VALID"])
def test_wino2d_kernel_fused_epilogue_and_groups(padding):
    """Fused bias+ReLU epilogue + grouped (batch-folded) conv vs oracle."""
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((2, 12, 12, 8)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 3, 4, 10)) * 0.2, jnp.float32)
    b = jnp.asarray(rng.standard_normal((10,)), jnp.float32)
    out = wg_k.conv2d_winograd(x, w, b, m=4, padding=padding, relu=True,
                               groups=2, c_block=4, interpret=True)
    ref = wg_ref.conv2d_ref(x, w, b, padding=padding, groups=2, relu=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_wino1d_custom_vjp_matches_ref():
    from repro.kernels.conv.ops import conv1d_depthwise_causal as op
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((2, 29, 8)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((4, 8)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((8,)), jnp.float32)
    f = lambda x, w, b: (op(x, w, b, pallas=True) * jnp.sin(x)).sum()
    fr = lambda x, w, b: (wg_ref.conv1d_depthwise_causal_ref(x, w, b)
                          * jnp.sin(x)).sum()
    gf = jax.grad(f, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(fr, argnums=(0, 1, 2))(x, w, b)
    for a, c in zip(gf, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   rtol=1e-3, atol=1e-4)


# --------------------------------------------------------------------------
# bfp matmul kernel
# --------------------------------------------------------------------------
@pytest.mark.parametrize("M,K,N,block", [(64, 256, 48, 32), (8, 64, 8, 32),
                                         (130, 512, 70, 64)])
def test_bfp_kernel_bitmatches_ref(M, K, N, block):
    rng = np.random.default_rng(M + K + N)
    x = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)
    out_k = bfp_ops.bfp_matmul(x, w, block=block, pallas=True, interpret=True)
    out_r = bfp_ref.bfp_matmul_ref(x, w, block=block)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-6, atol=1e-5)


def test_bfp_kernel_error_vs_exact():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((64, 256)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((256, 32)), jnp.float32)
    out = np.asarray(bfp_ops.bfp_matmul(x, w, pallas=True, interpret=True))
    ex = np.asarray(bfp_ref.exact_matmul(x, w))
    assert np.abs(out - ex).max() / np.abs(ex).max() < 0.05


# --------------------------------------------------------------------------
# decode attention kernel
# --------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,KV,D", [(3, 64, 4, 2, 16), (2, 100, 8, 8, 32),
                                        (1, 33, 6, 3, 8)])
def test_decode_attn_kernel_sweep(B, S, H, KV, D, dtype):
    from repro.kernels.decode_attn.ops import decode_attention
    from repro.kernels.decode_attn.ref import decode_attention_ref
    rng = np.random.default_rng(B * S)
    q = jnp.asarray(rng.standard_normal((B, 1, H, D)), dtype)
    k = jnp.asarray(rng.standard_normal((B, S, KV, D)), dtype)
    v = jnp.asarray(rng.standard_normal((B, S, KV, D)), dtype)
    lens = jnp.asarray(rng.integers(1, S + 1, B), jnp.int32)
    out = decode_attention(q, k, v, lens, pallas=True)
    ref = decode_attention_ref(q, k, v, lens)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


# --------------------------------------------------------------------------
# ssd kernel
# --------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("L,H,P,G,N,chunk", [
    (64, 4, 8, 2, 16, 16), (100, 2, 4, 1, 8, 32), (16, 8, 16, 1, 4, 16)])
def test_ssd_kernel_vs_recurrence(L, H, P, G, N, chunk, dtype):
    rng = np.random.default_rng(L + H)
    B = 2
    x = jnp.asarray(rng.standard_normal((B, L, H, P)), dtype)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (B, L, H)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (H,)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B, L, G, N)), dtype)
    Cm = jnp.asarray(rng.standard_normal((B, L, G, N)), dtype)
    y_k, s_k = ssd_k.ssd_chunked_pallas(x, dt, A, Bm, Cm, chunk=chunk,
                                        interpret=True)
    y_r, s_r = ssd_ref.ssd_reference(x, dt, A, Bm, Cm)
    tol = 1e-4 if dtype == jnp.float32 else 1e-1
    np.testing.assert_allclose(np.asarray(y_k, np.float32),
                               np.asarray(y_r, np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r),
                               rtol=tol, atol=tol)


def test_ssd_kernel_matches_jnp_chunked():
    """Kernel and the GSPMD-partitionable jnp twin agree (same math)."""
    from repro.nn.ssd import ssd_chunked as jnp_impl
    rng = np.random.default_rng(9)
    B, L, H, P, G, N = 1, 48, 2, 8, 1, 8
    x = jnp.asarray(rng.standard_normal((B, L, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (B, L, H)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (H,)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B, L, G, N)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((B, L, G, N)), jnp.float32)
    y_k, s_k = ssd_k.ssd_chunked_pallas(x, dt, A, Bm, Cm, chunk=16,
                                        interpret=True)
    y_j, s_j = jnp_impl(x, dt, A, Bm, Cm, 16)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_j),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_j),
                               rtol=1e-5, atol=1e-5)
