"""CnnEngine: bucket-padding bit-exactness, counters, mixed arrival, DP.

The adversarial core: served logits must *bit-match* a direct
``alexnet.apply`` on the same images for every bucket padding — a single
request (bucket 1), a partial bucket (3 requests padded to 4), and a full
``max_batch`` — so batching/padding can never change what a user gets back.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import alexnet
from repro.serving import (CnnEngine, CnnServeConfig, ImageRequest,
                           SlotScheduler, bucket_sizes)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def served():
    """One reduced config + params + jitted direct-apply oracle."""
    cfg = get_config("alexnet").reduced()
    params = alexnet.init(jax.random.PRNGKey(0), cfg)
    ref = jax.jit(lambda p, x: alexnet.apply(p, cfg, x))
    return cfg, params, lambda x: ref(params, x)


def _images(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.standard_normal(
        (n, cfg.image_size, cfg.image_size, cfg.in_channels)
    ).astype(np.float32)


def test_bucket_sizes():
    assert bucket_sizes(1) == (1,)
    assert bucket_sizes(8) == (1, 2, 4, 8)
    assert bucket_sizes(6) == (1, 2, 4, 6)      # non-pow2 cap kept as-is


@pytest.mark.parametrize("n_req,max_batch", [
    (1, 4),    # bucket 1: single request
    (3, 4),    # partial bucket: padded 3 -> 4
    (4, 4),    # full max_batch bucket
])
def test_served_logits_bitmatch_direct_apply(served, n_req, max_batch):
    """Bucket padding must never perturb logits: exact array equality."""
    cfg, params, ref = served
    eng = CnnEngine(cfg, CnnServeConfig(max_batch=max_batch), params=params)
    imgs = _images(cfg, n_req, seed=n_req)
    reqs = [ImageRequest(image=imgs[i]) for i in range(n_req)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done()
    expect = np.asarray(ref(jnp.asarray(imgs)))
    got = np.stack([r.logits for r in reqs])
    assert np.array_equal(got, expect), \
        np.abs(got - expect).max()
    assert all(r.done and r.label == int(expect[i].argmax())
               for i, r in enumerate(reqs))
    # the padded bucket really was used (3 -> 4), not an exact-shape compile
    if n_req == 3:
        assert eng.bucket_counts == {4: 1}


def test_counters_consistent(served):
    """Occupancy/throughput accounting adds up across multiple groups."""
    cfg, params, _ = served
    eng = CnnEngine(cfg, CnnServeConfig(max_batch=4), params=params)
    reqs = [ImageRequest(image=im) for im in _images(cfg, 6, seed=9)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done()
    s = eng.stats()
    assert s["images_completed"] == 6
    assert eng.sched.submitted == eng.sched.completed == 6
    assert eng.sched.occupancy == 0 and eng.sched.idle
    # 6 requests over max_batch=4 slots*depth -> groups of 4 and 2
    assert s["batches_run"] == 2
    assert s["bucket_counts"] == {2: 1, 4: 1}
    assert sum(k * v for k, v in s["bucket_counts"].items()) >= 6
    assert s["avg_occupancy"] == pytest.approx(3.0)
    # every staged shape came from the declared bucket set (bounded jit)
    assert set(s["bucket_counts"]) <= set(eng.buckets)
    assert s["imgs_per_s"] > 0
    lat = s["latency_ms"]
    assert len(eng.latency) == 6
    assert 0 < lat["p50"] <= lat["p90"] <= lat["p99"]


def test_mixed_arrival_retires_correctly(served):
    """Shuffled submissions across several groups: each request gets *its*
    logits (per-image oracle), FIFO admission order, uids intact."""
    cfg, params, ref = served
    eng = CnnEngine(cfg, CnnServeConfig(max_batch=2), params=params)
    imgs = _images(cfg, 7, seed=3)
    order = [4, 0, 6, 2, 5, 1, 3]
    reqs = {i: ImageRequest(image=imgs[i]) for i in order}
    for i in order:
        eng.submit(reqs[i])
    eng.run_until_done()
    assert all(r.done for r in reqs.values())
    # groups of (2,2,2,1) in arrival order
    assert eng.stats()["bucket_counts"] == {1: 1, 2: 3}
    for i in order:
        expect = np.asarray(ref(jnp.asarray(imgs[i][None])))[0]
        np.testing.assert_allclose(reqs[i].logits, expect,
                                   rtol=1e-5, atol=1e-6)
        assert reqs[i].label == int(expect.argmax())
    # latency ordering: earlier-arriving requests never finish after
    # later ones (FIFO groups retire in admission order)
    times = [reqs[i].t_done for i in order]
    assert times == sorted(times)


def test_incremental_submission_reuses_buckets(served):
    """Requests arriving between steps are admitted mid-flight and only
    compile shapes from the declared bucket set."""
    cfg, params, ref = served
    eng = CnnEngine(cfg, CnnServeConfig(max_batch=4), params=params)
    imgs = _images(cfg, 5, seed=11)
    reqs = [ImageRequest(image=im) for im in imgs]
    eng.submit(reqs[0])
    eng.step()                      # group of 1 in flight
    for r in reqs[1:]:
        eng.submit(r)
    eng.run_until_done()
    assert all(r.done for r in reqs)
    assert set(eng.bucket_counts) <= {1, 2, 4}
    expect = np.asarray(ref(jnp.asarray(imgs)))
    for i, r in enumerate(reqs):
        np.testing.assert_allclose(r.logits, expect[i], rtol=1e-5, atol=1e-6)


def test_submit_rejects_wrong_image_shape(served):
    """Shape errors surface at the API boundary, not via silent numpy
    broadcasting deep inside staging."""
    cfg, params, _ = served
    eng = CnnEngine(cfg, CnnServeConfig(max_batch=2), params=params)
    bad = [np.zeros((1, cfg.image_size, 3), np.float32),          # broadcastable
           np.zeros((cfg.image_size, cfg.image_size), np.float32),
           np.zeros((cfg.image_size + 1, cfg.image_size, 3), np.float32)]
    for img in bad:
        with pytest.raises(ValueError, match="image shape"):
            eng.submit(ImageRequest(image=img))
    assert eng.sched.submitted == 0


def test_slot_scheduler_invariants():
    """Shared core: FIFO admission, limit, retire bookkeeping."""
    s = SlotScheduler(3)
    for i in range(5):
        s.submit(f"r{i}")
    assert s.submitted == 5 and not s.idle
    got = s.admit(limit=2)
    assert [(0, "r0"), (1, "r1")] == got
    assert s.occupancy == 2 and s.active.tolist() == [True, True, False]
    assert s.admit() == [(2, "r2")]
    assert s.admit() == []                      # full
    assert s.retire(1) == "r1"
    assert s.completed == 1
    assert s.admit() == [(1, "r3")]             # freed slot reused FIFO
    assert s.retire(0) == "r0"
    with pytest.raises(AssertionError):
        s.retire(0)                             # double retire must assert


def test_data_parallel_bitmatch_subprocess(served):
    """DP sharding over forced host devices must not change served logits
    (divisible bucket sharded, indivisible bucket replicated)."""
    del served  # subprocess re-creates state; fixture just orders tests
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=2",
               PYTHONPATH=os.path.join(ROOT, "src"))
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models import alexnet
        from repro.serving import CnnEngine, CnnServeConfig, ImageRequest
        assert jax.device_count() == 2
        cfg = get_config("alexnet").reduced()
        params = alexnet.init(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(0)
        imgs = rng.standard_normal(
            (5, cfg.image_size, cfg.image_size, 3)).astype(np.float32)
        eng = CnnEngine(cfg, CnnServeConfig(max_batch=4, data_parallel=True),
                        params=params)
        assert eng.mesh is not None and eng.mesh.devices.size == 2
        reqs = [ImageRequest(image=im) for im in imgs]
        for r in reqs:
            eng.submit(r)
        eng.run_until_done()          # groups: 4 (sharded), 1 (replicated)
        assert all(r.done for r in reqs)
        ref = np.asarray(jax.jit(
            lambda p, x: alexnet.apply(p, cfg, x))(params, jnp.asarray(imgs)))
        got = np.stack([r.logits for r in reqs])
        np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-6)
        print("OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "OK" in r.stdout
