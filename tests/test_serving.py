"""Serving engine: greedy equivalence, continuous batching, SSM path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model_for
from repro.serving import Engine, Request, ServeConfig


def _greedy_reference(cfg, params, prompt, n_new):
    """Teacher-forced greedy continuation via repeated full forward."""
    mod = model_for(cfg)
    toks = list(prompt)
    for _ in range(n_new):
        logits, _, _ = mod.apply(params, cfg,
                                 jnp.asarray([toks], jnp.int32),
                                 mode="train")
        toks.append(int(logits[0, -1].argmax()))
    return toks[len(prompt):]


@pytest.mark.parametrize("arch", ["llama3.2-3b", "mamba2-2.7b"])
def test_engine_matches_greedy_reference(arch):
    cfg = get_config(arch).reduced()
    eng = Engine(cfg, ServeConfig(max_batch=2, max_len=64,
                                  prefill_bucket=8), seed=0)
    prompt = [3, 1, 4, 1, 5, 9, 2, 6]     # exactly one bucket: no pad noise
    req = Request(prompt=prompt, max_new=5)
    eng.submit(req)
    eng.run_until_done()
    ref = _greedy_reference(cfg, eng.params, prompt, 5)
    assert req.generated == ref, (req.generated, ref)


def test_continuous_batching_mixed_lengths():
    cfg = get_config("smollm-360m").reduced()
    eng = Engine(cfg, ServeConfig(max_batch=3, max_len=96,
                                  prefill_bucket=16), seed=1)
    reqs = [Request(prompt=list(range(1, n + 1)), max_new=4)
            for n in (5, 12, 3, 20, 7, 9)]      # 6 requests, 3 slots
    for r in reqs:
        eng.submit(r)
    eng.run_until_done()
    assert all(r.done for r in reqs)
    assert all(len(r.generated) == 4 for r in reqs)
    assert eng.tokens_generated == 24


def test_whisper_engine_cross_attention():
    cfg = get_config("whisper-tiny").reduced()
    eng = Engine(cfg, ServeConfig(max_batch=2, max_len=48, cross_len=16),
                 seed=2)
    rng = np.random.default_rng(0)
    req = Request(prompt=[1, 2, 3], max_new=4,
                  frames=rng.standard_normal((16, cfg.d_model))
                  .astype(np.float32) * 0.1)
    eng.submit(req)
    eng.run_until_done()
    assert req.done and len(req.generated) == 4


def test_engine_shares_scheduler_core():
    """Decode engine rides the same SlotScheduler/LatencyTracker core as
    CnnEngine: counters and latency percentiles line up after a run."""
    cfg = get_config("smollm-360m").reduced()
    eng = Engine(cfg, ServeConfig(max_batch=2, max_len=64,
                                  prefill_bucket=8), seed=4)
    reqs = [Request(prompt=[1, 2, 3, 4], max_new=3) for _ in range(3)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done()
    assert all(r.done for r in reqs)
    assert eng.sched.submitted == eng.sched.completed == 3
    assert eng.sched.idle and eng.sched.occupancy == 0
    assert len(eng.latency) == 3
    lat = eng.latency.percentiles_ms()
    assert 0 < lat["p50"] <= lat["p99"]
    assert all(r.t_done >= r.t_submit > 0 for r in reqs)
    # back-compat views still exposed
    assert eng.active.tolist() == [False, False]
    assert list(eng.queue) == [] and eng.slot_req == [None, None]


def test_batching_amortizes_weight_stream():
    """Paper §3.7's point, measured: tokens/s grows with occupancy (batched
    decode reuses the streamed weights).  On CPU the effect is modest but
    per-step time must grow far slower than batch size."""
    cfg = get_config("smollm-360m").reduced()
    import time

    def run(n_req):
        eng = Engine(cfg, ServeConfig(max_batch=8, max_len=64,
                                      prefill_bucket=8), seed=3)
        for i in range(n_req):
            eng.submit(Request(prompt=[1, 2, 3, 4, 5, 6, 7, 8], max_new=16))
        eng.run_until_done()
        return eng._t_decode / eng.decode_steps

    t1 = run(1)
    t8 = run(8)
    assert t8 < t1 * 8 * 0.8     # batching is strictly sublinear


def test_fc_bfp_decode_logits_parity():
    """§3.6 on the decode engine's FC path: with ``fc_bfp`` the lm_head
    weight stream moves as shared-exponent int8 BFP; logits must track the
    f32 readout within quantization error in both prefill and decode-shaped
    calls, and the engine must serve end-to-end with it."""
    import dataclasses

    cfg = get_config("starcoder2-15b").reduced()
    assert not cfg.tie_embeddings          # fc_bfp targets the lm_head
    cfg_bfp = dataclasses.replace(cfg, fc_bfp=True)
    mod = model_for(cfg)
    params = mod.init(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(np.arange(1, 9)[None], jnp.int32)
    exact, _, _ = mod.apply(params, cfg, toks, mode="train")
    quant, _, _ = mod.apply(params, cfg_bfp, toks, mode="train")
    exact, quant = np.asarray(exact), np.asarray(quant)
    assert exact.shape == quant.shape
    scale = np.abs(exact).max() + 1e-9
    assert np.abs(quant - exact).max() / scale < 5e-2
    assert not np.array_equal(quant, exact)    # the quantized path ran

    # end-to-end through the token-decode Engine (decode-mode readout)
    eng = Engine(cfg_bfp, ServeConfig(max_batch=2, max_len=32,
                                      prefill_bucket=8), seed=0)
    req = Request(prompt=[1, 2, 3, 4], max_new=4)
    eng.submit(req)
    eng.run_until_done()
    assert req.done and len(req.generated) == 4
