"""Shared-exponent block floating point (paper §3.6): error bounds."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import bfp


@given(rows=st.integers(1, 8), blocks=st.integers(1, 6),
       bits=st.sampled_from([6, 8, 12, 16]), axis=st.sampled_from([0, 1]),
       seed=st.integers(0, 10_000), scale_pow=st.integers(-20, 20))
@settings(max_examples=40, deadline=None)
def test_quantize_roundtrip_error_bound(rows, blocks, bits, axis, seed,
                                        scale_pow):
    """|dequant(x) - x| <= 2^(e - bits) per element (half a quant step)."""
    rng = np.random.default_rng(seed)
    block = 16
    shape = (rows, blocks * block) if axis == 1 else (blocks * block, rows)
    x = jnp.asarray(rng.standard_normal(shape) * 2.0 ** scale_pow,
                    jnp.float32)
    m, e, ax = bfp.quantize(x, block=block, bits=bits, axis=axis)
    xr = bfp.dequantize(m, e, bits=bits, axis=ax)
    bound = np.asarray(bfp.error_bound(e, bits=bits))
    err = np.abs(np.asarray(xr) - np.asarray(x))
    errb = err.reshape(*m.shape)       # blocked layout matches mantissas
    assert (errb <= np.expand_dims(bound, ax + 1) + 1e-30).all(), \
        (errb.max(), bound.max())


@given(seed=st.integers(0, 1000), bits=st.sampled_from([8, 16]))
@settings(max_examples=10, deadline=None)
def test_bfp_matmul_error(seed, bits):
    rng = np.random.default_rng(seed)
    M, K, N = 32, 128, 16
    x = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)
    exact = np.asarray(x @ w)
    out = np.asarray(bfp.bfp_matmul(x, w, block=32, bits=bits))
    rel = np.abs(out - exact).max() / np.abs(exact).max()
    # error per product ~2^-(bits-1); K=128 accumulation, loose 8x headroom
    assert rel < 2.0 ** -(bits - 1) * 8, rel


def test_zero_block_safe():
    x = jnp.zeros((4, 64), jnp.float32)
    m, e, ax = bfp.quantize(x, block=32)
    assert np.all(np.asarray(m) == 0)
    np.testing.assert_array_equal(np.asarray(bfp.dequantize(m, e, axis=ax)), 0)


def test_paper_accuracy_claim_proxy():
    """Paper §6.1: no accuracy impact from shared-exponent FP16.  Proxy:
    quantize-dequantize of AlexNet-like weights changes a conv output by
    < 0.5% relative — far below task-level noise."""
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((3, 3, 64, 64)) * 0.05, jnp.float32)
    x = jnp.asarray(rng.standard_normal((1, 13, 13, 64)), jnp.float32)
    from repro.core.winograd import conv2d_direct
    wq = bfp.quantize_dequantize(w.reshape(-1, 64), block=32,
                                 bits=16).reshape(w.shape)
    y0 = np.asarray(conv2d_direct(x, w))
    y1 = np.asarray(conv2d_direct(x, jnp.asarray(wq)))
    assert np.abs(y1 - y0).max() / np.abs(y0).max() < 5e-3
