"""Multi-device tests (subprocess with forced host devices): sharding rules,
BFP collectives, pipeline parallelism, elastic reshard, small-mesh dry-run."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8, timeout: int = 600):
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=os.path.join(ROOT, "src"))
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_bfp_psum_and_pipeline():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.parallel.compat import shard_map
        from repro.parallel.collectives import bfp_psum
        from repro.parallel.pipeline import pipeline_apply
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((8, 2048)), jnp.float32)
        out = shard_map(lambda xs: bfp_psum(xs[0], "data"), mesh=mesh,
                        in_specs=P("data"), out_specs=P(None),
                        check_vma=False)(x)
        rel = float(jnp.abs(out - x.sum(0)).max() / jnp.abs(x.sum(0)).max())
        assert rel < 0.05, rel
        out16 = shard_map(lambda xs: bfp_psum(xs[0], "data", bits=16),
                          mesh=mesh, in_specs=P("data"), out_specs=P(None),
                          check_vma=False)(x)
        rel16 = float(jnp.abs(out16 - x.sum(0)).max()/jnp.abs(x.sum(0)).max())
        assert rel16 < 3e-4, rel16
        mesh2 = jax.make_mesh((4, 2), ("pipe", "data"))
        ws = jnp.asarray(rng.standard_normal((4, 16, 16)) * 0.3, jnp.float32)
        xs = jnp.asarray(rng.standard_normal((8, 2, 16)), jnp.float32)
        fn = lambda w, x: jnp.tanh(x @ w)
        out_p = pipeline_apply(fn, ws, xs, mesh=mesh2, axis="pipe")
        ref = xs
        for s in range(4): ref = fn(ws[s], ref)
        assert float(jnp.abs(out_p - ref).max()) < 1e-5
        print("OK")
    """)
    assert "OK" in out


def test_sharding_rules_divisibility():
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.parallel import sharding as sh
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        with sh.use_mesh_rules(mesh, None):
            # divisible: sharded on model
            s = sh.logical_sharding((16, 8), (None, "heads"), mesh)
            assert s.spec == jax.sharding.PartitionSpec(None, "model"), s.spec
            # indivisible: dropped
            s2 = sh.logical_sharding((16, 5), (None, "heads"), mesh)
            assert s2.spec == jax.sharding.PartitionSpec(None, None), s2.spec
            # one mesh axis never used twice
            s3 = sh.logical_sharding((8, 8), ("heads", "mlp"), mesh)
            assert list(s3.spec).count("model") == 1, s3.spec
        print("OK")
    """)
    assert "OK" in out


def test_elastic_reshard_and_training_step():
    """Train 5 steps on a (4,2) mesh, reshard to (2,2) (shrink), continue,
    and match the single-device trajectory."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.runtime import Trainer, TrainerConfig, reshard_state
        cfg = get_config("smollm-360m").reduced()
        tc = dict(steps=6, batch=4, seq_len=32, base_lr=1e-3, log_every=2)
        mesh1 = jax.make_mesh((4, 2), ("data", "model"))
        t1 = Trainer(cfg, TrainerConfig(**tc), mesh=mesh1)
        t1.run()
        # elastic shrink to 4 devices
        mesh2 = jax.make_mesh((2, 2), ("data", "model"))
        st2 = reshard_state(t1.state, mesh2)
        t2 = Trainer(cfg, TrainerConfig(**dict(tc, steps=10)), mesh=mesh2)
        t2.state = st2
        t2.run()
        assert int(jax.device_get(t2.state["step"])) == 10
        # reference: uninterrupted single-mesh run
        t3 = Trainer(cfg, TrainerConfig(**dict(tc, steps=10)), mesh=mesh1)
        t3.run()
        for a, b in zip(jax.tree_util.tree_leaves(t2.state["params"]),
                        jax.tree_util.tree_leaves(t3.state["params"])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-3, atol=2e-4)
        print("OK")
    """, devices=8, timeout=900)
    assert "OK" in out


@pytest.mark.parametrize("arch", ["smollm-360m", "granite-moe-1b-a400m",
                                  "mamba2-2.7b"])
def test_small_mesh_dryrun_reduced(arch):
    """lower+compile a reduced config on a 2x4 host mesh: validates the
    sharding machinery end-to-end without the 512-device production run."""
    out = _run(f"""
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.parallel import sharding as shlib
        from repro.launch import specs as sp
        import dataclasses
        from repro.config import ShapeCfg
        cfg = get_config("{arch}").reduced()
        shape = ShapeCfg("t", 64, 8, "train")
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        with shlib.use_mesh_rules(mesh, None):
            state_spec = sp.state_specs(cfg)
            batch_spec = sp.batch_specs(cfg, shape)
            in_sh = (sp.state_shardings(cfg, state_spec, mesh),
                     sp.batch_shardings(cfg, shape, mesh, batch_spec))
            step = sp.make_train_step(cfg)
            j = jax.jit(step, in_shardings=in_sh,
                        out_shardings=(in_sh[0], None), donate_argnums=(0,))
            c = j.lower(state_spec, batch_spec).compile()
        ca = c.cost_analysis()
        if isinstance(ca, (list, tuple)):   # older JAX returns [dict]
            ca = ca[0]
        assert ca.get("flops", 0) > 0
        print("OK")
    """, devices=8, timeout=600)
    assert "OK" in out
