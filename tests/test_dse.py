"""Analytical-model reproduction of the paper's own numbers (§4, §6)."""
import numpy as np

from repro.core import dse
from repro.core.roofline import collective_wire_bytes


def test_resource_model_paper_config():
    """8x48 fits the A10-1150 (paper's final config); the next K_vec step
    does not — the DSP constraint binds exactly as in the paper."""
    cfg = dse.DLAConfig(c_vec=8, k_vec=48)
    assert dse.fits_device(cfg)
    assert dse.n_dsps(cfg) == 1352                # 2304/2 + 200
    assert not dse.fits_device(dse.DLAConfig(c_vec=8, k_vec=56))


def test_table2_per_layer_efficiency():
    """Table 2 DSP efficiencies: conv5 exact, conv3/4 within 3%, FC ~100%."""
    cfg = dse.DLAConfig(c_vec=8, k_vec=48)
    r = dse.alexnet_throughput(cfg)
    eff = {l["name"]: l["dsp_eff"] for l in r["layers"]}
    paper = {"conv1": .829, "conv2": .625, "conv3": .724, "conv4": .724,
             "conv5": .626, "fc6": .998, "fc7": .996, "fc8": .990}
    assert abs(eff["conv5"] - paper["conv5"]) < 0.005      # exact
    for name in ("conv3", "conv4"):
        assert abs(eff[name] - paper[name]) < 0.03
    for name in ("fc6", "fc7", "fc8"):
        assert eff[name] > 0.97
    # conv1 (fold detail) and conv2 (5x5 chunking) within 15%
    for name in ("conv1", "conv2"):
        assert abs(eff[name] - paper[name]) < 0.15


def test_headline_throughput():
    """1020 img/s measured system throughput; our model (with the paper's
    measured 16% system overhead) lands within 15%."""
    cfg = dse.DLAConfig(c_vec=8, k_vec=48)
    r = dse.alexnet_throughput(cfg, system_overhead=0.16)
    assert abs(r["img_per_s"] - 1020) / 1020 < 0.15, r["img_per_s"]


def test_fig8_sweep_optimum():
    """Paper: the 8x48 point is 'one of the peak throughput numbers'.
    Our sweep must rank it within 2% of the global best."""
    rows = dse.explore_fpga()
    best = max(r["img_per_s"] for r in rows)
    p848 = next(r for r in rows if r["c_vec"] == 8 and r["k_vec"] == 48)
    assert p848["img_per_s"] > 0.98 * best
    # infeasible points are zeroed (Fig 8's plateaus-and-holes)
    assert any(r["img_per_s"] == 0 for r in rows)


def test_fc_batching_curve():
    """Eq. 6 crossover: at small batch FC layers are DDR-bound; at the
    paper's S_batch=96 they are compute-bound (~99% efficiency)."""
    lo = dse.fc_cycles(("fc6", 9216, 4096), dse.DLAConfig(s_batch=4))
    hi = dse.fc_cycles(("fc6", 9216, 4096), dse.DLAConfig(s_batch=96))
    assert lo["cycles"] / lo["ideal_cycles"] > 2.0      # bandwidth-bound
    assert hi["cycles"] / hi["ideal_cycles"] < 1.05     # compute-bound


def test_tpu_decode_batch_curve_saturates():
    """Same crossover on TPU decode (the paper's FC insight, ported):
    tokens/s/batch falls once compute catches up to weight streaming."""
    inp = dse.TPUModelInput(n_active=3e9, n_total=3e9, seq_len=32768,
                            global_batch=1, kind="decode", d_model=3072,
                            num_layers=28, cache_bytes_per_token=1e4)
    rows = dse.decode_batch_curve(inp, data=16, model=16)
    tps = [r["throughput_tokens_s"] for r in rows]
    assert tps[-1] > tps[0] * 4          # batching pays
    gain_early = tps[1] / tps[0]
    gain_late = tps[-1] / tps[-2]
    assert gain_early > gain_late        # diminishing returns (saturation)


def test_collective_parser():
    hlo = """
HloModule test
%body.1 (p: f32[128,256]) -> f32[128,256] {
  %ag = f32[128,256] all-gather(f32[8,256] %x), replica_groups=[16,16]<=[256], dimensions={0}
  ROOT %ar = f32[128,256] all-reduce(%ag), replica_groups={{0,1,2,3}}, to_apply=%add
}
ENTRY %main (p0: f32[128,256]) -> f32[128,256] {
  %w = f32[128,256] while(%p0), body=%body.1, condition=%cond.1
  %cp = f32[64,64] collective-permute(%y), source_target_pairs={{0,1}}
}
"""
    c1 = collective_wire_bytes(hlo, loop_trip_count=1)
    c10 = collective_wire_bytes(hlo, loop_trip_count=10)
    assert c1["count"] == 3
    assert c10["all-gather"] == 10 * c1["all-gather"]
    assert c10["all-reduce"] == 10 * c1["all-reduce"]
    assert c10["collective-permute"] == c1["collective-permute"]  # not in loop
    ag_bytes = 128 * 256 * 4
    assert abs(c1["all-gather"] - ag_bytes * 15 / 16) < 1
