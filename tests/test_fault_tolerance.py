"""Fault-tolerant serving: seeded injection, deadlines/retries, health
gating, route degradation, crash/recovery.

The adversarial core mirrors the bit-exactness contract of the serving
tests: whatever the chaos does — corrupted staging buffers, transient
launch failures, NaN logits — a request that completes must carry logits
bit-identical to the fault-free oracle (retries re-stage from the
pristine host image; degraded buckets serve the bit-checked direct
route), and a request that cannot complete must retire *reported* (shed
or expired), never vanish: ``submitted == completed + shed + expired``
on every drained engine.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.checkpoint import checkpoint
from repro.configs import get_config
from repro.models import alexnet
from repro.serving import (DEGRADED, HEALTHY, QUARANTINED, AdmissionController,
                           CnnEngine, CnnServeConfig, DrainTimeout,
                           EngineCrash, FaultInjector, FaultSpec,
                           HealthMonitor, ImageRequest, ModelRegistry,
                           TransientLaunchError, derive_seed)


@pytest.fixture(scope="module")
def served():
    """One reduced config + params + jitted direct-apply oracle."""
    cfg = get_config("alexnet").reduced()
    params = alexnet.init(jax.random.PRNGKey(0), cfg)
    ref = jax.jit(lambda p, x: alexnet.apply(p, cfg, x))
    return cfg, params, lambda x: np.asarray(ref(params, x))


def _images(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.standard_normal(
        (n, cfg.image_size, cfg.image_size, cfg.in_channels)
    ).astype(np.float32)


def _engine(cfg, params, *, faults=None, **kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("retry_backoff_ms", 0.01)   # keep test retries snappy
    return CnnEngine(cfg, CnnServeConfig(**kw), params=params, faults=faults)


def _balanced(eng):
    acc = eng.accounting()
    return acc["balanced"] and acc["in_flight"] == 0


# ---------------------------------------------------------------------------
# FaultInjector: determinism, independence, validation
# ---------------------------------------------------------------------------
def test_injector_rejects_unknown_point():
    with pytest.raises(ValueError, match="unknown fault points"):
        FaultInjector(0, {"launch.meteor": FaultSpec(rate=1.0)})
    with pytest.raises(AssertionError):
        FaultSpec(rate=1.5)


def test_injector_explicit_schedule_and_limit():
    inj = FaultInjector(0, {"launch.transient": FaultSpec(at=(1, 3),
                                                          limit=1)})
    hits = [inj.fire("launch.transient") is not None for _ in range(5)]
    assert hits == [False, True, False, False, False]     # limit=1 capped
    assert inj.summary()["launch.transient"] == {"opportunities": 5,
                                                 "fired": 1}


def test_injector_streams_independent_of_interleaving():
    """A point's firing pattern is a pure function of (seed, its own
    opportunity count) — calls at other points must not perturb it."""
    spec = {"retire.nonfinite": FaultSpec(rate=0.3),
            "launch.transient": FaultSpec(rate=0.5)}
    a, b = FaultInjector(7, spec), FaultInjector(7, spec)
    pat_a = []
    for i in range(64):
        if i % 3 == 0:                       # extra traffic on another point
            a.fire("launch.transient")
        pat_a.append(a.fire("retire.nonfinite") is not None)
    pat_b = [b.fire("retire.nonfinite") is not None for _ in range(64)]
    assert pat_a == pat_b


def test_injector_idle_never_draws_rng():
    inj = FaultInjector(3, {})               # armed but idle
    state = inj._rng["stage.corrupt"].bit_generator.state
    for _ in range(100):
        assert inj.fire("stage.corrupt") is None
    assert inj._rng["stage.corrupt"].bit_generator.state == state
    assert inj.total_fired == 0


def test_derive_seed_stable_and_distinct():
    assert derive_seed(0, "alexnet") == derive_seed(0, "alexnet")
    assert derive_seed(0, "alexnet") != derive_seed(0, "vgg16")
    assert derive_seed(0, "alexnet") != derive_seed(1, "alexnet")


# ---------------------------------------------------------------------------
# HealthMonitor state machine
# ---------------------------------------------------------------------------
def test_health_ladder_and_recovery():
    h = HealthMonitor(fail_threshold=2, quarantine_threshold=4,
                      cooldown_ms=0.0)
    assert h.state == HEALTHY and h.allow_launch()
    h.record_failure(); h.record_failure()
    assert h.state == DEGRADED and h.allow_launch()
    h.record_ok()
    assert h.state == HEALTHY                 # clean batch recovers
    for _ in range(4):
        h.record_failure()
    assert h.state == QUARANTINED
    assert h.allow_launch()                   # cooldown 0 -> half-open probe
    assert not h.allow_launch()               # only ONE probe in flight
    h.record_failure()                        # probe failed: re-armed
    assert h.state == QUARANTINED
    assert h.allow_launch()                   # next probe
    h.record_ok()                             # probe succeeded
    assert h.state == HEALTHY
    assert any(e == (QUARANTINED, HEALTHY, "probe-ok") for e in h.events)


def test_health_force_quarantine():
    h = HealthMonitor(cooldown_ms=1e6)
    h.force_quarantine("crash: boom")
    assert h.state == QUARANTINED and not h.allow_launch()


# ---------------------------------------------------------------------------
# deadlines + retries on the engine
# ---------------------------------------------------------------------------
def test_transient_launch_retries_then_bitmatch(served):
    """One injected launch failure: the group re-queues with backoff and
    the retried serve returns logits bit-identical to the oracle."""
    cfg, params, ref = served
    inj = FaultInjector(0, {"launch.transient": FaultSpec(at=(0,))})
    eng = _engine(cfg, params, faults=inj)
    imgs = _images(cfg, 3, seed=1)
    reqs = [ImageRequest(image=imgs[i]) for i in range(3)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done()
    assert all(r.done and r.attempts == 1 for r in reqs)
    assert np.array_equal(np.stack([r.logits for r in reqs]), ref(imgs))
    assert eng.images_retried == 3 and eng.batches_failed == 1
    assert _balanced(eng)


def test_retry_budget_exhaustion_expires_reported(served):
    """Permanent launch failure + bounded retries: every request retires
    as expired (reason recorded), nothing vanishes, no exception escapes
    step()."""
    cfg, params, _ = served
    inj = FaultInjector(0, {"launch.transient": FaultSpec(rate=1.0)})
    eng = _engine(cfg, params, faults=inj, quarantine_threshold=10 ** 6)
    reqs = [ImageRequest(image=im, retries=1) for im in _images(cfg, 3)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done()
    assert all(r.expired and r.expire_reason == "retries" and not r.done
               for r in reqs)
    assert eng.images_expired == 3 and eng.images_completed == 0
    assert _balanced(eng)


def test_deadline_expiry_at_admission(served):
    """A request already past its deadline when admitted never burns a
    forward — it retires expired with reason 'deadline'."""
    cfg, params, _ = served
    eng = _engine(cfg, params)
    late = ImageRequest(image=_images(cfg, 1)[0], deadline_ms=0.0)
    ok = ImageRequest(image=_images(cfg, 1, seed=2)[0])
    eng.submit(late)
    eng.submit(ok)
    eng.run_until_done()
    assert late.expired and late.expire_reason == "deadline" and not late.done
    assert ok.done
    assert eng.images_expired == 1 and eng.images_completed == 1
    assert _balanced(eng)


def test_nonfinite_logits_screened_and_retried(served):
    """Injected NaN in retired logits: the bad row is never served —
    it retries and the final logits bit-match the oracle."""
    cfg, params, ref = served
    inj = FaultInjector(0, {"retire.nonfinite": FaultSpec(at=(0,))})
    eng = _engine(cfg, params, faults=inj)
    imgs = _images(cfg, 3, seed=3)
    reqs = [ImageRequest(image=imgs[i]) for i in range(3)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done()
    assert all(r.done for r in reqs)
    # rows 1-2 retired from the original clean batch-3 forward; row 0
    # retried alone, so its oracle is the single-image forward (batch
    # *size* changes vectorization — only padding within a bucket is
    # bit-stable)
    assert np.array_equal(np.stack([r.logits for r in reqs[1:]]),
                          ref(imgs)[1:])
    assert np.array_equal(reqs[0].logits, ref(imgs[:1])[0])
    assert reqs[0].attempts == 1              # only row 0 was corrupted
    assert eng.images_retried == 1
    assert _balanced(eng)


def test_staging_corruption_recovers_from_pristine_image(served):
    """stage.corrupt NaNs the staged copy only; req.image survives, the
    screen catches the poisoned logits, and the retry re-stages clean."""
    cfg, params, ref = served
    inj = FaultInjector(0, {"stage.corrupt": FaultSpec(at=(0,))})
    eng = _engine(cfg, params, faults=inj)
    imgs = _images(cfg, 2, seed=4)
    reqs = [ImageRequest(image=imgs[i]) for i in range(2)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done()
    assert all(r.done for r in reqs)
    # row 1 survived the corrupted batch (batch rows are independent in
    # AlexNet); row 0 re-staged alone from the pristine req.image
    assert np.array_equal(reqs[1].logits, ref(imgs)[1])
    assert np.array_equal(reqs[0].logits, ref(imgs[:1])[0])
    assert np.isfinite(reqs[0].logits).all()
    assert _balanced(eng)


def test_crash_quarantines_then_probe_recovers(served):
    """A hard crash opens the circuit: front-door submits shed while
    quarantined, the half-open probe closes it, queued work completes."""
    cfg, params, ref = served
    inj = FaultInjector(0, {"launch.crash": FaultSpec(at=(0,), limit=1)})
    eng = _engine(cfg, params, faults=inj, cooldown_ms=0.0)
    imgs = _images(cfg, 2, seed=5)
    reqs = [ImageRequest(image=imgs[i]) for i in range(2)]
    for r in reqs:
        eng.submit(r)
    eng.step()                                # crash -> quarantined
    assert eng.health.state == QUARANTINED
    shed = ImageRequest(image=imgs[0])
    assert not eng.try_submit(shed) and shed.shed
    assert eng.shed_reasons == {"unhealthy": 1}
    eng.run_until_done()                      # probe launch recovers
    assert eng.health.state == HEALTHY
    assert all(r.done for r in reqs)
    assert np.array_equal(np.stack([r.logits for r in reqs]), ref(imgs))
    assert any(e["reason"] == "probe-ok"
               for e in eng.health.stats()["events"])
    assert _balanced(eng)


def test_quarantined_engine_expires_queued_deadlines(served):
    """While the circuit is open (long cooldown), queued deadline-bearing
    work drains via expiry instead of hoarding forever."""
    cfg, params, _ = served
    inj = FaultInjector(0, {"launch.crash": FaultSpec(at=(0,), limit=1)})
    eng = _engine(cfg, params, faults=inj, cooldown_ms=1e6)
    reqs = [ImageRequest(image=im, deadline_ms=5.0, retries=10)
            for im in _images(cfg, 3, seed=6)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done()
    assert all(r.expired for r in reqs)
    assert eng.health.state == QUARANTINED
    assert _balanced(eng)


def test_run_until_done_raises_drain_timeout(served):
    """Work still in flight after max_steps must raise (with a report),
    never return as if the requests evaporated."""
    cfg, params, _ = served
    inj = FaultInjector(0, {"launch.transient": FaultSpec(rate=1.0)})
    eng = _engine(cfg, params, faults=inj, quarantine_threshold=10 ** 6)
    for im in _images(cfg, 2, seed=7):
        eng.submit(ImageRequest(image=im, retries=10 ** 6))
    with pytest.raises(DrainTimeout) as ei:
        eng.run_until_done(max_steps=50)
    assert ei.value.report["retry_pending"] + ei.value.report["queued"] == 2
    assert not ei.value.report["drained"]


# ---------------------------------------------------------------------------
# route degradation ladder
# ---------------------------------------------------------------------------
def test_bucket_degrades_to_direct_route_bitmatch(served):
    """degrade_threshold repeated datapath failures flip the bucket onto
    the direct route; served logits bit-match the direct-route oracle and
    the event is recorded (not an outage)."""
    cfg, params, _ = served
    assert cfg.use_winograd                  # primary route is not direct
    inj = FaultInjector(0, {"launch.transient": FaultSpec(at=(0, 1))})
    eng = _engine(cfg, params, faults=inj, degrade_threshold=2,
                  quarantine_threshold=10)
    imgs = _images(cfg, 3, seed=8)
    reqs = [ImageRequest(image=imgs[i], retries=3) for i in range(3)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done()
    assert all(r.done for r in reqs)
    assert eng.stats()["degraded_buckets"] == [4]
    ev, = eng.degradations
    assert ev["from"] == "winograd" and ev["to"] == "direct"
    cfg_d = dataclasses.replace(cfg, use_winograd=False, use_pallas=False)
    ref_d = jax.jit(lambda p, x: alexnet.apply(p, cfg_d, x))

    def direct_oracle(ims):
        # oracle must mirror the serving path: *jitted* direct apply at
        # the engine's padded bucket shape (eager XLA fuses differently,
        # and only padding within one compiled shape is bit-stable)
        padded = np.zeros((4, *ims.shape[1:]), np.float32)
        padded[: len(ims)] = ims
        return np.asarray(ref_d(params, padded))[: len(ims)]

    assert np.array_equal(np.stack([r.logits for r in reqs]),
                          direct_oracle(imgs))
    # later traffic on the degraded bucket stays on the direct route
    more = [ImageRequest(image=im) for im in _images(cfg, 3, seed=9)]
    for r in more:
        eng.submit(r)
    eng.run_until_done()
    assert np.array_equal(np.stack([r.logits for r in more]),
                          direct_oracle(np.stack([r.image for r in more])))
    assert _balanced(eng)


# ---------------------------------------------------------------------------
# armed-but-idle parity
# ---------------------------------------------------------------------------
def test_armed_idle_injector_bit_identical(served):
    cfg, params, _ = served
    imgs = _images(cfg, 5, seed=10)
    eng = _engine(cfg, params)

    def serve():
        reqs = [ImageRequest(image=imgs[i]) for i in range(len(imgs))]
        for r in reqs:
            eng.submit(r)
        eng.run_until_done()
        return np.stack([r.logits for r in reqs])

    plain = serve()
    eng.arm_faults(FaultInjector(seed=11, specs={}))
    armed = serve()
    assert np.array_equal(plain, armed)


# ---------------------------------------------------------------------------
# registry: KeyError, health gating, fleet drain report
# ---------------------------------------------------------------------------
def test_registry_getitem_unknown_model_lists_registered(served):
    cfg, params, _ = served
    reg = ModelRegistry()
    reg.register("alexnet", cfg, CnnServeConfig(max_batch=2), params=params)
    with pytest.raises(KeyError, match=r"unknown model 'nope'.*alexnet"):
        reg["nope"]
    with pytest.raises(KeyError, match="unknown model"):
        reg.submit("nope", ImageRequest(image=_images(cfg, 1)[0]))


def test_registry_drain_timeout_and_fleet_health(served):
    cfg, params, _ = served
    inj = FaultInjector(0, {"launch.transient": FaultSpec(rate=1.0)})
    reg = ModelRegistry()
    reg.register("sick", cfg,
                 CnnServeConfig(max_batch=2, retry_backoff_ms=0.01,
                                quarantine_threshold=10 ** 6),
                 params=params, faults=inj)
    reg.submit("sick", ImageRequest(image=_images(cfg, 1)[0],
                                    retries=10 ** 6))
    with pytest.raises(DrainTimeout) as ei:
        reg.run_until_done(max_steps=40)
    assert not ei.value.report["sick"]["drained"]
    assert reg.stats()["fleet"]["health"]["sick"] in (HEALTHY, DEGRADED,
                                                      QUARANTINED)


# ---------------------------------------------------------------------------
# deadline-aware admission
# ---------------------------------------------------------------------------
def test_admission_tightens_budget_to_request_deadline():
    adm = AdmissionController(slo_ms=100.0)
    adm.observe_batch(1, 0.010)               # 10 ms per image
    assert adm.admit(5)                       # 50 ms wait < 100 ms SLO
    assert not adm.admit(5, deadline_ms=20.0)  # but busts a 20 ms deadline
    assert adm.admit(1, deadline_ms=20.0)


# ---------------------------------------------------------------------------
# crash/recovery: checkpointed params -> fresh engine -> bit-identical
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n_req", [1, 3, 4])
def test_checkpoint_recovery_bit_identical_serving(served, tmp_path, n_req):
    """Serve, checkpoint the params, rebuild a *fresh* engine from the
    restored checkpoint, and assert served logits are bit-identical for
    every bucket padding — crash recovery must not perturb results."""
    cfg, params, _ = served
    imgs = _images(cfg, n_req, seed=20 + n_req)

    def serve(p):
        eng = _engine(cfg, p)
        reqs = [ImageRequest(image=imgs[i]) for i in range(n_req)]
        for r in reqs:
            eng.submit(r)
        eng.run_until_done()
        return np.stack([r.logits for r in reqs])

    before = serve(params)
    checkpoint.save(str(tmp_path), {"step": 0, "params": params})
    restored = checkpoint.restore(str(tmp_path),
                                  {"step": 0, "params": params})
    after = serve(restored["params"])
    assert np.array_equal(before, after)


# ---------------------------------------------------------------------------
# accounting invariant property: mixed chaos x bucket paddings x seeds
# ---------------------------------------------------------------------------
def test_registry_accounting_property_mixed_chaos(served):
    """Property: ``submitted == completed + shed + expired`` holds on every
    drained engine — and fleet-wide — under mixed seeded chaos (transient
    launches, NaN retirements, staging corruption, a hard crash) over
    traffic that exercises every bucket padding (group sizes 1..max_batch)
    with a mix of deadline-bearing and unbounded requests."""
    cfg, params, _ = served
    chaos = {
        "launch.transient": FaultSpec(rate=0.25),
        "retire.nonfinite": FaultSpec(rate=0.15),
        "stage.corrupt": FaultSpec(rate=0.10),
        "launch.crash": FaultSpec(rate=0.05, limit=1),
    }
    buckets_seen = set()
    for seed in range(3):
        reg = ModelRegistry()
        for name in ("a", "b"):
            reg.register(name, cfg,
                         CnnServeConfig(max_batch=4, retry_backoff_ms=0.01,
                                        cooldown_ms=0.0),
                         params=params,
                         faults=FaultInjector(derive_seed(seed, name),
                                              chaos))
        rng = np.random.default_rng(seed)
        counts = {"a": 0, "b": 0}
        for burst in (1, 2, 3, 4, 3, 1, 4, 2):
            model = "a" if rng.uniform() < 0.5 else "b"
            for _ in range(burst):
                dl = 5.0 if rng.uniform() < 0.25 else None
                reg.submit(model, ImageRequest(
                    image=_images(cfg, 1, seed=counts[model])[0],
                    deadline_ms=dl, retries=2))
                counts[model] += 1
            reg.step()          # interleave serving with arrivals
        reg.run_until_done(max_steps=5000)
        fleet = {"submitted": 0, "completed": 0, "shed": 0, "expired": 0}
        for name in ("a", "b"):
            acc = reg[name].accounting()
            assert acc["balanced"] and acc["in_flight"] == 0, (seed, name,
                                                               acc)
            assert acc["submitted"] == counts[name]
            assert acc["submitted"] == (acc["completed"] + acc["shed"]
                                        + acc["expired"])
            for k in fleet:
                fleet[k] += acc[k]
            buckets_seen |= set(reg[name].bucket_counts)
        assert fleet["submitted"] == sum(counts.values()) == 20
        assert fleet["submitted"] == (fleet["completed"] + fleet["shed"]
                                      + fleet["expired"])
    # the sweep exercised every compiled padding shape in the ladder
    assert buckets_seen == {1, 2, 4}


def test_error_types_exported():
    assert issubclass(TransientLaunchError, RuntimeError)
    assert issubclass(EngineCrash, RuntimeError)
    assert TransientLaunchError.code == "RESOURCE_EXHAUSTED"
