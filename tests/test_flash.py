"""Blockwise attention: forward + custom-VJP backward vs dense reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn.flash import decode_attention, flash_attention


def dense_ref(q, k, v, causal, q_offset=0, kv_valid_len=None):
    B, Sq, H, D = q.shape
    _, Skv, KV, _ = k.shape
    g = H // KV
    kr = jnp.repeat(k, g, axis=2)
    vr = jnp.repeat(v, g, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q * (D ** -0.5), kr)
    kpos = jnp.arange(Skv)
    qpos = q_offset + jnp.arange(Sq)
    m = jnp.ones((Sq, Skv), bool)
    if causal:
        m = m & (qpos[:, None] >= kpos[None, :])
    if kv_valid_len is not None:
        m = m & (kpos[None, :] < kv_valid_len)
    s = jnp.where(m[None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vr)


@pytest.mark.parametrize("B,Sq,H,KV,D,causal", [
    (2, 64, 6, 2, 16, True), (2, 50, 4, 4, 8, True),
    (1, 37, 3, 1, 8, False), (2, 128, 8, 2, 32, True),
    (1, 17, 15, 5, 8, True)])
def test_flash_fwd_bwd(B, Sq, H, KV, D, causal):
    rng = np.random.default_rng(Sq)
    q = jnp.asarray(rng.standard_normal((B, Sq, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Sq, KV, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Sq, KV, D)), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, q_chunk=16, k_chunk=32)
    ref = dense_ref(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)
    f = lambda q, k, v: (flash_attention(q, k, v, causal=causal, q_chunk=16,
                                         k_chunk=32) * jnp.cos(q)).sum()
    r = lambda q, k, v: (dense_ref(q, k, v, causal) * jnp.cos(q)).sum()
    gf = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(r, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gf, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-4)


def test_flash_kv_valid_len():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((1, 8, 2, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 32, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 32, 2, 8)), jnp.float32)
    out = flash_attention(q, k, v, causal=False, kv_valid_len=20,
                          q_chunk=4, k_chunk=8)
    ref = dense_ref(q, k, v, False, kv_valid_len=20)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)


def test_flash_q_offset_matches_suffix():
    """Prefill continuation: q at offset T against a longer k/v."""
    rng = np.random.default_rng(1)
    Sfull, T = 48, 32
    q = jnp.asarray(rng.standard_normal((1, Sfull, 4, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, Sfull, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, Sfull, 2, 8)), jnp.float32)
    full = flash_attention(q, k, v, causal=True, q_chunk=8, k_chunk=16)
    tail = flash_attention(q[:, T:], k, v, causal=True, q_offset=T,
                           q_chunk=8, k_chunk=16)
    np.testing.assert_allclose(np.asarray(tail), np.asarray(full[:, T:]),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("B,S,H,KV,D,c", [
    (2, 64, 4, 2, 16, 16), (1, 50, 6, 3, 8, 16), (2, 128, 8, 8, 32, 32)])
def test_flash_banded_matches_dense(B, S, H, KV, D, c):
    """Lower-triangle-only chunk schedule == dense causal attention
    (fwd + all three gradients)."""
    rng = np.random.default_rng(S)
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KV, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KV, D)), jnp.float32)
    out = flash_attention(q, k, v, causal=True, q_chunk=c, banded=True)
    ref = dense_ref(q, k, v, True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)
    fb = lambda q, k, v: (flash_attention(q, k, v, causal=True, q_chunk=c,
                                          banded=True) * jnp.cos(q)).sum()
    fr = lambda q, k, v: (dense_ref(q, k, v, True) * jnp.cos(q)).sum()
    gb = jax.grad(fb, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(fr, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gb, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-4)


def test_decode_attention_vector_lengths():
    rng = np.random.default_rng(2)
    B, S, H, KV, D = 3, 24, 4, 2, 8
    q = jnp.asarray(rng.standard_normal((B, 1, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KV, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KV, D)), jnp.float32)
    lengths = jnp.asarray([5, 24, 13], jnp.int32)
    out = decode_attention(q, k, v, lengths)
    for b in range(B):
        ref = dense_ref(q[b:b + 1], k[b:b + 1], v[b:b + 1], False,
                        kv_valid_len=int(lengths[b]))
        np.testing.assert_allclose(np.asarray(out[b:b + 1]), np.asarray(ref),
                                   rtol=1e-4, atol=1e-5)
