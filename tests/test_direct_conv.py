"""Strided direct Pallas conv kernel vs the lax oracle (paper §3.3/§3.5).

The direct kernel is the pallas route's datapath for every geometry the
Winograd kernel can't take (AlexNet conv1's 11x11 stride 4, conv2's 5x5,
pointwise, ...).  The hypothesis suite sweeps random kernel sizes (1-11),
strides (1-4), groups, SAME/VALID, and the fusion flags against
``lax.conv_general_dilated`` (+ the unfused epilogue reference) in
interpret mode on CPU; deterministic sweeps pin the AlexNet geometries,
block decompositions, and the filter-cache batch grid.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import assume, given, settings, st  # optional-hypothesis shim

from repro.kernels.conv.direct import conv2d_direct, same_pad
from repro.kernels.conv.ref import conv2d_ref
from repro.nn.conv import conv_out_hw
from repro.nn.pooling import LrnParams, apply_epilogue


def _ref(x, w, b, *, stride, padding, groups=1, relu=False, lrn=None,
         pool=None):
    y = conv2d_ref(x, w, b, stride=stride, padding=padding, groups=groups,
                   relu=relu)
    return apply_epilogue(y, lrn, pool)


@given(kernel=st.integers(1, 11), stride=st.integers(1, 4),
       padding=st.sampled_from(["SAME", "VALID"]),
       groups=st.sampled_from([1, 2]), relu=st.booleans(),
       fuse_lrn=st.booleans(), fuse_pool=st.booleans(),
       seed=st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_direct_kernel_matches_lax_oracle(kernel, stride, padding, groups,
                                          relu, fuse_lrn, fuse_pool, seed):
    """Random geometry sweep: the strided Pallas kernel == lax conv + the
    unfused conv->lrn->pool reference."""
    H = max(kernel + 2, 3 * stride)
    assume(conv_out_hw(H, kernel, stride, padding) >= 1)
    assume(not fuse_pool or conv_out_hw(H, kernel, stride, padding) >= 3)
    rng = np.random.default_rng(seed)
    c_in, c_out = 4 * groups, 2 * groups
    x = jnp.asarray(rng.standard_normal((2, H, H, c_in)), jnp.float32)
    w = jnp.asarray(rng.standard_normal(
        (kernel, kernel, c_in // groups, c_out)) * 0.3, jnp.float32)
    b = jnp.asarray(rng.standard_normal((c_out,)), jnp.float32)
    lrn = LrnParams() if fuse_lrn else None
    pool = (3, 2) if fuse_pool else None
    out = conv2d_direct(x, w, b, stride=stride, padding=padding,
                        groups=groups, relu=relu, lrn=lrn, pool=pool,
                        interpret=True)
    ref = _ref(x, w, b, stride=stride, padding=padding, groups=groups,
               relu=relu, lrn=lrn, pool=pool)
    assert out.shape == ref.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


# the strided AlexNet geometries the Winograd kernel cannot serve
ALEXNET_DIRECT = [
    ("conv1", dict(stride=4, padding="VALID", relu=True,
                   lrn=LrnParams(), pool=(3, 2)), 11, 35, 3, 16),
    ("conv2", dict(stride=1, padding="SAME", groups=2, relu=True,
                   lrn=LrnParams(), pool=(3, 2)), 5, 13, 16, 32),
]


@pytest.mark.parametrize("name,kw,r,H,c_in,c_out", ALEXNET_DIRECT)
def test_direct_kernel_alexnet_geometries(name, kw, r, H, c_in, c_out):
    rng = np.random.default_rng(hash(name) % 100)
    x = jnp.asarray(rng.standard_normal((3, H, H, c_in)), jnp.float32)
    w = jnp.asarray(rng.standard_normal(
        (r, r, c_in // kw.get("groups", 1), c_out)) * r ** -2, jnp.float32)
    b = jnp.asarray(rng.standard_normal((c_out,)), jnp.float32)
    out = conv2d_direct(x, w, b, interpret=True, **kw)
    ref = _ref(x, w, b, groups=kw.get("groups", 1), stride=kw["stride"],
               padding=kw["padding"], relu=kw["relu"], lrn=kw["lrn"],
               pool=kw["pool"])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4, err_msg=name)


@pytest.mark.parametrize("c_block,k_block,batch_block", [
    (4, 4, 1),     # multi c/k blocks, no filter-cache batching
    (4, 5, 2),     # non-dividing k_block widens to K; Bb=2 over B=3
    (None, 128, 8),  # auto c_block (full C resident), Bb > B clamps
])
def test_direct_kernel_block_decompositions(c_block, k_block, batch_block):
    """Channel-block reduction, per-k-block deposit, and the batch-innermost
    filter-cache grid must be invisible in the output for any blocking."""
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.standard_normal((3, 17, 17, 12)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((5, 5, 6, 8)) * 0.1, jnp.float32)
    b = jnp.asarray(rng.standard_normal((8,)), jnp.float32)
    p = LrnParams()
    out = conv2d_direct(x, w, b, stride=2, groups=2, relu=True, lrn=p,
                        pool=(3, 2), c_block=c_block, k_block=k_block,
                        batch_block=batch_block, interpret=True)
    ref = _ref(x, w, b, stride=2, padding="SAME", groups=2, relu=True, lrn=p,
               pool=(3, 2))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_winograd_kernel_filter_cache_batching():
    """Same invariant on the Winograd kernel's batch-innermost grid: any
    batch_block (dividing or not) gives the per-image answer."""
    from repro.kernels.conv.winograd import conv2d_winograd
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.standard_normal((5, 13, 13, 8)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 3, 4, 6)) * 0.2, jnp.float32)
    b = jnp.asarray(rng.standard_normal((6,)), jnp.float32)
    ref = conv2d_ref(x, w, b, groups=2, relu=True)
    for bb in (1, 2, 5, 8):
        out = conv2d_winograd(x, w, b, groups=2, relu=True,
                              batch_block=bb, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4, err_msg=f"Bb={bb}")


def test_same_pad_matches_lax_semantics():
    """same_pad must reproduce XLA's SAME padding split exactly (low side
    gets the floor) for every (extent, kernel, stride)."""
    for extent in (5, 7, 10, 13, 27):
        for r in (1, 2, 3, 5, 11):
            for s in (1, 2, 3, 4):
                out, lo, hi = same_pad(extent, r, s)
                assert out == -(-extent // s)
                assert lo + hi == max((out - 1) * s + r - extent, 0)
                assert lo == (lo + hi) // 2


def test_fused_pool_stride_exceeds_window_both_kernels():
    """pool_stride > pool_window: the pooled windows skip trailing conv
    rows, so the row plan reads fewer rows than the conv extent — both
    Pallas kernels must crop instead of mis-padding (negative pad crash)."""
    from repro.kernels.conv.winograd import conv2d_winograd
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.standard_normal((1, 12, 12, 4)), jnp.float32)
    wd = jnp.asarray(rng.standard_normal((3, 3, 4, 4)) * 0.3, jnp.float32)
    out = conv2d_direct(x, wd, None, stride=2, padding="VALID", relu=True,
                        pool=(3, 4), interpret=True)
    ref = _ref(x, wd, None, stride=2, padding="VALID", relu=True,
               pool=(3, 4))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    out = conv2d_winograd(x, wd, None, padding="VALID", relu=True,
                          pool=(3, 4), interpret=True)
    ref = _ref(x, wd, None, stride=1, padding="VALID", relu=True,
               pool=(3, 4))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_direct_kernel_even_stride_tail_rows():
    """VALID stride-3 on an extent the windows don't cover exactly: the
    kernel must crop the unread tail rows/cols, not mis-pad them."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((1, 14, 11, 4)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((4, 4, 4, 5)) * 0.2, jnp.float32)
    out = conv2d_direct(x, w, None, stride=3, padding="VALID",
                        interpret=True)
    ref = _ref(x, w, None, stride=3, padding="VALID")
    assert out.shape == ref.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
