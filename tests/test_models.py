"""Per-arch smoke tests (reduced configs) + prefill/decode consistency.

Every assigned architecture instantiates a reduced same-family config and
runs one forward + one train step on CPU, asserting shapes and finiteness.
Representatives of each cache structure additionally verify that
prefill+decode reproduces teacher-forced logits (MoE capacity unconstrained
so routing is deterministic across groupings).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config
from repro.models import model_for
from repro.optim import adamw_step, init_state


def _batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, cfg.vocab_size, size=(B, S + 1))
    batch = {"inputs": jnp.asarray(toks[:, :-1], jnp.int32),
             "targets": jnp.asarray(toks[:, 1:], jnp.int32)}
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, 16, cfg.d_model)) * 0.1, jnp.float32)
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.standard_normal((B, cfg.num_patches, 1024)) * 0.1,
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    mod = model_for(cfg)
    params = mod.init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    kw = {k: v for k, v in batch.items() if k in ("frames", "patches")}
    logits, _, _ = mod.apply(params, cfg, batch["inputs"], mode="train", **kw)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), "non-finite logits"

    (loss, metrics), grads = jax.value_and_grad(
        mod.loss_fn, has_aux=True)(params, cfg, batch)
    assert bool(jnp.isfinite(loss))
    state = init_state(params)
    state, om = adamw_step(state, grads, lr=1e-3)
    assert int(state["step"]) == 1
    assert bool(jnp.isfinite(om["grad_norm"]))
    # params actually moved
    moved = any(
        float(jnp.abs(a - b).max()) > 0
        for a, b in zip(jax.tree_util.tree_leaves(state["params"]),
                        jax.tree_util.tree_leaves(params)))
    assert moved


@pytest.mark.parametrize("arch", [
    "llama3.2-3b",            # GQA + RoPE cache
    "starcoder2-15b",         # layernorm/gelu/bias variant
    "deepseek-v2-lite-16b",   # MLA absorbed decode + MoE
    "mamba2-2.7b",            # SSD state decode
    "jamba-v0.1-52b",         # hybrid period-8 pattern
    "whisper-tiny",           # enc-dec cross-attention cache
    "phi-3-vision-4.2b",      # patch-prefix cache offsets
])
def test_prefill_decode_matches_teacher_forcing(arch):
    cfg = get_config(arch).reduced()
    if cfg.moe:   # unconstrained capacity => grouping-independent routing
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=100.0))
    mod = model_for(cfg)
    params = mod.init(jax.random.PRNGKey(0), cfg)
    B, S, dec = 2, 24, 3
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + dec)),
                       jnp.int32)
    kw, cs_kw, extra = {}, {}, 0
    if cfg.family == "audio":
        kw["frames"] = jnp.asarray(
            rng.standard_normal((B, 16, cfg.d_model)) * 0.1, jnp.float32)
        cs_kw = {"cross_len": 16}
    if cfg.family == "vlm":
        kw["patches"] = jnp.asarray(
            rng.standard_normal((B, cfg.num_patches, 1024)) * 0.1,
            jnp.float32)
        extra = cfg.num_patches
    full, _, _ = mod.apply(params, cfg, toks, mode="train", **kw)
    cache = jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype),
        mod.cache_shape(cfg, B, S + dec, **cs_kw))
    lp, cache, _ = mod.apply(params, cfg, toks[:, :S], mode="prefill",
                             caches=cache, **kw)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(full[:, :S]),
                               rtol=5e-2, atol=5e-2)
    length = S + extra
    for i in range(dec):
        ld, cache, _ = mod.apply(params, cfg, toks[:, S + i:S + i + 1],
                                 mode="decode", length=jnp.int32(length),
                                 caches=cache)
        np.testing.assert_allclose(np.asarray(ld[:, 0]),
                                   np.asarray(full[:, S + i]),
                                   rtol=5e-2, atol=5e-2)
        length += 1


def test_pattern_periodicity():
    """jamba: attention at index 4 of 8; MoE at odd indices; deepseek:
    first layer dense, rest MoE."""
    j = get_config("jamba-v0.1-52b")
    kinds = [j.layer_kind(i) for i in range(j.num_layers)]
    assert [k[0] for k in kinds[:8]] == ["ssm"] * 4 + ["attn"] + ["ssm"] * 3
    assert [k[1] for k in kinds[:4]] == ["mlp", "moe", "mlp", "moe"]
    d = get_config("deepseek-v2-lite-16b")
    assert d.layer_kind(0) == ("attn", "mlp")
    assert d.layer_kind(1) == ("attn", "moe")
    assert d.layer_kind(26) == ("attn", "moe")


def test_alexnet_smoke():
    from repro.models import alexnet
    cfg = get_config("alexnet")
    assert alexnet._fc_input_dim(cfg) == 9216     # matches Krizhevsky
    rcfg = cfg.reduced()
    params = alexnet.init(jax.random.PRNGKey(0), rcfg)
    imgs = jax.random.normal(jax.random.PRNGKey(1),
                             (4, rcfg.image_size, rcfg.image_size, 3))
    loss, m = alexnet.loss_fn(params, rcfg,
                              {"images": imgs,
                               "labels": jnp.asarray([0, 1, 2, 3])})
    assert bool(jnp.isfinite(loss))
    lw = alexnet.apply(params, rcfg, imgs)
    ld = alexnet.apply(params,
                       dataclasses.replace(rcfg, use_winograd=False), imgs)
    np.testing.assert_allclose(np.asarray(lw), np.asarray(ld),
                               rtol=1e-4, atol=1e-4)
