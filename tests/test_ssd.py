"""SSD (Mamba-2) properties: chunked == recurrence, decode continuation."""
import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.kernels.ssd.ref import ssd_reference
from repro.nn.ssd import ssd_chunked, ssd_decode_step


@given(L=st.integers(4, 80), chunk=st.sampled_from([4, 16, 64]),
       H=st.sampled_from([2, 4]), G=st.sampled_from([1, 2]),
       seed=st.integers(0, 100))
@settings(max_examples=15, deadline=None)
def test_chunked_equals_recurrence(L, chunk, H, G, seed):
    rng = np.random.default_rng(seed)
    B, P, N = 2, 4, 8
    x = jnp.asarray(rng.standard_normal((B, L, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.2, (B, L, H)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.2, 4.0, (H,)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B, L, G, N)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((B, L, G, N)), jnp.float32)
    y_c, s_c = ssd_chunked(x, dt, A, Bm, Cm, chunk)
    y_r, s_r = ssd_reference(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_r),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_c), np.asarray(s_r),
                               rtol=1e-4, atol=1e-4)


def test_decode_continues_chunked_state():
    """Prefill L tokens chunked, then decode token L+1 recurrently — must
    equal the full chunked pass over L+1 tokens."""
    rng = np.random.default_rng(0)
    B, L, H, P, G, N = 1, 32, 2, 4, 1, 8
    x = jnp.asarray(rng.standard_normal((B, L + 1, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (B, L + 1, H)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (H,)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B, L + 1, G, N)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((B, L + 1, G, N)), jnp.float32)
    y_full, _ = ssd_chunked(x, dt, A, Bm, Cm, 16)
    _, state = ssd_chunked(x[:, :L], dt[:, :L], A, Bm[:, :L], Cm[:, :L], 16)
    y_dec, _ = ssd_decode_step(x[:, L:], dt[:, L:], A, Bm[:, L:], Cm[:, L:],
                               state)
    np.testing.assert_allclose(np.asarray(y_dec[:, 0]),
                               np.asarray(y_full[:, L]),
                               rtol=1e-4, atol=1e-4)


def test_state_decay_property():
    """With dt*|A| large, the state forgets: output at position t depends
    only on recent tokens."""
    rng = np.random.default_rng(1)
    B, L, H, P, G, N = 1, 64, 1, 2, 1, 4
    x = jnp.asarray(rng.standard_normal((B, L, H, P)), jnp.float32)
    dt = jnp.full((B, L, H), 5.0, jnp.float32)          # huge decay
    A = jnp.asarray([-10.0], jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B, L, G, N)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((B, L, G, N)), jnp.float32)
    y1, _ = ssd_chunked(x, dt, A, Bm, Cm, 16)
    x2 = x.at[:, :L // 2].set(0.0)                      # perturb distant past
    y2, _ = ssd_chunked(x2, dt, A, Bm, Cm, 16)
    np.testing.assert_allclose(np.asarray(y1[:, -1]), np.asarray(y2[:, -1]),
                               rtol=1e-5, atol=1e-5)
