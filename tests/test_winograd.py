"""Winograd transform + convolution correctness (paper §3.3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st  # optional-hypothesis shim

from repro.core.winograd import (conv1d_depthwise_causal, conv2d_direct,
                                 conv2d_hbm_bytes, conv2d_winograd,
                                 conv_flops, winograd_transform)
from repro.kernels.conv.ref import conv2d_ref
from repro.nn.conv import ConvSpec, dispatch_conv, resolve_route


@given(m=st.integers(2, 4), r=st.integers(2, 5))
@settings(max_examples=12, deadline=None)
def test_transform_bilinear_identity(m, r):
    """A^T[(Gg) ⊙ (B^T d)] == correlation, for random g, d (any m, r)."""
    t = winograd_transform(m, r)
    rng = np.random.default_rng(m * 10 + r)
    g = rng.standard_normal((r,))
    d = rng.standard_normal((t.n,))
    o = t.AT @ ((t.G @ g) * (t.BT @ d))
    o_ref = np.array([np.dot(g, d[j:j + r]) for j in range(m)])
    np.testing.assert_allclose(o, o_ref, rtol=1e-6, atol=1e-8)


def test_f43_paper_ratio():
    """Paper's F(4,3): 4 outputs with 6 instead of 12 multiplies (2x)."""
    assert winograd_transform(4, 3).mult_ratio == 2.0
    # Mamba's k=4 depthwise conv via F(3,4): also 2x
    assert winograd_transform(3, 4).mult_ratio == 2.0


@given(st.integers(5, 70), st.integers(1, 9), st.integers(3, 4),
       st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_conv1d_depthwise_matches_direct(L, C, r, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((2, L, C)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((r, C)), jnp.float32)
    xp = jnp.pad(x, ((0, 0), (r - 1, 0), (0, 0)))
    ref = sum(xp[:, i:i + L, :] * w[i] for i in range(r))
    out = conv1d_depthwise_causal(x, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("m", [2, 4])
@pytest.mark.parametrize("padding", ["SAME", "VALID"])
@pytest.mark.parametrize("hw", [(13, 13), (8, 20), (5, 5)])
def test_conv2d_matches_direct(m, padding, hw):
    rng = np.random.default_rng(0)
    H, W = hw
    x = jnp.asarray(rng.standard_normal((2, H, W, 6)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 3, 6, 5)) * 0.2, jnp.float32)
    ref = conv2d_direct(x, w, stride=1, padding=padding)
    out = conv2d_winograd(x, w, m=m, padding=padding)
    assert out.shape == ref.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_conv2d_gradients():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((1, 9, 9, 4)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 3, 4, 3)) * 0.2, jnp.float32)
    gw = jax.grad(lambda w: conv2d_winograd(x, w).sum())(w)
    gr = jax.grad(lambda w: conv2d_direct(x, w).sum())(w)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(gr),
                               rtol=1e-3, atol=1e-3)


def test_flops_accounting():
    direct, wino = conv_flops(13, 13, 256, 384, 3, winograd_m=4)
    assert direct == 13 * 13 * 256 * 384 * 9
    # ~2.6x fewer multiplies for 13x13 with F(4,3) (4.5x ideal for r=3, m=4
    # in 2D, minus tile padding of 13 -> 16)
    assert 1.7 < direct / wino < 3.0


# ---------------------------------------------------------------------------
# fused conv pipeline: both routes vs jax.lax.conv_general_dilated
# ---------------------------------------------------------------------------
def _lax_ref(x, w, b, *, padding, groups, relu):
    y = jax.lax.conv_general_dilated(
        x, w, (1, 1), padding, dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)
    y = y + b
    return jax.nn.relu(y) if relu else y


@pytest.mark.parametrize("route", ["winograd", "pallas"])
@pytest.mark.parametrize("padding,groups,relu", [
    ("SAME", 1, False), ("VALID", 1, True), ("SAME", 2, True),
    ("VALID", 2, False)])
def test_fused_conv_matches_lax(route, padding, groups, relu):
    """Grouped / VALID / fused bias+ReLU parity on both conv routes."""
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.standard_normal((2, 13, 13, 8)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 3, 8 // groups, 10)) * 0.2,
                    jnp.float32)
    b = jnp.asarray(rng.standard_normal((10,)), jnp.float32)
    ref = _lax_ref(x, w, b, padding=padding, groups=groups, relu=relu)
    spec = ConvSpec(kernel=3, padding=padding, groups=groups, relu=relu,
                    route=route)
    out = dispatch_conv(spec, x, w, b, interpret=True)
    assert out.shape == ref.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_fused_matches_unfused_reference():
    """Fused bias+ReLU epilogue == unfused conv -> +bias -> relu (1e-4)."""
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.standard_normal((1, 12, 12, 6)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 3, 6, 4)) * 0.3, jnp.float32)
    b = jnp.asarray(rng.standard_normal((4,)), jnp.float32)
    for route in ("winograd", "pallas"):
        spec = ConvSpec(kernel=3, relu=True, route=route)
        fused = dispatch_conv(spec, x, w, b, interpret=True)
        unfused = jax.nn.relu(
            dispatch_conv(ConvSpec(kernel=3, route=route), x, w,
                          interpret=True) + b)
        np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused),
                                   rtol=1e-4, atol=1e-4)


def test_dispatch_route_fallback():
    """Non-eligible specs fall back per route policy: the jnp winograd path
    (stride-1 3x3 math only) degrades to direct, while pallas serves every
    geometry via the strided direct kernel — no model branching needed."""
    assert resolve_route(ConvSpec(kernel=3)) == "winograd"
    assert resolve_route(ConvSpec(kernel=3, route="pallas")) == "pallas"
    assert resolve_route(ConvSpec(kernel=11, stride=4, route="pallas")) == \
        "pallas"
    assert resolve_route(ConvSpec(kernel=5, route="winograd")) == "direct"
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((1, 11, 11, 4)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((5, 5, 2, 6)) * 0.2, jnp.float32)
    b = jnp.asarray(rng.standard_normal((6,)), jnp.float32)
    spec = ConvSpec(kernel=5, groups=2, relu=True, route="winograd")
    out = dispatch_conv(spec, x, w, b)
    ref = conv2d_ref(x, w, b, groups=2, relu=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_hbm_traffic_model():
    """Stream-buffered path must beat the host-tiled path whenever the tile
    tensor inflates traffic (the paper's §3.5 bandwidth argument)."""
    hb = conv2d_hbm_bytes(8, 13, 13, 256, 384, 3, 4)
    assert hb["tile_inflation"] > 2.0        # (n/m)^2 = 2.25 at 13->16 pad
    assert hb["savings"] > 1.0
    # single k/c block: stream path reads the raw slab exactly once
    hb1 = conv2d_hbm_bytes(1, 16, 16, 64, 64, 3, 4, c_block=64, k_block=64)
    assert hb1["stream_bytes"] == 1 * 18 * 18 * 64 * 4
