"""nn/conv.py routing: resolve_route truth table + dispatch equivalence.

`resolve_route` is the single policy point every model conv goes through
(PR-1's ConvSpec dispatch layer); these tests pin the full route x
eligibility truth table and, property-based, that every route agrees with
the direct `lax.conv_general_dilated` oracle for random geometry —
including the silent ``pallas``/``winograd`` -> ``direct`` fallback.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import HAVE_HYPOTHESIS, assume, given, settings, st  # optional shim

from repro.kernels.winograd.ref import conv2d_ref
from repro.nn.conv import ROUTES, ConvSpec, dispatch_conv, resolve_route

# geometry -> winograd eligibility (stride 1 and 3x3 kernel, paper F(4,3))
GEOMETRIES = [
    (3, 1, True),     # the paper's Winograd layers
    (3, 2, False),    # right kernel, wrong stride
    (5, 1, False),    # wrong kernel
    (1, 1, False),    # pointwise
    (11, 4, False),   # AlexNet conv1
]


@pytest.mark.parametrize("route", ROUTES)
@pytest.mark.parametrize("kernel,stride,eligible", GEOMETRIES)
def test_resolve_route_truth_table(route, kernel, stride, eligible):
    """Every route x eligibility combination, exhaustively."""
    spec = ConvSpec(kernel=kernel, stride=stride, route=route)
    assert spec.winograd_eligible == eligible
    got = resolve_route(spec)
    if route == "direct":
        expect = "direct"                      # explicit direct never changes
    elif route == "auto":
        expect = "winograd" if eligible else "direct"
    else:  # winograd / pallas honored only when eligible
        expect = route if eligible else "direct"
    assert got == expect, (spec, got, expect)
    assert got != "auto"                       # always fully resolved


def test_resolve_route_never_auto_never_invalid():
    for route in ROUTES:
        for kernel, stride, _ in GEOMETRIES:
            r = resolve_route(ConvSpec(kernel=kernel, stride=stride,
                                       route=route))
            assert r in ("direct", "winograd", "pallas")


def test_silent_pallas_fallback_is_exactly_direct():
    """Ineligible pallas/winograd specs take the *identical* code path as
    route="direct": bit-equal outputs, not merely close."""
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal((2, 9, 9, 4)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((5, 5, 2, 6)) * 0.2, jnp.float32)
    b = jnp.asarray(rng.standard_normal((6,)), jnp.float32)
    kw = dict(kernel=5, stride=2, groups=2, relu=True)
    ref = dispatch_conv(ConvSpec(route="direct", **kw), x, w, b)
    for route in ("pallas", "winograd", "auto"):
        spec = ConvSpec(route=route, **kw)
        assert resolve_route(spec) == "direct"
        out = dispatch_conv(spec, x, w, b)
        assert np.array_equal(np.asarray(out), np.asarray(ref)), route


def test_invalid_spec_rejected():
    with pytest.raises(AssertionError):
        ConvSpec(kernel=3, route="nonsense")
    with pytest.raises(AssertionError):
        ConvSpec(kernel=3, padding="FULL")
    with pytest.raises(AssertionError):
        # weight geometry must match the spec
        dispatch_conv(ConvSpec(kernel=3),
                      jnp.zeros((1, 8, 8, 4)), jnp.zeros((5, 5, 4, 2)))


# ---------------------------------------------------------------------------
# property tests: route equivalence on random geometry (tests/_hyp.py shim)
# ---------------------------------------------------------------------------
def _conv_out_hw(h, kernel, stride, padding):
    return ((h - kernel) // stride + 1 if padding == "VALID"
            else -(-h // stride))


def _run_spec(route, kernel, stride, padding, groups, relu, fuse_bias, seed,
              interpret=None, fuse_lrn=False, fuse_pool=False, H=8):
    rng = np.random.default_rng(seed)
    c_in, c_out = 4 * groups, 2 * groups
    x = jnp.asarray(rng.standard_normal((1, H, H, c_in)), jnp.float32)
    w = jnp.asarray(rng.standard_normal(
        (kernel, kernel, c_in // groups, c_out)) * 0.3, jnp.float32)
    b = jnp.asarray(rng.standard_normal((c_out,)), jnp.float32)
    spec = ConvSpec(kernel=kernel, stride=stride, padding=padding,
                    groups=groups, relu=relu, fuse_bias=fuse_bias,
                    fuse_lrn=fuse_lrn, fuse_pool=fuse_pool, route=route)
    out = dispatch_conv(spec, x, w, b, interpret=interpret)
    ref = conv2d_ref(x, w, b, stride=stride, padding=padding, groups=groups,
                     relu=relu)
    from repro.nn.pooling import apply_epilogue
    ref = apply_epilogue(ref, spec.lrn if fuse_lrn else None,
                         (spec.pool_window, spec.pool_stride) if fuse_pool
                         else None)
    return spec, np.asarray(out), np.asarray(ref)


@given(kernel=st.sampled_from([1, 3, 5]), stride=st.sampled_from([1, 2]),
       padding=st.sampled_from(["SAME", "VALID"]),
       groups=st.sampled_from([1, 2]), relu=st.booleans(),
       fuse_bias=st.booleans(), fuse_lrn=st.booleans(),
       fuse_pool=st.booleans(), seed=st.integers(0, 1000))
@settings(max_examples=25, deadline=None)
def test_auto_and_winograd_routes_match_direct(kernel, stride, padding,
                                               groups, relu, fuse_bias,
                                               fuse_lrn, fuse_pool, seed):
    """auto/winograd == unfused conv->lrn->pool oracle for random
    stride/padding/groups/fusion flags, whether the spec resolves to
    winograd or silently falls back."""
    H = 9
    assume(not fuse_pool or _conv_out_hw(H, kernel, stride, padding) >= 3)
    for route in ("auto", "winograd"):
        spec, out, ref = _run_spec(route, kernel, stride, padding, groups,
                                   relu, fuse_bias, seed, fuse_lrn=fuse_lrn,
                                   fuse_pool=fuse_pool, H=H)
        assert out.shape == ref.shape, spec
        if resolve_route(spec) == "direct" and not (fuse_lrn or fuse_pool):
            np.testing.assert_array_equal(out, ref, err_msg=str(spec))
        else:
            np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3,
                                       err_msg=str(spec))


@given(kernel=st.sampled_from([3, 5]), stride=st.sampled_from([1, 2]),
       padding=st.sampled_from(["SAME", "VALID"]),
       groups=st.sampled_from([1, 2]), relu=st.booleans(),
       fuse_lrn=st.booleans(), fuse_pool=st.booleans(),
       seed=st.integers(0, 1000))
@settings(max_examples=8, deadline=None)
def test_pallas_route_matches_direct(kernel, stride, padding, groups, relu,
                                     fuse_lrn, fuse_pool, seed):
    """pallas (interpret mode on CPU) == unfused oracle, incl. the in-kernel
    LRN/pool epilogue; ineligible specs exercise the silent pallas ->
    direct fallback."""
    H = 9
    assume(not fuse_pool or _conv_out_hw(H, kernel, stride, padding) >= 3)
    spec, out, ref = _run_spec("pallas", kernel, stride, padding, groups,
                               relu, True, seed, interpret=True,
                               fuse_lrn=fuse_lrn, fuse_pool=fuse_pool, H=H)
    assert out.shape == ref.shape, spec
    if resolve_route(spec) == "direct" and not (fuse_lrn or fuse_pool):
        np.testing.assert_array_equal(out, ref, err_msg=str(spec))
    else:
        np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3,
                                   err_msg=str(spec))


def test_property_suite_present():
    """Tier-1 sanity: the property tests above exist and either ran (with
    hypothesis) or skipped cleanly (without)."""
    assert callable(test_auto_and_winograd_routes_match_direct)
    assert callable(test_pallas_route_matches_direct)
    assert HAVE_HYPOTHESIS in (True, False)
