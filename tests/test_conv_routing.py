"""nn/conv.py routing: resolve_route/resolve_kernel truth table + dispatch
equivalence.

`resolve_route` is the single policy point every model conv goes through
(PR-1's ConvSpec dispatch layer); these tests pin the full route x
eligibility truth table and, property-based, that every route agrees with
the direct `lax.conv_general_dilated` oracle for random geometry.  Since
the strided direct Pallas kernel landed, ``route="pallas"`` never silently
degrades: Winograd-ineligible specs resolve to ``pallas-direct`` (the
paper's non-Winograd first-layer datapath), and only the pure-jnp
``winograd`` route still falls back to ``direct``.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import HAVE_HYPOTHESIS, assume, given, settings, st  # optional shim

from repro.kernels.conv.ref import conv2d_ref
from repro.nn.conv import (KERNELS, ROUTES, ConvSpec, conv_out_hw,
                           dispatch_conv, resolve_kernel, resolve_route)

# geometry -> winograd eligibility (stride 1 and 3x3 kernel, paper F(4,3))
GEOMETRIES = [
    (3, 1, True),     # the paper's Winograd layers
    (3, 2, False),    # right kernel, wrong stride
    (5, 1, False),    # wrong kernel
    (1, 1, False),    # pointwise
    (11, 4, False),   # AlexNet conv1
]


@pytest.mark.parametrize("route", ROUTES)
@pytest.mark.parametrize("kernel,stride,eligible", GEOMETRIES)
def test_resolve_route_truth_table(route, kernel, stride, eligible):
    """Every route x eligibility combination, exhaustively."""
    spec = ConvSpec(kernel=kernel, stride=stride, route=route)
    assert spec.winograd_eligible == eligible
    got = resolve_route(spec)
    if route == "direct":
        expect = "direct"                      # explicit direct never changes
    elif route == "auto":
        expect = "winograd" if eligible else "direct"
    elif route == "winograd":                  # jnp path: stride-1 3x3 only
        expect = "winograd" if eligible else "direct"
    else:                                      # pallas serves every geometry
        expect = "pallas"
    assert got == expect, (spec, got, expect)
    assert got != "auto"                       # always fully resolved


@pytest.mark.parametrize("kernel,stride,eligible", GEOMETRIES)
def test_resolve_kernel_exposes_pallas_datapath(kernel, stride, eligible):
    """The resolved-datapath helper serving logs use: pallas specs report
    which Pallas kernel will run instead of degrading silently."""
    spec = ConvSpec(kernel=kernel, stride=stride, route="pallas")
    got = resolve_kernel(spec)
    assert got == ("pallas-winograd" if eligible else "pallas-direct")
    for route in ("auto", "direct", "winograd"):
        k = resolve_kernel(ConvSpec(kernel=kernel, stride=stride,
                                    route=route))
        assert k == resolve_route(ConvSpec(kernel=kernel, stride=stride,
                                           route=route))
        assert k in KERNELS


def test_resolve_route_never_auto_never_invalid():
    for route in ROUTES:
        for kernel, stride, _ in GEOMETRIES:
            spec = ConvSpec(kernel=kernel, stride=stride, route=route)
            assert resolve_route(spec) in ("direct", "winograd", "pallas")
            assert resolve_kernel(spec) in KERNELS


def test_silent_winograd_fallback_is_exactly_direct():
    """Ineligible *winograd* specs take the identical code path as
    route="direct": bit-equal outputs, not merely close.  (pallas no longer
    falls back — it runs the strided direct kernel; checked for closeness.)
    """
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal((2, 9, 9, 4)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((5, 5, 2, 6)) * 0.2, jnp.float32)
    b = jnp.asarray(rng.standard_normal((6,)), jnp.float32)
    kw = dict(kernel=5, stride=2, groups=2, relu=True)
    ref = dispatch_conv(ConvSpec(route="direct", **kw), x, w, b)
    for route in ("winograd", "auto"):
        spec = ConvSpec(route=route, **kw)
        assert resolve_route(spec) == "direct"
        out = dispatch_conv(spec, x, w, b)
        assert np.array_equal(np.asarray(out), np.asarray(ref)), route
    spec = ConvSpec(route="pallas", **kw)
    assert resolve_kernel(spec) == "pallas-direct"
    out = dispatch_conv(spec, x, w, b, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_pallas_pool_larger_than_output_falls_back():
    """The single remaining pallas fallback: a fused pool window larger
    than the conv output has no VALID pooled region for the kernel's
    row-blocks to own, so dispatch degrades to the lax path (which emits
    an empty pooled map) — on both the direct and the winograd datapath."""
    # pallas-direct: stride 2, conv out 2x2 < pool window
    spec = ConvSpec(kernel=3, stride=2, padding="VALID", fuse_pool=True,
                    pool_window=3, route="pallas")
    x = jnp.zeros((1, 5, 5, 4), jnp.float32)
    w = jnp.zeros((3, 3, 4, 4), jnp.float32)
    out = dispatch_conv(spec, x, w, None, interpret=True)
    assert out.shape[1] == 0                   # same as the lax reference
    # pallas-winograd: stride-1 3x3 VALID on 4x4 input, conv out 2x2 < 3
    spec = ConvSpec(kernel=3, padding="VALID", fuse_pool=True,
                    pool_window=3, route="pallas")
    assert resolve_kernel(spec) == "pallas-winograd"
    # shape-aware resolution reports the fallback dispatch will take, so
    # serving logs / benchmark rows can't claim pallas while lax runs
    assert resolve_kernel(spec, in_hw=4) == "direct"
    assert resolve_kernel(spec, in_hw=(9, 4)) == "direct"
    assert resolve_kernel(spec, in_hw=9) == "pallas-winograd"
    out = dispatch_conv(spec, jnp.zeros((1, 4, 4, 4), jnp.float32), w, None,
                        interpret=True)
    assert out.shape[1] == 0


def test_invalid_spec_rejected():
    with pytest.raises(AssertionError):
        ConvSpec(kernel=3, route="nonsense")
    with pytest.raises(AssertionError):
        ConvSpec(kernel=3, padding="FULL")
    with pytest.raises(AssertionError):
        # weight geometry must match the spec
        dispatch_conv(ConvSpec(kernel=3),
                      jnp.zeros((1, 8, 8, 4)), jnp.zeros((5, 5, 4, 2)))


# ---------------------------------------------------------------------------
# property tests: route equivalence on random geometry (tests/_hyp.py shim)
# ---------------------------------------------------------------------------
def _run_spec(route, kernel, stride, padding, groups, relu, fuse_bias, seed,
              interpret=None, fuse_lrn=False, fuse_pool=False, H=8):
    rng = np.random.default_rng(seed)
    c_in, c_out = 4 * groups, 2 * groups
    x = jnp.asarray(rng.standard_normal((1, H, H, c_in)), jnp.float32)
    w = jnp.asarray(rng.standard_normal(
        (kernel, kernel, c_in // groups, c_out)) * 0.3, jnp.float32)
    b = jnp.asarray(rng.standard_normal((c_out,)), jnp.float32)
    spec = ConvSpec(kernel=kernel, stride=stride, padding=padding,
                    groups=groups, relu=relu, fuse_bias=fuse_bias,
                    fuse_lrn=fuse_lrn, fuse_pool=fuse_pool, route=route)
    out = dispatch_conv(spec, x, w, b, interpret=interpret)
    ref = conv2d_ref(x, w, b, stride=stride, padding=padding, groups=groups,
                     relu=relu)
    from repro.nn.pooling import apply_epilogue
    ref = apply_epilogue(ref, spec.lrn if fuse_lrn else None,
                         (spec.pool_window, spec.pool_stride) if fuse_pool
                         else None)
    return spec, np.asarray(out), np.asarray(ref)


@given(kernel=st.sampled_from([1, 3, 5]), stride=st.sampled_from([1, 2]),
       padding=st.sampled_from(["SAME", "VALID"]),
       groups=st.sampled_from([1, 2]), relu=st.booleans(),
       fuse_bias=st.booleans(), fuse_lrn=st.booleans(),
       fuse_pool=st.booleans(), seed=st.integers(0, 1000))
@settings(max_examples=25, deadline=None)
def test_auto_and_winograd_routes_match_direct(kernel, stride, padding,
                                               groups, relu, fuse_bias,
                                               fuse_lrn, fuse_pool, seed):
    """auto/winograd == unfused conv->lrn->pool oracle for random
    stride/padding/groups/fusion flags, whether the spec resolves to
    winograd or silently falls back."""
    H = 9
    assume(not fuse_pool or conv_out_hw(H, kernel, stride, padding) >= 3)
    for route in ("auto", "winograd"):
        spec, out, ref = _run_spec(route, kernel, stride, padding, groups,
                                   relu, fuse_bias, seed, fuse_lrn=fuse_lrn,
                                   fuse_pool=fuse_pool, H=H)
        assert out.shape == ref.shape, spec
        if resolve_route(spec) == "direct" and not (fuse_lrn or fuse_pool):
            np.testing.assert_array_equal(out, ref, err_msg=str(spec))
        else:
            np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3,
                                       err_msg=str(spec))


@given(kernel=st.sampled_from([3, 5]), stride=st.sampled_from([1, 2]),
       padding=st.sampled_from(["SAME", "VALID"]),
       groups=st.sampled_from([1, 2]), relu=st.booleans(),
       fuse_lrn=st.booleans(), fuse_pool=st.booleans(),
       seed=st.integers(0, 1000))
@settings(max_examples=8, deadline=None)
def test_pallas_route_matches_direct(kernel, stride, padding, groups, relu,
                                     fuse_lrn, fuse_pool, seed):
    """pallas (interpret mode on CPU) == unfused oracle, incl. the in-kernel
    LRN/pool epilogue; ineligible specs now exercise the strided *direct
    Pallas kernel* (never a silent lax fallback).  The wider
    kernel-size/stride sweep lives in tests/test_direct_conv.py."""
    H = 9
    assume(not fuse_pool or conv_out_hw(H, kernel, stride, padding) >= 3)
    spec, out, ref = _run_spec("pallas", kernel, stride, padding, groups,
                               relu, True, seed, interpret=True,
                               fuse_lrn=fuse_lrn, fuse_pool=fuse_pool, H=H)
    assert resolve_route(spec) == "pallas"
    assert out.shape == ref.shape, spec
    np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3,
                               err_msg=str(spec))


def test_property_suite_present():
    """Tier-1 sanity: the property tests above exist and either ran (with
    hypothesis) or skipped cleanly (without)."""
    assert callable(test_auto_and_winograd_routes_match_direct)
    assert callable(test_pallas_route_matches_direct)
    assert HAVE_HYPOTHESIS in (True, False)
