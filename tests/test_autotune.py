"""Measured autotuner: plan plumbing, candidate validity, cache persistence.

The autotuner's contract (``core/autotune.py``) rests on three claims these
tests pin:

* every candidate plan the enumerator emits runs **bit-equal** to the
  default plan — the knobs only re-block the launch, never the f32
  accumulation order — across all five AlexNet layer geometries (both the
  Winograd-domain and the strided direct kernel);
* ``dispatch_conv(plan=...)`` obeys the documented precedence (explicit
  knob kwarg beats plan beats built-in default) and a slab packed for a
  plan is accepted by a dispatch running the same plan;
* the JSON plan cache round-trips exactly (key stability across sessions,
  any-batch fallback), and a fast ``autotune_layer`` run persists a winner
  that the model-side loader finds again.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autotune as at
from repro.core.autotune import PlanCache, enumerate_plans, plan_key, key_str
from repro.models import alexnet
from repro.nn.conv import (ConvPlan, ConvSpec, DEFAULT_PLAN, dispatch_conv,
                           pack_conv_weights, plan_knobs, resolve_kernel)

# the five AlexNet layer geometries (reduced channels; conv1/conv2 resolve
# to the strided direct kernel, conv3-5 to the Winograd-domain kernel)
ALEXNET_LAYERS = [
    ("conv1", dict(kernel=11, stride=4, padding="VALID", relu=True,
                   fuse_lrn=True, fuse_pool=True), 35, 3, 16),
    ("conv2", dict(kernel=5, groups=2, relu=True, fuse_lrn=True,
                   fuse_pool=True), 13, 16, 32),
    ("conv3", dict(kernel=3, relu=True), 13, 32, 48),
    ("conv4", dict(kernel=3, groups=2, relu=True), 13, 48, 48),
    ("conv5", dict(kernel=3, groups=2, relu=True, fuse_pool=True),
     13, 48, 32),
]


def _arrays(kw, H, c_in, c_out, seed=0, B=3):
    rng = np.random.default_rng(seed)
    k = kw["kernel"]
    x = jnp.asarray(rng.standard_normal((B, H, H, c_in)), jnp.float32)
    w = jnp.asarray(rng.standard_normal(
        (k, k, c_in // kw.get("groups", 1), c_out)) * k ** -1, jnp.float32)
    b = jnp.asarray(rng.standard_normal((c_out,)), jnp.float32)
    return x, w, b


# ---------------------------------------------------------------------------
# plan + key + cache round-trips
# ---------------------------------------------------------------------------
def test_convplan_dict_roundtrip():
    p = ConvPlan(batch_block=2, k_block=64, pool_row_block=2,
                 weight_prefetch=False, row_parallel=True)
    assert ConvPlan.from_dict(p.to_dict()) == p
    # unknown keys are ignored (forward-compat with newer cache files)
    assert ConvPlan.from_dict({**p.to_dict(), "future_knob": 1}) == p
    # defaults really are the built-in launch configuration
    assert ConvPlan() == DEFAULT_PLAN


def test_plan_knobs_precedence():
    base = ConvPlan(batch_block=2, k_block=64, weight_prefetch=False)
    # plan beats default
    assert plan_knobs(base).batch_block == 2
    # explicit kwarg beats plan
    k = plan_knobs(base, batch_block=4)
    assert k.batch_block == 4 and k.k_block == 64
    assert k.weight_prefetch is False
    # explicit None (= auto) still overrides a plan's concrete block
    k = plan_knobs(ConvPlan(pool_row_block=2), pool_row_block=None)
    assert k.pool_row_block is None
    # no plan: the defaults
    assert plan_knobs(None) == DEFAULT_PLAN


def test_plan_key_stability():
    spec = ConvSpec(kernel=3, relu=True, route="pallas")
    k1 = plan_key(spec, (2, 13, 13, 32), interpret=True)
    k2 = plan_key(ConvSpec(kernel=3, relu=True, route="pallas"),
                  (2, 13, 13, 32), interpret=True)
    assert key_str(k1) == key_str(k2)
    # the string form is insensitive to dict field order (JSON sort_keys)
    assert key_str(dict(reversed(list(k1.items())))) == key_str(k1)
    # geometry, fusion flags, dtype and backend all discriminate
    assert key_str(plan_key(spec, (4, 13, 13, 32), interpret=True)) \
        != key_str(k1)
    assert key_str(plan_key(dataclasses.replace(spec, fuse_pool=True),
                            (2, 13, 13, 32), interpret=True)) != key_str(k1)
    assert key_str(plan_key(spec, (2, 13, 13, 32), dtype="bfloat16",
                            interpret=True)) != key_str(k1)
    assert key_str(plan_key(spec, (2, 13, 13, 32), interpret=False)) \
        != key_str(k1)


def test_plan_cache_roundtrip(tmp_path):
    spec = ConvSpec(kernel=3, relu=True, route="pallas")
    key = plan_key(spec, (2, 13, 13, 32), interpret=True)
    plan = ConvPlan(batch_block=2, k_block=64, weight_prefetch=False)
    cache = PlanCache()
    cache.put(key, plan, {"default_us": 10.0, "tuned_us": 7.0})
    path = tmp_path / "plans.json"
    cache.save(path)

    loaded = PlanCache.load(path)
    assert loaded.get(key) == plan
    assert loaded.stats(key)["tuned_us"] == 7.0
    # any-batch fallback: same geometry at a different batch still hits
    other = dict(key, batch=16)
    assert loaded.get(other) is None
    assert loaded.get(other, any_batch=True) == plan
    # but a different geometry never does
    assert loaded.get(dict(key, h=27, w=27), any_batch=True) is None
    # the file is plain JSON a human can audit
    data = json.loads(path.read_text())
    assert data["version"] == 1 and len(data["entries"]) == 1


def test_plan_cache_load_corrupt_falls_back(tmp_path):
    """A corrupt/truncated/alien cache file must never crash engine
    construction: load warns and returns an empty cache (default plans)."""
    key = plan_key(ConvSpec(kernel=3, route="pallas"), (2, 13, 13, 32),
                   interpret=True)
    bad = [
        ("garbage.json", "{not json at all"),
        ("truncated.json",
         '{"version": 1, "entries": {"k": {"plan": {"batch_bl'),
        ("wrong_version.json", json.dumps({"version": 99, "entries": {}})),
        ("no_version.json", json.dumps({"entries": {}})),
        ("alien_schema.json", json.dumps({"version": 1, "entries": "nope"})),
        ("bad_entry.json",
         json.dumps({"version": 1, "entries": {"k": {"no_plan": 1}}})),
    ]
    for name, text in bad:
        p = tmp_path / name
        p.write_text(text)
        with pytest.warns(UserWarning, match="plan cache"):
            cache = PlanCache.load(p)
        assert cache.get(key) is None, name        # falls back to defaults
        assert not cache.entries, name


def test_plan_cache_load_missing_file_is_silent(tmp_path):
    """A missing cache file is the normal never-tuned state — empty cache,
    no warning."""
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error")
        cache = PlanCache.load(tmp_path / "nope.json")
    assert not cache.entries


# ---------------------------------------------------------------------------
# candidate enumeration: validity + bit-equality
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name,kw,H,c_in,c_out", ALEXNET_LAYERS)
def test_enumerated_plans_bit_equal_to_default(name, kw, H, c_in, c_out):
    """Every plan the enumerator emits must produce the exact bits of the
    default plan — the autotuner's license to pick any of them on speed
    alone."""
    spec = ConvSpec(route="pallas", **kw)
    x, w, b = _arrays(kw, H, c_in, c_out, seed=H + c_in)
    plans = enumerate_plans(spec, x.shape, w.shape)
    assert plans[0] == DEFAULT_PLAN
    assert len(plans) == len(set(plans))        # no duplicate ConvPlans
    y0 = np.asarray(dispatch_conv(spec, x, w, b, interpret=True))
    for plan in plans[1:]:
        y = np.asarray(dispatch_conv(spec, x, w, b, plan=plan,
                                     interpret=True))
        assert np.array_equal(y0, y), (name, plan)


def test_enumeration_non_pallas_is_default_only():
    spec = ConvSpec(kernel=3, relu=True, route="direct")
    assert enumerate_plans(spec, (2, 13, 13, 8), (3, 3, 8, 8)) \
        == [DEFAULT_PLAN]


def test_enumeration_dedupes_clamped_knobs():
    """batch_block values above B and k_blocks that widen to K collapse to
    one effective launch each — the sweep never measures them twice."""
    spec = ConvSpec(kernel=3, relu=True, route="pallas")
    small = enumerate_plans(spec, (1, 13, 13, 8), (3, 3, 8, 8))
    # B=1: every batch_block clamps to 1; K=8 < all k_blocks: all widen
    assert all(p.batch_block == 1 or p == DEFAULT_PLAN for p in small)
    assert len(small) <= 1 + 4      # default + prefetch/row_parallel combos


# ---------------------------------------------------------------------------
# dispatch/pack plan plumbing
# ---------------------------------------------------------------------------
def test_packed_slab_matches_planned_dispatch():
    """A slab packed for a tuned plan must be shape-accepted by a dispatch
    running the same plan (and still produce the default bits)."""
    kw = dict(kernel=5, groups=2, relu=True, fuse_lrn=True, fuse_pool=True)
    spec = ConvSpec(route="pallas", **kw)
    x, w, b = _arrays(kw, 13, 16, 32)
    plan = ConvPlan(batch_block=2, k_block=8)
    wp = pack_conv_weights(spec, x.shape, w, plan=plan)
    y0 = np.asarray(dispatch_conv(spec, x, w, b, interpret=True))
    y = np.asarray(dispatch_conv(spec, x, w, b, w_packed=wp, plan=plan,
                                 interpret=True))
    assert np.array_equal(y0, y)


def test_pack_explicit_kwarg_overrides_plan():
    """k_block precedence is observable in the slab shape: an explicit
    kwarg must beat the plan's value."""
    spec = ConvSpec(kernel=3, relu=True, route="pallas")
    x, w, _ = _arrays(dict(kernel=3), 13, 32, 48)
    slab_plan = pack_conv_weights(spec, x.shape, w,
                                  plan=ConvPlan(k_block=8)).data
    slab_override = pack_conv_weights(spec, x.shape, w,
                                      plan=ConvPlan(k_block=8),
                                      k_block=16).data
    slab_16 = pack_conv_weights(spec, x.shape, w,
                                plan=ConvPlan(k_block=16)).data
    assert slab_plan.shape != slab_override.shape
    assert slab_override.shape == slab_16.shape


@pytest.mark.parametrize("name,kw,H,c_in,c_out", [ALEXNET_LAYERS[1],
                                                  ALEXNET_LAYERS[2]])
def test_row_parallel_bit_parity_multi_tile(name, kw, H, c_in, c_out):
    """The per-row-block stream restart (row grid dimension freed to run
    parallel) is bit-equal on a forced multi-tile stream, prefetch on and
    off, on both kernels."""
    spec = ConvSpec(route="pallas", **kw)
    x, w, b = _arrays(kw, H, c_in, c_out, seed=7)
    y0 = np.asarray(dispatch_conv(spec, x, w, b, interpret=True))
    for pf in (True, False):
        plan = ConvPlan(batch_block=2, k_block=max(c_out // 4, 1),
                        weight_prefetch=pf, row_parallel=True)
        y = np.asarray(dispatch_conv(spec, x, w, b, plan=plan,
                                     interpret=True))
        assert np.array_equal(y0, y), (name, pf)


def test_plan_route_override():
    """A plan's route field re-routes the spec before kernel resolution."""
    spec = ConvSpec(kernel=3, relu=True, route="pallas")
    x, w, b = _arrays(dict(kernel=3), 9, 8, 8)
    y_pal = np.asarray(dispatch_conv(spec, x, w, b, interpret=True))
    y_lax = np.asarray(dispatch_conv(spec, x, w, b, interpret=True,
                                     plan=ConvPlan(route="direct")))
    ref = np.asarray(dispatch_conv(spec.with_route("direct"), x, w, b))
    assert np.array_equal(y_lax, ref)
    np.testing.assert_allclose(y_pal, y_lax, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# fast measured runs + persistence end-to-end
# ---------------------------------------------------------------------------
def test_autotune_layer_fast(tmp_path):
    kw = dict(kernel=3, relu=True)
    spec = ConvSpec(route="pallas", **kw)
    x, w, b = _arrays(kw, 9, 8, 8, B=2)
    best, rows = at.autotune_layer(spec, x, w, b, interpret=True,
                                   iters=1, max_candidates=3,
                                   check_equal=True)
    assert rows[0]["default"] and len(rows) >= 1
    tuned_us = min(r["us"] for r in rows)
    assert any(ConvPlan.from_dict(r["plan"]) == best and r["us"] == tuned_us
               for r in rows)
    # tuned can never be recorded slower than the default
    assert tuned_us <= next(r["us"] for r in rows if r["default"])


def test_autotune_alexnet_persists_and_reloads(tmp_path):
    """autotune_alexnet -> PlanCache.save -> load_tuned_plans round-trip,
    and a forward pass under the tuned plans is bit-equal to default."""
    cfg = dataclasses.replace(alexnet.AlexNetConfig().reduced(),
                              image_size=35, use_pallas=True)
    path = tmp_path / "plans.json"
    cache = PlanCache()
    results = at.autotune_alexnet(cfg, 2, iters=1, max_candidates=2,
                                  cache=cache)
    assert [r["layer"] for r in results] == [f"conv{i}" for i in range(1, 6)]
    assert all(r["tuned_us"] <= r["default_us"] for r in results)
    cache.save(path)

    plans = alexnet.load_tuned_plans(cfg, 2, path=path)
    assert plans, "loader found no tuned plans"
    assert all(isinstance(p, ConvPlan) for p in plans.values())
    # any-batch fallback serves other bucket sizes from the same cache
    assert alexnet.load_tuned_plans(cfg, 4, path=path)

    params = alexnet.init(jax.random.PRNGKey(0), cfg)
    imgs = jnp.asarray(np.random.default_rng(0).standard_normal(
        (2, cfg.image_size, cfg.image_size, cfg.in_channels)), jnp.float32)
    y0 = np.asarray(alexnet.apply(params, cfg, imgs))
    y1 = np.asarray(alexnet.apply(params, cfg, imgs, plans=plans))
    assert np.array_equal(y0, y1)
