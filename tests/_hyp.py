"""Optional-hypothesis shim for mixed test modules.

``tests/test_bfp.py`` is property-based end to end and uses
``pytest.importorskip``; modules that mix property tests with plain unit
tests import ``given/settings/st`` from here instead, so the plain tests
still run (and the property tests skip cleanly) when hypothesis is not
installed.
"""
import pytest

try:
    from hypothesis import assume, given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:           # tier-1 runs without extras
    HAVE_HYPOTHESIS = False

    def assume(*_a, **_k):
        return True

    class _AnyStrategy:
        """Stands in for ``strategies`` — any strategy call returns None."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def given(*_a, **_k):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_a, **_k):
        return lambda f: f
