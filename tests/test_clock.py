"""Injectable virtual clock: deterministic, sleep-free time for the
serving stack's cooldowns, deadlines, backoffs, and latency faults.

Every time-coupled behavior in the fault-tolerance plane (circuit-breaker
cooldown, deadline expiry, exponential retry backoff, injected latency
spikes) reads :class:`repro.serving.clock.Clock`.  These tests drive them
with :class:`VirtualClock` — no ``time.sleep``, no wall-clock dependence —
so chaos replays are bit-deterministic and CI never waits out a backoff.
"""
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import alexnet
from repro.serving import (QUARANTINED, CnnEngine, CnnServeConfig,
                           FaultInjector, FaultSpec, HealthMonitor,
                           ImageRequest, MonotonicClock, VirtualClock)


@pytest.fixture(scope="module")
def served():
    cfg = get_config("alexnet").reduced()
    params = alexnet.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _image(cfg, seed=0):
    rng = np.random.default_rng(seed)
    return rng.standard_normal(
        (cfg.image_size, cfg.image_size, cfg.in_channels)).astype(np.float32)


# ---------------------------------------------------------------------------
# the clock itself
# ---------------------------------------------------------------------------
def test_virtual_clock_semantics():
    vc = VirtualClock(t0=10.0)
    assert vc.now() == 10.0
    vc.advance(2.5)
    assert vc.now() == 12.5
    vc.sleep(0.5)                   # sleeping advances virtual time
    assert vc.now() == 13.0
    with pytest.raises(AssertionError):
        vc.advance(-1.0)


def test_virtual_clock_sleep_is_instant():
    """A 100-virtual-second sleep must not consume wall time."""
    vc = VirtualClock()
    t0 = time.perf_counter()
    vc.sleep(100.0)
    assert time.perf_counter() - t0 < 1.0
    assert vc.now() == 100.0


def test_monotonic_clock_tracks_wall():
    mc = MonotonicClock()
    a = mc.now()
    assert mc.now() >= a


# ---------------------------------------------------------------------------
# health-monitor cooldown: no sleeping through the circuit breaker
# ---------------------------------------------------------------------------
def test_cooldown_half_open_probe_sleep_free():
    vc = VirtualClock()
    hm = HealthMonitor(fail_threshold=1, quarantine_threshold=2,
                       cooldown_ms=250.0, clock=vc)
    hm.force_quarantine("test")
    assert hm.state == QUARANTINED
    assert not hm.allow_launch()            # cooldown not elapsed
    vc.advance(0.249)
    assert not hm.allow_launch()
    vc.advance(0.002)                       # past 250ms, virtually
    assert hm.allow_launch()                # exactly one half-open probe
    assert not hm.allow_launch()            # probe in flight
    hm.record_ok()
    assert hm.state == "healthy"


def test_cooldown_rearms_after_failed_probe():
    vc = VirtualClock()
    hm = HealthMonitor(cooldown_ms=100.0, clock=vc)
    hm.force_quarantine("test")
    vc.advance(0.2)
    assert hm.allow_launch()
    hm.record_failure("probe")              # probe failed: cooldown re-arms
    assert not hm.allow_launch()
    vc.advance(0.2)
    assert hm.allow_launch()


# ---------------------------------------------------------------------------
# engine deadlines + backoff on virtual time
# ---------------------------------------------------------------------------
def test_deadline_expiry_without_waiting(served):
    """A 50ms deadline expires by advancing the virtual clock, not by
    sleeping 50ms of CI time."""
    cfg, params = served
    vc = VirtualClock()
    eng = CnnEngine(cfg, CnnServeConfig(max_batch=2), params=params,
                    clock=vc)
    req = ImageRequest(image=_image(cfg), deadline_ms=50.0)
    eng.submit(req)
    vc.advance(0.1)                         # 100 virtual ms later
    eng.run_until_done()
    assert req.expired and req.expire_reason == "deadline"
    acc = eng.accounting()
    assert acc["balanced"] and acc["expired"] == 1


def test_retry_backoff_elapses_virtually(served):
    """A huge retry backoff (10 virtual seconds) is pending until the
    clock is advanced — then the retry fires and serving completes.  On
    a real clock this test would take 10s; it must not."""
    cfg, params = served
    vc = VirtualClock()
    eng = CnnEngine(cfg, CnnServeConfig(max_batch=2,
                                        retry_backoff_ms=10_000.0),
                    params=params, clock=vc,
                    faults=FaultInjector(0, {
                        "launch.transient": FaultSpec(at=(0,))}))
    t0 = time.perf_counter()
    req = ImageRequest(image=_image(cfg), retries=2)
    eng.submit(req)
    for _ in range(20):                     # backoff pending: no progress
        eng.step()
    assert not req.done and eng.retry_pending == 1
    vc.advance(11.0)                        # backoff elapses virtually
    eng.run_until_done()
    assert req.done and not req.expired
    assert eng.accounting()["balanced"]
    assert time.perf_counter() - t0 < 60.0  # and no 10s wall-clock stall


def test_retire_latency_fault_on_virtual_clock(served):
    """An injected 30-virtual-second retirement spike completes instantly
    on the virtual clock and shows up in the measured latency."""
    cfg, params = served
    vc = VirtualClock()
    eng = CnnEngine(cfg, CnnServeConfig(max_batch=2), params=params,
                    clock=vc,
                    faults=FaultInjector(0, {
                        "retire.latency": FaultSpec(at=(0,),
                                                    delay_ms=30_000.0)}))
    t0 = time.perf_counter()
    req = ImageRequest(image=_image(cfg))
    eng.submit(req)
    eng.run_until_done()
    assert req.done
    assert time.perf_counter() - t0 < 60.0  # virtual spike, real speed
    # the spike is visible in the engine's own latency accounting
    assert eng.latency.percentiles_ms()["p99"] >= 30_000.0


def test_virtual_runs_are_bit_deterministic(served):
    """Two identical chaos runs on virtual clocks retire identical logits
    and identical accounting — time is no longer a source of noise."""
    cfg, params = served

    def run():
        eng = CnnEngine(cfg, CnnServeConfig(max_batch=2,
                                            retry_backoff_ms=100.0),
                        params=params, clock=VirtualClock(),
                        faults=FaultInjector(7, {
                            "launch.transient": FaultSpec(at=(0,)),
                            "retire.latency": FaultSpec(rate=0.5,
                                                        delay_ms=5.0)}))
        reqs = [ImageRequest(image=_image(cfg, seed=3), retries=3)
                for _ in range(3)]
        for r in reqs:
            eng.submit(r)
        for _ in range(50):
            eng.step()
            eng.clock.advance(0.2)          # march virtual time forward
            if all(r.done for r in reqs):
                break
        assert all(r.done for r in reqs)
        return ([np.asarray(r.logits) for r in reqs], eng.accounting())

    la, aa = run()
    lb, ab = run()
    assert aa == ab
    assert all(np.array_equal(a, b) for a, b in zip(la, lb))
