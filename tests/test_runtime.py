"""Trainer fault tolerance: checkpoint restart, failure recovery, stragglers,
data determinism, checkpoint atomicity."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.configs import get_config
from repro.data.pipeline import synthetic_batches
from repro.runtime import Trainer, TrainerConfig


def _tiny():
    return get_config("smollm-360m").reduced()


def test_loss_decreases(tmp_path):
    tr = Trainer(_tiny(), TrainerConfig(steps=40, batch=8, seq_len=64,
                                        base_lr=3e-3, log_every=5))
    hist = tr.run()
    assert hist[-1]["loss"] < hist[0]["loss"] * 0.7


def test_checkpoint_restart_exact(tmp_path):
    d = str(tmp_path / "ck")
    cfg = _tiny()
    t1 = Trainer(cfg, TrainerConfig(steps=20, batch=4, seq_len=32,
                                    ckpt_every=20, ckpt_dir=d, log_every=5))
    t1.run()
    # run 10 more steps from the checkpoint
    t2 = Trainer(cfg, TrainerConfig(steps=30, batch=4, seq_len=32,
                                    ckpt_dir=d, log_every=5))
    assert t2.restore_latest()
    assert int(jax.device_get(t2.state["step"])) == 20
    t2.run()
    # reference: 30 uninterrupted steps
    t3 = Trainer(cfg, TrainerConfig(steps=30, batch=4, seq_len=32,
                                    log_every=5))
    t3.run()
    # data pipeline is keyed by step, so trajectories must match closely
    a = jax.tree_util.tree_leaves(t2.state["params"])
    b = jax.tree_util.tree_leaves(t3.state["params"])
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-4, atol=1e-5)


def test_failure_recovery(tmp_path):
    d = str(tmp_path / "ck")
    fails = {15}
    tr = Trainer(_tiny(), TrainerConfig(steps=25, batch=4, seq_len=32,
                                        ckpt_every=10, ckpt_dir=d,
                                        log_every=5),
                 failure_injector=lambda s: s in fails and
                 not fails.discard(s))
    tr.run()
    assert len(tr.events.recoveries) == 1
    assert tr.events.recoveries[0]["restored"]
    assert int(jax.device_get(tr.state["step"])) == 25


def test_straggler_detection():
    slow = {30}

    def injector(s):
        if s in slow:
            slow.discard(s)
            time.sleep(1.0)
        return False

    tr = Trainer(_tiny(), TrainerConfig(steps=35, batch=2, seq_len=16,
                                        log_every=50,
                                        straggler_min_history=8),
                 failure_injector=injector)
    tr.run()
    assert len(tr.events.stragglers) >= 1


def test_checkpoint_atomic_and_gc(tmp_path):
    d = str(tmp_path / "ck")
    state = {"step": jnp.int32(1), "w": jnp.arange(8.0)}
    for s in range(1, 6):
        state["step"] = jnp.int32(s)
        ckpt.save(d, state, keep=2)
    steps = sorted(int(p.split("_")[1]) for p in os.listdir(d)
                   if p.startswith("step_") and not p.endswith(".tmp"))
    assert steps == [4, 5]
    assert not any(p.endswith(".tmp") for p in os.listdir(d))
    restored = ckpt.restore(d, state)
    assert int(restored["step"]) == 5
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.arange(8.0))


def test_checkpoint_integrity_manifest_and_verify(tmp_path):
    """save() records per-leaf crc32s; verify_step catches bit-rot and
    missing leaves."""
    d = str(tmp_path / "ck")
    ckpt.save(d, {"step": jnp.int32(1), "w": jnp.arange(8.0)})
    ok, problems = ckpt.verify_step(d, 1)
    assert ok and not problems
    # flip a byte in a leaf -> crc mismatch
    leaf = os.path.join(d, "step_0000000001", "w.npy")
    with open(leaf, "r+b") as f:
        f.seek(-1, os.SEEK_END)
        b = f.read(1)
        f.seek(-1, os.SEEK_END)
        f.write(bytes([b[0] ^ 0xFF]))
    ok, problems = ckpt.verify_step(d, 1)
    assert not ok and any("crc mismatch" in p for p in problems)
    # a missing leaf is also caught
    os.remove(leaf)
    ok, problems = ckpt.verify_step(d, 1)
    assert not ok and any("missing leaf" in p for p in problems)


def test_checkpoint_restore_falls_back_past_torn_latest(tmp_path):
    """A torn/corrupt *latest* checkpoint must not be restored: the loader
    warns and falls back to the previous intact step; naming the corrupt
    step explicitly raises CheckpointCorrupt."""
    d = str(tmp_path / "ck")
    for s in (1, 2):
        ckpt.save(d, {"step": jnp.int32(s), "w": jnp.full((4,), float(s))})
    # tear step 2 (as a crash mid-write that beat the manifest would)
    os.remove(os.path.join(d, "step_0000000002", "w.npy"))
    assert ckpt.latest_step(d) == 2
    with pytest.warns(UserWarning, match="failed integrity"):
        assert ckpt.latest_intact_step(d) == 1
    with pytest.warns(UserWarning, match="failed integrity"):
        r = ckpt.restore(d, {"step": jnp.int32(0), "w": jnp.zeros(4)})
    assert int(r["step"]) == 1
    np.testing.assert_array_equal(np.asarray(r["w"]), 1.0)
    with pytest.raises(ckpt.CheckpointCorrupt):
        ckpt.restore(d, {"step": jnp.int32(0), "w": jnp.zeros(4)}, step=2)
    # verify=False preserves the old trusting behavior (explicit opt-out)
    r = ckpt.restore(d, {"step": jnp.int32(0), "w": jnp.zeros(4)}, step=1,
                     verify=False)
    assert int(r["step"]) == 1


def test_async_checkpointer(tmp_path):
    d = str(tmp_path / "ck")
    ac = ckpt.AsyncCheckpointer(d, keep=3)
    for s in (1, 2, 3):
        ac.submit({"step": jnp.int32(s), "w": jnp.full((4,), float(s))})
    ac.close()
    assert ckpt.latest_step(d) == 3
    r = ckpt.restore(d, {"step": jnp.int32(0), "w": jnp.zeros(4)})
    np.testing.assert_array_equal(np.asarray(r["w"]), 3.0)


def test_data_determinism_and_sharding():
    g1 = list(synthetic_batches(batch=4, seq_len=16, vocab=97, seed=7,
                                steps=3))
    g2 = list(synthetic_batches(batch=4, seq_len=16, vocab=97, seed=7,
                                steps=3))
    for a, b in zip(g1, g2):
        np.testing.assert_array_equal(a["inputs"], b["inputs"])
    # different hosts -> different streams
    h0 = next(synthetic_batches(batch=4, seq_len=16, vocab=97, seed=7,
                                process_index=0, process_count=2))
    h1 = next(synthetic_batches(batch=4, seq_len=16, vocab=97, seed=7,
                                process_index=1, process_count=2))
    assert not np.array_equal(h0["inputs"], h1["inputs"])
    # learnable: next token is a fixed affine function of current token
    b = next(synthetic_batches(batch=8, seq_len=64, vocab=97, seed=3))
    x, y = b["inputs"], b["targets"]
    assert np.array_equal(x[:, 1:], y[:, :-1])
