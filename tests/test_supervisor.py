"""Supervised multi-process serving: protocol, heartbeats, failover,
crash-consistent restart, and the fleet accounting invariant.

These tests spawn real worker processes (multiprocessing ``spawn``
context — each worker owns its own JAX runtime), so they are the slowest
in the suite; configs are shrunk (35px AlexNet, max_batch=2) to keep the
per-worker build short.  The invariant under test everywhere::

    submitted == completed + shed + expired          (fleet-wide, drained)

must hold across worker kills, stalls, and respawns — no request is ever
silently lost — and every failed-over request's served logits must
bit-match a jitted direct forward at the exact padded bucket shape it
was served in (crash-consistent restart: respawned workers rebuild
bit-identical engines from checkpoint + plan cache).
"""
import dataclasses
import os
import time

import numpy as np
import pytest

from repro.configs import get_config
from repro.serving import (CnnServeConfig, FaultSpec, ImageRequest,
                           Supervisor, SupervisorConfig, WorkerModel)


@pytest.fixture(scope="module")
def small():
    cfg = dataclasses.replace(get_config("alexnet").reduced(),
                              image_size=35)
    scfg = CnnServeConfig(max_batch=2, staging_depth=2,
                          retry_backoff_ms=0.5)
    return cfg, scfg


def _images(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.standard_normal(
        (n, cfg.image_size, cfg.image_size, cfg.in_channels)
    ).astype(np.float32)


def _sup(cfg, scfg, **kw):
    sup_kw = {}
    for k in ("ckpt_dir", "chaos", "chaos_workers", "seed"):
        if k in kw:
            sup_kw[k] = kw.pop(k)
    cfg_kw = dict(n_workers=2, max_restarts=2, checkpoint_on_start=False,
                  heartbeat_timeout_ms=500.0)
    cfg_kw.update(kw)
    return Supervisor((WorkerModel("alexnet", cfg, scfg,
                                   seed=sup_kw.get("seed", 0)),),
                      SupervisorConfig(**cfg_kw), **sup_kw)


def _drain_ok(sup, n_submitted):
    acc = sup.run_until_done(max_steps=2000)
    assert acc["balanced"] and acc["in_flight"] == 0, acc
    assert acc["submitted"] == n_submitted
    assert acc["submitted"] == (acc["completed"] + acc["shed"]
                                + acc["expired"]), acc
    return acc


def _await_respawn(sup, name, timeout_s=300.0):
    """Pump until the respawned worker's ready handshake lands."""
    t0 = time.perf_counter()
    while time.perf_counter() - t0 < timeout_s:
        sup.step()
        h = sup.workers[name]
        if h.alive:
            return h
        time.sleep(0.2)
    raise AssertionError(f"{name} never came back")


# ---------------------------------------------------------------------------
def test_protocol_roundtrip_heartbeat_and_bitmatch(small, tmp_path):
    """The pickle-over-pipe protocol end to end: submit/step/retire via
    the pump, heartbeat accounting snapshots, a checkpoint op that writes
    an intact (crc-verified) checkpoint — and every served logit
    bit-matching the direct forward at its padded bucket shape."""
    from repro import checkpoint as ckpt

    cfg, scfg = small
    sup = _sup(cfg, scfg, n_workers=1, ckpt_dir=str(tmp_path / "ck"))
    with sup:
        imgs = _images(cfg, 5)
        reqs = [ImageRequest(image=im) for im in imgs]
        for r in reqs:
            assert sup.submit("alexnet", r)
        _drain_ok(sup, 5)
        assert all(r.done for r in reqs)
        # worker-side accounting arrives via heartbeat — which runs at the
        # top of a pump, so the snapshot trails the work by one step
        sup.step()
        wacc = sup.workers["w0"].last_accounting
        assert wacc["alexnet"]["completed"] == 5
        # served logits bit-match the padded-shape oracle, cross-process
        par = sup.verify_bit_parity(uids=[r.uid for r in reqs])
        assert par["checked"] == 5 and par["mismatched"] == 0, par
        # provenance was stamped by the engine and survived the pipe
        assert all(r.served_bucket in (1, 2) for r in reqs)
        assert all(r.uid in r.served_group for r in reqs)
        # checkpoint RPC writes a crc-intact checkpoint
        rep = sup.checkpoint()
        d = os.path.join(str(tmp_path / "ck"), "alexnet")
        step = rep["step"]
        ok, problems = ckpt.verify_step(d, step)
        assert ok, problems
        assert ckpt.latest_intact_step(d) == step


def test_stall_trips_heartbeat_but_worker_survives(small):
    """worker.stall chaos: the worker sleeps through a heartbeat deadline
    — the health ladder records the miss, but below the quarantine
    threshold the worker recovers (stale replies dropped by seq) and
    nothing is killed or lost."""
    cfg, scfg = small
    sup = _sup(cfg, scfg, n_workers=2,
               heartbeat_timeout_ms=150.0, miss_threshold=6,
               chaos={"worker.stall": FaultSpec(at=(1,), delay_ms=350.0,
                                                limit=1)},
               chaos_workers=("w0",))
    with sup:
        imgs = _images(cfg, 8)
        reqs = [ImageRequest(image=im) for im in imgs]
        # two waves: the stall fires at pump opportunity 1, so wave two
        # must still be in flight when it lands
        for r in reqs[:4]:
            sup.submit("alexnet", r)
        sup.step()                          # opportunity 0: no stall
        for r in reqs[4:]:
            sup.submit("alexnet", r)
        acc = _drain_ok(sup, 8)
        assert acc["completed"] == 8
        h = sup.workers["w0"]
        assert h.injector.summary()["worker.stall"]["fired"] == 1
        assert h.monitor.failures_total >= 1      # the miss was recorded
        assert not h.deaths                       # ...but no kill
        assert h.restarts == 0


def test_mid_flight_kill_fails_over_zero_lost_bit_identical(small):
    """SIGKILL a worker with queued + in-flight requests: survivors pick
    the orphans up at their remaining deadline, the fleet invariant holds,
    and every failed-over logit bit-matches the padded-shape oracle."""
    cfg, scfg = small
    sup = _sup(cfg, scfg, n_workers=2)
    with sup:
        imgs = _images(cfg, 10)
        reqs = [ImageRequest(image=im, deadline_ms=60_000.0)
                for im in imgs]
        for r in reqs:
            sup.submit("alexnet", r)
        assert len(sup.workers["w0"].inflight) > 0
        sup.kill_worker("w0", "test-kill")
        acc = _drain_ok(sup, 10)
        assert acc["completed"] == 10 and acc["failed_over"] > 0
        par = sup.verify_bit_parity()
        assert par["checked"] == sup.failed_over
        assert par["mismatched"] == 0, par
        kinds = [e["event"] for e in sup.events]
        assert "death" in kinds
        assert sup.workers["w0"].restarts == 1    # respawn in flight/ready


def test_crash_consistent_restart_restores_intact_checkpoint(small,
                                                             tmp_path):
    """Kill a worker whose model has checkpoints on disk, with the
    *latest* checkpoint torn: the respawn must fall back to the previous
    intact step (crc manifest scan), rebuild, and serve bit-identically."""
    cfg, scfg = small
    ckpt_dir = str(tmp_path / "ck")
    sup = _sup(cfg, scfg, n_workers=2, ckpt_dir=ckpt_dir,
               checkpoint_on_start=True)
    with sup:
        sup.checkpoint()                  # step 2 (start() wrote step 1)
        d = os.path.join(ckpt_dir, "alexnet")
        # tear the newest checkpoint, as a crash mid-write would
        leaves = [f for f in os.listdir(os.path.join(d, "step_0000000002"))
                  if f.endswith(".npy")]
        os.remove(os.path.join(d, "step_0000000002", leaves[0]))

        imgs = _images(cfg, 4)
        reqs = [ImageRequest(image=im, deadline_ms=120_000.0)
                for im in imgs]
        for r in reqs:
            sup.submit("alexnet", r)
        sup.kill_worker("w0", "test-kill")
        _drain_ok(sup, 4)
        h = _await_respawn(sup, "w0")
        # the respawn skipped the torn step 2 (the integrity warning fires
        # in the child process) and restored intact step 1
        assert h.restored == {"alexnet": 1}, h.restored
        # and serves bit-identically: route fresh traffic through w0 only
        sup.workers["w1"].alive = False   # force routing to the respawn
        more = [ImageRequest(image=im) for im in _images(cfg, 3, seed=9)]
        for r in more:
            assert sup.submit("alexnet", r)
        sup.workers["w1"].alive = True
        acc = sup.run_until_done(max_steps=2000)
        assert acc["balanced"] and all(r.done for r in more)
        par = sup.verify_bit_parity(uids=[r.uid for r in more])
        assert par["checked"] == 3 and par["mismatched"] == 0, par


def test_accounting_invariant_under_mixed_process_chaos(small):
    """Property: the fleet invariant holds across a mixed seeded chaos
    schedule (crashes + stalls) over traffic spanning every bucket
    padding, with deadlines tight enough that some requests expire."""
    cfg, scfg = small
    sup = _sup(cfg, scfg, n_workers=2, seed=3,
               heartbeat_timeout_ms=200.0,
               chaos={"worker.crash": FaultSpec(at=(3,), limit=1),
                      "worker.stall": FaultSpec(rate=0.15, delay_ms=250.0,
                                                limit=2)},
               chaos_workers=("w0", "w1"))
    with sup:
        rng = np.random.default_rng(3)
        submitted = 0
        # group sizes 1..max_batch exercise every bucket padding; a mix
        # of no-deadline and tight-deadline requests exercises expiry
        for burst in (1, 2, 1, 2, 2, 1, 2, 2):
            for _ in range(burst):
                dl = 25.0 if rng.uniform() < 0.3 else 60_000.0
                sup.submit("alexnet", ImageRequest(
                    image=rng.standard_normal(
                        (cfg.image_size, cfg.image_size,
                         cfg.in_channels)).astype(np.float32),
                    deadline_ms=dl, retries=2))
                submitted += 1
            sup.step()
        acc = _drain_ok(sup, submitted)
        assert acc["completed"] > 0
        # the seeded crash fired (or the worker died trying)
        fired = sum((h.injector.summary().get("worker.crash", {})
                     .get("fired", 0)) for h in sup.workers.values()
                    if h.injector)
        assert fired >= 1
        # every completed request bit-matches its padded-shape oracle
        done = [u for u, (m, r) in sup.requests.items() if r.done]
        par = sup.verify_bit_parity(uids=done)
        assert par["mismatched"] == 0, par
