"""Silent-data-corruption defense: ABFT checksums, slab fingerprints,
magnitude screen, and the detect -> repack -> retry recovery loop.

The property under test is the ABFT guarantee: a *single bit flip at any
position* in any packed weight slab is detected before the affected
logits retire (the bit-pattern integer checksum changes by +-2^k mod
2^width, never 0), and the armed clean path is bit-identical to the
unarmed one with zero false positives (integer wraparound addition is
exact and order-independent).  Swept across the five reduced-AlexNet
layer geometries on their natural Pallas kernels (direct for conv1/2,
Winograd for conv3-5) x weight_prefetch on/off x row_parallel.

The serving half mirrors the fault-tolerance contract: an injected
``slab.bitflip`` / ``slab.stale`` / ``retire.plausible`` never serves a
tainted row — the request completes later with logits bit-identical to
the fault-free oracle, and ``submitted == completed + shed + expired``
on every drained engine.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels.conv import dma
from repro.models import alexnet
from repro.nn.conv import (dispatch_conv, expected_pack_context,
                           pack_conv_weights, resolve_kernel, verify_packed)
from repro.serving import (CnnEngine, CnnServeConfig, FaultInjector,
                           FaultSpec, ImageRequest, derive_seed)

# ---------------------------------------------------------------------------
# helpers / fixtures
# ---------------------------------------------------------------------------


def _layer_geometries(image_size):
    """(name, pallas-routed spec, input shape, filter shape) for every
    reduced-AlexNet conv layer, shapes threaded like the model does."""
    cfg = dataclasses.replace(get_config("alexnet").reduced(),
                              image_size=image_size, use_pallas=True)
    geoms = []
    h, c_in = cfg.image_size, cfg.in_channels
    for i, (spec, c_out) in enumerate(zip(alexnet.layer_specs(cfg),
                                          cfg.conv_channels)):
        spec = spec.with_route("pallas")
        k, g = spec.kernel, spec.groups
        geoms.append((f"conv{i + 1}", spec, (2, h, h, c_in),
                      (k, k, c_in // g, c_out)))
        h, c_in = spec.out_hw(h), c_out
    return geoms


# image 67 keeps all five layers on a Pallas kernel (at smaller images
# conv5's fused pool exceeds its output and falls back to lax)
GEOMS = _layer_geometries(67)
assert all(resolve_kernel(s, in_hw=shape[1]).startswith("pallas")
           for _, s, shape, _ in GEOMS)


def _filters(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape) * 0.1, jnp.float32)


def _flip_bit(pw, bit_index):
    """One slab with exactly one bit flipped at ``bit_index`` (mod size)."""
    host = np.array(np.asarray(pw.data))
    flat = host.view(np.uint8).reshape(-1)
    byte, bit = (bit_index // 8) % flat.size, bit_index % 8
    flat[byte] ^= np.uint8(1 << bit)
    return dataclasses.replace(pw, data=jnp.asarray(host))


# ---------------------------------------------------------------------------
# checksum math: any single bit flip is detected, at every position
# ---------------------------------------------------------------------------
def test_checksum_detects_single_flip_at_any_position():
    rng = np.random.default_rng(0)
    tiles = jnp.asarray(rng.standard_normal((3, 2, 2, 8, 16)) * 0.3,
                        jnp.float32)
    slab = dma.append_checksum_row(tiles)
    assert slab.shape == (3, 2, 2, 9, 16)
    host = np.asarray(slab)
    assert int(jax.vmap(dma.checksum_mismatches)(slab).sum()) == 0
    nbits = host.view(np.uint8).size * 8
    # boundary bits + a seeded sample across the whole slab — including
    # positions inside the checksum row itself
    positions = [0, 7, 31, nbits - 1, nbits // 2]
    positions += [int(p) for p in rng.integers(0, nbits, size=96)]
    for pos in positions:
        flat = host.copy().view(np.uint8).reshape(-1)
        flat[pos // 8] ^= np.uint8(1 << (pos % 8))
        bad = jnp.asarray(flat.view(np.float32).reshape(host.shape))
        n = int(jax.vmap(dma.checksum_mismatches)(bad).sum())
        assert n > 0, f"flip at bit {pos} undetected"


def test_checksum_row_survives_shuffle_but_not_value_change():
    """The checksum is order-independent along Cb (wraparound integer
    add), so a row permutation alone is NOT flagged — it flags value
    changes, which is exactly the ABFT contract (the kernel consumes
    tiles whole; ordering is fixed by the layout)."""
    rng = np.random.default_rng(1)
    tiles = jnp.asarray(rng.standard_normal((1, 6, 6, 4, 8)), jnp.float32)
    slab = np.asarray(dma.append_checksum_row(tiles))
    shuffled = slab.copy()
    shuffled[..., [0, 1], :] = shuffled[..., [1, 0], :]
    assert int(jax.vmap(dma.checksum_mismatches)(
        jnp.asarray(shuffled)).sum()) == 0
    changed = slab.copy()
    changed[0, 0, 0, 0, 0] *= 2.0
    assert int(jax.vmap(dma.checksum_mismatches)(
        jnp.asarray(changed)).sum()) > 0


# ---------------------------------------------------------------------------
# kernel sweep: five geometries x both kernels x prefetch x row_parallel
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("prefetch", [True, False],
                         ids=["prefetch", "sync"])
@pytest.mark.parametrize("row_parallel", [False, True],
                         ids=["seq", "rowpar"])
@pytest.mark.parametrize("name,spec,in_shape,w_shape", GEOMS,
                         ids=[g[0] for g in GEOMS])
def test_kernel_abft_clean_and_flip(name, spec, in_shape, w_shape,
                                    prefetch, row_parallel):
    rng = np.random.default_rng(hash(name) % 2 ** 31)
    x = jnp.asarray(rng.standard_normal(in_shape), jnp.float32)
    w = _filters(w_shape, seed=3)
    b = jnp.asarray(rng.standard_normal((w_shape[-1],)) * 0.1, jnp.float32)
    kw = dict(interpret=True, weight_prefetch=prefetch,
              row_parallel=row_parallel)
    pw = pack_conv_weights(spec, in_shape, w, abft=True, fingerprint=True)
    assert pw.kernel.startswith("pallas"), (name, pw.kernel)

    # clean: armed output bit-identical to unarmed, verdict exactly 0
    y0 = dispatch_conv(spec, x, w, b, **kw)
    y1, v = dispatch_conv(spec, x, w, b, w_packed=pw, abft=True, **kw)
    assert jnp.array_equal(y0, y1), "armed clean path diverged"
    assert int(v) == 0, "false positive on a clean slab"

    # one seeded single-bit flip anywhere in the slab -> detected
    nbits = np.asarray(pw.data).view(np.uint8).size * 8
    pos = int(np.random.default_rng(17).integers(nbits))
    _, v_bad = dispatch_conv(spec, x, w, b, w_packed=_flip_bit(pw, pos),
                             abft=True, **kw)
    assert int(v_bad) > 0, f"{name}: flip at bit {pos} undetected"


def test_kernel_abft_bfp_slab_clean_and_flip():
    """BFP-quantized slabs: the checksum row covers the *requantized*
    bits (appended post-quantization), so clean verdicts stay 0 and
    flips in the quantized slab are still caught."""
    name, spec, in_shape, w_shape = GEOMS[2]          # conv3, winograd
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal(in_shape), jnp.float32)
    w = _filters(w_shape, seed=5)
    pw = pack_conv_weights(spec, in_shape, w, bfp_pack=True, abft=True)
    y0 = dispatch_conv(spec, x, w, None, interpret=True,
                       w_packed=pack_conv_weights(spec, in_shape, w,
                                                  bfp_pack=True))
    y1, v = dispatch_conv(spec, x, w, None, interpret=True, w_packed=pw,
                          abft=True)
    assert jnp.array_equal(y0, y1) and int(v) == 0
    _, v_bad = dispatch_conv(spec, x, w, None, interpret=True,
                             w_packed=_flip_bit(pw, 12345), abft=True)
    assert int(v_bad) > 0


# ---------------------------------------------------------------------------
# slab fingerprints + the WeightStager cache-hit verification
# ---------------------------------------------------------------------------
def test_fingerprint_catches_flip_shape_and_context():
    name, spec, in_shape, w_shape = GEOMS[3]
    w = _filters(w_shape, seed=7)
    pw = pack_conv_weights(spec, in_shape, w, abft=True, fingerprint=True)
    assert verify_packed(pw)
    assert not verify_packed(_flip_bit(pw, 99))
    # context mismatch: same bytes, wrong pack flags expected
    ctx = expected_pack_context(spec, in_shape, abft=True)
    assert pw.fingerprint.context == ctx
    assert pw.fingerprint.matches(pw, expect=ctx)
    other = expected_pack_context(spec, in_shape, abft=False)
    assert not pw.fingerprint.matches(pw, expect=other)
    # unfingerprinted slabs always pass (the check is opt-in)
    assert verify_packed(pack_conv_weights(spec, in_shape, w, abft=True))


def test_fingerprint_excluded_from_pytree():
    """The fingerprint must not leak into jit cache keys or tree ops —
    flatten/unflatten drops it (re-attach via dataclasses.replace)."""
    name, spec, in_shape, w_shape = GEOMS[2]
    pw = pack_conv_weights(spec, in_shape, _filters(w_shape, 11),
                           abft=True, fingerprint=True)
    leaves, treedef = jax.tree_util.tree_flatten(pw)
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    assert rebuilt.fingerprint is None
    assert rebuilt.kernel == pw.kernel
    assert jnp.array_equal(rebuilt.data, pw.data)


def test_stager_cache_hit_verification_repacks():
    """A verifying WeightStager detects a corrupted or contextually stale
    cached slab on the *hit* path and repacks instead of serving it —
    the silent stale-slab reuse failure the fingerprint context closes."""
    name, spec, in_shape, w_shape = GEOMS[2]
    w = _filters(w_shape, seed=13)
    stager = dma.WeightStager(verify=True)
    ctx = expected_pack_context(spec, in_shape, abft=True)
    pack = lambda: stager.stage("k", pack_conv_weights, spec, in_shape, w,
                                abft=True, fingerprint=True, expect=ctx)
    first = pack()
    assert stager.misses == 1
    assert pack() is first and stager.hits == 1     # intact hit
    # corrupt the cached slab in place -> next hit repacks
    stager._cache["k"] = _flip_bit(first, 4242)
    again = pack()
    assert stager.integrity_failures == 1 and stager.misses == 2
    assert verify_packed(again) and jnp.array_equal(again.data, first.data)
    # same bytes, wrong expected context (e.g. layer repacked under
    # different fusion flags) -> also repacked, not reused
    wrong = expected_pack_context(spec, in_shape, abft=False)
    stager.stage("k", pack_conv_weights, spec, in_shape, w,
                 abft=True, fingerprint=True, expect=wrong)
    assert stager.integrity_failures == 2
    # a non-verifying stager serves the corrupted hit untouched (the
    # pre-PR behavior, kept for the zero-sync eager prefetch path)
    plain = dma.WeightStager()
    plain._cache["k"] = _flip_bit(first, 7)
    assert plain.stage("k", pack_conv_weights, spec, in_shape, w,
                       abft=True) is plain._cache["k"]


def test_fault_points_appended_not_reordered():
    """Per-point RNG streams are keyed by FAULT_POINTS index: committed
    chaos schedules stay bit-reproducible only if new points append."""
    from repro.serving.faults import FAULT_POINTS
    assert FAULT_POINTS[:7] == (
        "stage.corrupt", "launch.transient", "launch.crash",
        "retire.nonfinite", "retire.latency", "worker.crash",
        "worker.stall")
    assert FAULT_POINTS[7:] == ("slab.bitflip", "slab.stale",
                                "retire.plausible")


# ---------------------------------------------------------------------------
# serving engine: detect -> repack -> retry, never serve tainted rows
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def sdc_served():
    """Armed reduced config (35px keeps engine compiles cheap) + params
    + the fault-free armed oracle logits for a fixed probe set."""
    cfg = dataclasses.replace(get_config("alexnet").reduced(),
                              image_size=35, use_pallas=True,
                              sdc_abft=True)
    params = alexnet.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(42)
    imgs = [rng.standard_normal((35, 35, 3)).astype(np.float32)
            for _ in range(8)]
    eng = CnnEngine(cfg, _scfg(), params=params)
    oracle = _serve(eng, imgs)
    assert all(r.done for r in oracle)
    return cfg, params, imgs, [np.asarray(r.logits) for r in oracle]


def _scfg(**kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("retry_backoff_ms", 0.01)
    kw.setdefault("screen_sample", 4)
    return CnnServeConfig(**kw)


def _serve(eng, imgs, retries=5):
    rs = [ImageRequest(image=im, retries=retries) for im in imgs]
    for r in rs:
        eng.submit(r)
    eng.run_until_done()
    return rs


def _balanced(eng):
    acc = eng.accounting()
    return acc["balanced"] and acc["in_flight"] == 0


def test_engine_bitflip_detected_before_retire_bitmatch(sdc_served):
    cfg, params, imgs, oracle = sdc_served
    eng = CnnEngine(cfg, _scfg(), params=params)
    _serve(eng, imgs[:4])               # warm compiles before arming
    eng.arm_faults(FaultInjector(
        seed=derive_seed(0, "flip"),
        specs={"slab.bitflip": FaultSpec(at=(0, 1))}))
    eng.reset_metrics()
    rs = _serve(eng, imgs)
    fired = eng.faults.summary()["slab.bitflip"]["fired"]
    assert fired == 2
    assert eng.sdc_detections == fired  # every flip caught, none served
    assert eng.images_retried > 0       # recovery = repack + retry
    assert all(r.done for r in rs) and _balanced(eng)
    # completed logits bit-match the fault-free armed oracle: the retry
    # re-dispatched against a slab repacked from the pristine params
    for r, want in zip(rs, oracle):
        assert np.array_equal(np.asarray(r.logits), want)


def test_engine_verify_slabs_catches_flip_and_stale(sdc_served):
    cfg, params, imgs, oracle = sdc_served
    eng = CnnEngine(cfg, _scfg(verify_slabs=True), params=params)
    _serve(eng, imgs[:4])
    eng.arm_faults(FaultInjector(
        seed=derive_seed(0, "stale"),
        specs={"slab.bitflip": FaultSpec(at=(0,)),
               "slab.stale": FaultSpec(at=(1,))}))
    eng.reset_metrics()
    rs = _serve(eng, imgs)
    # both corruption classes caught *pre-dispatch* by the fingerprint
    # check — the stale slab is only catchable here (a wrong-shape slab
    # would be silently repacked in-trace by the dispatch shape guard)
    assert eng.slab_integrity_failures == 2
    assert eng.sdc_detections == 0      # never reached a forward
    assert all(r.done for r in rs) and _balanced(eng)
    for r, want in zip(rs, oracle):
        assert np.array_equal(np.asarray(r.logits), want)


def test_engine_plausible_corruption_screened(sdc_served):
    cfg, params, imgs, oracle = sdc_served
    eng = CnnEngine(cfg, _scfg(screen_abs_max=1e4), params=params)
    _serve(eng, imgs[:4])
    eng.arm_faults(FaultInjector(
        seed=derive_seed(0, "plausible"),
        specs={"retire.plausible": FaultSpec(at=(0,), magnitude=1e6)}))
    eng.reset_metrics()
    rs = _serve(eng, imgs)
    assert eng.screen_magnitude >= 1    # finite corruption caught by the
    assert eng.screen_nonfinite == 0    # magnitude bound, not isfinite
    assert eng.images_retried >= 1
    assert all(r.done for r in rs) and _balanced(eng)
    acc = eng.accounting()
    assert acc["screen_magnitude"] == eng.screen_magnitude
    for r, want in zip(rs, oracle):
        assert np.array_equal(np.asarray(r.logits), want)


def test_engine_armed_idle_sdc_bit_identical(sdc_served):
    """Defense fully armed + injector attached but idle: serving must be
    bit-identical to the unarmed engine (the no-overhead-when-clean
    contract, extended to the SDC points)."""
    cfg, params, imgs, oracle = sdc_served
    eng = CnnEngine(cfg, _scfg(verify_slabs=True, screen_abs_max=1e6),
                    params=params)
    eng.arm_faults(FaultInjector(seed=derive_seed(0, "idle"), specs={}))
    rs = _serve(eng, imgs)
    assert eng.sdc_detections == 0 and eng.slab_integrity_failures == 0
    assert eng.screen_magnitude == 0
    for r, want in zip(rs, oracle):
        assert np.array_equal(np.asarray(r.logits), want)


def test_engine_repeated_sdc_failures_degrade_bucket(sdc_served):
    """Consecutive detections on one bucket walk the degradation ladder:
    the bucket flips to the direct route (no Pallas weight stream to
    corrupt) and the pen still completes, reported as a degradation."""
    cfg, params, imgs, _ = sdc_served
    eng = CnnEngine(cfg, _scfg(degrade_threshold=3,
                               quarantine_threshold=10), params=params)
    _serve(eng, imgs[:4])
    eng.arm_faults(FaultInjector(
        seed=derive_seed(0, "degrade"),
        specs={"slab.bitflip": FaultSpec(at=(0, 1, 2))}))
    eng.reset_metrics()
    rs = _serve(eng, imgs[:4], retries=6)
    assert eng.sdc_detections == 3
    assert eng.stats()["degraded_buckets"] == [4]
    assert eng.stats()["degradations"][0]["reason"] == "sdc"
    assert all(r.done for r in rs) and _balanced(eng)


def test_engine_stats_surface_sdc_block(sdc_served):
    cfg, params, imgs, _ = sdc_served
    eng = CnnEngine(cfg, _scfg(verify_slabs=True, screen_abs_max=1e6),
                    params=params)
    _serve(eng, imgs[:2])
    s = eng.stats()["sdc"]
    assert s == {"abft_armed": True, "verify_slabs": True,
                 "detections": 0, "slab_integrity_failures": 0,
                 "screen_nonfinite": 0, "screen_magnitude": 0}
