"""Serving fleet: SLO policy, admission shedding, multi-model registry,
pack-once slabs — plus the serving-layer bug-sweep regressions (bounded
latency tracker, staging dtype, bucket_for contract)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import alexnet
from repro.serving import (AdmissionController, CnnEngine, CnnServeConfig,
                           DynamicBucketPolicy, ImageRequest, LatencyTracker,
                           ModelRegistry)


@pytest.fixture(scope="module")
def served():
    cfg = get_config("alexnet").reduced()
    params = alexnet.init(jax.random.PRNGKey(0), cfg)
    ref = jax.jit(lambda p, x: alexnet.apply(p, cfg, x))
    return cfg, params, lambda x: ref(params, x)


def _images(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.standard_normal(
        (n, cfg.image_size, cfg.image_size, cfg.in_channels)
    ).astype(np.float32)


# ---------------------------------------------------------------------------
# bug sweep regressions
# ---------------------------------------------------------------------------
def test_latency_tracker_bounded():
    """The tracker is a ring buffer: unbounded request streams must not
    grow host memory, while percentiles track the recent window."""
    t = LatencyTracker(window=64)
    for _ in range(1000):
        t.record(1.0)                   # old regime: 1000 ms latencies
    for _ in range(64):
        t.record(0.001)                 # recent regime: 1 ms
    assert len(t) == 64                 # bounded, not 1064
    assert t.total == 1064              # lifetime count still exact
    p = t.percentiles_ms()
    assert p["p99"] == pytest.approx(1.0, rel=0.1)   # old spikes aged out
    assert p["p50"] <= p["p90"] <= p["p99"]


def test_latency_tracker_window_shorter_than_stream():
    t = LatencyTracker(window=4)
    for ms in (1, 2, 3, 4, 5, 6):
        t.record(ms / 1e3)
    assert len(t) == 4 and t.total == 6
    assert t.percentiles_ms()["p50"] == pytest.approx(4.5, rel=0.05)


def test_bucket_for_rejects_oversized_group(served):
    """A group larger than max_batch must raise, not silently pad to an
    undeclared bucket shape (which would jit-compile off-ladder)."""
    cfg, params, _ = served
    eng = CnnEngine(cfg, CnnServeConfig(max_batch=4), params=params)
    assert eng.bucket_for(3) == 4 and eng.bucket_for(4) == 4
    with pytest.raises(ValueError, match="exceeds max_batch"):
        eng.bucket_for(5)


def test_staging_buffer_uses_config_dtype(served):
    """The staged H2D buffer must carry the model's dtype — a bf16 model
    silently fed fp32 doubles the §3.5 stream-buffer bytes."""
    cfg, params, _ = served
    eng32 = CnnEngine(cfg, CnnServeConfig(max_batch=2), params=params)
    assert eng32._buf_dtype == jnp.dtype("float32")

    cfg16 = dataclasses.replace(cfg, dtype="bfloat16")
    eng16 = CnnEngine(cfg16, CnnServeConfig(max_batch=2), seed=0)
    assert eng16._buf_dtype == jnp.dtype(jnp.bfloat16)
    imgs = _images(cfg16, 2, seed=5)
    reqs = [ImageRequest(image=im) for im in imgs]
    for r in reqs:
        eng16.submit(r)
    eng16.run_until_done()
    ref = np.asarray(jax.jit(lambda p, x: alexnet.apply(p, cfg16, x))(
        eng16.params, jnp.asarray(imgs)), np.float32)
    got = np.stack([np.asarray(r.logits, np.float32) for r in reqs])
    scale = np.abs(ref).max() + 1e-9
    assert np.abs(got - ref).max() / scale < 5e-2   # bf16 tolerance
    assert all(r.done for r in reqs)


# ---------------------------------------------------------------------------
# SLO policy units
# ---------------------------------------------------------------------------
def test_dynamic_bucket_policy_inserts_dominant_size():
    pol = DynamicBucketPolicy(8, slo_ms=5.0, max_extra=2, min_samples=8)
    assert pol.buckets() == (1, 2, 4, 8)
    for _ in range(8):
        pol.observe_admit(6)            # bursts of 6 padded to 8 (25% waste)
        pol.observe_latency(0.010)      # 10ms > 5ms SLO
    assert pol.maybe_resize() == 6
    assert pol.buckets() == (1, 2, 4, 6, 8)
    assert pol.resizes == [6]


def test_dynamic_bucket_policy_noop_within_slo():
    pol = DynamicBucketPolicy(8, slo_ms=50.0, min_samples=4)
    for _ in range(8):
        pol.observe_admit(6)
        pol.observe_latency(0.010)      # 10ms < 50ms SLO: healthy
    assert pol.maybe_resize() is None
    assert pol.buckets() == (1, 2, 4, 8)


def test_dynamic_bucket_policy_bounded_insertions():
    pol = DynamicBucketPolicy(8, slo_ms=1.0, max_extra=1, min_samples=2)
    for size in (6, 3):
        for _ in range(8):
            pol.observe_admit(size)
            pol.observe_latency(0.050)
        pol.maybe_resize()
    assert pol.extra == [6]             # second insert refused: max_extra=1
    assert len(pol.buckets()) == len((1, 2, 4, 8)) + 1


def test_dynamic_bucket_policy_skips_small_padding():
    """7->8 pads 12.5% < pad_frac: not worth an extra compiled shape."""
    pol = DynamicBucketPolicy(8, slo_ms=1.0, min_samples=2, pad_frac=0.2)
    for _ in range(8):
        pol.observe_admit(7)
        pol.observe_latency(0.050)
    assert pol.maybe_resize() is None


def test_admission_controller_sheds_on_backlog():
    adm = AdmissionController(slo_ms=10.0, slack=1.0)
    assert adm.admit(10 ** 6)           # no estimate yet: admit everything
    adm.observe_batch(4, 0.008)         # 2ms per image
    assert adm.t_img_ms == pytest.approx(2.0)
    assert adm.admit(5)                 # 10ms wait == budget: still in
    assert not adm.admit(6)             # 12ms wait: shed
    assert adm.estimated_wait_ms(6) == pytest.approx(12.0)


def test_engine_sheds_and_reports(served):
    """Shed requests are *reported* (False + req.shed + counter), never
    silently dropped, and never occupy a slot or produce logits."""
    cfg, params, _ = served
    eng = CnnEngine(cfg, CnnServeConfig(max_batch=2, slo_ms=1.0,
                                        admission=True), params=params)
    eng.admission.observe_batch(1, 1.0)     # 1000ms/img: anything queued busts
    ok = ImageRequest(image=_images(cfg, 1, seed=1)[0])
    assert eng.try_submit(ok)               # empty queue: 0 wait, admitted
    shed = ImageRequest(image=_images(cfg, 1, seed=2)[0])
    assert not eng.try_submit(shed)         # 1 image backlog > 1ms SLO
    assert shed.shed and not shed.done
    assert eng.images_shed == 1
    eng.run_until_done()
    assert ok.done and ok.logits is not None
    assert not shed.done and shed.logits is None
    s = eng.stats()
    assert s["images_shed"] == 1 and s["images_completed"] == 1
    assert eng.sched.submitted == 1         # shed never reached the queue


def test_arm_slo_on_live_engine(served):
    """SLO control plane attaches after warmup without losing compiled
    buckets or counters (calibrated-SLO deployment path)."""
    cfg, params, _ = served
    eng = CnnEngine(cfg, CnnServeConfig(max_batch=2), params=params)
    assert eng.policy is None and eng.admission is None
    for r in [ImageRequest(image=im) for im in _images(cfg, 2, seed=3)]:
        eng.submit(r)
    eng.run_until_done()
    compiled = set(eng._compiled)
    eng.arm_slo(50.0, dynamic_buckets=True, admission=True)
    assert eng.policy is not None and eng.admission is not None
    assert eng.scfg.slo_ms == 50.0
    assert eng._compiled == compiled        # warm state survives
    assert eng.images_completed == 2
    eng.arm_slo(None)                       # disarm
    assert eng.policy is None and eng.admission is None


def test_goodput_accounting(served):
    cfg, params, _ = served
    eng = CnnEngine(cfg, CnnServeConfig(max_batch=4, slo_ms=10_000.0),
                    params=params)
    for r in [ImageRequest(image=im) for im in _images(cfg, 4, seed=4)]:
        eng.submit(r)
    eng.run_until_done()
    s = eng.stats()
    assert s["images_within_slo"] == 4      # 10s SLO: everything makes it
    assert s["goodput_imgs_per_s"] == pytest.approx(s["imgs_per_s"])


# ---------------------------------------------------------------------------
# pack-once hoisted slabs
# ---------------------------------------------------------------------------
def test_pack_once_slabs_bitmatch_and_reuse(served):
    """apply(packed=pack_serving_slabs(...)) must bit-match the plain
    forward at the same batch, and the engine must pack each bucket shape
    exactly once (slabs are reused jit arguments, not re-packed)."""
    cfg, params, ref = served
    imgs = jnp.asarray(_images(cfg, 4, seed=7))
    packed = alexnet.pack_serving_slabs(params, cfg, 4)
    got = jax.jit(lambda p, s, x: alexnet.apply(p, cfg, x, packed=s))(
        params, packed, imgs)
    assert np.array_equal(np.asarray(got), np.asarray(ref(imgs)))

    eng = CnnEngine(cfg, CnnServeConfig(max_batch=4), params=params)
    assert eng._hoist
    first = eng._slabs(4)
    assert eng._slabs(4) is first           # cached, not re-packed
    for r in [ImageRequest(image=im) for im in _images(cfg, 4, seed=8)]:
        eng.submit(r)
    eng.run_until_done()
    assert eng._slabs(4) is first and set(eng._packed) == {4}


# ---------------------------------------------------------------------------
# multi-model registry
# ---------------------------------------------------------------------------
def test_registry_two_models_interleaved():
    """AlexNet + VGG-16 served concurrently through one registry: each
    request's logits bit-match its own model's direct apply, and the
    per-model counters stay consistent under interleaved submission."""
    reg = ModelRegistry(slot_budget=16)
    cfgs, refs = {}, {}
    for name in ("alexnet", "vgg16"):
        cfg = get_config(name).reduced()
        eng = reg.register(name, cfg, CnnServeConfig(max_batch=4))
        cfgs[name] = cfg
        refs[name] = jax.jit(
            lambda p, x, c=cfg: alexnet.apply(p, c, x)), eng.params
    imgs = {"alexnet": _images(cfgs["alexnet"], 3, seed=10),
            "vgg16": _images(cfgs["vgg16"], 2, seed=11)}
    reqs = {n: [ImageRequest(image=im) for im in imgs[n]] for n in imgs}
    for pair in zip(reqs["alexnet"], reqs["vgg16"]):    # interleave models
        for r, n in zip(pair, ("alexnet", "vgg16")):
            assert reg.submit(n, r)
    assert reg.submit("alexnet", reqs["alexnet"][2])
    reg.run_until_done()

    for n in ("alexnet", "vgg16"):
        ref, params = refs[n]
        expect = np.asarray(ref(params, jnp.asarray(imgs[n])))
        got = np.stack([r.logits for r in reqs[n]])
        assert np.array_equal(got, expect), (n, np.abs(got - expect).max())
    s = reg.stats()
    assert s["models"]["alexnet"]["images_completed"] == 3
    assert s["models"]["vgg16"]["images_completed"] == 2
    assert s["fleet"]["images_completed"] == 5
    assert s["fleet"]["images_shed"] == 0
    assert s["fleet"]["slots_used"] == 16 and reg.idle
    for n in ("alexnet", "vgg16"):
        e = reg[n]
        assert e.sched.submitted == e.sched.completed == len(reqs[n])
        assert e.sched.occupancy == 0


def test_registry_enforces_slot_budget():
    cfg = get_config("alexnet").reduced()
    reg = ModelRegistry(slot_budget=20)
    reg.register("a", cfg, CnnServeConfig(max_batch=8))     # 16 slots
    with pytest.raises(ValueError, match="slots"):
        reg.register("b", cfg, CnnServeConfig(max_batch=4))  # needs 8 > 4 left
    reg.register("c", cfg, CnnServeConfig(max_batch=2))     # 4 slots: fits
    assert reg.slots_used == 20
    with pytest.raises(ValueError, match="already registered"):
        reg.register("a", cfg, CnnServeConfig(max_batch=1))
    with pytest.raises(KeyError, match="unknown model"):
        reg.submit("nope", ImageRequest(image=_images(cfg, 1)[0]))


# ---------------------------------------------------------------------------
# traffic generators (benchmarks/serve_fleet.py)
# ---------------------------------------------------------------------------
def test_trace_generators():
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
    from benchmarks.serve_fleet import (bursty_trace, diurnal_trace,
                                        poisson_trace)
    rng = np.random.default_rng(0)
    p = poisson_trace(100.0, 2.0, rng)
    assert p == sorted(p) and all(0 <= t < 2.0 for t in p)
    assert 100 < len(p) < 300           # ~200 expected

    b = bursty_trace(5, 6, 0.1, np.random.default_rng(1))
    assert len(b) == 30 and b == sorted(b)
    assert b[:6] == [0.0] * 6           # first burst lands together

    d = diurnal_trace(100.0, 2.0, 1.0, np.random.default_rng(2))
    assert d == sorted(d) and all(0 <= t < 2.0 for t in d)
    assert len(d) > 50
    # same seed -> same trace (benchmark reproducibility)
    d2 = diurnal_trace(100.0, 2.0, 1.0, np.random.default_rng(2))
    assert d == d2
