"""Layer-level unit tests: RoPE, norms, MLA, cache writes, BFP matmul op."""
import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.nn.attention import cache_write, len_mask, pos_of
from repro.nn.layers import layernorm, layernorm_init, rmsnorm, rmsnorm_init, rope


def test_rope_preserves_norm():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, 16))
    pos = jnp.arange(8)[None, :]
    y = rope(x, pos)
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(y, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)),
                               rtol=1e-5)


def test_rope_relative_property():
    """<rope(q,i), rope(k,j)> depends only on i - j."""
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 32))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 32))

    def score(i, j):
        qi = rope(q, jnp.asarray([[i]]))
        kj = rope(k, jnp.asarray([[j]]))
        return float(jnp.sum(qi * kj))

    assert abs(score(5, 3) - score(105, 103)) < 1e-3
    assert abs(score(0, 0) - score(77, 77)) < 1e-3


@given(st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_norms_normalize(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (4, 32)) * 7 + 3
    y = rmsnorm(rmsnorm_init(32, jnp.float32), x)
    rms = np.asarray(jnp.sqrt(jnp.mean(jnp.square(y), -1)))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3)
    z = layernorm(layernorm_init(32, jnp.float32), x)
    np.testing.assert_allclose(np.asarray(z.mean(-1)), 0.0, atol=1e-4)
    np.testing.assert_allclose(np.asarray(z.std(-1)), 1.0, rtol=1e-2)


def test_cache_write_scalar_and_vector():
    buf = jnp.zeros((2, 8, 3))
    val = jnp.ones((2, 1, 3))
    out = cache_write(buf, val, jnp.int32(5))
    assert float(out[:, 5].sum()) == 6.0 and float(out.sum()) == 6.0
    out2 = cache_write(buf, val, jnp.asarray([2, 7], jnp.int32))
    assert float(out2[0, 2].sum()) == 3.0
    assert float(out2[1, 7].sum()) == 3.0
    assert float(out2.sum()) == 6.0


def test_pos_and_mask_helpers():
    assert pos_of(jnp.int32(4), 3).tolist() == [[4, 5, 6]]
    assert pos_of(jnp.asarray([1, 9]), 2).tolist() == [[1, 2], [9, 10]]
    m = len_mask(jnp.asarray([2, 5]), 6, extra=1)
    assert m.shape == (2, 1, 1, 6)
    assert m[0, 0, 0].tolist() == [True] * 3 + [False] * 3
