"""MoE dispatch: routing correctness, capacity, load-balance aux."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ArchConfig, MoECfg
from repro.nn.moe import moe_apply, moe_capacity, moe_init


def _cfg(**kw):
    moe = MoECfg(num_experts=8, top_k=2, d_ff=32, group_size=16,
                 capacity_factor=kw.pop("cf", 100.0),
                 num_shared=kw.pop("shared", 0))
    return ArchConfig(name="t", family="moe", num_layers=2, d_model=16,
                      num_heads=2, num_kv_heads=2, head_dim=8, d_ff=32,
                      vocab_size=64, moe=moe, dtype="float32",
                      param_dtype="float32", **kw)


def _dense_ref(p, cfg, x):
    """Unconstrained-capacity oracle: explicit per-token top-k mixture."""
    B, S, D = x.shape
    logits = x.reshape(-1, D) @ p["router"]["w"]
    probs = jax.nn.softmax(logits, -1)
    gates, idx = jax.lax.top_k(probs, cfg.moe.top_k)
    gates = gates / gates.sum(-1, keepdims=True)
    w = p["experts"]
    out = jnp.zeros((B * S, D))
    for t in range(B * S):
        acc = jnp.zeros((D,))
        for j in range(cfg.moe.top_k):
            e = idx[t, j]
            h = jax.nn.silu(x.reshape(-1, D)[t] @ w["w1"][e]) * \
                (x.reshape(-1, D)[t] @ w["w3"][e])
            acc = acc + gates[t, j] * (h @ w["w2"][e])
        out = out.at[t].set(acc)
    return out.reshape(B, S, D)


def test_moe_matches_dense_reference():
    cfg = _cfg()
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16))
    y, _ = moe_apply(p, cfg, x)
    ref = _dense_ref(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_capacity_drops_tokens():
    """With capacity factor ~0, most tokens are dropped -> output ~0."""
    cfg_lo = _cfg(cf=0.01)
    p = moe_init(jax.random.PRNGKey(0), cfg_lo)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16))
    y_lo, _ = moe_apply(p, cfg_lo, x)
    cfg_hi = _cfg(cf=100.0)
    y_hi, _ = moe_apply(p, cfg_hi, x)
    assert float(jnp.abs(y_lo).mean()) < float(jnp.abs(y_hi).mean())
    assert moe_capacity(cfg_lo.moe, 16) == 1


def test_shared_experts_add():
    cfg = _cfg(shared=2)
    p = moe_init(jax.random.PRNGKey(0), cfg)
    assert "shared" in p
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 16))
    y, _ = moe_apply(p, cfg, x)
    assert y.shape == x.shape
    # shared experts are always-on: zeroing router weights still gives output
    p2 = dict(p, router={"w": jnp.zeros_like(p["router"]["w"])})
    y2, _ = moe_apply(p2, cfg, x)
    assert float(jnp.abs(y2).mean()) > 0


def test_aux_loss_prefers_balance():
    cfg = _cfg()
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16))
    _, aux = moe_apply(p, cfg, x, return_aux=True)
    assert aux is not None and float(aux) > 0
    # a router collapsed onto one expert must have higher aux loss
    w = p["router"]["w"].at[:, 0].set(100.0)
    _, aux_bad = moe_apply(dict(p, router={"w": w}), cfg, x, return_aux=True)
    assert float(aux_bad) > float(aux)


def test_decode_single_token_groups():
    cfg = _cfg()
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 1, 16))
    y, _ = moe_apply(p, cfg, x)
    ref = _dense_ref(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
