"""Fused layer pipeline: in-kernel LRN + max-pool epilogue parity.

The layer-level ConvSpec fuses cross-channel LRN and VALID max-pool into
the conv call; these tests pin every route (direct / jnp-winograd / pallas
interpret) against the unfused conv -> lrn -> maxpool reference
(``repro.nn.pooling`` on top of ``conv2d_ref``), including grouped
conv2-style layers (LRN windows crossing the group seam), odd feature
sizes where the pool drops trailing rows, and the five AlexNet layer
geometries end-to-end.  Also: the fused HBM traffic model is strictly
lower than unfused for every fusing layer, and the BFP FC path tracks the
f32 classifier.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.winograd import conv2d_hbm_bytes
from repro.kernels.conv.ref import conv2d_ref
from repro.models import alexnet
from repro.nn.conv import ConvSpec, dispatch_conv, resolve_kernel
from repro.nn.pooling import LrnParams, apply_epilogue, lrn, pooled_hw

ROUTES = ("direct", "winograd", "pallas")


def _reference(x, w, b, spec: ConvSpec):
    """Unfused oracle: conv(+bias+relu) -> lrn -> maxpool, stagewise."""
    y = conv2d_ref(x, w, b, stride=spec.stride, padding=spec.padding,
                   groups=spec.groups, relu=spec.relu)
    return apply_epilogue(y, spec.lrn if spec.fuse_lrn else None,
                          (spec.pool_window, spec.pool_stride)
                          if spec.fuse_pool else None)


def _run(spec: ConvSpec, H: int, c_in: int, c_out: int, seed=0, B=2):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((B, H, H, c_in)), jnp.float32)
    w = jnp.asarray(rng.standard_normal(
        (spec.kernel, spec.kernel, c_in // spec.groups, c_out)) * 0.3,
        jnp.float32)
    b = jnp.asarray(rng.standard_normal((c_out,)), jnp.float32)
    out = dispatch_conv(spec, x, w, b, interpret=True)
    ref = _reference(x, w, b, spec)
    return np.asarray(out), np.asarray(ref)


# the five AlexNet layer geometries (reduced channel counts), incl. the
# strided conv1/conv2 (the direct Pallas kernel on route="pallas") and the
# grouped pool-only conv5
ALEXNET_LAYERS = [
    ("conv1", dict(kernel=11, stride=4, padding="VALID", relu=True,
                   fuse_lrn=True, fuse_pool=True), 35, 3, 16),
    ("conv2", dict(kernel=5, groups=2, relu=True, fuse_lrn=True,
                   fuse_pool=True), 13, 16, 32),
    ("conv3", dict(kernel=3, relu=True), 13, 32, 48),
    ("conv4", dict(kernel=3, groups=2, relu=True), 13, 48, 48),
    ("conv5", dict(kernel=3, groups=2, relu=True, fuse_pool=True),
     13, 48, 32),
]


@pytest.mark.parametrize("route", ROUTES)
@pytest.mark.parametrize("name,kw,H,c_in,c_out", ALEXNET_LAYERS)
def test_alexnet_layer_geometries_fused_matches_unfused(route, name, kw, H,
                                                        c_in, c_out):
    spec = ConvSpec(route=route, **kw)
    out, ref = _run(spec, H, c_in, c_out)
    assert out.shape == ref.shape, (name, out.shape, ref.shape)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4,
                               err_msg=f"{name} via {route}")


@pytest.mark.parametrize("route", ROUTES)
@pytest.mark.parametrize("H", [7, 8, 9, 12])   # even sizes drop a conv row
def test_fused_pool_odd_and_partial_sizes(route, H):
    """Pool windows near the boundary: even conv outputs leave a dangling
    row/col that VALID pooling drops; fused epilogues must agree."""
    spec = ConvSpec(kernel=3, relu=True, fuse_lrn=True, fuse_pool=True,
                    route=route)
    out, ref = _run(spec, H, 8, 8, seed=H)
    assert out.shape[1] == pooled_hw(H)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("route", ROUTES)
def test_fused_lrn_crosses_group_seam(route):
    """LRN spans the full concatenated channel dim (Krizhevsky conv2): the
    fused output must match the cross-seam reference, which demonstrably
    differs from applying LRN per group."""
    spec = ConvSpec(kernel=3, groups=2, relu=True, fuse_lrn=True,
                    route=route)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((2, 9, 9, 12)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 3, 6, 12)) * 0.3, jnp.float32)
    b = jnp.asarray(rng.standard_normal((12,)), jnp.float32)
    conv = conv2d_ref(x, w, b, groups=2, relu=True)
    ref = lrn(conv, spec.lrn)                   # LRN over all 12 channels
    per_group = np.concatenate(                 # LRN within each group of 6
        [np.asarray(lrn(conv[..., g * 6:(g + 1) * 6], spec.lrn))
         for g in range(2)], axis=-1)
    assert not np.allclose(np.asarray(ref), per_group, rtol=1e-4, atol=1e-4), (
        "test geometry must make the seam observable")
    out = np.asarray(dispatch_conv(spec, x, w, b, interpret=True))
    np.testing.assert_allclose(out, np.asarray(ref), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("route", ROUTES)
def test_fused_lrn_only_and_unfused_bias_defer(route):
    """lrn without pool, and the deferred-bias epilogue ordering
    (conv -> +b -> relu -> lrn -> pool) when fuse_bias=False."""
    spec = ConvSpec(kernel=3, relu=True, fuse_lrn=True, route=route)
    out, ref = _run(spec, 10, 8, 8, seed=5)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)
    spec2 = ConvSpec(kernel=3, relu=True, fuse_bias=False, fuse_lrn=True,
                     fuse_pool=True, route=route)
    out2, ref2 = _run(spec2, 10, 8, 8, seed=6)
    np.testing.assert_allclose(out2, ref2, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("c_block,k_block,groups", [
    (4, 4, 2),     # ncb=3, nkb=2 per group: multi-block deposit into y_ref
    (4, 5, 2),     # K=8 % 5 != 0 -> kernel widens Kb to K (no pad channels)
    (128, 128, 1),  # single-block baseline on the same geometry
])
def test_pallas_fused_kernel_multiblock(c_block, k_block, groups):
    """The fused kernel's channel-block reduction and per-k-block deposit
    into the full-channel scratch, on non-trivial block decompositions
    (several C blocks, several K blocks per group, non-dividing k_block)."""
    from repro.kernels.conv.winograd import conv2d_winograd
    rng = np.random.default_rng(11)
    c_in, c_out = 12 * groups, 8 * groups
    x = jnp.asarray(rng.standard_normal((2, 17, 17, c_in)), jnp.float32)
    w = jnp.asarray(rng.standard_normal(
        (3, 3, c_in // groups, c_out)) * 0.3, jnp.float32)
    b = jnp.asarray(rng.standard_normal((c_out,)), jnp.float32)
    p = LrnParams()
    out = conv2d_winograd(x, w, b, groups=groups, relu=True, lrn=p,
                          pool=(3, 2), c_block=c_block, k_block=k_block,
                          pool_row_block=2, interpret=True)
    ref = apply_epilogue(conv2d_ref(x, w, b, groups=groups, relu=True),
                         p, (3, 2))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_alexnet_features_has_no_freestanding_epilogues():
    """The model declares LRN/pool in its layer specs (conv1, conv2 lrn+pool;
    conv5 pool), and the legacy free-standing helpers are gone."""
    cfg = get_config("alexnet")
    specs = alexnet.layer_specs(cfg)
    assert [s.fuse_lrn for s in specs] == [True, True, False, False, False]
    assert [s.fuse_pool for s in specs] == [True, True, False, False, True]
    assert not hasattr(alexnet, "_lrn") and not hasattr(alexnet, "_maxpool")
    assert specs[0].lrn == LrnParams(n=cfg.lrn_n, k=cfg.lrn_k,
                                     alpha=cfg.lrn_alpha, beta=cfg.lrn_beta)


def test_alexnet_pallas_route_end_to_end():
    """Full model through the Pallas fused kernels == direct route."""
    cfg = get_config("alexnet").reduced()
    params = alexnet.init(jax.random.PRNGKey(0), cfg)
    imgs = jax.random.normal(jax.random.PRNGKey(1),
                             (2, cfg.image_size, cfg.image_size, 3))
    ref = alexnet.apply(params,
                        dataclasses.replace(cfg, use_winograd=False), imgs)
    out = alexnet.apply(params, dataclasses.replace(cfg, use_pallas=True),
                        imgs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def _layer_hbm(spec, B, h, c_in, c_out, route):
    from repro.nn.conv import MODEL_ROUTES
    model_route, wino = MODEL_ROUTES[route]
    return conv2d_hbm_bytes(
        B, h, h, c_in, c_out, spec.kernel,
        spec.winograd_m if wino else None, stride=spec.stride,
        padding=spec.padding, relu=spec.relu, fuse_lrn=spec.fuse_lrn,
        fuse_pool=spec.fuse_pool, groups=spec.groups, route=model_route)


def test_hbm_model_fused_strictly_lower_for_all_alexnet_layers():
    """conv2d_hbm_bytes, full 227px config on the pallas route: every one
    of the five layers — conv1's strided direct kernel included — models
    fused traffic strictly below the unfused stagewise baseline, and below
    the lax unfused-direct baseline too."""
    cfg = get_config("alexnet")
    h, c_in = cfg.image_size, cfg.in_channels
    for spec, c_out in zip(alexnet.layer_specs(cfg), cfg.conv_channels):
        route = resolve_kernel(spec.with_route("pallas"))
        assert route.startswith("pallas"), spec
        hb = _layer_hbm(spec, 1, h, c_in, c_out, route)
        assert hb["layer_fused_bytes"] < hb["layer_unfused_bytes"], spec
        assert hb["layer_fused_bytes"] < hb["layer_unfused_direct_bytes"]
        assert hb["fused_savings"] > 1.0
        h, c_in = spec.out_hw(h), c_out


def test_hbm_model_lax_route_gets_no_fusion_credit():
    """On the lax direct route the in-function epilogue is still separate
    XLA ops — the model must not credit on-chip fusion there."""
    cfg = get_config("alexnet")
    spec = alexnet.layer_specs(cfg)[0]          # conv1, lrn+pool
    hb = _layer_hbm(spec, 1, cfg.image_size, cfg.in_channels,
                    cfg.conv_channels[0], "direct")
    assert hb["layer_fused_bytes"] == hb["layer_unfused_bytes"]
    assert hb["stream_bytes"] == hb["raw_bytes"]
    assert hb["fused_savings"] == 1.0


def test_hbm_model_direct_kernel_strided_slab_terms():
    """m=None + pallas models the strided direct kernel: no tile tensor, a
    halo-padded slab (>= raw, bounded), and the fused layer writes only the
    pooled map — strictly below the 3-round-trip unfused baseline."""
    hb = conv2d_hbm_bytes(1, 227, 227, 3, 96, 11, None, stride=4,
                          padding="VALID", relu=True, fuse_lrn=True,
                          fuse_pool=True, route="pallas")
    assert hb["tile_inflation"] == 0.0
    raw = 227 * 227 * 3 * 4
    assert raw <= hb["stream_bytes"] <= 1.3 * raw   # halo/pool-overlap pad
    assert hb["fused_savings"] > 2.0            # 3 round-trips -> 1 write
    assert hb["layer_fused_bytes"] < hb["layer_unfused_direct_bytes"]


def test_hbm_model_filter_cache_reuse():
    """The batch-innermost grid fetches each weight tile once per
    batch_block images; the model's weight stream reflects the reuse."""
    hb = conv2d_hbm_bytes(8, 13, 13, 256, 384, 3, 4, batch_block=8)
    assert hb["filter_cache_reuse"] == 8.0
    assert hb["weight_hbm_bytes"] * 8 == hb["weight_hbm_nocache_bytes"]
    hb1 = conv2d_hbm_bytes(8, 13, 13, 256, 384, 3, 4, batch_block=1)
    assert hb1["filter_cache_reuse"] == 1.0


def test_fc_bfp_parity_with_f32_classifier():
    """§3.6 satellite: the BFP FC path tracks the exact f32 classifier
    within the shared-exponent int8 quantization error."""
    cfg = get_config("alexnet").reduced()
    params = alexnet.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(7)
    feats = jnp.asarray(rng.standard_normal(
        (4, alexnet._fc_input_dim(cfg))), jnp.float32)
    exact = np.asarray(alexnet.classifier(params, cfg, feats))
    bfp = np.asarray(alexnet.classifier(
        params, dataclasses.replace(cfg, fc_bfp=True), feats))
    assert exact.shape == bfp.shape == (4, cfg.num_classes)
    scale = np.abs(exact).max() + 1e-9
    assert np.abs(bfp - exact).max() / scale < 5e-2
    assert not np.array_equal(bfp, exact)       # the quantized path ran


# ---------------------------------------------------------------------------
# manual-DMA double-buffered weight pipeline (§3.5 filter prefetch)
# ---------------------------------------------------------------------------
def _kernel_kwargs(kw):
    """ConvSpec-style layer kwargs -> direct kernel-entry kwargs."""
    return dict(stride=kw.get("stride", 1),
                padding=kw.get("padding", "SAME"),
                groups=kw.get("groups", 1), relu=kw.get("relu", False),
                lrn=LrnParams() if kw.get("fuse_lrn") else None,
                pool=(3, 2) if kw.get("fuse_pool") else None)


def _layer_arrays(kw, H, c_in, c_out, seed=0, B=3):
    rng = np.random.default_rng(seed)
    k = kw["kernel"]
    x = jnp.asarray(rng.standard_normal((B, H, H, c_in)), jnp.float32)
    w = jnp.asarray(rng.standard_normal(
        (k, k, c_in // kw.get("groups", 1), c_out)) * k ** -1, jnp.float32)
    b = jnp.asarray(rng.standard_normal((c_out,)), jnp.float32)
    return x, w, b


@pytest.mark.parametrize("name,kw,H,c_in,c_out", ALEXNET_LAYERS)
def test_weight_prefetch_bit_parity_direct_kernel(name, kw, H, c_in, c_out):
    """prefetch on/off must be bit-equal on the strided direct kernel for
    every AlexNet layer geometry — same copies, same slots, only the
    overlap differs.  Small c/k blocks + batch_block=2 force a multi-tile
    stream with several cache generations (the odd-tile slot-parity wrap
    included)."""
    from repro.kernels.conv.direct import conv2d_direct
    x, w, b = _layer_arrays(kw, H, c_in, c_out, seed=H + c_in + c_out)
    kk = _kernel_kwargs(kw)
    out = {}
    for pf in (True, False):
        out[pf] = np.asarray(conv2d_direct(
            x, w, b, weight_prefetch=pf, c_block=max(c_in // 4, 1),
            k_block=max(c_out // 4, 1), batch_block=2, interpret=True, **kk))
    assert np.array_equal(out[True], out[False]), name


@pytest.mark.parametrize("name,kw,H,c_in,c_out",
                         [l for l in ALEXNET_LAYERS
                          if l[1].get("stride", 1) == 1])
def test_weight_prefetch_bit_parity_winograd_kernel(name, kw, H, c_in,
                                                    c_out):
    """Same invariant on the Winograd-domain kernel (stride-1 layers; the
    5x5 conv2 runs as F(4,5)) — both the plain and the layer-fused grids."""
    from repro.kernels.conv.winograd import conv2d_winograd
    x, w, b = _layer_arrays(kw, H, c_in, c_out, seed=2 * H + c_out)
    kk = _kernel_kwargs(kw)
    kk.pop("stride")
    out = {}
    for pf in (True, False):
        out[pf] = np.asarray(conv2d_winograd(
            x, w, b, weight_prefetch=pf, c_block=max(c_in // 4, 1),
            k_block=max(c_out // 4, 1), batch_block=2, interpret=True, **kk))
    assert np.array_equal(out[True], out[False]), name


@pytest.mark.parametrize("route", ("direct", "winograd", "pallas"))
@pytest.mark.parametrize("name,kw,H,c_in,c_out", ALEXNET_LAYERS[:2])
def test_dispatch_prefetch_bit_parity(route, name, kw, H, c_in, c_out):
    """dispatch_conv's weight_prefetch flag: bit-equal on the Pallas
    datapaths, inert elsewhere."""
    from repro.nn.conv import dispatch_conv
    spec = ConvSpec(route=route, **kw)
    x, w, b = _layer_arrays(kw, H, c_in, c_out, seed=3)
    on = np.asarray(dispatch_conv(spec, x, w, b, weight_prefetch=True,
                                  interpret=True))
    off = np.asarray(dispatch_conv(spec, x, w, b, weight_prefetch=False,
                                   interpret=True))
    assert np.array_equal(on, off), (route, name)


@pytest.mark.parametrize("name,kw,H,c_in,c_out", ALEXNET_LAYERS)
def test_staged_weight_slab_bit_equal(name, kw, H, c_in, c_out):
    """pack_conv_weights ahead of time == in-trace packing, bit for bit,
    on every layer's resolved Pallas datapath."""
    from repro.nn.conv import dispatch_conv, pack_conv_weights
    spec = ConvSpec(route="pallas", **kw)
    x, w, b = _layer_arrays(kw, H, c_in, c_out, seed=11)
    packed = pack_conv_weights(spec, x.shape, w)
    assert packed.kernel.startswith("pallas")
    assert packed.data is not None
    base = np.asarray(dispatch_conv(spec, x, w, b, interpret=True))
    staged = np.asarray(dispatch_conv(spec, x, w, b, w_packed=packed,
                                      interpret=True))
    assert np.array_equal(base, staged), name


def test_stale_weight_slab_is_ignored():
    """A slab staged for a different input shape (different plan) must be
    ignored, not crash the kernel or corrupt the output."""
    from repro.nn.conv import dispatch_conv, pack_conv_weights
    spec = ConvSpec(kernel=3, relu=True, fuse_pool=True, route="pallas")
    x, w, b = _layer_arrays(dict(kernel=3), 13, 8, 8, seed=5)
    stale = pack_conv_weights(spec, (3, 29, 29, 8), w)
    base = np.asarray(dispatch_conv(spec, x, w, b, interpret=True))
    out = np.asarray(dispatch_conv(spec, x, w, b, w_packed=stale,
                                   interpret=True))
    assert np.array_equal(base, out)


def test_stale_bfp_slab_is_repacked_not_dropped():
    """A bfp-marked slab that misses the plan (wrong shape, or a
    deferred-bias call) must be *repacked* quantized for the actual plan —
    §3.6 quantization is never silently dropped to f32."""
    from repro.nn.conv import dispatch_conv, pack_conv_weights
    spec = ConvSpec(kernel=3, relu=True, fuse_pool=True, route="pallas")
    x, w, b = _layer_arrays(dict(kernel=3), 13, 8, 8, seed=6)
    fresh = pack_conv_weights(spec, x.shape, w, bfp_pack=True)
    want = np.asarray(dispatch_conv(spec, x, w, b, w_packed=fresh,
                                    interpret=True))
    plain = np.asarray(dispatch_conv(spec, x, w, b, interpret=True))
    assert not np.array_equal(want, plain)      # quantization is observable
    stale = pack_conv_weights(spec, (3, 29, 29, 8), w, bfp_pack=True)
    out = np.asarray(dispatch_conv(spec, x, w, b, w_packed=stale,
                                   interpret=True))
    assert np.array_equal(want, out)
    # deferred bias strips the fused plan too — still quantized
    spec_d = dataclasses.replace(spec, fuse_bias=False)
    out_d = np.asarray(dispatch_conv(spec_d, x, w, b, w_packed=fresh,
                                     interpret=True))
    plain_d = np.asarray(dispatch_conv(spec_d, x, w, b, interpret=True))
    assert not np.array_equal(out_d, plain_d)


def test_weight_stager_caches_across_forward_passes():
    """A persistent stager packs each slab once: the second forward pass
    is all cache hits and bit-equal to the first params' unstaged run."""
    from repro.kernels.conv.dma import WeightStager
    cfg = dataclasses.replace(get_config("alexnet").reduced(),
                              use_pallas=True)
    params = alexnet.init(jax.random.PRNGKey(0), cfg)
    imgs = jax.random.normal(jax.random.PRNGKey(1),
                             (2, cfg.image_size, cfg.image_size, 3))
    stager = WeightStager()
    a = np.asarray(alexnet.features(params, cfg, imgs, stager=stager))
    misses = stager.misses
    assert misses == 5                  # one pack per conv layer
    b = np.asarray(alexnet.features(params, cfg, imgs, stager=stager))
    assert stager.misses == misses      # second pass: no repacking
    assert stager.hits >= 5
    ref = np.asarray(alexnet.features(params, cfg, imgs))
    assert np.array_equal(a, b) and np.array_equal(a, ref)
    # a different batch size resolves different plans: the shape-carrying
    # keys pack fresh slabs (no stale-slab reuse) and stay correct
    imgs1 = imgs[:1]
    c = np.asarray(alexnet.features(params, cfg, imgs1, stager=stager))
    assert stager.misses == misses + 5
    assert np.array_equal(c, np.asarray(alexnet.features(params, cfg,
                                                         imgs1)))
    # the same stager serving a conv_bfp config must not reuse the
    # unquantized slabs — the cache key carries the quantization mode
    cfgq = dataclasses.replace(cfg, conv_bfp=True)
    q = np.asarray(alexnet.features(params, cfgq, imgs1, stager=stager))
    assert not np.array_equal(q, c)


def test_conv_bfp_slab_tracks_f32_and_differs():
    """§3.6 on the staged filter slabs: conv_bfp quantizes the weight
    stream (so outputs must differ bit-wise) while tracking the f32 model
    within shared-exponent int8 error."""
    cfg = dataclasses.replace(get_config("alexnet").reduced(),
                              use_pallas=True)
    params = alexnet.init(jax.random.PRNGKey(0), cfg)
    imgs = jax.random.normal(jax.random.PRNGKey(2),
                             (2, cfg.image_size, cfg.image_size, 3))
    exact = np.asarray(alexnet.apply(params, cfg, imgs))
    bfp = np.asarray(alexnet.apply(
        params, dataclasses.replace(cfg, conv_bfp=True), imgs))
    assert not np.array_equal(bfp, exact)       # the quantized stream ran
    scale = np.abs(exact).max() + 1e-9
    assert np.abs(bfp - exact).max() / scale < 5e-2


def test_fc_bfp_staged_quantization_matches_unstaged():
    """conv5's prefetch_next stages fc6's quantized BFP stream; the staged
    classifier must bit-match the unstaged fc_bfp classifier."""
    from repro.kernels.conv.dma import WeightStager
    cfg = dataclasses.replace(get_config("alexnet").reduced(), fc_bfp=True)
    params = alexnet.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(9)
    feats = jnp.asarray(rng.standard_normal(
        (4, alexnet._fc_input_dim(cfg))), jnp.float32)
    unstaged = np.asarray(alexnet.classifier(params, cfg, feats))
    stager = WeightStager()
    stager.stage("fc6", alexnet._stage_fc6, params, cfg)
    staged = np.asarray(alexnet.classifier(params, cfg, feats,
                                           stager=stager))
    assert np.array_equal(unstaged, staged)
    assert stager.hits >= 1             # the staged stream was consumed


def test_hbm_model_prefetch_exposure_terms():
    """Prefetch on exposes one warmup tile; off exposes the whole stream;
    hidden + exposed == total; non-Pallas routes expose everything."""
    hb = conv2d_hbm_bytes(8, 27, 27, 96, 256, 5, None, groups=2,
                          fuse_lrn=True, fuse_pool=True, route="pallas",
                          batch_block=4)
    # one warmup tile per filter-cache generation (B=8, Bb=4 -> 2)
    assert hb["weight_exposed_prefetch_bytes"] == 2 * hb["weight_tile_bytes"]
    assert hb["weight_exposed_noprefetch_bytes"] == hb["weight_hbm_bytes"]
    assert hb["weight_fetches"] > 1
    assert (hb["weight_exposed_prefetch_bytes"]
            < hb["weight_exposed_noprefetch_bytes"])
    assert (hb["weight_hbm_hidden_bytes"] + hb["weight_hbm_exposed_bytes"]
            == hb["weight_hbm_bytes"])
    off = conv2d_hbm_bytes(8, 27, 27, 96, 256, 5, None, groups=2,
                           fuse_lrn=True, fuse_pool=True, route="pallas",
                           batch_block=4, weight_prefetch=False)
    assert off["weight_hbm_exposed_bytes"] == off["weight_hbm_bytes"]
    assert off["weight_hbm_hidden_bytes"] == 0
    lax = conv2d_hbm_bytes(8, 27, 27, 96, 256, 5, None, groups=2,
                           route="direct")
    assert lax["weight_hbm_exposed_bytes"] == lax["weight_hbm_bytes"]
    assert lax["weight_hbm_hidden_bytes"] == 0


def test_hbm_model_prefetch_exposed_below_noprefetch_all_layers():
    """Full 227px AlexNet on the pallas route with the K dimension split
    into tiles (the steady-state streaming regime): every layer models
    prefetch-exposed weight bytes strictly below the non-prefetch stream
    (the CI bench gate's invariant)."""
    cfg = get_config("alexnet")
    h, c_in = cfg.image_size, cfg.in_channels
    for spec, c_out in zip(alexnet.layer_specs(cfg), cfg.conv_channels):
        route = resolve_kernel(spec.with_route("pallas"))
        hb = conv2d_hbm_bytes(
            8, h, h, c_in, c_out, spec.kernel,
            spec.winograd_m if route == "pallas-winograd" else None,
            stride=spec.stride, padding=spec.padding, relu=spec.relu,
            fuse_lrn=spec.fuse_lrn, fuse_pool=spec.fuse_pool,
            groups=spec.groups, route="pallas", k_block=32, batch_block=4)
        assert hb["weight_fetches"] > 1, spec
        assert (hb["weight_exposed_prefetch_bytes"]
                < hb["weight_exposed_noprefetch_bytes"]), spec
        h, c_in = spec.out_hw(h), c_out


def test_hbm_model_single_tile_stream_fetched_once():
    """A single-tile weight stream (g=1, one C block, one K block) is
    fetched once and kept resident — the model must not charge the
    per-transition re-copy the kernels elide, and both prefetch modes
    expose the same single warmup tile."""
    hb = conv2d_hbm_bytes(8, 227, 227, 3, 96, 11, None, stride=4,
                          padding="VALID", relu=True, fuse_lrn=True,
                          fuse_pool=True, route="pallas", batch_block=4)
    assert hb["weight_fetches"] == 1
    assert hb["weight_hbm_bytes"] == hb["weight_tile_bytes"]
    assert (hb["weight_exposed_prefetch_bytes"]
            == hb["weight_exposed_noprefetch_bytes"]
            == hb["weight_tile_bytes"])
    assert hb["weight_hbm_hidden_bytes"] == 0


def test_single_tile_stream_kernel_parity():
    """Kernel-level single-tile elision: with one weight tile (default
    blocks, g=1) both prefetch modes and several cache generations give
    the reference answer bit-equally."""
    from repro.kernels.conv.direct import conv2d_direct
    from repro.kernels.conv.direct import plan as dplan
    x, w, b = _layer_arrays(dict(kernel=5), 17, 6, 8, seed=21, B=5)
    p = dplan(x.shape, w.shape, stride=2, batch_block=2)
    assert p.weights.n_tiles == 1
    ref = _reference(x, w, b, ConvSpec(kernel=5, stride=2, relu=True))
    for pf in (True, False):
        out = np.asarray(conv2d_direct(x, w, b, stride=2, relu=True,
                                       batch_block=2, weight_prefetch=pf,
                                       interpret=True))
        np.testing.assert_allclose(out, np.asarray(ref), rtol=1e-4,
                                   atol=1e-4)


def test_conv_layer_roofline_terms():
    """Weight-stream roofline: hiding the filter stream raises effective
    arithmetic intensity and can flip a layer from memory- to
    compute-bound."""
    from repro.core.roofline import (ConvLayerRoofline, conv_layer_roofline,
                                     network_conv_roofline)
    hb = conv2d_hbm_bytes(8, 27, 27, 96, 256, 5, None, groups=2,
                          fuse_lrn=True, fuse_pool=True, route="pallas")
    on = conv_layer_roofline("conv2", hb, flops=1e9, weight_prefetch=True)
    off = conv_layer_roofline("conv2", hb, flops=1e9, weight_prefetch=False)
    assert on.ai_total == off.ai_total          # same bytes moved
    assert on.ai_exposed > off.ai_exposed       # fewer exposed
    assert on.t_memory < off.t_memory
    assert on.weight_hidden_bytes > 0 and off.weight_hidden_bytes == 0
    # a layer whose exposed bytes shrink enough flips to compute-bound
    big = ConvLayerRoofline("x", flops=1e12, feature_bytes=1e9,
                            weight_bytes=4e9, weight_exposed_bytes=1e6)
    small = ConvLayerRoofline("x", flops=1e12, feature_bytes=1e9,
                              weight_bytes=4e9, weight_exposed_bytes=4e9)
    assert big.bound == "compute" and small.bound == "memory"
    net = network_conv_roofline([on, off])
    assert net["weight_bytes"] == on.weight_bytes + off.weight_bytes
    assert net["bound"] in ("compute", "memory")
