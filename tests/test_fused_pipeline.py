"""Fused layer pipeline: in-kernel LRN + max-pool epilogue parity.

The layer-level ConvSpec fuses cross-channel LRN and VALID max-pool into
the conv call; these tests pin every route (direct / jnp-winograd / pallas
interpret) against the unfused conv -> lrn -> maxpool reference
(``repro.nn.pooling`` on top of ``conv2d_ref``), including grouped
conv2-style layers (LRN windows crossing the group seam), odd feature
sizes where the pool drops trailing rows, and the five AlexNet layer
geometries end-to-end.  Also: the fused HBM traffic model is strictly
lower than unfused for every fusing layer, and the BFP FC path tracks the
f32 classifier.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.winograd import conv2d_hbm_bytes
from repro.kernels.conv.ref import conv2d_ref
from repro.models import alexnet
from repro.nn.conv import ConvSpec, dispatch_conv, resolve_kernel
from repro.nn.pooling import LrnParams, apply_epilogue, lrn, pooled_hw

ROUTES = ("direct", "winograd", "pallas")


def _reference(x, w, b, spec: ConvSpec):
    """Unfused oracle: conv(+bias+relu) -> lrn -> maxpool, stagewise."""
    y = conv2d_ref(x, w, b, stride=spec.stride, padding=spec.padding,
                   groups=spec.groups, relu=spec.relu)
    return apply_epilogue(y, spec.lrn if spec.fuse_lrn else None,
                          (spec.pool_window, spec.pool_stride)
                          if spec.fuse_pool else None)


def _run(spec: ConvSpec, H: int, c_in: int, c_out: int, seed=0, B=2):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((B, H, H, c_in)), jnp.float32)
    w = jnp.asarray(rng.standard_normal(
        (spec.kernel, spec.kernel, c_in // spec.groups, c_out)) * 0.3,
        jnp.float32)
    b = jnp.asarray(rng.standard_normal((c_out,)), jnp.float32)
    out = dispatch_conv(spec, x, w, b, interpret=True)
    ref = _reference(x, w, b, spec)
    return np.asarray(out), np.asarray(ref)


# the five AlexNet layer geometries (reduced channel counts), incl. the
# strided conv1/conv2 (the direct Pallas kernel on route="pallas") and the
# grouped pool-only conv5
ALEXNET_LAYERS = [
    ("conv1", dict(kernel=11, stride=4, padding="VALID", relu=True,
                   fuse_lrn=True, fuse_pool=True), 35, 3, 16),
    ("conv2", dict(kernel=5, groups=2, relu=True, fuse_lrn=True,
                   fuse_pool=True), 13, 16, 32),
    ("conv3", dict(kernel=3, relu=True), 13, 32, 48),
    ("conv4", dict(kernel=3, groups=2, relu=True), 13, 48, 48),
    ("conv5", dict(kernel=3, groups=2, relu=True, fuse_pool=True),
     13, 48, 32),
]


@pytest.mark.parametrize("route", ROUTES)
@pytest.mark.parametrize("name,kw,H,c_in,c_out", ALEXNET_LAYERS)
def test_alexnet_layer_geometries_fused_matches_unfused(route, name, kw, H,
                                                        c_in, c_out):
    spec = ConvSpec(route=route, **kw)
    out, ref = _run(spec, H, c_in, c_out)
    assert out.shape == ref.shape, (name, out.shape, ref.shape)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4,
                               err_msg=f"{name} via {route}")


@pytest.mark.parametrize("route", ROUTES)
@pytest.mark.parametrize("H", [7, 8, 9, 12])   # even sizes drop a conv row
def test_fused_pool_odd_and_partial_sizes(route, H):
    """Pool windows near the boundary: even conv outputs leave a dangling
    row/col that VALID pooling drops; fused epilogues must agree."""
    spec = ConvSpec(kernel=3, relu=True, fuse_lrn=True, fuse_pool=True,
                    route=route)
    out, ref = _run(spec, H, 8, 8, seed=H)
    assert out.shape[1] == pooled_hw(H)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("route", ROUTES)
def test_fused_lrn_crosses_group_seam(route):
    """LRN spans the full concatenated channel dim (Krizhevsky conv2): the
    fused output must match the cross-seam reference, which demonstrably
    differs from applying LRN per group."""
    spec = ConvSpec(kernel=3, groups=2, relu=True, fuse_lrn=True,
                    route=route)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((2, 9, 9, 12)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 3, 6, 12)) * 0.3, jnp.float32)
    b = jnp.asarray(rng.standard_normal((12,)), jnp.float32)
    conv = conv2d_ref(x, w, b, groups=2, relu=True)
    ref = lrn(conv, spec.lrn)                   # LRN over all 12 channels
    per_group = np.concatenate(                 # LRN within each group of 6
        [np.asarray(lrn(conv[..., g * 6:(g + 1) * 6], spec.lrn))
         for g in range(2)], axis=-1)
    assert not np.allclose(np.asarray(ref), per_group, rtol=1e-4, atol=1e-4), (
        "test geometry must make the seam observable")
    out = np.asarray(dispatch_conv(spec, x, w, b, interpret=True))
    np.testing.assert_allclose(out, np.asarray(ref), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("route", ROUTES)
def test_fused_lrn_only_and_unfused_bias_defer(route):
    """lrn without pool, and the deferred-bias epilogue ordering
    (conv -> +b -> relu -> lrn -> pool) when fuse_bias=False."""
    spec = ConvSpec(kernel=3, relu=True, fuse_lrn=True, route=route)
    out, ref = _run(spec, 10, 8, 8, seed=5)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)
    spec2 = ConvSpec(kernel=3, relu=True, fuse_bias=False, fuse_lrn=True,
                     fuse_pool=True, route=route)
    out2, ref2 = _run(spec2, 10, 8, 8, seed=6)
    np.testing.assert_allclose(out2, ref2, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("c_block,k_block,groups", [
    (4, 4, 2),     # ncb=3, nkb=2 per group: multi-block deposit into y_ref
    (4, 5, 2),     # K=8 % 5 != 0 -> kernel widens Kb to K (no pad channels)
    (128, 128, 1),  # single-block baseline on the same geometry
])
def test_pallas_fused_kernel_multiblock(c_block, k_block, groups):
    """The fused kernel's channel-block reduction and per-k-block deposit
    into the full-channel scratch, on non-trivial block decompositions
    (several C blocks, several K blocks per group, non-dividing k_block)."""
    from repro.kernels.conv.winograd import conv2d_winograd
    rng = np.random.default_rng(11)
    c_in, c_out = 12 * groups, 8 * groups
    x = jnp.asarray(rng.standard_normal((2, 17, 17, c_in)), jnp.float32)
    w = jnp.asarray(rng.standard_normal(
        (3, 3, c_in // groups, c_out)) * 0.3, jnp.float32)
    b = jnp.asarray(rng.standard_normal((c_out,)), jnp.float32)
    p = LrnParams()
    out = conv2d_winograd(x, w, b, groups=groups, relu=True, lrn=p,
                          pool=(3, 2), c_block=c_block, k_block=k_block,
                          pool_row_block=2, interpret=True)
    ref = apply_epilogue(conv2d_ref(x, w, b, groups=groups, relu=True),
                         p, (3, 2))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_alexnet_features_has_no_freestanding_epilogues():
    """The model declares LRN/pool in its layer specs (conv1, conv2 lrn+pool;
    conv5 pool), and the legacy free-standing helpers are gone."""
    cfg = get_config("alexnet")
    specs = alexnet.layer_specs(cfg)
    assert [s.fuse_lrn for s in specs] == [True, True, False, False, False]
    assert [s.fuse_pool for s in specs] == [True, True, False, False, True]
    assert not hasattr(alexnet, "_lrn") and not hasattr(alexnet, "_maxpool")
    assert specs[0].lrn == LrnParams(n=cfg.lrn_n, k=cfg.lrn_k,
                                     alpha=cfg.lrn_alpha, beta=cfg.lrn_beta)


def test_alexnet_pallas_route_end_to_end():
    """Full model through the Pallas fused kernels == direct route."""
    cfg = get_config("alexnet").reduced()
    params = alexnet.init(jax.random.PRNGKey(0), cfg)
    imgs = jax.random.normal(jax.random.PRNGKey(1),
                             (2, cfg.image_size, cfg.image_size, 3))
    ref = alexnet.apply(params,
                        dataclasses.replace(cfg, use_winograd=False), imgs)
    out = alexnet.apply(params, dataclasses.replace(cfg, use_pallas=True),
                        imgs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def _layer_hbm(spec, B, h, c_in, c_out, route):
    from repro.nn.conv import MODEL_ROUTES
    model_route, wino = MODEL_ROUTES[route]
    return conv2d_hbm_bytes(
        B, h, h, c_in, c_out, spec.kernel,
        spec.winograd_m if wino else None, stride=spec.stride,
        padding=spec.padding, relu=spec.relu, fuse_lrn=spec.fuse_lrn,
        fuse_pool=spec.fuse_pool, groups=spec.groups, route=model_route)


def test_hbm_model_fused_strictly_lower_for_all_alexnet_layers():
    """conv2d_hbm_bytes, full 227px config on the pallas route: every one
    of the five layers — conv1's strided direct kernel included — models
    fused traffic strictly below the unfused stagewise baseline, and below
    the lax unfused-direct baseline too."""
    cfg = get_config("alexnet")
    h, c_in = cfg.image_size, cfg.in_channels
    for spec, c_out in zip(alexnet.layer_specs(cfg), cfg.conv_channels):
        route = resolve_kernel(spec.with_route("pallas"))
        assert route.startswith("pallas"), spec
        hb = _layer_hbm(spec, 1, h, c_in, c_out, route)
        assert hb["layer_fused_bytes"] < hb["layer_unfused_bytes"], spec
        assert hb["layer_fused_bytes"] < hb["layer_unfused_direct_bytes"]
        assert hb["fused_savings"] > 1.0
        h, c_in = spec.out_hw(h), c_out


def test_hbm_model_lax_route_gets_no_fusion_credit():
    """On the lax direct route the in-function epilogue is still separate
    XLA ops — the model must not credit on-chip fusion there."""
    cfg = get_config("alexnet")
    spec = alexnet.layer_specs(cfg)[0]          # conv1, lrn+pool
    hb = _layer_hbm(spec, 1, cfg.image_size, cfg.in_channels,
                    cfg.conv_channels[0], "direct")
    assert hb["layer_fused_bytes"] == hb["layer_unfused_bytes"]
    assert hb["stream_bytes"] == hb["raw_bytes"]
    assert hb["fused_savings"] == 1.0


def test_hbm_model_direct_kernel_strided_slab_terms():
    """m=None + pallas models the strided direct kernel: no tile tensor, a
    halo-padded slab (>= raw, bounded), and the fused layer writes only the
    pooled map — strictly below the 3-round-trip unfused baseline."""
    hb = conv2d_hbm_bytes(1, 227, 227, 3, 96, 11, None, stride=4,
                          padding="VALID", relu=True, fuse_lrn=True,
                          fuse_pool=True, route="pallas")
    assert hb["tile_inflation"] == 0.0
    raw = 227 * 227 * 3 * 4
    assert raw <= hb["stream_bytes"] <= 1.3 * raw   # halo/pool-overlap pad
    assert hb["fused_savings"] > 2.0            # 3 round-trips -> 1 write
    assert hb["layer_fused_bytes"] < hb["layer_unfused_direct_bytes"]


def test_hbm_model_filter_cache_reuse():
    """The batch-innermost grid fetches each weight tile once per
    batch_block images; the model's weight stream reflects the reuse."""
    hb = conv2d_hbm_bytes(8, 13, 13, 256, 384, 3, 4, batch_block=8)
    assert hb["filter_cache_reuse"] == 8.0
    assert hb["weight_hbm_bytes"] * 8 == hb["weight_hbm_nocache_bytes"]
    hb1 = conv2d_hbm_bytes(8, 13, 13, 256, 384, 3, 4, batch_block=1)
    assert hb1["filter_cache_reuse"] == 1.0


def test_fc_bfp_parity_with_f32_classifier():
    """§3.6 satellite: the BFP FC path tracks the exact f32 classifier
    within the shared-exponent int8 quantization error."""
    cfg = get_config("alexnet").reduced()
    params = alexnet.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(7)
    feats = jnp.asarray(rng.standard_normal(
        (4, alexnet._fc_input_dim(cfg))), jnp.float32)
    exact = np.asarray(alexnet.classifier(params, cfg, feats))
    bfp = np.asarray(alexnet.classifier(
        params, dataclasses.replace(cfg, fc_bfp=True), feats))
    assert exact.shape == bfp.shape == (4, cfg.num_classes)
    scale = np.abs(exact).max() + 1e-9
    assert np.abs(bfp - exact).max() / scale < 5e-2
    assert not np.array_equal(bfp, exact)       # the quantized path ran
