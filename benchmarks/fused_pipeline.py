"""Fused layer pipeline: measured wall-clock + modeled HBM bytes per layer.

The paper's headline argument (§3.5, Table 3) is that running conv, ReLU,
LRN, and pool on-chip keeps feature maps out of external memory between
layers.  This benchmark runs every AlexNet conv layer both ways —

  unfused:  dispatch_conv (conv+bias+ReLU)  ->  lrn  ->  maxpool
            (full-resolution feature map round-trips HBM up to 3x)
  fused:    one dispatch_conv with the layer-level ConvSpec
            (LRN+pool in the conv epilogue; only the pooled map is written)

— and emits measured wall-clock per layer next to the modeled HBM traffic
(``core/winograd.py::conv2d_hbm_bytes`` fused-vs-unfused terms), writing the
repo's first ``BENCH_*.json`` artifact.

    PYTHONPATH=src python benchmarks/fused_pipeline.py [--full]
        [--route {auto,direct,winograd,pallas}] [--check]
        [--out BENCH_fused_pipeline.json]

``--check`` exits nonzero unless the fused modeled bytes are strictly lower
than unfused for every layer that fuses anything (the CI bench-smoke gate).
"""
import argparse
import dataclasses
import json
import sys

import jax
import numpy as np

try:                      # package use (benchmarks.run)
    from .common import emit, time_us
except ImportError:       # direct `python benchmarks/fused_pipeline.py` (CI)
    from common import emit, time_us

import jax.numpy as jnp                                    # noqa: E402
from repro.core.winograd import conv2d_hbm_bytes           # noqa: E402
from repro.launch.serve import CNN_ROUTES, apply_cnn_route  # noqa: E402
from repro.models import alexnet                           # noqa: E402
from repro.nn import pooling                               # noqa: E402
from repro.nn.conv import dispatch_conv, resolve_route     # noqa: E402


def layer_rows(cfg, *, batch: int, seed: int = 0):
    """Per-layer fused vs unfused: wall-clock (measured) + HBM bytes (model)."""
    rng = np.random.default_rng(seed)
    route = alexnet._route(cfg)
    rows = []
    h, c_in = cfg.image_size, cfg.in_channels
    for i, (spec, c_out) in enumerate(zip(alexnet.layer_specs(cfg),
                                          cfg.conv_channels)):
        spec = spec.with_route(route)
        unfused = dataclasses.replace(spec, fuse_lrn=False, fuse_pool=False)
        x = jnp.asarray(rng.standard_normal((batch, h, h, c_in)), jnp.float32)
        w = jnp.asarray(rng.standard_normal(
            (spec.kernel, spec.kernel, c_in // spec.groups, c_out))
            * (spec.kernel ** -2), jnp.float32)
        b = jnp.asarray(rng.standard_normal((c_out,)), jnp.float32)

        def run_unfused(x, w, b, spec=spec, unfused=unfused):
            return pooling.apply_epilogue(
                dispatch_conv(unfused, x, w, b),
                spec.lrn if spec.fuse_lrn else None,
                (spec.pool_window, spec.pool_stride) if spec.fuse_pool
                else None)

        def run_fused(x, w, b, spec=spec):
            return dispatch_conv(spec, x, w, b)

        t_un = time_us(jax.jit(run_unfused), x, w, b)
        t_fu = time_us(jax.jit(run_fused), x, w, b)
        wino = resolve_route(spec) in ("winograd", "pallas")
        hb = conv2d_hbm_bytes(
            batch, h, h, c_in, c_out, spec.kernel,
            spec.winograd_m if wino else None, stride=spec.stride,
            padding=spec.padding, fuse_lrn=spec.fuse_lrn,
            fuse_pool=spec.fuse_pool, pool_window=spec.pool_window,
            pool_stride=spec.pool_stride)
        rows.append({
            "layer": f"conv{i+1}",
            "route": resolve_route(spec),
            "in_hw": h, "c_in": c_in, "c_out": c_out,
            "fuse_lrn": spec.fuse_lrn, "fuse_pool": spec.fuse_pool,
            "unfused_us": t_un, "fused_us": t_fu,
            "unfused_hbm_bytes": hb["layer_unfused_bytes"],
            "fused_hbm_bytes": hb["layer_fused_bytes"],
            "hbm_savings": hb["fused_savings"],
        })
        h, c_in = spec.out_hw(h), c_out
    return rows


def check_rows(rows) -> list:
    """Layers that fuse something but don't model strictly lower traffic."""
    return [r for r in rows if (r["fuse_lrn"] or r["fuse_pool"])
            and not r["fused_hbm_bytes"] < r["unfused_hbm_bytes"]]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full 227px AlexNet (default: reduced config)")
    ap.add_argument("--route", default="auto", choices=CNN_ROUTES)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--out", default="BENCH_fused_pipeline.json")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless every fused layer models strictly "
                         "lower HBM bytes than unfused")
    args = ap.parse_args(argv)

    cfg = alexnet.AlexNetConfig()
    if not args.full:
        cfg = cfg.reduced()
    cfg = apply_cnn_route(cfg, args.route)

    rows = layer_rows(cfg, batch=args.batch)
    emit([{"name": f"fused_pipeline/{r['layer']}",
           "us_per_call": r["fused_us"],
           "derived": (f"route={r['route']};unfused_us={r['unfused_us']:.0f}"
                       f";unfused_MB={r['unfused_hbm_bytes']/2**20:.2f}"
                       f";fused_MB={r['fused_hbm_bytes']/2**20:.2f}"
                       f";hbm_savings={r['hbm_savings']:.2f}x")}
          for r in rows])

    artifact = {
        "config": dataclasses.asdict(cfg),
        "batch": args.batch,
        "route": args.route,
        "backend": jax.default_backend(),
        "layers": rows,
    }
    with open(args.out, "w") as f:
        json.dump(artifact, f, indent=2)

    if args.check:
        bad = check_rows(rows)
        if bad:
            print(f"fused_pipeline/CHECK_FAILED,0,"
                  f"layers={[r['layer'] for r in bad]}")
            return 1
        print("fused_pipeline/CHECK_OK,0,"
              "fused_bytes<unfused_bytes_for_all_fused_layers")
    return 0


if __name__ == "__main__":
    sys.exit(main())
