"""Fused layer pipeline: measured wall-clock + modeled HBM bytes per layer.

The paper's headline argument (§3.5, Table 3) is that running conv, ReLU,
LRN, and pool on-chip keeps feature maps out of external memory between
layers.  This benchmark runs every AlexNet conv layer both ways —

  unfused:  dispatch_conv (conv+bias+ReLU)  ->  lrn  ->  maxpool
            (full-resolution feature map round-trips HBM up to 3x)
  fused:    one dispatch_conv with the layer-level ConvSpec
            (LRN+pool in the conv epilogue; only the pooled map is written)

— and emits measured wall-clock per layer next to the modeled HBM traffic
(``core/winograd.py::conv2d_hbm_bytes``, route-aware: the strided direct
kernel's slab terms for conv1/conv2, the Winograd slab for the 3x3 layers,
and no fusion credit on the lax route, whose in-function epilogue is still
separate XLA ops).  Under ``--route pallas`` every layer — conv1's 11x11
stride 4 included — resolves to a Pallas kernel, so every row models fused
bytes strictly below the unfused stagewise baseline.

A ``network`` aggregate reports the whole-network modeled-bytes ratio,
fused-pallas vs the unfused-*direct* (lax, stagewise) baseline, next to
the same ratio computed under the PR-3 rules (conv1/conv2 silently on lax,
optimistic lax fusion credit) to show the strided-kernel payoff.

    PYTHONPATH=src python benchmarks/fused_pipeline.py [--full]
        [--route {auto,direct,winograd,pallas}] [--check]
        [--image-size N] [--out BENCH_fused_pipeline.json]

``--check`` exits nonzero unless every Pallas-resolved layer models fused
bytes strictly below unfused — all five AlexNet layers under
``--route pallas`` — and no layer models fused above unfused (the CI
bench-smoke gate).
"""
import argparse
import dataclasses
import json
import sys

import jax
import numpy as np

try:                      # package use (benchmarks.run)
    from .common import emit, time_us
except ImportError:       # direct `python benchmarks/fused_pipeline.py` (CI)
    from common import emit, time_us

import jax.numpy as jnp                                    # noqa: E402
from repro.core.winograd import conv2d_hbm_bytes           # noqa: E402
from repro.launch.serve import CNN_ROUTES, apply_cnn_route  # noqa: E402
from repro.models import alexnet                           # noqa: E402
from repro.nn import pooling                               # noqa: E402
from repro.nn.conv import (MODEL_ROUTES, dispatch_conv,  # noqa: E402
                           resolve_kernel)


def _layer_model(spec, batch, h, c_in, c_out, kernel_name):
    route, wino = MODEL_ROUTES[kernel_name]
    return conv2d_hbm_bytes(
        batch, h, h, c_in, c_out, spec.kernel,
        spec.winograd_m if wino else None, stride=spec.stride,
        padding=spec.padding, relu=spec.relu, fuse_lrn=spec.fuse_lrn,
        fuse_pool=spec.fuse_pool, pool_window=spec.pool_window,
        pool_stride=spec.pool_stride, groups=spec.groups, route=route)


def _pr3_model(spec, batch, h, c_in, c_out):
    """The PR-3 modeling rules, for the network-ratio comparison: pallas
    silently fell back to lax off the 3x3 stride-1 path, the lax route was
    (optimistically) credited with fusion, and bias/ReLU was not counted as
    an unfused stage pass."""
    eligible = spec.winograd_eligible
    hb = conv2d_hbm_bytes(
        batch, h, h, c_in, c_out, spec.kernel,
        spec.winograd_m if eligible else None, stride=spec.stride,
        padding=spec.padding, relu=False, fuse_lrn=spec.fuse_lrn,
        fuse_pool=spec.fuse_pool, pool_window=spec.pool_window,
        pool_stride=spec.pool_stride, groups=spec.groups,
        route="pallas" if eligible else "direct", c_block=128)
    return {"unfused": hb["layer_unfused_bytes"],
            "fused": hb["stream_unfused_bytes"] + hb["final_out_bytes"]}


def layer_rows(cfg, *, batch: int, seed: int = 0):
    """Per-layer fused vs unfused: wall-clock (measured) + HBM bytes (model)."""
    rng = np.random.default_rng(seed)
    route = alexnet._route(cfg)
    rows = []
    h, c_in = cfg.image_size, cfg.in_channels
    for i, (spec, c_out) in enumerate(zip(alexnet.layer_specs(cfg),
                                          cfg.conv_channels)):
        spec = spec.with_route(route)
        unfused = dataclasses.replace(spec, fuse_lrn=False, fuse_pool=False)
        x = jnp.asarray(rng.standard_normal((batch, h, h, c_in)), jnp.float32)
        w = jnp.asarray(rng.standard_normal(
            (spec.kernel, spec.kernel, c_in // spec.groups, c_out))
            * (spec.kernel ** -2), jnp.float32)
        b = jnp.asarray(rng.standard_normal((c_out,)), jnp.float32)

        def run_unfused(x, w, b, spec=spec, unfused=unfused):
            return pooling.apply_epilogue(
                dispatch_conv(unfused, x, w, b),
                spec.lrn if spec.fuse_lrn else None,
                (spec.pool_window, spec.pool_stride) if spec.fuse_pool
                else None)

        def run_fused(x, w, b, spec=spec):
            return dispatch_conv(spec, x, w, b)

        t_un = time_us(jax.jit(run_unfused), x, w, b)
        t_fu = time_us(jax.jit(run_fused), x, w, b)
        kernel_name = resolve_kernel(spec, in_hw=h)
        hb = _layer_model(spec, batch, h, c_in, c_out, kernel_name)
        pr3 = _pr3_model(spec, batch, h, c_in, c_out)
        rows.append({
            "layer": f"conv{i+1}",
            "route": kernel_name,
            "in_hw": h, "c_in": c_in, "c_out": c_out,
            "fuse_lrn": spec.fuse_lrn, "fuse_pool": spec.fuse_pool,
            "unfused_us": t_un, "fused_us": t_fu,
            "unfused_hbm_bytes": hb["layer_unfused_bytes"],
            "fused_hbm_bytes": hb["layer_fused_bytes"],
            "unfused_direct_hbm_bytes": hb["layer_unfused_direct_bytes"],
            "hbm_savings": hb["fused_savings"],
            "weight_hbm_bytes": hb["weight_hbm_bytes"],
            "filter_cache_reuse": hb["filter_cache_reuse"],
            "pr3_unfused_hbm_bytes": pr3["unfused"],
            "pr3_fused_hbm_bytes": pr3["fused"],
        })
        h, c_in = spec.out_hw(h), c_out
    return rows


def network_summary(rows) -> dict:
    """Whole-network modeled-bytes ratio: fused-pallas vs unfused-direct,
    next to the PR-3-rule value for the same config."""
    fused = sum(r["fused_hbm_bytes"] for r in rows)
    unfused_direct = sum(r["unfused_direct_hbm_bytes"] for r in rows)
    pr3_f = sum(r["pr3_fused_hbm_bytes"] for r in rows)
    pr3_u = sum(r["pr3_unfused_hbm_bytes"] for r in rows)
    return {
        "fused_hbm_bytes": fused,
        "unfused_direct_hbm_bytes": unfused_direct,
        "ratio": unfused_direct / fused,
        "pr3_rule_ratio": pr3_u / pr3_f,
    }


def check_rows(rows) -> list:
    """Layers violating the gate: a Pallas-resolved layer must model fused
    strictly below unfused; no layer may model fused above unfused."""
    bad = []
    for r in rows:
        if r["route"].startswith("pallas"):
            if not r["fused_hbm_bytes"] < r["unfused_hbm_bytes"]:
                bad.append(r)
        elif r["fused_hbm_bytes"] > r["unfused_hbm_bytes"]:
            bad.append(r)
    return bad


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full 227px AlexNet (default: reduced config)")
    ap.add_argument("--route", default="auto", choices=CNN_ROUTES)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--image-size", type=int, default=None,
                    help="override the input image size (reduced default "
                         "131, so the late layers keep non-degenerate "
                         "feature maps)")
    ap.add_argument("--out", default="BENCH_fused_pipeline.json")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless every pallas layer models strictly "
                         "lower fused HBM bytes than unfused")
    args = ap.parse_args(argv)

    cfg = alexnet.AlexNetConfig()
    if not args.full:
        # reduced channels but a 131px input: the stock 67px reduction
        # shrinks conv3-5 to 3x3 maps where tile padding swamps the model
        cfg = dataclasses.replace(cfg.reduced(), image_size=131)
    if args.image_size:
        cfg = dataclasses.replace(cfg, image_size=args.image_size)
    cfg = apply_cnn_route(cfg, args.route)

    rows = layer_rows(cfg, batch=args.batch)
    net = network_summary(rows)
    emit([{"name": f"fused_pipeline/{r['layer']}",
           "us_per_call": r["fused_us"],
           "derived": (f"route={r['route']};unfused_us={r['unfused_us']:.0f}"
                       f";unfused_MB={r['unfused_hbm_bytes']/2**20:.2f}"
                       f";fused_MB={r['fused_hbm_bytes']/2**20:.2f}"
                       f";hbm_savings={r['hbm_savings']:.2f}x"
                       f";filter_cache={r['filter_cache_reuse']:.0f}x")}
          for r in rows])
    emit([{"name": "fused_pipeline/network", "us_per_call": 0,
           "derived": (f"fused_MB={net['fused_hbm_bytes']/2**20:.2f}"
                       f";unfused_direct_MB="
                       f"{net['unfused_direct_hbm_bytes']/2**20:.2f}"
                       f";ratio={net['ratio']:.2f}x"
                       f";pr3_rule_ratio={net['pr3_rule_ratio']:.2f}x")}])

    artifact = {
        "config": dataclasses.asdict(cfg),
        "batch": args.batch,
        "route": args.route,
        "backend": jax.default_backend(),
        "layers": rows,
        "network": net,
    }
    with open(args.out, "w") as f:
        json.dump(artifact, f, indent=2)

    if args.check:
        bad = check_rows(rows)
        if bad:
            print(f"fused_pipeline/CHECK_FAILED,0,"
                  f"layers={[r['layer'] for r in bad]}")
            return 1
        print("fused_pipeline/CHECK_OK,0,"
              "fused_bytes<unfused_bytes_for_all_pallas_layers")
    return 0


if __name__ == "__main__":
    sys.exit(main())
