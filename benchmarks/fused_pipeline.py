"""Fused layer pipeline: measured wall-clock + modeled HBM bytes per layer,
with the §3.5 weight-prefetch on/off comparison.

The paper's headline argument (§3.5, Table 3) is that running conv, ReLU,
LRN, and pool on-chip keeps feature maps out of external memory between
layers, *and* that filter prefetch hides the weight stream behind compute
("filters for the next convolution layer are prefetched while the current
layer is computed").  This benchmark runs every AlexNet conv layer three
ways —

  unfused:        dispatch_conv (conv+bias+ReLU) -> lrn -> maxpool
                  (full-resolution feature map round-trips HBM up to 3x)
  fused+prefetch: one dispatch_conv with the layer-level ConvSpec; the
                  kernels' manual-DMA 2-slot weight stream double-buffers
                  every filter fetch under MXU compute
  fused-prefetch: same kernels with the DMA run synchronously at each
                  weight-tile transition (bit-equal output, every fetch
                  exposed)

— and emits measured wall-clock next to the modeled HBM traffic
(``core/winograd.py::conv2d_hbm_bytes``, route-aware) including the
prefetch split: total weight stream, exposed vs prefetch-hidden bytes, and
the per-layer roofline terms (``core/roofline.py::conv_layer_roofline``,
arithmetic intensity over total and over exposed bytes).

``--batch-block`` / ``--k-block`` set the filter-cache depth and K block
for both the kernels and the model; the defaults (2 cache generations at
batch 4, K split into several tiles per layer) put *every* layer in the
steady-state streaming regime — >= 2 weight fetches, the re-fetches being
exactly what the prefetch hides — so the on/off exposure delta is strict
on all five layers.  (A single-tile stream is fetched once and kept
resident; both modes then expose the same warmup tile.)

    PYTHONPATH=src python benchmarks/fused_pipeline.py [--full]
        [--route {auto,direct,winograd,pallas}] [--prefetch {on,off}]
        [--batch N] [--batch-block N] [--k-block N] [--check]
        [--image-size N] [--out BENCH_fused_pipeline.json]
        [--autotune] [--autotune-budget N] [--trace DIR]

``--autotune`` additionally runs the measured per-layer autotuner
(``core/autotune.py``) over the same config — enumerating the real launch
knobs, timing each candidate through dispatch_conv, and reporting
default-vs-tuned wall-clock per layer (the ``autotune`` artifact
section).  ``--trace DIR`` wraps the measured region in a JAX profiler
trace (viewable in TensorBoard/Perfetto) so kernel-level timelines sit
next to the wall-clock numbers.

``--check`` exits nonzero unless (a) every Pallas-resolved layer models
fused bytes strictly below unfused and no layer models fused above
unfused, and (b) modeled prefetch-exposed weight bytes are <= the
non-prefetch weight bytes on every layer — strictly below whenever the
layer has more than one weight fetch (the CI bench-smoke gate).
"""
import argparse
import dataclasses
import json
import sys

import jax
import numpy as np

try:                      # package use (benchmarks.run)
    from .common import emit, time_us
except ImportError:       # direct `python benchmarks/fused_pipeline.py` (CI)
    from common import emit, time_us

import jax.numpy as jnp                                    # noqa: E402
from repro.core.roofline import (ConvLayerRoofline,        # noqa: E402
                                 conv_layer_roofline, network_conv_roofline)
from repro.core.winograd import conv2d_hbm_bytes, conv_flops  # noqa: E402
from repro.launch.serve import CNN_ROUTES, apply_cnn_route  # noqa: E402
from repro.models import alexnet                           # noqa: E402
from repro.nn import pooling                               # noqa: E402
from repro.nn.conv import (MODEL_ROUTES, dispatch_conv,  # noqa: E402
                           resolve_kernel)


def _layer_model(spec, batch, h, c_in, c_out, kernel_name, *,
                 k_block: int = 128, batch_block: int = 8,
                 weight_prefetch: bool = True):
    route, wino = MODEL_ROUTES[kernel_name]
    return conv2d_hbm_bytes(
        batch, h, h, c_in, c_out, spec.kernel,
        spec.winograd_m if wino else None, stride=spec.stride,
        padding=spec.padding, relu=spec.relu, fuse_lrn=spec.fuse_lrn,
        fuse_pool=spec.fuse_pool, pool_window=spec.pool_window,
        pool_stride=spec.pool_stride, groups=spec.groups, route=route,
        k_block=k_block, batch_block=batch_block,
        weight_prefetch=weight_prefetch)


def _layer_flops(spec, batch, h, c_in, c_out, kernel_name) -> float:
    """2 * MACs on the layer's actual datapath (Winograd-domain mults on
    the Winograd kernels, direct mults elsewhere), batch included."""
    _, wino = MODEL_ROUTES[kernel_name]
    # conv output extent (pre-pool)
    from repro.nn.conv import conv_out_hw
    oh = conv_out_hw(h, spec.kernel, spec.stride, spec.padding)
    direct, wmad = conv_flops(oh, oh, c_in // spec.groups, c_out // spec.groups,
                              spec.kernel, spec.winograd_m if wino else None)
    madds = (wmad if wino else direct) * spec.groups
    return 2.0 * madds * batch


def _pr3_model(spec, batch, h, c_in, c_out):
    """The PR-3 modeling rules, for the network-ratio comparison: pallas
    silently fell back to lax off the 3x3 stride-1 path, the lax route was
    (optimistically) credited with fusion, and bias/ReLU was not counted as
    an unfused stage pass."""
    eligible = spec.winograd_eligible
    hb = conv2d_hbm_bytes(
        batch, h, h, c_in, c_out, spec.kernel,
        spec.winograd_m if eligible else None, stride=spec.stride,
        padding=spec.padding, relu=False, fuse_lrn=spec.fuse_lrn,
        fuse_pool=spec.fuse_pool, pool_window=spec.pool_window,
        pool_stride=spec.pool_stride, groups=spec.groups,
        route="pallas" if eligible else "direct", c_block=128)
    return {"unfused": hb["layer_unfused_bytes"],
            "fused": hb["stream_unfused_bytes"] + hb["final_out_bytes"]}


def layer_rows(cfg, *, batch: int, batch_block: int, k_block: int,
               prefetch: bool, seed: int = 0):
    """Per-layer fused vs unfused and prefetch on vs off: wall-clock
    (measured) + HBM bytes incl. the weight-stream split (model)."""
    rng = np.random.default_rng(seed)
    route = alexnet._route(cfg)
    rows = []
    h, c_in = cfg.image_size, cfg.in_channels
    for i, (spec, c_out) in enumerate(zip(alexnet.layer_specs(cfg),
                                          cfg.conv_channels)):
        spec = spec.with_route(route)
        unfused = dataclasses.replace(spec, fuse_lrn=False, fuse_pool=False)
        x = jnp.asarray(rng.standard_normal((batch, h, h, c_in)), jnp.float32)
        w = jnp.asarray(rng.standard_normal(
            (spec.kernel, spec.kernel, c_in // spec.groups, c_out))
            * (spec.kernel ** -2), jnp.float32)
        b = jnp.asarray(rng.standard_normal((c_out,)), jnp.float32)

        def run_unfused(x, w, b, spec=spec, unfused=unfused):
            # same prefetch mode as the headline fused measurement, so the
            # fused-vs-unfused wall-clock delta never mixes weight-stream
            # modes within one artifact
            return pooling.apply_epilogue(
                dispatch_conv(unfused, x, w, b, weight_prefetch=prefetch,
                              k_block=k_block, batch_block=batch_block),
                spec.lrn if spec.fuse_lrn else None,
                (spec.pool_window, spec.pool_stride) if spec.fuse_pool
                else None)

        def run_fused(x, w, b, spec=spec, pf=True):
            return dispatch_conv(spec, x, w, b, weight_prefetch=pf,
                                 k_block=k_block, batch_block=batch_block)

        t_un = time_us(jax.jit(run_unfused), x, w, b)
        t_fu_on = time_us(jax.jit(lambda x, w, b: run_fused(x, w, b)),
                          x, w, b)
        t_fu_off = time_us(jax.jit(lambda x, w, b: run_fused(x, w, b,
                                                             pf=False)),
                           x, w, b)
        t_fu = t_fu_on if prefetch else t_fu_off
        kernel_name = resolve_kernel(spec, in_hw=h)
        hb = _layer_model(spec, batch, h, c_in, c_out, kernel_name,
                          k_block=k_block, batch_block=batch_block,
                          weight_prefetch=prefetch)
        flops = _layer_flops(spec, batch, h, c_in, c_out, kernel_name)
        rl = conv_layer_roofline(f"conv{i+1}", hb, flops=flops,
                                 weight_prefetch=prefetch)
        pr3 = _pr3_model(spec, batch, h, c_in, c_out)
        rows.append({
            "layer": f"conv{i+1}",
            "route": kernel_name,
            "in_hw": h, "c_in": c_in, "c_out": c_out,
            "fuse_lrn": spec.fuse_lrn, "fuse_pool": spec.fuse_pool,
            "unfused_us": t_un, "fused_us": t_fu,
            "fused_us_prefetch": t_fu_on, "fused_us_noprefetch": t_fu_off,
            "unfused_hbm_bytes": hb["layer_unfused_bytes"],
            "fused_hbm_bytes": hb["layer_fused_bytes"],
            "unfused_direct_hbm_bytes": hb["layer_unfused_direct_bytes"],
            "hbm_savings": hb["fused_savings"],
            "weight_hbm_bytes": hb["weight_hbm_bytes"],
            "weight_tile_bytes": hb["weight_tile_bytes"],
            "weight_fetches": hb["weight_fetches"],
            "weight_exposed_prefetch_bytes":
                hb["weight_exposed_prefetch_bytes"],
            "weight_exposed_noprefetch_bytes":
                hb["weight_exposed_noprefetch_bytes"],
            "weight_hidden_bytes": hb["weight_hbm_hidden_bytes"],
            "filter_cache_reuse": hb["filter_cache_reuse"],
            "flops": flops,
            "ai_total": rl.ai_total, "ai_exposed": rl.ai_exposed,
            "roofline_bound": rl.bound,
            "pr3_unfused_hbm_bytes": pr3["unfused"],
            "pr3_fused_hbm_bytes": pr3["fused"],
        })
        h, c_in = spec.out_hw(h), c_out
    return rows


def network_summary(rows, *, prefetch: bool) -> dict:
    """Whole-network modeled-bytes ratio (fused-pallas vs unfused-direct,
    next to the PR-3-rule value) plus the weight-stream aggregate and the
    network roofline over exposed bytes."""
    fused = sum(r["fused_hbm_bytes"] for r in rows)
    unfused_direct = sum(r["unfused_direct_hbm_bytes"] for r in rows)
    pr3_f = sum(r["pr3_fused_hbm_bytes"] for r in rows)
    pr3_u = sum(r["pr3_unfused_hbm_bytes"] for r in rows)
    exp_on = sum(r["weight_exposed_prefetch_bytes"] for r in rows)
    exp_off = sum(r["weight_exposed_noprefetch_bytes"] for r in rows)
    mode = "prefetch" if prefetch else "noprefetch"
    rl = network_conv_roofline([
        ConvLayerRoofline(
            name=r["layer"], flops=r["flops"],
            feature_bytes=r["fused_hbm_bytes"],
            weight_bytes=r["weight_hbm_bytes"],
            weight_exposed_bytes=r[f"weight_exposed_{mode}_bytes"],
            weight_prefetch=prefetch) for r in rows])
    return {
        "fused_hbm_bytes": fused,
        "unfused_direct_hbm_bytes": unfused_direct,
        "ratio": unfused_direct / fused,
        "pr3_rule_ratio": pr3_u / pr3_f,
        "weight_hbm_bytes": sum(r["weight_hbm_bytes"] for r in rows),
        "weight_exposed_prefetch_bytes": exp_on,
        "weight_exposed_noprefetch_bytes": exp_off,
        "prefetch_exposure_ratio": exp_off / exp_on if exp_on else 0.0,
        "fused_us_prefetch": sum(r["fused_us_prefetch"] for r in rows),
        "fused_us_noprefetch": sum(r["fused_us_noprefetch"] for r in rows),
        "roofline": rl,
    }


def check_rows(rows) -> list:
    """Layers violating the gates: a Pallas-resolved layer must model fused
    strictly below unfused and no layer may model fused above unfused; the
    prefetch-exposed weight bytes must be <= the non-prefetch bytes, and
    strictly below whenever the layer re-fetches (weight_fetches > 1)."""
    bad = []
    for r in rows:
        exp_on = r["weight_exposed_prefetch_bytes"]
        exp_off = r["weight_exposed_noprefetch_bytes"]
        if r["route"].startswith("pallas"):
            if not r["fused_hbm_bytes"] < r["unfused_hbm_bytes"]:
                bad.append(r)
            elif exp_on > exp_off:
                bad.append(r)
            elif r["weight_fetches"] > 1 and not exp_on < exp_off:
                bad.append(r)
        elif r["fused_hbm_bytes"] > r["unfused_hbm_bytes"]:
            bad.append(r)
    return bad


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full 227px AlexNet (default: reduced config)")
    ap.add_argument("--route", default="auto", choices=CNN_ROUTES)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--batch-block", type=int, default=2,
                    help="filter-cache depth for kernels AND model (the "
                         "default gives 2 cache generations at batch 4)")
    ap.add_argument("--k-block", type=int, default=8,
                    help="K block for kernels AND model; the default "
                         "splits every reduced layer's K into several "
                         "tiles, so all five layers exercise the "
                         "steady-state streaming regime the prefetch "
                         "hides (single-tile streams are fetched once "
                         "and exposed equally in both modes)")
    ap.add_argument("--prefetch", default="on", choices=("on", "off"),
                    help="primary weight-stream mode (both are always "
                         "measured and modeled; this picks the headline "
                         "fused_us / exposed-bytes columns)")
    ap.add_argument("--image-size", type=int, default=None,
                    help="override the input image size (reduced default "
                         "131, so the late layers keep non-degenerate "
                         "feature maps)")
    ap.add_argument("--out", default="BENCH_fused_pipeline.json")
    ap.add_argument("--autotune", action="store_true",
                    help="also run the measured per-layer autotuner over "
                         "this config and report default-vs-tuned "
                         "wall-clock (core/autotune.py)")
    ap.add_argument("--autotune-budget", type=int, default=8,
                    help="max measured candidates per layer for --autotune")
    ap.add_argument("--trace", default=None, metavar="DIR",
                    help="capture a JAX profiler trace of the measured "
                         "region into DIR")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless every pallas layer models strictly "
                         "lower fused HBM bytes than unfused AND prefetch-"
                         "exposed weight bytes <= (strict when re-fetching) "
                         "non-prefetch weight bytes")
    args = ap.parse_args(argv)

    cfg = alexnet.AlexNetConfig()
    if not args.full:
        # reduced channels but a 131px input: the stock 67px reduction
        # shrinks conv3-5 to 3x3 maps where tile padding swamps the model
        cfg = dataclasses.replace(cfg.reduced(), image_size=131)
    if args.image_size:
        cfg = dataclasses.replace(cfg, image_size=args.image_size)
    cfg = apply_cnn_route(cfg, args.route)
    prefetch = args.prefetch == "on"
    cfg = dataclasses.replace(cfg, weight_prefetch=prefetch)

    if args.trace:
        jax.profiler.start_trace(args.trace)
    rows = layer_rows(cfg, batch=args.batch, batch_block=args.batch_block,
                      k_block=args.k_block, prefetch=prefetch)
    tune = None
    if args.autotune:
        from repro.core.autotune import autotune_alexnet
        tune = autotune_alexnet(cfg, args.batch,
                                max_candidates=args.autotune_budget)
    if args.trace:
        jax.profiler.stop_trace()
    net = network_summary(rows, prefetch=prefetch)
    emit([{"name": f"fused_pipeline/{r['layer']}",
           "us_per_call": r["fused_us"],
           "derived": (f"route={r['route']};unfused_us={r['unfused_us']:.0f}"
                       f";unfused_MB={r['unfused_hbm_bytes']/2**20:.2f}"
                       f";fused_MB={r['fused_hbm_bytes']/2**20:.2f}"
                       f";hbm_savings={r['hbm_savings']:.2f}x"
                       f";filter_cache={r['filter_cache_reuse']:.0f}x"
                       f";w_exposed_on_KB="
                       f"{r['weight_exposed_prefetch_bytes']/2**10:.1f}"
                       f";w_exposed_off_KB="
                       f"{r['weight_exposed_noprefetch_bytes']/2**10:.1f}"
                       f";ai_exposed={r['ai_exposed']:.0f}"
                       f";bound={r['roofline_bound']}")}
          for r in rows])
    emit([{"name": "fused_pipeline/network", "us_per_call": 0,
           "derived": (f"fused_MB={net['fused_hbm_bytes']/2**20:.2f}"
                       f";unfused_direct_MB="
                       f"{net['unfused_direct_hbm_bytes']/2**20:.2f}"
                       f";ratio={net['ratio']:.2f}x"
                       f";pr3_rule_ratio={net['pr3_rule_ratio']:.2f}x"
                       f";w_exposed_on_KB="
                       f"{net['weight_exposed_prefetch_bytes']/2**10:.1f}"
                       f";w_exposed_off_KB="
                       f"{net['weight_exposed_noprefetch_bytes']/2**10:.1f}"
                       f";prefetch_exposure="
                       f"{net['prefetch_exposure_ratio']:.1f}x"
                       f";us_on={net['fused_us_prefetch']:.0f}"
                       f";us_off={net['fused_us_noprefetch']:.0f}")}])
    if tune is not None:
        emit([{"name": f"fused_pipeline/autotune/{t['layer']}",
               "us_per_call": t["tuned_us"],
               "derived": (f"default_us={t['default_us']:.0f}"
                           f";speedup={t['default_us']/t['tuned_us']:.2f}x"
                           f";candidates={t['candidates']}"
                           f";plan={t['plan']}")}
              for t in tune])

    artifact = {
        "config": dataclasses.asdict(cfg),
        "batch": args.batch,
        "batch_block": args.batch_block,
        "k_block": args.k_block,
        "route": args.route,
        "prefetch": args.prefetch,
        "backend": jax.default_backend(),
        "layers": rows,
        "network": net,
    }
    if tune is not None:
        artifact["autotune"] = tune
    with open(args.out, "w") as f:
        json.dump(artifact, f, indent=2)

    if args.check:
        bad = check_rows(rows)
        if bad:
            print(f"fused_pipeline/CHECK_FAILED,0,"
                  f"layers={[r['layer'] for r in bad]}")
            return 1
        print("fused_pipeline/CHECK_OK,0,"
              "fused<unfused_and_prefetch_exposed<=noprefetch_all_layers")
    return 0


if __name__ == "__main__":
    sys.exit(main())
