"""Paper Tables 5-6 regime: served img/s vs batch (FC weight-stream
amortization), analytic + measured.

Analytic (eq. 6 shape): conv time is activation-bound and scales with the
batch, the FC layers are weight-bandwidth-bound and stream their weights
once per batch, so  t(S) = S*t_conv + t_fc  and

    img/s(S) = S / (S*t_conv + t_fc)

which is monotonically increasing in S and saturates at 1/t_conv — the
paper's S_batch=96 saturating curve.  The two constants are measured once
from the reduced AlexNet (features/classifier split in models/alexnet.py).

Measured: end-to-end CnnEngine img/s at max_batch in {1, 2, 4, 8} over the
same request stream (bucketed batching + double-buffered staging).
"""
from .common import emit, time_us


def rows():
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.models import alexnet
    from repro.serving import CnnEngine, CnnServeConfig, ImageRequest

    cfg = get_config("alexnet").reduced()
    params = alexnet.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)

    def image():
        return rng.standard_normal(
            (cfg.image_size, cfg.image_size, cfg.in_channels)
        ).astype(np.float32)

    # -- analytic curve: one conv-per-image + one FC-stream-per-batch ------
    feats = jax.jit(lambda p, x: alexnet.features(p, cfg, x))
    clf = jax.jit(lambda p, f: alexnet.classifier(p, cfg, f))
    x1 = jnp.asarray(image()[None])
    f1 = feats(params, x1)
    t_conv = time_us(feats, params, x1)          # us per image (conv regime)
    t_fc = time_us(clf, params, f1)              # us per weight stream (FC)
    peak = 1e6 / t_conv

    out = []
    prev = 0.0
    for S in (1, 2, 4, 8, 16, 32, 96):
        t_batch = S * t_conv + t_fc
        imgs_s = S / t_batch * 1e6
        assert imgs_s > prev, "analytic curve must be monotone"
        prev = imgs_s
        out.append({
            "name": f"serve_images/analytic_b{S}",
            "us_per_call": t_batch,
            "derived": (f"imgs_s={imgs_s:.1f}"
                        f";saturation={imgs_s / peak * 100:.1f}%"
                        f";monotone=True"),
        })

    # -- measured engine curve ---------------------------------------------
    for mb in (1, 2, 4, 8):
        eng = CnnEngine(cfg, CnnServeConfig(max_batch=mb), params=params)
        # warm every bucket shape so the curve measures serving, not jit
        for b in eng.buckets:
            for _ in range(b):
                eng.submit(ImageRequest(image=image()))
            eng.run_until_done()
        eng.reset_metrics()
        reqs = [ImageRequest(image=image()) for _ in range(24)]
        for r in reqs:
            eng.submit(r)
        eng.run_until_done()
        s = eng.stats()
        assert s["images_completed"] == len(reqs)
        out.append({
            "name": f"serve_images/engine_b{mb}",
            "us_per_call": 1e6 / max(s["imgs_per_s"], 1e-9),
            "derived": (f"imgs_s={s['imgs_per_s']:.1f}"
                        f";occupancy={s['avg_occupancy']:.2f}"
                        f";p50_ms={s['latency_ms']['p50']:.1f}"
                        f";p99_ms={s['latency_ms']['p99']:.1f}"),
        })
    return out


def main():
    emit(rows())


if __name__ == "__main__":
    main()
