"""Paper Fig. 8: expected throughput over (C_vec, K_vec) — DSE surface.

Reproduces the sweep with the resource constraints of the Arria-10 1150;
the paper's chosen 8x48 must rank among the peak points.  Also runs the TPU
analog: (data, model) mesh factorization sweep for an LM cell.
"""
from .common import emit, time_us


def rows():
    from repro.core import dse
    sweep = dse.explore_fpga()
    t = time_us(dse.explore_fpga, iters=1)
    feasible = [r for r in sweep if r["img_per_s"] > 0]
    best = max(feasible, key=lambda r: r["img_per_s"])
    p848 = next(r for r in sweep if (r["c_vec"], r["k_vec"]) == (8, 48))
    out = [{
        "name": "fig8/fpga_sweep",
        "us_per_call": t,
        "derived": (f"points={len(sweep)};feasible={len(feasible)}"
                    f";best=({best['c_vec']}x{best['k_vec']},"
                    f"{best['img_per_s']:.0f}img/s)"
                    f";paper_848={p848['img_per_s']:.0f}img/s"
                    f";within={(p848['img_per_s']/best['img_per_s'])*100:.1f}%"),
    }]
    for r in sorted(feasible, key=lambda r: -r["img_per_s"])[:5]:
        out.append({"name": f"fig8/c{r['c_vec']}_k{r['k_vec']}",
                    "us_per_call": 0.0,
                    "derived": f"img_per_s={r['img_per_s']:.0f}"})
    # TPU analog: mesh factorization sweep for llama3.2-3b train
    inp = dse.TPUModelInput(n_active=3.2e9, n_total=3.2e9, seq_len=4096,
                            global_batch=256, kind="train", d_model=3072,
                            num_layers=28)
    tpu = dse.explore_tpu(inp, chips=256)
    bt = max(tpu, key=lambda r: r["mfu"])
    out.append({"name": "fig8/tpu_mesh_sweep",
                "us_per_call": 0.0,
                "derived": (f"best=(data{bt['data']}xmodel{bt['model']})"
                            f";mfu={bt['mfu']*100:.1f}%"
                            f";bound={bt['bound']}")})
    return out


def main():
    emit(rows())


if __name__ == "__main__":
    main()
