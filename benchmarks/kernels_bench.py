"""Kernel microbenchmarks: Pallas (interpret) vs pure-jnp twin vs oracle.

CPU wall times are for harness sanity/relative comparison only (the kernels
target TPU); `derived` carries the arithmetic-intensity facts that transfer.
"""
import jax
import jax.numpy as jnp
import numpy as np

from .common import emit, time_us


def rows():
    out = []
    rng = np.random.default_rng(0)

    # winograd 1d (mamba conv shape: d_inner=1024 slice)
    from repro.core.winograd import conv1d_depthwise_causal as jnp1d
    from repro.kernels.conv.ref import conv1d_depthwise_causal_ref
    x = jnp.asarray(rng.standard_normal((4, 2048, 512)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((4, 512)), jnp.float32)
    t_ref = time_us(jax.jit(conv1d_depthwise_causal_ref), x, w)
    t_wg = time_us(jax.jit(jnp1d), x, w)
    out.append({"name": "kernels/wino1d_f34",
                "us_per_call": t_wg,
                "derived": (f"direct_us={t_ref:.0f};mults_ratio=2.0"
                            f";shape=4x2048x512xk4")})

    # winograd 2d (alexnet conv3)
    from repro.core.winograd import (conv2d_direct, conv2d_hbm_bytes,
                                     conv2d_winograd)
    x2 = jnp.asarray(rng.standard_normal((8, 13, 13, 256)), jnp.float32)
    w2 = jnp.asarray(rng.standard_normal((3, 3, 256, 384)) * .05, jnp.float32)
    t_d = time_us(jax.jit(lambda a, b: conv2d_direct(a, b)), x2, w2)
    t_w = time_us(jax.jit(lambda a, b: conv2d_winograd(a, b)), x2, w2)
    out.append({"name": "kernels/wino2d_f43_conv3",
                "us_per_call": t_w,
                "derived": f"direct_us={t_d:.0f};speedup={t_d/t_w:.2f}x"})

    # modeled HBM feature-map traffic, host-tiled vs stream-buffered
    # in-kernel tiling (paper §3.5's bandwidth argument, roofline units);
    # conv3 (13x13x256->384) and a large-C VGG-ish layer for contrast
    for name, (H, C, K) in (("conv3_13x13x256", (13, 256, 384)),
                            ("vgg_56x56x256", (56, 256, 256))):
        hb = conv2d_hbm_bytes(8, H, H, C, K, 3, 4)
        out.append({"name": f"kernels/wino2d_hbm_{name}",
                    "us_per_call": 0.0,
                    "derived": (f"host_tiled_MB={hb['host_tiled_bytes']/2**20:.1f}"
                                f";stream_MB={hb['stream_bytes']/2**20:.1f}"
                                f";tile_inflation={hb['tile_inflation']:.2f}x"
                                f";hbm_savings={hb['savings']:.2f}x"
                                f";w_exposed_on_KB="
                                f"{hb['weight_exposed_prefetch_bytes']/2**10:.1f}"
                                f";w_exposed_off_KB="
                                f"{hb['weight_exposed_noprefetch_bytes']/2**10:.1f}")})

    # strided direct kernel (conv1's 11x11 s4 datapath) vs the lax oracle,
    # Pallas interpret on CPU — plus the same modeled-bytes columns the
    # Winograd rows carry (m=None -> the strided-slab direct-route terms)
    from repro.kernels.conv.direct import conv2d_direct as pallas_direct
    from repro.kernels.conv.ref import conv2d_ref
    xd = jnp.asarray(rng.standard_normal((4, 35, 35, 3)), jnp.float32)
    wd = jnp.asarray(rng.standard_normal((11, 11, 3, 16)) * 11 ** -2,
                     jnp.float32)
    t_lax = time_us(jax.jit(lambda a, b: conv2d_ref(
        a, b, None, stride=4, padding="VALID", relu=True)), xd, wd)
    t_pd = time_us(lambda a, b: pallas_direct(
        a, b, stride=4, padding="VALID", relu=True, interpret=True), xd, wd)
    out.append({"name": "kernels/direct2d_conv1_11x11s4",
                "us_per_call": t_pd,
                "derived": (f"lax_us={t_lax:.0f};shape=4x35x35x3k11s4"
                            f";pallas_interpret=cpu")})
    for name, (H, C, K, r, s, g) in (
            ("conv1_227x227x3", (227, 3, 96, 11, 4, 1)),
            ("conv2_27x27x96g2", (27, 96, 256, 5, 1, 2))):
        hb = conv2d_hbm_bytes(8, H, H, C, K, r, None, stride=s, groups=g,
                              padding="VALID" if s > 1 else "SAME",
                              fuse_lrn=True, fuse_pool=True)
        out.append({"name": f"kernels/direct2d_hbm_{name}",
                    "us_per_call": 0.0,
                    "derived": (f"host_tiled_MB={hb['host_tiled_bytes']/2**20:.1f}"
                                f";stream_MB={hb['stream_bytes']/2**20:.1f}"
                                f";tile_inflation={hb['tile_inflation']:.2f}x"
                                f";hbm_savings={hb['savings']:.2f}x"
                                f";fused_savings={hb['fused_savings']:.2f}x"
                                f";w_exposed_on_KB="
                                f"{hb['weight_exposed_prefetch_bytes']/2**10:.1f}"
                                f";w_exposed_off_KB="
                                f"{hb['weight_exposed_noprefetch_bytes']/2**10:.1f}")})

    # bfp matmul (decode weight-streaming shape)
    from repro.core.bfp import bfp_matmul
    xm = jnp.asarray(rng.standard_normal((8, 4096)), jnp.float32)
    wm = jnp.asarray(rng.standard_normal((4096, 1024)), jnp.float32)
    t_bf = time_us(lambda a, b: bfp_matmul(a, b, block=32, bits=8), xm, wm)
    t_ex = time_us(jax.jit(lambda a, b: a @ b), xm, wm)
    out.append({"name": "kernels/bfp_matmul_8b",
                "us_per_call": t_bf,
                "derived": (f"exact_us={t_ex:.0f};wire_bytes=0.53x_bf16"
                            f";rel_err<1.6e-2")})

    # ssd chunked scan (pallas interpret vs jnp twin)
    from repro.kernels.ssd.ssd import ssd_chunked_pallas
    from repro.nn.ssd import ssd_chunked as jnp_ssd
    B, L, H, P, G, N = 2, 1024, 8, 64, 1, 64
    xs = jnp.asarray(rng.standard_normal((B, L, H, P)), jnp.float32)
    dts = jnp.asarray(rng.uniform(0.001, 0.1, (B, L, H)), jnp.float32)
    As = jnp.asarray(-rng.uniform(0.5, 2, (H,)), jnp.float32)
    Bs = jnp.asarray(rng.standard_normal((B, L, G, N)), jnp.float32)
    Cs = jnp.asarray(rng.standard_normal((B, L, G, N)), jnp.float32)
    t_j = time_us(jax.jit(lambda *a: jnp_ssd(*a, 128)), xs, dts, As, Bs, Cs)
    t_p = time_us(lambda *a: ssd_chunked_pallas(*a, chunk=128,
                                                interpret=True),
                  xs, dts, As, Bs, Cs, iters=1)
    out.append({"name": "kernels/ssd_chunk128",
                "us_per_call": t_j,
                "derived": (f"pallas_interpret_us={t_p:.0f}"
                            f";vmem_per_step=(Q*P+2QN+NP)*4B"
                            f"={(128*64+2*128*64+64*64)*4//1024}KiB")})
    return out


def main():
    emit(rows())


if __name__ == "__main__":
    main()
