"""§Roofline source: per (arch x shape x mesh) roofline terms from the
dry-run JSONL (results/dryrun.jsonl)."""
import json
import os

from .common import emit

DRYRUN = os.environ.get("DRYRUN_JSONL", "results/dryrun.jsonl")


def load(path=DRYRUN):
    recs = {}
    if not os.path.exists(path):
        return recs
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            # keep the latest record per cell
            recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def rows():
    recs = load()
    out = []
    if not recs:
        return [{"name": "roofline/missing", "us_per_call": 0,
                 "derived": f"no dry-run data at {DRYRUN}; run "
                            "python -m repro.launch.dryrun first"}]
    for (arch, shape, mesh), r in sorted(recs.items()):
        if r["status"] == "skipped":
            out.append({"name": f"roofline/{arch}/{shape}/{mesh}",
                        "us_per_call": 0,
                        "derived": f"SKIPPED:{r['reason'][:40]}"})
            continue
        if r["status"] != "ok":
            out.append({"name": f"roofline/{arch}/{shape}/{mesh}",
                        "us_per_call": 0,
                        "derived": f"ERROR:{r.get('error','')[:60]}"})
            continue
        t = r["roofline"]
        out.append({
            "name": f"roofline/{arch}/{shape}/{mesh}",
            "us_per_call": t["step_time"] * 1e6,
            "derived": (f"t_comp={t['t_compute']*1e3:.2f}ms"
                        f";t_mem={t['t_memory']*1e3:.2f}ms"
                        f";t_coll={t['t_collective']*1e3:.2f}ms"
                        f";bound={t['bound']}"
                        f";useful_flops={t['useful_flops_ratio']*100:.0f}%"
                        f";roofline_frac={t['roofline_fraction']*100:.1f}%"),
        })
    return out


def main():
    emit(rows())


if __name__ == "__main__":
    main()
