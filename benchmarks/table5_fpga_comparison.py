"""Paper Table 5: DLA vs state-of-the-art FPGA work (effective GFLOPS).

Model-derived effective GFLOPS of our DLA reproduction vs the published
baselines (Stratix-V 72.4 GOPS, KU060 165 GOPS, paper's DLA 1382 GFLOPS).
"""
from .common import emit

BASELINES = {"stratixV_suda": 72.4, "ku060_caffeine": 165.0}
PAPER_DLA = 1382.0


def rows():
    from repro.core.dse import DLAConfig, alexnet_throughput
    # paper's Table-5 metric: algorithmic (direct-conv) FLOPs / time —
    # 1020 img/s * 1.355 GF/img = 1382 GFLOPS in the paper
    r = alexnet_throughput(DLAConfig(c_vec=8, k_vec=48),
                           system_overhead=0.16)
    eff_gflops = r["gflops_per_img"] * r["img_per_s"]
    out = [{"name": "table5/dla_effective_gflops",
            "us_per_call": 0.0,
            "derived": (f"model={eff_gflops:.0f}GFLOPS"
                        f";paper={PAPER_DLA:.0f}"
                        f";deviation={(eff_gflops/PAPER_DLA-1)*100:+.1f}%")}]
    for name, gops in BASELINES.items():
        out.append({"name": f"table5/speedup_vs_{name}",
                    "us_per_call": 0.0,
                    "derived": (f"ratio={eff_gflops/gops:.1f}x"
                                f";paper_ratio={PAPER_DLA/gops:.1f}x")})
    return out


def main():
    emit(rows())


if __name__ == "__main__":
    main()
