"""Paper Table 2: per-layer GFLOPS and DSP efficiency of AlexNet on the DLA.

Reproduction: the analytical model (eq. 5/6 with quantization terms) gives
per-layer efficiency and actual/effective GFLOPS, compared against the
paper's published numbers.  us_per_call additionally reports the measured
CPU wall time of our Winograd path vs direct convolution for the 3x3 layers
(the arithmetic-reduction the FPGA exploits, observable on any backend).
"""
from .common import emit, time_us

PAPER = {"conv1": (1154, .829), "conv2": (870, .625), "conv3": (980, .724),
         "conv4": (980, .724), "conv5": (871, .626), "fc6": (1389, .998),
         "fc7": (1386, .996), "fc8": (1378, .990)}


def rows():
    from repro.core.dse import DLAConfig, alexnet_throughput
    r = alexnet_throughput(DLAConfig(c_vec=8, k_vec=48))
    out = []
    for l in r["layers"]:
        act_paper, eff_paper = PAPER[l["name"]]
        out.append({
            "name": f"table2/{l['name']}",
            "us_per_call": 0.0,
            "derived": (f"act_gflops={l['act_gflops']:.0f}"
                        f";paper={act_paper}"
                        f";dsp_eff={l['dsp_eff']*100:.1f}%"
                        f";paper_eff={eff_paper*100:.1f}%"),
        })
    # measured winograd-vs-direct wall time on conv3 shapes (batch 1)
    import jax.numpy as jnp
    import numpy as np
    from repro.core.winograd import conv2d_direct, conv2d_winograd
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((1, 13, 13, 256)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 3, 256, 384)) * .05, jnp.float32)
    import jax
    t_dir = time_us(jax.jit(lambda x, w: conv2d_direct(x, w)), x, w)
    t_win = time_us(jax.jit(lambda x, w: conv2d_winograd(x, w)), x, w)
    out.append({"name": "table2/conv3_winograd_vs_direct",
                "us_per_call": t_win,
                "derived": f"direct_us={t_dir:.0f};speedup={t_dir/t_win:.2f}x"
                           f";mult_reduction=2.0x(F(4,3))"})
    return out


def main():
    emit(rows())


if __name__ == "__main__":
    main()
