"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  CPU wall times are for relative
comparison / harness sanity (TPU v5e is the target, not the runtime);
``derived`` fields carry the model numbers compared against the paper.
"""
from . import (decode_batching, fig8_dse, fig9_model_vs_measured,
               fused_pipeline, kernels_bench, roofline_table, serve_fleet,
               serve_images, table2_layers, table5_fpga_comparison,
               table6_efficiency)

MODULES = [
    ("table2", table2_layers),
    ("fig8", fig8_dse),
    ("fig9", fig9_model_vs_measured),
    ("table5", table5_fpga_comparison),
    ("table6", table6_efficiency),
    ("decode_batching", decode_batching),
    ("serve_images", serve_images),
    ("serve_fleet", serve_fleet),
    ("kernels", kernels_bench),
    ("fused_pipeline", fused_pipeline),
    ("roofline", roofline_table),
]


def main() -> None:
    print("name,us_per_call,derived")
    for name, mod in MODULES:
        try:
            mod.main()
        except Exception as e:  # keep the harness running; surface the error
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}")


if __name__ == "__main__":
    main()
