"""Paper §3.7 (FC batching) in the LM-decode regime.

Analytical tokens/s vs decode batch (weight streaming amortization — the
saturating curve of eq. 6), plus a measured CPU curve from the serving
engine on a reduced config (relative shape is backend-independent).
"""
from .common import emit, time_us


def rows():
    from repro.core import dse
    inp = dse.TPUModelInput(n_active=15e9, n_total=15e9, seq_len=32768,
                            global_batch=1, kind="decode", d_model=6144,
                            num_layers=40,
                            cache_bytes_per_token=40 * 2 * 4 * 128 * 2)
    curve = dse.decode_batch_curve(inp, data=16, model=16)
    out = []
    for r in curve:
        out.append({"name": f"decode_batch/model_b{r['batch']}",
                    "us_per_call": r["step_time"] * 1e6,
                    "derived": (f"tokens_s={r['throughput_tokens_s']:.0f}"
                                f";bound={r['bound']}"
                                f";mfu={r['mfu']*100:.2f}%")})

    # measured engine curve (reduced config, CPU)
    import numpy as np
    from repro.configs import get_config
    from repro.serving import Engine, Request, ServeConfig
    cfg = get_config("smollm-360m").reduced()

    def run(n):
        eng = Engine(cfg, ServeConfig(max_batch=8, max_len=64,
                                      prefill_bucket=8), seed=0)
        for _ in range(n):
            eng.submit(Request(prompt=[1, 2, 3, 4, 5, 6, 7, 8], max_new=16))
        eng.run_until_done()
        return eng._t_decode / max(eng.decode_steps, 1)

    t1, t8 = run(1), run(8)
    out.append({"name": "decode_batch/engine_measured",
                "us_per_call": t8 * 1e6,
                "derived": (f"t_step_b1={t1*1e3:.2f}ms;t_step_b8={t8*1e3:.2f}ms"
                            f";amortization={8*t1/t8:.1f}x_of_8x")})
    return out


def main():
    emit(rows())


if __name__ == "__main__":
    main()
