"""Paper Fig. 9: analytical model vs measurement.

Two validations (no FPGA / TPU silicon in this container):
  1. FPGA side — our eq. 2-7 model (with the paper's measured 16% system
     overhead) vs the paper's published measured points (1020 img/s @ 8x48).
  2. TPU side — the DSE cost model's FLOP counts vs XLA's compiled
     cost_analysis for the AlexNet forward pass (model vs "measured" on the
     artifact we *can* measure here: the compiled HLO).
"""
import jax
import jax.numpy as jnp
import numpy as np

from .common import emit, time_us


def rows():
    from repro.core.dse import (ALEXNET_CONV, ALEXNET_FC, DLAConfig,
                                alexnet_throughput)
    out = []
    for cvec, kvec, paper_meas in [(8, 48, 1020.0)]:
        r = alexnet_throughput(DLAConfig(c_vec=cvec, k_vec=kvec),
                               system_overhead=0.16)
        dev = (r["img_per_s"] - paper_meas) / paper_meas
        out.append({"name": f"fig9/model_vs_paper_{cvec}x{kvec}",
                    "us_per_call": 1e6 / r["img_per_s"],
                    "derived": (f"model={r['img_per_s']:.0f}img/s"
                                f";paper_measured={paper_meas:.0f}"
                                f";deviation={dev*100:+.1f}%")})

    # TPU: model FLOPs vs compiled HLO FLOPs for AlexNet fwd (batch 16)
    from repro.configs import get_config
    from repro.models import alexnet
    cfg = get_config("alexnet")
    B = 16
    params = jax.eval_shape(lambda k: alexnet.init(k, cfg),
                            jax.random.PRNGKey(0))
    imgs = jax.ShapeDtypeStruct((B, 227, 227, 3), jnp.float32)
    import dataclasses
    for wino in (False, True):
        c = dataclasses.replace(cfg, use_winograd=wino)
        compiled = jax.jit(
            lambda p, x: alexnet.apply(p, c, x)).lower(params, imgs).compile()
        ca = compiled.cost_analysis()
        hlo_flops = float(ca.get("flops", 0))
        model_macs = sum(2 * k * (ci // g) * p * q * r * s
                         for (_, ci, k, p, q, r, s, _, g) in ALEXNET_CONV)
        model_macs += sum(2 * ci * k for (_, ci, k) in ALEXNET_FC)
        model_flops = model_macs * B
        out.append({
            "name": f"fig9/tpu_hlo_vs_model_wino={int(wino)}",
            "us_per_call": 0.0,
            "derived": (f"hlo_gflops={hlo_flops/1e9:.1f}"
                        f";model_gflops={model_flops/1e9:.1f}"
                        f";ratio={hlo_flops/model_flops:.2f}"),
        })
    return out


def main():
    emit(rows())


if __name__ == "__main__":
    main()
