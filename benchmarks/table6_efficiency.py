"""Paper Table 6: throughput and power efficiency vs GPU baselines.

img/s from our analytical model; power numbers are the published board
figures (45 W A10 dev-kit, 227 W TitanX, 58 W M4, 25 W KU060) — power is a
property of the hardware, not reproducible in software.
"""
from .common import emit

PUBLISHED = {
    "dla_paper": (1020, 45.0),
    "ku060": (104, 25.0),
    "titanx": (5120, 227.0),
    "m4": (1150, 58.0),
}


def rows():
    from repro.core.dse import DLAConfig, alexnet_throughput
    r = alexnet_throughput(DLAConfig(c_vec=8, k_vec=48),
                           system_overhead=0.16)
    ours = r["img_per_s"] / 45.0
    out = [{"name": "table6/dla_img_s_per_w",
            "us_per_call": 0.0,
            "derived": (f"model={ours:.1f}img/s/W;paper=23"
                        f";board_w=45")}]
    for name, (imgs, watts) in PUBLISHED.items():
        out.append({"name": f"table6/{name}",
                    "us_per_call": 0.0,
                    "derived": (f"img_s={imgs};watts={watts}"
                                f";img_s_per_w={imgs/watts:.1f}"
                                f";dla_ratio={ours/(imgs/watts):.2f}x")})
    return out


def main():
    emit(rows())


if __name__ == "__main__":
    main()
