"""SLO-aware multi-model serving fleet benchmark (paper Tables 5-6, fleet
form).

The paper's 1020 img/s is sustained *serving* throughput under a stream of
requests.  This benchmark drives the fleet stack the same way, with a
synthetic traffic generator, and reports img/s, goodput-under-SLO, and
p50/p90/p99 tail latency:

* ``policy_ab`` — the dynamic-bucket A/B: one AlexNet engine serves a
  *bursty* open-loop trace (bursts sized between bucket points) twice —
  fixed power-of-two ladder vs the SLO-driven
  :class:`~repro.serving.policy.DynamicBucketPolicy`.  The dynamic run
  resizes the ladder to the burst size, trimming padded dead compute per
  batch, and must land a lower steady-state p99 on the identical trace.
* ``fleet`` — :class:`~repro.serving.registry.ModelRegistry` serving
  AlexNet + VGG-16 (reduced) concurrently under one slot budget, mixed
  diurnal + Poisson open-loop arrivals, admission control shedding what
  the SLO can't absorb; per-model and aggregate goodput.
* ``closed_loop`` — N clients with think time against one engine (the
  classic closed-loop regime: latency ~ service time, no queue blowup).

``--chaos`` switches to the fault-tolerance harness instead: a seeded
:class:`~repro.serving.faults.FaultInjector` replays a committed fault
schedule (transient launch failures, staging corruption, non-finite
logits, latency spikes, one hard crash) against the same bursty traffic,
and the artifact (``BENCH_chaos.json``) reports goodput-under-faults next
to the fault-free baseline on the identical trace, the armed-but-idle
bit-parity check, and a pallas->direct route-degradation run whose
degraded outputs are gated bit-identical to the direct-route oracle.
``--chaos --check`` gates: zero lost requests
(``submitted == completed + shed + expired`` on every engine), goodput > 0
under the seeded schedule, idle-parity bit-identical, and the degraded
bucket serving bit-correct logits.

``--chaos --sdc`` (or just ``--sdc``) switches to the silent-data-
corruption defense harness (``BENCH_sdc.json``): the ABFT weight-stream
checksums, pre-dispatch slab fingerprints, and magnitude-bounded logit
screen measured against injected slab bit flips, stale-slab reuse, and
finite (isfinite-defeating) logit corruption, plus the clean-path
wall-clock overhead of arming the defense.  ``--sdc --check`` gates:
detection rate 1.0 on injected flips, zero false positives and
bit-identical logits on the clean trace, and every request completing via
repack-and-retry.

Traces are seeded and host-generated; arrival timestamps are wall-clock
offsets so queue-wait latency is real.  ``--fast`` shrinks everything for
the CI smoke, which gates goodput > 0, full drain (zero unretired slots),
and submitted == completed + shed accounting per engine.  Results are
persisted to ``BENCH_serve_fleet.json``.
"""
import argparse
import json
import time

import numpy as np

from .common import emit

PAPER_IMGS_PER_S = 1020.0          # Arria 10 AlexNet, paper Tables 5-6


# ---------------------------------------------------------------------------
# synthetic traffic
# ---------------------------------------------------------------------------
def poisson_trace(rate_hz: float, duration_s: float, rng) -> list:
    """Open-loop Poisson arrivals: exponential inter-arrival gaps."""
    out, t = [], 0.0
    while True:
        t += rng.exponential(1.0 / rate_hz)
        if t >= duration_s:
            return out
        out.append(t)


def bursty_trace(n_bursts: int, burst_size: int, gap_s: float, rng,
                 jitter_s: float = 0.0) -> list:
    """Bursts of ``burst_size`` near-simultaneous arrivals every ``gap_s``
    (an on/off source: the regime where bucket padding hurts most)."""
    out = []
    for i in range(n_bursts):
        t0 = i * gap_s
        for _ in range(burst_size):
            out.append(t0 + (rng.uniform(0, jitter_s) if jitter_s else 0.0))
    return sorted(out)


def diurnal_trace(base_hz: float, duration_s: float, period_s: float, rng,
                  depth: float = 0.8) -> list:
    """Nonhomogeneous Poisson with a sinusoidal rate (compressed diurnal
    cycle), sampled by thinning against the peak rate."""
    peak = base_hz * (1 + depth)
    out, t = [], 0.0
    while True:
        t += rng.exponential(1.0 / peak)
        if t >= duration_s:
            return out
        rate = base_hz * (1 + depth * np.sin(2 * np.pi * t / period_s))
        if rng.uniform() * peak <= rate:
            out.append(t)
    return out


# ---------------------------------------------------------------------------
# drivers
# ---------------------------------------------------------------------------
def drive_open_loop(arrivals, submit, step, idle, max_wall_s: float = 120.0):
    """Replay ``arrivals`` (sorted (t_offset, payload) pairs) against a
    serving loop in real time: due requests are submitted, then the fleet
    ticks; the driver sleeps only when everything is idle and the next
    arrival is in the future."""
    t0 = time.perf_counter()
    i = 0
    while True:
        now = time.perf_counter() - t0
        if now > max_wall_s:
            raise RuntimeError(f"open-loop driver exceeded {max_wall_s}s")
        while i < len(arrivals) and arrivals[i][0] <= now:
            submit(arrivals[i][1])
            i += 1
        if i == len(arrivals) and idle():
            return
        if idle() and i < len(arrivals):
            time.sleep(min(arrivals[i][0] - now, 0.02))
            continue
        step()


def drive_closed_loop(eng, make_req, n_clients: int, n_per_client: int,
                      think_s: float, max_wall_s: float = 120.0):
    """N closed-loop clients: each keeps one request in flight and thinks
    ``think_s`` between completion and the next submit."""
    t0 = time.perf_counter()
    next_t = [0.0] * n_clients
    inflight = [None] * n_clients
    remaining = [n_per_client] * n_clients
    done = []
    while any(remaining) or any(r is not None for r in inflight):
        now = time.perf_counter() - t0
        if now > max_wall_s:
            raise RuntimeError(f"closed-loop driver exceeded {max_wall_s}s")
        submitted_any = False
        for c in range(n_clients):
            if inflight[c] is None and remaining[c] and next_t[c] <= now:
                req = make_req()
                eng.submit(req)
                inflight[c] = req
                remaining[c] -= 1
                submitted_any = True
        eng.step()
        now = time.perf_counter() - t0
        for c in range(n_clients):
            if inflight[c] is not None and inflight[c].done:
                done.append(inflight[c])
                inflight[c] = None
                next_t[c] = now + think_s
        if (not submitted_any and eng.sched.idle and not eng._staged
                and not eng._compute):
            time.sleep(0.001)
    return done


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------
def _image_fn(cfg, seed=0):
    rng = np.random.default_rng(seed)

    def image():
        return rng.standard_normal(
            (cfg.image_size, cfg.image_size, cfg.in_channels)
        ).astype(np.float32)
    return image


def _warm_buckets(eng, image):
    """Compile every ladder bucket before measuring (jit out of the data)."""
    from repro.serving import ImageRequest
    for b in eng.buckets:
        for _ in range(b):
            eng.submit(ImageRequest(image=image()))
        eng.run_until_done()
    eng.reset_metrics()


def _drained(eng) -> bool:
    return eng.drained and eng.sched.occupancy == 0


def _lat_percentiles_ms(reqs) -> dict:
    lat = np.asarray([r.t_done - r.t_submit for r in reqs if r.done]) * 1e3
    if lat.size == 0:
        return {"p50": 0.0, "p90": 0.0, "p99": 0.0}
    return {f"p{q}": float(np.percentile(lat, q)) for q in (50, 90, 99)}


def _service_ms(eng, image, batch: int) -> float:
    """Measured single-group service latency at one already-compiled
    bucket (median of 5 isolated groups)."""
    from repro.serving import ImageRequest
    samples = []
    for _ in range(5):
        reqs = [ImageRequest(image=image()) for _ in range(batch)]
        for r in reqs:
            eng.submit(r)
        eng.run_until_done()
        samples.append(np.median([r.t_done - r.t_submit for r in reqs]))
    eng.reset_metrics()
    return float(np.median(samples)) * 1e3


# ---------------------------------------------------------------------------
# scenario 1: fixed vs dynamic buckets on a bursty trace
# ---------------------------------------------------------------------------
def run_policy_ab(fast: bool, seed: int = 0) -> dict:
    import jax
    from repro.configs import get_config
    from repro.models import alexnet
    from repro.serving import CnnEngine, CnnServeConfig, ImageRequest

    cfg = get_config("alexnet").reduced()
    params = alexnet.init(jax.random.PRNGKey(seed), cfg)
    image = _image_fn(cfg, seed)
    max_batch, burst = 8, 6         # burst sits between buckets 4 and 8

    def build():
        eng = CnnEngine(cfg, CnnServeConfig(max_batch=max_batch),
                        params=params)
        _warm_buckets(eng, image)
        return eng

    # calibrate: t(b) = a + c*b from the two largest compiled buckets, so
    # the SLO can be pinned between the padded (8) and trimmed (6) service
    # times — tight enough that the fixed ladder busts it.  The fixed
    # engine doubles as the calibration engine (arm_slo keeps its compiled
    # buckets).
    eng_fixed = build()
    t4 = _service_ms(eng_fixed, image, 4)
    t8 = _service_ms(eng_fixed, image, 8)
    c = max((t8 - t4) / 4.0, 0.0)
    t6 = t4 + 2 * c
    slo_ms = max((t6 + t8) / 2, t8 * 0.9)

    n_bursts = 10 if fast else 48
    gap_s = max(t8, 1.0) * 1.15e-3  # mild queueing: ~one burst in flight
    rng = np.random.default_rng(seed)
    trace = bursty_trace(n_bursts, burst, gap_s, rng)

    def run(dynamic: bool) -> dict:
        eng = eng_fixed if not dynamic else build()
        eng.arm_slo(slo_ms, dynamic_buckets=dynamic)
        if dynamic:
            # preflight: let the policy see the SLO violations and resize,
            # and compile the inserted bucket, before the measured trace —
            # the A/B then compares steady-state ladders
            for _ in range(8):
                reqs = [ImageRequest(image=image()) for _ in range(burst)]
                for r in reqs:
                    eng.submit(r)
                eng.run_until_done()
                if eng.policy.extra:
                    break
            for r in [ImageRequest(image=image()) for _ in range(burst)]:
                eng.submit(r)
            eng.run_until_done()    # compile the inserted bucket shape
            eng.reset_metrics()
        reqs = []

        def submit(_):
            req = ImageRequest(image=image())
            reqs.append(req)
            eng.submit(req)

        drive_open_loop([(t, None) for t in trace], submit, eng.step,
                        lambda: _drained(eng))
        assert _drained(eng), "unretired slots after drain"
        s = eng.stats()
        return {
            "dynamic_buckets": dynamic,
            "buckets": s["buckets"],
            "bucket_resizes": s["bucket_resizes"],
            "bucket_counts": s["bucket_counts"],
            "images_completed": s["images_completed"],
            "imgs_per_s": s["imgs_per_s"],
            "goodput_imgs_per_s": s["goodput_imgs_per_s"],
            "latency_ms": _lat_percentiles_ms(reqs),
        }

    fixed, dynamic = run(False), run(True)
    p99_f = fixed["latency_ms"]["p99"]
    p99_d = dynamic["latency_ms"]["p99"]
    return {
        "trace": {"kind": "bursty", "n_bursts": n_bursts, "burst": burst,
                  "gap_ms": gap_s * 1e3},
        "slo_ms": slo_ms,
        "calibration_ms": {"t4": t4, "t6_est": t6, "t8": t8},
        "fixed": fixed,
        "dynamic": dynamic,
        "p99_reduction_pct": (100.0 * (p99_f - p99_d) / p99_f
                              if p99_f else 0.0),
    }


# ---------------------------------------------------------------------------
# scenario 2: multi-model fleet under admission control
# ---------------------------------------------------------------------------
def run_fleet(fast: bool, seed: int = 0) -> dict:
    from repro.configs import get_config
    from repro.serving import CnnServeConfig, ImageRequest, ModelRegistry

    names = ("alexnet", "vgg16")
    cfgs = {n: get_config(n).reduced() for n in names}
    images = {n: _image_fn(cfgs[n], seed + i) for i, n in enumerate(names)}

    reg = ModelRegistry(slot_budget=32)
    for i, n in enumerate(names):
        reg.register(n, cfgs[n], CnnServeConfig(max_batch=8), seed=seed + i)
        _warm_buckets(reg[n], images[n])

    # per-model SLO from each model's measured full-bucket service time,
    # then arm the SLO control plane (shedding + dynamic ladder) on the
    # warmed engines
    svc_ms = {n: _service_ms(reg[n], images[n], 8) for n in names}
    slos = {n: max(svc_ms[n] * 1.6, 2.0) for n in names}
    for n in names:
        # admission only: a mid-run ladder insert would compile a new
        # bucket shape inside the measured trace (a ~1s XLA stall that
        # swamps every latency percentile); the policy_ab scenario
        # isolates the dynamic-ladder lever with a preflight compile
        reg[n].arm_slo(slos[n], admission=True)

    # mixed open-loop traffic: AlexNet takes a diurnal cycle, VGG a flat
    # Poisson stream; rates scaled to each model's service capacity so the
    # diurnal peak oversubscribes the (time-shared) fleet — shedding is
    # exercised — while the trough is comfortable
    dur = 1.5 if fast else 6.0
    rng = np.random.default_rng(seed + 7)
    cap_hz = {n: 8e3 / max(svc_ms[n], 1e-3)
              for n in names}     # ~images/s at full buckets
    arrivals = sorted(
        [(t, "alexnet") for t in diurnal_trace(
            0.5 * cap_hz["alexnet"], dur, dur / 1.5, rng)]
        + [(t, "vgg16") for t in poisson_trace(
            0.35 * cap_hz["vgg16"], dur, rng)])

    reqs = {n: [] for n in names}
    shed = {n: [] for n in names}

    def submit(model):
        req = ImageRequest(image=images[model]())
        if reg.submit(model, req):
            reqs[model].append(req)
        else:
            shed[model].append(req)     # reported, not dropped on the floor

    t0 = time.perf_counter()
    drive_open_loop(arrivals, submit, reg.step, lambda: reg.idle,
                    max_wall_s=dur * 20 + 60)
    wall_s = time.perf_counter() - t0
    for n in names:
        assert _drained(reg[n]), f"unretired slots in {n}"
    s = reg.stats()
    per = {}
    for n in names:
        e = s["models"][n]
        assert all(r.shed and not r.done for r in shed[n])
        assert e["images_shed"] == len(shed[n])
        assert e["images_completed"] == len(reqs[n])
        per[n] = {
            "slo_ms": slos[n],
            "submitted": len(reqs[n]) + len(shed[n]),
            "completed": e["images_completed"],
            "shed": e["images_shed"],
            "within_slo": e["images_within_slo"],
            "imgs_per_s": e["imgs_per_s"],
            "goodput_imgs_per_s": e["goodput_imgs_per_s"],
            "buckets": e["buckets"],
            "latency_ms": _lat_percentiles_ms(reqs[n]),
        }
    fleet = dict(s["fleet"])
    # per-engine imgs_per_s divides by that engine's own step time, which
    # overstates a time-shared fleet; the honest aggregate is wall clock
    fleet["imgs_per_s_wall"] = fleet["images_completed"] / wall_s
    fleet["paper_imgs_per_s"] = PAPER_IMGS_PER_S
    fleet["vs_paper"] = fleet["imgs_per_s_wall"] / PAPER_IMGS_PER_S
    return {"duration_s": dur, "wall_s": wall_s, "arrivals": len(arrivals),
            "models": per, "fleet": fleet}


# ---------------------------------------------------------------------------
# scenario 3: closed loop
# ---------------------------------------------------------------------------
def run_closed_loop(fast: bool, seed: int = 0) -> dict:
    import jax
    from repro.configs import get_config
    from repro.models import alexnet
    from repro.serving import CnnEngine, CnnServeConfig, ImageRequest

    cfg = get_config("alexnet").reduced()
    params = alexnet.init(jax.random.PRNGKey(seed), cfg)
    image = _image_fn(cfg, seed)
    eng = CnnEngine(cfg, CnnServeConfig(max_batch=8), params=params)
    _warm_buckets(eng, image)

    n_clients = 4 if fast else 12
    n_per = 4 if fast else 16
    done = drive_closed_loop(eng, lambda: ImageRequest(image=image()),
                             n_clients, n_per, think_s=0.002)
    assert _drained(eng), "unretired slots after drain"
    assert len(done) == n_clients * n_per
    s = eng.stats()
    return {
        "n_clients": n_clients,
        "requests": len(done),
        "imgs_per_s": s["imgs_per_s"],
        "avg_occupancy": s["avg_occupancy"],
        "bucket_counts": s["bucket_counts"],
        "latency_ms": _lat_percentiles_ms(done),
    }


# ---------------------------------------------------------------------------
# chaos harness (--chaos): seeded fault schedule vs fault-free baseline
# ---------------------------------------------------------------------------
def _chaos_engine_record(eng, reqs) -> dict:
    """One chaos run's accounting + throughput record (per engine)."""
    s = eng.stats()
    acc = s["accounting"]
    return {
        "submitted": acc["submitted"],
        "completed": acc["completed"],
        "shed": acc["shed"],
        "expired": acc["expired"],
        "retried": s["images_retried"],
        "batches_failed": s["batches_failed"],
        "in_flight": acc["in_flight"],
        "accounting_balanced": acc["balanced"],
        "imgs_per_s": s["imgs_per_s"],
        "goodput_imgs_per_s": s["goodput_imgs_per_s"],
        "latency_ms": _lat_percentiles_ms(reqs),
        "health": s["health"],
        "shed_reasons": s["shed_reasons"],
        "degraded_buckets": s["degraded_buckets"],
        "faults": s["faults"],
    }


def run_chaos(fast: bool, seed: int = 0) -> dict:
    import dataclasses

    import jax
    from repro.configs import get_config
    from repro.models import alexnet
    from repro.serving import (CnnEngine, CnnServeConfig, FaultInjector,
                               FaultSpec, ImageRequest, derive_seed)

    cfg = get_config("alexnet").reduced()
    params = alexnet.init(jax.random.PRNGKey(seed), cfg)
    image = _image_fn(cfg, seed)
    scfg = CnnServeConfig(max_batch=4, cooldown_ms=80.0,
                          retry_backoff_ms=0.5, screen_sample=4)

    # -- 1. armed-but-idle parity: a FaultInjector with no specs must be
    # invisible — same engine, same inputs, bit-identical logits ----------
    eng = CnnEngine(cfg, scfg, params=params)
    _warm_buckets(eng, image)
    probe = [image() for _ in range(7)]     # spans buckets 4/2/1

    def serve(imgs):
        rs = [ImageRequest(image=im) for im in imgs]
        for r in rs:
            eng.submit(r)
        eng.run_until_done()
        return [np.asarray(r.logits) for r in rs]

    base_logits = serve(probe)
    eng.arm_faults(FaultInjector(seed=derive_seed(seed, "idle"), specs={}))
    armed_logits = serve(probe)
    eng.arm_faults(None)
    idle_parity = {
        "requests": len(probe),
        "bit_identical": bool(all(
            np.array_equal(a, b)
            for a, b in zip(base_logits, armed_logits))),
    }

    # -- 2. seeded fault schedule vs fault-free baseline on the identical
    # bursty trace (the PR-7 traffic generator) ---------------------------
    svc = _service_ms(eng, image, 4)
    deadline_ms = max(6.0 * svc, 50.0)
    slo_ms = max(4.0 * svc, 25.0)
    n_bursts = 12 if fast else 40
    crash_at = 6 if fast else 20            # launch-opportunity index
    rng = np.random.default_rng(seed + 3)
    trace = bursty_trace(n_bursts, 3, max(svc, 1.0) * 1.3e-3, rng)
    schedule = {
        "launch.transient": FaultSpec(rate=0.10),
        "retire.nonfinite": FaultSpec(rate=0.06),
        "stage.corrupt": FaultSpec(rate=0.05),
        "retire.latency": FaultSpec(rate=0.08, delay_ms=2.0),
        "launch.crash": FaultSpec(at=(crash_at,), limit=1),
    }

    def run_traced(injector):
        e = CnnEngine(cfg, scfg, params=params)
        _warm_buckets(e, image)
        e.arm_slo(slo_ms)               # goodput = within-SLO completions
        e.arm_faults(injector)          # armed after warmup: opportunity
        reqs = []                       # indices count serving work only

        def submit(_):
            r = ImageRequest(image=image(), deadline_ms=deadline_ms,
                             retries=3)
            reqs.append(r)
            e.try_submit(r)             # quarantine sheds at the front door

        drive_open_loop([(t, None) for t in trace], submit, e.step,
                        lambda: _drained(e))
        e.run_until_done()              # raises DrainTimeout if hung
        assert _drained(e), "unretired work after chaos drain"
        return _chaos_engine_record(e, reqs)

    baseline = run_traced(None)
    faulted = run_traced(FaultInjector(seed=derive_seed(seed, "chaos"),
                                       specs=schedule))

    # -- 3. route degradation: repeated pallas-route launch failures flip
    # the bucket to the direct route; served logits must bit-match the
    # direct-route oracle -------------------------------------------------
    dcfg = dataclasses.replace(get_config("alexnet").reduced(),
                               image_size=35, use_pallas=True)
    dparams = alexnet.init(jax.random.PRNGKey(seed + 1), dcfg)
    dimage = _image_fn(dcfg, seed + 1)
    dscfg = CnnServeConfig(max_batch=2, retry_backoff_ms=0.2,
                           degrade_threshold=3, quarantine_threshold=8,
                           screen_sample=2)
    deng = CnnEngine(dcfg, dscfg, params=dparams)
    _warm_buckets(deng, dimage)
    deng.arm_faults(FaultInjector(
        seed=derive_seed(seed, "degrade"),
        specs={"launch.transient": FaultSpec(at=(0, 1, 2))}))
    imgs = [dimage() for _ in range(2)]
    dreqs = [ImageRequest(image=im, retries=4) for im in imgs]
    for r in dreqs:
        deng.submit(r)
    deng.run_until_done()
    assert all(r.done for r in dreqs), "degradation run did not complete"
    padded = np.zeros((2, dcfg.image_size, dcfg.image_size,
                       dcfg.in_channels), np.float32)
    for i, im in enumerate(imgs):
        padded[i] = im
    cfg_direct = dataclasses.replace(dcfg, use_winograd=False,
                                     use_pallas=False)
    # jitted at the served bucket shape, like the engine's degraded path
    oracle = np.asarray(jax.jit(
        lambda p, x: alexnet.apply(p, cfg_direct, x))(dparams, padded))[:2]
    ds = deng.stats()
    degradation = {
        "route_before": "pallas",
        "degraded_buckets": ds["degraded_buckets"],
        "events": ds["degradations"],
        "completed": ds["images_completed"],
        "retried": ds["images_retried"],
        "batches_failed": ds["batches_failed"],
        "health": ds["health"]["state"],
        "accounting": ds["accounting"],
        "bit_match_direct": bool(all(
            np.array_equal(np.asarray(r.logits), o)
            for r, o in zip(dreqs, oracle))),
    }

    gp_base = baseline["goodput_imgs_per_s"]
    return {
        "meta": {"fast": fast, "seed": seed,
                 "deadline_ms": deadline_ms, "slo_ms": slo_ms,
                 "retries": 3, "service_ms_b4": svc,
                 "trace": {"kind": "bursty", "n_bursts": n_bursts,
                           "burst": 3}},
        "schedule": {p: dataclasses.asdict(s) for p, s in schedule.items()},
        "idle_parity": idle_parity,
        "baseline": baseline,
        "faulted": faulted,
        "goodput_under_faults_ratio": (
            faulted["goodput_imgs_per_s"] / gp_base if gp_base else 0.0),
        "degradation": degradation,
    }


def check_chaos(out: dict):
    """CI chaos-smoke gates: nothing lost, goodput under faults, armed-idle
    bit-parity, degraded bucket serving bit-correct logits."""
    assert out["idle_parity"]["bit_identical"], \
        "armed-but-idle injector perturbed serving output"
    for name in ("baseline", "faulted"):
        r = out[name]
        assert r["accounting_balanced"] and r["in_flight"] == 0, \
            f"{name}: accounting does not balance ({r})"
        assert r["submitted"] == (r["completed"] + r["shed"]
                                  + r["expired"]), \
            f"{name}: lost requests"
    assert out["faulted"]["goodput_imgs_per_s"] > 0, \
        "zero goodput under the seeded fault schedule"
    fired = sum(v["fired"] for v in out["faulted"]["faults"].values())
    assert fired > 0, "fault schedule never fired"
    d = out["degradation"]
    assert d["degraded_buckets"], "no bucket degraded"
    assert d["bit_match_direct"], \
        "degraded-bucket logits diverge from the direct-route oracle"
    assert d["accounting"]["balanced"]
    print("serve_fleet/CHAOS_OK,0,all-gates-passed")


def chaos_rows(out: dict) -> list:
    b, f = out["baseline"], out["faulted"]
    d = out["degradation"]
    return [
        {"name": "serve_fleet/chaos_baseline",
         "us_per_call": 1e6 / max(b["imgs_per_s"], 1e-9),
         "derived": (f"goodput={b['goodput_imgs_per_s']:.1f}"
                     f";completed={b['completed']}"
                     f";p99_ms={b['latency_ms']['p99']:.1f}")},
        {"name": "serve_fleet/chaos_faulted",
         "us_per_call": 1e6 / max(f["imgs_per_s"], 1e-9),
         "derived": (f"goodput={f['goodput_imgs_per_s']:.1f}"
                     f";completed={f['completed']}"
                     f";expired={f['expired']};shed={f['shed']}"
                     f";retried={f['retried']}"
                     f";ratio={out['goodput_under_faults_ratio']:.3f}")},
        {"name": "serve_fleet/chaos_degradation", "us_per_call": 0,
         "derived": (f"buckets={d['degraded_buckets']}"
                     f";bit_match={int(d['bit_match_direct'])}"
                     f";retried={d['retried']}")},
        {"name": "serve_fleet/chaos_idle_parity", "us_per_call": 0,
         "derived": f"bit_identical={int(out['idle_parity']['bit_identical'])}"},
    ]


# ---------------------------------------------------------------------------
# SDC harness (--sdc): ABFT + slab-integrity defense vs injected corruption
# ---------------------------------------------------------------------------
def run_sdc(fast: bool, seed: int = 0) -> dict:
    """Silent-data-corruption defense harness (artifact: BENCH_sdc.json).

    Four measured scenarios against one AlexNet engine (pallas route, the
    datapath the ABFT checksum row actually protects):

    1. *clean overhead* — the identical probe set served with the defense
       off vs fully armed (ABFT + slab fingerprints + magnitude screen):
       logits must be bit-identical, zero detections (false-positive rate
       0.0), and the wall-clock ratio is the price of the defense.
    2. *bitflip detection* — a seeded ``slab.bitflip`` schedule against
       the ABFT verdict gate (fingerprint check off, so the in-kernel
       checksums are the detector): every fired flip must be detected
       before its batch retires and every request must still complete via
       repack-and-retry (detection_rate == 1.0, accounting balanced).
    3. *pre-dispatch integrity* — ``verify_slabs`` against ``slab.bitflip``
       + ``slab.stale``: both corruption classes caught by the host-side
       fingerprint check before a forward is burned (the stale-slab class
       is *only* catchable here — a wrong-shape slab would otherwise be
       silently repacked in-trace).
    4. *plausible corruption* — ``retire.plausible`` (finite,
       bounded-magnitude logit perturbation that defeats the isfinite
       screen) against ``screen_abs_max``: the row is screened out and
       retried, never served.
    """
    import dataclasses

    import jax
    from repro.configs import get_config
    from repro.models import alexnet
    from repro.serving import (CnnEngine, CnnServeConfig, FaultInjector,
                               FaultSpec, ImageRequest, derive_seed)

    cfg_off = dataclasses.replace(get_config("alexnet").reduced(),
                                  image_size=35, use_pallas=True)
    cfg_abft = dataclasses.replace(cfg_off, sdc_abft=True)
    params = alexnet.init(jax.random.PRNGKey(seed), cfg_off)
    image = _image_fn(cfg_off, seed)
    scfg = CnnServeConfig(max_batch=4, retry_backoff_ms=0.5,
                          screen_sample=4)

    def serve(eng, imgs, retries=3):
        rs = [ImageRequest(image=im, retries=retries) for im in imgs]
        for r in rs:
            eng.submit(r)
        eng.run_until_done()
        return rs

    # -- 1. clean-path parity + overhead ---------------------------------
    n_clean = 16 if fast else 48
    probe = [image() for _ in range(n_clean)]

    def run_clean(cfg_run, scfg_run):
        e = CnnEngine(cfg_run, scfg_run, params=params)
        _warm_buckets(e, image)
        e.reset_metrics()
        t0 = time.perf_counter()
        rs = serve(e, probe)
        return e, rs, time.perf_counter() - t0

    scfg_armed = dataclasses.replace(scfg, verify_slabs=True,
                                     screen_abs_max=1e6)
    e_off, rs_off, wall_off = run_clean(cfg_off, scfg)
    e_on, rs_on, wall_on = run_clean(cfg_abft, scfg_armed)
    clean = {
        "requests": n_clean,
        "bit_identical": bool(all(
            np.array_equal(np.asarray(a.logits), np.asarray(b.logits))
            for a, b in zip(rs_off, rs_on))),
        "detections": e_on.sdc_detections,
        "slab_integrity_failures": e_on.slab_integrity_failures,
        "screen_magnitude": e_on.screen_magnitude,
        "false_positive_rate": (
            (e_on.sdc_detections + e_on.slab_integrity_failures
             + e_on.screen_magnitude) / max(e_on.batches_run, 1)),
        "wall_off_s": wall_off,
        "wall_armed_s": wall_on,
        "overhead_ratio": wall_on / wall_off if wall_off else 0.0,
        "accounting_balanced": (e_off.accounting()["balanced"]
                                and e_on.accounting()["balanced"]),
    }

    # -- 2. ABFT bitflip detection + repack-and-retry recovery -----------
    flips_at = tuple(range(0, 6, 2)) if fast else tuple(range(0, 16, 2))
    inj = FaultInjector(seed=derive_seed(seed, "sdc-bitflip"),
                        specs={"slab.bitflip": FaultSpec(at=flips_at)})
    e = CnnEngine(cfg_abft, scfg, params=params)   # fingerprints off:
    _warm_buckets(e, image)                        # ABFT is the detector
    e.arm_faults(inj)
    e.reset_metrics()
    n_flip_reqs = 4 * (max(flips_at) + 2)
    rs = serve(e, [image() for _ in range(n_flip_reqs)])
    fired = inj.summary()["slab.bitflip"]["fired"]
    bitflip = {
        "requests": n_flip_reqs,
        "flips_fired": fired,
        "detections": e.sdc_detections,
        "detection_rate": e.sdc_detections / fired if fired else 0.0,
        "completed": int(sum(r.done for r in rs)),
        "retried": e.images_retried,
        "batches_failed": e.batches_failed,
        "accounting_balanced": e.accounting()["balanced"],
        "faults": e.faults.summary(),
    }

    # -- 3. pre-dispatch slab fingerprint verification -------------------
    inj_v = FaultInjector(seed=derive_seed(seed, "sdc-verify"),
                          specs={"slab.bitflip": FaultSpec(at=(0,)),
                                 "slab.stale": FaultSpec(at=(1,))})
    e_v = CnnEngine(cfg_abft, scfg_armed, params=params)
    _warm_buckets(e_v, image)
    e_v.arm_faults(inj_v)
    e_v.reset_metrics()
    rs_v = serve(e_v, [image() for _ in range(12)])
    fired_v = sum(v["fired"] for p, v in inj_v.summary().items()
                  if p.startswith("slab."))
    verify = {
        "requests": 12,
        "faults_fired": fired_v,
        "slab_integrity_failures": e_v.slab_integrity_failures,
        "abft_detections": e_v.sdc_detections,
        "completed": int(sum(r.done for r in rs_v)),
        "accounting_balanced": e_v.accounting()["balanced"],
        "faults": e_v.faults.summary(),
    }

    # -- 4. plausible (finite) corruption vs the magnitude screen --------
    inj_p = FaultInjector(
        seed=derive_seed(seed, "sdc-plausible"),
        specs={"retire.plausible": FaultSpec(at=(0,), magnitude=1e8)})
    e_p = CnnEngine(cfg_abft, scfg_armed, params=params)
    _warm_buckets(e_p, image)
    e_p.arm_faults(inj_p)
    e_p.reset_metrics()
    rs_p = serve(e_p, [image() for _ in range(8)])
    plausible = {
        "requests": 8,
        "fired": inj_p.summary()["retire.plausible"]["fired"],
        "screen_magnitude": e_p.screen_magnitude,
        "screen_nonfinite": e_p.screen_nonfinite,
        "completed": int(sum(r.done for r in rs_p)),
        "retried": e_p.images_retried,
        "accounting_balanced": e_p.accounting()["balanced"],
    }

    return {
        "meta": {"fast": fast, "seed": seed, "image_size": 35,
                 "route": "pallas",
                 "defense": {"sdc_abft": True, "verify_slabs": True,
                             "screen_abs_max": 1e6}},
        "clean": clean,
        "bitflip": bitflip,
        "verify": verify,
        "plausible": plausible,
    }


def check_sdc(out: dict):
    """CI sdc-smoke gates: detection rate 1.0 on injected flips, zero
    false positives and bit-identical logits on the clean trace, both
    slab corruption classes caught pre-dispatch, the plausible-corruption
    row screened, and no engine losing a request."""
    c = out["clean"]
    assert c["bit_identical"], "armed clean serving diverged from unarmed"
    assert c["detections"] == 0 and c["false_positive_rate"] == 0.0, \
        f"false positives on a clean run ({c})"
    b = out["bitflip"]
    assert b["flips_fired"] > 0, "bitflip schedule never fired"
    assert b["detection_rate"] == 1.0, \
        f"missed injected bit flips ({b})"
    assert b["completed"] == b["requests"], \
        "bitflip run lost requests (repack-and-retry must complete them)"
    v = out["verify"]
    assert v["slab_integrity_failures"] == v["faults_fired"] > 0, \
        f"fingerprint check missed slab corruption ({v})"
    assert v["completed"] == v["requests"]
    p = out["plausible"]
    assert p["screen_magnitude"] >= p["fired"] > 0, \
        f"magnitude screen missed plausible corruption ({p})"
    assert p["completed"] == p["requests"]
    for name in ("clean", "bitflip", "verify", "plausible"):
        assert out[name]["accounting_balanced"], f"{name}: lost requests"
    print("serve_fleet/SDC_OK,0,all-gates-passed")


def sdc_rows(out: dict) -> list:
    c, b = out["clean"], out["bitflip"]
    v, p = out["verify"], out["plausible"]
    return [
        {"name": "serve_fleet/sdc_clean_overhead",
         "us_per_call": 1e6 * c["wall_armed_s"] / max(c["requests"], 1),
         "derived": (f"ratio={c['overhead_ratio']:.3f}"
                     f";bit_identical={int(c['bit_identical'])}"
                     f";fp_rate={c['false_positive_rate']:.3f}")},
        {"name": "serve_fleet/sdc_bitflip", "us_per_call": 0,
         "derived": (f"detection_rate={b['detection_rate']:.3f}"
                     f";fired={b['flips_fired']}"
                     f";completed={b['completed']}/{b['requests']}"
                     f";retried={b['retried']}")},
        {"name": "serve_fleet/sdc_verify_slabs", "us_per_call": 0,
         "derived": (f"integrity_failures={v['slab_integrity_failures']}"
                     f";fired={v['faults_fired']}"
                     f";completed={v['completed']}/{v['requests']}")},
        {"name": "serve_fleet/sdc_plausible", "us_per_call": 0,
         "derived": (f"screen_magnitude={p['screen_magnitude']}"
                     f";fired={p['fired']}"
                     f";completed={p['completed']}/{p['requests']}")},
    ]


# ---------------------------------------------------------------------------
# supervised fleet: multi-process workers, seeded mid-trace kill
# ---------------------------------------------------------------------------
def run_supervised(fast: bool, seed: int = 0) -> dict:
    """Supervised multi-process fleet chaos run: the identical seeded
    bursty trace served twice by a 2-worker process fleet — once
    undisturbed, once with a deterministic ``worker.crash`` (SIGKILL of
    worker w0 at a seeded pump opportunity mid-trace).  The artifact
    reports goodput and p99 for both, the fleet accounting invariant, and
    the failover bit-parity check (every failed-over request's logits vs
    a jitted direct forward at its exact padded bucket shape)."""
    import dataclasses

    from repro.configs import get_config
    from repro.serving import (CnnServeConfig, FaultSpec, ImageRequest,
                               Supervisor, SupervisorConfig, WorkerModel)

    cfg = dataclasses.replace(get_config("alexnet").reduced(), image_size=35)
    scfg = CnnServeConfig(max_batch=4, retry_backoff_ms=0.5)
    slo_ms = 300.0                      # process fleet: RPC + pump overhead
    deadline_ms = 2000.0
    n_bursts = 8 if fast else 24
    kill_at = 2 if fast else 8          # w0 pump-opportunity index
    trace = bursty_trace(n_bursts, 3, 0.015,
                         np.random.default_rng(seed + 11))

    def run(kill: bool) -> dict:
        chaos = ({"worker.crash": FaultSpec(at=(kill_at,), limit=1)}
                 if kill else None)
        sup = Supervisor(
            (WorkerModel("alexnet", cfg, scfg, seed=seed),),
            SupervisorConfig(n_workers=2, max_restarts=2,
                             checkpoint_on_start=False),
            seed=seed, chaos=chaos, chaos_workers=("w0",))
        with sup:
            reqs = []

            def submit(_):
                r = ImageRequest(image=image(), deadline_ms=deadline_ms,
                                 retries=3)
                reqs.append(r)
                sup.submit("alexnet", r)

            image = _image_fn(cfg, seed)
            t0 = time.perf_counter()
            drive_open_loop([(t, None) for t in trace], submit, sup.step,
                            lambda: sup.drained, max_wall_s=300.0)
            sup.run_until_done()
            wall = time.perf_counter() - t0
            acc = sup.accounting()
            lat = _lat_percentiles_ms(reqs)
            within = sum(1 for r in reqs if r.done
                         and (r.t_done - r.t_submit) * 1e3 <= slo_ms)
            parity = (sup.verify_bit_parity() if sup.failover_uids
                      else {"checked": 0, "mismatched": 0, "bad_uids": []})
            deaths = [e for e in sup.events if e["event"] == "death"]
            respawns = [e for e in sup.events
                        if e["event"] == "spawn" and e["restarts"] > 0]
            return {
                "accounting": acc,
                "imgs_per_s": acc["completed"] / wall if wall else 0.0,
                "goodput_imgs_per_s": within / wall if wall else 0.0,
                "latency_ms": lat,
                "wall_s": wall,
                "deaths": [{"worker": e["worker"], "reason": e["reason"]}
                           for e in deaths],
                "respawns": len(respawns),
                "failover_parity": parity,
                "worker_stats": {n: {"restarts": w["restarts"],
                                     "deaths": w["deaths"],
                                     "health": w["health"]["state"]}
                                 for n, w in sup.stats()["workers"].items()},
            }

    baseline = run(kill=False)
    killed = run(kill=True)
    gp = baseline["goodput_imgs_per_s"]
    return {
        "meta": {"fast": fast, "seed": seed, "n_workers": 2,
                 "slo_ms": slo_ms, "deadline_ms": deadline_ms,
                 "kill_at_opportunity": kill_at,
                 "trace": {"kind": "bursty", "n_bursts": n_bursts,
                           "burst": 3}},
        "baseline": baseline,
        "killed": killed,
        "goodput_under_kill_ratio": (
            killed["goodput_imgs_per_s"] / gp if gp else 0.0),
    }


def check_supervised(out: dict):
    """CI supervisor-smoke gates: zero lost requests fleet-wide across the
    worker kill, goodput survives, failed-over logits bit-match."""
    for name in ("baseline", "killed"):
        acc = out[name]["accounting"]
        assert acc["balanced"] and acc["in_flight"] == 0, \
            f"{name}: fleet accounting does not balance ({acc})"
        assert acc["submitted"] == (acc["completed"] + acc["shed"]
                                    + acc["expired"]), \
            f"{name}: lost requests ({acc})"
        assert out[name]["goodput_imgs_per_s"] > 0, f"{name}: zero goodput"
    k = out["killed"]
    assert k["deaths"], "seeded worker.crash never fired"
    assert k["accounting"]["failed_over"] > 0, \
        "kill run failed over no requests (kill landed on an idle worker)"
    p = k["failover_parity"]
    assert p["checked"] > 0 and p["mismatched"] == 0, \
        f"failover bit-parity violated: {p}"
    print("serve_fleet/SUPERVISED_OK,0,all-gates-passed")


def supervised_rows(out: dict) -> list:
    b, k = out["baseline"], out["killed"]
    p = k["failover_parity"]
    return [
        {"name": "serve_fleet/supervised_baseline",
         "us_per_call": 1e6 / max(b["imgs_per_s"], 1e-9),
         "derived": (f"goodput={b['goodput_imgs_per_s']:.1f}"
                     f";completed={b['accounting']['completed']}"
                     f";p99_ms={b['latency_ms']['p99']:.1f}")},
        {"name": "serve_fleet/supervised_killed",
         "us_per_call": 1e6 / max(k["imgs_per_s"], 1e-9),
         "derived": (f"goodput={k['goodput_imgs_per_s']:.1f}"
                     f";completed={k['accounting']['completed']}"
                     f";failed_over={k['accounting']['failed_over']}"
                     f";deaths={len(k['deaths'])}"
                     f";respawns={k['respawns']}"
                     f";p99_ms={k['latency_ms']['p99']:.1f}"
                     f";ratio={out['goodput_under_kill_ratio']:.3f}")},
        {"name": "serve_fleet/supervised_failover_parity", "us_per_call": 0,
         "derived": (f"checked={p['checked']}"
                     f";mismatched={p['mismatched']}")},
    ]


# ---------------------------------------------------------------------------
def check(out: dict):
    """CI gates: goodput flowed, everything drained, accounting closed.
    (The p99 A/B delta is reported in the artifact, not gated — shared CI
    runners are too noisy to bound a latency percentile.)"""
    ab = out["policy_ab"]
    assert ab["fixed"]["imgs_per_s"] > 0
    assert ab["dynamic"]["goodput_imgs_per_s"] > 0
    for n, m in out["fleet"]["models"].items():
        assert m["completed"] > 0, f"{n}: nothing served"
        assert m["goodput_imgs_per_s"] > 0, f"{n}: zero goodput under SLO"
        assert m["submitted"] == m["completed"] + m["shed"], n
    assert out["closed_loop"]["imgs_per_s"] > 0
    print("serve_fleet/CHECK_OK,0,all-gates-passed")


def rows(out: dict) -> list:
    ab, fl, cl = out["policy_ab"], out["fleet"], out["closed_loop"]
    r = []
    for kind in ("fixed", "dynamic"):
        m = ab[kind]
        r.append({"name": f"serve_fleet/bursty_{kind}",
                  "us_per_call": 1e6 / max(m["imgs_per_s"], 1e-9),
                  "derived": (f"imgs_s={m['imgs_per_s']:.1f}"
                              f";goodput={m['goodput_imgs_per_s']:.1f}"
                              f";p99_ms={m['latency_ms']['p99']:.1f}"
                              f";buckets={'/'.join(map(str, m['buckets']))}")})
    r.append({"name": "serve_fleet/ab_delta", "us_per_call": 0,
              "derived": f"p99_reduction_pct={ab['p99_reduction_pct']:.1f}"})
    for n, m in fl["models"].items():
        r.append({"name": f"serve_fleet/fleet_{n}",
                  "us_per_call": 1e6 / max(m["imgs_per_s"], 1e-9),
                  "derived": (f"imgs_s={m['imgs_per_s']:.1f}"
                              f";goodput={m['goodput_imgs_per_s']:.1f}"
                              f";shed={m['shed']}"
                              f";p99_ms={m['latency_ms']['p99']:.1f}")})
    r.append({"name": "serve_fleet/fleet_total", "us_per_call": 0,
              "derived": (f"imgs_s={fl['fleet']['imgs_per_s_wall']:.1f}"
                          f";vs_paper={fl['fleet']['vs_paper']:.3f}"
                          f";shed={fl['fleet']['images_shed']}")})
    r.append({"name": "serve_fleet/closed_loop",
              "us_per_call": 1e6 / max(cl["imgs_per_s"], 1e-9),
              "derived": (f"imgs_s={cl['imgs_per_s']:.1f}"
                          f";occupancy={cl['avg_occupancy']:.2f}"
                          f";p99_ms={cl['latency_ms']['p99']:.1f}")})
    return r


def run_all(fast: bool, seed: int = 0) -> dict:
    return {
        "meta": {"fast": fast, "seed": seed,
                 "paper_imgs_per_s": PAPER_IMGS_PER_S,
                 "note": ("CPU wall-clock; relative comparisons only — the "
                          "paper number is Arria 10 silicon")},
        "policy_ab": run_policy_ab(fast, seed),
        "fleet": run_fleet(fast, seed),
        "closed_loop": run_closed_loop(fast, seed),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="CI smoke scale (short traces, few clients)")
    ap.add_argument("--check", action="store_true",
                    help="assert the CI gates (goodput/drain/accounting)")
    ap.add_argument("--chaos", action="store_true",
                    help="run the seeded fault-injection harness instead "
                         "(artifact: BENCH_chaos.json)")
    ap.add_argument("--sdc", action="store_true",
                    help="run the silent-data-corruption defense harness "
                         "instead: ABFT/fingerprint/screen detection vs "
                         "injected slab bit flips, stale slabs, and "
                         "plausible logit corruption (artifact: "
                         "BENCH_sdc.json)")
    ap.add_argument("--supervised", action="store_true",
                    help="run the supervised multi-process fleet chaos "
                         "harness instead (artifact: BENCH_supervisor.json)")
    ap.add_argument("--out", default=None,
                    help="write the JSON artifact (BENCH_serve_fleet.json)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.supervised:
        out = run_supervised(args.fast, args.seed)
        emit(supervised_rows(out))
    elif args.sdc:                  # --chaos --sdc runs the SDC harness
        out = run_sdc(args.fast, args.seed)
        emit(sdc_rows(out))
    elif args.chaos:
        out = run_chaos(args.fast, args.seed)
        emit(chaos_rows(out))
    else:
        out = run_all(args.fast, args.seed)
        emit(rows(out))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(out, f, indent=1, sort_keys=True)
        print(f"serve_fleet/ARTIFACT,0,wrote={args.out}")
    if args.check:
        (check_supervised if args.supervised else
         check_sdc if args.sdc else
         check_chaos if args.chaos else check)(out)


if __name__ == "__main__":
    main()
