import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))


def time_us(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time per call in microseconds (CPU; used for relative
    comparisons and harness sanity, not TPU projections).

    Thin veneer over the shared measurement core (``repro.core.timing``)
    so every benchmark and the autotuner apply one timing discipline:
    warmup calls excluded (jit compile), every sample bracketed by
    ``block_until_ready`` fences, median-of-k with an IQR steady-state
    guard that re-samples noisy runs.
    """
    from repro.core.timing import measure_us
    return measure_us(fn, *args, warmup=warmup, iters=iters)


def emit(rows):
    for r in rows:
        print(f"{r['name']},{r.get('us_per_call', 0):.1f},{r['derived']}")
