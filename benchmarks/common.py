import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))


def time_us(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time per call in microseconds (CPU; used for relative
    comparisons and harness sanity, not TPU projections)."""
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def emit(rows):
    for r in rows:
        print(f"{r['name']},{r.get('us_per_call', 0):.1f},{r['derived']}")
