"""Host->device stream buffer (paper §3.5 at the input-pipeline level).

The DLA's stream buffers double-buffer feature maps so the PEs never stall on
DDR.  The JAX training analogue at the host boundary: while step N computes,
batch N+1 is already being transferred, so the accelerator never waits on the
data pipeline.  (Inside the chip, the same role is played by the Pallas grid
pipeline's automatic double-buffered HBM->VMEM DMA and by XLA's latency
hiding scheduler for collectives.)
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional

import jax


class StreamBuffer:
    """Wrap a host batch iterator with ``depth``-deep async device prefetch."""

    def __init__(self, it: Iterator, *, depth: int = 2,
                 put_fn: Optional[Callable] = None):
        self._it = it
        self._put = put_fn or jax.device_put
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._done = object()
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        try:
            for batch in self._it:
                # device_put is async: the transfer overlaps compute.
                self._q.put(self._put(batch))
        except BaseException as e:   # surfaced on next()
            self._err = e
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item
