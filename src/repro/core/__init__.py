"""The paper's contribution as composable modules:

  winograd  — general Cook-Toom F(m,r) transforms (paper §3.3)
  bfp       — shared-exponent block floating point (paper §3.6)
  dse       — analytical resource/throughput models + exploration (paper §4)
  roofline  — compute/memory/collective terms from compiled HLO
  streambuf — double-buffered host->device prefetch (paper §3.5 analog)
"""
from . import bfp, dse, roofline, streambuf, winograd  # noqa: F401
