"""Measured wall-clock timing shared by the autotuner and the benchmarks.

One timing discipline for every measured number in the repo (the paper's §4
DSE picks its design point from *measured* candidates, so the measurement
itself has to be trustworthy):

* **warmup** calls first — the first call pays jit tracing + compilation
  and must never land in a sample;
* every sample brackets a full ``jax.block_until_ready`` — JAX dispatch is
  async, so without the fence a "measurement" only times the enqueue;
* **median-of-k** — the median is robust to the one-sided noise wall-clock
  has (preemption, GC, frequency ramps all make samples *slower*, never
  faster);
* a **steady-state guard** — if the middle half of the samples still spreads
  more than ``steady_rtol`` around the median, the run hasn't settled
  (compilation cache warming, thermal ramp); collect another round of
  samples, up to ``max_rounds``, and report whether steadiness was reached
  so callers (the autotuner's candidate ranking, CI gates) can weigh the
  number accordingly.

``benchmarks/common.py::time_us`` and ``core/autotune.py`` both delegate
here, so a benchmark row and an autotuner decision can never disagree about
what "measured" means.
"""
from __future__ import annotations

import time
from dataclasses import dataclass


@dataclass(frozen=True)
class Timing:
    """One measured call: median microseconds + the evidence behind it."""
    us: float                   # median wall-time per call, microseconds
    samples: tuple              # all collected samples (us), sorted
    spread: float               # IQR / median of the final sample set
    steady: bool                # spread <= steady_rtol within max_rounds
    rounds: int                 # sample rounds taken (1 = no retry needed)

    def __float__(self) -> float:
        return self.us


def _iqr_spread(sorted_us) -> float:
    n = len(sorted_us)
    med = sorted_us[n // 2]
    if med <= 0:
        return 0.0
    q1, q3 = sorted_us[n // 4], sorted_us[(3 * n) // 4]
    return (q3 - q1) / med


def measure(fn, *args, warmup: int = 1, iters: int = 3,
            steady_rtol: float = 0.25, max_rounds: int = 3) -> Timing:
    """Measure ``fn(*args)`` wall-clock; returns a :class:`Timing` (us).

    ``warmup`` calls run (and are fenced) before any sample is taken;
    each of the ``iters`` samples brackets a ``jax.block_until_ready``.
    If the samples' inter-quartile spread exceeds ``steady_rtol`` of the
    median, another round of ``iters`` samples is collected (the median is
    then taken over *all* samples) — at most ``max_rounds`` rounds.
    """
    import jax
    for _ in range(max(warmup, 0)):
        jax.block_until_ready(fn(*args))
    samples: list[float] = []
    rounds = 0
    while True:
        rounds += 1
        for _ in range(max(iters, 1)):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            samples.append((time.perf_counter() - t0) * 1e6)
        samples.sort()
        spread = _iqr_spread(samples)
        if spread <= steady_rtol or rounds >= max_rounds:
            return Timing(us=samples[len(samples) // 2],
                          samples=tuple(samples), spread=spread,
                          steady=spread <= steady_rtol, rounds=rounds)


def measure_us(fn, *args, warmup: int = 1, iters: int = 3,
               steady_rtol: float = 0.25, max_rounds: int = 3) -> float:
    """Median wall-time per call in microseconds (:func:`measure`'s ``us``)."""
    return measure(fn, *args, warmup=warmup, iters=iters,
                   steady_rtol=steady_rtol, max_rounds=max_rounds).us
