"""Live measured autotuner: per-layer DSE over the real Pallas knobs.

The paper's §4 design-space exploration ranks candidate (C_vec, K_vec)
configurations with an *analytical* model and synthesizes the winner.  Our
analog runs the same loop live: for one conv layer (a
:class:`~repro.nn.conv.ConvSpec` + concrete input geometry) it enumerates
the valid launch plans over the knobs the kernels actually expose —
``batch_block`` (filter-cache depth), ``k_block``, ``c_block`` /
``pool_row_block`` (VMEM-budget auto-sizing overrides), ``weight_prefetch``
(double-buffered DMA stream on/off) and ``row_parallel`` (per-row-block
stream restart that frees the row grid dimension) — *measures* each through
the full :func:`~repro.nn.conv.dispatch_conv` path with the shared timing
discipline (warmup, ``block_until_ready`` fences, median-of-k,
steady-state guard; ``core/timing.py``), and persists the winner in a JSON
plan cache keyed by (geometry, backend kind, dtype, fusion flags).

Guarantees by construction:

* the default ``ConvPlan()`` is always a candidate, so the tuned plan can
  never measure slower than the default *in the sweep that chose it*;
* every candidate is **bit-equal** to the default plan — the knobs swept
  here only re-block the launch (filter-cache depth, weight-tile shape,
  pool row ownership, DMA scheduling), never the f32 accumulation order.
  ``c_block`` *would* change reduction order, so candidates keep the
  auto-sized value (full-C residency for every AlexNet layer under the
  8 MiB budget) — the one knob the measured sweep leaves to the analytic
  VMEM model;
* plans deduplicate by their *effective* kernel launch (the resolved
  ``WinogradPlan``/``DirectPlan`` plus the stream knobs), so clamped or
  widened knob values (``batch_block > B``, non-dividing ``k_block``)
  never measure twice.

``scripts/autotune_alexnet.py`` wraps :func:`autotune_alexnet` as a CLI;
``benchmarks/fused_pipeline.py --autotune`` folds tuned plans into the
fused-pipeline bench; ``models/alexnet.py`` / ``serving/cnn.py`` load the
persisted cache at engine build.
"""
from __future__ import annotations

import json
import os
import warnings
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..nn.conv import (ConvPlan, ConvSpec, DEFAULT_PLAN, _pallas_weight_plan,
                       _spec_fusion, dispatch_conv, resolve_kernel)
from .timing import Timing, measure

# default on-disk home for persisted plan caches
PLAN_DIR = os.path.join("results", "plans")

# knob grids the enumerator crosses (pruned + deduped against the layer)
BATCH_BLOCKS = (1, 2, 4, 8, 16)
K_BLOCKS = (64, 128, 256)
POOL_ROW_BLOCKS = (None, 1, 2, 4)


# ---------------------------------------------------------------------------
# cache keys
# ---------------------------------------------------------------------------
def backend_kind(interpret: bool | None = None) -> str:
    """The measurement substrate a plan was tuned on.  Interpret-mode
    numbers are emulation wall-clock — never valid on a real backend, so
    the marker keeps them from leaking across."""
    kind = jax.default_backend()
    if interpret is None:
        interpret = kind != "tpu"
    return f"{kind}-interpret" if interpret else kind


def plan_key(spec: ConvSpec, in_shape, *, dtype="float32",
             interpret: bool | None = None) -> dict:
    """The cache identity of one tuning problem: layer geometry (batch
    included — the filter-cache depth trades against it), fusion flags,
    dtype, and the backend kind measurements ran on."""
    B, H, W, C = in_shape
    return {
        "kernel": spec.kernel, "stride": spec.stride,
        "padding": spec.padding, "groups": spec.groups,
        "route": spec.route, "winograd_m": spec.winograd_m,
        "relu": spec.relu, "fuse_bias": spec.fuse_bias,
        "fuse_lrn": spec.fuse_lrn, "fuse_pool": spec.fuse_pool,
        "pool_window": spec.pool_window, "pool_stride": spec.pool_stride,
        "batch": B, "h": H, "w": W, "c": C,
        "dtype": str(jnp.dtype(dtype)),
        "backend": backend_kind(interpret),
    }


def key_str(key: dict) -> str:
    """Canonical string form (stable across field order / sessions)."""
    return json.dumps(key, sort_keys=True, separators=(",", ":"))


# ---------------------------------------------------------------------------
# plan cache
# ---------------------------------------------------------------------------
@dataclass
class PlanCache:
    """A JSON-backed map from :func:`plan_key` to the tuned best plan.

    One file per model/network (``results/plans/<name>.json``); each entry
    records the winning plan, the measured numbers behind it, and the full
    key fields so lookups can relax the batch (a serving engine with a
    different bucket size still wants conv2's tuned blocking)."""
    path: str | None = None
    entries: dict = field(default_factory=dict)     # key_str -> entry dict

    @classmethod
    def load(cls, path) -> "PlanCache":
        """Load a persisted cache; tolerate a broken one.

        A corrupt/truncated JSON file, an unknown schema version, or a
        malformed entries table must never take down model build — the
        cache is a performance hint, and every plan is bit-equal to the
        default anyway.  Such a file loads as an *empty* cache with a
        warning (the next autotune run rewrites it atomically).
        """
        path = os.fspath(path)
        cache = cls(path=path)
        if not os.path.exists(path):
            return cache
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, ValueError) as e:
            warnings.warn(f"plan cache {path} is unreadable ({e}); "
                          f"falling back to default plans", stacklevel=2)
            return cache
        version = data.get("version") if isinstance(data, dict) else None
        if version != 1:
            warnings.warn(f"plan cache {path} has unknown schema version "
                          f"{version!r} (expected 1); falling back to "
                          f"default plans", stacklevel=2)
            return cache
        entries = data.get("entries", {})
        if not (isinstance(entries, dict)
                and all(isinstance(e, dict) and "plan" in e and "key" in e
                        for e in entries.values())):
            warnings.warn(f"plan cache {path} has a malformed entries "
                          f"table; falling back to default plans",
                          stacklevel=2)
            return cache
        cache.entries = entries
        return cache

    def save(self, path=None) -> str:
        path = os.fspath(path or self.path)
        assert path, "PlanCache.save needs a path"
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"version": 1, "entries": self.entries}, f, indent=2,
                      sort_keys=True)
            f.write("\n")
        os.replace(tmp, path)
        self.path = path
        return path

    def put(self, key: dict, plan: ConvPlan, stats: dict | None = None):
        self.entries[key_str(key)] = {
            "key": dict(key), "plan": plan.to_dict(),
            "stats": dict(stats or {}),
        }

    def get(self, key: dict, *, any_batch: bool = False) -> ConvPlan | None:
        """Exact lookup; with ``any_batch`` fall back to an entry matching
        every field but the batch (serving buckets reuse the nearest tuned
        geometry rather than running untuned)."""
        hit = self.entries.get(key_str(key))
        if hit is None and any_batch:
            want = {k: v for k, v in key.items() if k != "batch"}
            for e in self.entries.values():
                have = {k: v for k, v in e["key"].items() if k != "batch"}
                if have == want:
                    hit = e
                    break
        return None if hit is None else ConvPlan.from_dict(hit["plan"])

    def stats(self, key: dict) -> dict | None:
        hit = self.entries.get(key_str(key))
        return None if hit is None else hit.get("stats")


def default_cache_path(name: str = "alexnet") -> str:
    return os.path.join(PLAN_DIR, f"{name}.json")


# ---------------------------------------------------------------------------
# candidate enumeration
# ---------------------------------------------------------------------------
def _effective_signature(spec: ConvSpec, kernel: str, in_shape, w_shape,
                         plan: ConvPlan):
    """What the launch actually runs: the resolved kernel blocking plan
    plus the stream knobs that live outside it.  Two ConvPlans with the
    same signature are the same launch — measure one."""
    lrn_p, pool = _spec_fusion(spec)
    p = _pallas_weight_plan(spec, kernel, tuple(in_shape), w_shape,
                            lrn=lrn_p, pool=pool, knobs=plan)
    single = p.weights.n_tiles == 1
    return (kernel, p, plan.weight_prefetch,
            plan.row_parallel and not single)


def enumerate_plans(spec: ConvSpec, in_shape, w_shape, *,
                    max_candidates: int | None = None) -> list[ConvPlan]:
    """All distinct candidate launch plans for one layer, default first.

    The cross product of the knob grids is pruned two ways: knobs the
    kernel would clamp or widen anyway (``batch_block > B``, a ``k_block``
    that doesn't divide K, a ``pool_row_block`` past the pooled extent)
    collapse onto their effective launch via :func:`_effective_signature`,
    and ``c_block`` stays on the analytic auto-sizing (see module doc) so
    every emitted plan is bit-equal to the default.  Non-Pallas datapaths
    have no launch knobs — the default plan is the only candidate.
    """
    kernel = resolve_kernel(spec, in_hw=(in_shape[1], in_shape[2]))
    if not kernel.startswith("pallas"):
        return [DEFAULT_PLAN]

    B = in_shape[0]
    batch_grid = sorted({min(bb, B) for bb in BATCH_BLOCKS})
    pool_grid = POOL_ROW_BLOCKS if spec.fuse_pool else (None,)

    seen, out = set(), []

    def admit(plan: ConvPlan):
        sig = _effective_signature(spec, kernel, in_shape, w_shape, plan)
        if sig in seen:
            return
        seen.add(sig)
        out.append(plan)

    admit(DEFAULT_PLAN)             # tuned can never regress the default
    for bb in batch_grid:
        for kb in K_BLOCKS:
            for prb in pool_grid:
                for pref in (True, False):
                    for rp in (False, True):
                        admit(ConvPlan(batch_block=bb, k_block=kb,
                                       pool_row_block=prb,
                                       weight_prefetch=pref,
                                       row_parallel=rp))
    if max_candidates is not None and len(out) > max_candidates:
        out = out[:max_candidates]
    return out


def _neighbors(plan: ConvPlan, B: int) -> list[ConvPlan]:
    """Hill-climb moves: halve/double the two blocking knobs the grids may
    have bracketed too coarsely."""
    moves = []
    for bb in (plan.batch_block // 2, plan.batch_block * 2):
        if 1 <= bb <= max(B, 1):
            moves.append(ConvPlan(**{**plan.to_dict(), "batch_block": bb}))
    for kb in (plan.k_block // 2, plan.k_block * 2):
        if 16 <= kb <= 512:
            moves.append(ConvPlan(**{**plan.to_dict(), "k_block": kb}))
    return moves


# ---------------------------------------------------------------------------
# measurement
# ---------------------------------------------------------------------------
def measure_plan(spec: ConvSpec, x, w, b, plan: ConvPlan, *,
                 interpret: bool | None = None, warmup: int = 1,
                 iters: int = 3) -> Timing:
    """Median wall-clock of the full jitted dispatch under one plan."""
    fn = jax.jit(lambda x_, w_, b_: dispatch_conv(
        spec, x_, w_, b_, plan=plan, interpret=interpret))
    return measure(fn, x, w, b, warmup=warmup, iters=iters)


def autotune_layer(spec: ConvSpec, x, w, b=None, *,
                   interpret: bool | None = None, warmup: int = 1,
                   iters: int = 3, max_candidates: int | None = None,
                   hill_climb: bool = False, check_equal: bool = False,
                   log=None):
    """Measure every candidate plan for one layer; return the winner.

    Returns ``(best_plan, rows)`` where ``rows`` is one measurement record
    per candidate (``plan``/``us``/``steady``/``default`` fields), rows[0]
    always the default plan.  With ``hill_climb`` the winner seeds a
    halve/double neighborhood walk past the grid edges.  ``check_equal``
    additionally asserts each candidate's output is bit-equal to the
    default's (the enumerator guarantees it; the flag makes a tuning run
    self-auditing at ~2x cost).
    """
    kernel = resolve_kernel(spec, in_hw=(x.shape[1], x.shape[2]))
    plans = enumerate_plans(spec, x.shape, w.shape,
                            max_candidates=max_candidates)
    y_ref = None
    if check_equal:
        y_ref = dispatch_conv(spec, x, w, b, plan=DEFAULT_PLAN,
                              interpret=interpret)
        y_ref = jax.block_until_ready(y_ref)

    rows, measured = [], {}

    def run(plan: ConvPlan) -> float:
        sig = _effective_signature(spec, kernel, x.shape, w.shape, plan) \
            if kernel.startswith("pallas") else ("ref",)
        if sig in measured:
            return measured[sig]
        if check_equal and y_ref is not None:
            y = jax.block_until_ready(
                dispatch_conv(spec, x, w, b, plan=plan, interpret=interpret))
            assert np.array_equal(np.asarray(y_ref), np.asarray(y)), (
                f"candidate plan not bit-equal to default: {plan}")
        t = measure_plan(spec, x, w, b, plan, interpret=interpret,
                         warmup=warmup, iters=iters)
        measured[sig] = t.us
        rows.append({"plan": plan.to_dict(), "us": t.us,
                     "steady": t.steady,
                     "default": plan == DEFAULT_PLAN})
        if log is not None:
            log(f"    {t.us:10.1f} us  {plan.to_dict()}")
        return t.us

    best, best_us = DEFAULT_PLAN, run(DEFAULT_PLAN)
    for plan in plans[1:]:
        us = run(plan)
        if us < best_us:
            best, best_us = plan, us

    if hill_climb and kernel.startswith("pallas"):
        improved = True
        while improved:
            improved = False
            for nb in _neighbors(best, x.shape[0]):
                us = run(nb)
                if us < best_us:
                    best, best_us = nb, us
                    improved = True
    return best, rows


# ---------------------------------------------------------------------------
# network walker (AlexNet)
# ---------------------------------------------------------------------------
def alexnet_layer_geometries(cfg, batch: int):
    """(name, spec-with-route, in_shape, w_shape) per conv layer — the
    same shape chain ``models.alexnet.features`` walks."""
    from ..models import alexnet as ax
    route = ax._route(cfg)
    geoms, h, c_in = [], cfg.image_size, cfg.in_channels
    for i, (spec, c_out) in enumerate(zip(ax.layer_specs(cfg),
                                          cfg.conv_channels)):
        spec = spec.with_route(route)
        geoms.append((f"conv{i + 1}", spec, (batch, h, h, c_in),
                      (spec.kernel, spec.kernel, c_in // spec.groups, c_out)))
        h, c_in = spec.out_hw(h), c_out
    return geoms


def autotune_alexnet(cfg, batch: int, *, interpret: bool | None = None,
                     warmup: int = 1, iters: int = 3,
                     max_candidates: int | None = None,
                     hill_climb: bool = False, check_equal: bool = False,
                     cache: PlanCache | None = None, seed: int = 0,
                     log=None):
    """Tune every conv layer of an AlexNet config at one batch size.

    Returns per-layer result rows (name, key, default/tuned us, winning
    plan, candidate count) and writes each winner into ``cache`` when one
    is passed (caller saves).  Layer inputs are synthetic — launch-plan
    timing depends on geometry, not values.
    """
    dtype = jnp.dtype(cfg.dtype)
    key = jax.random.PRNGKey(seed)
    results = []
    for name, spec, in_shape, w_shape in alexnet_layer_geometries(cfg, batch):
        kx, kw, key = jax.random.split(key, 3)
        x = jax.random.normal(kx, in_shape, dtype)
        w = (jax.random.normal(kw, w_shape, dtype)
             * (w_shape[0] * w_shape[1] * w_shape[2]) ** -0.5)
        b = jnp.zeros((w_shape[-1],), dtype)
        if log is not None:
            log(f"  {name}: in={in_shape} w={w_shape} "
                f"kernel={resolve_kernel(spec, in_hw=in_shape[1])}")
        best, rows = autotune_layer(
            spec, x, w, b, interpret=interpret, warmup=warmup, iters=iters,
            max_candidates=max_candidates, hill_climb=hill_climb,
            check_equal=check_equal, log=log)
        default_us = next(r["us"] for r in rows if r["default"])
        tuned_us = min(r["us"] for r in rows)
        k = plan_key(spec, in_shape, dtype=cfg.dtype, interpret=interpret)
        stats = {"default_us": default_us, "tuned_us": tuned_us,
                 "candidates": len(rows)}
        if cache is not None:
            cache.put(k, best, stats)
        results.append({"layer": name, "key": k, "plan": best.to_dict(),
                        **stats})
    return results


def load_alexnet_plans(cfg, batch: int, *, path=None,
                       interpret: bool | None = None,
                       any_batch: bool = True) -> dict:
    """Tuned plans for an AlexNet config: ``{"conv1": ConvPlan, ...}`` for
    every layer with a cache hit (missing layers simply run the default).
    The lookup key must match what :func:`autotune_alexnet` stored —
    geometry, dtype, and the *current* backend kind — so plans tuned on
    one substrate never steer another."""
    path = path or default_cache_path(getattr(cfg, "name", "alexnet"))
    if not os.path.exists(path):
        return {}
    cache = PlanCache.load(path)
    plans = {}
    for name, spec, in_shape, _ in alexnet_layer_geometries(cfg, batch):
        k = plan_key(spec, in_shape, dtype=cfg.dtype, interpret=interpret)
        hit = cache.get(k, any_batch=any_batch)
        if hit is not None:
            plans[name] = hit
    return plans
