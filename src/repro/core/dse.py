"""Design-space exploration with analytical models (paper §4, eq. 2–7).

Two halves:

1. **Paper-faithful FPGA model** — equations 2–7 verbatim, with AlexNet layer
   dimensions, used by the benchmarks to reproduce Fig. 8 (throughput surface
   over C_vec x K_vec, optimum at 8x48), Table 2 (per-layer DSP efficiency)
   and the 1020 img/s headline (Fig. 9 applies the paper's measured 16%
   system overhead).  This is the reproduction *baseline*.

2. **TPU cost model** — the same methodology re-targeted: closed-form
   compute/HBM/ICI time estimates for LM train/prefill/decode cells over a
   (data, model) mesh, grid-searched over the free knobs.  Validated against
   the compiled-HLO roofline terms (Fig. 9 analog: model vs "measured").
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Iterable

from .roofline import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from .winograd import winograd_transform

# ---------------------------------------------------------------------------
# 1. Paper-faithful model (eq. 2-7)
# ---------------------------------------------------------------------------
# AlexNet (Krizhevsky) conv dims incl. groups (conv2/4/5 are 2-group convs)
ALEXNET_CONV = [
    # name   C    K    P   Q   R   S  stride groups
    ("conv1", 3, 96, 55, 55, 11, 11, 4, 1),
    ("conv2", 96, 256, 27, 27, 5, 5, 1, 2),
    ("conv3", 256, 384, 13, 13, 3, 3, 1, 1),
    ("conv4", 384, 384, 13, 13, 3, 3, 1, 2),
    ("conv5", 384, 256, 13, 13, 3, 3, 1, 2),
]
ALEXNET_FC = [
    # name    C(in)  K(out)
    ("fc6", 9216, 4096),
    ("fc7", 4096, 4096),
    ("fc8", 4096, 1000),
]
# feature map sizes feeding each conv layer (for stream buffer M20K model)
ALEXNET_FEATURES = [
    ("conv1", 3, 227, 227, 96, 55, 55),
    ("conv2", 96, 27, 27, 256, 27, 27),
    ("conv3", 256, 13, 13, 384, 13, 13),
    ("conv4", 384, 13, 13, 384, 13, 13),
    ("conv5", 384, 13, 13, 256, 13, 13),
]

A10_1150_DSPS = 1518
A10_1150_M20K = 2713


@dataclass(frozen=True)
class DLAConfig:
    c_vec: int = 8
    k_vec: int = 48
    q_vec: int = 4
    w_vec: int = 6
    l_w: int = 1
    l_h: int = 3
    fmax_hz: float = 303e6
    winograd: bool = True
    s_batch: int | None = None        # None -> K_vec * 2 (paper)
    ddr_bytes_per_cycle: float = 64.0


def n_dsps(cfg: DLAConfig) -> float:
    """Equation 2 (+ Winograd halving with the +200 constant)."""
    base = ((cfg.w_vec - cfg.q_vec + 1) * cfg.q_vec * cfg.k_vec
            * cfg.c_vec * 0.5)
    return base / 2 + 200 if cfg.winograd else base


def n_m20k_stream(cfg: DLAConfig, features=ALEXNET_FEATURES) -> float:
    """Equation 3: stream-buffer M20Ks for the worst layer."""
    n_banks = cfg.w_vec * cfg.c_vec
    worst = 0.0
    for (_, c, h, w, k, p, q) in features:
        depth_in = c * h * w / n_banks
        depth_out = k * p * q / n_banks
        worst = max(worst, depth_in + depth_out)
    return math.ceil(worst / (512 * 2)) * n_banks


def n_m20k_filter(cfg: DLAConfig) -> float:
    """Equation 4: filter-cache M20Ks."""
    return cfg.w_vec * cfg.c_vec * cfg.k_vec / 2


S_VEC = 3   # filter-tap vector width of the F(4,3) engine (W_vec = S_vec+Q_vec-1)


def _quant(x: int, step: int) -> float:
    """x useful slots out of ceil(x/step)*step provisioned."""
    return x / (math.ceil(x / step) * step)


def dsp_efficiency(layer, cfg: DLAConfig) -> float:
    """Equation 5's DSP_eff, extended with the quantization terms the paper
    applies implicitly (K tiling on K_vec, 5x5 taps on S_vec=3 chunks, conv1
    input folding): Q/P terms are the printed equation; the others are
    required to reproduce Table 2 (e.g. conv5 = 62.6%).
    """
    name, c, k, p, q, r, s, stride, groups = layer
    cg = c // groups
    qe = _quant(q, cfg.q_vec * cfg.l_w)
    pe = _quant(p, cfg.l_h)
    ke = _quant(k, cfg.k_vec)
    if name == "conv1":
        # paper folds 3 input maps x 11 taps into C_vec*S_vec-wide chunks
        taps = cg * r * s
        cse = _quant(taps, cfg.c_vec * S_VEC)
    else:
        cse = _quant(s, S_VEC) * _quant(cg, cfg.c_vec)
    return qe * pe * ke * cse


def _wino_mults_per_cycle(cfg: DLAConfig) -> float:
    """Winograd-domain multiplies per cycle: K_vec PEs x W_vec dot units x
    C_vec lanes (paper: 48*6*8 = 2304 @ 8x48)."""
    return cfg.k_vec * cfg.w_vec * cfg.c_vec


def conv_cycles(layer, nxt, cfg: DLAConfig) -> dict:
    """Equation 5 for one conv layer; ``nxt`` is the next conv layer whose
    filters are prefetched during this one (None for the last)."""
    name, c, k, p, q, r, s, stride, groups = layer
    eff = dsp_efficiency(layer, cfg)
    macs = k * (c // groups) * q * p * r * s
    n_mult = macs / 2 if cfg.winograd else macs   # F(4,3): 12 MACs -> 6 mults
    n_cycles = n_mult / (_wino_mults_per_cycle(cfg) * eff)
    if nxt is not None:
        _, cn, kn, _, _, rn, sn, _, gn = nxt
        byte_req = kn * rn * sn * (cn // gn) * 2
    else:
        byte_req = 0.0
    byte_ddr = cfg.ddr_bytes_per_cycle * n_cycles
    n_real = n_cycles * max(1.0, byte_req / byte_ddr) if byte_ddr else n_cycles
    return {"name": name, "cycles": n_real, "ideal_cycles": n_cycles,
            "dsp_eff": eff, "flops": 2 * macs, "winograd": cfg.winograd}


def fc_cycles(layer, cfg: DLAConfig) -> dict:
    """Equation 6 for one FC layer (whole batch); no Winograd, engine runs
    K_vec*W_vec*C_vec MACs/cycle with features cached / filters streamed."""
    name, c, k = layer
    s_batch = cfg.s_batch or cfg.k_vec * 2
    macs = k * c * s_batch
    n_cycles = macs / _wino_mults_per_cycle(cfg)
    byte_req = c * k * 2
    byte_ddr = cfg.ddr_bytes_per_cycle * n_cycles
    n_real = n_cycles * max(1.0, byte_req / byte_ddr)
    return {"name": name, "cycles": n_real, "ideal_cycles": n_cycles,
            "flops": 2 * macs, "s_batch": s_batch}


def alexnet_throughput(cfg: DLAConfig, *, system_overhead: float = 0.0) -> dict:
    """Equation 7: img/s for AlexNet + per-layer detail (Table 2 analog)."""
    convs = [conv_cycles(ALEXNET_CONV[i],
                         ALEXNET_CONV[i + 1] if i + 1 < len(ALEXNET_CONV) else None,
                         cfg)
             for i in range(len(ALEXNET_CONV))]
    fcs = [fc_cycles(l, cfg) for l in ALEXNET_FC]
    s_batch = cfg.s_batch or cfg.k_vec * 2
    total_cycles = (sum(c["cycles"] for c in convs)
                    + sum(f["cycles"] / s_batch for f in fcs))
    img_s = cfg.fmax_hz / total_cycles * (1.0 - system_overhead)
    flops_per_img = (sum(c["flops"] for c in convs)
                     + sum(f["flops"] / f["s_batch"] for f in fcs))
    # per-layer achieved GFLOPS at this throughput (actual; effective = *2 for
    # winograd layers)
    layers = []
    for c in convs:
        gf = c["flops"] * cfg.fmax_hz / c["cycles"] / 1e9
        layers.append({"name": c["name"], "act_gflops": gf / (2 if c["winograd"] else 1),
                       "eff_gflops": gf if c["winograd"] else gf,
                       "dsp_eff": c["dsp_eff"]})
    for f in fcs:
        gf = f["flops"] * cfg.fmax_hz / f["cycles"] / 1e9
        layers.append({"name": f["name"], "act_gflops": gf, "eff_gflops": gf,
                       "dsp_eff": f["ideal_cycles"] / f["cycles"]})
    return {"img_per_s": img_s, "total_cycles": total_cycles,
            "gflops_per_img": flops_per_img / 1e9, "layers": layers,
            "effective_gflops": flops_per_img * img_s / 1e9}


def fits_device(cfg: DLAConfig, dsps=A10_1150_DSPS, m20ks=A10_1150_M20K) -> bool:
    return (n_dsps(cfg) <= dsps and
            n_m20k_stream(cfg) + n_m20k_filter(cfg) <= m20ks)


def explore_fpga(c_vecs: Iterable[int] = (2, 4, 8, 16),
                 k_vecs: Iterable[int] = tuple(range(8, 129, 8))) -> list:
    """Fig. 8: sweep (C_vec, K_vec), 0 throughput where infeasible/odd."""
    rows = []
    for c in c_vecs:
        for k in k_vecs:
            cfg = DLAConfig(c_vec=c, k_vec=k)
            if k % c != 0 or not fits_device(cfg):
                rows.append({"c_vec": c, "k_vec": k, "img_per_s": 0.0})
                continue
            r = alexnet_throughput(cfg)
            rows.append({"c_vec": c, "k_vec": k, "img_per_s": r["img_per_s"]})
    return rows


# ---------------------------------------------------------------------------
# 2. TPU cost model (same methodology, new resources)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class TPUModelInput:
    n_active: float          # active matmul params per token
    n_total: float           # total params (streamed bytes in decode)
    seq_len: int
    global_batch: int
    kind: str                # train | prefill | decode
    d_model: int
    num_layers: int
    cache_bytes_per_token: float = 0.0


def lm_cost(inp: TPUModelInput, *, data: int, model: int, pod: int = 1,
            dtype_bytes: int = 2, grad_compress: float = 1.0) -> dict:
    """Closed-form roofline terms (seconds) — the TPU analog of eq. 5-7.

    grad_compress < 1 models BFP-compressed gradient reduce-scatter.
    """
    chips = data * model * pod
    tokens = (inp.global_batch if inp.kind == "decode"
              else inp.seq_len * inp.global_batch)
    mult = 6.0 if inp.kind == "train" else 2.0
    flops = mult * inp.n_active * tokens
    t_compute = flops / (chips * PEAK_FLOPS_BF16)

    if inp.kind == "decode":
        # weight streaming dominates (paper's FC regime): every step reads
        # all (model-sharded) weights + the KV cache slice
        hbm = (inp.n_total * dtype_bytes / model
               + inp.cache_bytes_per_token * inp.seq_len
               * inp.global_batch / chips)
        t_mem = hbm / HBM_BW
    else:
        # activations + weights per step per device
        act = tokens * inp.d_model * dtype_bytes * inp.num_layers * 4 / chips
        hbm = inp.n_total * dtype_bytes / model + act
        t_mem = hbm / HBM_BW

    # collectives: TP all-reduce of layer outputs (2/layer fwd, 2 bwd) +
    # DP gradient reduce-scatter+all-gather
    act_bytes = tokens * inp.d_model * dtype_bytes / (data * pod)
    tp_coll = (2 * (3 if inp.kind == "train" else 1) * inp.num_layers
               * act_bytes * 2 * (model - 1) / max(model, 1))
    dp_coll = 0.0
    if inp.kind == "train" and data * pod > 1:
        g = data * pod
        dp_coll = (2 * inp.n_total * 4 / model) * (g - 1) / g * grad_compress
    t_coll = (tp_coll + dp_coll) / ICI_BW
    step = max(t_compute, t_mem, t_coll)
    return {"t_compute": t_compute, "t_memory": t_mem, "t_collective": t_coll,
            "step_time": step,
            "bound": max((("compute", t_compute), ("memory", t_mem),
                          ("collective", t_coll)), key=lambda kv: kv[1])[0],
            "throughput_tokens_s": tokens / step if step else 0.0,
            "mfu": flops / (step * chips * PEAK_FLOPS_BF16) if step else 0.0}


def explore_tpu(inp: TPUModelInput, chips: int = 256,
                pods: int = 1) -> list[dict]:
    """Sweep (data, model) factorizations — Fig. 8 analog on TPU."""
    rows = []
    m = 1
    while m <= chips:
        if chips % m == 0:
            r = lm_cost(inp, data=chips // m, model=m, pod=pods)
            rows.append(dict(r, data=chips // m, model=m))
        m *= 2
    return rows


def decode_batch_curve(inp: TPUModelInput, *, data: int, model: int,
                       batches=(1, 2, 4, 8, 16, 32, 64, 128, 256)) -> list:
    """Paper §3.7 reproduction in the decode regime: tokens/s vs batch
    saturates when compute time overtakes weight-streaming time (the FC
    batching curve, eq. 6's BYTE_req/BYTE_ddr crossover)."""
    import dataclasses as dc
    rows = []
    for b in batches:
        r = lm_cost(dc.replace(inp, global_batch=b), data=data, model=model)
        rows.append(dict(r, batch=b))
    return rows


def winograd_speedup(r: int = 3, m: int = 4) -> float:
    return winograd_transform(m, r).mult_ratio
