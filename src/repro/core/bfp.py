"""Shared-exponent block floating point (paper §3.6), TPU-adapted.

The paper aligns a broadcast group of FP16 values to the group's maximum
exponent so the Arria-10 DSP can multiply them as 18-bit fixed point.  On TPU
the MXU natively does bf16, so the *compute* motivation disappears — but the
*bandwidth* motivation gets stronger: int8 mantissas + one exponent per block
is ~1.9x fewer bytes than bf16.  We use it where bytes are the binding
constraint:

  * weight streaming in the decode/FC path (kernels/bfp_matmul),
  * gradient reduce-scatter compression (parallel/collectives.bfp_*).

Quantization: per block of ``block`` values along the chosen axis,
  e      = exponent of max|x|   (power of two, like the paper)
  q      = clip(round(x * 2^(bits-1-e)), -(2^(bits-1)-1), 2^(bits-1)-1)
  dequant= q * 2^(e-(bits-1))
Max absolute error per element is 3*2^(e-bits) (half a quantization step of
rounding + up to one step of clipping at the block max), i.e. relative to
the block max: <= 3*2^-bits — the property test asserts this.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _block_reshape(x, block: int, axis: int):
    axis = axis % x.ndim
    n = x.shape[axis]
    assert n % block == 0, f"axis size {n} not divisible by block {block}"
    newshape = x.shape[:axis] + (n // block, block) + x.shape[axis + 1:]
    return x.reshape(newshape), axis


def quantize(x, *, block: int = 32, bits: int = 8, axis: int = -1):
    """-> (mantissa int8/int16, exponent int8 per block, blocked axis)."""
    xb, axis = _block_reshape(x.astype(jnp.float32), block, axis)
    amax = jnp.max(jnp.abs(xb), axis=axis + 1, keepdims=True)
    # exponent of max: amax = f * 2^e with f in [0.5, 1)
    _, e = jnp.frexp(jnp.where(amax > 0, amax, 1.0))
    e = jnp.where(amax > 0, e, 0).astype(jnp.int32)
    qmax = 2 ** (bits - 1) - 1
    scale = jnp.exp2((bits - 1.0) - e.astype(jnp.float32))
    m = jnp.clip(jnp.round(xb * scale), -qmax, qmax)
    mdtype = jnp.int8 if bits <= 8 else jnp.int16
    return m.astype(mdtype), jnp.squeeze(e, axis=axis + 1).astype(jnp.int8), axis


def dequantize(m, e, *, bits: int = 8, axis: int | None = None):
    """Inverse of :func:`quantize`; axis = blocked axis (of the block pair)."""
    if axis is None:
        axis = m.ndim - 2
    scale = jnp.exp2(e.astype(jnp.float32) - (bits - 1.0))
    x = m.astype(jnp.float32) * jnp.expand_dims(scale, axis + 1)
    shape = x.shape[:axis] + (x.shape[axis] * x.shape[axis + 1],) + x.shape[axis + 2:]
    return x.reshape(shape)


def quantize_dequantize(x, *, block: int = 32, bits: int = 8, axis: int = -1):
    m, e, ax = quantize(x, block=block, bits=bits, axis=axis)
    return dequantize(m, e, bits=bits, axis=ax)


@functools.partial(jax.jit, static_argnames=("block", "bits"))
def bfp_matmul(x, w, *, block: int = 32, bits: int = 8):
    """(M,K) @ (K,N) with both operands quantized per K-block.

    Pure-jnp emulation of the shared-exponent dot product: int mantissa
    multiply, int32 accumulate within a block, f32 rescale across blocks —
    exactly the paper's DSP dataflow (18x18 int multiplies, exponent
    reapplied after the dot product).  The Pallas kernel in
    ``kernels/bfp_matmul`` implements the same contract.
    """
    mx, ex, _ = quantize(x, block=block, bits=bits, axis=1)    # (M,KB,B)
    mw, ew, _ = quantize(w, block=block, bits=bits, axis=0)    # (KB,B,N)
    if bits <= 8:
        # int8 x int8 -> int32 MAC is exact for blocks up to 2^15 long
        acc = jnp.einsum("mkb,kbn->mkn", mx.astype(jnp.int32),
                         mw.astype(jnp.int32)).astype(jnp.float32)
    else:
        # 16-bit mantissa products overflow int32 accumulation; f32 MAC is
        # exact to 2^-24 relative, far below the 2^-15 mantissa error
        acc = jnp.einsum("mkb,kbn->mkn", mx.astype(jnp.float32),
                         mw.astype(jnp.float32))
    scale = jnp.exp2(ex.astype(jnp.float32)[:, :, None]
                     + ew.astype(jnp.float32)[None, :, :]
                     - 2.0 * (bits - 1.0))                     # (M,KB,N)
    return jnp.sum(acc * scale, axis=1)


def quantize_linear_tree(params, *, block: int = 64, bits: int = 8,
                         min_size: int = 1 << 16):
    """Serving-time weight compression (paper §3.6 applied to the decode
    weight stream): every large 2D linear weight {"w": (K, N)} becomes
    {"w_q": int8 (KB, block, N), "w_e": int8 (KB, N)}; ``nn.layers.linear``
    dequantizes transparently.  HBM traffic per decode step drops ~4x vs
    f32 (and ~2x vs bf16) for weight-dominated steps."""
    import numpy as np

    QKEYS = ("w", "w1", "w2", "w3")   # linears + (stacked) expert weights

    def quantizable(v):
        return (hasattr(v, "ndim") and v.ndim in (2, 3, 4) and
                hasattr(v, "dtype") and
                jnp.issubdtype(v.dtype, jnp.floating) and
                int(np.prod(v.shape)) >= min_size and
                v.shape[-2] % block == 0)

    def walk(node):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                if k in QKEYS and quantizable(v):
                    m, e, _ = quantize(v, block=block, bits=bits,
                                       axis=v.ndim - 2)
                    out[k + "_q"] = m
                    out[k + "_e"] = e
                else:
                    out[k] = walk(v)
            return out
        if isinstance(node, list):
            return [walk(v) for v in node]
        return node

    return walk(params)


def dequantize_linear(p, key: str = "w", *, bits: int = 8):
    """Reassemble the (.., K, N) f32 weight from a quantized param dict."""
    m = p[key + "_q"]
    return dequantize(m, p[key + "_e"], bits=bits, axis=m.ndim - 3)


def weight_of(p, key: str = "w", dtype=None):
    """Raw or dequantized weight from a (possibly BFP-compressed) dict."""
    w = dequantize_linear(p, key) if key + "_q" in p else p[key]
    return w.astype(dtype) if dtype is not None else w


def error_bound(e, *, bits: int = 8):
    """Per-element max abs quantization error given block exponents:
    half a step from rounding plus up to one step from clipping the block
    max at 2^(bits-1)-1 -> 1.5 * 2^(e-(bits-1)) = 3 * 2^(e-bits)."""
    return 3.0 * jnp.exp2(e.astype(jnp.float32) - bits)
