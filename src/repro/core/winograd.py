"""General Cook–Toom Winograd transforms F(m, r) — paper §3.3, generalized.

The paper hardcodes F(4,3) for AlexNet's 3x3 convolutions.  We generate
transform matrices for ANY small (m, r) via the Toom-Cook construction
(beyond-paper: this gives F(3,4) for Mamba2's k=4 depthwise conv and F(2,3)/
F(4,3) for 3x3 CNN layers from one code path):

    o = A^T [ (G g) ⊙ (B^T d) ]          (1D, n = m + r - 1 products)
    O = A^T [ (G g G^T) ⊙ (B^T D B) ] A  (2D, nested)

Construction: evaluation points {0, ±1, ±2, ±1/2, ...} plus the point at
infinity give Vandermonde matrices V_k (n x k).  G = V_r and A^T = V_m^T up
to the infinity-row convention; rather than chase sign conventions we solve
for B^T exactly from the bilinear identity

    Σ_t A^T[j,t] G[t,k] B^T[t,i] = [i == j + k]

(least squares in float64; the residual is checked to ~1e-10, so the
returned transform is *verified by construction*).

Arithmetic-complexity accounting (paper Table 2's "effective vs actual
GFLOPS") is exposed via ``mult_ratio``: direct m*r multiplies per tile vs
n = m+r-1 Winograd-domain multiplies, e.g. F(4,3): 12 -> 6 (the paper's 2x).
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np

# good default point sets (wincnn-style), indexed by number of finite points
_POINTS = [0.0, 1.0, -1.0, 2.0, -2.0, 0.5, -0.5, 3.0, -3.0, 1.5, -1.5]


@dataclass(frozen=True)
class WinogradTransform:
    m: int                 # outputs per tile
    r: int                 # filter taps
    AT: np.ndarray         # (m, n)
    G: np.ndarray          # (n, r)
    BT: np.ndarray         # (n, n)

    @property
    def n(self) -> int:
        return self.m + self.r - 1

    @property
    def mult_ratio(self) -> float:
        """direct multiplies / winograd multiplies per 1D tile."""
        return (self.m * self.r) / self.n


def _vandermonde(points, k: int) -> np.ndarray:
    """(len(points)+1, k): rows eval poly of deg k-1 at points; last row = ∞
    (leading-coefficient selector)."""
    rows = [[p ** j for j in range(k)] for p in points]
    rows.append([0.0] * (k - 1) + [1.0])
    return np.asarray(rows, dtype=np.float64)


@lru_cache(maxsize=None)
def winograd_transform(m: int, r: int) -> WinogradTransform:
    n = m + r - 1
    assert 2 <= m and 2 <= r and n - 1 <= len(_POINTS), (m, r)
    pts = _POINTS[: n - 1]
    G = _vandermonde(pts, r)                    # (n, r)
    AT = _vandermonde(pts, m).T                 # (m, n)

    # Solve for B^T from the bilinear identity (exact; verified below).
    # M[(j,k), t] = AT[j,t] * G[t,k]; target T[(j,k), i] = [i == j+k]
    M = np.einsum("jt,tk->jkt", AT, G).reshape(m * r, n)
    T = np.zeros((m, r, n))
    for j in range(m):
        for k in range(r):
            T[j, k, j + k] = 1.0
    T = T.reshape(m * r, n)
    BT, res, rank, _ = np.linalg.lstsq(M, T, rcond=None)
    # verify the algorithm end-to-end on random data
    rng = np.random.default_rng(0)
    g = rng.standard_normal((r,))
    d = rng.standard_normal((n,))
    o = AT @ ((G @ g) * (BT @ d))
    o_ref = np.array([np.dot(g, d[j:j + r]) for j in range(m)])
    err = np.abs(o - o_ref).max() / max(np.abs(o_ref).max(), 1e-9)
    assert err < 1e-8, f"F({m},{r}) construction failed: rel err {err}"
    return WinogradTransform(m, r, AT, G, BT)


# ---------------------------------------------------------------------------
# pure-jnp convolutions in the Winograd domain (oracles + laptop path;
# repro.kernels.conv holds the Pallas TPU kernels)
# ---------------------------------------------------------------------------
def _tiles_1d(x, m: int, n: int, r: int):
    """x (B, L, C) -> causal overlapping tiles (B, nt, n, C), nt = ceil(L/m)."""
    B, L, C = x.shape
    nt = -(-L // m)
    xp = jnp.pad(x, ((0, 0), (r - 1, nt * m - L + (n - m) - (r - 1)), (0, 0)))
    idx = (jnp.arange(nt) * m)[:, None] + jnp.arange(n)[None, :]
    return jnp.take(xp, idx, axis=1)            # (B, nt, n, C)


def conv1d_depthwise_causal(x, w, b=None, m: int | None = None):
    """Winograd depthwise causal conv.  x (B,L,C); w (r,C); returns (B,L,C).

    Output o[t, c] = sum_k w[k, c] * x[t - r + 1 + k, c]  (left-padded).
    """
    r = w.shape[0]
    m = m or {3: 4, 4: 3}.get(r, 2)
    t = winograd_transform(m, r)
    B, L, C = x.shape
    tiles = _tiles_1d(x, t.m, t.n, r)
    BTj = jnp.asarray(t.BT, x.dtype)
    Gj = jnp.asarray(t.G, x.dtype)
    ATj = jnp.asarray(t.AT, x.dtype)
    U = jnp.einsum("tn,bjnc->bjtc", BTj, tiles)
    V = jnp.einsum("tr,rc->tc", Gj, w.astype(x.dtype))
    Y = jnp.einsum("mt,bjtc->bjmc", ATj, U * V[None, None])
    y = Y.reshape(B, -1, C)[:, :L]
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def _tiles_2d(x, m: int, n: int):
    """x (B,H,W,C) pre-padded -> (B, th, tw, n, n, C); stride m windows."""
    B, H, W, C = x.shape
    th = (H - n) // m + 1
    tw = (W - n) // m + 1
    ih = (jnp.arange(th) * m)[:, None] + jnp.arange(n)[None, :]
    iw = (jnp.arange(tw) * m)[:, None] + jnp.arange(n)[None, :]
    xt = jnp.take(x, ih, axis=1)                # (B, th, n, W, C)
    xt = jnp.take(xt, iw, axis=3)               # (B, th, n, tw, n, C)
    return xt.transpose(0, 1, 3, 2, 4, 5)       # (B, th, tw, n, n, C)


def _conv2d_winograd_single(x, w, b, *, m: int, padding: str, relu: bool):
    r = w.shape[0]
    t = winograd_transform(m, r)
    B, H, W, C = x.shape
    K = w.shape[-1]
    if padding == "SAME":
        ph = pw = r // 2
        out_h, out_w = H, W
    else:  # VALID
        ph = pw = 0
        out_h, out_w = H - r + 1, W - r + 1
    th, tw = -(-out_h // t.m), -(-out_w // t.m)
    need_h = th * t.m + r - 1
    need_w = tw * t.m + r - 1
    xp = jnp.pad(x, ((0, 0), (ph, need_h - H - ph), (pw, need_w - W - pw),
                     (0, 0)))
    tiles = _tiles_2d(xp, t.m, t.n)             # (B,th,tw,n,n,C)

    BTj = jnp.asarray(t.BT, jnp.float32)
    Gj = jnp.asarray(t.G, jnp.float32)
    ATj = jnp.asarray(t.AT, jnp.float32)
    U = jnp.einsum("in,bhwnmc,jm->bhwijc", BTj, tiles.astype(jnp.float32), BTj)
    V = jnp.einsum("in,nmck,jm->ijck", Gj, w.astype(jnp.float32), Gj)
    Yw = jnp.einsum("bhwijc,ijck->bhwijk", U, V)   # n^2 batched GEMMs
    Y = jnp.einsum("pi,bhwijk,qj->bhwpqk", ATj, Yw, ATj)
    y = Y.transpose(0, 1, 3, 2, 4, 5).reshape(B, th * t.m, tw * t.m, K)
    y = y[:, :out_h, :out_w]
    if b is not None:
        y = y + b.astype(y.dtype)
    if relu:
        y = jnp.maximum(y, 0.0)
    return y.astype(x.dtype)


def conv2d_winograd(x, w, b=None, *, m: int = 4, padding: str = "SAME",
                    relu: bool = False, groups: int = 1, lrn=None, pool=None):
    """2D stride-1 convolution via F(m, r)xF(m, r), fused layer epilogue.

    x (B,H,W,C); w (r,r,C//groups,K).  The Winograd-domain multiply is
    expressed as n^2 independent (tiles x C) @ (C x K) matmuls (Lavin) — on
    TPU these are MXU-shaped GEMMs, the faithful analogue of the paper's PE
    dot products.  Signature mirrors the Pallas kernel
    (``repro.kernels.conv.winograd.conv2d_winograd``): optional bias ``b (K,)``,
    fused ``relu``, ``groups`` as a batched vmap (no Python loop), plus the
    layer epilogue — cross-channel LRN (``lrn``: LrnParams) then VALID
    max-pool (``pool``: (window, stride)) — so the routes stay numerically
    interchangeable.  LRN runs *after* group reassembly: its window spans
    the full concatenated channel dim, including across group seams.
    """
    assert w.shape[0] == w.shape[1], "square filters only"
    if groups == 1:
        y = _conv2d_winograd_single(x, w, b, m=m, padding=padding, relu=relu)
    else:
        g = groups
        r = w.shape[0]
        B, H, W, Ct = x.shape
        K = w.shape[-1] // g
        C = Ct // g
        xg = jnp.moveaxis(x.reshape(B, H, W, g, C), 3, 0)    # (g,B,H,W,C)
        wg = jnp.moveaxis(w.reshape(r, r, C, g, K), 3, 0)    # (g,r,r,C,K)
        bg = None if b is None else b.reshape(g, K)
        f = functools.partial(_conv2d_winograd_single, m=m, padding=padding,
                              relu=relu)
        yg = jax.vmap(f, in_axes=(0, 0, None if bg is None else 0))(xg, wg,
                                                                    bg)
        y = jnp.moveaxis(yg, 0, 3).reshape(B, *yg.shape[2:4], g * K)
    if lrn is not None or pool is not None:
        # function-level import: nn.pooling sits above core in the package
        # graph (nn.conv imports this module at import time)
        from ..nn.pooling import apply_epilogue
        y = apply_epilogue(y, lrn, pool)
    return y


def conv2d_direct(x, w, *, stride: int = 1, padding: str = "SAME"):
    """lax direct conv (oracle / non-Winograd layers like AlexNet conv1)."""
    return jax.lax.conv_general_dilated(
        x.astype(jnp.float32), w.astype(jnp.float32),
        window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC")).astype(x.dtype)


def auto_c_block(hp: int, wp: int, c: int, *, batch: int = 1,
                 dtype_bytes: int = 4,
                 budget_bytes: int = 8 * 2 ** 20) -> int:
    """Channel block auto-sizing shared by the kernels and the HBM model.

    Largest channel block (<= ``c``) whose *whole resident input block*
    (batch, hp, wp, Cb) — the filter-cache grid keeps ``batch_block``
    images' slabs in VMEM at once — fits the slab budget.  Every AlexNet
    layer gets all of C resident even at batch_block=8 — the slab then
    streams HBM->VMEM exactly once per image, with no re-fetch over the
    channel-block reduction (paper §3.5: stream buffers hold whole
    feature-map planes).  VGG-class 224x224 planes fall back to a smaller
    block (the re-fetch trade documented in ``conv2d_hbm_bytes``).
    """
    per_chan = max(batch * hp * wp * dtype_bytes, 1)
    fit = max(int(budget_bytes // per_chan), 1)
    return c if fit >= c else max(min(fit, 128), 1)


def auto_pool_rows(ph_out: int, pwin: int, ps: int, *, align: int = 1,
                   row_align: int = 1, cols: int, kfull: int, batch: int = 1,
                   dtype_bytes: int = 4,
                   budget_bytes: int = 4 * 2 ** 20) -> int:
    """Pooled-row block auto-sizing shared by the kernels and the HBM model.

    Largest ``align``-multiple pooled-row block whose full-channel epilogue
    scratch (batch, conv rows, cols, kfull) fits the budget — ideally the
    whole pooled extent, so the row loop collapses to one step and a
    grouped layer's slab is never re-fetched (the grouped block index
    cycles per row block; see ``conv2d_hbm_bytes``).  ``row_align`` rounds
    the conv rows up to the Winograd tile size where applicable.
    """
    Pb = align * (-(-max(ph_out, 1) // align))
    while Pb > align:
        rows = -(-(ps * (Pb - 1) + pwin) // row_align) * row_align
        if batch * rows * cols * kfull * dtype_bytes <= budget_bytes:
            break
        Pb -= align
    return Pb


def conv2d_hbm_bytes(B: int, H: int, W: int, C: int, K: int, r: int,
                     m: int | None, *, dtype_bytes: int = 4,
                     c_block: int | None = None, k_block: int = 128,
                     row_block: int = 8, pool_row_block: int | None = None,
                     padding: str = "SAME", stride: int = 1,
                     relu: bool = True, fuse_lrn: bool = False,
                     fuse_pool: bool = False, pool_window: int = 3,
                     pool_stride: int = 2, groups: int = 1,
                     route: str = "pallas", batch_block: int = 8,
                     weight_prefetch: bool = True,
                     row_parallel: bool = False) -> dict:
    """Modeled HBM traffic for one conv *layer*, per resolved datapath.

    ``route`` is the resolved datapath (``nn.conv.resolve_kernel`` family):

    * ``"pallas"`` — the stream-buffered kernels.  ``m`` set models the
      Winograd kernel's halo-padded tile slab; ``m=None`` models the
      strided *direct* kernel (AlexNet conv1's 11x11 s4, conv2's 5x5): a
      ``(npr-1)*s*ps*Pb + s*(Rc-1)+r`` row slab at width ``s*(out_w-1)+r``
      — the strided-fused layer terms.  Fusion flags are honored
      *in-kernel*, so the fused layer writes only the final map.
    * ``"winograd"`` — the pure-jnp path: the overlapping-tile tensor
      (B, th, tw, n, n, C) is materialized in HBM by an XLA gather (written
      once, read once) on top of the raw read — the ~(n/m)^2 inflation of
      §3.5.  No on-chip fusion: fused == unfused.
    * ``"direct"`` / ``"lax"`` — ``lax.conv``: raw read once.  The
      in-function epilogue is separate XLA reduce ops, so no fusion credit:
      fused == unfused.

    Input re-fetch (pallas): with one channel block (``c_block=None``
    auto-sizes so AlexNet layers qualify) and no groups, the slab block
    index is constant across the (row, k) revisits and Pallas elides the
    repeated DMA; grouped layers cycle each group's slab once per row
    block, and multiple c blocks re-stream the slab per
    (row-block, k-block) revisit.

    Output side — the unfused baseline is the paper's strawman (§3.5: in
    prior work "the output of each stage goes to DDR and back"): conv
    writes the full-resolution map, bias+ReLU / LRN each read+rewrite it,
    pool reads it and writes the pooled map.  Fused (pallas), only the
    final normalized/pooled map is written once.

    Weight side (reported separately from the layer totals, which count
    feature maps only): the batch-innermost filter-cache grid fetches each
    weight tile once per ``batch_block`` images; ``weight_hbm_nocache_bytes``
    is the batch-outermost grid's once-per-image stream for comparison.
    The manual-DMA double-buffered stream (``kernels/conv/dma.py``) splits
    the fetched bytes into *exposed* vs *prefetch-hidden*: with
    ``weight_prefetch`` only each filter-cache generation's warmup tile
    (``weight_tile_bytes`` x batch-outer blocks; the stream restarts per
    generation so the batch grid dim stays parallel) is exposed — every
    later fetch is issued one transition early and overlaps MXU compute —
    while without it all ``weight_fetches`` synchronous copies stall the
    PEs
    (``weight_exposed_prefetch_bytes`` / ``weight_exposed_noprefetch_bytes``
    report both; ``weight_hbm_exposed_bytes`` follows the flag).  With
    ``row_parallel`` the multi-tile stream additionally restarts per *row
    block* (freeing the row grid dimension to run parallel), so one warmup
    tile is exposed per (batch-outer, row) block instead of per batch-outer
    block — the extra exposed bytes the autotuner weighs against the
    parallel row schedule.  Non-Pallas routes have no in-kernel stream:
    everything is exposed.

    Keys ``layer_unfused_bytes``/``layer_fused_bytes`` compare fused vs
    unfused *on this route*; ``layer_unfused_direct_bytes`` is the lax
    stagewise baseline every route is measured against (the benchmark's
    whole-network fused-pallas vs unfused-direct ratio).
    """
    g = groups
    if padding == "SAME":
        out_h, out_w = -(-H // stride), -(-W // stride)
    else:
        out_h = (H - r) // stride + 1
        out_w = (W - r) // stride + 1
    raw = B * H * W * C * dtype_bytes
    ph = max((out_h - pool_window) // pool_stride + 1, 0)
    pw = max((out_w - pool_window) // pool_stride + 1, 0)
    Cg, Kg = C // g, K // g                     # per-group extents

    Bb = max(1, min(batch_block, B))

    def _blocks(hp, wp):
        Cb = (auto_c_block(hp, wp, Cg, batch=Bb, dtype_bytes=dtype_bytes)
              if c_block is None else min(c_block, Cg))
        ncb = -(-Cg // Cb)
        Kb = min(k_block, Kg)
        nkb = Kg // Kb if Kg % Kb == 0 else 1   # kernel widens Kb to Kg
        return Cb, ncb, nkb

    def _wino_plan(with_pool):
        t = winograd_transform(m, r)
        tw = -(-out_w // t.m)
        if with_pool:
            q = t.m // math.gcd(pool_stride, t.m)
            if pool_row_block is None:
                Pb = auto_pool_rows(ph, pool_window, pool_stride, align=q,
                                    row_align=t.m, cols=tw * t.m, kfull=K,
                                    batch=Bb, dtype_bytes=dtype_bytes)
            else:
                Pb = q * (-(-max(min(pool_row_block, ph), 1) // q))
            row_step = pool_stride * Pb // t.m
            Rt = -(-(pool_stride * (Pb - 1) + pool_window) // t.m)
            npr = -(-max(ph, 1) // Pb)
            thp = (npr - 1) * row_step + Rt
        else:
            th = -(-out_h // t.m)
            Rt = min(row_block, th)
            npr = -(-th // Rt)
            thp = npr * Rt
        return thp * t.m + r - 1, tw * t.m + r - 1, npr

    def _direct_plan(with_pool):
        if with_pool:
            if pool_row_block is None:
                Pb = auto_pool_rows(ph, pool_window, pool_stride,
                                    cols=out_w, kfull=K, batch=Bb,
                                    dtype_bytes=dtype_bytes)
            else:
                Pb = max(min(pool_row_block, ph), 1)
            Rc = pool_stride * (Pb - 1) + pool_window
            step_in = stride * pool_stride * Pb
            npr = -(-max(ph, 1) // Pb)
        else:
            Rc = min(row_block, out_h)
            step_in = stride * Rc
            npr = -(-out_h // Rc)
        in_rows = stride * (Rc - 1) + r
        return (npr - 1) * step_in + in_rows, stride * (out_w - 1) + r, npr

    def _stream(with_pool):
        hp, wp, npr = (_wino_plan(with_pool) if m is not None
                       else _direct_plan(with_pool))
        Cb, ncb, nkb = _blocks(hp, wp)
        # the slab block index (k // nkb) * ncb + c is constant across every
        # step only when g == 1 and ncb == 1 (one fetch, DMA elided);
        # grouped layers cycle the group's slab per row block even with all
        # of C resident, and multiple c blocks re-stream per (row, k) revisit
        if ncb > 1:
            refetch = nkb * npr
        elif g > 1:
            refetch = npr
        else:
            refetch = 1
        return (B * hp * wp * (g * ncb * Cb) * dtype_bytes * refetch, npr,
                (Cb, ncb, nkb))

    # --- input side ---------------------------------------------------------
    if m is None:
        tile_tensor = 0
    else:
        t = winograd_transform(m, r)
        th, tw = -(-out_h // t.m), -(-out_w // t.m)
        tile_tensor = B * th * tw * t.n * t.n * C * dtype_bytes
    host_tiled = raw + 2 * tile_tensor          # read raw + write/read tiles
    if route == "pallas":
        stream, npr_f, blocks_f = _stream(fuse_pool)
        stream_unfused, npr_u, _ = _stream(False)
    elif route == "winograd":
        stream = stream_unfused = host_tiled
        npr_f = npr_u = 1
        blocks_f = None
    else:                                       # lax direct
        stream = stream_unfused = raw
        npr_f = npr_u = 1
        blocks_f = None

    # --- output side: stagewise strawman vs in-kernel fused -----------------
    conv_out = B * out_h * out_w * K * dtype_bytes
    pooled = B * ph * pw * K * dtype_bytes
    final = pooled if fuse_pool else conv_out
    stage_passes = (conv_out + (2 * conv_out if relu else 0)
                    + (2 * conv_out if fuse_lrn else 0)
                    + ((conv_out + pooled) if fuse_pool else 0))
    layer_unfused = stream_unfused + stage_passes
    layer_fused = (stream + final if route == "pallas" else layer_unfused)
    layer_unfused_direct = raw + stage_passes

    # --- weight side (filter cache + manual-DMA prefetch) -------------------
    wunit = (winograd_transform(m, r).n ** 2 if m is not None else r * r)
    weight_bytes = wunit * Cg * Kg * g * dtype_bytes
    Bo = -(-B // Bb)
    if route == "pallas":
        Cb, ncb, nkb = blocks_f
        Kb = Kg // nkb
        # the DMA moves whole padded tiles; one (wunit, Cb, Kb) tile per
        # (k, c) transition, the stream re-running per row block and per
        # filter-cache generation (batch-outer step) — except a
        # single-tile stream, which the kernels fetch once and keep
        # resident for the whole launch (dma.fetch_weight_tile)
        tile_bytes = wunit * Cb * Kb * dtype_bytes
        tiles = g * nkb * ncb
        fetches = tiles * npr_f * Bo if tiles > 1 else 1
        weight_hbm = tile_bytes * fetches
        weight_nocache = tile_bytes * (tiles * npr_f if tiles > 1 else 1) * B
        # double-buffered: only each stream generation's warmup tile is
        # exposed — one generation per batch-outer block (batch grid dim
        # stays parallel), times the row blocks when the row-parallel
        # restart is on; prefetch off exposes every fetch
        gens = Bo * (npr_f if row_parallel else 1)
        exposed_pref = tile_bytes * (gens if tiles > 1 else 1)
        exposed_nopref = weight_hbm
    else:
        weight_hbm = weight_nocache = weight_bytes
        tile_bytes = weight_bytes
        fetches = 1
        exposed_pref = exposed_nopref = weight_bytes
    weight_exposed = exposed_pref if weight_prefetch else exposed_nopref
    return {
        "route": route,
        "raw_bytes": raw,
        "host_tiled_bytes": host_tiled,
        "stream_bytes": stream,
        "stream_unfused_bytes": stream_unfused,
        "tile_inflation": tile_tensor / raw,
        "savings": host_tiled / stream,
        "conv_out_bytes": conv_out,
        "pooled_bytes": pooled,
        "final_out_bytes": final,
        "stage_pass_bytes": stage_passes,
        "layer_unfused_bytes": layer_unfused,
        "layer_fused_bytes": layer_fused,
        "layer_unfused_direct_bytes": layer_unfused_direct,
        "fused_savings": layer_unfused / layer_fused,
        "weight_bytes": weight_bytes,
        "weight_hbm_bytes": weight_hbm,
        "weight_hbm_nocache_bytes": weight_nocache,
        "filter_cache_reuse": weight_nocache / weight_hbm,
        "weight_tile_bytes": tile_bytes,
        "weight_fetches": fetches,
        "weight_exposed_prefetch_bytes": exposed_pref,
        "weight_exposed_noprefetch_bytes": exposed_nopref,
        "weight_hbm_exposed_bytes": weight_exposed,
        "weight_hbm_hidden_bytes": weight_hbm - weight_exposed,
    }


def conv_flops(h_out: int, w_out: int, c: int, k: int, r: int,
               winograd_m: int | None = None) -> tuple[int, int]:
    """(direct_madds, winograd_madds) for one image, paper Table 2 style."""
    direct = h_out * w_out * c * k * r * r
    if winograd_m is None:
        return direct, direct
    t = winograd_transform(winograd_m, r)
    tiles = -(-h_out // t.m) * (-(-w_out // t.m))
    wino = tiles * t.n * t.n * c * k
    return direct, wino
