"""Roofline-term extraction from compiled XLA artifacts (no silicon needed).

Three terms per (arch x shape x mesh), per the assignment:

  compute    = HLO_FLOPs / (chips * peak_FLOP/s)
  memory     = HLO_bytes / (chips * HBM_bw)
  collective = collective_bytes / (chips * link_bw)

``compiled.cost_analysis()`` yields FLOPs/bytes of the *partitioned*
(per-device) module; we rescale to the global convention the formulas above
expect (x chips) so both conventions are recorded explicitly.

collective_bytes is not in cost_analysis, so the (post-SPMD) HLO text is
parsed: for every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute we take the result shape (per-device) and convert to
*wire bytes per device* with ring-algorithm factors:

  all-gather:        R*(g-1)/g        (R = result bytes, g = group size)
  all-reduce:        2*R*(g-1)/g      (ring RS + AG)
  reduce-scatter:    R*(g-1)          (operand = R*g)
  all-to-all:        R*(g-1)/g
  collective-permute R
"""
from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass, field

# --- TPU v5e-class hardware constants (per assignment) ----------------------
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9\[\],\s{}()]*?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.I)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\})")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_WHILE_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[":{\s]+n["\s:]+(\d+)')
# "%name = TYPE op(args...)": TYPE parsed lazily up to the space before the
# op token (TYPE may be a tuple and contain parens/spaces itself).
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*?)\s([a-z][a-z0-9\-]*)\(")
_ARGS_RE = re.compile(r"%([\w.\-]+)")
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _split_computations(hlo_text: str):
    """-> (computation name -> list of op lines, entry computation name)."""
    comps: dict = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        m = _COMP_RE.match(line.strip())
        if m:
            cur = m.group(2)
            comps[cur] = []
            if m.group(1):
                entry = cur
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    if entry is None and comps:
        entry = list(comps)[-1]
    return comps, entry


def _dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None, ()
    dt, dims = m.groups()
    return dt, tuple(int(d) for d in dims.split(",") if d)


def analyze_hlo(hlo_text: str, default_trip: int = 1) -> dict:
    """Loop-aware static analysis of post-partitioning HLO text.

    XLA's ``compiled.cost_analysis()`` counts while bodies ONCE (measured:
    scan-over-layers FLOPs come out ~n_layers too small), so we re-derive:

      * per-computation execution multipliers: while bodies multiply by the
        ``known_trip_count`` backend_config (fallback ``default_trip``),
        nested loops compose multiplicatively via the call graph;
      * FLOPs: 2 * prod(result dims) * prod(lhs contracting dims) per dot;
      * HBM bytes: operand+result bytes of ops in non-fused computations
        (fusion bodies touch VMEM/registers, not HBM), with slice-aware
        special cases for dynamic-(update-)slice and zero-cost ops skipped;
      * collective wire bytes by kind (ring-algorithm factors).
    """
    comps, entry = _split_computations(hlo_text)

    # ---- symbol table: op name -> (dtype, dims) of its result -------------
    shapes = {}
    kinds = {}
    for lines in comps.values():
        for line in lines:
            m = _DEF_RE.match(line)
            if m:
                name, type_str, op = m.groups()
                shapes[name] = type_str
                kinds[name] = op

    # ---- call graph with multipliers ---------------------------------------
    fused: set = set()
    edges: dict = {c: [] for c in comps}          # comp -> [(callee, mult)]
    for cname, lines in comps.items():
        for line in lines:
            if " while(" in line:
                mb = _WHILE_BODY_RE.search(line)
                if mb:
                    mt = _TRIP_RE.search(line)
                    trip = int(mt.group(1)) if mt else default_trip
                    edges[cname].append((mb.group(1), trip))
                mc = re.search(r"condition=%?([\w.\-]+)", line)
                if mc:
                    edges[cname].append((mc.group(1), 1))
                continue
            for key in ("calls=", "to_apply=", "branch_computations={",
                        "true_computation=", "false_computation="):
                if key in line:
                    for callee in re.findall(key.rstrip("{") + r"\{?%?([\w.\-]+)",
                                             line):
                        edges[cname].append((callee, 1))
                        if "fusion(" in line:
                            fused.add(callee)

    mult = {c: 0.0 for c in comps}
    if entry in mult:
        mult[entry] = 1.0
    # propagate along the DAG (bounded passes; HLO has no recursion)
    for _ in range(64):
        changed = False
        new = {c: 0.0 for c in comps}
        new[entry] = 1.0
        for c in comps:
            for callee, m in edges[c]:
                if callee in new:
                    new[callee] += mult.get(c, 0.0) * m
        for c in comps:
            tot = new[c]
            if abs(tot - mult[c]) > 1e-9:
                changed = True
            mult[c] = tot
        if not changed:
            break

    # fusion bodies inherit "fused" through nested fusion calls
    frontier = list(fused)
    while frontier:
        c = frontier.pop()
        for callee, _ in edges.get(c, []):
            if callee not in fused:
                fused.add(callee)
                frontier.append(callee)

    SKIP_BYTES = {"get-tuple-element", "tuple", "parameter", "constant",
                  "bitcast", "while", "conditional", "after-all",
                  "opt-barrier"}

    _PARAM_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*.*?\sparameter\((\d+)\)")

    def _fusion_traffic(callee: str, result_bytes: int, fname: str) -> float:
        """HBM traffic of one fusion call: per-parameter slice-aware reads +
        root-aware writes (DUS roots are in-place; convert roots fuse into
        consumers on TPU)."""
        lines = comps.get(callee)
        if lines is None:
            return None
        params = {}
        for line in lines:
            mp = _PARAM_RE.match(line)
            if mp:
                params[mp.group(1)] = _shape_bytes(shapes.get(mp.group(1), ""))
        traffic = 0.0
        root_line = ""
        for line in lines:
            if re.match(r"^\s*ROOT\s", line):
                root_line = line
        for pname, pbytes in params.items():
            consumer = None
            for line in lines:
                if re.search(r"\(%" + re.escape(pname) + r"[,)]", line) or \
                   re.search(r",\s*%" + re.escape(pname) + r"[,)]", line):
                    consumer = line
                    break
            if consumer is not None:
                mc_ = _DEF_RE.match(consumer)
                cop = mc_.group(3) if mc_ else ""
                if cop == "dynamic-slice":
                    traffic += _shape_bytes(mc_.group(2))   # slice read only
                    continue
                if cop == "dynamic-update-slice":
                    args_ = _ARGS_RE.findall(consumer.split("(", 1)[1])
                    if args_ and args_[0] == pname:
                        continue                            # in-place buffer
                    traffic += 2 * pbytes                   # update r/w
                    continue
            traffic += pbytes
        if "dynamic-update-slice" in root_line:
            pass                                            # in-place write
        elif "convert" in fname and result_bytes > sum(params.values()):
            pass                                            # fuses on TPU
        else:
            traffic += result_bytes
        return traffic
    flops = 0.0
    bytes_hbm = 0.0
    coll = {"all-gather": 0.0, "all-reduce": 0.0, "reduce-scatter": 0.0,
            "all-to-all": 0.0, "collective-permute": 0.0, "count": 0,
            "in_loop_count": 0}

    for cname, lines in comps.items():
        m_c = mult.get(cname, 0.0)
        if m_c <= 0:
            continue
        in_fusion = cname in fused
        for line in lines:
            md = _DEF_RE.match(line)
            if not md:
                continue
            name, type_str, op = md.groups()
            # --- flops: dots (anywhere, incl. fusion bodies) ---------------
            if op == "dot":
                args = _ARGS_RE.findall(line.split("(", 1)[1])
                cd = _CDIMS_RE.search(line)
                _, rdims = _dims(type_str)
                lhs_dims = ()
                if args:
                    _, lhs_dims = _dims(shapes.get(args[0], ""))
                csize = 1
                if cd:
                    for i in cd.group(1).split(","):
                        if i and int(i) < len(lhs_dims):
                            csize *= lhs_dims[int(i)]
                f = 2.0
                for d in rdims:
                    f *= d
                flops += f * csize * m_c
            # --- collectives ------------------------------------------------
            kw = _line_wire_bytes(line)
            if kw is not None:
                kind, wire = kw
                # TPU-width projection: the CPU backend upcasts bf16 dot
                # inputs to f32, so collectives of convert-fusion outputs are
                # counted at the narrow source width (on TPU they stay bf16).
                args_c = _ARGS_RE.findall(line.split("(", 1)[1])
                if args_c:
                    src = args_c[0]
                    sdt, _ = _dims(shapes.get(src, ""))
                    if kinds.get(src) == "fusion" and sdt == "f32":
                        mcall2 = None
                        for l2 in lines:
                            if re.match(r"^\s*(?:ROOT\s+)?%" + re.escape(src)
                                        + r"\s*=", l2):
                                mcall2 = re.search(r"calls=%?([\w.\-]+)", l2)
                                break
                        if mcall2 and any(
                                ("bf16[" in pl and
                                 ("parameter(" in pl or " convert(" in pl))
                                for pl in comps.get(mcall2.group(1), [])):
                            wire *= 0.5
                coll[kind] += wire * m_c
                coll["count"] += 1
                if m_c > 1:
                    coll["in_loop_count"] += 1
            # --- bytes (non-fused computations only) -----------------------
            if in_fusion or op in SKIP_BYTES:
                continue
            rbytes = _shape_bytes(type_str)
            args = _ARGS_RE.findall(line.split("(", 1)[1])
            opbytes = [(_shape_bytes(shapes.get(a, "")), a) for a in args
                       if kinds.get(a) not in ("constant",)]
            total_ops = sum(b for b, _ in opbytes)
            if op == "dynamic-slice":
                bytes_hbm += 2 * rbytes * m_c
            elif op == "dynamic-update-slice":
                upd = total_ops - max((b for b, _ in opbytes), default=0)
                bytes_hbm += 2 * max(upd, 0) * m_c
            elif op == "fusion":
                callee = None
                mcall = re.search(r"calls=%?([\w.\-]+)", line)
                if mcall:
                    callee = mcall.group(1)
                t = _fusion_traffic(callee, rbytes, name) if callee else None
                bytes_hbm += (t if t is not None
                              else rbytes + total_ops) * m_c
            else:
                bytes_hbm += (rbytes + total_ops) * m_c

    return {"flops": flops, "bytes": bytes_hbm, "collectives": coll}


def _line_wire_bytes(line: str):
    m = _COLL_RE.search(line)
    if not m:
        return None
    kind = m.group(2).lower()
    rbytes = _shape_bytes(m.group(1))
    if rbytes == 0:
        rbytes = _shape_bytes(line.split("(", 1)[-1])
    g = _group_size(line)
    if kind == "all-gather":
        wire = rbytes * (g - 1) / max(g, 1)
    elif kind == "all-reduce":
        wire = 2 * rbytes * (g - 1) / max(g, 1)
    elif kind == "reduce-scatter":
        wire = rbytes * (g - 1)
    elif kind == "all-to-all":
        wire = rbytes * (g - 1) / max(g, 1)
    else:
        wire = rbytes
    return kind, wire


def collective_wire_bytes(hlo_text: str, loop_trip_count: int = 1) -> dict:
    """Per-device wire bytes by collective kind, from partitioned HLO text.

    Collectives inside ``while`` bodies (scan-over-layers and friends)
    execute once per iteration; ``loop_trip_count`` (the layer-group count)
    multiplies them.  Nested loops inside a while body inherit the same
    multiplier (under-counts deeper nesting; documented in EXPERIMENTS.md).
    """
    comps, _entry = _split_computations(hlo_text)
    # find while bodies (+ their transitive callees)
    loop_comps: set = set()
    for lines in comps.values():
        for line in lines:
            if " while(" in line or "= while(" in line or " while " in line:
                mb = _WHILE_BODY_RE.search(line)
                if mb:
                    loop_comps.add(mb.group(1))
    # transitive closure over called computations
    call_re = re.compile(r"(?:calls=|to_apply=|body=|condition=)%?([\w.\-]+)")
    frontier = list(loop_comps)
    while frontier:
        c = frontier.pop()
        for line in comps.get(c, []):
            for callee in call_re.findall(line):
                if callee not in loop_comps:
                    loop_comps.add(callee)
                    frontier.append(callee)

    out = {"all-gather": 0.0, "all-reduce": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0, "count": 0,
           "in_loop_count": 0}
    for name, lines in comps.items():
        mult = loop_trip_count if name in loop_comps else 1
        for line in lines:
            kw = _line_wire_bytes(line)
            if kw is None:
                continue
            kind, wire = kw
            out[kind] += wire * mult
            out["count"] += 1
            if mult > 1:
                out["in_loop_count"] += 1
    return out


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).strip("{}").split(","))
    return 2


@dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    # per-device (as reported by the partitioned module)
    flops_per_device: float
    hbm_bytes_per_device: float
    coll_bytes_per_device: float
    coll_breakdown: dict = field(default_factory=dict)
    peak_memory_bytes: float = 0.0
    # analytical reference
    model_flops: float = 0.0          # 6*N*D (dense) / 6*N_active*D (MoE)

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_device / ICI_BW

    @property
    def bound(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / global HLO FLOPs (catches remat/redundancy waste)."""
        tot = self.flops_per_device * self.chips
        return self.model_flops / tot if tot else 0.0

    @property
    def step_time(self) -> float:
        """Roofline step time: max of the three terms (overlap assumed)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute fraction of roofline: how close the *useful* work
        comes to peak if the step ran at the modeled step time."""
        if self.step_time == 0 or self.chips == 0:
            return 0.0
        useful_per_dev = self.model_flops / self.chips
        return useful_per_dev / (self.step_time * PEAK_FLOPS_BF16)

    def to_json(self) -> dict:
        d = asdict(self)
        d.update(t_compute=self.t_compute, t_memory=self.t_memory,
                 t_collective=self.t_collective, bound=self.bound,
                 useful_flops_ratio=self.useful_flops_ratio,
                 step_time=self.step_time,
                 roofline_fraction=self.roofline_fraction)
        return d


def from_compiled(compiled, hlo_text: str, *, arch: str, shape: str,
                  mesh: str, chips: int, model_flops: float,
                  loop_trip_count: int = 1) -> RooflineTerms:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    # loop-aware re-derivation (XLA's cost_analysis counts while bodies once)
    an = analyze_hlo(hlo_text, default_trip=loop_trip_count)
    coll = dict(an["collectives"])
    coll["xla_flops_raw"] = float(ca.get("flops", 0.0))
    coll["xla_bytes_raw"] = float(ca.get("bytes accessed", 0.0))
    coll_total = sum(v for k, v in an["collectives"].items()
                     if k not in ("count", "in_loop_count"))
    mem = 0.0
    try:
        ma = compiled.memory_analysis()
        mem = float(getattr(ma, "temp_size_in_bytes", 0) +
                    getattr(ma, "argument_size_in_bytes", 0) +
                    getattr(ma, "output_size_in_bytes", 0))
    except Exception:
        pass
    return RooflineTerms(
        arch=arch, shape=shape, mesh=mesh, chips=chips,
        flops_per_device=float(an["flops"]),
        hbm_bytes_per_device=float(an["bytes"]),
        coll_bytes_per_device=float(coll_total),
        coll_breakdown=coll,
        peak_memory_bytes=mem,
        model_flops=model_flops,
    )


# ---------------------------------------------------------------------------
# conv-layer roofline (paper Table 2/3 regime: one fused conv layer)
# ---------------------------------------------------------------------------
@dataclass
class ConvLayerRoofline:
    """Roofline terms for one fused conv layer, weight stream included.

    Memory time counts the modeled *fused* feature-map traffic plus only
    the **exposed** weight bytes — the §3.5 double-buffered manual-DMA
    stream hides ``weight_hidden_bytes`` under MXU compute, so those never
    contribute to the memory wall (the paper's "filters for the next layer
    are prefetched while the current layer is computed").  ``ai_total``
    is the classic arithmetic intensity over *all* moved bytes;
    ``ai_exposed`` is the effective intensity the PEs see once the
    prefetch hides the steady-state filter stream.
    """
    name: str
    flops: float                    # 2 * MACs for the layer (batch incl.)
    feature_bytes: float            # modeled fused feature-map HBM traffic
    weight_bytes: float             # total filter stream (cache-reused)
    weight_exposed_bytes: float     # fetches not hidden by the DMA overlap
    weight_prefetch: bool = True

    @property
    def weight_hidden_bytes(self) -> float:
        return self.weight_bytes - self.weight_exposed_bytes

    @property
    def total_bytes(self) -> float:
        return self.feature_bytes + self.weight_bytes

    @property
    def exposed_bytes(self) -> float:
        return self.feature_bytes + self.weight_exposed_bytes

    @property
    def ai_total(self) -> float:
        return self.flops / self.total_bytes if self.total_bytes else 0.0

    @property
    def ai_exposed(self) -> float:
        return self.flops / self.exposed_bytes if self.exposed_bytes else 0.0

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.exposed_bytes / HBM_BW

    @property
    def bound(self) -> str:
        return "compute" if self.t_compute >= self.t_memory else "memory"

    def to_json(self) -> dict:
        return {
            "name": self.name, "flops": self.flops,
            "feature_bytes": self.feature_bytes,
            "weight_bytes": self.weight_bytes,
            "weight_exposed_bytes": self.weight_exposed_bytes,
            "weight_hidden_bytes": self.weight_hidden_bytes,
            "weight_prefetch": self.weight_prefetch,
            "ai_total": self.ai_total, "ai_exposed": self.ai_exposed,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "bound": self.bound,
        }


def conv_layer_roofline(name: str, hbm: dict, *, flops: float,
                        weight_prefetch: bool = True) -> ConvLayerRoofline:
    """Build the layer roofline from a ``conv2d_hbm_bytes`` dict.

    ``hbm`` supplies the fused feature-map traffic
    (``layer_fused_bytes``), the filter-cache weight stream
    (``weight_hbm_bytes``), and the prefetch split
    (``weight_exposed_{prefetch,noprefetch}_bytes``); ``flops`` is the
    layer's 2*MACs on its actual datapath (``conv_flops``), batch
    included.
    """
    exposed = hbm["weight_exposed_prefetch_bytes" if weight_prefetch
                  else "weight_exposed_noprefetch_bytes"]
    return ConvLayerRoofline(
        name=name, flops=flops,
        feature_bytes=float(hbm["layer_fused_bytes"]),
        weight_bytes=float(hbm["weight_hbm_bytes"]),
        weight_exposed_bytes=float(exposed),
        weight_prefetch=weight_prefetch)


def network_conv_roofline(layers: list) -> dict:
    """Whole-network aggregate of :class:`ConvLayerRoofline` terms."""
    flops = sum(l.flops for l in layers)
    feat = sum(l.feature_bytes for l in layers)
    wtot = sum(l.weight_bytes for l in layers)
    wexp = sum(l.weight_exposed_bytes for l in layers)
    t_c = flops / PEAK_FLOPS_BF16
    t_m = (feat + wexp) / HBM_BW
    return {
        "flops": flops, "feature_bytes": feat, "weight_bytes": wtot,
        "weight_exposed_bytes": wexp, "weight_hidden_bytes": wtot - wexp,
        "ai_total": flops / (feat + wtot) if feat + wtot else 0.0,
        "ai_exposed": flops / (feat + wexp) if feat + wexp else 0.0,
        "t_compute": t_c, "t_memory": t_m,
        "bound": "compute" if t_c >= t_m else "memory",
    }


def model_flops_estimate(cfg, shape) -> float:
    """6*N*D with N = active params (excl. embeddings' readout is included
    as in common MFU practice: use all matmul params actually touched)."""
    from ..nn.module import count_params  # lazy
    n_active = active_param_count(cfg)
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def active_param_count(cfg) -> float:
    """Analytical active (per-token) matmul parameter count."""
    d, L, V = cfg.d_model, cfg.num_layers, cfg.vocab_size
    total = V * d  # embedding (readout counted below if untied)
    if not cfg.tie_embeddings:
        total += V * d
    for i in range(L):
        mixer, ffn = cfg.layer_kind(i)
        if mixer == "attn":
            if cfg.mla is not None:
                m = cfg.mla
                H = cfg.num_heads
                total += d * H * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                total += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                total += m.kv_lora_rank * H * (m.qk_nope_head_dim + m.v_head_dim)
                total += H * m.v_head_dim * d
            else:
                hd, H, KV = cfg.d_head, cfg.num_heads, cfg.num_kv_heads
                total += d * hd * (H + 2 * KV) + H * hd * d
        else:
            s = cfg.ssm
            di, G, N, Hs = cfg.d_inner, s.ngroups, s.d_state, cfg.ssm_heads
            total += d * (2 * di + 2 * G * N + Hs) + di * d
        if ffn == "mlp":
            mult = 3 if cfg.mlp_type == "swiglu" else 2
            total += mult * d * cfg.d_ff
        elif ffn == "moe":
            mo = cfg.moe
            total += d * mo.num_experts  # router
            total += 3 * d * mo.d_ff * (mo.top_k + mo.num_shared)
    if cfg.encoder_layers:
        hd, H = cfg.d_head, cfg.num_heads
        per_enc = d * hd * H * 4 + 2 * d * cfg.d_ff
        total += cfg.encoder_layers * per_enc
        # decoder cross-attention
        total += cfg.num_layers * (d * hd * H * 4)
    return float(total)
