"""GPipe-style pipeline parallelism via shard_map + ppermute.

Opt-in: the launcher can re-purpose the multi-pod "pod" axis (or a dedicated
"pipe" axis) as pipeline stages — inter-pod links carry only the (micro)batch
activations once per tick, which suits the low inter-pod bandwidth regime.

Schedule: plain GPipe fill-drain over T = M + S - 1 ticks (M microbatches,
S stages).  Bubble fraction = (S-1)/(M+S-1), reported by
:func:`bubble_fraction` and used in the DSE model when the pod axis is a
pipeline axis.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from .compat import shard_map


def bubble_fraction(n_micro: int, n_stages: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)


def pipeline_apply(fn: Callable, stage_params, x, *, mesh: Mesh,
                   axis: str = "pipe", n_micro: int | None = None):
    """Run ``y = fn(params_s, x)`` through S stages over microbatches.

    stage_params: pytree with leading stage axis S (sharded over ``axis``).
    x: (M, mb, ...) microbatched input (replicated).  fn must preserve the
    activation shape (residual-block stacks do).  Returns (M, mb, ...).
    """
    S = mesh.shape[axis]
    M = x.shape[0] if n_micro is None else n_micro
    T = M + S - 1

    pspec = jax.tree_util.tree_map(lambda _: P(axis), stage_params)

    def run(params, xs):
        # params: leading stage dim of size 1 (this stage's slice)
        params = jax.tree_util.tree_map(lambda a: a[0], params)
        sid = jax.lax.axis_index(axis)
        perm = [(i, i + 1) for i in range(S - 1)]

        def tick(carry, t):
            buf, outs = carry                       # buf: (mb, ...) in transit
            mb_idx = jnp.clip(t, 0, M - 1)
            first_in = jax.lax.dynamic_index_in_dim(xs, mb_idx, 0,
                                                    keepdims=False)
            inp = jnp.where(sid == 0, first_in, buf)
            out = fn(params, inp)
            # stage s processes microbatch t-s at tick t; valid window check
            valid = (t - sid >= 0) & (t - sid < M)
            out = jnp.where(valid, out, jnp.zeros_like(out))
            # last stage records its finished microbatch
            out_idx = jnp.clip(t - (S - 1), 0, M - 1)
            record = (sid == S - 1) & (t - (S - 1) >= 0)
            outs = jax.lax.dynamic_update_index_in_dim(
                outs, jnp.where(record,
                                out, jax.lax.dynamic_index_in_dim(
                                    outs, out_idx, 0, keepdims=False)),
                out_idx, 0)
            nxt = jax.lax.ppermute(out, axis, perm) if S > 1 else out
            return (nxt, outs), None

        outs0 = jnp.zeros_like(xs)
        buf0 = jnp.zeros_like(xs[0])
        (_, outs), _ = jax.lax.scan(tick, (buf0, outs0), jnp.arange(T))
        # only the last stage holds real outputs; broadcast them to all
        outs = jax.lax.psum(
            jnp.where(sid == S - 1, outs, jnp.zeros_like(outs)), axis)
        return outs

    other_axes = [a for a in mesh.axis_names if a != axis]
    in_x_spec = P()      # replicated microbatches (data axis handled outside)
    return shard_map(run, mesh=mesh, in_specs=(pspec, in_x_spec),
                     out_specs=P(), check_vma=False)(stage_params, x)
