# Submodules are imported explicitly (repro.parallel.sharding, .collectives,
# .pipeline) to keep import-time light and avoid cycles.
