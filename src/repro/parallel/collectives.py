"""BFP-compressed gradient collectives (paper §3.6 -> distributed training).

The shared-exponent trick applied to the wire: a ring reduce-scatter whose
per-hop payload is int8 mantissas + one int8 exponent per block (~1.9x fewer
bytes than bf16, ~3.8x fewer than f32), with f32 accumulation at every hop so
error does not compound multiplicatively.  Built on shard_map + ppermute so
it works inside any jit program.

This is the framework's gradient-compression knob for collective-bound
training cells; the §Perf log quantifies it via the roofline collective term.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..core import bfp
from .compat import axis_size, shard_map


def _ring_rs(x, axis_name: str, *, block: int, bits: int):
    """Ring reduce-scatter with BFP-compressed hops.

    x: (n * chunk, ...) locally identical-shaped shard view. Returns this
    device's reduced chunk, i.e. chunk index = axis_index."""
    n = axis_size(axis_name)
    d = jax.lax.axis_index(axis_name)
    chunks = x.reshape((n, -1) + x.shape[1:])
    perm = [(i, (i + 1) % n) for i in range(n)]

    # Device d seeds the ring with its copy of chunk (d+1)%n; each hop the
    # partial moves d -> d+1 and the receiver adds its local copy.  After
    # n-1 hops device d owns the fully reduced chunk (d+2)%n.
    acc = jnp.take(chunks, (d + 1) % n, axis=0)
    for s in range(n - 1):
        m, e, ax = bfp.quantize(acc.reshape(-1), block=block, bits=bits)
        m = jax.lax.ppermute(m, axis_name, perm)
        e = jax.lax.ppermute(e, axis_name, perm)
        recv = bfp.dequantize(m, e, bits=bits, axis=ax).reshape(acc.shape)
        acc = recv + jnp.take(chunks, (d - s) % n, axis=0)
    return acc


def bfp_psum(x, axis_name: str, *, block: int = 32, bits: int = 8):
    """All-reduce = compressed ring reduce-scatter + compressed all-gather."""
    n = axis_size(axis_name)
    if n == 1:
        return x
    orig_shape = x.shape
    size = _size(orig_shape)
    flat = x.reshape(-1)
    pad = (-size) % (n * block)
    if pad:
        flat = jnp.pad(flat, (0, pad))
    chunk = _ring_rs(flat, axis_name, block=block, bits=bits)  # this dev's chunk
    # compressed all-gather of the reduced chunks
    m, e, ax = bfp.quantize(chunk.reshape(-1), block=block, bits=bits)
    ms = jax.lax.all_gather(m, axis_name, tiled=False)         # (n, nb, blk)
    es = jax.lax.all_gather(e, axis_name, tiled=False)         # (n, nb)
    parts = bfp.dequantize(ms, es, bits=bits, axis=ax + 1)     # (n, chunk)
    # device i holds reduced chunk (i+2)%n -> reorder to 0..n-1
    parts = jnp.roll(parts, 2, axis=0)
    return parts.reshape(-1)[:size].reshape(orig_shape)


def _size(shape) -> int:
    n = 1
    for s in shape:
        n *= int(s)
    return n


def make_compressed_grad_sync(mesh: Mesh, axis: str = "data", *,
                              block: int = 32, bits: int = 8,
                              min_size: int = 1024):
    """Returns grads -> grads averaged over ``axis`` with BFP compression for
    large leaves (small leaves use exact psum)."""

    def sync(grads):
        def one(g):
            if _size(g.shape) >= min_size and _size(g.shape) % block == 0:
                s = bfp_psum(g, axis, block=block, bits=bits)
            else:
                s = jax.lax.psum(g, axis)
            return s / axis_size(axis)
        return jax.tree_util.tree_map(one, grads)

    def wrapped(grads):
        spec = jax.tree_util.tree_map(lambda _: P(), grads)
        return shard_map(sync, mesh=mesh, in_specs=(spec,), out_specs=spec,
                         check_vma=False)(grads)

    return wrapped


def wire_bytes_ratio(bits: int = 8, block: int = 32,
                     baseline_bytes: int = 2) -> float:
    """Compression ratio vs an uncompressed ring (per hop)."""
    payload = block * (bits / 8) + 1      # mantissas + shared exponent
    return payload / (block * baseline_bytes)
