"""Version-compat shard_map wrapper.

``shard_map`` moved from ``jax.experimental.shard_map`` to ``jax.shard_map``
and its replication-check kwarg was renamed ``check_rep`` -> ``check_vma``
across JAX releases.  Callers import from here and always pass ``check_vma``;
we translate for whatever is installed.
"""
from __future__ import annotations

import inspect

try:
    from jax import shard_map as _shard_map
except ImportError:                      # older JAX
    from jax.experimental.shard_map import shard_map as _shard_map

_PARAMS = frozenset(inspect.signature(_shard_map).parameters)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kw):
    if check_vma is not None:
        kw["check_vma" if "check_vma" in _PARAMS else "check_rep"] = check_vma
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)


def axis_size(axis_name) -> int:
    """``jax.lax.axis_size`` polyfill (older JAX: psum of ones)."""
    import jax

    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)
