"""Logical-axis sharding rules (t5x/MaxText style).

Model code annotates activations with *logical* axis names via
:func:`constrain`; a rules table maps logical names to mesh axes.  When no
mesh is active the annotations are no-ops, so the same model code runs on a
laptop and on a 512-chip mesh.  Rules are plain dicts, so hillclimbing a
different sharding is a one-line config change.
"""
from __future__ import annotations

import re
from contextlib import contextmanager
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# --- default logical -> mesh-axis rules -------------------------------------
# "pod" composes as an outer data axis by default (multi-pod DP); the
# pipeline launcher re-purposes it as a stage axis instead.
DEFAULT_RULES = {
    "batch": ("pod", "data"),
    "seq": None,
    "seq_res": "model",        # SP: layer-boundary residual sharded along seq
    "embed": None,
    "heads": "model",          # attention heads (activations)
    "kv_heads": "model",       # kv heads (dropped automatically if indivisible)
    "head_dim": None,
    "qkv_flat": "model",       # flattened H*head_dim param dim
    "mlp": "model",
    "vocab": "model",
    "experts": "model",        # EP: expert dim of MoE weights / dispatch
    "expert_group": ("pod", "data"),   # MoE token groups stay data-sharded
    "expert_mlp": None,
    "ssm_inner": "model",      # mamba d_inner
    "ssm_heads": "model",
    "state": None,
    "kv_lora": None,
    "cache_seq": "model",      # decode KV cache sharded along sequence (SP)
    "cache_kv_heads": None,
    "frames": None,
    "layers": None,
    "stage": "pipe",           # pipeline-parallel stage axis (opt-in meshes)
}

_ACTIVE: dict = {"mesh": None, "rules": dict(DEFAULT_RULES)}


@contextmanager
def use_mesh_rules(mesh: Optional[Mesh], rules: Optional[dict] = None):
    prev = dict(_ACTIVE)
    _ACTIVE["mesh"] = mesh
    _ACTIVE["rules"] = dict(DEFAULT_RULES, **(rules or {}))
    try:
        yield
    finally:
        _ACTIVE.update(prev)


def active_mesh() -> Optional[Mesh]:
    return _ACTIVE["mesh"]


def _mesh_axes_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= mesh.shape.get(a, 1)
    return size


def _resolve(mesh: Mesh, logical_axes, shape) -> P:
    """Map logical axes -> PartitionSpec, dropping indivisible/absent axes and
    never using one mesh axis twice."""
    rules = _ACTIVE["rules"]
    used: set = set()
    spec = []
    for dim, name in zip(shape, logical_axes):
        target = rules.get(name) if name else None
        if target is None:
            spec.append(None)
            continue
        axes = (target,) if isinstance(target, str) else tuple(target)
        axes = tuple(a for a in axes if a in mesh.shape and a not in used)
        if not axes or dim % _mesh_axes_size(mesh, axes) != 0:
            spec.append(None)
            continue
        used.update(axes)
        spec.append(axes if len(axes) > 1 else axes[0])
    return P(*spec)


def logical_sharding(shape, logical_axes, mesh: Optional[Mesh] = None):
    mesh = mesh or active_mesh()
    assert mesh is not None, "no active mesh"
    return NamedSharding(mesh, _resolve(mesh, logical_axes, shape))


def constrain(x, logical_axes):
    """with_sharding_constraint on logical axes; no-op without a mesh."""
    mesh = active_mesh()
    if mesh is None:
        return x
    if len(logical_axes) != x.ndim:
        raise ValueError(f"{logical_axes} vs rank {x.ndim}")
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, _resolve(mesh, logical_axes, x.shape)))


# --- parameter sharding by path ----------------------------------------------
# regex on the parameter path (dict keys joined with '/'); value = logical
# axes of the *trailing* dims (left-padded with "layers"/None for stacked
# leaves created by scan-over-layers vmapped init).
PARAM_RULES = [
    (r"embedding$", ("vocab", "embed")),
    (r"(wq|wkv|wk|wv|wuk|wuv|in_proj|wqkv)/w$", ("embed", "qkv_flat")),
    (r"(wo|out_proj)/w$", ("qkv_flat", "embed")),
    (r"wdkv/w$", ("embed", None)),                    # MLA down-proj (small)
    (r"(w1|w3)/w$", ("embed", "mlp")),
    (r"w2/w$", ("mlp", "embed")),
    (r"router/w$", ("embed", None)),
    (r"experts/(w1|w3)$", ("experts", "embed", "expert_mlp")),
    (r"experts/w2$", ("experts", "expert_mlp", "embed")),
    (r"conv/w$", (None, "ssm_inner")),
    (r"(A_log|D|dt_bias)$", ("ssm_heads",)),
    (r"(patch_proj)/w$", ("embed", None)),
]


def path_str(path) -> str:
    parts = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            parts.append(str(k.key))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_logical_axes(path, leaf) -> tuple:
    s = path_str(path)
    # BFP-quantized linear weights: w_q (KB, block, N) / w_e (KB, N) inherit
    # the underlying w (K, N) rule with the block dim unsharded.
    bfp_kind = None
    if s.endswith("/w_q") or s.endswith("/w_e"):
        bfp_kind = s[-1]
        s = s[:-2]
    for pat, axes in PARAM_RULES:
        if re.search(pat, s):
            if bfp_kind == "q" and len(axes) == 2:
                axes = (axes[0], None, axes[1])
            pad = leaf.ndim - len(axes)
            return ("layers",) * pad + tuple(axes) if pad >= 0 else tuple(axes)[-leaf.ndim:]
    return (None,) * leaf.ndim   # norms, biases, scalars: replicated


def param_shardings(params_shape, mesh: Mesh):
    """Pytree of NamedShardings for a (possibly abstract) param tree."""
    def one(path, leaf):
        axes = param_logical_axes(path, leaf)
        return NamedSharding(mesh, _resolve(mesh, axes, leaf.shape))
    return jax.tree_util.tree_map_with_path(one, params_shape)


def batch_sharding(mesh: Mesh, ndim: int = 2):
    """Inputs: batch dim sharded over (pod, data)."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    return NamedSharding(mesh, P(axes if len(axes) > 1 else (axes[0] if axes else None),
                                 *([None] * (ndim - 1))))


def data_parallel_mesh(devices=None) -> Mesh:
    """1-axis ("data",) mesh over all local devices (serving-style pure DP:
    replicated weights, batch axis sharded)."""
    devices = list(jax.devices() if devices is None else devices)
    return Mesh(np.asarray(devices), ("data",))


def replicated_sharding(mesh: Mesh) -> NamedSharding:
    """Fully replicated placement on ``mesh`` (weights under pure DP, or the
    fallback for batches indivisible by the data axis)."""
    return NamedSharding(mesh, P())


def zero1_shardings(params_shape, mesh: Mesh):
    """ZeRO-1: optimizer moments additionally sharded over 'data' on the
    largest divisible dim that the param sharding leaves unsharded."""
    base = param_shardings(params_shape, mesh)

    def upgrade(leaf_shape, ns):
        spec = list(ns.spec) + [None] * (len(leaf_shape.shape) - len(ns.spec))
        dsize = mesh.shape.get("data", 1)
        if dsize == 1:
            return ns
        # pick the largest unsharded dim divisible by the data axis
        cands = [(d, i) for i, d in enumerate(leaf_shape.shape)
                 if spec[i] is None and d % dsize == 0]
        if not cands:
            return ns
        _, i = max(cands)
        spec[i] = "data"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map(upgrade, params_shape, base)
