"""Production training runtime: fault tolerance, stragglers, elasticity.

Fault model (1000+ node fleets):
  * step failure (node loss, injected in tests)  -> restore last checkpoint,
    continue; the data pipeline is keyed by step so replayed batches are
    identical.
  * preemption (SIGTERM)                         -> synchronous final
    checkpoint, clean exit; restart resumes from it.
  * stragglers                                   -> per-step EMA/z-score
    detector with a pluggable action hook (on a real fleet: re-shard or
    evict; here: recorded + logged).
  * elastic scaling                              -> reshard_state() re-places
    the state pytree onto a new mesh (grown or shrunk); verified by test.
"""
from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import numpy as np

from .. import checkpoint as ckpt_lib
from ..config import ArchConfig
from ..core.streambuf import StreamBuffer
from ..data.pipeline import synthetic_batches
from ..models import model_for
from ..optim import adamw_step, init_state, lr_schedule
from ..parallel import sharding as shlib


class InjectedFailure(RuntimeError):
    """Raised by a failure injector to simulate a node loss."""


@dataclass
class TrainerConfig:
    steps: int = 100
    base_lr: float = 1e-3
    warmup: int = 20
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    batch: int = 8
    seq_len: int = 128
    log_every: int = 10
    ckpt_every: int = 0                 # 0 = checkpointing off
    ckpt_dir: str = ""
    keep: int = 3
    async_ckpt: bool = False
    straggler_zscore: float = 3.0
    straggler_min_history: int = 16
    seed: int = 0


@dataclass
class TrainerEvents:
    stragglers: list = field(default_factory=list)
    recoveries: list = field(default_factory=list)
    preempted: bool = False


class Trainer:
    def __init__(self, cfg: ArchConfig, tcfg: TrainerConfig, *,
                 mesh=None, rules=None, data_it=None,
                 failure_injector: Optional[Callable[[int], bool]] = None,
                 straggler_hook: Optional[Callable] = None,
                 params=None):
        self.cfg, self.tcfg = cfg, tcfg
        self.mesh, self.rules = mesh, rules
        self.mod = model_for(cfg)
        self.events = TrainerEvents()
        self._failure_injector = failure_injector
        self._straggler_hook = straggler_hook
        self._times: list = []
        self._sigterm = False
        self.history: list = []

        key = jax.random.PRNGKey(tcfg.seed)
        with shlib.use_mesh_rules(mesh, rules):
            if params is None:
                params = self.mod.init(key, cfg)
            if mesh is not None:
                pshard = shlib.param_shardings(
                    jax.tree_util.tree_map(
                        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params),
                    mesh)
                params = jax.device_put(params, pshard)
            self.state = init_state(params)

        self._user_data_it = data_it
        self.data = None           # built lazily at run() aligned to `step`

        self._ckpt = None
        if tcfg.ckpt_every and tcfg.ckpt_dir:
            if tcfg.async_ckpt:
                self._ckpt = ckpt_lib.AsyncCheckpointer(tcfg.ckpt_dir,
                                                        keep=tcfg.keep)

        mod, tc = self.mod, tcfg

        def train_step(state, batch):
            lr = lr_schedule(state["step"], base_lr=tc.base_lr,
                             warmup=tc.warmup, total=tc.steps)
            (loss, metrics), grads = jax.value_and_grad(
                mod.loss_fn, has_aux=True)(state["params"], cfg, batch)
            state, om = adamw_step(state, grads, lr=lr,
                                   weight_decay=tc.weight_decay,
                                   clip_norm=tc.clip_norm)
            return state, {**metrics, **om, "lr": lr}

        def wrapped(state, batch):
            with shlib.use_mesh_rules(mesh, rules):
                return train_step(state, batch)

        self._step = jax.jit(wrapped, donate_argnums=(0,))

    # -- fault handling -----------------------------------------------------
    def _install_sigterm(self):
        def handler(signum, frame):
            self._sigterm = True
        try:
            signal.signal(signal.SIGTERM, handler)
        except ValueError:      # not in main thread
            pass

    def save(self):
        if not self.tcfg.ckpt_dir:
            return
        if self._ckpt is not None:
            self._ckpt.submit(self.state)
        else:
            ckpt_lib.save(self.tcfg.ckpt_dir, self.state, keep=self.tcfg.keep)

    def restore_latest(self) -> bool:
        step = ckpt_lib.latest_step(self.tcfg.ckpt_dir) \
            if self.tcfg.ckpt_dir else None
        if step is None:
            return False
        if self._ckpt is not None:
            self._ckpt.wait()
        self.state = ckpt_lib.restore(self.tcfg.ckpt_dir, self.state)
        return True

    # -- data ------------------------------------------------------------------
    def _make_data(self, start_step: int):
        """Step-keyed stream: restarting at step s replays batch s exactly
        (checkpoint restore and failure recovery stay bit-reproducible)."""
        if self._user_data_it is not None:
            return StreamBuffer(self._user_data_it)
        tc, cfg = self.tcfg, self.cfg

        def gen():
            s = start_step
            while True:
                it = synthetic_batches(
                    batch=tc.batch, seq_len=tc.seq_len, vocab=cfg.vocab_size,
                    seed=tc.seed + s, family=cfg.family, d_model=cfg.d_model,
                    num_patches=cfg.num_patches,
                    frames_len=min(tc.seq_len, 128), steps=1)
                yield next(it)
                s += 1

        return StreamBuffer(gen())

    # -- straggler detection --------------------------------------------------
    def _check_straggler(self, step: int, dt: float):
        if len(self._times) < 2:       # warmup: skip compile-dominated steps
            self._times.append(dt)
            return
        self._times.append(dt)
        hist = self._times[2:][-256:]
        if len(hist) < self.tcfg.straggler_min_history:
            return
        mu = float(np.mean(hist[:-1]))
        sd = float(np.std(hist[:-1])) + 1e-9
        z = (dt - mu) / sd
        if z > self.tcfg.straggler_zscore:
            ev = {"step": step, "dt": dt, "mean": mu, "z": z}
            self.events.stragglers.append(ev)
            if self._straggler_hook:
                self._straggler_hook(ev)

    # -- main loop -------------------------------------------------------------
    def run(self) -> list:
        self._install_sigterm()
        tc = self.tcfg
        step = int(jax.device_get(self.state["step"]))
        if self.data is None:
            self.data = self._make_data(step)
        while step < tc.steps:
            batch = next(self.data)
            t0 = time.perf_counter()
            try:
                if self._failure_injector and self._failure_injector(step):
                    raise InjectedFailure(f"injected failure @ step {step}")
                new_state, metrics = self._step(self.state, batch)
                jax.block_until_ready(new_state["step"])
                self.state = new_state
            except InjectedFailure as e:
                restored = self.restore_latest()
                self.events.recoveries.append(
                    {"step": step, "restored": restored, "err": str(e)})
                # re-align the (step-keyed) data stream with the restored step
                step = int(jax.device_get(self.state["step"]))
                self.data = self._make_data(step)
                continue
            dt = time.perf_counter() - t0
            step = int(jax.device_get(self.state["step"]))
            self._check_straggler(step, dt)
            if tc.log_every and step % tc.log_every == 0:
                rec = {k: float(jax.device_get(v)) for k, v in metrics.items()}
                rec.update(step=step, dt=dt)
                self.history.append(rec)
            if tc.ckpt_every and step % tc.ckpt_every == 0:
                self.save()
            if self._sigterm:
                self.events.preempted = True
                self.save()
                break
        if self._ckpt is not None:
            self._ckpt.wait()
        return self.history


def reshard_state(state, mesh, rules=None):
    """Elastic re-placement of a state pytree onto a (new) mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    with shlib.use_mesh_rules(mesh, rules):
        abstract = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state["params"])
        pshard = shlib.param_shardings(abstract, mesh)
        out = {
            "step": jax.device_put(state["step"], NamedSharding(mesh, P())),
            "params": jax.device_put(state["params"], pshard),
            "m": jax.device_put(state["m"], pshard),
            "v": jax.device_put(state["v"], pshard),
        }
    return out
