from .trainer import Trainer, TrainerConfig, reshard_state  # noqa: F401
