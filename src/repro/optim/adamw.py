"""AdamW with global-norm clipping and warmup+cosine schedule.

State is a plain pytree dict (checkpoint-friendly).  Under a mesh, the
moments get ZeRO-1 shardings from ``parallel.sharding.zero1_shardings`` via
the train-step's out_shardings — the optimizer code itself is layout-free.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_state(params):
    zeros = lambda: jax.tree_util.tree_map(
        lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return {"step": jnp.zeros((), jnp.int32), "params": params,
            "m": zeros(), "v": zeros()}


def lr_schedule(step, *, base_lr: float, warmup: int = 100,
                total: int = 10_000, min_ratio: float = 0.1):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(warmup, 1), 1.0)
    prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return base_lr * warm * cos


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def adamw_step(state, grads, *, lr, b1: float = 0.9, b2: float = 0.95,
               eps: float = 1e-8, weight_decay: float = 0.0,
               clip_norm: float = 1.0):
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if clip_norm else 1.0
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1 - b1 ** t
    bc2 = 1 - b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        update = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        if weight_decay:
            update = update + weight_decay * p.astype(jnp.float32)
        return (p - lr * update.astype(p.dtype)).astype(p.dtype), m, v

    flat_p, tdef = jax.tree_util.tree_flatten(state["params"])
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state["m"])
    flat_v = jax.tree_util.tree_leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_state = {
        "step": step,
        "params": jax.tree_util.tree_unflatten(tdef, [o[0] for o in out]),
        "m": jax.tree_util.tree_unflatten(tdef, [o[1] for o in out]),
        "v": jax.tree_util.tree_unflatten(tdef, [o[2] for o in out]),
    }
    return new_state, {"grad_norm": gnorm}
