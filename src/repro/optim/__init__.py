from .adamw import adamw_step, init_state, lr_schedule  # noqa: F401
