"""phi4-mini-3.8b [dense] — RoPE SwiGLU GQA(kv=8). [arXiv:2412.08905]"""
from repro.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="phi4-mini-3.8b", family="dense",
        num_layers=32, d_model=3072,
        num_heads=24, num_kv_heads=8, head_dim=128,
        d_ff=8192, vocab_size=200_064,
        mlp_type="swiglu", norm_type="rmsnorm",
        tie_embeddings=True,   # phi-4-mini shares input/output embeddings
    )
