"""mamba2-2.7b [ssm] — SSD, attn-free. [arXiv:2405.21060]

64L d_model=2560, d_ff=0, vocab=50280, ssm_state=128.
d_inner = 2*2560 = 5120, head_dim 64 -> 80 SSD heads, 1 group, conv k=4.
"""
from repro.config import ArchConfig, SSMCfg


def config() -> ArchConfig:
    return ArchConfig(
        name="mamba2-2.7b", family="ssm",
        num_layers=64, d_model=2560,
        num_heads=0, num_kv_heads=0, head_dim=64,
        d_ff=0, vocab_size=50_280,
        tie_embeddings=True, norm_type="rmsnorm",
        ssm=SSMCfg(d_state=128, head_dim=64, expand=2, conv_kernel=4,
                   ngroups=1, chunk=256),
    )
