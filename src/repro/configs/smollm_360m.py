"""smollm-360m [dense] — llama-arch small, GQA(kv=5). [hf:HuggingFaceTB/SmolLM]"""
from repro.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="smollm-360m", family="dense",
        num_layers=32, d_model=960,
        num_heads=15, num_kv_heads=5, head_dim=64,
        d_ff=2560, vocab_size=49_152,
        mlp_type="swiglu", norm_type="rmsnorm",
        tie_embeddings=True,
    )
