"""llama3.2-3b [dense] — small llama3. [hf:meta-llama/Llama-3.2-*]"""
from repro.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="llama3.2-3b", family="dense",
        num_layers=28, d_model=3072,
        num_heads=24, num_kv_heads=8, head_dim=128,
        d_ff=8192, vocab_size=128_256,
        mlp_type="swiglu", norm_type="rmsnorm",
        tie_embeddings=True, rope_theta=500_000.0,
    )
