"""whisper-tiny [audio] — enc-dec, conv frontend STUB (precomputed frame
embeddings per the assignment). [arXiv:2212.04356]

4 encoder + 4 decoder layers, d_model=384, 6 heads (MHA), d_ff=1536,
vocab=51865, LayerNorm+GELU+bias, cross-attention decoder.
"""
from repro.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="whisper-tiny", family="audio",
        num_layers=4, d_model=384,
        num_heads=6, num_kv_heads=6, head_dim=64,
        d_ff=1536, vocab_size=51_865,
        mlp_type="gelu", norm_type="layernorm", qkv_bias=True,
        tie_embeddings=True,
        encoder_layers=4, cross_attention=True,
    )
