"""granite-moe-1b-a400m [moe] — 32 experts top-8, every layer MoE.
[hf:ibm-granite/granite-3.0-1b-a400m-base]"""
from repro.config import ArchConfig, MoECfg


def config() -> ArchConfig:
    return ArchConfig(
        name="granite-moe-1b-a400m", family="moe",
        num_layers=24, d_model=1024,
        num_heads=16, num_kv_heads=8, head_dim=64,
        d_ff=512, vocab_size=49_155,
        mlp_type="swiglu", norm_type="rmsnorm",
        tie_embeddings=True,
        moe=MoECfg(num_experts=32, top_k=8, d_ff=512, period=1, offset=0),
    )
