"""deepseek-v2-lite-16b [moe] — MLA (kv_lora=512) + MoE. [arXiv:2405.04434]

27L d_model=2048, 16 heads, MLA: kv_lora_rank=512, qk_nope 128, qk_rope 64,
v 128.  MoE: 64 routed experts top-6 + 2 shared, expert d_ff=1408, first
layer dense.

Note: the assignment header says "64e top-6" while its detail note says
"160 routed" (that is full V2, not Lite); we follow the Lite numbers:
64 routed + 2 shared, top-6.  Dense first-layer FFN uses the real model's
10944 (the assignment's d_ff=1408 is the per-expert width).
"""
from repro.config import ArchConfig, MLACfg, MoECfg


def config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-v2-lite-16b", family="moe",
        num_layers=27, d_model=2048,
        num_heads=16, num_kv_heads=16, head_dim=128,
        d_ff=10_944, vocab_size=102_400,
        mlp_type="swiglu", norm_type="rmsnorm",
        mla=MLACfg(kv_lora_rank=512, qk_nope_head_dim=128,
                   qk_rope_head_dim=64, v_head_dim=128),
        moe=MoECfg(num_experts=64, top_k=6, d_ff=1408, num_shared=2,
                   period=1, offset=0, first_k_dense=1),
    )
