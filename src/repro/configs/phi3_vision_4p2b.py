"""phi-3-vision-4.2b [vlm] — phi3-mini backbone + CLIP patch STUB.
[hf:microsoft/Phi-3-vision-128k-instruct]

32L d_model=3072, 32 heads MHA (kv=32, head_dim 96), d_ff=8192, vocab=32064.
The vision tower is a stub: input_specs() provides (B, 576, 1024) precomputed
CLIP patch embeddings, projected and prepended to the token sequence.
"""
from repro.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="phi-3-vision-4.2b", family="vlm",
        num_layers=32, d_model=3072,
        num_heads=32, num_kv_heads=32, head_dim=96,
        d_ff=8192, vocab_size=32_064,
        mlp_type="swiglu", norm_type="rmsnorm",
        num_patches=576,
    )
