"""Config registry: ``get_config("starcoder2-15b")`` etc.

One module per assigned architecture (+ the paper's own AlexNet).  All
numbers follow the assignment block; deviations are noted inline and in
DESIGN.md §Arch-applicability.
"""
from __future__ import annotations

from importlib import import_module

_MODULES = {
    "mamba2-2.7b": "mamba2_2p7b",
    "starcoder2-15b": "starcoder2_15b",
    "phi4-mini-3.8b": "phi4_mini_3p8b",
    "llama3.2-3b": "llama3p2_3b",
    "smollm-360m": "smollm_360m",
    "jamba-v0.1-52b": "jamba_v0p1_52b",
    "whisper-tiny": "whisper_tiny",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "phi-3-vision-4.2b": "phi3_vision_4p2b",
    "alexnet": "alexnet",
    "vgg16": "vgg16",
}

# the paper-side CNNs live outside the assigned-architecture list
CNN_ARCHS = ["alexnet", "vgg16"]
ASSIGNED = [n for n in _MODULES if n not in CNN_ARCHS]


def list_configs():
    return list(_MODULES)


def get_config(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {list(_MODULES)}")
    return import_module(f"repro.configs.{_MODULES[name]}").config()
