"""VGG-16 — the second fleet-served CNN (Simonyan & Zisserman 2014).

Same ConvSpec pipeline as AlexNet (``models/alexnet.py`` with
``arch="vgg"``): thirteen 3x3 stride-1 SAME convs — every one
Winograd-eligible, the geometry regime ``tests/test_vgg_geometry.py``
sweeps the auto channel/pooled-row blocking over — with fused 2x2 s2
max-pools closing the five stages and no LRN.  ``reduced()`` keeps the
all-3x3 + staged-pool shape at smoke scale for CI and the fleet benchmark.
"""
from repro.models.alexnet import AlexNetConfig


def config() -> AlexNetConfig:
    return AlexNetConfig(
        name="vgg16",
        arch="vgg",
        image_size=224,
        conv_channels=(64, 64, 128, 128, 256, 256, 256,
                       512, 512, 512, 512, 512, 512),
        pool_after=(2, 4, 7, 10, 13),
        fc_dims=(4096, 4096, 1000),
        num_classes=1000,
    )
