"""starcoder2-15b [dense] — GQA(kv=4), RoPE, LayerNorm+GELU+bias. [arXiv:2402.19173]"""
from repro.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="starcoder2-15b", family="dense",
        num_layers=40, d_model=6144,
        num_heads=48, num_kv_heads=4, head_dim=128,
        d_ff=24_576, vocab_size=49_152,
        mlp_type="gelu", norm_type="layernorm", qkv_bias=True,
        rope_theta=1e5,
    )
