"""AlexNet — the paper's own benchmark topology (Krizhevsky 2012).

Drives the paper-table benchmarks: Table 2 (per-layer GFLOPS/efficiency),
Fig. 8 (DSE surface), Fig. 9 (model vs measured), Tables 5/6 (throughput).
"""
from repro.models.alexnet import AlexNetConfig


def config() -> AlexNetConfig:
    return AlexNetConfig()
