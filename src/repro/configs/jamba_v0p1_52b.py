"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2.
[arXiv:2403.19887]

32L, attention at layer index 4 of every 8 (HF: attn_layer_period=8,
attn_layer_offset=4); MoE FFN every 2 layers at odd indices (expert period 2,
offset 1), 16 experts top-2, expert d_ff = dense d_ff = 14336.

Deviation (DESIGN.md): Jamba's Mamba-1 layers (d_state 16) are modeled with
the SSD (Mamba-2 style) mixer of this framework, head_dim 64.
"""
from repro.config import ArchConfig, MoECfg, SSMCfg


def config() -> ArchConfig:
    return ArchConfig(
        name="jamba-v0.1-52b", family="hybrid",
        num_layers=32, d_model=4096,
        num_heads=32, num_kv_heads=8, head_dim=128,
        d_ff=14_336, vocab_size=65_536,
        mlp_type="swiglu", norm_type="rmsnorm",
        attn_period=8, attn_offset=4,
        moe=MoECfg(num_experts=16, top_k=2, d_ff=14_336, period=2, offset=1),
        ssm=SSMCfg(d_state=16, head_dim=64, expand=2, conv_kernel=4,
                   ngroups=1, chunk=256),
    )
