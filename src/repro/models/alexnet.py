"""AlexNet — the paper's own benchmark network, end-to-end in JAX.

All layers run on-device (the paper's headline point vs conv-only FPGA
work): conv (Winograd F(4,3) for the 3x3 layers, the strided direct
datapath for conv1/conv2 as in the paper), ReLU, cross-channel LRN,
max-pool, and the batched FC layers (§3.7).  Each conv *layer* — including
its LRN/pool epilogue — is one :class:`~repro.nn.conv.ConvSpec`, and all
*five* layers are pallas-servable: under ``use_pallas`` the 3x3 layers hit
the Winograd-domain kernel and conv1 (11x11 stride 4) / conv2 (5x5) hit
the strided direct kernel, so every layer's post-conv stages run in VMEM
and no feature map round-trips HBM between conv, norm, and pool (§3.5) —
no layer falls back to ``lax.conv``.  Grouped convolutions (conv2/4/5)
follow Krizhevsky.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import List, Tuple

import jax
import jax.numpy as jnp

from ..kernels.bfp_matmul.ops import bfp_linear
from ..nn.conv import ConvSpec, dispatch_conv, resolve_kernel
from ..nn.module import param, split
from ..nn.pooling import LrnParams


@dataclass(frozen=True)
class AlexNetConfig:
    name: str = "alexnet"
    family: str = "cnn"
    image_size: int = 227
    in_channels: int = 3
    conv_channels: Tuple[int, ...] = (96, 256, 384, 384, 256)
    fc_dims: Tuple[int, ...] = (4096, 4096, 1000)
    num_classes: int = 1000
    use_winograd: bool = True      # F(4,3) on the 3x3 stride-1 layers
    use_pallas: bool = False       # route 3x3 convs through the Pallas kernel
    fc_batch: int = 96             # paper's S_batch
    fc_bfp: bool = False           # shared-exponent BFP FC weight stream §3.6
    lrn_n: int = 5
    lrn_k: float = 2.0
    lrn_alpha: float = 1e-4
    lrn_beta: float = 0.75
    dtype: str = "float32"

    def reduced(self) -> "AlexNetConfig":
        return replace(self, image_size=67, conv_channels=(16, 32, 48, 48, 32),
                       fc_dims=(64, 48, 10), num_classes=10, fc_batch=4)


def layer_specs(cfg: "AlexNetConfig") -> List[ConvSpec]:
    """The five conv layers as fused layer-level specs (Krizhevsky geometry).

    conv1/conv2 carry LRN + pool, conv5 pool only; every conv fuses
    bias+ReLU and routes through ``repro.nn.conv.dispatch_conv`` (the 3x3
    stride-1 layers are Winograd-eligible; conv1/conv2 take the direct
    datapath — the strided Pallas kernel on the pallas route — as in the
    paper's non-Winograd first layer).
    """
    lrn = LrnParams(n=cfg.lrn_n, k=cfg.lrn_k, alpha=cfg.lrn_alpha,
                    beta=cfg.lrn_beta)
    return [
        ConvSpec(kernel=11, stride=4, padding="VALID", relu=True,
                 fuse_lrn=True, lrn=lrn, fuse_pool=True),
        ConvSpec(kernel=5, groups=2, relu=True,
                 fuse_lrn=True, lrn=lrn, fuse_pool=True),
        ConvSpec(kernel=3, relu=True),
        ConvSpec(kernel=3, groups=2, relu=True),
        ConvSpec(kernel=3, groups=2, relu=True, fuse_pool=True),
    ]


def _route(cfg: "AlexNetConfig") -> str:
    """Model-wide route preference; per-layer eligibility lives in nn.conv."""
    if not cfg.use_winograd:
        return "direct"
    return "pallas" if cfg.use_pallas else "winograd"


def layer_routes(cfg: "AlexNetConfig") -> List[Tuple[str, str]]:
    """(layer name, fully resolved datapath) per conv layer — what serving
    logs print so ``--route pallas`` shows conv1/conv2 on ``pallas-direct``
    instead of silently degrading.  Shape-aware: each layer's input extent
    is threaded through, so the report matches what dispatch_conv runs."""
    route = _route(cfg)
    routes = []
    h = cfg.image_size
    for i, spec in enumerate(layer_specs(cfg)):
        routes.append((f"conv{i + 1}",
                       resolve_kernel(spec.with_route(route), in_hw=h)))
        h = spec.out_hw(h)
    return routes


def init(key, cfg: AlexNetConfig):
    dtype = jnp.dtype(cfg.dtype)
    specs = layer_specs(cfg)
    keys = split(key, len(specs) + len(cfg.fc_dims))
    p = {}
    c_in = cfg.in_channels
    for i, (spec, c_out) in enumerate(zip(specs, cfg.conv_channels)):
        k, g = spec.kernel, spec.groups
        p[f"conv{i+1}"] = {
            "w": param(keys[i], (k, k, c_in // g, c_out), dtype,
                       scale=(k * k * c_in // g) ** -0.5),
            "b": jnp.zeros((c_out,), dtype),
        }
        c_in = c_out
    d_in = _fc_input_dim(cfg)
    for j, d_out in enumerate(cfg.fc_dims):
        p[f"fc{j+6}"] = {
            "w": param(keys[len(specs) + j], (d_in, d_out), dtype),
            "b": jnp.zeros((d_out,), dtype),
        }
        d_in = d_out
    return p


def _feature_hw(cfg: AlexNetConfig) -> int:
    h = cfg.image_size
    for spec in layer_specs(cfg):
        h = spec.out_hw(h)
    return h


def _fc_input_dim(cfg: AlexNetConfig) -> int:
    return _feature_hw(cfg) ** 2 * cfg.conv_channels[-1]


def features(params, cfg: AlexNetConfig, images):
    """images (B, H, W, 3) -> flattened conv features (B, d).

    One ``dispatch_conv`` per layer; the LRN/pool epilogues live in the
    layer specs, so there are no free-standing norm/pool calls here.
    """
    x = images.astype(jnp.dtype(cfg.dtype))
    route = _route(cfg)
    for i, spec in enumerate(layer_specs(cfg)):
        p = params[f"conv{i+1}"]
        x = dispatch_conv(spec.with_route(route), x, p["w"], p["b"])
    return x.reshape(x.shape[0], -1)


def classifier(params, cfg: AlexNetConfig, feats):
    """Batched FC layers (paper §3.7: weights streamed, features cached).

    With ``cfg.fc_bfp`` the weight stream moves as shared-exponent int8
    block floating point (§3.6, ``kernels/bfp_matmul``) — 1 byte/value on
    the paper's stated FC bandwidth bottleneck — instead of f32.
    """
    x = feats
    n_fc = len(cfg.fc_dims)
    for j in range(n_fc):
        p = params[f"fc{j+6}"]
        if cfg.fc_bfp:
            x = (bfp_linear(x, p["w"])
                 + p["b"].astype(jnp.float32)).astype(x.dtype)
        else:
            x = x @ p["w"].astype(x.dtype) + p["b"].astype(x.dtype)
        if j < n_fc - 1:
            x = jax.nn.relu(x)
    return x


def apply(params, cfg: AlexNetConfig, images):
    return classifier(params, cfg, features(params, cfg, images))


def loss_fn(params, cfg: AlexNetConfig, batch):
    logits = apply(params, cfg, batch["images"])
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    loss = -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()
    acc = (logits.argmax(-1) == labels).mean()
    return loss, {"loss": loss, "accuracy": acc}
