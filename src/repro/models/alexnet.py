"""AlexNet — the paper's own benchmark network, end-to-end in JAX.

All layers run on-device (the paper's headline point vs conv-only FPGA work):
conv (Winograd F(4,3) for the 3x3 layers, direct for conv1/conv2 as in the
paper), ReLU, cross-channel LRN, max-pool, and the batched FC layers (§3.7).
Grouped convolutions (conv2/4/5) follow Krizhevsky.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Tuple

import jax
import jax.numpy as jnp

from ..core.winograd import conv2d_direct, conv2d_winograd
from ..nn.module import param, split


@dataclass(frozen=True)
class AlexNetConfig:
    name: str = "alexnet"
    family: str = "cnn"
    image_size: int = 227
    in_channels: int = 3
    conv_channels: Tuple[int, ...] = (96, 256, 384, 384, 256)
    fc_dims: Tuple[int, ...] = (4096, 4096, 1000)
    num_classes: int = 1000
    use_winograd: bool = True      # F(4,3) on the 3x3 stride-1 layers
    use_pallas: bool = False       # route 3x3 convs through the Pallas kernel
    fc_batch: int = 96             # paper's S_batch
    lrn_n: int = 5
    lrn_k: float = 2.0
    lrn_alpha: float = 1e-4
    lrn_beta: float = 0.75
    dtype: str = "float32"

    def reduced(self) -> "AlexNetConfig":
        return replace(self, image_size=67, conv_channels=(16, 32, 48, 48, 32),
                       fc_dims=(64, 48, 10), num_classes=10, fc_batch=4)


# (kernel, stride, pad, groups, lrn?, pool?) per conv layer — Krizhevsky
_LAYERS = [
    (11, 4, "VALID", 1, True, True),
    (5, 1, "SAME", 2, True, True),
    (3, 1, "SAME", 1, False, False),
    (3, 1, "SAME", 2, False, False),
    (3, 1, "SAME", 2, False, True),
]


def init(key, cfg: AlexNetConfig):
    dtype = jnp.dtype(cfg.dtype)
    keys = split(key, len(_LAYERS) + len(cfg.fc_dims))
    p = {}
    c_in = cfg.in_channels
    for i, ((k, s, pad, g, _, _), c_out) in enumerate(zip(_LAYERS,
                                                          cfg.conv_channels)):
        p[f"conv{i+1}"] = {
            "w": param(keys[i], (k, k, c_in // g, c_out), dtype,
                       scale=(k * k * c_in // g) ** -0.5),
            "b": jnp.zeros((c_out,), dtype),
        }
        c_in = c_out
    d_in = _fc_input_dim(cfg)
    for j, d_out in enumerate(cfg.fc_dims):
        p[f"fc{j+6}"] = {
            "w": param(keys[len(_LAYERS) + j], (d_in, d_out), dtype),
            "b": jnp.zeros((d_out,), dtype),
        }
        d_in = d_out
    return p


def _feature_hw(cfg: AlexNetConfig) -> int:
    h = cfg.image_size
    for (k, s, pad, _, _, pool) in _LAYERS:
        h = (h - k) // s + 1 if pad == "VALID" else -(-h // s)
        if pool:
            h = (h - 3) // 2 + 1
    return h


def _fc_input_dim(cfg: AlexNetConfig) -> int:
    return _feature_hw(cfg) ** 2 * cfg.conv_channels[-1]


def _lrn(x, cfg: AlexNetConfig):
    """Cross-channel local response normalization (paper §2.2)."""
    sq = jnp.square(x)
    half = cfg.lrn_n // 2
    pad = jnp.pad(sq, ((0, 0), (0, 0), (0, 0), (half, half)))
    win = sum(pad[..., i:i + x.shape[-1]] for i in range(cfg.lrn_n))
    return x / jnp.power(cfg.lrn_k + cfg.lrn_alpha / cfg.lrn_n * win,
                         cfg.lrn_beta)


def _maxpool(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 3, 3, 1),
                                 (1, 2, 2, 1), "VALID")


def _conv(p, x, k, s, pad, groups, cfg: AlexNetConfig):
    w = p["w"]
    use_wino = cfg.use_winograd and k == 3 and s == 1

    def one(xg, wg):
        if use_wino:
            if cfg.use_pallas:
                from ..kernels.winograd.ops import conv2d as pallas_conv2d
                return pallas_conv2d(xg, wg, m=4, padding=pad)
            return conv2d_winograd(xg, wg, m=4, padding=pad)
        return conv2d_direct(xg, wg, stride=s, padding=pad)

    if groups == 1:
        y = one(x, w)
    else:
        cg = x.shape[-1] // groups
        kg = w.shape[-1] // groups
        y = jnp.concatenate(
            [one(x[..., g * cg:(g + 1) * cg], w[..., g * kg:(g + 1) * kg])
             for g in range(groups)], axis=-1)
    return y + p["b"].astype(y.dtype)


def features(params, cfg: AlexNetConfig, images):
    """images (B, H, W, 3) -> flattened conv features (B, d)."""
    x = images.astype(jnp.dtype(cfg.dtype))
    for i, (k, s, pad, g, lrn, pool) in enumerate(_LAYERS):
        x = _conv(params[f"conv{i+1}"], x, k, s, pad, g, cfg)
        x = jax.nn.relu(x)
        if lrn:
            x = _lrn(x, cfg)
        if pool:
            x = _maxpool(x)
    return x.reshape(x.shape[0], -1)


def classifier(params, cfg: AlexNetConfig, feats):
    """Batched FC layers (paper §3.7: weights streamed, features cached)."""
    x = feats
    n_fc = len(cfg.fc_dims)
    for j in range(n_fc):
        p = params[f"fc{j+6}"]
        x = x @ p["w"].astype(x.dtype) + p["b"].astype(x.dtype)
        if j < n_fc - 1:
            x = jax.nn.relu(x)
    return x


def apply(params, cfg: AlexNetConfig, images):
    return classifier(params, cfg, features(params, cfg, images))


def loss_fn(params, cfg: AlexNetConfig, batch):
    logits = apply(params, cfg, batch["images"])
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    loss = -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()
    acc = (logits.argmax(-1) == labels).mean()
    return loss, {"loss": loss, "accuracy": acc}
