"""AlexNet — the paper's own benchmark network, end-to-end in JAX.

All layers run on-device (the paper's headline point vs conv-only FPGA work):
conv (Winograd F(4,3) for the 3x3 layers, direct for conv1/conv2 as in the
paper), ReLU, cross-channel LRN, max-pool, and the batched FC layers (§3.7).
Grouped convolutions (conv2/4/5) follow Krizhevsky.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Tuple

import jax
import jax.numpy as jnp

from ..nn.conv import ConvSpec, dispatch_conv
from ..nn.module import param, split


@dataclass(frozen=True)
class AlexNetConfig:
    name: str = "alexnet"
    family: str = "cnn"
    image_size: int = 227
    in_channels: int = 3
    conv_channels: Tuple[int, ...] = (96, 256, 384, 384, 256)
    fc_dims: Tuple[int, ...] = (4096, 4096, 1000)
    num_classes: int = 1000
    use_winograd: bool = True      # F(4,3) on the 3x3 stride-1 layers
    use_pallas: bool = False       # route 3x3 convs through the Pallas kernel
    fc_batch: int = 96             # paper's S_batch
    lrn_n: int = 5
    lrn_k: float = 2.0
    lrn_alpha: float = 1e-4
    lrn_beta: float = 0.75
    dtype: str = "float32"

    def reduced(self) -> "AlexNetConfig":
        return replace(self, image_size=67, conv_channels=(16, 32, 48, 48, 32),
                       fc_dims=(64, 48, 10), num_classes=10, fc_batch=4)


# (ConvSpec, lrn?, pool?) per conv layer — Krizhevsky geometry; every conv
# fuses bias+ReLU and routes through repro.nn.conv.dispatch_conv (the 3x3
# stride-1 layers are Winograd-eligible; conv1/conv2 go direct, as in the
# paper).
_LAYERS = [
    (ConvSpec(kernel=11, stride=4, padding="VALID", relu=True), True, True),
    (ConvSpec(kernel=5, groups=2, relu=True), True, True),
    (ConvSpec(kernel=3, relu=True), False, False),
    (ConvSpec(kernel=3, groups=2, relu=True), False, False),
    (ConvSpec(kernel=3, groups=2, relu=True), False, True),
]


def _route(cfg: "AlexNetConfig") -> str:
    """Model-wide route preference; per-layer eligibility lives in nn.conv."""
    if not cfg.use_winograd:
        return "direct"
    return "pallas" if cfg.use_pallas else "winograd"


def init(key, cfg: AlexNetConfig):
    dtype = jnp.dtype(cfg.dtype)
    keys = split(key, len(_LAYERS) + len(cfg.fc_dims))
    p = {}
    c_in = cfg.in_channels
    for i, ((spec, _, _), c_out) in enumerate(zip(_LAYERS,
                                                  cfg.conv_channels)):
        k, g = spec.kernel, spec.groups
        p[f"conv{i+1}"] = {
            "w": param(keys[i], (k, k, c_in // g, c_out), dtype,
                       scale=(k * k * c_in // g) ** -0.5),
            "b": jnp.zeros((c_out,), dtype),
        }
        c_in = c_out
    d_in = _fc_input_dim(cfg)
    for j, d_out in enumerate(cfg.fc_dims):
        p[f"fc{j+6}"] = {
            "w": param(keys[len(_LAYERS) + j], (d_in, d_out), dtype),
            "b": jnp.zeros((d_out,), dtype),
        }
        d_in = d_out
    return p


def _feature_hw(cfg: AlexNetConfig) -> int:
    h = cfg.image_size
    for (spec, _, pool) in _LAYERS:
        h = ((h - spec.kernel) // spec.stride + 1 if spec.padding == "VALID"
             else -(-h // spec.stride))
        if pool:
            h = (h - 3) // 2 + 1
    return h


def _fc_input_dim(cfg: AlexNetConfig) -> int:
    return _feature_hw(cfg) ** 2 * cfg.conv_channels[-1]


def _lrn(x, cfg: AlexNetConfig):
    """Cross-channel local response normalization (paper §2.2)."""
    sq = jnp.square(x)
    half = cfg.lrn_n // 2
    pad = jnp.pad(sq, ((0, 0), (0, 0), (0, 0), (half, half)))
    win = sum(pad[..., i:i + x.shape[-1]] for i in range(cfg.lrn_n))
    return x / jnp.power(cfg.lrn_k + cfg.lrn_alpha / cfg.lrn_n * win,
                         cfg.lrn_beta)


def _maxpool(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 3, 3, 1),
                                 (1, 2, 2, 1), "VALID")


def features(params, cfg: AlexNetConfig, images):
    """images (B, H, W, 3) -> flattened conv features (B, d)."""
    x = images.astype(jnp.dtype(cfg.dtype))
    route = _route(cfg)
    for i, (spec, lrn, pool) in enumerate(_LAYERS):
        p = params[f"conv{i+1}"]
        x = dispatch_conv(spec.with_route(route), x, p["w"], p["b"])
        if lrn:
            x = _lrn(x, cfg)
        if pool:
            x = _maxpool(x)
    return x.reshape(x.shape[0], -1)


def classifier(params, cfg: AlexNetConfig, feats):
    """Batched FC layers (paper §3.7: weights streamed, features cached)."""
    x = feats
    n_fc = len(cfg.fc_dims)
    for j in range(n_fc):
        p = params[f"fc{j+6}"]
        x = x @ p["w"].astype(x.dtype) + p["b"].astype(x.dtype)
        if j < n_fc - 1:
            x = jax.nn.relu(x)
    return x


def apply(params, cfg: AlexNetConfig, images):
    return classifier(params, cfg, features(params, cfg, images))


def loss_fn(params, cfg: AlexNetConfig, batch):
    logits = apply(params, cfg, batch["images"])
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    loss = -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()
    acc = (logits.argmax(-1) == labels).mean()
    return loss, {"loss": loss, "accuracy": acc}
