"""AlexNet — the paper's own benchmark network, end-to-end in JAX.

All layers run on-device (the paper's headline point vs conv-only FPGA
work): conv (Winograd F(4,3) for the 3x3 layers, the strided direct
datapath for conv1/conv2 as in the paper), ReLU, cross-channel LRN,
max-pool, and the batched FC layers (§3.7).  Each conv *layer* — including
its LRN/pool epilogue — is one :class:`~repro.nn.conv.ConvSpec`, and all
*five* layers are pallas-servable: under ``use_pallas`` the 3x3 layers hit
the Winograd-domain kernel and conv1 (11x11 stride 4) / conv2 (5x5) hit
the strided direct kernel, so every layer's post-conv stages run in VMEM
and no feature map round-trips HBM between conv, norm, and pool (§3.5) —
no layer falls back to ``lax.conv``.  Grouped convolutions (conv2/4/5)
follow Krizhevsky.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import List, Tuple

import jax
import jax.numpy as jnp

from ..kernels.bfp_matmul.ops import bfp_linear, fc_block, quantize_weights
from ..kernels.conv.dma import WeightStager
from ..nn.conv import ConvSpec, dispatch_conv, expected_pack_context, \
    pack_conv_weights, resolve_kernel
from ..nn.module import param, split
from ..nn.pooling import LrnParams


@dataclass(frozen=True)
class AlexNetConfig:
    """CNN model config.  ``arch="alexnet"`` is the paper's five-layer
    Krizhevsky topology; ``arch="vgg"`` reuses the same ConvSpec pipeline
    for a VGG-style stack (all-3x3 SAME convs, 2x2 s2 pools after the
    ``pool_after`` layers, no LRN) — the geometries the kernel sweep in
    ``tests/test_vgg_geometry.py`` validates, served as a second model by
    the fleet registry."""
    name: str = "alexnet"
    family: str = "cnn"
    arch: str = "alexnet"          # "alexnet" | "vgg" layer-table shape
    image_size: int = 227
    in_channels: int = 3
    conv_channels: Tuple[int, ...] = (96, 256, 384, 384, 256)
    pool_after: Tuple[int, ...] = ()   # vgg: 1-based conv indices with pool
    fc_dims: Tuple[int, ...] = (4096, 4096, 1000)
    num_classes: int = 1000
    use_winograd: bool = True      # F(4,3) on the 3x3 stride-1 layers
    use_pallas: bool = False       # route 3x3 convs through the Pallas kernel
    fc_batch: int = 96             # paper's S_batch
    fc_bfp: bool = False           # shared-exponent BFP FC weight stream §3.6
    conv_bfp: bool = False         # §3.6 BFP on the staged conv filter slabs
    weight_prefetch: bool = True   # §3.5 double-buffered in-kernel DMA stream
    sdc_abft: bool = False         # ABFT checksum row on the filter stream;
                                   # forward returns (logits, sdc_verdict)
    lrn_n: int = 5
    lrn_k: float = 2.0
    lrn_alpha: float = 1e-4
    lrn_beta: float = 0.75
    dtype: str = "float32"

    def reduced(self) -> "AlexNetConfig":
        if self.arch == "vgg":
            return replace(self, image_size=32, conv_channels=(8, 16, 16, 24),
                           pool_after=(1, 2, 4), fc_dims=(32, 24, 10),
                           num_classes=10, fc_batch=4)
        return replace(self, image_size=67, conv_channels=(16, 32, 48, 48, 32),
                       fc_dims=(64, 48, 10), num_classes=10, fc_batch=4)


def layer_specs(cfg: "AlexNetConfig") -> List[ConvSpec]:
    """The conv layers as fused layer-level specs, one per
    ``cfg.conv_channels`` entry.

    ``arch="alexnet"`` (Krizhevsky geometry): conv1/conv2 carry LRN + pool,
    conv5 pool only; every conv fuses bias+ReLU and routes through
    ``repro.nn.conv.dispatch_conv`` (the 3x3 stride-1 layers are
    Winograd-eligible; conv1/conv2 take the direct datapath — the strided
    Pallas kernel on the pallas route — as in the paper's non-Winograd
    first layer).

    ``arch="vgg"``: every layer is a 3x3 stride-1 SAME conv (all
    Winograd-eligible — the regime ``tests/test_vgg_geometry.py`` sweeps),
    with a fused 2x2 s2 max-pool after each layer index in
    ``cfg.pool_after`` and no LRN.
    """
    if cfg.arch == "vgg":
        return [ConvSpec(kernel=3, relu=True,
                         fuse_pool=(i + 1) in cfg.pool_after,
                         pool_window=2, pool_stride=2)
                for i in range(len(cfg.conv_channels))]
    lrn = LrnParams(n=cfg.lrn_n, k=cfg.lrn_k, alpha=cfg.lrn_alpha,
                    beta=cfg.lrn_beta)
    return [
        ConvSpec(kernel=11, stride=4, padding="VALID", relu=True,
                 fuse_lrn=True, lrn=lrn, fuse_pool=True),
        ConvSpec(kernel=5, groups=2, relu=True,
                 fuse_lrn=True, lrn=lrn, fuse_pool=True),
        ConvSpec(kernel=3, relu=True),
        ConvSpec(kernel=3, groups=2, relu=True),
        ConvSpec(kernel=3, groups=2, relu=True, fuse_pool=True),
    ]


def _route(cfg: "AlexNetConfig") -> str:
    """Model-wide route preference; per-layer eligibility lives in nn.conv."""
    if not cfg.use_winograd:
        return "direct"
    return "pallas" if cfg.use_pallas else "winograd"


def layer_routes(cfg: "AlexNetConfig") -> List[Tuple[str, str]]:
    """(layer name, fully resolved datapath) per conv layer — what serving
    logs print so ``--route pallas`` shows conv1/conv2 on ``pallas-direct``
    instead of silently degrading.  Shape-aware: each layer's input extent
    is threaded through, so the report matches what dispatch_conv runs."""
    route = _route(cfg)
    routes = []
    h = cfg.image_size
    for i, spec in enumerate(layer_specs(cfg)):
        routes.append((f"conv{i + 1}",
                       resolve_kernel(spec.with_route(route), in_hw=h)))
        h = spec.out_hw(h)
    return routes


def init(key, cfg: AlexNetConfig):
    dtype = jnp.dtype(cfg.dtype)
    specs = layer_specs(cfg)
    keys = split(key, len(specs) + len(cfg.fc_dims))
    p = {}
    c_in = cfg.in_channels
    for i, (spec, c_out) in enumerate(zip(specs, cfg.conv_channels)):
        k, g = spec.kernel, spec.groups
        p[f"conv{i+1}"] = {
            "w": param(keys[i], (k, k, c_in // g, c_out), dtype,
                       scale=(k * k * c_in // g) ** -0.5),
            "b": jnp.zeros((c_out,), dtype),
        }
        c_in = c_out
    d_in = _fc_input_dim(cfg)
    for j, d_out in enumerate(cfg.fc_dims):
        p[f"fc{j+6}"] = {
            "w": param(keys[len(specs) + j], (d_in, d_out), dtype),
            "b": jnp.zeros((d_out,), dtype),
        }
        d_in = d_out
    return p


def _feature_hw(cfg: AlexNetConfig) -> int:
    h = cfg.image_size
    for spec in layer_specs(cfg):
        h = spec.out_hw(h)
    return h


def _fc_input_dim(cfg: AlexNetConfig) -> int:
    return _feature_hw(cfg) ** 2 * cfg.conv_channels[-1]


def _stage_fc6(params, cfg: AlexNetConfig):
    """The §3.6 quantized FC weight stream fc6 will use — staged during
    conv5 so the quantization pass overlaps the last conv layer."""
    w = params["fc6"]["w"]
    return quantize_weights(w, block=fc_block(w.shape[0]))


def load_tuned_plans(cfg: AlexNetConfig, batch: int, *, path=None):
    """Tuned per-layer :class:`~repro.nn.conv.ConvPlan`s from the measured
    autotuner's persisted cache (``results/plans/``), keyed to this
    config's layer geometries at ``batch`` on the *current* backend —
    ``{}`` when nothing applicable is cached (layers run the defaults).
    See ``core/autotune.py`` / ``scripts/autotune_alexnet.py``."""
    from ..core.autotune import load_alexnet_plans
    return load_alexnet_plans(cfg, batch, path=path)


def pack_serving_slabs(params, cfg: AlexNetConfig, batch: int, *,
                       plans=None, fingerprint: bool = False) -> dict:
    """Pack-once serving slabs for one compiled batch shape: every conv
    layer's :class:`~repro.nn.conv.PackedConvWeights` (tile-packed, plan-
    blocked, §3.6 BFP-quantized under ``cfg.conv_bfp``), plus fc6's
    quantized BFP stream under ``cfg.fc_bfp``.

    This is the serving engines' enabling refactor: the dict is a pytree,
    so it is hoisted *out* of the jitted forward and passed back in as a
    jit argument (``apply(packed=...)``) — the compiled graph consumes the
    staged slabs instead of re-packing filters in-trace on every call,
    which is what the eager-path :class:`WeightStager` could never give
    the compiled path.  Pure function of (params, config, batch), so an
    engine packs each bucket's slabs exactly once.

    SDC defense: ``cfg.sdc_abft`` packs each slab with its per-tile ABFT
    checksum row (the kernels verify it in-stream); ``fingerprint=True``
    additionally stamps each slab with a pack-time
    :class:`~repro.nn.conv.SlabFingerprint` so the engine can verify slab
    integrity before every dispatch (``CnnServeConfig.verify_slabs``).
    Fingerprinting crcs the packed bytes on the host — fine here (packing
    is already a synchronous one-time cost per bucket), opt-in because the
    eager prefetch path cannot afford the device sync.
    """
    plans = plans or {}
    route = _route(cfg)
    specs = [s.with_route(route) for s in layer_specs(cfg)]
    packed = {}
    h, c_in = cfg.image_size, cfg.in_channels
    for i, (spec, c_out) in enumerate(zip(specs, cfg.conv_channels)):
        name = f"conv{i + 1}"
        packed[name] = pack_conv_weights(
            spec, (batch, h, h, c_in), params[name]["w"],
            bfp_pack=cfg.conv_bfp, abft=cfg.sdc_abft,
            fingerprint=fingerprint, plan=plans.get(name))
        h, c_in = spec.out_hw(h), c_out
    if cfg.fc_bfp:
        packed["fc6"] = _stage_fc6(params, cfg)
    return packed


def features(params, cfg: AlexNetConfig, images, *, stager=None, plans=None,
             packed=None):
    """images (B, H, W, 3) -> flattened conv features (B, d).

    One ``dispatch_conv`` per layer; the LRN/pool epilogues live in the
    layer specs, so there are no free-standing norm/pool calls here.

    Cross-layer weight staging (paper §3.5: "filters for the next layer
    are prefetched while the current layer is computed"): each layer's
    ``prefetch_next`` hook stages layer N+1's tile-packed slab
    (``pack_conv_weights`` — Winograd transform, DMA tile layout, §3.6
    BFP quantization under ``cfg.conv_bfp``) right after layer N's conv is
    issued, so the (async-dispatched) packing runs behind layer N's
    compute; conv5 stages fc6's quantized BFP stream when ``cfg.fc_bfp``.
    Pass a persistent :class:`WeightStager` (bound to this param set) to
    also reuse the packed slabs *across* forward passes — the host-level
    filter cache the serving path wants.  Values are identical staged or
    not; staging only moves work earlier.

    ``plans`` maps layer names (``"conv1"``..) to tuned
    :class:`~repro.nn.conv.ConvPlan`s (see :func:`load_tuned_plans`); a
    layer with a plan launches with its knobs — including the plan's
    ``weight_prefetch`` choice, which overrides ``cfg.weight_prefetch``
    for that layer — and its staged slab is packed for the same plan, so
    staging and dispatch always agree.  All plan knobs are bit-equal
    re-blockings; outputs are identical tuned or not.

    ``packed`` is a :func:`pack_serving_slabs` dict hoisted across the jit
    boundary: each layer consumes its pre-packed slab directly (a missing
    or shape-stale entry falls back to in-trace packing — identical
    values) and the stager/prefetch hooks are skipped, since the §3.5
    staging already happened once on the host.

    SDC defense: with ``cfg.sdc_abft`` each layer dispatches with
    ``abft=True`` and the return becomes ``(flat_features, sdc)`` where
    ``sdc`` is the summed int32 ABFT mismatch count across all conv layers
    — 0 on a clean pass, positive iff some staged filter tile's bits
    changed between pack and consumption.  The feature values themselves
    stay bit-identical to the unarmed forward.
    """
    x = images.astype(jnp.dtype(cfg.dtype))
    route = _route(cfg)
    abft = cfg.sdc_abft
    sdc = jnp.zeros((), jnp.int32)
    stager = WeightStager() if stager is None else stager
    specs = [s.with_route(route) for s in layer_specs(cfg)]

    if packed is not None:          # hoisted pack-once serving path
        plans = plans or {}
        for i, spec in enumerate(specs):
            p = params[f"conv{i + 1}"]
            plan = plans.get(f"conv{i + 1}")
            kw = ({"plan": plan} if plan is not None
                  else {"weight_prefetch": cfg.weight_prefetch})
            x = dispatch_conv(spec, x, p["w"], p["b"], abft=abft,
                              w_packed=packed.get(f"conv{i + 1}"), **kw)
            if abft:
                x, v = x
                sdc = sdc + v
        flat = x.reshape(x.shape[0], -1)
        return (flat, sdc) if abft else flat

    # the plan chain follows the *actual* input (the forward works for any
    # image size), so slabs staged here always match what dispatch resolves
    B, shapes, h, c_in = x.shape[0], [], x.shape[1], cfg.in_channels
    for spec, c_out in zip(specs, cfg.conv_channels):
        shapes.append((B, h, h, c_in))
        h, c_in = spec.out_hw(h), c_out

    staged = {}                     # per-forward handoff (tracer-safe)
    plans = plans or {}

    def stage(i):
        # the slab depends on the layer's input shape (batch included), the
        # quantization mode, and the launch plan it's blocked for, so the
        # persistent cache key carries all three — a stager serving mixed
        # batch sizes / configs / plans keeps one slab per combination and
        # can never serve the wrong quantization or blocking
        plan = plans.get(f"conv{i+1}")
        key = (f"conv{i+1}:{shapes[i]}:bfp{int(cfg.conv_bfp)}"
               f":abft{int(abft)}"
               + (f":plan{plan.to_dict()}" if plan is not None else ""))
        if key not in staged:
            # a verifying stager gets fingerprinted slabs plus the pack
            # context it should expect on cache hits, so a slab staged
            # under different fusion flags/knobs is repacked, not reused
            verify = getattr(stager, "verify", False)
            expect = (expected_pack_context(
                specs[i], shapes[i], bfp_pack=cfg.conv_bfp, abft=abft,
                plan=plan) if verify else None)
            staged[key] = stager.stage(
                key, pack_conv_weights, specs[i], shapes[i],
                params[f"conv{i+1}"]["w"], bfp_pack=cfg.conv_bfp,
                abft=abft, fingerprint=verify, plan=plan, expect=expect)
        return staged[key]

    def stage_fc():
        if "fc6" not in staged:
            staged["fc6"] = stager.stage("fc6", _stage_fc6, params, cfg)
        return staged["fc6"]

    for i, spec in enumerate(specs):
        p = params[f"conv{i+1}"]
        nxt = ((lambda i=i: stage(i + 1)) if i + 1 < len(specs)
               else (stage_fc if cfg.fc_bfp else None))
        plan = plans.get(f"conv{i+1}")
        # a tuned plan governs all launch knobs (its weight_prefetch was
        # part of the measured winner); untuned layers keep the config's
        kw = ({"plan": plan} if plan is not None
              else {"weight_prefetch": cfg.weight_prefetch})
        x = dispatch_conv(spec, x, p["w"], p["b"], w_packed=stage(i),
                          abft=abft, prefetch_next=nxt, **kw)
        if abft:
            x, v = x
            sdc = sdc + v
    flat = x.reshape(x.shape[0], -1)
    return (flat, sdc) if abft else flat


def classifier(params, cfg: AlexNetConfig, feats, *, stager=None,
               packed=None):
    """Batched FC layers (paper §3.7: weights streamed, features cached).

    With ``cfg.fc_bfp`` the weight stream moves as shared-exponent int8
    block floating point (§3.6, ``kernels/bfp_matmul``) — 1 byte/value on
    the paper's stated FC bandwidth bottleneck — instead of f32; fc6's
    quantized stream is taken from the ``stager`` when the conv phase
    staged it (``features``' last ``prefetch_next`` hook), or from a
    hoisted ``packed`` dict (:func:`pack_serving_slabs`) on the compiled
    serving path.
    """
    x = feats
    n_fc = len(cfg.fc_dims)
    for j in range(n_fc):
        p = params[f"fc{j+6}"]
        if cfg.fc_bfp:
            if j == 0 and packed is not None:
                q = packed.get("fc6")
            else:
                q = (stager.get("fc6")
                     if (j == 0 and stager is not None) else None)
            x = (bfp_linear(x, p["w"], quantized=q)
                 + p["b"].astype(jnp.float32)).astype(x.dtype)
        else:
            x = x @ p["w"].astype(x.dtype) + p["b"].astype(x.dtype)
        if j < n_fc - 1:
            x = jax.nn.relu(x)
    return x


def apply(params, cfg: AlexNetConfig, images, *, stager=None, plans=None,
          packed=None):
    """Full forward; one stager spans conv + FC so conv5's hook can stage
    the quantized fc6 stream (§3.5 prefetch across the conv/FC seam).
    ``plans`` carries tuned per-layer launch plans into :func:`features`;
    ``packed`` carries :func:`pack_serving_slabs` slabs hoisted across the
    jit boundary (pack-once compiled serving).  With ``cfg.sdc_abft`` the
    return is ``(logits, sdc)`` — the summed ABFT verdict rides alongside
    the logits through the classifier untouched."""
    stager = WeightStager() if stager is None else stager
    feats = features(params, cfg, images, stager=stager, plans=plans,
                     packed=packed)
    sdc = None
    if cfg.sdc_abft:
        feats, sdc = feats
    logits = classifier(params, cfg, feats, stager=stager, packed=packed)
    return (logits, sdc) if cfg.sdc_abft else logits


def loss_fn(params, cfg: AlexNetConfig, batch):
    logits = apply(params, cfg, batch["images"])
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    loss = -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()
    acc = (logits.argmax(-1) == labels).mean()
    return loss, {"loss": loss, "accuracy": acc}
