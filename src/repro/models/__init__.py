from . import lm  # noqa: F401


def model_for(cfg):
    """Dispatch to the model family implementation."""
    if cfg.family == "cnn":
        from . import alexnet
        return alexnet
    if cfg.family == "audio":
        from . import encdec
        return encdec
    if cfg.family == "vlm":
        from . import vlm
        return vlm
    return lm
