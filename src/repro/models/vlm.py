"""Phi-3-vision style VLM: phi3 backbone + stubbed CLIP patch frontend.

Per the assignment the vision tower is a STUB: ``input_specs`` provides
precomputed patch embeddings (B, P, clip_dim) which are linearly projected and
prepended to the token sequence.  Loss / logits cover token positions only.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..config import ArchConfig
from ..nn.blocks import stack_apply, stack_cache_shape, stack_init
from ..nn.layers import embed, embed_init, linear, linear_init, norm, norm_init
from ..nn.module import split
from ..parallel.sharding import constrain
from . import lm

CLIP_DIM = 1024


def init(key, cfg: ArchConfig):
    ke, ks, kp, kh = split(key, 4)
    dtype = jnp.dtype(cfg.param_dtype)
    p = {
        "embed": embed_init(ke, cfg.vocab_size, cfg.d_model, dtype),
        "patch_proj": linear_init(kp, CLIP_DIM, cfg.d_model, dtype),
        "stack": stack_init(ks, cfg),
        "final_norm": norm_init(cfg.norm_type, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = linear_init(kh, cfg.d_model, cfg.vocab_size, dtype)
    return p


def cache_shape(cfg: ArchConfig, batch: int, max_len: int):
    # cache covers patch prefix + generated tokens
    return stack_cache_shape(cfg, batch, cfg.num_patches + max_len)


def apply(params, cfg: ArchConfig, tokens, *, patches=None, mode: str = "train",
          length=None, caches=None, collect_aux: bool = False):
    dt = jnp.dtype(cfg.dtype)
    x = embed(params["embed"], tokens, dt)
    n_patch = 0
    if patches is not None:
        pe = linear(params["patch_proj"], patches.astype(dt))
        x = jnp.concatenate([pe, x], axis=1)
        n_patch = pe.shape[1]
    x = constrain(x, ("batch", "seq", "embed"))
    x, new_caches, aux = stack_apply(params["stack"], cfg, x, mode=mode,
                                     length=length, caches=caches,
                                     collect_aux=collect_aux)
    x = norm(cfg.norm_type, params["final_norm"], x[:, n_patch:, :])
    logits = lm._readout(params, cfg, x)
    return logits, new_caches, aux


def loss_fn(params, cfg: ArchConfig, batch, collect_aux: bool = True):
    """batch: {"patches": (B,P,1024), "inputs": (B,S), "targets": (B,S)}."""
    logits, _, aux = apply(params, cfg, batch["inputs"],
                           patches=batch["patches"], mode="train",
                           collect_aux=collect_aux)
    return lm._ce(logits, batch["targets"], aux, cfg)
