"""Decoder-only language model (dense / MoE / SSM / hybrid families)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..config import ArchConfig
from ..kernels.bfp_matmul.ops import bfp_linear
from ..nn.blocks import stack_apply, stack_cache_shape, stack_init
from ..nn.layers import embed, embed_attend, embed_init, linear, linear_init, norm, norm_init
from ..nn.module import split
from ..parallel.sharding import constrain


def init(key, cfg: ArchConfig):
    ke, ks, kh = split(key, 3)
    dtype = jnp.dtype(cfg.param_dtype)
    p = {
        "embed": embed_init(ke, cfg.vocab_size, cfg.d_model, dtype),
        "stack": stack_init(ks, cfg),
        "final_norm": norm_init(cfg.norm_type, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = linear_init(kh, cfg.d_model, cfg.vocab_size, dtype)
    return p


def cache_shape(cfg: ArchConfig, batch: int, max_len: int):
    return stack_cache_shape(cfg, batch, max_len)


def _readout(params, cfg, x):
    x = x.astype(jnp.dtype(cfg.dtype))
    if cfg.tie_embeddings:
        logits = embed_attend(params["embed"], x)
    elif cfg.fc_bfp:
        # paper §3.6 on the decode engine's FC path: every decode step
        # streams the full (d_model, vocab) head, so the weight bandwidth
        # bound is the paper's FC regime — move the stream as
        # shared-exponent int8 BFP (1 byte/value) instead of f32
        logits = bfp_linear(x, params["lm_head"]["w"])
    else:
        logits = linear(params["lm_head"], x, dtype=jnp.float32)
    return constrain(logits, ("batch", "seq", "vocab"))


def apply(params, cfg: ArchConfig, tokens, *, mode: str = "train",
          length=None, caches=None, collect_aux: bool = False):
    """tokens (B, S) int32 -> logits (B, S, V) f32.

    mode train: no caches.  prefill: caches filled, logits returned.
    decode: S new tokens (usually 1) appended at ``length``.
    """
    dt = jnp.dtype(cfg.dtype)
    x = embed(params["embed"], tokens, dt)
    x = constrain(x, ("batch", "seq", "embed"))
    x, new_caches, aux = stack_apply(params["stack"], cfg, x, mode=mode,
                                     length=length, caches=caches,
                                     collect_aux=collect_aux)
    x = norm(cfg.norm_type, params["final_norm"], x)
    logits = _readout(params, cfg, x)
    return logits, new_caches, aux


def loss_fn(params, cfg: ArchConfig, batch, collect_aux: bool = True):
    """batch: {"inputs": (B,S), "targets": (B,S)}; targets < 0 are masked."""
    logits, _, aux = apply(params, cfg, batch["inputs"], mode="train",
                           collect_aux=collect_aux)
    return _ce(logits, batch["targets"], aux, cfg)


def _ce(logits, targets, aux, cfg):
    """Vocab-shard-friendly cross entropy: the label logit comes from a fused
    select-reduce over the (sharded) vocab axis instead of take_along_axis,
    which would force GSPMD to all-gather full-vocab logits (measured: 12 GiB
    of temp per device on smollm train_4k before this formulation)."""
    valid = targets >= 0
    tgt = jnp.maximum(targets, 0)
    lf = logits.astype(jnp.float32)
    V = lf.shape[-1]
    m = jax.lax.stop_gradient(lf.max(axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]
    vio = jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
    onehot = vio == tgt[..., None]
    label_logit = jnp.sum(jnp.where(onehot, lf, 0.0), axis=-1)
    nll = lse - label_logit
    denom = jnp.maximum(valid.sum(), 1)
    loss = jnp.where(valid, nll, 0.0).sum() / denom
    total = loss + aux
    # accuracy via shard-local "is my label the global max" — argmax over a
    # sharded vocab axis would force an all-gather of the logits.
    is_max = label_logit >= m[..., 0]
    metrics = {"loss": loss, "aux_loss": aux, "tokens": denom,
               "accuracy": jnp.where(valid, is_max, False).sum() / denom}
    return total, metrics
