"""Whisper-style encoder-decoder backbone.

Per the assignment, the audio frontend (mel + conv) is a STUB: the encoder
consumes precomputed frame embeddings (B, T, d_model).  The decoder is a
standard causal stack with cross-attention into the encoder output.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..config import ArchConfig
from ..nn.blocks import stack_apply, stack_cache_shape, stack_init
from ..nn.layers import embed, embed_init, linear, linear_init, norm, norm_init
from ..nn.module import split
from ..parallel.sharding import constrain
from . import lm

CROSS_LEN_DEFAULT = 1500   # whisper 30s -> 1500 frames


def enc_cfg(cfg: ArchConfig) -> ArchConfig:
    return dataclasses.replace(cfg, num_layers=cfg.encoder_layers,
                               cross_attention=False, moe=None)


def init(key, cfg: ArchConfig):
    ke, kd, kte, kh = split(key, 4)
    dtype = jnp.dtype(cfg.param_dtype)
    p = {
        "enc_stack": stack_init(ke, enc_cfg(cfg)),
        "enc_norm": norm_init(cfg.norm_type, cfg.d_model, dtype),
        "embed": embed_init(kte, cfg.vocab_size, cfg.d_model, dtype),
        "dec_stack": stack_init(kd, cfg),
        "final_norm": norm_init(cfg.norm_type, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = linear_init(kh, cfg.d_model, cfg.vocab_size, dtype)
    return p


def cache_shape(cfg: ArchConfig, batch: int, max_len: int,
                cross_len: int = CROSS_LEN_DEFAULT):
    return stack_cache_shape(cfg, batch, max_len, cross_len=cross_len)


def encode(params, cfg: ArchConfig, frames):
    x = constrain(frames.astype(jnp.dtype(cfg.dtype)), ("batch", "seq", "embed"))
    x, _, _ = stack_apply(params["enc_stack"], enc_cfg(cfg), x, mode="bidir")
    return norm(cfg.norm_type, params["enc_norm"], x)


def apply(params, cfg: ArchConfig, tokens, *, frames=None, enc_out=None,
          mode: str = "train", length=None, caches=None,
          collect_aux: bool = False):
    if enc_out is None and frames is not None:
        enc_out = encode(params, cfg, frames)
    dt = jnp.dtype(cfg.dtype)
    x = embed(params["embed"], tokens, dt)
    x, new_caches, aux = stack_apply(params["dec_stack"], cfg, x, mode=mode,
                                     length=length, caches=caches,
                                     enc_out=enc_out, collect_aux=collect_aux)
    x = norm(cfg.norm_type, params["final_norm"], x)
    logits = lm._readout(params, cfg, x)
    return logits, new_caches, aux


def loss_fn(params, cfg: ArchConfig, batch, collect_aux: bool = True):
    """batch: {"frames": (B,T,d), "inputs": (B,S), "targets": (B,S)}."""
    logits, _, aux = apply(params, cfg, batch["inputs"],
                           frames=batch["frames"], mode="train",
                           collect_aux=collect_aux)
    return lm._ce(logits, batch["targets"], aux, cfg)
