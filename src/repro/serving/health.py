"""Per-engine health monitor + circuit breaker for the serving fleet.

PipeCNN's pipelined kernel chain shows how one stalled stage poisons
whole-pipeline throughput; the fleet analogue is one sick engine eating
the shared device queue while serving garbage.  :class:`HealthMonitor`
tracks consecutive datapath failures (launch exceptions, non-finite
retired logits) and walks a three-state machine:

    healthy --fail_threshold--> degraded --quarantine_threshold--> quarantined

* **healthy** — normal serving; any clean retirement resets the
  consecutive-failure count.
* **degraded** — elevated failures: the engine keeps serving (this is the
  warning state the route-degradation ladder reacts to), but one more run
  of failures quarantines it.
* **quarantined** — the circuit is open: ``allow_launch`` refuses
  dispatch, the registry stops admitting requests, queued work drains via
  deadline expiry.  After ``cooldown_ms`` the breaker goes *half-open*:
  exactly one probe launch is allowed through; a clean retirement closes
  the circuit (back to healthy), a failure re-arms the cooldown.

A hard crash (:class:`~repro.serving.faults.EngineCrash`) skips the
ladder via :meth:`force_quarantine`.  All transitions are recorded in
``events`` for the fleet stats/chaos artifact.

Distinct from the *route* degradation ladder in ``serving/cnn.py``
(pallas -> direct per bucket): health states describe whether the engine
may launch at all; route degradation swaps the datapath a bucket launches
on.  Both are reported in ``stats()``.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

from .clock import MONOTONIC, Clock

__all__ = ["HEALTHY", "DEGRADED", "QUARANTINED", "HealthMonitor"]

HEALTHY = "healthy"
DEGRADED = "degraded"
QUARANTINED = "quarantined"


class HealthMonitor:
    def __init__(self, *, fail_threshold: int = 3,
                 quarantine_threshold: int = 6,
                 cooldown_ms: float = 250.0,
                 clock: Optional[Clock] = None):
        assert 1 <= fail_threshold <= quarantine_threshold
        assert cooldown_ms >= 0
        self.clock = clock or MONOTONIC
        self.fail_threshold = fail_threshold
        self.quarantine_threshold = quarantine_threshold
        self.cooldown_ms = cooldown_ms
        self.state = HEALTHY
        self.consecutive_failures = 0
        self.failures_total = 0
        self.ok_total = 0
        self.events: List[Tuple[str, str, str]] = []   # (from, to, reason)
        self._t_quarantined: Optional[float] = None
        self._probe_inflight = False

    # -- transitions --------------------------------------------------------
    def _move(self, to: str, reason: str):
        if to != self.state:
            self.events.append((self.state, to, reason))
            self.state = to

    def record_ok(self):
        """A clean batch retirement: closes a half-open circuit, clears
        the consecutive-failure count, recovers degraded -> healthy."""
        self.ok_total += 1
        self.consecutive_failures = 0
        if self.state == QUARANTINED and self._probe_inflight:
            self._probe_inflight = False
            self._t_quarantined = None
            self._move(HEALTHY, "probe-ok")
        elif self.state == DEGRADED:
            self._move(HEALTHY, "recovered")

    def record_failure(self, kind: str = "failure"):
        """A datapath failure (launch exception / non-finite logits)."""
        self.failures_total += 1
        self.consecutive_failures += 1
        if self.state == QUARANTINED:
            if self._probe_inflight:            # half-open probe failed
                self._probe_inflight = False
                self._t_quarantined = self.clock.now()
                self.events.append((QUARANTINED, QUARANTINED,
                                    f"probe-failed:{kind}"))
            return
        if self.consecutive_failures >= self.quarantine_threshold:
            self._t_quarantined = self.clock.now()
            self._probe_inflight = False
            self._move(QUARANTINED, f"{kind} x{self.consecutive_failures}")
        elif self.consecutive_failures >= self.fail_threshold:
            self._move(DEGRADED, f"{kind} x{self.consecutive_failures}")

    def force_quarantine(self, reason: str = "crash"):
        """Immediate circuit-open (hard crash path) — no ladder."""
        self.consecutive_failures = max(self.consecutive_failures,
                                        self.quarantine_threshold)
        self._t_quarantined = self.clock.now()
        self._probe_inflight = False
        self._move(QUARANTINED, reason)

    # -- gate ---------------------------------------------------------------
    def allow_launch(self, now: Optional[float] = None) -> bool:
        """May the engine dispatch a forward right now?  Healthy/degraded:
        yes.  Quarantined: only a single half-open probe once the cooldown
        has elapsed (the probe stays "in flight" until a record_ok /
        record_failure resolves it)."""
        if self.state != QUARANTINED:
            return True
        if self._probe_inflight:
            return False
        now = self.clock.now() if now is None else now
        if (self._t_quarantined is not None
                and (now - self._t_quarantined) * 1e3 >= self.cooldown_ms):
            self._probe_inflight = True         # half-open: one probe
            return True
        return False

    def stats(self) -> dict:
        return {
            "state": self.state,
            "consecutive_failures": self.consecutive_failures,
            "failures_total": self.failures_total,
            "ok_total": self.ok_total,
            "events": [{"from": a, "to": b, "reason": r}
                       for a, b, r in self.events],
        }
