"""Workload-agnostic slot-scheduler core shared by the serving engines.

Both serving regimes in the paper reduce to the same bookkeeping: a fixed
pool of batch slots that admitted requests occupy while the accelerator
works, fed FIFO from a submission queue.  The token-decode :class:`Engine`
holds a slot for the lifetime of a request (its cache row lives there across
many decode ticks); the image :class:`CnnEngine` holds slots only for the
duration of one bucketed forward pass.  The scheduler owns slots, queue and
admission/retirement counters; the engines own all device state.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, List, Optional, Tuple

import numpy as np


class DrainTimeout(RuntimeError):
    """``run_until_done`` exhausted its step budget with work still in
    flight.  Carries a ``report`` dict (queued/staged/computing/retrying
    counts per engine) so a hung fleet fails loudly with its state instead
    of silently vanishing the in-flight requests."""

    def __init__(self, message: str, report: dict):
        super().__init__(message)
        self.report = report


class SlotScheduler:
    """Fixed slot pool + FIFO admission queue (no device state)."""

    def __init__(self, n_slots: int):
        assert n_slots > 0, n_slots
        self.n_slots = n_slots
        self.slot_req: List[Optional[object]] = [None] * n_slots
        # deque, not list: admission drains the queue head one request at a
        # time, and a deep backlog (the fleet traffic generator routinely
        # queues thousands) would make list.pop(0) O(n^2) overall.
        self.queue: Deque[object] = deque()
        self.submitted = 0
        self.completed = 0

    # -- queue --------------------------------------------------------------
    def submit(self, req) -> None:
        self.queue.append(req)
        self.submitted += 1

    def requeue(self, reqs) -> None:
        """Return previously admitted requests to the *front* of the queue
        (retry path: they keep their FIFO seniority) without re-counting
        them as submitted — each request is submitted exactly once."""
        self.queue.extendleft(reversed(list(reqs)))

    # -- slots --------------------------------------------------------------
    @property
    def active(self) -> np.ndarray:
        """Boolean occupancy mask, index-aligned with the slot pool."""
        return np.asarray([r is not None for r in self.slot_req], bool)

    @property
    def occupancy(self) -> int:
        return sum(r is not None for r in self.slot_req)

    @property
    def idle(self) -> bool:
        return not self.queue and self.occupancy == 0

    def occupied(self) -> List[Tuple[int, object]]:
        """Snapshot of (slot, request) pairs — safe to retire while iterating."""
        return [(i, r) for i, r in enumerate(self.slot_req) if r is not None]

    def admit(self, limit: Optional[int] = None) -> List[Tuple[int, object]]:
        """Move queued requests into free slots (FIFO, lowest slot first)."""
        out: List[Tuple[int, object]] = []
        for slot in range(self.n_slots):
            if not self.queue or (limit is not None and len(out) >= limit):
                break
            if self.slot_req[slot] is not None:
                continue
            req = self.queue.popleft()
            self.slot_req[slot] = req
            out.append((slot, req))
        return out

    def retire(self, slot: int):
        req = self.slot_req[slot]
        assert req is not None, f"retire of empty slot {slot}"
        self.slot_req[slot] = None
        self.completed += 1
        return req

    def release(self, slot: int):
        """Free a slot *without* counting a completion — the retry/expiry
        path: the request either re-queues or retires as expired, and the
        completed counter must only ever count served results."""
        req = self.slot_req[slot]
        assert req is not None, f"release of empty slot {slot}"
        self.slot_req[slot] = None
        return req


class LatencyTracker:
    """Submit->complete request latency percentiles (Tables 5-6 companion:
    the paper reports throughput; a serving system must also bound tail
    latency, which batching trades against).

    Bounded: samples live in a sliding window (``deque(maxlen=window)``) so
    a long-running fleet neither leaks memory nor pays an ever-growing
    ``np.percentile`` — and the reported p50/p90/p99 track *recent* traffic,
    which is what an SLO controller needs to react to.  ``total`` counts
    every recorded sample for throughput accounting.
    """

    def __init__(self, window: int = 4096):
        assert window >= 1, window
        self.window = window
        self._lat_s: Deque[float] = deque(maxlen=window)
        self.total = 0

    def record(self, seconds: float) -> None:
        self._lat_s.append(seconds)
        self.total += 1

    def __len__(self) -> int:
        return len(self._lat_s)

    def percentiles_ms(self, qs=(50, 90, 99)) -> dict:
        if not self._lat_s:
            return {f"p{q}": 0.0 for q in qs}
        a = np.asarray(self._lat_s)
        return {f"p{q}": float(np.percentile(a, q)) * 1e3 for q in qs}
