"""Multi-model serving fleet: several compiled networks behind one front
door.

The ROADMAP north star is production traffic — many models, many tenants —
while the paper's Tables 5-6 measure one AlexNet.  :class:`ModelRegistry`
closes that gap in software: each registered model gets its own
:class:`CnnEngine` (its own compiled buckets, pack-once weight slabs, SLO
policy and latency accounting), the engines share one *device slot budget*
(the fleet analogue of the DLA's fixed stream-buffer/slot capacity — a
registry refuses to register a model whose slot pool would oversubscribe
it), and one ``step()`` drives every engine's stage->launch->retire tick so
the models' transfers and forwards interleave on the shared device queue.

Front-door semantics: ``submit(model, req)`` routes through the target
engine's admission control (``try_submit``) — a shed request is reported to
the caller (False + ``req.shed``), never dropped on the floor, and a
*quarantined* engine (health circuit open) sheds at the front door rather
than queueing work it cannot launch.  ``stats()`` reports the per-model
Tables 5-6 metrics plus fleet aggregates (img/s, goodput, shed/expired
counts, worst-model p99, per-model health states).  ``run_until_done``
raises :class:`~repro.serving.scheduler.DrainTimeout` with a per-engine
drain report when the fleet cannot drain within its step budget.
"""
from __future__ import annotations

from typing import Dict, Optional

from .cnn import CnnEngine, CnnServeConfig, ImageRequest
from .faults import FaultInjector
from .scheduler import DrainTimeout


class ModelRegistry:
    """Named :class:`CnnEngine` fleet with a shared device slot budget."""

    def __init__(self, *, slot_budget: Optional[int] = None):
        assert slot_budget is None or slot_budget >= 1
        self.slot_budget = slot_budget
        self.engines: Dict[str, CnnEngine] = {}

    # -- registration -------------------------------------------------------
    @property
    def slots_used(self) -> int:
        return sum(e.sched.n_slots for e in self.engines.values())

    def register(self, name: str, cfg, scfg: CnnServeConfig, *, params=None,
                 seed: int = 0,
                 faults: Optional[FaultInjector] = None,
                 clock=None) -> CnnEngine:
        """Build and register one model's engine under ``name``.  Raises
        when the engine's slot pool (``max_batch * staging_depth``) would
        exceed the fleet's remaining device budget — oversubscription must
        fail loudly at registration, not as memory pressure under load."""
        if name in self.engines:
            raise ValueError(f"model {name!r} already registered")
        need = scfg.max_batch * scfg.staging_depth
        if (self.slot_budget is not None
                and self.slots_used + need > self.slot_budget):
            raise ValueError(
                f"registering {name!r} needs {need} slots but only "
                f"{self.slot_budget - self.slots_used} of "
                f"{self.slot_budget} remain; shrink max_batch or "
                f"staging_depth")
        eng = CnnEngine(cfg, scfg, params=params, seed=seed, faults=faults,
                        clock=clock)
        self.engines[name] = eng
        return eng

    def export_state(self) -> dict:
        """Per-model host-side state a process-level restart needs to
        rebuild this fleet (checkpointing hook for ``serving/worker.py``)."""
        return {name: eng.export_state()
                for name, eng in self.engines.items()}

    def __contains__(self, name: str) -> bool:
        return name in self.engines

    def __getitem__(self, name: str) -> CnnEngine:
        if name not in self.engines:
            raise KeyError(f"unknown model {name!r}; "
                           f"registered: {sorted(self.engines)}")
        return self.engines[name]

    # -- front door ---------------------------------------------------------
    def submit(self, model: str, req: ImageRequest) -> bool:
        """Route one request to its model's engine through admission
        control; False means shed (``req.shed`` is set and the engine's
        ``images_shed`` counter incremented).  A quarantined engine sheds
        at the front door (reason ``"unhealthy"``) — the registry never
        admits work the health circuit says cannot launch."""
        return self[model].try_submit(req)

    def step(self):
        """One fleet tick: every engine stages, launches, and retires —
        JAX dispatch is async, so the engines' H2D copies and forwards
        interleave on the device queue within one pass."""
        for eng in self.engines.values():
            eng.step()

    @property
    def idle(self) -> bool:
        return all(e.drained for e in self.engines.values())

    def drain_report(self) -> dict:
        return {name: eng.drain_report()
                for name, eng in self.engines.items()}

    def run_until_done(self, max_steps: int = 100_000) -> dict:
        """Step the fleet until every engine drains; returns the per-engine
        drain report.  Raises :class:`DrainTimeout` (report attached) when
        requests are still in flight after ``max_steps`` — a hung fleet
        must fail loudly, not return as if the work vanished."""
        for _ in range(max_steps):
            if self.idle:
                return self.drain_report()
            self.step()
        if self.idle:
            return self.drain_report()
        report = self.drain_report()
        stuck = sorted(n for n, r in report.items() if not r["drained"])
        raise DrainTimeout(
            f"fleet not drained after {max_steps} steps; stuck engines: "
            f"{stuck}", report)

    def reset_metrics(self):
        for eng in self.engines.values():
            eng.reset_metrics()

    # -- accounting ---------------------------------------------------------
    def stats(self) -> dict:
        """Per-model engine stats plus fleet aggregates."""
        per = {name: eng.stats() for name, eng in self.engines.items()}
        completed = sum(s["images_completed"] for s in per.values())
        shed = sum(s["images_shed"] for s in per.values())
        expired = sum(s["images_expired"] for s in per.values())
        return {
            "models": per,
            "fleet": {
                "images_completed": completed,
                "images_shed": shed,
                "images_expired": expired,
                "health": {name: s["health"]["state"]
                           for name, s in per.items()},
                "degraded_buckets": {name: s["degraded_buckets"]
                                     for name, s in per.items()
                                     if s["degraded_buckets"]},
                "accounting_balanced": all(s["accounting"]["balanced"]
                                           for s in per.values()),
                "imgs_per_s": sum(s["imgs_per_s"] for s in per.values()),
                "goodput_imgs_per_s": sum(s["goodput_imgs_per_s"]
                                          for s in per.values()),
                "worst_p99_ms": max(
                    (s["latency_ms"]["p99"] for s in per.values()),
                    default=0.0),
                "slots_used": self.slots_used,
                "slot_budget": self.slot_budget,
            },
        }
