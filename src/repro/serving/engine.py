"""Slot-based continuous-batching token engine (paper §3.7 generalized).

The paper batches images through the FC layers because FC throughput is
weight-bandwidth-bound: each streamed weight must be reused S_batch times.
LM decode is the same regime — every decode step streams the full
(model-sharded) weight set — so the engine keeps a fixed pool of ``max_batch``
cache slots and decodes all active slots in one batched step.  Prefill
(activation-bound, the paper's conv regime) runs per-request at admission,
and its cache rows are inserted into the batch pool.

Slot/queue bookkeeping lives in the shared :class:`SlotScheduler`
(``serving/scheduler.py``) — the same core that drives the image-serving
:class:`CnnEngine`; this module owns only the decode-specific device state
(cache pool, lengths, last tokens).

Request lifecycle: submit() -> queued -> admitted (prefill) -> decoding ->
finished (max_new or eos).  step() = admit + one batched decode; tokens/s
scales with occupancy exactly like the paper's FC batching curve.
"""
from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..config import ArchConfig
from ..models import model_for
from .scheduler import LatencyTracker, SlotScheduler


@dataclass
class ServeConfig:
    max_batch: int = 8
    max_len: int = 512
    prefill_bucket: int = 64          # prompts padded to multiples (fewer compiles)
    eos_id: int = -1                  # -1: disabled
    cross_len: int = 0                # enc-dec: encoder length


@dataclass
class Request:
    prompt: List[int]
    max_new: int = 16
    uid: int = field(default_factory=itertools.count().__next__)
    frames: Optional[np.ndarray] = None       # audio family
    patches: Optional[np.ndarray] = None      # vlm family
    # outputs
    generated: List[int] = field(default_factory=list)
    done: bool = False
    t_submit: float = 0.0
    t_done: float = 0.0


class Engine:
    def __init__(self, cfg: ArchConfig, scfg: ServeConfig, *, params=None,
                 seed: int = 0):
        self.cfg, self.scfg = cfg, scfg
        self.mod = model_for(cfg)
        if params is None:
            params = self.mod.init(jax.random.PRNGKey(seed), cfg)
        self.params = params

        B, L = scfg.max_batch, scfg.max_len
        kw = {}
        if cfg.family == "audio":
            kw["cross_len"] = scfg.cross_len or 128
        self.cache = jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype),
            self.mod.cache_shape(cfg, B, L, **kw))
        self.lengths = jnp.zeros((B,), jnp.int32)
        self.sched = SlotScheduler(B)
        self.latency = LatencyTracker()
        self.tokens_generated = 0
        self.decode_steps = 0
        self._t_decode = 0.0

        mod, ccfg = self.mod, cfg

        one_shape = self.mod.cache_shape(cfg, 1, L, **kw)

        def prefill(params, tokens, extras):
            onecache = jax.tree_util.tree_map(
                lambda s: jnp.zeros(s.shape, s.dtype), one_shape)
            logits, c, _ = mod.apply(params, ccfg, tokens, mode="prefill",
                                     caches=onecache, **extras)
            return logits.argmax(-1).astype(jnp.int32), c

        def insert(cache, one, slot):
            # batch axis: 0 for unrolled prefix blocks, 1 for scanned blocks
            # (leading axis there is the layer-group dim)
            def at(axis):
                def f(full, o):
                    idx = [0] * full.ndim
                    idx[axis] = slot
                    return jax.lax.dynamic_update_slice(
                        full, o.astype(full.dtype), tuple(idx))
                return f
            return {
                "prefix": [jax.tree_util.tree_map(at(0), c, o)
                           for c, o in zip(cache["prefix"], one["prefix"])],
                "scan": jax.tree_util.tree_map(at(1), cache["scan"],
                                               one["scan"]),
            }

        def decode(params, cache, last_tokens, lengths):
            logits, cache, _ = mod.apply(params, ccfg, last_tokens,
                                         mode="decode", length=lengths,
                                         caches=cache)
            return logits[:, 0].argmax(-1).astype(jnp.int32), cache

        self._prefill = jax.jit(prefill)
        self._insert = jax.jit(insert, donate_argnums=(0,), static_argnums=(2,))
        self._decode = jax.jit(decode, donate_argnums=(1,))
        self.last_tokens = jnp.zeros((B, 1), jnp.int32)

    # -- back-compat views over the shared scheduler ------------------------
    @property
    def queue(self) -> List[Request]:
        return self.sched.queue

    @property
    def active(self) -> np.ndarray:
        return self.sched.active

    @property
    def slot_req(self) -> List[Optional[Request]]:
        return self.sched.slot_req

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        req.t_submit = time.perf_counter()
        self.sched.submit(req)

    def _pad_len(self, n: int) -> int:
        # SSM/hybrid prefill state would absorb pad-token garbage, so those
        # families prefill at exact length (one compile per distinct length).
        if self.cfg.family in ("ssm", "hybrid"):
            return n
        b = self.scfg.prefill_bucket
        return min(-(-n // b) * b, self.scfg.max_len)

    def _admit(self):
        for slot, req in self.sched.admit():
            prompt = req.prompt[: self.scfg.max_len - req.max_new]
            plen = len(prompt)
            padded = self._pad_len(plen)
            toks = np.zeros((1, padded), np.int32)
            toks[0, :plen] = prompt
            extras = {}
            if self.cfg.family == "audio":
                fl = self.scfg.cross_len or 128
                fr = req.frames if req.frames is not None else \
                    np.zeros((fl, self.cfg.d_model), np.float32)
                extras["frames"] = jnp.asarray(fr)[None]
            if self.cfg.family == "vlm":
                pa = req.patches if req.patches is not None else \
                    np.zeros((self.cfg.num_patches, 1024), np.float32)
                extras["patches"] = jnp.asarray(pa)[None]
            greedy, one = self._prefill(self.params, jnp.asarray(toks), extras)
            # note: prefill over the padded region also wrote cache entries
            # past plen; lengths[slot]=plen masks them out of attention.
            self.cache = self._insert(self.cache, one, slot)
            extra_prefix = self.cfg.num_patches if self.cfg.family == "vlm" else 0
            self.lengths = self.lengths.at[slot].set(plen + extra_prefix)
            first_tok = int(jax.device_get(greedy)[0, plen - 1])
            self.last_tokens = self.last_tokens.at[slot, 0].set(first_tok)
            req.generated.append(first_tok)
            self.tokens_generated += 1

    def _retire(self):
        # one host sync per tick: fetch the whole lengths vector, index on host
        lengths = np.asarray(jax.device_get(self.lengths))
        for slot, req in self.sched.occupied():
            limit = (len(req.generated) >= req.max_new or
                     int(lengths[slot]) >= self.scfg.max_len - 1)
            eos = (self.scfg.eos_id >= 0 and req.generated and
                   req.generated[-1] == self.scfg.eos_id)
            if limit or eos:
                req.done = True
                req.t_done = time.perf_counter()
                self.latency.record(req.t_done - req.t_submit)
                self.sched.retire(slot)

    def step(self):
        """One engine tick: admit waiting requests, decode all active slots."""
        self._admit()
        mask = self.sched.active
        if not mask.any():
            return
        t0 = time.perf_counter()
        nxt, self.cache = self._decode(self.params, self.cache,
                                       self.last_tokens, self.lengths)
        nxt_host = np.asarray(jax.device_get(nxt))
        self._t_decode += time.perf_counter() - t0
        self.decode_steps += 1
        self.lengths = self.lengths + jnp.asarray(mask, jnp.int32)
        self.last_tokens = jnp.where(jnp.asarray(mask)[:, None],
                                     nxt[:, None], self.last_tokens)
        for slot in np.nonzero(mask)[0]:
            req = self.sched.slot_req[slot]
            req.generated.append(int(nxt_host[slot]))
            self.tokens_generated += 1
        self._retire()

    def run_until_done(self, max_steps: int = 100_000):
        for _ in range(max_steps):
            if self.sched.idle:
                break
            self.step()

    @property
    def decode_tokens_per_s(self) -> float:
        return self.tokens_generated / self._t_decode if self._t_decode else 0.0
