from .cnn import (CnnEngine, CnnServeConfig, ImageRequest,  # noqa: F401
                  bucket_sizes)
from .engine import Engine, Request, ServeConfig  # noqa: F401
from .policy import AdmissionController, DynamicBucketPolicy  # noqa: F401
from .registry import ModelRegistry  # noqa: F401
from .scheduler import LatencyTracker, SlotScheduler  # noqa: F401
