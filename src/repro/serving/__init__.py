from .clock import (MONOTONIC, Clock, MonotonicClock,  # noqa: F401
                    VirtualClock)
from .cnn import (CnnEngine, CnnServeConfig, ImageRequest,  # noqa: F401
                  bucket_sizes)
from .engine import Engine, Request, ServeConfig  # noqa: F401
from .faults import (FAULT_POINTS, EngineCrash, FaultInjector,  # noqa: F401
                     FaultSpec, TransientLaunchError, derive_seed)
from .health import (DEGRADED, HEALTHY, QUARANTINED,  # noqa: F401
                     HealthMonitor)
from .policy import AdmissionController, DynamicBucketPolicy  # noqa: F401
from .registry import ModelRegistry  # noqa: F401
from .scheduler import (DrainTimeout, LatencyTracker,  # noqa: F401
                        SlotScheduler)
from .supervisor import (Supervisor, SupervisorConfig,  # noqa: F401
                         WorkerDead, WorkerTimeout)
from .worker import WorkerModel, WorkerSpec  # noqa: F401
