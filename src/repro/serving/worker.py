"""Serving worker process: a :class:`ModelRegistry` behind a pickled pipe.

PipeCNN (PAPERS.md) decouples its data-mover and compute kernels into
independent concurrently-running units; the fleet-scale analogue is
decoupling the *serving host* itself: each worker is a separate OS process
owning its own JAX runtime, compiled buckets, packed weight slabs, and
:class:`~repro.serving.registry.ModelRegistry`, so one worker's crash,
stall, or leak cannot take down the rest of the fleet.  The parent-side
:class:`~repro.serving.supervisor.Supervisor` owns N of these and speaks
the small request/reply protocol below over a duplex
``multiprocessing.Pipe`` (messages are plain dicts + numpy arrays —
pickle-over-pipe, nothing fancier).

Protocol (every request carries a ``seq`` the reply echoes, so a reply
that arrives after its RPC timed out — a recovered stall — is recognised
and dropped instead of being matched to the wrong call):

==================  ======================================================
``submit``          enqueue one request ``{model, uid, image, deadline_ms,
                    retries}`` through the engine's admission control;
                    reply ``{accepted}`` (False = shed at the worker)
``step``            tick the registry ``n`` times (stage -> launch ->
                    retire overlap inside each engine); reply ``{drained}``
``retire_batch``    pop every finished request; reply ``{results: [...]}``
                    — per request: uid, status (``done``/``expired``),
                    logits/label, expire_reason, and the serving
                    provenance (``bucket``/``row``/``group``) a failover
                    verifier needs to rebuild the exact padded batch
``heartbeat``       liveness probe; reply carries queue depth + the
                    per-model accounting snapshot
``checkpoint``      persist every model's params (per-file crc32 manifest,
                    atomic publish) under ``<ckpt_dir>/<model>/``; reply
                    ``{paths}``
``stall``           chaos payload (``worker.stall``): sleep ``delay_ms``
                    before replying, so the supervisor's heartbeat
                    deadline trips without the process dying
``shutdown``        ack, close the pipe, exit 0
==================  ======================================================

Crash-consistent restart: at build, each model's params come from the
newest *intact* checkpoint under ``<ckpt_dir>/<model>/`` when one exists
(:func:`repro.checkpoint.restore` verifies the crc manifest and falls
back past a torn latest step), else from ``init(seed)`` — either way the
respawned worker repacks its weight slabs and reuses the persisted
autotuner plan cache (``results/plans/``, auto-loaded at engine build),
so a replacement worker serves bit-identical logits to the one that died.

The worker exits on a closed pipe (supervisor death) — no orphan
processes hold the device.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import numpy as np

__all__ = ["WorkerModel", "WorkerSpec", "worker_main"]


@dataclass(frozen=True)
class WorkerModel:
    """One model a worker serves: everything needed to rebuild its engine
    from scratch in a fresh process (spawn pickles this)."""
    name: str
    cfg: object                     # model config (frozen dataclass)
    scfg: object                    # CnnServeConfig
    seed: int = 0


@dataclass(frozen=True)
class WorkerSpec:
    """A worker's full build recipe — respawn == spawn(same spec)."""
    name: str
    models: Tuple[WorkerModel, ...]
    ckpt_dir: Optional[str] = None  # model params under <ckpt_dir>/<model>/
    warm: bool = True               # compile every bucket before 'ready'
    slot_budget: Optional[int] = None
    keep_checkpoints: int = 3


@dataclass
class _WorkerState:
    registry: object
    params: dict                    # model -> params pytree
    restored: dict                  # model -> restored step (None = init)
    live: Dict[int, tuple] = field(default_factory=dict)  # uid -> (model, req)
    ckpt_step: int = 0


def _model_ckpt_dir(spec: WorkerSpec, model: str) -> Optional[str]:
    return os.path.join(spec.ckpt_dir, model) if spec.ckpt_dir else None


def _build(spec: WorkerSpec) -> _WorkerState:
    """Registry construction + crash-consistent param recovery + warmup."""
    import jax

    from ..checkpoint import checkpoint as ckpt
    from ..models import model_for
    from .cnn import ImageRequest
    from .registry import ModelRegistry

    reg = ModelRegistry(slot_budget=spec.slot_budget)
    params, restored = {}, {}
    for wm in spec.models:
        mod = model_for(wm.cfg)
        p = mod.init(jax.random.PRNGKey(wm.seed), wm.cfg)
        d = _model_ckpt_dir(spec, wm.name)
        step = ckpt.latest_intact_step(d) if d else None
        if step is not None:
            # restore into the init structure: the intact-step scan already
            # skipped any torn latest checkpoint
            p = ckpt.restore(d, {"step": 0, "params": p},
                             step=step)["params"]
        params[wm.name] = p
        restored[wm.name] = step
        eng = reg.register(wm.name, wm.cfg, wm.scfg, params=p, seed=wm.seed)
        if spec.warm:
            rng = np.random.default_rng(wm.seed)
            for b in eng.buckets:
                for _ in range(b):
                    eng.submit(ImageRequest(image=rng.standard_normal(
                        (wm.cfg.image_size, wm.cfg.image_size,
                         wm.cfg.in_channels)).astype(np.float32)))
                eng.run_until_done()
            eng.reset_metrics()
    return _WorkerState(registry=reg, params=params, restored=restored)


def _retire_batch(st: _WorkerState) -> list:
    """Drain every terminal request out of the live table."""
    out = []
    for uid in list(st.live):
        model, req = st.live[uid]
        if req.done:
            out.append({"uid": uid, "model": model, "status": "done",
                        "logits": np.asarray(req.logits),
                        "label": req.label,
                        "bucket": req.served_bucket,
                        "row": req.served_row,
                        "group": req.served_group,
                        "attempts": req.attempts})
        elif req.expired:
            out.append({"uid": uid, "model": model, "status": "expired",
                        "expire_reason": req.expire_reason,
                        "attempts": req.attempts})
        else:
            continue
        del st.live[uid]
    return out


def _accounting(st: _WorkerState) -> dict:
    return {name: eng.accounting()
            for name, eng in st.registry.engines.items()}


def worker_main(conn, spec: WorkerSpec) -> None:
    """Child-process entry point (top-level so ``spawn`` can import it)."""
    from .cnn import ImageRequest

    try:
        st = _build(spec)
    except BaseException as e:          # surface build failures to parent
        try:
            conn.send({"op": "ready", "ok": False, "worker": spec.name,
                       "error": f"{type(e).__name__}: {e}"})
        finally:
            conn.close()
        raise
    conn.send({"op": "ready", "ok": True, "worker": spec.name, "pid":
               os.getpid(), "models": [m.name for m in spec.models],
               "restored": st.restored})

    while True:
        try:
            msg = conn.recv()
        except (EOFError, OSError):     # supervisor died: don't linger
            return
        op = msg.get("op")
        reply = {"op": op, "seq": msg.get("seq"), "worker": spec.name}
        if op == "submit":
            req = ImageRequest(image=msg["image"], uid=msg["uid"],
                               deadline_ms=msg.get("deadline_ms"),
                               retries=msg.get("retries", 2))
            accepted = st.registry.submit(msg["model"], req)
            if accepted:
                st.live[req.uid] = (msg["model"], req)
            reply.update(accepted=accepted)
        elif op == "step":
            for _ in range(max(int(msg.get("n", 1)), 1)):
                st.registry.step()
            reply.update(drained=st.registry.idle)
        elif op == "retire_batch":
            reply.update(results=_retire_batch(st))
        elif op == "heartbeat":
            reply.update(alive=True, pid=os.getpid(),
                         inflight=len(st.live),
                         accounting=_accounting(st))
        elif op == "checkpoint":
            from ..checkpoint import checkpoint as ckpt
            st.ckpt_step += 1
            paths = {}
            for name, p in st.params.items():
                paths[name] = ckpt.save(
                    _model_ckpt_dir(spec, name),
                    {"step": st.ckpt_step, "params": p},
                    keep=spec.keep_checkpoints)
            reply.update(paths=paths, step=st.ckpt_step)
        elif op == "stall":
            time.sleep(msg.get("delay_ms", 0.0) / 1e3)
            reply.update(stalled_ms=msg.get("delay_ms", 0.0))
        elif op == "shutdown":
            reply.update(bye=True)
            try:
                conn.send(reply)
            finally:
                conn.close()
            return
        else:
            reply.update(error=f"unknown op {op!r}")
        try:
            conn.send(reply)
        except (BrokenPipeError, OSError):
            return
