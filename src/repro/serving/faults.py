"""Deterministic, seeded fault injection for the serving stack.

The paper's 1020 img/s (§4) is measured on an uninterrupted pipeline; a
production fleet must keep its accounting and SLO story intact when a
launch throws, a logit goes non-finite, or a device stalls (the gap the
FPGA-accelerator surveys flag between benchmark and deployed systems).
:class:`FaultInjector` makes those failures *reproducible*: every fault
point is a named hook the engine calls at a pipeline stage, each point
counts its own opportunities, and firing decisions come from a per-point
seeded RNG stream (or an explicit opportunity-index schedule), so a chaos
run replays bit-identically from (seed, schedule) regardless of how other
points interleave.

Fault points (wired through ``CnnEngine._stage/_launch/_finish_oldest``):

==================  ======================================================
``stage.corrupt``   staging-buffer corruption: NaNs written into the host
                    staging buffer *after* the request images are copied
                    in (the pristine ``req.image`` survives for retry) —
                    caught downstream by the retire-time finiteness screen
``launch.transient``transient launch failure (RESOURCE_EXHAUSTED class):
                    the forward dispatch raises
                    :class:`TransientLaunchError`; the engine re-queues
                    the group with exponential backoff
``launch.crash``    hard engine crash: raises :class:`EngineCrash`; the
                    health monitor force-quarantines the engine (circuit
                    opens, cooldown, half-open probe)
``retire.nonfinite``NaN written into fetched logits before the screen —
                    models device-side numeric corruption; affected
                    requests are retried, never served the bad row
``retire.latency``  host-side latency spike (``delay_ms`` sleep) during
                    retirement — exercises deadline expiry and SLO
                    accounting without corrupting data
``worker.crash``    process-level chaos (fired by the *supervisor*, one
                    injector per worker): SIGKILL the worker process at
                    this pump opportunity — exercises heartbeat death
                    detection, failover re-dispatch, and crash-consistent
                    restart (``serving/supervisor.py``)
``worker.stall``    process-level chaos: the worker's command loop sleeps
                    ``delay_ms`` before replying, so the supervisor's
                    heartbeat deadline trips — exercises the liveness
                    ladder without killing the process
``slab.bitflip``    silent data corruption: one bit flipped in a staged
                    weight slab before dispatch (position drawn from the
                    point's payload RNG stream) — the SEU/DRAM-corruption
                    model ABFT exists for; caught by the in-kernel
                    checksum verdict and/or the slab fingerprint check
``slab.stale``      staging-path confusion: a *different layer's* slab is
                    served from the cache at dispatch — models the silent
                    stale-reuse bug class; caught by fingerprint context
                    verification (``CnnServeConfig.verify_slabs``)
``retire.plausible``bounded-magnitude logit perturbation (``magnitude``
                    added to one row) — *finite* corruption that defeats
                    the isfinite screen; caught only by the magnitude
                    bound (``CnnServeConfig.screen_abs_max``)
==================  ======================================================

Arming is zero-overhead when idle: the engine guards every hook with
``self.faults is not None``, and an armed injector with no matching spec
only bumps an integer opportunity counter — it never touches the data
path or draws from an RNG, so an armed-but-idle run is bit-identical to a
no-injector run (the CI chaos-smoke gate asserts exactly this).
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = ["FAULT_POINTS", "FaultSpec", "FaultInjector",
           "TransientLaunchError", "EngineCrash", "derive_seed"]

# order matters: each point's RNG stream is keyed by its index, so new
# points append (existing committed chaos schedules stay bit-reproducible)
FAULT_POINTS = ("stage.corrupt", "launch.transient", "launch.crash",
                "retire.nonfinite", "retire.latency",
                "worker.crash", "worker.stall",
                "slab.bitflip", "slab.stale", "retire.plausible")


class TransientLaunchError(RuntimeError):
    """A retryable launch failure (the RESOURCE_EXHAUSTED class: transient
    allocator pressure, queue-full, preemption).  The engine re-queues the
    group with backoff instead of crashing."""
    code = "RESOURCE_EXHAUSTED"


class EngineCrash(RuntimeError):
    """A hard, non-retryable engine failure.  The health monitor
    force-quarantines the engine; the registry stops admitting to it."""
    code = "ENGINE_CRASH"


@dataclass(frozen=True)
class FaultSpec:
    """When (and how) one fault point fires.

    ``rate``      per-opportunity firing probability, drawn from the
                  point's own seeded RNG stream.
    ``at``        explicit opportunity indices that always fire (0-based,
                  counted per point since arming) — exact schedules for
                  tests and committed chaos runs.
    ``limit``     cap on total firings (None = unbounded).
    ``delay_ms``  payload for ``retire.latency`` (spike duration).
    ``magnitude`` payload for ``retire.plausible`` (the finite offset
                  added to one logit row; 0.0 = the point's default).
    """
    rate: float = 0.0
    at: Tuple[int, ...] = ()
    limit: Optional[int] = None
    delay_ms: float = 0.0
    magnitude: float = 0.0

    def __post_init__(self):
        assert 0.0 <= self.rate <= 1.0, self.rate
        assert self.limit is None or self.limit >= 0, self.limit


def derive_seed(seed: int, name: str) -> int:
    """Stable per-engine seed derivation so a fleet-level chaos seed fans
    out into independent, reproducible per-engine streams."""
    return (int(seed) * 0x9E3779B1 + zlib.crc32(name.encode())) % (2 ** 31)


@dataclass
class FaultEvent:
    point: str
    opportunity: int                # per-point opportunity index that fired


class FaultInjector:
    """Seeded, named-point chaos source.  One injector per engine — each
    point owns an independent RNG stream (``default_rng([seed, point_i])``)
    and an opportunity counter, so the firing pattern is a pure function
    of (seed, specs) and the engine's own call sequence."""

    def __init__(self, seed: int = 0,
                 specs: Optional[Dict[str, FaultSpec]] = None):
        specs = dict(specs or {})
        unknown = set(specs) - set(FAULT_POINTS)
        if unknown:
            raise ValueError(f"unknown fault points {sorted(unknown)}; "
                             f"valid: {list(FAULT_POINTS)}")
        self.seed = seed
        self.specs = specs
        self._rng = {p: np.random.default_rng([seed, i])
                     for i, p in enumerate(FAULT_POINTS)}
        self._seen: Dict[str, int] = {p: 0 for p in FAULT_POINTS}
        self._fired: Dict[str, int] = {p: 0 for p in FAULT_POINTS}
        self.events: List[FaultEvent] = []

    def fire(self, point: str) -> Optional[FaultSpec]:
        """Record one opportunity at ``point``; return the spec iff the
        fault fires now.  No spec for the point -> counter bump only (no
        RNG draw, no perturbation of other points' streams)."""
        assert point in FAULT_POINTS, point
        i = self._seen[point]
        self._seen[point] = i + 1
        spec = self.specs.get(point)
        if spec is None:
            return None
        if spec.limit is not None and self._fired[point] >= spec.limit:
            return None
        hit = i in spec.at
        if not hit and spec.rate:
            hit = bool(self._rng[point].random() < spec.rate)
        if not hit:
            return None
        self._fired[point] += 1
        self.events.append(FaultEvent(point, i))
        return spec

    def payload_rng(self, point: str) -> np.random.Generator:
        """The point's own RNG stream, for fault *payloads* (which bit to
        flip, which row to perturb) — drawn from the same per-point stream
        as the firing decisions, so payload positions replay from (seed,
        specs) too.  Only call after :meth:`fire` returned a spec (a
        payload draw advances the stream)."""
        assert point in FAULT_POINTS, point
        return self._rng[point]

    @property
    def total_fired(self) -> int:
        return sum(self._fired.values())

    def summary(self) -> dict:
        """Per-point (opportunities, fired) — persisted next to chaos
        results so a replay can be checked against the original run."""
        return {p: {"opportunities": self._seen[p], "fired": self._fired[p]}
                for p in FAULT_POINTS
                if self._seen[p] or p in self.specs}
