"""SLO control plane for the CNN serving engines: dynamic occupancy
buckets + admission control.

The paper's §3.7 batching picks one S_batch ahead of time; a production
fleet faces a latency SLO under time-varying traffic, where the fixed
power-of-two ladder has two failure modes this module addresses:

* **Padding waste under bursty arrivals** — a burst of, say, 6 requests is
  padded to the 8-bucket forever, so every batch carries 25% dead compute
  and the backlog drains that much slower.  :class:`DynamicBucketPolicy`
  watches the recent admitted group sizes whenever the windowed p99 is
  over the SLO and *inserts a bucket at the dominant group size* — the
  ladder resizes to the traffic.  Extra buckets are bounded
  (``max_extra``), so the §3.7 bounded-recompile guarantee survives: at
  most ``O(log2 max_batch) + max_extra`` batch shapes ever compile.

* **Unbounded queueing past the SLO** — once the arrival rate exceeds the
  service rate, every queued request is already late and admitting more
  only pushes the tail further out.  :class:`AdmissionController` tracks
  an EWMA of the per-image service time and sheds a request when the
  estimated queue drain time at admission already exceeds the SLO budget
  (classic load shedding: protect the goodput of the requests that can
  still make their deadline).

Both are pure host-side bookkeeping — no device state — so they compose
with any engine that reports admitted group sizes and completion
latencies.  The fleet benchmark (``benchmarks/serve_fleet.py``) measures
the p99 deltas both levers buy on bursty/diurnal traces.
"""
from __future__ import annotations

from collections import Counter, deque
from typing import Deque, List, Optional, Tuple

from .scheduler import LatencyTracker


def bucket_sizes(max_batch: int) -> Tuple[int, ...]:
    """Powers of two below ``max_batch`` plus ``max_batch`` itself — the
    base §3.7 ladder every policy starts from."""
    assert max_batch >= 1, max_batch
    bs: List[int] = []
    b = 1
    while b < max_batch:
        bs.append(b)
        b *= 2
    bs.append(max_batch)
    return tuple(bs)


class DynamicBucketPolicy:
    """Resize the bucket ladder under a p99-latency SLO.

    Observes admitted group sizes and completion latencies over a sliding
    window; while the windowed p99 exceeds ``slo_ms`` it looks for the
    dominant group size whose current bucket pads by at least
    ``pad_frac`` and inserts that size as a new bucket (at most
    ``max_extra`` insertions, so jit compiles stay bounded).  Inserted
    buckets only ever *shrink* padding — group->bucket mapping stays
    next-bucket-up — so outputs are unchanged by construction; only the
    padded dead compute per batch drops.
    """

    def __init__(self, max_batch: int, slo_ms: float, *, max_extra: int = 2,
                 window: int = 64, min_samples: int = 16,
                 pad_frac: float = 0.2):
        assert slo_ms > 0 and max_extra >= 0
        self.max_batch = max_batch
        self.slo_ms = slo_ms
        self.max_extra = max_extra
        self.min_samples = min_samples
        self.pad_frac = pad_frac
        self.base = bucket_sizes(max_batch)
        self.extra: List[int] = []
        self.resizes: List[int] = []        # insertion log (stats/debug)
        self._admits: Deque[int] = deque(maxlen=window)
        self._lat = LatencyTracker(window=window)

    def buckets(self) -> Tuple[int, ...]:
        """The current ladder (base + inserted sizes, ascending)."""
        return tuple(sorted(set(self.base) | set(self.extra)))

    def observe_admit(self, group_size: int) -> None:
        self._admits.append(group_size)

    def observe_latency(self, seconds: float) -> None:
        self._lat.record(seconds)

    def p99_ms(self) -> float:
        return self._lat.percentiles_ms((99,))["p99"]

    def maybe_resize(self) -> Optional[int]:
        """Insert one bucket if the SLO is busted and padding waste is the
        dominant pattern; returns the inserted size (or None)."""
        if len(self.extra) >= self.max_extra:
            return None
        if len(self._lat) < self.min_samples or not self._admits:
            return None
        if self.p99_ms() <= self.slo_ms:
            return None
        ladder = self.buckets()
        counts = Counter(self._admits)
        for n, c in counts.most_common():
            if c < max(len(self._admits) // 4, 2):
                break                       # no dominant group size
            b = next(x for x in ladder if x >= n)
            if b > n and (b - n) / b >= self.pad_frac:
                self.extra.append(n)
                self.resizes.append(n)
                self._admits.clear()        # re-observe under the new ladder
                return n
        return None


class AdmissionController:
    """Shed requests the SLO can no longer absorb (load shedding).

    ``observe_batch(n_images, seconds)`` feeds an EWMA of the per-image
    service time from every retired batch; ``admit(backlog_images)``
    estimates the newcomer's queue drain time as ``backlog * t_img`` and
    rejects when that estimate already exceeds ``slo_ms * slack`` — the
    request would bust its deadline just waiting, so completing it would
    only steal service from requests that can still make theirs.  Before
    the first observation every request is admitted (no estimate, no
    grounds to shed).
    """

    def __init__(self, slo_ms: float, *, slack: float = 1.0,
                 ewma: float = 0.2):
        assert slo_ms > 0 and slack > 0 and 0 < ewma <= 1
        self.slo_ms = slo_ms
        self.slack = slack
        self.ewma = ewma
        self.t_img_ms: Optional[float] = None

    def observe_batch(self, n_images: int, seconds: float) -> None:
        per_ms = seconds * 1e3 / max(n_images, 1)
        self.t_img_ms = (per_ms if self.t_img_ms is None else
                         (1 - self.ewma) * self.t_img_ms
                         + self.ewma * per_ms)

    def estimated_wait_ms(self, backlog_images: int) -> float:
        if self.t_img_ms is None:
            return 0.0
        return backlog_images * self.t_img_ms

    def admit(self, backlog_images: int,
              deadline_ms: Optional[float] = None) -> bool:
        """Admit unless the estimated queue wait already busts the budget.
        A request-level ``deadline_ms`` tightens the budget to
        ``min(slo * slack, deadline)``: a request that would expire just
        waiting is shed at the door (reported) instead of burning a slot
        and retiring as expired after wasting service time."""
        budget = self.slo_ms * self.slack
        if deadline_ms is not None:
            budget = min(budget, deadline_ms)
        return self.estimated_wait_ms(backlog_images) <= budget
