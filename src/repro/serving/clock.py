"""Injectable time source for the serving stack.

Every latency-bearing decision in the serving tier — retry backoff,
deadline expiry, circuit-breaker cooldown, injected latency spikes — used
to read ``time.perf_counter()`` / ``time.sleep()`` directly, which made
the corresponding tests wall-clock-bound (real sleeps) and chaos replays
only *statistically* reproducible (a loaded CI runner shifts which
deadline fires first).  A :class:`Clock` is threaded through
:class:`~repro.serving.cnn.CnnEngine` and
:class:`~repro.serving.health.HealthMonitor` instead:

* :class:`MonotonicClock` — the production default; delegates to
  ``time.perf_counter`` / ``time.sleep``.  The module-level
  :data:`MONOTONIC` singleton is what every engine uses when no clock is
  passed, so the default path allocates nothing new.
* :class:`VirtualClock` — a manually advanced clock for tests and
  deterministic chaos replays: ``now()`` returns the virtual time,
  ``sleep()`` *advances* it instead of blocking, and ``advance()`` moves
  time forward explicitly.  Cooldown/deadline/backoff tests become exact
  and sleep-free: "wait out the 250 ms cooldown" is ``clock.advance(0.25)``.

The clock contract is monotone seconds (perf_counter semantics), not wall
time — nothing in serving needs calendar time.
"""
from __future__ import annotations

import time

__all__ = ["Clock", "MonotonicClock", "VirtualClock", "MONOTONIC"]


class Clock:
    """Time-source protocol: monotone ``now()`` seconds + ``sleep()``."""

    def now(self) -> float:
        raise NotImplementedError

    def sleep(self, seconds: float) -> None:
        raise NotImplementedError


class MonotonicClock(Clock):
    """Real time: ``time.perf_counter`` / ``time.sleep``."""

    def now(self) -> float:
        return time.perf_counter()

    def sleep(self, seconds: float) -> None:
        if seconds > 0:
            time.sleep(seconds)


class VirtualClock(Clock):
    """Manually advanced clock — deterministic, sleep-free tests.

    ``sleep`` advances virtual time (a component that sleeps still
    observes time passing), so engine code behaves identically under
    either clock; only the wall stops moving.
    """

    def __init__(self, t0: float = 0.0):
        self._t = float(t0)

    def now(self) -> float:
        return self._t

    def sleep(self, seconds: float) -> None:
        self.advance(seconds)

    def advance(self, seconds: float) -> float:
        assert seconds >= 0, f"clock cannot run backwards ({seconds})"
        self._t += seconds
        return self._t


MONOTONIC = MonotonicClock()
