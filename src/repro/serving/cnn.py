"""Batched image-inference serving engine (paper §3.5 + §3.7, serving form).

The paper's headline number — 1020 img/s AlexNet on Arria 10 — is a *serving*
result: images are admitted, batched through the conv pipeline, and the FC
layers amortize one weight stream over S_batch images.  :class:`CnnEngine`
reproduces that request-to-prediction path in software on top of the shared
:class:`SlotScheduler` core:

* **Occupancy buckets** — each admitted group is padded to the next
  power-of-two bucket (<= ``max_batch``), so ``jax.jit`` compiles at most
  ``O(log2 max_batch)`` batch shapes.  This is §3.7's S_batch with bounded
  recompiles; padded rows are zeros and are sliced off before retirement.
* **Double-buffered staging** — host->device image copies are dispatched
  asynchronously up to ``staging_depth`` groups ahead, so the H2D transfer
  of group N+1 overlaps the forward pass of group N — the software analogue
  of the §3.5 stream buffers (``core/streambuf.py`` is the training-input
  twin of the same idea).  The slot pool is sized ``max_batch *
  staging_depth`` so a full bucket can stage while another computes.
* **Data parallelism** — with ``data_parallel=True`` the parameters are
  replicated over a 1-axis device mesh and each bucket's batch axis is
  sharded across devices (``parallel/sharding.py``); buckets indivisible by
  the device count fall back to replicated placement.

Request lifecycle: submit() -> queued -> admitted (slots held for one
bucketed forward) -> staged (H2D in flight) -> computing -> finished
(logits + argmax label on the request).  Metrics mirror Tables 5-6:
img/s, average occupancy, per-bucket batch counts, and p50/p90/p99
request latency.
"""
from __future__ import annotations

import itertools
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

import jax
import numpy as np

from ..models import model_for
from ..parallel.sharding import (batch_sharding, data_parallel_mesh,
                                 replicated_sharding)
from .scheduler import LatencyTracker, SlotScheduler


@dataclass
class CnnServeConfig:
    max_batch: int = 8          # largest serve bucket (paper's S_batch knob)
    staging_depth: int = 2      # groups staged ahead of compute (§3.5 buffer)
    data_parallel: bool = False  # shard bucket batch axis over jax.devices()


@dataclass
class ImageRequest:
    image: np.ndarray           # (H, W, C) host-side float image
    uid: int = field(default_factory=itertools.count().__next__)
    # outputs
    logits: Optional[np.ndarray] = None   # (num_classes,) on completion
    label: Optional[int] = None           # argmax of logits
    done: bool = False
    t_submit: float = 0.0
    t_done: float = 0.0


def bucket_sizes(max_batch: int) -> Tuple[int, ...]:
    """Powers of two below ``max_batch`` plus ``max_batch`` itself."""
    assert max_batch >= 1, max_batch
    bs: List[int] = []
    b = 1
    while b < max_batch:
        bs.append(b)
        b *= 2
    bs.append(max_batch)
    return tuple(bs)


@dataclass
class _Group:
    """One admitted batch moving through the stage->compute->retire pipe."""
    slots: List[int]
    reqs: List[ImageRequest]
    bucket: int
    images: object              # device array (bucket, H, W, C), H2D async
    logits: object = None       # device array once compute is dispatched


class CnnEngine:
    def __init__(self, cfg, scfg: CnnServeConfig, *, params=None,
                 seed: int = 0):
        self.cfg, self.scfg = cfg, scfg
        self.mod = model_for(cfg)
        if params is None:
            params = self.mod.init(jax.random.PRNGKey(seed), cfg)
        self.buckets = bucket_sizes(scfg.max_batch)
        self.sched = SlotScheduler(scfg.max_batch * scfg.staging_depth)
        self.mesh = data_parallel_mesh() if scfg.data_parallel else None
        if self.mesh is not None:
            params = jax.device_put(params, replicated_sharding(self.mesh))
        self.params = params

        # tuned launch plans from the measured autotuner's persisted cache
        # (results/plans/) — loaded at build, keyed to this config's layer
        # geometries on the current backend; {} runs the defaults.  Plans
        # are bit-equal re-blockings, so serving outputs are unchanged.
        self.plans: Dict[str, object] = {}
        if hasattr(self.mod, "load_tuned_plans"):
            self.plans = self.mod.load_tuned_plans(cfg, scfg.max_batch)

        mod, ccfg, plans = self.mod, cfg, self.plans
        self._apply = jax.jit(
            (lambda p, x: mod.apply(p, ccfg, x, plans=plans)) if plans
            else (lambda p, x: mod.apply(p, ccfg, x)))
        self._staged: Deque[_Group] = deque()
        self._compute: Deque[_Group] = deque()
        self.latency = LatencyTracker()
        self.images_completed = 0
        self.batches_run = 0
        self.bucket_counts: Dict[int, int] = {}
        self._t_serve = 0.0

    # ------------------------------------------------------------------
    def submit(self, req: ImageRequest):
        expect = (self.cfg.image_size, self.cfg.image_size,
                  self.cfg.in_channels)
        shape = np.shape(req.image)
        if shape != expect:
            raise ValueError(f"image shape {shape} != expected {expect} "
                             f"for {self.cfg.name}")
        req.t_submit = time.perf_counter()
        self.sched.submit(req)

    def bucket_for(self, n: int) -> int:
        for b in self.buckets:
            if b >= n:
                return b
        return self.buckets[-1]

    def _put(self, host: np.ndarray):
        """Async H2D copy (transfer overlaps in-flight compute)."""
        if self.mesh is None:
            return jax.device_put(host)
        if host.shape[0] % self.mesh.devices.size == 0:
            return jax.device_put(host, batch_sharding(self.mesh, host.ndim))
        return jax.device_put(host, replicated_sharding(self.mesh))

    def _stage(self):
        """Admit queued requests into free slots and start their H2D copies."""
        while (self.sched.queue and
               len(self._staged) + len(self._compute) < self.scfg.staging_depth):
            group = self.sched.admit(limit=self.scfg.max_batch)
            if not group:
                break                                   # no free slots
            slots = [s for s, _ in group]
            reqs = [r for _, r in group]
            bucket = self.bucket_for(len(reqs))
            h, w, c = reqs[0].image.shape
            buf = np.zeros((bucket, h, w, c), np.float32)
            for i, r in enumerate(reqs):
                buf[i] = r.image
            self._staged.append(_Group(slots, reqs, bucket, self._put(buf)))

    def _launch(self):
        """Dispatch the forward pass for the oldest staged group (async)."""
        if self._staged:
            g = self._staged.popleft()
            g.logits = self._apply(self.params, g.images)
            self._compute.append(g)

    def _finish_oldest(self):
        """Block on the oldest computed group and retire its requests."""
        if not self._compute:
            return
        g = self._compute.popleft()
        logits = np.asarray(jax.device_get(g.logits))[: len(g.reqs)]
        now = time.perf_counter()
        for slot, req, row in zip(g.slots, g.reqs, logits):
            req.logits = row
            req.label = int(row.argmax())
            req.done = True
            req.t_done = now
            self.latency.record(now - req.t_submit)
            self.sched.retire(slot)
        self.images_completed += len(g.reqs)
        self.batches_run += 1
        self.bucket_counts[g.bucket] = self.bucket_counts.get(g.bucket, 0) + 1

    def step(self):
        """One tick: stage ahead (H2D), launch oldest staged, retire oldest
        computed — so transfer, compute, and host retirement overlap."""
        t0 = time.perf_counter()
        self._stage()
        self._launch()
        self._finish_oldest()
        self._t_serve += time.perf_counter() - t0

    def run_until_done(self, max_steps: int = 100_000):
        for _ in range(max_steps):
            if self.sched.idle and not self._staged and not self._compute:
                break
            self.step()

    def reset_metrics(self):
        """Zero throughput/latency counters (e.g. after jit warmup) without
        touching queue, slots, or compiled buckets."""
        self.latency = LatencyTracker()
        self.images_completed = 0
        self.batches_run = 0
        self.bucket_counts = {}
        self._t_serve = 0.0

    # ------------------------------------------------------------------
    @property
    def imgs_per_s(self) -> float:
        return self.images_completed / self._t_serve if self._t_serve else 0.0

    def stats(self) -> dict:
        return {
            "images_completed": self.images_completed,
            "batches_run": self.batches_run,
            "avg_occupancy": (self.images_completed / self.batches_run
                              if self.batches_run else 0.0),
            "bucket_counts": dict(sorted(self.bucket_counts.items())),
            "imgs_per_s": self.imgs_per_s,
            "latency_ms": self.latency.percentiles_ms(),
            "tuned_layers": sorted(self.plans),
        }
