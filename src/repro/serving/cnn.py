"""Batched image-inference serving engine (paper §3.5 + §3.7, serving form).

The paper's headline number — 1020 img/s AlexNet on Arria 10 — is a *serving*
result: images are admitted, batched through the conv pipeline, and the FC
layers amortize one weight stream over S_batch images.  :class:`CnnEngine`
reproduces that request-to-prediction path in software on top of the shared
:class:`SlotScheduler` core:

* **Occupancy buckets** — each admitted group is padded to the next bucket
  (<= ``max_batch``), so ``jax.jit`` compiles a bounded set of batch
  shapes.  The ladder starts at §3.7's powers of two; under an SLO
  (``slo_ms`` + ``dynamic_buckets``) a :class:`DynamicBucketPolicy` may
  insert up to ``max_extra_buckets`` sizes at the traffic's dominant group
  size, trimming padding waste while keeping recompiles bounded.  Padded
  rows are zeros and are sliced off before retirement.
* **Admission control** — with ``slo_ms`` + ``admission`` an
  :class:`AdmissionController` sheds requests (``try_submit`` -> False,
  ``req.shed`` set, counted in ``images_shed``) whose estimated queue wait
  already busts the SLO, protecting the goodput of requests that can still
  make their deadline.
* **Pack-once weight staging** — the model's §3.5 weight slabs
  (``pack_serving_slabs``: tile-packed, plan-blocked, optionally
  BFP-quantized) are packed exactly once per bucket shape on the host and
  passed to the compiled forward as *jit arguments* (the
  ``PackedConvWeights`` pytree), so the serving graph consumes staged
  slabs instead of re-packing filters in-trace every call; the staged
  image buffer is donated to the compiled call where the backend supports
  buffer donation.
* **Double-buffered staging** — host->device image copies are dispatched
  asynchronously up to ``staging_depth`` groups ahead, so the H2D transfer
  of group N+1 overlaps the forward pass of group N — the software analogue
  of the §3.5 stream buffers (``core/streambuf.py`` is the training-input
  twin of the same idea).  The slot pool is sized ``max_batch *
  staging_depth`` so a full bucket can stage while another computes.
* **Data parallelism** — with ``data_parallel=True`` the parameters are
  replicated over a 1-axis device mesh and each bucket's batch axis is
  sharded across devices (``parallel/sharding.py``); buckets indivisible by
  the device count fall back to replicated placement.

Request lifecycle: submit() -> queued -> admitted (slots held for one
bucketed forward) -> staged (H2D in flight) -> computing -> finished
(logits + argmax label on the request).  Metrics mirror Tables 5-6:
img/s, average occupancy, per-bucket batch counts, p50/p90/p99 request
latency — plus the fleet-serving companions: shed counts, within-SLO
completions, and goodput img/s.
"""
from __future__ import annotations

import itertools
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models import model_for
from ..parallel.sharding import (batch_sharding, data_parallel_mesh,
                                 replicated_sharding)
from .policy import AdmissionController, DynamicBucketPolicy, bucket_sizes
from .scheduler import LatencyTracker, SlotScheduler

__all__ = ["CnnEngine", "CnnServeConfig", "ImageRequest", "bucket_sizes"]


@dataclass
class CnnServeConfig:
    max_batch: int = 8          # largest serve bucket (paper's S_batch knob)
    staging_depth: int = 2      # groups staged ahead of compute (§3.5 buffer)
    data_parallel: bool = False  # shard bucket batch axis over jax.devices()
    # -- SLO control plane (serving/policy.py) --------------------------
    slo_ms: Optional[float] = None  # p99 latency SLO; None = no SLO policy
    dynamic_buckets: bool = False   # SLO-driven bucket-ladder resizing
    admission: bool = False         # SLO-driven load shedding (try_submit)
    max_extra_buckets: int = 2      # bound on inserted bucket shapes
    policy_window: int = 64         # sliding window the policy reacts to
    admission_slack: float = 1.0    # shed when est. wait > slo_ms * slack
    latency_window: int = 4096      # LatencyTracker ring size (bounded)


@dataclass
class ImageRequest:
    image: np.ndarray           # (H, W, C) host-side float image
    uid: int = field(default_factory=itertools.count().__next__)
    # outputs
    logits: Optional[np.ndarray] = None   # (num_classes,) on completion
    label: Optional[int] = None           # argmax of logits
    done: bool = False
    shed: bool = False          # rejected by admission control (never served)
    t_submit: float = 0.0
    t_done: float = 0.0


@dataclass
class _Group:
    """One admitted batch moving through the stage->compute->retire pipe."""
    slots: List[int]
    reqs: List[ImageRequest]
    bucket: int
    images: object              # device array (bucket, H, W, C), H2D async
    logits: object = None       # device array once compute is dispatched
    t_launch: float = 0.0       # forward dispatch time (service-time EWMA)
    first_compile: bool = False  # first time this bucket shape was launched


class CnnEngine:
    def __init__(self, cfg, scfg: CnnServeConfig, *, params=None,
                 seed: int = 0):
        self.cfg, self.scfg = cfg, scfg
        self.mod = model_for(cfg)
        if params is None:
            params = self.mod.init(jax.random.PRNGKey(seed), cfg)
        self._buckets = bucket_sizes(scfg.max_batch)
        self.sched = SlotScheduler(scfg.max_batch * scfg.staging_depth)
        self.mesh = data_parallel_mesh() if scfg.data_parallel else None
        if self.mesh is not None:
            params = jax.device_put(params, replicated_sharding(self.mesh))
        self.params = params
        # staging buffers carry the model's configured dtype — a non-fp32
        # model must not be silently fed fp32 (wrong input dtype + 2x the
        # H2D bytes the §3.5 stream buffer is sized for)
        self._buf_dtype = jnp.dtype(getattr(cfg, "dtype", "float32"))

        # SLO control plane: bucket resizing + load shedding (policy.py)
        self.policy = (DynamicBucketPolicy(
            scfg.max_batch, scfg.slo_ms, max_extra=scfg.max_extra_buckets,
            window=scfg.policy_window)
            if scfg.slo_ms and scfg.dynamic_buckets else None)
        self.admission = (AdmissionController(
            scfg.slo_ms, slack=scfg.admission_slack)
            if scfg.slo_ms and scfg.admission else None)

        # tuned launch plans from the measured autotuner's persisted cache
        # (results/plans/) — loaded at build, keyed to this config's layer
        # geometries on the current backend; {} runs the defaults.  Plans
        # are bit-equal re-blockings, so serving outputs are unchanged.
        self.plans: Dict[str, object] = {}
        if hasattr(self.mod, "load_tuned_plans"):
            self.plans = self.mod.load_tuned_plans(cfg, scfg.max_batch)

        # pack-once serving forward: weight slabs are packed per bucket
        # shape on the host (_slabs) and enter the compiled graph as jit
        # *arguments*; the staged image buffer is donated where the
        # backend implements donation (each buffer is consumed by exactly
        # one forward).
        mod, ccfg, plans = self.mod, cfg, self.plans
        self._hoist = hasattr(mod, "pack_serving_slabs")
        self._packed: Dict[int, dict] = {}
        self._compiled: set = set()
        donate = (2,) if jax.default_backend() in ("gpu", "tpu") else ()
        if self._hoist:
            self._apply = jax.jit(
                lambda p, slabs, x: mod.apply(p, ccfg, x, plans=plans,
                                              packed=slabs),
                donate_argnums=donate)
        else:
            self._apply = jax.jit(
                (lambda p, x: mod.apply(p, ccfg, x, plans=plans)) if plans
                else (lambda p, x: mod.apply(p, ccfg, x)))
        self._staged: Deque[_Group] = deque()
        self._compute: Deque[_Group] = deque()
        self.latency = LatencyTracker(window=scfg.latency_window)
        self.images_completed = 0
        self.images_shed = 0
        self.images_within_slo = 0
        self.batches_run = 0
        self.bucket_counts: Dict[int, int] = {}
        self._t_serve = 0.0

    def arm_slo(self, slo_ms: Optional[float], *, dynamic_buckets: bool =
                False, admission: bool = False):
        """Arm (or replace) the SLO control plane on a live engine.

        Serving deployments calibrate the SLO from *measured* service
        times — which needs a warmed engine — so the control plane must be
        attachable after warmup.  Compiled buckets, packed slabs, and
        counters are all kept; only the policy objects are rebuilt.
        """
        import dataclasses
        scfg = dataclasses.replace(self.scfg, slo_ms=slo_ms,
                                   dynamic_buckets=dynamic_buckets,
                                   admission=admission)
        self.scfg = scfg
        self.policy = (DynamicBucketPolicy(
            scfg.max_batch, scfg.slo_ms, max_extra=scfg.max_extra_buckets,
            window=scfg.policy_window)
            if scfg.slo_ms and scfg.dynamic_buckets else None)
        self.admission = (AdmissionController(
            scfg.slo_ms, slack=scfg.admission_slack)
            if scfg.slo_ms and scfg.admission else None)

    # ------------------------------------------------------------------
    @property
    def buckets(self) -> Tuple[int, ...]:
        """The current bucket ladder (static, or the policy's resized
        ladder under ``dynamic_buckets``)."""
        return self.policy.buckets() if self.policy else self._buckets

    def _validate(self, req: ImageRequest):
        expect = (self.cfg.image_size, self.cfg.image_size,
                  self.cfg.in_channels)
        shape = np.shape(req.image)
        if shape != expect:
            raise ValueError(f"image shape {shape} != expected {expect} "
                             f"for {self.cfg.name}")

    def submit(self, req: ImageRequest):
        """Unconditional submit (no admission control) — validates shape
        and queues the request."""
        self._validate(req)
        req.t_submit = time.perf_counter()
        self.sched.submit(req)

    def backlog_images(self) -> int:
        """Images ahead of a newcomer: queued + staged + computing."""
        return (len(self.sched.queue)
                + sum(len(g.reqs) for g in self._staged)
                + sum(len(g.reqs) for g in self._compute))

    def try_submit(self, req: ImageRequest) -> bool:
        """Admission-controlled submit: returns False (and marks
        ``req.shed``) when the SLO controller estimates the queue can no
        longer absorb the request; shed requests are counted in
        ``images_shed`` and never occupy a slot."""
        self._validate(req)
        if (self.admission is not None
                and not self.admission.admit(self.backlog_images())):
            req.shed = True
            self.images_shed += 1
            return False
        req.t_submit = time.perf_counter()
        self.sched.submit(req)
        return True

    def bucket_for(self, n: int) -> int:
        """Smallest bucket holding ``n`` requests.  A group larger than
        ``max_batch`` is a contract violation — admission must never build
        one — and raises instead of silently padding past the ladder
        (which would compile an undeclared shape)."""
        for b in self.buckets:
            if b >= n:
                return b
        raise ValueError(
            f"group of {n} exceeds max_batch={self.buckets[-1]}; "
            f"admission must cap groups at the largest bucket")

    def _put(self, host: np.ndarray):
        """Async H2D copy (transfer overlaps in-flight compute)."""
        if self.mesh is None:
            return jax.device_put(host)
        if host.shape[0] % self.mesh.devices.size == 0:
            return jax.device_put(host, batch_sharding(self.mesh, host.ndim))
        return jax.device_put(host, replicated_sharding(self.mesh))

    def _slabs(self, bucket: int):
        """The hoisted pack-once weight slabs for one bucket shape (packed
        on first use, then reused as jit arguments for every forward of
        that bucket — the compiled-path twin of the eager WeightStager)."""
        if bucket not in self._packed:
            packed = self.mod.pack_serving_slabs(self.params, self.cfg,
                                                 bucket, plans=self.plans)
            if self.mesh is not None:
                packed = jax.device_put(packed,
                                        replicated_sharding(self.mesh))
            self._packed[bucket] = packed
        return self._packed[bucket]

    def _stage(self):
        """Admit queued requests into free slots and start their H2D copies."""
        while (self.sched.queue and
               len(self._staged) + len(self._compute) < self.scfg.staging_depth):
            group = self.sched.admit(limit=self.scfg.max_batch)
            if not group:
                break                                   # no free slots
            slots = [s for s, _ in group]
            reqs = [r for _, r in group]
            if self.policy is not None:
                self.policy.observe_admit(len(reqs))
            bucket = self.bucket_for(len(reqs))
            h, w, c = reqs[0].image.shape
            buf = np.zeros((bucket, h, w, c), self._buf_dtype)
            for i, r in enumerate(reqs):
                buf[i] = r.image
            self._staged.append(_Group(slots, reqs, bucket, self._put(buf)))

    def _launch(self):
        """Dispatch the forward pass for the oldest staged group (async)."""
        if self._staged:
            g = self._staged.popleft()
            g.first_compile = g.bucket not in self._compiled
            self._compiled.add(g.bucket)
            g.t_launch = time.perf_counter()
            if self._hoist:
                g.logits = self._apply(self.params, self._slabs(g.bucket),
                                       g.images)
            else:
                g.logits = self._apply(self.params, g.images)
            self._compute.append(g)

    def _finish_oldest(self):
        """Block on the oldest computed group and retire its requests."""
        if not self._compute:
            return
        g = self._compute.popleft()
        logits = np.asarray(jax.device_get(g.logits))[: len(g.reqs)]
        now = time.perf_counter()
        slo_s = (self.scfg.slo_ms or 0.0) / 1e3
        for slot, req, row in zip(g.slots, g.reqs, logits):
            req.logits = row
            req.label = int(row.argmax())
            req.done = True
            req.t_done = now
            lat = now - req.t_submit
            self.latency.record(lat)
            if slo_s and lat <= slo_s:
                self.images_within_slo += 1
            if self.policy is not None:
                self.policy.observe_latency(lat)
            self.sched.retire(slot)
        # service-time EWMA feeds load shedding; a first-compile batch
        # carries the jit trace and would poison the estimate
        if self.admission is not None and not g.first_compile:
            self.admission.observe_batch(len(g.reqs), now - g.t_launch)
        if self.policy is not None:
            self.policy.maybe_resize()
        self.images_completed += len(g.reqs)
        self.batches_run += 1
        self.bucket_counts[g.bucket] = self.bucket_counts.get(g.bucket, 0) + 1

    def step(self):
        """One tick: stage ahead (H2D), launch oldest staged, retire oldest
        computed — so transfer, compute, and host retirement overlap."""
        t0 = time.perf_counter()
        self._stage()
        self._launch()
        self._finish_oldest()
        self._t_serve += time.perf_counter() - t0

    def run_until_done(self, max_steps: int = 100_000):
        for _ in range(max_steps):
            if self.sched.idle and not self._staged and not self._compute:
                break
            self.step()

    def reset_metrics(self):
        """Zero throughput/latency counters (e.g. after jit warmup) without
        touching queue, slots, compiled buckets, or the packed-slab and
        admission state (a warmed service-time estimate is kept)."""
        self.latency = LatencyTracker(window=self.scfg.latency_window)
        self.images_completed = 0
        self.images_shed = 0
        self.images_within_slo = 0
        self.batches_run = 0
        self.bucket_counts = {}
        self._t_serve = 0.0

    # ------------------------------------------------------------------
    @property
    def imgs_per_s(self) -> float:
        return self.images_completed / self._t_serve if self._t_serve else 0.0

    @property
    def goodput_imgs_per_s(self) -> float:
        """Within-SLO completions per serve-second (== img/s when no SLO
        is configured: every completion counts)."""
        if not self._t_serve:
            return 0.0
        good = (self.images_within_slo if self.scfg.slo_ms
                else self.images_completed)
        return good / self._t_serve

    def stats(self) -> dict:
        return {
            "images_completed": self.images_completed,
            "images_shed": self.images_shed,
            "images_within_slo": (self.images_within_slo
                                  if self.scfg.slo_ms else None),
            "batches_run": self.batches_run,
            "avg_occupancy": (self.images_completed / self.batches_run
                              if self.batches_run else 0.0),
            "bucket_counts": dict(sorted(self.bucket_counts.items())),
            "buckets": list(self.buckets),
            "bucket_resizes": list(self.policy.resizes) if self.policy else [],
            "imgs_per_s": self.imgs_per_s,
            "goodput_imgs_per_s": self.goodput_imgs_per_s,
            "latency_ms": self.latency.percentiles_ms(),
            "tuned_layers": sorted(self.plans),
        }
