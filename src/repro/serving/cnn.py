"""Batched image-inference serving engine (paper §3.5 + §3.7, serving form).

The paper's headline number — 1020 img/s AlexNet on Arria 10 — is a *serving*
result: images are admitted, batched through the conv pipeline, and the FC
layers amortize one weight stream over S_batch images.  :class:`CnnEngine`
reproduces that request-to-prediction path in software on top of the shared
:class:`SlotScheduler` core:

* **Occupancy buckets** — each admitted group is padded to the next bucket
  (<= ``max_batch``), so ``jax.jit`` compiles a bounded set of batch
  shapes.  The ladder starts at §3.7's powers of two; under an SLO
  (``slo_ms`` + ``dynamic_buckets``) a :class:`DynamicBucketPolicy` may
  insert up to ``max_extra_buckets`` sizes at the traffic's dominant group
  size, trimming padding waste while keeping recompiles bounded.  Padded
  rows are zeros and are sliced off before retirement.
* **Admission control** — with ``slo_ms`` + ``admission`` an
  :class:`AdmissionController` sheds requests (``try_submit`` -> False,
  ``req.shed`` set, counted in ``images_shed``) whose estimated queue wait
  already busts the SLO — or the request's own ``deadline_ms``, whichever
  is tighter — protecting the goodput of requests that can still make
  their deadline.
* **Pack-once weight staging** — the model's §3.5 weight slabs
  (``pack_serving_slabs``: tile-packed, plan-blocked, optionally
  BFP-quantized) are packed exactly once per bucket shape on the host and
  passed to the compiled forward as *jit arguments* (the
  ``PackedConvWeights`` pytree), so the serving graph consumes staged
  slabs instead of re-packing filters in-trace every call; the staged
  image buffer is donated to the compiled call where the backend supports
  buffer donation.
* **Double-buffered staging** — host->device image copies are dispatched
  asynchronously up to ``staging_depth`` groups ahead, so the H2D transfer
  of group N+1 overlaps the forward pass of group N — the software analogue
  of the §3.5 stream buffers (``core/streambuf.py`` is the training-input
  twin of the same idea).  The slot pool is sized ``max_batch *
  staging_depth`` so a full bucket can stage while another computes.
* **Data parallelism** — with ``data_parallel=True`` the parameters are
  replicated over a 1-axis device mesh and each bucket's batch axis is
  sharded across devices (``parallel/sharding.py``); buckets indivisible by
  the device count fall back to replicated placement.

Fault tolerance (the chaos layer — ``serving/faults.py`` +
``serving/health.py``):

* **Named fault points** — an armed :class:`FaultInjector` is consulted at
  ``stage.corrupt`` (host staging buffer), ``launch.transient`` /
  ``launch.crash`` (forward dispatch), and ``retire.nonfinite`` /
  ``retire.latency`` (retirement); with no injector the hooks are a single
  ``is not None`` check, and an armed-but-idle injector never touches the
  data path (bit-identical serving — the CI chaos gate).
* **Deadlines + bounded retry** — ``ImageRequest.deadline_ms`` /
  ``retries``: transient launch failures and non-finite logits re-queue
  the affected requests at the queue *front* with exponential backoff
  (``retry_backoff_ms * 2**(attempt-1)``) instead of crashing the engine;
  a request past its deadline or retry budget retires as **expired**
  (``req.expired`` + ``expire_reason``, counted in ``images_expired``,
  never silently dropped).  The accounting invariant is
  ``submitted == completed + shed + expired`` once drained.
* **Health monitor + circuit breaker** — retired logits pass a sampled
  finiteness screen (``screen_sample`` rows); consecutive datapath
  failures walk healthy -> degraded -> quarantined
  (:class:`HealthMonitor`), a quarantined engine stops launching (and
  ``try_submit`` sheds) until a half-open probe succeeds after
  ``cooldown_ms``.  A hard crash quarantines immediately.
* **Route degradation ladder** — ``degrade_threshold`` repeated datapath
  failures on one bucket flip *that bucket's* compiled forward onto the
  direct route (``use_winograd=False, use_pallas=False`` — the reference
  datapath every Pallas kernel is bit-checked against), recorded as a
  degradation event rather than an outage; other buckets keep the fast
  route.
* **Silent-data-corruption defense** — with the model's ``sdc_abft`` the
  compiled forward returns ``(logits, sdc)``: the kernels verify an ABFT
  checksum row on every staged filter tile as it streams through the
  §3.5 DMA pipe, and a positive verdict at retirement means some weight
  bits changed between pack and consumption — the batch is *never
  served*; the engine repacks the bucket's slabs from the pristine
  params and retries the group (counted in ``sdc_detections``, fed to
  the health monitor / degradation ladder like any datapath failure).
  ``verify_slabs`` adds a host-side pre-dispatch fingerprint check
  (shape/dtype/crc32/pack-context) on the staged slabs — the layer that
  catches corruption *and* stale-slab reuse before a forward is burned —
  and ``screen_abs_max`` arms a magnitude bound on the retirement screen
  for finite-but-implausible logits the isfinite screen cannot see.
  Injected via the ``slab.bitflip`` / ``slab.stale`` /
  ``retire.plausible`` fault points.

No Python exception escapes :meth:`step`: injected and real launch/device
errors are converted into the retry/health machinery above.

Request lifecycle: submit() -> queued -> admitted (slots held for one
bucketed forward) -> staged (H2D in flight) -> computing -> finished
(logits + argmax label on the request), with shed / expired as the
reported non-success terminals and retry loops back to queued.  Metrics
mirror Tables 5-6: img/s, average occupancy, per-bucket batch counts,
p50/p90/p99 request latency — plus the fleet-serving companions: shed /
expired / retried counts, within-SLO completions, goodput img/s, health
state, and the accounting block.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models import model_for
from ..parallel.sharding import (batch_sharding, data_parallel_mesh,
                                 replicated_sharding)
from .clock import MONOTONIC, Clock
from .faults import EngineCrash, FaultInjector, TransientLaunchError
from .health import QUARANTINED, HealthMonitor
from .policy import AdmissionController, DynamicBucketPolicy, bucket_sizes
from .scheduler import DrainTimeout, LatencyTracker, SlotScheduler

__all__ = ["CnnEngine", "CnnServeConfig", "ImageRequest", "bucket_sizes"]


@dataclass
class CnnServeConfig:
    max_batch: int = 8          # largest serve bucket (paper's S_batch knob)
    staging_depth: int = 2      # groups staged ahead of compute (§3.5 buffer)
    data_parallel: bool = False  # shard bucket batch axis over jax.devices()
    # -- SLO control plane (serving/policy.py) --------------------------
    slo_ms: Optional[float] = None  # p99 latency SLO; None = no SLO policy
    dynamic_buckets: bool = False   # SLO-driven bucket-ladder resizing
    admission: bool = False         # SLO-driven load shedding (try_submit)
    max_extra_buckets: int = 2      # bound on inserted bucket shapes
    policy_window: int = 64         # sliding window the policy reacts to
    admission_slack: float = 1.0    # shed when est. wait > slo_ms * slack
    latency_window: int = 4096      # LatencyTracker ring size (bounded)
    # -- fault tolerance (serving/faults.py + serving/health.py) --------
    retry_backoff_ms: float = 1.0   # exponential retry backoff base
    screen_sample: int = 8          # retired rows finiteness-screened (0=off)
    fail_threshold: int = 3         # consecutive failures -> degraded
    quarantine_threshold: int = 6   # consecutive failures -> quarantined
    cooldown_ms: float = 250.0      # circuit-breaker half-open cooldown
    degrade_threshold: int = 3      # per-bucket failures -> direct-route flip
    # -- SDC defense (ABFT verdicts ride the model's sdc_abft flag) -----
    verify_slabs: bool = False      # pre-dispatch slab fingerprint check
    screen_abs_max: Optional[float] = None  # |logit| bound on the screen


@dataclass
class ImageRequest:
    image: np.ndarray           # (H, W, C) host-side float image
    uid: int = field(default_factory=itertools.count().__next__)
    # -- fault-tolerance contract --------------------------------------
    deadline_ms: Optional[float] = None  # relative to submit; None = none
    retries: int = 2            # transient-failure re-launch budget
    attempts: int = 0           # failed launch/screen attempts consumed
    # outputs
    logits: Optional[np.ndarray] = None   # (num_classes,) on completion
    label: Optional[int] = None           # argmax of logits
    done: bool = False
    shed: bool = False          # rejected by admission control (never served)
    expired: bool = False       # deadline or retry budget exhausted
    expire_reason: Optional[str] = None   # "deadline" | "retries"
    t_submit: float = 0.0
    t_done: float = 0.0
    # serving provenance (set at retirement): the padded bucket shape this
    # request was served at, its row in that batch, and the uids of every
    # request in the group (row order).  A failover verifier rebuilds the
    # exact staged buffer from these and bit-checks against the jitted
    # direct forward at the same padded shape.
    served_bucket: Optional[int] = None
    served_row: Optional[int] = None
    served_group: Optional[Tuple[int, ...]] = None


@dataclass
class _Group:
    """One admitted batch moving through the stage->compute->retire pipe."""
    slots: List[int]
    reqs: List[ImageRequest]
    bucket: int
    images: object              # device array (bucket, H, W, C), H2D async
    logits: object = None       # device array once compute is dispatched
    sdc: object = None          # device scalar ABFT verdict (sdc_abft only)
    t_launch: float = 0.0       # forward dispatch time (service-time EWMA)
    first_compile: bool = False  # first time this bucket shape was launched


class CnnEngine:
    def __init__(self, cfg, scfg: CnnServeConfig, *, params=None,
                 seed: int = 0, faults: Optional[FaultInjector] = None,
                 clock: Optional[Clock] = None):
        self.cfg, self.scfg = cfg, scfg
        # injectable time source: deadlines, retry backoff, cooldowns, and
        # the injected latency spike all read this clock, so chaos replays
        # and timing tests run deterministic + sleep-free on VirtualClock
        self.clock = clock or MONOTONIC
        self.mod = model_for(cfg)
        if params is None:
            params = self.mod.init(jax.random.PRNGKey(seed), cfg)
        self._buckets = bucket_sizes(scfg.max_batch)
        self.sched = SlotScheduler(scfg.max_batch * scfg.staging_depth)
        self.mesh = data_parallel_mesh() if scfg.data_parallel else None
        if self.mesh is not None:
            params = jax.device_put(params, replicated_sharding(self.mesh))
        self.params = params
        # staging buffers carry the model's configured dtype — a non-fp32
        # model must not be silently fed fp32 (wrong input dtype + 2x the
        # H2D bytes the §3.5 stream buffer is sized for)
        self._buf_dtype = jnp.dtype(getattr(cfg, "dtype", "float32"))

        # SLO control plane: bucket resizing + load shedding (policy.py)
        self.policy = (DynamicBucketPolicy(
            scfg.max_batch, scfg.slo_ms, max_extra=scfg.max_extra_buckets,
            window=scfg.policy_window)
            if scfg.slo_ms and scfg.dynamic_buckets else None)
        self.admission = (AdmissionController(
            scfg.slo_ms, slack=scfg.admission_slack)
            if scfg.slo_ms and scfg.admission else None)

        # fault-tolerance plane: seeded chaos hooks (None = zero-overhead
        # pass-through) + the health state machine / circuit breaker
        self.faults = faults
        self.health = HealthMonitor(
            fail_threshold=scfg.fail_threshold,
            quarantine_threshold=scfg.quarantine_threshold,
            cooldown_ms=scfg.cooldown_ms,
            clock=self.clock)

        # route degradation ladder: the direct-route twin config this
        # engine falls back to per bucket after repeated datapath failures
        # (None when the model has no route knobs or already runs direct)
        uw = getattr(cfg, "use_winograd", None)
        if uw is None:
            self._primary_route, self._cfg_direct = "n/a", None
        else:
            self._primary_route = (
                "pallas" if getattr(cfg, "use_pallas", False)
                else ("winograd" if uw else "direct"))
            self._cfg_direct = (
                dataclasses.replace(cfg, use_winograd=False,
                                    use_pallas=False)
                if self._primary_route != "direct" else None)
        self._degraded: Set[int] = set()
        self._bucket_failures: Dict[int, int] = {}
        self.degradations: List[dict] = []

        # tuned launch plans from the measured autotuner's persisted cache
        # (results/plans/) — loaded at build, keyed to this config's layer
        # geometries on the current backend; {} runs the defaults.  Plans
        # are bit-equal re-blockings, so serving outputs are unchanged.
        self.plans: Dict[str, object] = {}
        if hasattr(self.mod, "load_tuned_plans"):
            self.plans = self.mod.load_tuned_plans(cfg, scfg.max_batch)

        # pack-once serving forward: weight slabs are packed per bucket
        # shape on the host (_slabs) and enter the compiled graph as jit
        # *arguments*; the staged image buffer is donated where the
        # backend implements donation (each buffer is consumed by exactly
        # one forward).
        mod, ccfg, plans = self.mod, cfg, self.plans
        self._hoist = hasattr(mod, "pack_serving_slabs")
        # SDC defense plane: when the model config arms sdc_abft the
        # compiled forward returns (logits, verdict) and retirement gates
        # on the verdict; verify_slabs adds the pre-dispatch fingerprint
        # check on the hoisted slabs.
        self._abft = bool(getattr(cfg, "sdc_abft", False))
        self.sdc_detections = 0
        self.slab_integrity_failures = 0
        self.screen_nonfinite = 0
        self.screen_magnitude = 0
        self._packed: Dict[int, dict] = {}
        self._packed_direct: Dict[int, dict] = {}
        self._compiled: set = set()
        self._compiled_direct: set = set()
        self._apply_direct = None       # built lazily on first degradation
        donate = (2,) if jax.default_backend() in ("gpu", "tpu") else ()
        if self._hoist:
            self._apply = jax.jit(
                lambda p, slabs, x: mod.apply(p, ccfg, x, plans=plans,
                                              packed=slabs),
                donate_argnums=donate)
        else:
            self._apply = jax.jit(
                (lambda p, x: mod.apply(p, ccfg, x, plans=plans)) if plans
                else (lambda p, x: mod.apply(p, ccfg, x)))
        self._staged: Deque[_Group] = deque()
        self._compute: Deque[_Group] = deque()
        # retry holding pen: (ready_time, [reqs]) groups waiting out their
        # exponential backoff before re-queueing at the queue front
        self._retry: List[Tuple[float, List[ImageRequest]]] = []
        self.latency = LatencyTracker(window=scfg.latency_window)
        self.images_submitted = 0
        self.images_completed = 0
        self.images_shed = 0
        self.images_expired = 0
        self.images_retried = 0
        self.images_within_slo = 0
        self.batches_run = 0
        self.batches_failed = 0
        self.bucket_counts: Dict[int, int] = {}
        self.shed_reasons: Dict[str, int] = {}
        self._t_serve = 0.0

    def arm_slo(self, slo_ms: Optional[float], *, dynamic_buckets: bool =
                False, admission: bool = False):
        """Arm (or replace) the SLO control plane on a live engine.

        Serving deployments calibrate the SLO from *measured* service
        times — which needs a warmed engine — so the control plane must be
        attachable after warmup.  Compiled buckets, packed slabs, and
        counters are all kept; only the policy objects are rebuilt.
        """
        scfg = dataclasses.replace(self.scfg, slo_ms=slo_ms,
                                   dynamic_buckets=dynamic_buckets,
                                   admission=admission)
        self.scfg = scfg
        self.policy = (DynamicBucketPolicy(
            scfg.max_batch, scfg.slo_ms, max_extra=scfg.max_extra_buckets,
            window=scfg.policy_window)
            if scfg.slo_ms and scfg.dynamic_buckets else None)
        self.admission = (AdmissionController(
            scfg.slo_ms, slack=scfg.admission_slack)
            if scfg.slo_ms and scfg.admission else None)

    def arm_faults(self, injector: Optional[FaultInjector]):
        """Attach (or detach) a fault injector on a live engine — chaos
        runs arm after jit warmup so the fault schedule's opportunity
        indices count serving launches, not compiles."""
        self.faults = injector

    # ------------------------------------------------------------------
    @property
    def buckets(self) -> Tuple[int, ...]:
        """The current bucket ladder (static, or the policy's resized
        ladder under ``dynamic_buckets``)."""
        return self.policy.buckets() if self.policy else self._buckets

    def _validate(self, req: ImageRequest):
        expect = (self.cfg.image_size, self.cfg.image_size,
                  self.cfg.in_channels)
        shape = np.shape(req.image)
        if shape != expect:
            raise ValueError(f"image shape {shape} != expected {expect} "
                             f"for {self.cfg.name}")

    def submit(self, req: ImageRequest):
        """Unconditional submit (no admission control) — validates shape
        and queues the request."""
        self._validate(req)
        req.t_submit = self.clock.now()
        self.images_submitted += 1
        self.sched.submit(req)

    def backlog_images(self) -> int:
        """Images ahead of a newcomer: queued + staged + computing +
        waiting out a retry backoff."""
        return (len(self.sched.queue)
                + sum(len(g.reqs) for g in self._staged)
                + sum(len(g.reqs) for g in self._compute)
                + self.retry_pending)

    def shed(self, req: ImageRequest, reason: str = "admission"):
        """Mark + count one shed request (reported, never dropped): the
        request still figures in ``submitted`` so the accounting invariant
        ``submitted == completed + shed + expired`` closes."""
        req.shed = True
        self.images_submitted += 1
        self.images_shed += 1
        self.shed_reasons[reason] = self.shed_reasons.get(reason, 0) + 1

    def try_submit(self, req: ImageRequest) -> bool:
        """Admission-controlled submit: returns False (and marks
        ``req.shed``) when the engine is quarantined or the SLO controller
        estimates the queue can no longer absorb the request before its
        budget (SLO or the request's own deadline); shed requests are
        counted in ``images_shed`` and never occupy a slot."""
        self._validate(req)
        if self.health.state == QUARANTINED:
            self.shed(req, "unhealthy")
            return False
        if (self.admission is not None
                and not self.admission.admit(self.backlog_images(),
                                             deadline_ms=req.deadline_ms)):
            self.shed(req, "admission")
            return False
        req.t_submit = self.clock.now()
        self.images_submitted += 1
        self.sched.submit(req)
        return True

    def bucket_for(self, n: int) -> int:
        """Smallest bucket holding ``n`` requests.  A group larger than
        ``max_batch`` is a contract violation — admission must never build
        one — and raises instead of silently padding past the ladder
        (which would compile an undeclared shape)."""
        for b in self.buckets:
            if b >= n:
                return b
        raise ValueError(
            f"group of {n} exceeds max_batch={self.buckets[-1]}; "
            f"admission must cap groups at the largest bucket")

    def _put(self, host: np.ndarray):
        """Async H2D copy (transfer overlaps in-flight compute)."""
        if self.mesh is None:
            return jax.device_put(host)
        if host.shape[0] % self.mesh.devices.size == 0:
            return jax.device_put(host, batch_sharding(self.mesh, host.ndim))
        return jax.device_put(host, replicated_sharding(self.mesh))

    def _slabs(self, bucket: int):
        """The hoisted pack-once weight slabs for one bucket shape (packed
        on first use, then reused as jit arguments for every forward of
        that bucket — the compiled-path twin of the eager WeightStager)."""
        if bucket not in self._packed:
            kw = ({"fingerprint": True} if self.scfg.verify_slabs else {})
            packed = self.mod.pack_serving_slabs(self.params, self.cfg,
                                                 bucket, plans=self.plans,
                                                 **kw)
            if self.mesh is not None:
                packed = jax.device_put(packed,
                                        replicated_sharding(self.mesh))
            self._packed[bucket] = packed
        return self._packed[bucket]

    # -- fault-tolerance internals -------------------------------------
    def _is_expired(self, req: ImageRequest, now: float) -> bool:
        return (req.deadline_ms is not None
                and now >= req.t_submit + req.deadline_ms / 1e3)

    def _retire_expired(self, req: ImageRequest, reason: str):
        """Terminal non-success retirement: reported via ``req.expired``
        and ``images_expired`` — never silently dropped."""
        req.expired = True
        req.expire_reason = reason
        self.images_expired += 1

    def _schedule_retry(self, reqs: List[ImageRequest], now: float):
        if not reqs:
            return
        attempt = min(r.attempts for r in reqs)
        delay_s = (self.scfg.retry_backoff_ms
                   * (2 ** max(attempt - 1, 0))) / 1e3
        self._retry.append((now + delay_s, reqs))
        self.images_retried += len(reqs)

    def _fail_one(self, slot: int, req: ImageRequest, now: float,
                  retry: List[ImageRequest]):
        """Disposition one request after a failed attempt: slot freed
        (no completion counted), then retry / expire by budget."""
        self.sched.release(slot)
        req.attempts += 1
        if self._is_expired(req, now):
            self._retire_expired(req, "deadline")
        elif req.attempts > req.retries:
            self._retire_expired(req, "retries")
        else:
            retry.append(req)

    def _requeue_group(self, g: _Group):
        """A whole-group launch failure: free the slots and send every
        request through the retry/expiry disposition with backoff."""
        now = self.clock.now()
        retry: List[ImageRequest] = []
        for slot, req in zip(g.slots, g.reqs):
            self._fail_one(slot, req, now, retry)
        self._schedule_retry(retry, now)

    def _pump_retries(self):
        """Move retry groups whose backoff has elapsed to the queue front
        (they keep FIFO seniority); expire any that ran out of deadline
        while waiting."""
        if not self._retry:
            return
        now = self.clock.now()
        ready = [e for e in self._retry if e[0] <= now]
        if not ready:
            return
        self._retry = [e for e in self._retry if e[0] > now]
        for _, reqs in sorted(ready, key=lambda e: e[0], reverse=True):
            live = []
            for r in reqs:
                if self._is_expired(r, now):
                    self._retire_expired(r, "deadline")
                else:
                    live.append(r)
            if live:
                self.sched.requeue(live)

    def _note_datapath_failure(self, bucket: int, kind: str):
        """Count per-bucket datapath failures toward the degradation
        ladder: ``degrade_threshold`` repeated failures flip that bucket's
        forward onto the direct route (recorded, not an outage)."""
        if self._cfg_direct is None or bucket in self._degraded:
            return
        n = self._bucket_failures.get(bucket, 0) + 1
        self._bucket_failures[bucket] = n
        if n >= self.scfg.degrade_threshold:
            self._degraded.add(bucket)
            self.degradations.append({
                "bucket": bucket, "reason": kind, "failures": n,
                "from": self._primary_route, "to": "direct"})

    def _direct_apply(self):
        """The degraded-bucket forward: same model, direct route (the
        bit-checked reference datapath), no tuned plans — compiled lazily
        on the first degradation."""
        if self._apply_direct is None:
            mod, cfg_d = self.mod, self._cfg_direct
            if self._hoist:
                self._apply_direct = jax.jit(
                    lambda p, slabs, x: mod.apply(p, cfg_d, x, packed=slabs))
            else:
                self._apply_direct = jax.jit(
                    lambda p, x: mod.apply(p, cfg_d, x))
        return self._apply_direct

    def _slabs_direct(self, bucket: int):
        if bucket not in self._packed_direct:
            packed = self.mod.pack_serving_slabs(self.params,
                                                 self._cfg_direct, bucket)
            if self.mesh is not None:
                packed = jax.device_put(packed,
                                        replicated_sharding(self.mesh))
            self._packed_direct[bucket] = packed
        return self._packed_direct[bucket]

    # -- SDC defense internals -----------------------------------------
    def _slab_entries(self, packed: dict) -> List[str]:
        """Names of the packed entries that are injectable/verifiable conv
        slabs (a device tile array behind a PackedConvWeights), sorted for
        deterministic payload-RNG indexing."""
        return sorted(k for k, v in packed.items()
                      if hasattr(v, "kernel")
                      and getattr(v, "data", None) is not None)

    def _inject_bitflip(self, bucket: int):
        """``slab.bitflip`` payload: flip one bit — layer, byte, and bit
        position all drawn from the point's seeded payload stream — in the
        bucket's staged slab cache.  The pristine params are untouched, so
        the repack after detection restores a clean slab."""
        packed = self._slabs(bucket)
        names = self._slab_entries(packed)
        if not names:
            return
        rng = self.faults.payload_rng("slab.bitflip")
        name = names[int(rng.integers(len(names)))]
        pw = packed[name]
        host = np.array(jax.device_get(pw.data))
        flat = host.view(np.uint8).reshape(-1)
        flat[int(rng.integers(flat.size))] ^= np.uint8(
            1 << int(rng.integers(8)))
        self._packed[bucket] = {
            **packed, name: dataclasses.replace(pw, data=jnp.asarray(host))}

    def _inject_stale(self, bucket: int):
        """``slab.stale`` payload: one layer's cache entry starts serving a
        *different* layer's slab data (its pack-time fingerprint stays, so
        only the fingerprint check can tell) — the silent stale-reuse bug
        class the ``verify_slabs`` path exists to catch."""
        packed = self._slabs(bucket)
        names = self._slab_entries(packed)
        if len(names) < 2:
            return
        rng = self.faults.payload_rng("slab.stale")
        i = int(rng.integers(len(names)))
        victim, donor = names[i], names[(i + 1) % len(names)]
        self._packed[bucket] = {
            **packed, victim: dataclasses.replace(
                packed[victim], data=packed[donor].data)}

    def _slabs_intact(self, bucket: int, degraded: bool) -> bool:
        """Pre-dispatch fingerprint verification of the bucket's staged
        slabs (shape/dtype/crc32 against pack time).  Unfingerprinted
        entries pass — the check is opt-in per slab."""
        cache = self._packed_direct if degraded else self._packed
        packed = cache.get(bucket)
        if packed is None:
            return True
        from ..nn.conv import verify_packed
        return all(verify_packed(v) for v in packed.values()
                   if hasattr(v, "kernel"))

    def _fail_batch(self, g: _Group, kind: str, *, repack: bool = False):
        """Common datapath-failure disposition: count, feed health and the
        degradation ladder, optionally drop the bucket's staged slabs (so
        the retry repacks from the pristine params), re-queue the group."""
        self.batches_failed += 1
        self.health.record_failure(kind)
        self._note_datapath_failure(g.bucket, kind)
        if repack:
            self._packed.pop(g.bucket, None)
            self._packed_direct.pop(g.bucket, None)
        self._requeue_group(g)

    def _screen(self, logits: np.ndarray) -> np.ndarray:
        """Sampled screen on retired logits: True = row may be served.
        ``screen_sample`` rows are checked (all rows when the sample covers
        the group).  Two verdicts, counted separately: a NaN/Inf row
        (``screen_nonfinite``) and — with ``screen_abs_max`` — a finite row
        whose magnitude busts the bound (``screen_magnitude``, the
        plausible-corruption class ``retire.plausible`` injects).  A
        screened-out row is never served; the request retries from its
        pristine host image instead."""
        n = len(logits)
        ok = np.ones(n, bool)
        k = self.scfg.screen_sample
        if not n or k <= 0:
            return ok
        idx = (np.arange(n) if k >= n
               else np.unique(np.linspace(0, n - 1, k).astype(int)))
        rows = logits[idx].astype(np.float32)
        finite = np.isfinite(rows).all(axis=1)
        self.screen_nonfinite += int((~finite).sum())
        ok[idx] = finite
        amax = self.scfg.screen_abs_max
        if amax is not None:
            bounded = (np.abs(np.where(np.isfinite(rows), rows, 0.0))
                       .max(axis=1) <= amax)
            self.screen_magnitude += int((finite & ~bounded).sum())
            ok[idx] &= bounded
        return ok

    def _quarantine_purge(self):
        """While the circuit is open: unstage held groups (slots freed,
        requests back to the queue front — they re-stage after recovery)
        and expire overdue queued requests so a quarantined engine still
        drains instead of hoarding work."""
        now = self.clock.now()
        while self._staged:
            g = self._staged.popleft()
            live = []
            for slot, req in zip(g.slots, g.reqs):
                self.sched.release(slot)
                if self._is_expired(req, now):
                    self._retire_expired(req, "deadline")
                else:
                    live.append(req)
            if live:
                self.sched.requeue(live)
        q = self.sched.queue
        for _ in range(len(q)):         # stable full rotation
            r = q.popleft()
            if self._is_expired(r, now):
                self._retire_expired(r, "deadline")
            else:
                q.append(r)

    # -- pipeline ------------------------------------------------------
    def _stage(self):
        """Admit queued requests into free slots and start their H2D copies.
        Requests already past their deadline at admission retire as
        expired instead of burning a forward."""
        while (self.sched.queue and
               len(self._staged) + len(self._compute) < self.scfg.staging_depth):
            group = self.sched.admit(limit=self.scfg.max_batch)
            if not group:
                break                                   # no free slots
            now = self.clock.now()
            slots, reqs = [], []
            for s, r in group:
                if self._is_expired(r, now):
                    self.sched.release(s)
                    self._retire_expired(r, "deadline")
                else:
                    slots.append(s)
                    reqs.append(r)
            if not reqs:
                continue
            if self.policy is not None:
                self.policy.observe_admit(len(reqs))
            bucket = self.bucket_for(len(reqs))
            h, w, c = reqs[0].image.shape
            buf = np.zeros((bucket, h, w, c), self._buf_dtype)
            for i, r in enumerate(reqs):
                buf[i] = r.image
            if self.faults is not None and self.faults.fire("stage.corrupt"):
                # corrupt only the staged copy — req.image stays pristine,
                # so the retry after the finiteness screen re-stages clean
                buf[0] = np.nan
            self._staged.append(_Group(slots, reqs, bucket, self._put(buf)))

    def _launch(self):
        """Dispatch the forward pass for the oldest staged group (async).
        Launch failures — injected or real — never escape: the group
        re-queues with backoff and the health monitor is fed."""
        if not self._staged:
            return
        g = self._staged.popleft()
        degraded = g.bucket in self._degraded
        compiled = self._compiled_direct if degraded else self._compiled
        g.first_compile = g.bucket not in compiled
        # slab chaos (hoisted primary-route path only — that is where a
        # staged slab cache exists to corrupt) + the pre-dispatch
        # fingerprint gate: a corrupted or stale slab never reaches a
        # forward; the bucket repacks from pristine params and the group
        # retries with backoff.
        if self.faults is not None and self._hoist and not degraded:
            if self.faults.fire("slab.bitflip"):
                self._inject_bitflip(g.bucket)
            if self.faults.fire("slab.stale"):
                self._inject_stale(g.bucket)
        if (self.scfg.verify_slabs and self._hoist
                and not self._slabs_intact(g.bucket, degraded)):
            self.slab_integrity_failures += 1
            self._fail_batch(g, "slab", repack=True)
            return
        g.t_launch = self.clock.now()
        try:
            if self.faults is not None:
                if self.faults.fire("launch.crash"):
                    raise EngineCrash("injected hard engine crash")
                if self.faults.fire("launch.transient"):
                    raise TransientLaunchError(
                        "injected transient launch failure "
                        "(RESOURCE_EXHAUSTED)")
            if degraded:
                if self._hoist:
                    g.logits = self._direct_apply()(
                        self.params, self._slabs_direct(g.bucket), g.images)
                else:
                    g.logits = self._direct_apply()(self.params, g.images)
            elif self._hoist:
                g.logits = self._apply(self.params, self._slabs(g.bucket),
                                       g.images)
            else:
                g.logits = self._apply(self.params, g.images)
            if self._abft:
                g.logits, g.sdc = g.logits
        except EngineCrash as e:
            self.batches_failed += 1
            self.health.force_quarantine(f"crash: {e}")
            self._note_datapath_failure(g.bucket, "crash")
            self._requeue_group(g)
            return
        except Exception:       # transient injected or real launch error
            self.batches_failed += 1
            self.health.record_failure("launch")
            self._note_datapath_failure(g.bucket, "launch")
            self._requeue_group(g)
            return
        compiled.add(g.bucket)
        self._compute.append(g)

    def _finish_oldest(self):
        """Block on the oldest computed group and retire its requests.
        Retired logits pass the sampled finiteness screen; bad rows retry
        (never served), clean rows retire normally."""
        if not self._compute:
            return
        g = self._compute.popleft()
        try:
            logits = np.asarray(jax.device_get(g.logits))[: len(g.reqs)]
        except Exception:       # async device error surfaces at fetch
            self._fail_batch(g, "device")
            return
        # ABFT verdict gate: a positive in-kernel checksum mismatch count
        # means the staged filter bits changed between pack and the DMA
        # stream — the whole batch is tainted and is *never served*.  The
        # bucket's slab cache is dropped (retry repacks from the pristine
        # params) and the group re-queues with backoff, so detection feeds
        # the same retry/health/degradation machinery as any datapath
        # failure.  This runs before any retire-stage chaos: the verdict
        # belongs to the forward that computed these logits.
        if self._abft and g.sdc is not None:
            if int(np.asarray(jax.device_get(g.sdc))) > 0:
                self.sdc_detections += 1
                self._fail_batch(g, "sdc", repack=True)
                return
        if self.faults is not None:
            spec = self.faults.fire("retire.latency")
            if spec is not None and spec.delay_ms:
                self.clock.sleep(spec.delay_ms / 1e3)
            if self.faults.fire("retire.nonfinite"):
                logits = np.array(logits)       # own the buffer
                logits[0] = np.nan
            spec = self.faults.fire("retire.plausible")
            if spec is not None:
                # finite, bounded-magnitude corruption — crafted to pass
                # the isfinite screen; only screen_abs_max can catch it
                logits = np.array(logits)
                rng = self.faults.payload_rng("retire.plausible")
                row = int(rng.integers(len(logits)))
                logits[row] = logits[row] + (spec.magnitude or 1e8)
        ok = self._screen(logits)
        now = self.clock.now()
        slo_s = (self.scfg.slo_ms or 0.0) / 1e3
        n_good = 0
        retry: List[ImageRequest] = []
        group_uids = tuple(r.uid for r in g.reqs)
        for i, (slot, req, row, good) in enumerate(
                zip(g.slots, g.reqs, logits, ok)):
            if not good:
                self._fail_one(slot, req, now, retry)
                continue
            req.logits = row
            req.label = int(row.argmax())
            req.done = True
            req.t_done = now
            # serving provenance: enough to rebuild the exact padded batch
            # this row came from (failover bit-parity verification)
            req.served_bucket = g.bucket
            req.served_row = i
            req.served_group = group_uids
            lat = now - req.t_submit
            self.latency.record(lat)
            if slo_s and lat <= slo_s:
                self.images_within_slo += 1
            if self.policy is not None:
                self.policy.observe_latency(lat)
            self.sched.retire(slot)
            n_good += 1
        self._schedule_retry(retry, now)
        if n_good == len(g.reqs):
            self.health.record_ok()
            self._bucket_failures[g.bucket] = 0
        else:
            self.health.record_failure("nonfinite")
            self._note_datapath_failure(g.bucket, "nonfinite")
        # service-time EWMA feeds load shedding; a first-compile batch
        # carries the jit trace and would poison the estimate
        if self.admission is not None and not g.first_compile and n_good:
            self.admission.observe_batch(n_good, now - g.t_launch)
        if self.policy is not None:
            self.policy.maybe_resize()
        self.images_completed += n_good
        self.batches_run += 1
        self.bucket_counts[g.bucket] = self.bucket_counts.get(g.bucket, 0) + 1

    def step(self):
        """One tick: pump elapsed retries, stage ahead (H2D), launch the
        oldest staged, retire the oldest computed — transfer, compute, and
        host retirement overlap.  Under quarantine the circuit is open:
        nothing launches except the half-open probe after ``cooldown_ms``,
        and queued work drains via deadline expiry.  No Python exception
        escapes this method for launch/device failures — they feed the
        retry + health machinery instead."""
        t0 = self.clock.now()
        self._pump_retries()
        if self.health.state == QUARANTINED:
            self._quarantine_purge()
            if (self.sched.queue
                    and len(self._staged) + len(self._compute)
                    < self.scfg.staging_depth
                    and self.health.allow_launch()):
                self._stage()
                if self._staged:
                    self._launch()              # the half-open probe
                else:
                    self.health.cancel_probe()  # nothing admissible
        else:
            self._stage()
            self._launch()
        self._finish_oldest()
        self._t_serve += self.clock.now() - t0

    @property
    def retry_pending(self) -> int:
        return sum(len(rs) for _, rs in self._retry)

    @property
    def drained(self) -> bool:
        """No queued, staged, computing, or backoff-pending work."""
        return (self.sched.idle and not self._staged and not self._compute
                and not self._retry)

    def drain_report(self) -> dict:
        return {
            "drained": self.drained,
            "queued": len(self.sched.queue),
            "staged": sum(len(g.reqs) for g in self._staged),
            "computing": sum(len(g.reqs) for g in self._compute),
            "retry_pending": self.retry_pending,
            "occupancy": self.sched.occupancy,
            "health": self.health.state,
        }

    def run_until_done(self, max_steps: int = 100_000) -> dict:
        """Step until drained; returns the (empty) drain report.  Raises
        :class:`DrainTimeout` — with the report attached — if ``max_steps``
        elapse with work still in flight, so a hung engine fails loudly
        instead of silently vanishing requests."""
        for _ in range(max_steps):
            if self.drained:
                return self.drain_report()
            self.step()
        if self.drained:
            return self.drain_report()
        report = self.drain_report()
        raise DrainTimeout(
            f"engine not drained after {max_steps} steps: {report}", report)

    def export_state(self) -> dict:
        """Host-side snapshot of what a process-level restart must
        persist: the params (everything else — compiled buckets, packed
        slabs, plan cache — is rebuilt deterministically from them)."""
        return {"params": jax.device_get(self.params)}

    def reset_metrics(self):
        """Zero throughput/latency counters (e.g. after jit warmup) without
        touching queue, slots, compiled buckets, health state, or the
        packed-slab and admission state (a warmed service-time estimate is
        kept)."""
        self.latency = LatencyTracker(window=self.scfg.latency_window)
        self.images_submitted = 0
        self.images_completed = 0
        self.images_shed = 0
        self.images_expired = 0
        self.images_retried = 0
        self.images_within_slo = 0
        self.batches_run = 0
        self.batches_failed = 0
        self.bucket_counts = {}
        self.shed_reasons = {}
        self.sdc_detections = 0
        self.slab_integrity_failures = 0
        self.screen_nonfinite = 0
        self.screen_magnitude = 0
        self._t_serve = 0.0

    # ------------------------------------------------------------------
    @property
    def imgs_per_s(self) -> float:
        return self.images_completed / self._t_serve if self._t_serve else 0.0

    @property
    def goodput_imgs_per_s(self) -> float:
        """Within-SLO completions per serve-second (== img/s when no SLO
        is configured: every completion counts)."""
        if not self._t_serve:
            return 0.0
        good = (self.images_within_slo if self.scfg.slo_ms
                else self.images_completed)
        return good / self._t_serve

    def accounting(self) -> dict:
        """The fault-tolerance invariant, live: every submitted image is
        completed, shed, expired, or still in flight — nothing vanishes.
        Once drained, ``submitted == completed + shed + expired``."""
        in_flight = (len(self.sched.queue)
                     + sum(len(g.reqs) for g in self._staged)
                     + sum(len(g.reqs) for g in self._compute)
                     + self.retry_pending)
        accounted = (self.images_completed + self.images_shed
                     + self.images_expired + in_flight)
        return {
            "submitted": self.images_submitted,
            "completed": self.images_completed,
            "shed": self.images_shed,
            "expired": self.images_expired,
            "in_flight": in_flight,
            "balanced": self.images_submitted == accounted,
            # SDC screen verdicts, separated: rows rejected for
            # non-finiteness vs for busting the magnitude bound (both
            # retried, so neither breaks the balance above)
            "screen_nonfinite": self.screen_nonfinite,
            "screen_magnitude": self.screen_magnitude,
        }

    def stats(self) -> dict:
        return {
            "images_completed": self.images_completed,
            "images_shed": self.images_shed,
            "images_expired": self.images_expired,
            "images_retried": self.images_retried,
            "images_within_slo": (self.images_within_slo
                                  if self.scfg.slo_ms else None),
            "batches_run": self.batches_run,
            "batches_failed": self.batches_failed,
            "avg_occupancy": (self.images_completed / self.batches_run
                              if self.batches_run else 0.0),
            "bucket_counts": dict(sorted(self.bucket_counts.items())),
            "buckets": list(self.buckets),
            "bucket_resizes": list(self.policy.resizes) if self.policy else [],
            "imgs_per_s": self.imgs_per_s,
            "goodput_imgs_per_s": self.goodput_imgs_per_s,
            "latency_ms": self.latency.percentiles_ms(),
            "tuned_layers": sorted(self.plans),
            "health": self.health.stats(),
            "shed_reasons": dict(self.shed_reasons),
            "degraded_buckets": sorted(self._degraded),
            "degradations": list(self.degradations),
            "faults": self.faults.summary() if self.faults else None,
            "sdc": {
                "abft_armed": self._abft,
                "verify_slabs": self.scfg.verify_slabs,
                "detections": self.sdc_detections,
                "slab_integrity_failures": self.slab_integrity_failures,
                "screen_nonfinite": self.screen_nonfinite,
                "screen_magnitude": self.screen_magnitude,
            },
            "accounting": self.accounting(),
        }
