"""Supervised multi-process serving: N worker processes, one referee.

The paper's deployment story (§4.3: host + accelerator board) has a
single failure domain — when the host serving process dies, the fleet
dies.  This module splits the serving tier into a parent-side
:class:`Supervisor` that owns N :mod:`~repro.serving.worker` processes
(each a full :class:`~repro.serving.registry.ModelRegistry` with its own
JAX runtime) and is the *sole* bookkeeper of the fleet invariant::

    submitted == completed + shed + expired        (after drain)

Requests are dispatched round-robin over *live* workers, where liveness
is the same :class:`~repro.serving.health.HealthMonitor` ladder the
engines use in-process, re-applied at process level: every pump sends a
heartbeat RPC; a miss (timeout) is a recorded failure, a reply is a
recorded ok, and a quarantined monitor means the worker is declared dead
— killed, respawned from its spec, and its work failed over.  A broken
pipe or a dead PID short-circuits the ladder via ``force_quarantine``.

Failover re-dispatch: the supervisor keeps every in-flight request's
pristine host image.  When a worker dies, its queued + in-flight
requests are re-submitted to survivors with their *remaining* deadline
(already-expired ones retire as expired, per the engine's own
accounting contract); nothing is ever silently lost, because a request
leaves the supervisor's in-flight table only through a retire record,
an expiry, or a shed — never through a worker death.

Crash-consistent restart: a respawned worker rebuilds from its
:class:`~repro.serving.worker.WorkerSpec` — params from the newest
*intact* checkpoint (crc-verified, torn-latest falls back one step),
weight slabs repacked, the persisted autotuner plan cache reused — so a
replacement serves bit-identical logits to the process it replaced.
:meth:`Supervisor.verify_bit_parity` closes the loop: every failed-over
request's served logits must bit-match a jitted direct forward at the
exact padded bucket shape it was served in (rebuilt from the
``served_bucket/row/group`` provenance the engine stamps at retire).

Chaos is seeded per worker (``derive_seed(seed, worker_name)`` → one
:class:`~repro.serving.faults.FaultInjector` each): ``worker.crash``
SIGKILLs the process at a pump opportunity, ``worker.stall`` makes the
worker's command loop sleep so heartbeats miss without the process
dying — both bit-reproducible from (seed, specs).
"""
from __future__ import annotations

import multiprocessing as mp
import os
import sys
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .clock import MONOTONIC, Clock
from .faults import FaultInjector, FaultSpec, derive_seed
from .health import QUARANTINED, HealthMonitor
from .scheduler import DrainTimeout, LatencyTracker
from .worker import WorkerModel, WorkerSpec, worker_main

__all__ = ["Supervisor", "SupervisorConfig", "WorkerDead", "WorkerTimeout",
           "WorkerModel"]


class WorkerTimeout(RuntimeError):
    """An RPC to a worker exceeded its deadline (stall / overload) — a
    heartbeat miss, not yet a death."""


class WorkerDead(RuntimeError):
    """The worker's pipe is gone or its process exited — hard failure."""


@dataclass(frozen=True)
class SupervisorConfig:
    n_workers: int = 2
    heartbeat_timeout_ms: float = 1000.0   # miss if no reply within this
    miss_threshold: int = 3                # consecutive misses -> dead
    rpc_timeout_ms: float = 60_000.0       # submit/step/retire budget
    spawn_timeout_s: float = 600.0         # build + warmup compile budget
    steps_per_pump: int = 2                # registry ticks per step RPC
    max_restarts: int = 2                  # respawns per worker slot
    default_retries: int = 2               # engine-level retry budget
    warm: bool = True                      # compile buckets before 'ready'
    checkpoint_on_start: bool = True       # seed a checkpoint pre-crash


@dataclass
class _Handle:
    """Parent-side state for one worker slot (survives respawns)."""
    name: str
    spec: WorkerSpec
    proc: Optional[mp.Process] = None
    conn: object = None
    monitor: Optional[HealthMonitor] = None
    injector: Optional[FaultInjector] = None
    seq: int = 0
    pid: Optional[int] = None
    restarts: int = 0
    alive: bool = False                 # ready and believed serving
    spawning: bool = False              # process launched, ready pending
    t_spawn: float = 0.0                # launch time (spawn_timeout clock)
    retired: bool = False               # restart budget exhausted
    restored: dict = field(default_factory=dict)   # model -> ckpt step
    last_accounting: dict = field(default_factory=dict)
    deaths: List[str] = field(default_factory=list)
    # uid -> (model, supervisor-side ImageRequest record)
    inflight: Dict[int, Tuple[str, object]] = field(default_factory=dict)


def _src_root() -> str:
    # .../src/repro/serving/supervisor.py -> .../src
    return os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))


class Supervisor:
    """Own N worker processes; route, heartbeat, fail over, account."""

    def __init__(self, models: Sequence[WorkerModel],
                 sup: Optional[SupervisorConfig] = None, *,
                 ckpt_dir: Optional[str] = None,
                 seed: int = 0,
                 chaos: Optional[Dict[str, FaultSpec]] = None,
                 chaos_workers: Optional[Sequence[str]] = None,
                 clock: Optional[Clock] = None):
        self.models = tuple(models)
        self.sup = sup or SupervisorConfig()
        self.ckpt_dir = ckpt_dir
        self.seed = seed
        self.chaos = dict(chaos or {})
        self.clock = clock or MONOTONIC
        self._ctx = mp.get_context("spawn")
        # spawn children re-import repro to unpickle the spec; make sure
        # they can even when the parent added src/ to sys.path manually
        root = _src_root()
        pp = os.environ.get("PYTHONPATH", "")
        if root not in pp.split(os.pathsep):
            os.environ["PYTHONPATH"] = (root + os.pathsep + pp) if pp else root

        self.workers: Dict[str, _Handle] = {}
        for k in range(self.sup.n_workers):
            name = f"w{k}"
            spec = WorkerSpec(name=name, models=self.models,
                              ckpt_dir=ckpt_dir, warm=self.sup.warm)
            # chaos_workers narrows the blast radius: "kill worker k at
            # opportunity s" schedules (FaultSpec(at=...)) would otherwise
            # fire on every worker at the same pump index
            armed = self.chaos and (chaos_workers is None
                                    or name in chaos_workers)
            inj = (FaultInjector(derive_seed(seed, name), self.chaos)
                   if armed else None)
            self.workers[name] = _Handle(name=name, spec=spec, injector=inj)

        # fleet accounting — the supervisor's counters are authoritative;
        # worker-side counters are diagnostics (heartbeat snapshots)
        self.submitted = 0
        self.completed = 0
        self.shed = 0
        self.expired = 0
        self.failed_over = 0
        self.latency = LatencyTracker()
        self.requests: Dict[int, Tuple[str, object]] = {}  # uid -> (model, req)
        self.pending: List[Tuple[str, object]] = []  # parked during outage
        self.failover_uids: set = set()
        self.events: List[dict] = []
        self._rr = 0                    # round-robin cursor
        self._started = False

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> "Supervisor":
        # launch every worker first, then wait: the N builds (JAX import +
        # bucket warmup compiles) run in parallel instead of serially
        for h in self.workers.values():
            self._launch_proc(h)
        for h in self.workers.values():
            if not self._finalize_ready(h, block=True):
                raise WorkerDead(f"{h.name}: failed to come up "
                                 f"({h.deaths[-1] if h.deaths else '?'})")
        if self.ckpt_dir and self.sup.checkpoint_on_start:
            self.checkpoint()
        self._started = True
        return self

    def __enter__(self) -> "Supervisor":
        return self.start() if not self._started else self

    def __exit__(self, *exc):
        self.shutdown()

    def _fresh_monitor(self) -> HealthMonitor:
        # process-level reuse of the engine health ladder: misses walk
        # healthy -> degraded -> quarantined; quarantined == declared dead
        return HealthMonitor(
            fail_threshold=max(1, self.sup.miss_threshold - 1),
            quarantine_threshold=self.sup.miss_threshold)

    def _launch_proc(self, h: _Handle):
        """Start the worker process without waiting for its ready
        handshake — builds (JAX import, warmup compiles) take tens of
        seconds, and a blocked supervisor would stall the whole fleet's
        heartbeats and deadlines (the respawn path pumps survivors while
        the replacement comes up)."""
        parent, child = self._ctx.Pipe()
        proc = self._ctx.Process(target=worker_main, args=(child, h.spec),
                                 daemon=True, name=f"serve-{h.name}")
        proc.start()
        child.close()
        h.proc, h.conn = proc, parent
        h.seq = 0
        h.alive, h.spawning = False, True
        h.t_spawn = time.monotonic()

    def _finalize_ready(self, h: _Handle, *, block: bool) -> bool:
        """Absorb the ready handshake.  ``block=False`` (pump path) polls
        and returns False while the build is still running; a build
        failure or spawn timeout retires the attempt (counted against the
        restart budget by the caller's next death handling)."""
        try:
            if not h.conn.poll(self.sup.spawn_timeout_s if block else 0):
                if (block or time.monotonic() - h.t_spawn
                        > self.sup.spawn_timeout_s):
                    self._spawn_failed(h, "no ready handshake within "
                                       f"{self.sup.spawn_timeout_s}s")
                return False
            ready = h.conn.recv()
        except (EOFError, OSError) as e:
            self._spawn_failed(h, f"{type(e).__name__}: {e}")
            return False
        if not ready.get("ok"):
            self._spawn_failed(h, f"build failed: "
                               f"{ready.get('error', 'unknown')}")
            return False
        h.pid = ready.get("pid")
        h.monitor = self._fresh_monitor()
        h.alive, h.spawning = True, False
        h.restored = dict(ready.get("restored") or {})
        self.events.append({"event": "spawn", "worker": h.name,
                            "pid": h.pid, "restarts": h.restarts,
                            "restored": h.restored})
        return True

    def _spawn_failed(self, h: _Handle, reason: str):
        h.spawning = False
        h.deaths.append(f"spawn-failed: {reason}")
        self.events.append({"event": "spawn-failed", "worker": h.name,
                            "reason": reason})
        if h.proc is not None:
            h.proc.kill()
            h.proc.join(timeout=10)
        if h.conn is not None:
            h.conn.close()
            h.conn = None
        if h.restarts < self.sup.max_restarts:
            h.restarts += 1
            self._launch_proc(h)
        else:
            h.retired = True
            self.events.append({"event": "retired", "worker": h.name})

    def shutdown(self):
        for h in self.workers.values():
            if h.conn is not None and h.alive:
                try:
                    self._rpc(h, {"op": "shutdown"}, timeout_s=5.0)
                except (WorkerDead, WorkerTimeout):
                    pass
            if h.proc is not None:
                h.proc.join(timeout=5)
                if h.proc.is_alive():
                    h.proc.kill()
                    h.proc.join(timeout=5)
            if h.conn is not None:
                h.conn.close()
            h.alive = False

    # -- RPC ----------------------------------------------------------------
    def _rpc(self, h: _Handle, msg: dict, timeout_s: float) -> dict:
        """Seq-matched request/reply with deadline.  Replies to RPCs that
        already timed out (a recovered stall) are recognised by their
        stale seq and dropped — never matched to the wrong call."""
        h.seq += 1
        msg = dict(msg, seq=h.seq)
        try:
            h.conn.send(msg)
            deadline = time.monotonic() + timeout_s
            while True:
                remaining = deadline - time.monotonic()
                if remaining <= 0 or not h.conn.poll(max(remaining, 0.0)):
                    raise WorkerTimeout(
                        f"{h.name}: no reply to {msg['op']!r} within "
                        f"{timeout_s * 1e3:.0f}ms")
                reply = h.conn.recv()
                if reply.get("seq") == h.seq:
                    return reply
        except (EOFError, BrokenPipeError, ConnectionResetError,
                OSError) as e:
            raise WorkerDead(
                f"{h.name}: {type(e).__name__}: {e}") from e

    def _send_only(self, h: _Handle, msg: dict):
        """Fire-and-forget (chaos stall payload); the eventual reply is
        dropped by seq matching."""
        h.seq += 1
        try:
            h.conn.send(dict(msg, seq=h.seq))
        except (BrokenPipeError, OSError):
            pass

    # -- routing + submit ---------------------------------------------------
    def _live(self) -> List[_Handle]:
        return [h for h in self.workers.values()
                if h.alive and h.monitor is not None
                and h.monitor.state != QUARANTINED
                and h.proc is not None and h.proc.is_alive()]

    def _route(self, exclude: set) -> Optional[_Handle]:
        live = [h for h in self._live() if h.name not in exclude]
        if not live:
            return None
        h = live[self._rr % len(live)]
        self._rr += 1
        return h

    def submit(self, model: str, req) -> bool:
        """Dispatch one request to a live worker.  Returns False (and
        counts a shed) when every live worker refuses or none exists."""
        req.t_submit = self.clock.now()
        self.submitted += 1
        self.requests[req.uid] = (model, req)
        return self._dispatch(model, req, first=True)

    def _remaining_deadline_ms(self, req, now: float) -> Optional[float]:
        if req.deadline_ms is None:
            return None
        return req.deadline_ms - (now - req.t_submit) * 1e3

    def _dispatch(self, model: str, req, *, first: bool) -> bool:
        tried: set = set()
        while True:
            h = self._route(tried)
            if h is None:
                req.shed = True
                self.shed += 1
                return False
            remaining = self._remaining_deadline_ms(req, self.clock.now())
            if remaining is not None and remaining <= 0:
                self._expire(req, "deadline")
                return False
            try:
                rep = self._rpc(h, {"op": "submit", "model": model,
                                    "uid": req.uid, "image": req.image,
                                    "deadline_ms": remaining,
                                    "retries": req.retries},
                                timeout_s=self.sup.rpc_timeout_ms / 1e3)
            except WorkerDead as e:
                self._on_worker_death(h, str(e))
                tried.add(h.name)
                continue
            except WorkerTimeout:
                h.monitor.record_failure("submit-timeout")
                tried.add(h.name)
                continue
            if rep.get("accepted"):
                h.inflight[req.uid] = (model, req)
                if not first:
                    self.failed_over += 1
                    self.failover_uids.add(req.uid)
                return True
            tried.add(h.name)       # shed at this worker; try another

    def _expire(self, req, reason: str):
        req.expired = True
        req.expire_reason = reason
        self.expired += 1

    # -- death + failover ---------------------------------------------------
    def kill_worker(self, name: str, reason: str = "operator-kill"):
        """SIGKILL a worker (chaos / drills) and run the failover path."""
        h = self.workers[name]
        if h.proc is not None and h.proc.is_alive():
            h.proc.kill()
        self._on_worker_death(h, reason)

    def _on_worker_death(self, h: _Handle, reason: str):
        if not h.alive:
            return                          # already handled (re-entrant)
        h.alive = False
        h.deaths.append(reason)
        if h.monitor is not None and h.monitor.state != QUARANTINED:
            h.monitor.force_quarantine(reason)
        self.events.append({"event": "death", "worker": h.name,
                            "pid": h.pid, "reason": reason})
        if h.proc is not None:
            h.proc.kill()
            h.proc.join(timeout=10)
        if h.conn is not None:
            h.conn.close()
            h.conn = None
        orphans = list(h.inflight.values())
        h.inflight.clear()
        # failover re-dispatch FIRST, to survivors, at the remaining
        # deadline — the respawn takes tens of seconds (JAX import +
        # warmup) and must never gate the orphans' deadlines
        now = self.clock.now()
        for model, req in orphans:
            remaining = self._remaining_deadline_ms(req, now)
            if remaining is not None and remaining <= 0:
                self._expire(req, "deadline")
            elif self._live():
                self._dispatch(model, req, first=False)
            else:
                # total outage: park until a worker comes back (drained
                # stays False; the pump re-dispatches on recovery)
                self.pending.append((model, req))
        # crash-consistent restart, asynchronously: same spec ->
        # checkpoint-restored params, repacked slabs, reused plan cache;
        # the ready handshake is absorbed by a later pump
        if h.restarts < self.sup.max_restarts:
            h.restarts += 1
            self._launch_proc(h)
        else:
            h.retired = True
            self.events.append({"event": "retired", "worker": h.name})

    # -- pump ---------------------------------------------------------------
    def step(self):
        """One supervisory tick over every worker slot: respawn
        handshakes, chaos, liveness, heartbeat, registry steps,
        retirement, and re-dispatch of outage-parked requests."""
        for h in list(self.workers.values()):
            if h.spawning:
                self._finalize_ready(h, block=False)
            if h.retired or not h.alive:
                continue
            if h.injector is not None:
                if h.injector.fire("worker.crash"):
                    self.kill_worker(h.name, "chaos:worker.crash")
                    continue
                spec = h.injector.fire("worker.stall")
                if spec is not None and spec.delay_ms:
                    self._send_only(h, {"op": "stall",
                                        "delay_ms": spec.delay_ms})
            if h.proc is None or not h.proc.is_alive():
                self._on_worker_death(h, "process-exit")
                continue
            try:
                rep = self._rpc(h, {"op": "heartbeat"},
                                timeout_s=self.sup.heartbeat_timeout_ms / 1e3)
                h.monitor.record_ok()
                h.last_accounting = rep.get("accounting", {})
            except WorkerTimeout:
                h.monitor.record_failure("heartbeat-miss")
                if h.monitor.state == QUARANTINED:
                    self.kill_worker(h.name, "heartbeat-quarantine")
                continue
            except WorkerDead as e:
                self._on_worker_death(h, str(e))
                continue
            try:
                self._rpc(h, {"op": "step", "n": self.sup.steps_per_pump},
                          timeout_s=self.sup.rpc_timeout_ms / 1e3)
                rep = self._rpc(h, {"op": "retire_batch"},
                                timeout_s=self.sup.rpc_timeout_ms / 1e3)
            except WorkerTimeout:
                h.monitor.record_failure("rpc-timeout")
                if h.monitor.state == QUARANTINED:
                    self.kill_worker(h.name, "rpc-quarantine")
                continue
            except WorkerDead as e:
                self._on_worker_death(h, str(e))
                continue
            self._absorb_retirements(h, rep.get("results", []))
        if self.pending:
            if self._live():
                parked, self.pending = self.pending, []
                now = self.clock.now()
                for model, req in parked:
                    remaining = self._remaining_deadline_ms(req, now)
                    if remaining is not None and remaining <= 0:
                        self._expire(req, "deadline")
                    else:
                        self._dispatch(model, req, first=False)
            elif all(h.retired for h in self.workers.values()):
                # permanent outage: no capacity will ever return — shed
                # (reported, accounted) instead of hanging the drain
                parked, self.pending = self.pending, []
                for _model, req in parked:
                    req.shed = True
                    self.shed += 1

    def _absorb_retirements(self, h: _Handle, results: List[dict]):
        now = self.clock.now()
        for rec in results:
            ent = h.inflight.pop(rec["uid"], None)
            if ent is None:
                continue        # stale: request was failed over elsewhere
            model, req = ent
            if rec["status"] == "done":
                req.logits = rec["logits"]
                req.label = rec["label"]
                req.served_bucket = rec["bucket"]
                req.served_row = rec["row"]
                req.served_group = rec["group"]
                req.attempts = rec.get("attempts", req.attempts)
                req.done = True
                req.t_done = now
                self.completed += 1
                self.latency.record(now - req.t_submit)
            else:
                req.expire_reason = rec.get("expire_reason")
                req.expired = True
                self.expired += 1

    # -- drain + accounting -------------------------------------------------
    @property
    def in_flight(self) -> int:
        return (sum(len(h.inflight) for h in self.workers.values())
                + len(self.pending))

    @property
    def drained(self) -> bool:
        return self.in_flight == 0

    def run_until_done(self, max_steps: int = 10_000) -> dict:
        for _ in range(max_steps):
            if self.drained:
                return self.accounting()
            self.step()
            if not self._live() and not all(
                    h.retired for h in self.workers.values()):
                # total outage with respawns in flight: pumping costs
                # nothing (no RPCs), so back off instead of burning the
                # step budget before any replacement can finish its build
                time.sleep(0.05)
        if self.drained:
            return self.accounting()
        raise DrainTimeout(
            f"fleet not drained after {max_steps} supervisor steps: "
            f"{self.accounting()}", self.accounting())

    def accounting(self) -> dict:
        """The fleet invariant, from the supervisor's own authoritative
        counters: no worker death may lose a request."""
        acc = {
            "submitted": self.submitted,
            "completed": self.completed,
            "shed": self.shed,
            "expired": self.expired,
            "in_flight": self.in_flight,
            "failed_over": self.failed_over,
        }
        acc["balanced"] = (self.submitted == self.completed + self.shed
                           + self.expired + self.in_flight)
        return acc

    def checkpoint(self) -> dict:
        """Persist every model's params via one live worker (they share
        seed-derived params, so one snapshot covers the fleet)."""
        live = self._live()
        if not live:
            raise WorkerDead("no live worker to checkpoint")
        return self._rpc(live[0], {"op": "checkpoint"},
                         timeout_s=self.sup.rpc_timeout_ms / 1e3)

    def stats(self) -> dict:
        per = {}
        for h in self.workers.values():
            per[h.name] = {
                "alive": h.alive,
                "retired": h.retired,
                "pid": h.pid,
                "restarts": h.restarts,
                "deaths": list(h.deaths),
                "restored": h.restored,
                "inflight": len(h.inflight),
                "health": h.monitor.stats() if h.monitor else None,
                "chaos": h.injector.summary() if h.injector else None,
                "accounting": h.last_accounting,
            }
        return {"accounting": self.accounting(), "workers": per,
                "events": list(self.events),
                "latency": self.latency.percentiles_ms()}

    # -- failover bit-parity ------------------------------------------------
    def verify_bit_parity(self, *, uids: Optional[Sequence[int]] = None,
                          params: Optional[dict] = None) -> dict:
        """Check served logits against a jitted direct forward at the
        exact padded bucket shape each request was served in (rebuilt
        from the retire-time provenance).  Defaults to every completed
        *failed-over* request — the ISSUE's failover contract.

        ``params``: optional {model: pytree}; defaults to ``init(seed)``
        per model (what an un-checkpointed worker serves).
        """
        import jax

        from ..models import model_for

        cfg_of = {m.name: m.cfg for m in self.models}
        seed_of = {m.name: m.seed for m in self.models}
        if uids is None:
            uids = [u for u in sorted(self.failover_uids)
                    if self.requests[u][1].done]
        oracles, params = {}, dict(params or {})
        checked = mismatched = 0
        bad: List[int] = []
        for uid in uids:
            model, req = self.requests[uid]
            if not req.done or req.served_bucket is None:
                continue
            cfg = cfg_of[model]
            if model not in oracles:
                mod = model_for(cfg)
                if model not in params:
                    params[model] = mod.init(
                        jax.random.PRNGKey(seed_of[model]), cfg)
                oracles[model] = jax.jit(
                    lambda p, x, _mod=mod, _cfg=cfg: _mod.apply(p, _cfg, x))
            buf = np.zeros((req.served_bucket, cfg.image_size,
                            cfg.image_size, cfg.in_channels),
                           np.dtype(getattr(cfg, "dtype", "float32")))
            for i, guid in enumerate(req.served_group):
                buf[i] = self.requests[guid][1].image
            ref = np.asarray(oracles[model](params[model], buf))
            checked += 1
            if not np.array_equal(ref[req.served_row],
                                  np.asarray(req.logits)):
                mismatched += 1
                bad.append(uid)
        return {"checked": checked, "mismatched": mismatched,
                "bad_uids": bad}
