"""Central architecture / run configuration dataclasses.

Every assigned architecture is expressed as an :class:`ArchConfig`; model
code in ``repro.nn`` / ``repro.models`` is driven entirely by these fields
(the DLA-paper "sequencer" idea: one engine, many topologies — §3.8 of the
paper; executing a different net only changes the configuration).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, replace
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoECfg:
    """Mixture-of-experts sub-config (GShard one-hot dispatch, EP-shardable)."""

    num_experts: int
    top_k: int
    d_ff: int                      # per-expert hidden size
    num_shared: int = 0            # always-on shared experts (DeepSeek style)
    period: int = 1                # MoE FFN every `period` layers ...
    offset: int = 0                # ... at layer index `offset` (mod period)
    first_k_dense: int = 0         # first k layers use a dense FFN instead
    group_size: int = 128          # dispatch group length along seq
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01


@dataclass(frozen=True)
class MLACfg:
    """Multi-head latent attention (DeepSeek-V2)."""

    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMCfg:
    """Mamba-2 SSD sub-config."""

    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_kernel: int = 4
    ngroups: int = 1
    chunk: int = 256               # SSD chunk length (stream-buffer residency)


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | ssm | hybrid | moe | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int                      # dense-FFN hidden (0 = no FFN sublayer)
    vocab_size: int

    head_dim: int = 0              # 0 -> d_model // num_heads
    mlp_type: str = "swiglu"       # swiglu | gelu
    norm_type: str = "rmsnorm"     # rmsnorm | layernorm
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0

    moe: Optional[MoECfg] = None
    mla: Optional[MLACfg] = None
    ssm: Optional[SSMCfg] = None

    # hybrid interleave (jamba): attention mixer at layer index `attn_offset`
    # of every `attn_period` layers; all other layers use the SSM mixer.
    attn_period: int = 1
    attn_offset: int = 0

    # encoder-decoder (whisper): encoder frames are precomputed embeddings
    # (the modality frontend is a stub per the assignment).
    encoder_layers: int = 0
    cross_attention: bool = False

    # vlm stub frontend: this many precomputed patch embeddings are
    # prepended to the token sequence.
    num_patches: int = 0

    # numerics / training
    dtype: str = "bfloat16"        # activation / compute dtype
    param_dtype: str = "float32"   # parameter storage dtype
    remat: bool = True             # checkpoint each block body under scan
    remat_policy: str = "nothing"  # nothing | save_attn (keep attention
    #                                outputs: no flash fwd recompute in bwd)
    logits_softcap: float = 0.0
    banded_attention: bool = False  # causal flash over lower-triangle chunk
    #                                 pairs only (~2x fewer attention FLOPs)
    fc_bfp: bool = False           # stream the lm_head (FC) weights as
    #                                shared-exponent int8 BFP (paper §3.6);
    #                                decode is the same weight-bandwidth-
    #                                bound regime as the paper's FC layers

    # --- derived -----------------------------------------------------------
    @property
    def d_head(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def d_inner(self) -> int:
        return self.ssm.expand * self.d_model if self.ssm else 0

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm.head_dim if self.ssm else 0

    @property
    def attn_supported_long(self) -> bool:
        """True if the arch can run the 500k-token long-context shape
        (sub-quadratic / constant-state sequence mixing)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        """Encoder-only archs have no decode step; everything here decodes."""
        return True

    def pattern_period(self) -> int:
        """Length of the repeating layer pattern (scan body covers one period)."""
        p = self.attn_period
        if self.moe is not None:
            p = _lcm(p, self.moe.period)
        return p

    def layer_kind(self, i: int) -> Tuple[str, str]:
        """(mixer, ffn) kind for absolute layer index ``i``.

        mixer in {attn, ssm}; ffn in {mlp, moe, none}.
        """
        if self.family in ("ssm", "hybrid"):
            mixer = "attn" if (self.attn_period > 0 and
                               i % self.attn_period == self.attn_offset and
                               self.family == "hybrid") else "ssm"
            if self.family == "ssm":
                mixer = "ssm"
        else:
            mixer = "attn"
        if self.d_ff == 0 and self.moe is None:
            ffn = "none"
        elif self.moe is not None and i >= self.moe.first_k_dense and \
                i % self.moe.period == self.moe.offset:
            ffn = "moe"
        else:
            ffn = "mlp" if self.d_ff > 0 else "none"
        return mixer, ffn

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        period = self.pattern_period()
        prefix = self.moe.first_k_dense if self.moe else 0
        n_layers = prefix + 2 * period
        kw = dict(
            num_layers=n_layers,
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads else 2,
            head_dim=16,
            d_ff=0 if self.d_ff == 0 else 128,
            vocab_size=503,  # deliberately non-round: catches padding bugs
            encoder_layers=2 if self.encoder_layers else 0,
            num_patches=8 if self.num_patches else 0,
            dtype="float32",
            param_dtype="float32",
            remat=False,
        )
        if self.moe is not None:
            kw["moe"] = replace(self.moe, num_experts=8,
                                top_k=min(self.moe.top_k, 2), d_ff=64,
                                group_size=16)
        if self.mla is not None:
            kw["mla"] = MLACfg(kv_lora_rank=32, qk_nope_head_dim=16,
                               qk_rope_head_dim=8, v_head_dim=16)
        if self.ssm is not None:
            kw["ssm"] = replace(self.ssm, d_state=16, head_dim=8, chunk=16)
        return replace(self, **kw)


def _lcm(a: int, b: int) -> int:
    import math
    return a * b // math.gcd(a, b)


# ---------------------------------------------------------------------------
# Input-shape cells assigned to this paper (LM-family): seq_len x global_batch
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCfg("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(arch: ArchConfig, shape: ShapeCfg) -> Tuple[bool, str]:
    """Whether a (arch x shape) cell runs, and the reason if not."""
    if shape.name == "long_500k" and not arch.attn_supported_long:
        return False, "full-attention arch: 500k decode needs sub-quadratic mixer (skip per assignment)"
    return True, ""
