"""Mixture-of-experts FFN — GShard-style one-hot dispatch (EP-shardable).

Tokens are grouped along the (local) sequence so the dispatch one-hot stays a
modest transient: (G, s, E, C) with s = moe.group_size.  Expert weights carry
the "experts" logical axis (-> mesh "model"), so under GSPMD the dispatch /
combine einsums lower to all-to-alls across the expert-parallel axis.

This is the paper's FC-layer philosophy applied to experts: the *streamed*
operand flips from features to filters depending on which is scarce; here the
scarce resource is expert capacity, managed analytically via the capacity
factor (dropped tokens fall back to the residual path).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..config import ArchConfig, MoECfg
from ..parallel.sharding import constrain
from .layers import linear, linear_init
from .module import param, split


def moe_init(key, cfg: ArchConfig):
    m = cfg.moe
    d, f, E = cfg.d_model, m.d_ff, m.num_experts
    dtype = jnp.dtype(cfg.param_dtype)
    kr, k1, k3, k2, ks = split(key, 5)
    p = {
        "router": linear_init(kr, d, E, dtype),
        "experts": {
            "w1": param(k1, (E, d, f), dtype),
            "w3": param(k3, (E, d, f), dtype),
            "w2": param(k2, (E, f, d), dtype),
        },
    }
    if m.num_shared:
        from .mlp import mlp_init
        p["shared"] = mlp_init(ks, cfg, d_ff=m.d_ff * m.num_shared)
    return p


def moe_capacity(m: MoECfg, sg: int) -> int:
    return max(1, int(sg * m.top_k / m.num_experts * m.capacity_factor))


def moe_apply(p, cfg: ArchConfig, x, *, return_aux: bool = False):
    m: MoECfg = cfg.moe
    B, S, D = x.shape
    E, k = m.num_experts, m.top_k
    sg = min(m.group_size, S)
    pad = (-S) % sg
    xp = jnp.pad(x, ((0, 0), (0, pad), (0, 0))) if pad else x
    G = B * ((S + pad) // sg)
    xg = xp.reshape(G, sg, D)
    # token groups inherit the batch sharding (without this the reshape
    # replicates and every dispatch tensor is global-sized — measured
    # 16 GiB/device transients on jamba train_4k)
    xg = constrain(xg, ("expert_group", None, "embed"))

    # --- routing (f32) ------------------------------------------------------
    logits = linear(p["router"], xg, dtype=jnp.float32)        # (G,s,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, k)                   # (G,s,k)
    gate_vals = (gate_vals /
                 jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
                 ).astype(x.dtype)

    # --- capacity assignment (priority: all top-1 before any top-2, ...) ----
    C = moe_capacity(m, sg)
    sel = jax.nn.one_hot(idx, E, dtype=jnp.int32)              # (G,s,k,E)
    flat = sel.transpose(0, 2, 1, 3).reshape(G, k * sg, E)     # k-major order
    pos = jnp.cumsum(flat, axis=1) - flat
    pos = pos.reshape(G, k, sg, E).transpose(0, 2, 1, 3)       # (G,s,k,E)

    dispatch = jnp.zeros((G, sg, E, C), x.dtype)
    combine = jnp.zeros((G, sg, E, C), x.dtype)
    for ki in range(k):                                        # small static k
        sel_k = sel[:, :, ki, :].astype(x.dtype)               # (G,s,E)
        pos_k = pos[:, :, ki, :]
        oh = (jax.nn.one_hot(pos_k, C, dtype=x.dtype)
              * sel_k[..., None]
              * (pos_k < C).astype(x.dtype)[..., None])        # (G,s,E,C)
        dispatch = dispatch + oh
        combine = combine + gate_vals[:, :, ki, None, None] * oh
    dispatch = constrain(dispatch, ("expert_group", None, "experts", None))
    combine = constrain(combine, ("expert_group", None, "experts", None))

    # --- expert compute (EP x DP: experts on "model", token groups stay on
    # "data"; the all-to-all runs within the model axis only) ---------------
    xe = jnp.einsum("gsec,gsd->egcd", dispatch, xg)            # a2a: tokens->experts
    xe = constrain(xe, ("experts", "expert_group", None, "embed"))
    from ..core.bfp import weight_of
    w = p["experts"]
    h = jax.nn.silu(jnp.einsum("egcd,edf->egcf", xe,
                               weight_of(w, "w1", dtype=x.dtype)))
    h = h * jnp.einsum("egcd,edf->egcf", xe, weight_of(w, "w3", dtype=x.dtype))
    ye = jnp.einsum("egcf,efd->egcd", h, weight_of(w, "w2", dtype=x.dtype))
    ye = constrain(ye, ("experts", "expert_group", None, "embed"))
    y = jnp.einsum("gsec,egcd->gsd", combine, ye)              # a2a: experts->tokens
    y = constrain(y, ("expert_group", None, "embed"))

    if "shared" in p:
        from .mlp import mlp_apply
        y = y + mlp_apply(p["shared"], cfg, xg)

    y = y.reshape(B, S + pad, D)[:, :S].astype(x.dtype)
    if not return_aux:
        return y, None

    # load-balance aux loss (Switch/GShard): E * sum_e f_e * p_e
    me = probs.mean(axis=(0, 1))                               # mean router prob
    ce = sel.astype(jnp.float32).sum(2).mean(axis=(0, 1)) / k  # fraction routed
    aux = E * jnp.sum(me * ce) * m.router_aux_coef
    return y, aux
