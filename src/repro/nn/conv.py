"""Unified conv dispatch: declarative ConvSpec -> one entry point.

Models declare each conv layer as a :class:`ConvSpec` (kernel geometry,
groups, fusion flags, route) and call :func:`dispatch_conv`; all routing
policy — Winograd eligibility, Pallas vs jnp, direct fallback, grouped
batching — lives here instead of ad-hoc per-model branching.

Routes
------
``direct``    ``lax.conv_general_dilated`` (any kernel/stride; groups via
              ``feature_group_count``), bias + ReLU applied as epilogue.
``winograd``  pure-jnp F(m,r) x F(m,r) path (differentiable; training).
``pallas``    stream-buffered Pallas kernel (in-kernel tiling, channel-block
              reduction, fused bias+ReLU epilogue; inference).
``auto``      ``winograd`` when eligible, else ``direct``.

Winograd routes require stride 1 and a 3x3 kernel (the paper's F(4,3)
layers); ineligible specs silently fall back to ``direct`` so models never
need their own conv branching.
"""
from __future__ import annotations

from dataclasses import dataclass, replace

import jax.numpy as jnp

from ..core.winograd import conv2d_winograd
from ..kernels.winograd.ops import conv2d as pallas_conv2d
from ..kernels.winograd.ref import conv2d_ref

ROUTES = ("auto", "direct", "winograd", "pallas")


@dataclass(frozen=True)
class ConvSpec:
    """Declarative description of one 2D conv layer (NHWC / HWIO)."""
    kernel: int
    stride: int = 1
    padding: str = "SAME"           # "SAME" | "VALID"
    groups: int = 1
    fuse_bias: bool = True          # apply bias inside the conv call
    relu: bool = False              # fused ReLU epilogue
    route: str = "auto"             # "auto" | "direct" | "winograd" | "pallas"
    winograd_m: int = 4             # F(m, 3) output tile size

    def __post_init__(self):
        assert self.route in ROUTES, self.route
        assert self.padding in ("SAME", "VALID"), self.padding

    def with_route(self, route: str) -> "ConvSpec":
        return replace(self, route=route)

    @property
    def winograd_eligible(self) -> bool:
        return self.stride == 1 and self.kernel == 3


def resolve_route(spec: ConvSpec) -> str:
    """Final route after eligibility fallback (never returns "auto")."""
    if spec.route == "auto":
        return "winograd" if spec.winograd_eligible else "direct"
    if spec.route in ("winograd", "pallas") and not spec.winograd_eligible:
        return "direct"
    return spec.route


def dispatch_conv(spec: ConvSpec, x, w, b=None, *, interpret=None):
    """Run one conv layer per its spec.  x (B,H,W,C), w (k,k,C//g,K), b (K,).

    Grouped convs are batched (``feature_group_count`` on the direct route,
    a group-folded kernel grid / vmap on the Winograd routes) — never a
    Python loop over groups.
    """
    assert w.shape[0] == w.shape[1] == spec.kernel, (w.shape, spec.kernel)
    # Unfused bias is an epilogue *between* conv and ReLU (conv -> +b -> relu),
    # so the in-kernel ReLU must be deferred along with it.
    defer_bias = b is not None and not spec.fuse_bias
    bias = b if spec.fuse_bias else None
    relu = spec.relu and not defer_bias
    route = resolve_route(spec)
    if route == "direct":
        y = conv2d_ref(x, w, bias, stride=spec.stride, padding=spec.padding,
                       groups=spec.groups, relu=relu)
    elif route == "pallas":
        y = pallas_conv2d(x, w, bias, m=spec.winograd_m, padding=spec.padding,
                          relu=relu, groups=spec.groups, pallas=True,
                          interpret=interpret)
    else:  # winograd (pure-jnp, differentiable)
        y = conv2d_winograd(x, w, bias, m=spec.winograd_m,
                            padding=spec.padding, relu=relu,
                            groups=spec.groups)
    if defer_bias:
        y = y + b.astype(y.dtype)
        if spec.relu:
            y = jnp.maximum(y, 0)
    return y
