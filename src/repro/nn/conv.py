"""Unified conv-layer dispatch: declarative ConvSpec -> one entry point.

Models declare each conv *layer* as a :class:`ConvSpec` — kernel geometry,
groups, fusion flags (bias, ReLU, cross-channel LRN, max-pool), route — and
call :func:`dispatch_conv`; all routing policy — Winograd eligibility,
Pallas vs jnp, direct fallback, grouped batching — lives here instead of
ad-hoc per-model branching.

Routes
------
``direct``    ``lax.conv_general_dilated`` (any kernel/stride; groups via
              ``feature_group_count``), bias/ReLU/LRN/pool as epilogue.
``winograd``  pure-jnp F(m,r) x F(m,r) path (differentiable; training).
``pallas``    stream-buffered Pallas kernel (in-kernel tiling, channel-block
              reduction, fused bias+ReLU+LRN+pool epilogue; inference).
``auto``      ``winograd`` when eligible, else ``direct``.

Winograd routes require stride 1 and a 3x3 kernel (the paper's F(4,3)
layers); ineligible specs silently fall back to ``direct`` so models never
need their own conv branching.

Layer-level fusion (paper §3.5): with ``fuse_lrn`` / ``fuse_pool`` the
post-conv stages run inside the conv call — in VMEM on the Pallas route, so
the full-resolution feature map never round-trips HBM between conv, norm,
and pool.  All three routes share one fused signature and stay numerically
interchangeable against the unfused conv -> lrn -> maxpool reference
(``repro.nn.pooling``).
"""
from __future__ import annotations

from dataclasses import dataclass, replace

import jax.numpy as jnp

from ..core.winograd import conv2d_winograd
from ..kernels.winograd.ops import conv2d as pallas_conv2d
from ..kernels.winograd.ref import conv2d_ref
from .pooling import LrnParams, apply_epilogue, pooled_hw

ROUTES = ("auto", "direct", "winograd", "pallas")


@dataclass(frozen=True)
class ConvSpec:
    """Declarative description of one 2D conv *layer* (NHWC / HWIO).

    Beyond the conv itself, the spec owns the whole layer epilogue: bias,
    ReLU, cross-channel LRN, and spatial max-pool, in that order (the
    Krizhevsky layer graph).  Flagged stages are fused into the conv call.
    """
    kernel: int
    stride: int = 1
    padding: str = "SAME"           # "SAME" | "VALID"
    groups: int = 1
    fuse_bias: bool = True          # apply bias inside the conv call
    relu: bool = False              # fused ReLU epilogue
    fuse_lrn: bool = False          # fused cross-channel LRN epilogue
    lrn: LrnParams = LrnParams()    # LRN constants (used when fuse_lrn)
    fuse_pool: bool = False         # fused VALID max-pool epilogue
    pool_window: int = 3
    pool_stride: int = 2
    route: str = "auto"             # "auto" | "direct" | "winograd" | "pallas"
    winograd_m: int = 4             # F(m, 3) output tile size

    def __post_init__(self):
        assert self.route in ROUTES, self.route
        assert self.padding in ("SAME", "VALID"), self.padding
        assert self.pool_window >= 1 and self.pool_stride >= 1

    def with_route(self, route: str) -> "ConvSpec":
        return replace(self, route=route)

    @property
    def winograd_eligible(self) -> bool:
        return self.stride == 1 and self.kernel == 3

    def out_hw(self, h: int) -> int:
        """Layer output extent for input extent ``h`` (conv then pool)."""
        h = ((h - self.kernel) // self.stride + 1 if self.padding == "VALID"
             else -(-h // self.stride))
        if self.fuse_pool:
            h = pooled_hw(h, self.pool_window, self.pool_stride)
        return h


def resolve_route(spec: ConvSpec) -> str:
    """Final route after eligibility fallback (never returns "auto")."""
    if spec.route == "auto":
        return "winograd" if spec.winograd_eligible else "direct"
    if spec.route in ("winograd", "pallas") and not spec.winograd_eligible:
        return "direct"
    return spec.route


def dispatch_conv(spec: ConvSpec, x, w, b=None, *, interpret=None):
    """Run one conv layer per its spec.  x (B,H,W,C), w (k,k,C//g,K), b (K,).

    Grouped convs are batched (``feature_group_count`` on the direct route,
    a group-folded kernel grid / vmap on the Winograd routes) — never a
    Python loop over groups.  LRN always spans the *full* concatenated
    channel dimension, including across group seams (Krizhevsky conv2).
    """
    assert w.shape[0] == w.shape[1] == spec.kernel, (w.shape, spec.kernel)
    # Unfused bias is an epilogue *between* conv and ReLU
    # (conv -> +b -> relu -> lrn -> pool), so every later stage must be
    # deferred along with it.
    defer_bias = b is not None and not spec.fuse_bias
    bias = b if spec.fuse_bias else None
    relu = spec.relu and not defer_bias
    lrn_p = spec.lrn if spec.fuse_lrn and not defer_bias else None
    pool = ((spec.pool_window, spec.pool_stride)
            if spec.fuse_pool and not defer_bias else None)
    route = resolve_route(spec)
    if route == "direct":
        y = conv2d_ref(x, w, bias, stride=spec.stride, padding=spec.padding,
                       groups=spec.groups, relu=relu, lrn=lrn_p, pool=pool)
    elif route == "pallas":
        y = pallas_conv2d(x, w, bias, m=spec.winograd_m, padding=spec.padding,
                          relu=relu, groups=spec.groups, lrn=lrn_p, pool=pool,
                          pallas=True, interpret=interpret)
    else:  # winograd (pure-jnp, differentiable)
        y = conv2d_winograd(x, w, bias, m=spec.winograd_m,
                            padding=spec.padding, relu=relu,
                            groups=spec.groups, lrn=lrn_p, pool=pool)
    if defer_bias:
        y = y + b.astype(y.dtype)
        if spec.relu:
            y = jnp.maximum(y, 0)
        y = apply_epilogue(y,
                           spec.lrn if spec.fuse_lrn else None,
                           (spec.pool_window, spec.pool_stride)
                           if spec.fuse_pool else None)
    return y
