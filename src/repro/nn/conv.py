"""Unified conv-layer dispatch: declarative ConvSpec -> one entry point.

Models declare each conv *layer* as a :class:`ConvSpec` — kernel geometry,
groups, fusion flags (bias, ReLU, cross-channel LRN, max-pool), route — and
call :func:`dispatch_conv`; all routing policy — Winograd eligibility,
Pallas vs jnp, direct fallback, grouped batching — lives here instead of
ad-hoc per-model branching.

Routes
------
``direct``    ``lax.conv_general_dilated`` (any kernel/stride; groups via
              ``feature_group_count``), bias/ReLU/LRN/pool as epilogue.
``winograd``  pure-jnp F(m,r) x F(m,r) path (differentiable; training).
``pallas``    stream-buffered Pallas kernels (in-kernel tiling,
              channel-block reduction, filter-cache batch grid, fused
              bias+ReLU+LRN+pool epilogue; inference).
``auto``      ``winograd`` when eligible, else ``direct``.

Winograd math requires stride 1 and a 3x3 kernel (the paper's F(4,3)
layers).  The ``pallas`` route serves *every* geometry: Winograd-eligible
specs hit the Winograd-domain kernel, everything else (AlexNet's 11x11
stride-4 conv1, the 5x5 conv2, pointwise, ...) hits the strided direct
kernel — like the paper's DLA, whose stream buffers feed both the Winograd
PEs and the non-Winograd first layer (§3.3/§3.5).  Only the pure-jnp
``winograd`` route still falls back to ``direct`` on ineligible specs.
:func:`resolve_kernel` exposes the fully resolved datapath
(``pallas-winograd`` / ``pallas-direct`` / ``winograd`` / ``direct``) so
serving can log per-layer routes instead of degrading silently.

Layer-level fusion (paper §3.5): with ``fuse_lrn`` / ``fuse_pool`` the
post-conv stages run inside the conv call — in VMEM on the Pallas route, so
the full-resolution feature map never round-trips HBM between conv, norm,
and pool.  All routes share one fused signature and stay numerically
interchangeable against the unfused conv -> lrn -> maxpool reference
(``repro.nn.pooling``).

Weight staging (paper §3.5 filter prefetch, cross-layer level): the Pallas
kernels take their filters as a *tile-packed slab* that a model can build
ahead of time — :func:`pack_conv_weights` is a pure function of the layer
spec and input shape, so layer N+1's slab (Winograd-transformed, blocked,
optionally §3.6 BFP-quantized) can be dispatched while layer N computes.
:func:`dispatch_conv` accepts the staged slab (``w_packed``) plus a
``prefetch_next`` callable it invokes right after issuing the conv — the
hook a model uses to stage the *next* layer's weights behind the current
layer's compute (see ``models/alexnet.py`` and
``kernels/conv/dma.py::WeightStager``).
"""
from __future__ import annotations

import math
import zlib
from dataclasses import dataclass, replace

import jax
import jax.numpy as jnp
import numpy as np

from ..core import bfp
from ..core.winograd import conv2d_winograd
from ..kernels.conv import direct as _direct_k
from ..kernels.conv import dma as _dma
from ..kernels.conv import winograd as _winograd_k
from ..kernels.conv.ops import conv2d as pallas_conv2d
from ..kernels.conv.ops import conv2d_direct as pallas_conv2d_direct
from ..kernels.conv.ref import conv2d_ref
from .pooling import LrnParams, apply_epilogue, pooled_hw

ROUTES = ("auto", "direct", "winograd", "pallas")

# fully resolved datapaths reported by resolve_kernel
KERNELS = ("direct", "winograd", "pallas-winograd", "pallas-direct")

# sentinel distinguishing "knob not passed" from an explicit None (= auto)
UNSET = object()


@dataclass(frozen=True)
class ConvPlan:
    """A per-layer launch plan over the real kernel knobs — what the
    measured autotuner (``core/autotune.py``, the paper's §4 DSE run live)
    searches, persists, and feeds back into :func:`dispatch_conv`.

    The defaults ARE the repo's default launch configuration: a
    ``ConvPlan()`` reproduces exactly what ``dispatch_conv`` runs when no
    knob is passed, so the default plan is always a member of any
    candidate set and "tuned" can never regress it.

    ``route`` optionally overrides the spec's route preference (a
    :data:`ROUTES` member); ``None`` keeps the spec's own routing.  All
    other fields mirror the kernel knobs: ``c_block``/``pool_row_block``
    ``None`` means auto-size against the VMEM budget
    (``auto_c_block``/``auto_pool_rows``), ``row_parallel`` restarts the
    DMA weight stream per row block so the row grid dimension runs
    ``parallel`` (bit-equal; one extra exposed warmup tile per row block).
    """
    batch_block: int = 8
    k_block: int = 128
    c_block: int | None = None
    pool_row_block: int | None = None
    weight_prefetch: bool = True
    row_parallel: bool = False
    route: str | None = None

    def __post_init__(self):
        assert self.route is None or self.route in ROUTES, self.route
        assert self.batch_block >= 1 and self.k_block >= 1

    def to_dict(self) -> dict:
        return {"batch_block": self.batch_block, "k_block": self.k_block,
                "c_block": self.c_block,
                "pool_row_block": self.pool_row_block,
                "weight_prefetch": self.weight_prefetch,
                "row_parallel": self.row_parallel, "route": self.route}

    @classmethod
    def from_dict(cls, d: dict) -> "ConvPlan":
        return cls(**{k: d[k] for k in cls.__dataclass_fields__ if k in d})


DEFAULT_PLAN = ConvPlan()


def plan_knobs(plan: "ConvPlan | None" = None, *, batch_block=UNSET,
               k_block=UNSET, c_block=UNSET, pool_row_block=UNSET,
               weight_prefetch=UNSET, row_parallel=UNSET) -> "ConvPlan":
    """The effective launch knobs for one dispatch: explicit kwarg beats
    plan beats built-in default.  ``UNSET`` marks "not passed" so an
    explicit ``c_block=None`` (force auto-sizing) still overrides a tuned
    plan's block choice."""
    base = plan if plan is not None else DEFAULT_PLAN
    return replace(
        base,
        batch_block=base.batch_block if batch_block is UNSET else batch_block,
        k_block=base.k_block if k_block is UNSET else k_block,
        c_block=base.c_block if c_block is UNSET else c_block,
        pool_row_block=(base.pool_row_block if pool_row_block is UNSET
                        else pool_row_block),
        weight_prefetch=(base.weight_prefetch if weight_prefetch is UNSET
                         else weight_prefetch),
        row_parallel=(base.row_parallel if row_parallel is UNSET
                      else row_parallel))

# resolved datapath -> (conv2d_hbm_bytes route, uses winograd transform):
# the one place benchmarks/tests translate a datapath into model terms
MODEL_ROUTES = {
    "pallas-winograd": ("pallas", True),
    "pallas-direct": ("pallas", False),
    "winograd": ("winograd", True),
    "direct": ("direct", False),
}


def conv_out_hw(extent: int, kernel: int, stride: int, padding: str) -> int:
    """Conv output extent (lax SAME/VALID semantics) — the one formula
    every spec/guard/model shares."""
    return ((extent - kernel) // stride + 1 if padding == "VALID"
            else -(-extent // stride))


@dataclass(frozen=True)
class ConvSpec:
    """Declarative description of one 2D conv *layer* (NHWC / HWIO).

    Beyond the conv itself, the spec owns the whole layer epilogue: bias,
    ReLU, cross-channel LRN, and spatial max-pool, in that order (the
    Krizhevsky layer graph).  Flagged stages are fused into the conv call.
    """
    kernel: int
    stride: int = 1
    padding: str = "SAME"           # "SAME" | "VALID"
    groups: int = 1
    fuse_bias: bool = True          # apply bias inside the conv call
    relu: bool = False              # fused ReLU epilogue
    fuse_lrn: bool = False          # fused cross-channel LRN epilogue
    lrn: LrnParams = LrnParams()    # LRN constants (used when fuse_lrn)
    fuse_pool: bool = False         # fused VALID max-pool epilogue
    pool_window: int = 3
    pool_stride: int = 2
    route: str = "auto"             # "auto" | "direct" | "winograd" | "pallas"
    winograd_m: int = 4             # F(m, 3) output tile size

    def __post_init__(self):
        assert self.route in ROUTES, self.route
        assert self.padding in ("SAME", "VALID"), self.padding
        assert self.pool_window >= 1 and self.pool_stride >= 1

    def with_route(self, route: str) -> "ConvSpec":
        return replace(self, route=route)

    @property
    def winograd_eligible(self) -> bool:
        return self.stride == 1 and self.kernel == 3

    def out_hw(self, h: int) -> int:
        """Layer output extent for input extent ``h`` (conv then pool)."""
        h = conv_out_hw(h, self.kernel, self.stride, self.padding)
        if self.fuse_pool:
            h = pooled_hw(h, self.pool_window, self.pool_stride)
        return h


def resolve_route(spec: ConvSpec) -> str:
    """Final route after eligibility fallback (never returns "auto").

    ``pallas`` is always honored — the strided direct kernel serves every
    geometry the Winograd kernel cannot.  Only the pure-jnp ``winograd``
    route (stride-1 3x3 math, no direct twin) still falls back to
    ``direct``.
    """
    if spec.route == "auto":
        return "winograd" if spec.winograd_eligible else "direct"
    if spec.route == "winograd" and not spec.winograd_eligible:
        return "direct"
    return spec.route


def resolve_kernel(spec: ConvSpec, in_hw=None) -> str:
    """The fully resolved datapath this spec will execute — what serving
    logs report per layer (``--route pallas`` shows ``pallas-direct`` for
    conv1/conv2 instead of silently degrading to lax).

    Pass ``in_hw`` (an int extent or an (h, w) pair) to also resolve the
    one shape-dependent fallback exactly as ``dispatch_conv`` will: a
    fused pool window larger than the conv output has no VALID pooled
    region for a Pallas row block to own, so the lax path runs (and emits
    the empty pooled map).  Without ``in_hw`` that case reports the Pallas
    kernel the spec would use on a large-enough input.
    """
    route = resolve_route(spec)
    if route != "pallas":
        return route
    if in_hw is not None and spec.fuse_pool:
        hw = (in_hw, in_hw) if isinstance(in_hw, int) else in_hw
        if min(conv_out_hw(e, spec.kernel, spec.stride, spec.padding)
               for e in hw) < spec.pool_window:
            return "direct"
    return "pallas-winograd" if spec.winograd_eligible else "pallas-direct"


@dataclass(frozen=True)
class SlabFingerprint:
    """Pack-time identity of one staged weight slab: shape, dtype, a crc32
    of the packed bytes, and the pack *context* (the spec/fusion/knob
    string the slab was built under).  Computed once when the slab is
    packed; :meth:`matches` re-derives all four from the live array, so a
    corrupted slab (crc), a stale one (context — e.g. the layer was
    repacked under different fusion flags), or a mis-shaped one never
    reaches a kernel when the staging path verifies before dispatch.
    """
    shape: tuple
    dtype: str
    crc32: int
    context: str | None = None

    def matches(self, pw, *, expect=None) -> bool:
        """Verify a packed slab (or raw array) against this fingerprint;
        ``expect`` additionally pins the pack context the caller wants."""
        if expect is not None and self.context != expect:
            return False
        data = getattr(pw, "data", pw)
        if data is None or isinstance(data, jax.core.Tracer):
            return data is None     # a tracer can't be checked host-side
        host = np.asarray(data)
        return (tuple(host.shape) == tuple(self.shape)
                and str(host.dtype) == self.dtype
                and zlib.crc32(host.tobytes()) == self.crc32)


def slab_fingerprint(data, context: str | None = None):
    """Fingerprint one packed array (None/tracer -> no fingerprint; crc32
    forces a host transfer, so callers opt in at pack time only)."""
    if data is None or isinstance(data, jax.core.Tracer):
        return None
    host = np.asarray(data)
    return SlabFingerprint(shape=tuple(host.shape), dtype=str(host.dtype),
                           crc32=zlib.crc32(host.tobytes()), context=context)


def verify_packed(pw, *, expect: str | None = None) -> bool:
    """True iff ``pw`` (a :class:`PackedConvWeights` or anything duck-typed
    like one) carries an intact slab.  Values without a fingerprint have
    nothing to verify against and pass."""
    fp = getattr(pw, "fingerprint", None)
    return fp is None or fp.matches(pw, expect=expect)


@dataclass(frozen=True)
class PackedConvWeights:
    """A staged weight slab: the resolved datapath it was packed for plus
    the packed array (tile-packed DMA slab on the Pallas kernels, the
    BFP-requantized raw filters elsewhere, or None when the route has no
    packed form).

    Registered as a pytree (``data`` is the sole child; ``kernel``/``bfp``
    ride as static aux data) so a slab dict can cross a ``jax.jit``
    boundary as an *argument* — the serving engines hoist their pack-once
    slabs out of the compiled forward this way instead of re-packing
    in-trace every call (ROADMAP's donated-buffer serving refactor).

    ``fingerprint`` (a :class:`SlabFingerprint`, or None) is host-side
    integrity metadata, deliberately EXCLUDED from the pytree — it must
    never change a jit cache key, and tree ops (device_put, tree_map)
    drop it; re-attach with ``dataclasses.replace`` after moving a slab.
    """
    kernel: str                     # resolved datapath (KERNELS member)
    data: object                    # jnp array or None
    bfp: bool = False
    fingerprint: object = None      # SlabFingerprint | None (not a pytree leaf)


jax.tree_util.register_pytree_node(
    PackedConvWeights,
    lambda p: ((p.data,), (p.kernel, p.bfp)),
    lambda aux, ch: PackedConvWeights(kernel=aux[0], data=ch[0], bfp=aux[1]))


def _spec_fusion(spec: ConvSpec):
    """(lrn, pool) as the kernels see them when the bias is fused."""
    lrn_p = spec.lrn if spec.fuse_lrn else None
    pool = (spec.pool_window, spec.pool_stride) if spec.fuse_pool else None
    return lrn_p, pool


def _pallas_weight_plan(spec: ConvSpec, kernel: str, in_shape, w_shape, *,
                        lrn, pool, knobs: ConvPlan, abft: bool = False):
    """The weight-blocking plan the resolved Pallas kernel will use for
    this (spec, input shape, fusion args, launch knobs) — the one source
    of truth for slab shapes.  ``lrn``/``pool`` are the values the kernel
    call actually receives (a deferred bias strips them even when the spec
    fuses).  ``abft`` arms the checksum row, so slab shapes grow one Cb
    row per tile."""
    if kernel == "pallas-winograd":
        return _winograd_k.plan(in_shape, w_shape, m=spec.winograd_m,
                                padding=spec.padding, groups=spec.groups,
                                lrn=lrn, pool=pool, c_block=knobs.c_block,
                                pool_row_block=knobs.pool_row_block,
                                k_block=knobs.k_block,
                                batch_block=knobs.batch_block,
                                checksum=abft)
    return _direct_k.plan(in_shape, w_shape, stride=spec.stride,
                          padding=spec.padding, pool=pool,
                          groups=spec.groups, c_block=knobs.c_block,
                          pool_row_block=knobs.pool_row_block,
                          k_block=knobs.k_block,
                          batch_block=knobs.batch_block,
                          checksum=abft)


def _pack_for_plan(kernel: str, w, p, bfp_pack: bool):
    """Pack (and optionally §3.6-quantize) the slab for an already-derived
    plan — shared by the ahead-of-time staging path and the in-dispatch
    repack fallback, so quantization semantics can never diverge."""
    pack = (_winograd_k.pack_weights if kernel == "pallas-winograd"
            else _direct_k.pack_weights)
    tiles = pack(w, p)
    if bfp_pack:
        # per-tile shared exponents along the Cb contraction axis.  An
        # ABFT checksum row must cover the *final* slab bits, so strip it
        # before quantizing (the quantization blocks then still tile Cb
        # exactly) and recompute it over the requantized rows.
        if p.checksum:
            tiles = tiles[..., :-1, :]
        tiles = bfp.quantize_dequantize(
            tiles, block=math.gcd(p.weights.Cb, 32), axis=-2)
        if p.checksum:
            tiles = _dma.append_checksum_row(tiles)
    return tiles


def pack_context(spec: ConvSpec, kernel: str, *, bfp_pack: bool,
                 abft: bool, knobs: ConvPlan) -> str:
    """Canonical pack-context string — everything that changes the bytes a
    slab holds.  Stored in the fingerprint so a cache hit can detect a
    slab packed under *different* fusion flags or knobs (the silent
    stale-slab reuse the WeightStager verify path closes)."""
    return (f"{kernel}:k{spec.kernel}s{spec.stride}g{spec.groups}"
            f":{spec.padding}:relu{int(spec.relu)}"
            f":lrn{int(spec.fuse_lrn)}:pool{int(spec.fuse_pool)}"
            f"w{spec.pool_window}s{spec.pool_stride}"
            f":bfp{int(bfp_pack)}:abft{int(abft)}"
            f":kb{knobs.k_block}:bb{knobs.batch_block}")


def expected_pack_context(spec: ConvSpec, in_shape, *, bfp_pack: bool = False,
                          abft: bool = False, plan: ConvPlan | None = None,
                          k_block=UNSET, batch_block=UNSET) -> str:
    """The :func:`pack_context` string :func:`pack_conv_weights` would stamp
    for these arguments — resolved the same way (plan route override, then
    shape-aware kernel resolution), so staging-path callers can assert a
    cached slab was packed under the fusion flags and knobs they are about
    to dispatch with (``WeightStager.stage(expect=...)``)."""
    knobs = plan_knobs(plan, k_block=k_block, batch_block=batch_block)
    if plan is not None and plan.route is not None:
        spec = spec.with_route(plan.route)
    kernel = resolve_kernel(spec, in_hw=(in_shape[1], in_shape[2]))
    return pack_context(spec, kernel, bfp_pack=bfp_pack, abft=abft,
                        knobs=knobs)


def pack_conv_weights(spec: ConvSpec, in_shape, w, *, bfp_pack: bool = False,
                      abft: bool = False, fingerprint: bool = False,
                      plan: ConvPlan | None = None, k_block=UNSET,
                      batch_block=UNSET) -> PackedConvWeights:
    """Build the weight slab for one conv layer ahead of its input.

    A pure function of the layer spec, the input *shape* (B, H, W, C), and
    the raw filters — everything the §3.5 cross-layer prefetch needs to
    stage layer N+1's slab while layer N computes.  On the Pallas datapaths
    this is the full packing the kernel would otherwise do in-trace:
    Winograd filter transform (G w G^T), group/channel blocking, and the
    manual-DMA tile layout.  With ``bfp_pack`` the slab is additionally
    quantized §3.6-style (shared-exponent int8 blocks along the
    contraction dim, ``fc_bfp``'s scheme applied to the filter stream —
    the DLA's filter cache holds *transformed* filters, so quantization
    happens post-transform) and dequantized back to the compute dtype, so
    the staged values are exactly what a 1-byte weight stream would carry.

    Non-Pallas routes have no tile slab; they still get a BFP
    requantization (``data`` replaces ``w``).  Quantization follows the
    datapath's *stored filter format* — Winograd-transformed tiles on the
    Pallas kernels (as in the DLA's cache), raw filters elsewhere — so a
    ``conv_bfp`` model's routes agree only within the shared-exponent
    int8 error, not bit-wise across datapaths.

    ``plan`` is an optional tuned :class:`ConvPlan` — the slab is blocked
    for its knobs, so staging and dispatch agree when both receive the
    same plan.  Explicit ``k_block``/``batch_block`` kwargs override it.

    SDC defense: ``abft=True`` packs the slab with the per-tile ABFT
    checksum row the kernels verify in-stream (pass the same flag to
    :func:`dispatch_conv`); ``fingerprint=True`` attaches a pack-time
    :class:`SlabFingerprint` (shape/dtype/crc32/pack-context) for the
    staging-path integrity checks.  Fingerprinting forces the packed bytes
    to the host (crc32), so it is opt-in — it would otherwise serialize
    the async cross-layer staging pipeline.
    """
    knobs = plan_knobs(plan, k_block=k_block, batch_block=batch_block)
    if plan is not None and plan.route is not None:
        spec = spec.with_route(plan.route)
    kernel = resolve_kernel(spec, in_hw=(in_shape[1], in_shape[2]))
    ctx = pack_context(spec, kernel, bfp_pack=bfp_pack, abft=abft,
                       knobs=knobs)
    if kernel.startswith("pallas"):
        lrn_p, pool = _spec_fusion(spec)
        p = _pallas_weight_plan(spec, kernel, tuple(in_shape), w.shape,
                                lrn=lrn_p, pool=pool, knobs=knobs,
                                abft=abft)
        data = _pack_for_plan(kernel, w, p, bfp_pack)
    else:
        data = (bfp.quantize_dequantize(w, block=math.gcd(w.shape[2], 32),
                                        axis=2) if bfp_pack else None)
    return PackedConvWeights(
        kernel=kernel, data=data, bfp=bfp_pack,
        fingerprint=slab_fingerprint(data, ctx) if fingerprint else None)


def dispatch_conv(spec: ConvSpec, x, w, b=None, *, interpret=None,
                  w_packed: PackedConvWeights | None = None,
                  plan: ConvPlan | None = None, weight_prefetch=UNSET,
                  k_block=UNSET, batch_block=UNSET, c_block=UNSET,
                  pool_row_block=UNSET, row_parallel=UNSET,
                  abft: bool = False, prefetch_next=None):
    """Run one conv layer per its spec.  x (B,H,W,C), w (k,k,C//g,K), b (K,).

    Grouped convs are batched (``feature_group_count`` on the direct route,
    a group-folded kernel grid / vmap on the Winograd/Pallas routes) — never
    a Python loop over groups.  LRN always spans the *full* concatenated
    channel dimension, including across group seams (Krizhevsky conv2).

    Weight pipeline (§3.5): ``w_packed`` is a slab staged earlier by
    :func:`pack_conv_weights` — used directly when it matches the datapath
    and plan this call resolves to; on a mismatch (deferred-bias epilogue,
    different input shape/plan, route fallback) a ``bfp``-marked slab is
    *repacked* for the actual plan so §3.6 quantization is never silently
    dropped, and a plain slab is ignored (the kernel packs in-trace —
    identical values either way).  ``weight_prefetch`` selects the kernels'
    double-buffered manual-DMA filter stream (on, default) vs the same
    copies run synchronously (off; bit-equal).  ``prefetch_next`` is a
    zero-arg callable invoked right after the conv is issued — JAX
    dispatch is async, so work it enqueues (packing layer N+1's slab)
    overlaps this layer's compute.

    ``plan`` is an optional tuned :class:`ConvPlan` (from the measured
    autotuner): its knobs replace the built-in launch defaults, and its
    ``route`` (when set) overrides the spec's route preference.  Explicit
    knob kwargs still win over the plan (see :func:`plan_knobs`), so call
    sites can pin single knobs on top of a tuned baseline.

    ``abft=True`` arms the ABFT weight-stream verification and the return
    becomes ``(y, verdict)`` uniformly across *all* routes: the Pallas
    kernels verify each staged checksum tile after its DMA slot swap and
    report the scalar int32 mismatch count; non-Pallas routes have no DMA
    stream to corrupt, so their verdict is the constant 0.  The ``y``
    values are bit-identical to the unarmed call (the GEMMs consume the
    slab minus its checksum row).
    """
    assert w.shape[0] == w.shape[1] == spec.kernel, (w.shape, spec.kernel)
    knobs = plan_knobs(plan, batch_block=batch_block, k_block=k_block,
                       c_block=c_block, pool_row_block=pool_row_block,
                       weight_prefetch=weight_prefetch,
                       row_parallel=row_parallel)
    if plan is not None and plan.route is not None:
        spec = spec.with_route(plan.route)
    # Unfused bias is an epilogue *between* conv and ReLU
    # (conv -> +b -> relu -> lrn -> pool), so every later stage must be
    # deferred along with it.
    defer_bias = b is not None and not spec.fuse_bias
    bias = b if spec.fuse_bias else None
    relu = spec.relu and not defer_bias
    lrn_p = spec.lrn if spec.fuse_lrn and not defer_bias else None
    pool = ((spec.pool_window, spec.pool_stride)
            if spec.fuse_pool and not defer_bias else None)
    kernel = resolve_kernel(spec, in_hw=(x.shape[1], x.shape[2]))

    slab = None
    if w_packed is not None and kernel.startswith("pallas"):
        p = _pallas_weight_plan(spec, kernel, x.shape, w.shape,
                                lrn=lrn_p, pool=pool, knobs=knobs,
                                abft=abft)
        want = (p.weights.n_tiles, *p.weights.tile_shape)
        if (w_packed.kernel == kernel and w_packed.data is not None
                and w_packed.data.shape == want):
            slab = w_packed.data
        elif w_packed.bfp:          # never silently drop §3.6 quantization
            slab = _pack_for_plan(kernel, w, p, True)
    elif w_packed is not None:
        if w_packed.kernel == kernel and w_packed.data is not None:
            w = w_packed.data       # BFP-requantized raw filters
        elif w_packed.bfp:          # route fell back with a stale slab
            w = bfp.quantize_dequantize(w, block=math.gcd(w.shape[2], 32),
                                        axis=2)

    if kernel == "direct":
        y = conv2d_ref(x, w, bias, stride=spec.stride, padding=spec.padding,
                       groups=spec.groups, relu=relu, lrn=lrn_p, pool=pool)
    elif kernel == "pallas-winograd":
        y = pallas_conv2d(x, w, bias, slab, m=spec.winograd_m,
                          padding=spec.padding, relu=relu, groups=spec.groups,
                          lrn=lrn_p, pool=pool, c_block=knobs.c_block,
                          pool_row_block=knobs.pool_row_block,
                          k_block=knobs.k_block,
                          batch_block=knobs.batch_block,
                          weight_prefetch=knobs.weight_prefetch,
                          row_parallel=knobs.row_parallel,
                          checksum=abft, pallas=True, interpret=interpret)
    elif kernel == "pallas-direct":
        y = pallas_conv2d_direct(x, w, bias, slab, stride=spec.stride,
                                 padding=spec.padding, relu=relu,
                                 groups=spec.groups, lrn=lrn_p, pool=pool,
                                 c_block=knobs.c_block,
                                 pool_row_block=knobs.pool_row_block,
                                 k_block=knobs.k_block,
                                 batch_block=knobs.batch_block,
                                 weight_prefetch=knobs.weight_prefetch,
                                 row_parallel=knobs.row_parallel,
                                 checksum=abft, pallas=True,
                                 interpret=interpret)
    else:  # winograd (pure-jnp, differentiable)
        y = conv2d_winograd(x, w, bias, m=spec.winograd_m,
                            padding=spec.padding, relu=relu,
                            groups=spec.groups, lrn=lrn_p, pool=pool)
    verdict = None
    if abft:
        if kernel.startswith("pallas"):
            y, verdict = y
        else:
            verdict = jnp.zeros((), jnp.int32)
    if prefetch_next is not None:
        prefetch_next()             # stage layer N+1 behind this dispatch
    if defer_bias:
        y = y + b.astype(y.dtype)
        if spec.relu:
            y = jnp.maximum(y, 0)
        y = apply_epilogue(y,
                           spec.lrn if spec.fuse_lrn else None,
                           (spec.pool_window, spec.pool_stride)
                           if spec.fuse_pool else None)
    return (y, verdict) if abft else y
