"""Minimal functional parameter-tree toolkit (no flax dependency).

Parameters are plain nested dicts of jnp arrays.  Every layer is a pair of
functions ``<layer>_init(key, ...) -> params`` and ``<layer>(params, x, ...)``.
Stacked (scan-over-layers) parameters are built with ``stack_init``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def truncated_normal(key, shape, dtype, stddev):
    # 2-sigma truncation, same flavour as flax default initializers.
    unscaled = jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
    return (unscaled * stddev).astype(dtype)


def dense_init_std(fan_in: int) -> float:
    return 1.0 / np.sqrt(fan_in)


def param(key, shape, dtype, scale: float | None = None):
    """Default weight init: truncated normal with 1/sqrt(fan_in) std."""
    if scale is None:
        scale = dense_init_std(shape[0] if len(shape) > 1 else shape[-1])
    return truncated_normal(key, shape, dtype, scale)


def split(key, n: int):
    return list(jax.random.split(key, n))


def stack_init(init_fn, key, n: int):
    """vmap an init function over ``n`` stacked copies (scan-over-layers)."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def cast_tree(tree, dtype):
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree)


def count_params(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree) -> int:
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(tree))
