"""Attention mixers: GQA (RoPE) and MLA (DeepSeek-V2), plus cross-attention.

Modes:
  train   — causal blockwise attention, no cache.
  bidir   — non-causal (encoder / cross-attention while training).
  prefill — causal, returns a populated KV cache (sequence-sharded).
  decode  — one new token against the cache; MLA uses the absorbed
            (latent-space) formulation so the per-head K/V are never
            materialized at cache length.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..config import ArchConfig
from ..parallel.sharding import constrain
from .flash import decode_attention, flash_attention
from .layers import linear, linear_init, rmsnorm, rmsnorm_init, rope
from .module import split


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------
def attn_init(key, cfg: ArchConfig, cross: bool = False):
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.d_head
    dtype = jnp.dtype(cfg.param_dtype)
    if cfg.mla is not None and not cross:
        m = cfg.mla
        kq, kdkv, kuk, kuv, ko = split(key, 5)
        return {
            "wq": linear_init(kq, d, H * (m.qk_nope_head_dim + m.qk_rope_head_dim), dtype),
            "wdkv": linear_init(kdkv, d, m.kv_lora_rank + m.qk_rope_head_dim, dtype),
            "kv_norm": rmsnorm_init(m.kv_lora_rank, dtype),
            "wuk": linear_init(kuk, m.kv_lora_rank, H * m.qk_nope_head_dim, dtype),
            "wuv": linear_init(kuv, m.kv_lora_rank, H * m.v_head_dim, dtype),
            "wo": linear_init(ko, H * m.v_head_dim, d, dtype),
        }
    kq, kk, kv, ko = split(key, 4)
    return {
        "wq": linear_init(kq, d, H * hd, dtype, bias=cfg.qkv_bias),
        "wk": linear_init(kk, d, KV * hd, dtype, bias=cfg.qkv_bias),
        "wv": linear_init(kv, d, KV * hd, dtype, bias=cfg.qkv_bias),
        "wo": linear_init(ko, H * hd, d, dtype, bias=cfg.qkv_bias),
    }


def attn_cache_shape(cfg: ArchConfig, batch: int, max_len: int, cross_len: int = 0):
    """Abstract cache structure (shapes/dtypes) for one attention layer."""
    dt = jnp.dtype(cfg.dtype)
    if cfg.mla is not None:
        m = cfg.mla
        cache = {
            "ckv": jax.ShapeDtypeStruct((batch, max_len, m.kv_lora_rank), dt),
            "kpe": jax.ShapeDtypeStruct((batch, max_len, m.qk_rope_head_dim), dt),
        }
    else:
        kv, hd = cfg.num_kv_heads, cfg.d_head
        cache = {
            "k": jax.ShapeDtypeStruct((batch, max_len, kv, hd), dt),
            "v": jax.ShapeDtypeStruct((batch, max_len, kv, hd), dt),
        }
    if cross_len:
        kv, hd = cfg.num_kv_heads, cfg.d_head
        cache["ck"] = jax.ShapeDtypeStruct((batch, cross_len, kv, hd), dt)
        cache["cv"] = jax.ShapeDtypeStruct((batch, cross_len, kv, hd), dt)
    return cache


def attn_cache_init(cfg, batch, max_len, cross_len: int = 0):
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype),
        attn_cache_shape(cfg, batch, max_len, cross_len))


# --------------------------------------------------------------------------
# GQA
# --------------------------------------------------------------------------
def _qkv(p, cfg, x):
    B, S, _ = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.d_head
    q = linear(p["wq"], x).reshape(B, S, H, hd)
    k = linear(p["wk"], x).reshape(B, S, KV, hd)
    v = linear(p["wv"], x).reshape(B, S, KV, hd)
    return q, k, v


def gqa_apply(p, cfg: ArchConfig, x, *, mode: str, length=None, cache=None,
              enc_out=None, use_rope: bool = True):
    B, S, _ = x.shape
    H, hd = cfg.num_heads, cfg.d_head
    new_cache = cache

    if mode in ("train", "bidir", "prefill"):
        if enc_out is not None:                      # cross-attn (training)
            q = linear(p["wq"], x).reshape(B, S, H, hd)
            T = enc_out.shape[1]
            k = linear(p["wk"], enc_out).reshape(B, T, cfg.num_kv_heads, hd)
            v = linear(p["wv"], enc_out).reshape(B, T, cfg.num_kv_heads, hd)
            use_rope = False
            causal = False
        else:
            q, k, v = _qkv(p, cfg, x)
            causal = mode != "bidir"
        if use_rope:
            pos = jnp.arange(S)[None, :]
            q = rope(q, pos, cfg.rope_theta)
            k = rope(k, pos, cfg.rope_theta)
        # Pin the attention-region layout BEFORE the flash chunk loops:
        # otherwise GSPMD propagates the sequence-parallel residual sharding
        # into the scan and re-shards every (q,k) chunk pair per iteration
        # (measured: per-layer all-to-alls x nq x nk inside the loop on
        # starcoder2 train_4k).  Two regimes:
        #   heads % model == 0 -> head-parallel attention (Megatron);
        #   otherwise          -> sequence-parallel q with replicated KV
        #                         (small-KV models; avoids full replication).
        from ..parallel.sharding import active_mesh
        mesh = active_mesh()
        msize = mesh.shape.get("model", 1) if mesh is not None else 1
        if cfg.num_heads % max(msize, 1) == 0:
            q = constrain(q, ("batch", "seq", "heads", "head_dim"))
            k = constrain(k, ("batch", "seq", "kv_heads", "head_dim"))
            v = constrain(v, ("batch", "seq", "kv_heads", "head_dim"))
            o_axes = ("batch", "seq", "heads", "head_dim")
        else:
            q = constrain(q, ("batch", "seq_res", None, "head_dim"))
            k = constrain(k, ("batch", None, None, "head_dim"))
            v = constrain(v, ("batch", None, None, "head_dim"))
            o_axes = ("batch", "seq_res", None, "head_dim")
        o = flash_attention(q, k, v, causal=causal,
                            banded=cfg.banded_attention)
        o = constrain(o, o_axes)
        from jax.ad_checkpoint import checkpoint_name
        o = checkpoint_name(o, "attn_out")
        if mode == "prefill" and cache is not None:
            if enc_out is not None:
                new_cache = dict(cache, ck=_ccache(k, cache["ck"]),
                                 cv=_ccache(v, cache["cv"]))
            else:
                new_cache = dict(cache,
                                 k=_into(cache["k"], k), v=_into(cache["v"], v))
    elif mode == "decode":
        q = linear(p["wq"], x).reshape(B, S, H, hd)
        if enc_out is None and "k" in cache:
            knew = linear(p["wk"], x).reshape(B, S, cfg.num_kv_heads, hd)
            vnew = linear(p["wv"], x).reshape(B, S, cfg.num_kv_heads, hd)
            if use_rope:
                posv = pos_of(length, S)
                q = rope(q, posv, cfg.rope_theta)
                knew = rope(knew, posv, cfg.rope_theta)
            kc = cache_write(cache["k"], knew, length)
            vc = cache_write(cache["v"], vnew, length)
            kc = constrain(kc, ("batch", "cache_seq", "cache_kv_heads", "head_dim"))
            vc = constrain(vc, ("batch", "cache_seq", "cache_kv_heads", "head_dim"))
            new_cache = dict(cache, k=kc, v=vc)
            o = decode_attention(q, kc, vc, length + S)
        else:                                       # cross-attn decode
            o = decode_attention(q, cache["ck"], cache["cv"],
                                 cache["ck"].shape[1])
    else:
        raise ValueError(mode)

    y = linear(p["wo"], o.reshape(B, S, H * hd))
    return y.astype(x.dtype), new_cache


def _into(buf, val):
    val = constrain(val.astype(buf.dtype), ("batch", "cache_seq") + (("cache_kv_heads", "head_dim") if val.ndim == 4 else (None,) * (val.ndim - 2)))
    return jax.lax.dynamic_update_slice(buf, val, (0,) * buf.ndim)


def _ccache(v, buf):
    return jax.lax.dynamic_update_slice(buf, v.astype(buf.dtype), (0,) * buf.ndim)


def cache_write(buf, val, length):
    """Write ``val`` (B, S, ...) into ``buf`` at seq offset ``length``.

    length: scalar (one shared offset) or (B,) vector (per-slot offsets used
    by the continuous-batching serving engine)."""
    val = val.astype(buf.dtype)
    if jnp.ndim(length) == 0:
        idx = (0, length) + (0,) * (buf.ndim - 2)
        return jax.lax.dynamic_update_slice(buf, val, idx)
    zero = (0,) * (buf.ndim - 2)
    return jax.vmap(
        lambda b, v, l: jax.lax.dynamic_update_slice(b, v, (l,) + zero[:b.ndim - 1]))(
        buf, val, length)


def pos_of(length, S):
    """RoPE positions for S new tokens at offset ``length`` -> (B?, S)."""
    ar = jnp.arange(S)[None, :]
    if jnp.ndim(length) == 0:
        return length + ar
    return length[:, None] + ar


def len_mask(length, S_total, extra: int = 0):
    """(B?,1,1,S_total) validity mask for positions < length + extra."""
    valid_to = (length + extra if jnp.ndim(length) == 0
                else (length + extra)[:, None, None, None])
    return jnp.arange(S_total)[None, None, None, :] < valid_to


# --------------------------------------------------------------------------
# MLA
# --------------------------------------------------------------------------
def mla_apply(p, cfg: ArchConfig, x, *, mode: str, length=None, cache=None):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    nope, rdim, vdim, lora = (m.qk_nope_head_dim, m.qk_rope_head_dim,
                              m.v_head_dim, m.kv_lora_rank)
    q = linear(p["wq"], x).reshape(B, S, H, nope + rdim)
    q_nope, q_pe = q[..., :nope], q[..., nope:]
    dkv = linear(p["wdkv"], x)
    ckv, k_pe = dkv[..., :lora], dkv[..., lora:]
    ckv = rmsnorm(p["kv_norm"], ckv)

    if mode in ("train", "prefill"):
        pos = jnp.arange(S)[None, :]
        q_pe = rope(q_pe, pos, cfg.rope_theta)
        k_pe_r = rope(k_pe[:, :, None, :], pos, cfg.rope_theta)  # (B,S,1,r)
        k_nope = linear(p["wuk"], ckv).reshape(B, S, H, nope)
        v = linear(p["wuv"], ckv).reshape(B, S, H, vdim)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_pe_r, (B, S, H, rdim))], axis=-1)
        qf = jnp.concatenate([q_nope, q_pe], axis=-1)
        qf = constrain(qf, ("batch", "seq", "heads", "head_dim"))
        # pad V to qk head_dim so flash's single V width works, then slice
        o = flash_attention(qf, k, jnp.pad(v, ((0, 0), (0, 0), (0, 0),
                                               (0, nope + rdim - vdim))),
                            causal=True,
                            banded=cfg.banded_attention)[..., :vdim]
        new_cache = cache
        if mode == "prefill" and cache is not None:
            new_cache = dict(cache,
                             ckv=_into(cache["ckv"], ckv),
                             kpe=_into(cache["kpe"], k_pe_r[:, :, 0, :]))
    elif mode == "decode":
        # absorbed (latent-space) decode: never materialize per-head K/V.
        posv = pos_of(length, S)
        q_pe = rope(q_pe, posv, cfg.rope_theta)
        k_pe_r = rope(k_pe[:, :, None, :], posv, cfg.rope_theta)[:, :, 0, :]
        ckv_c = cache_write(cache["ckv"], ckv, length)
        kpe_c = cache_write(cache["kpe"], k_pe_r, length)
        ckv_c = constrain(ckv_c, ("batch", "cache_seq", "kv_lora"))
        kpe_c = constrain(kpe_c, ("batch", "cache_seq", None))
        new_cache = dict(cache, ckv=ckv_c, kpe=kpe_c)
        from ..core.bfp import weight_of
        wuk = weight_of(p["wuk"], dtype=x.dtype).reshape(lora, H, nope)
        q_abs = jnp.einsum("bqhn,lhn->bqhl", q_nope, wuk)      # (B,S,H,lora)
        s = (jnp.einsum("bqhl,bsl->bhqs", q_abs, ckv_c,
                        preferred_element_type=jnp.float32) +
             jnp.einsum("bqhr,bsr->bhqs", q_pe, kpe_c,
                        preferred_element_type=jnp.float32))
        s = s * ((nope + rdim) ** -0.5)
        mask = len_mask(length, ckv_c.shape[1], extra=S)
        s = jnp.where(mask, s, -1e30)
        pr = jax.nn.softmax(s, axis=-1)
        lat = jnp.einsum("bhqs,bsl->bqhl", pr.astype(ckv_c.dtype), ckv_c)
        wuv = weight_of(p["wuv"], dtype=x.dtype).reshape(lora, H, vdim)
        o = jnp.einsum("bqhl,lhv->bqhv", lat, wuv)
    else:
        raise ValueError(mode)

    y = linear(p["wo"], o.reshape(B, S, H * vdim))
    return y.astype(x.dtype), new_cache


def attn_apply(p, cfg, x, *, mode, length=None, cache=None, enc_out=None,
               use_rope=True):
    if cfg.mla is not None and enc_out is None:
        if mode == "bidir":
            raise ValueError("MLA encoder not supported")
        return mla_apply(p, cfg, x, mode=mode, length=length, cache=cache)
    return gqa_apply(p, cfg, x, mode=mode, length=length, cache=cache,
                     enc_out=enc_out, use_rope=use_rope)
