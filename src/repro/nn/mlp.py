"""Feed-forward sublayers: GELU MLP and SwiGLU."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..config import ArchConfig
from ..parallel.sharding import constrain
from .layers import linear, linear_init
from .module import split


def mlp_init(key, cfg: ArchConfig, d_ff: int | None = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dtype = jnp.dtype(cfg.param_dtype)
    if cfg.mlp_type == "swiglu":
        k1, k3, k2 = split(key, 3)
        return {"w1": linear_init(k1, d, f, dtype),
                "w3": linear_init(k3, d, f, dtype),
                "w2": linear_init(k2, f, d, dtype)}
    k1, k2 = split(key, 2)
    return {"w1": linear_init(k1, d, f, dtype, bias=cfg.qkv_bias),
            "w2": linear_init(k2, f, d, dtype, bias=cfg.qkv_bias)}


def mlp_apply(p, cfg: ArchConfig, x):
    if cfg.mlp_type == "swiglu":
        h = jax.nn.silu(linear(p["w1"], x)) * linear(p["w3"], x)
    else:
        h = jax.nn.gelu(linear(p["w1"], x))
    h = constrain(h, ("batch", "seq", "mlp"))
    return linear(p["w2"], h).astype(x.dtype)
