"""Mamba-2 SSD (state-space duality) mixer, chunked for TPU.

The chunked algorithm is the stream-buffer idea in sequence space: a chunk of
``ssm.chunk`` tokens is the VMEM-resident working set; intra-chunk terms use
quadratic (attention-like) matmuls that feed the MXU, inter-chunk terms pass a
(H, N, P) state through an associative scan (log-depth across chunks).

Deviations from the reference CUDA implementation (documented in DESIGN.md):
  * z/x/B/C/dt are separate projections (a fused in_proj would be split with
    slices that cross TP shard boundaries and force an all-gather);
  * the depthwise causal conv is applied per-stream (x, B, C) — identical
    math, and the x-conv (width d_inner) is the Winograd kernel target.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..config import ArchConfig
from ..parallel.sharding import constrain
from .layers import linear, linear_init, rmsnorm
from .module import split


# --------------------------------------------------------------------------
# depthwise causal conv1d (k taps, pure jnp baseline; Pallas Winograd kernel
# in repro.kernels.conv is the drop-in optimized version)
# --------------------------------------------------------------------------
def causal_conv1d(w, b, x, use_winograd: bool = False):
    """x (B, L, ch); w (k, ch); left-padded causal depthwise conv.

    use_winograd routes through the pure-jnp F(3,4) Winograd path — the
    GSPMD-partitionable twin of the Pallas kernel in kernels/conv (which
    is used directly on single TPU cores / under shard_map)."""
    if use_winograd:
        from ..core.winograd import conv1d_depthwise_causal as wg_conv
        return wg_conv(x, w, b)
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    y = sum(xp[:, i:i + x.shape[1], :] * w[i].astype(x.dtype) for i in range(k))
    return y + b.astype(x.dtype)


def conv_decode_step(w, b, conv_state, xnew):
    """conv_state (B, k-1, ch); xnew (B, 1, ch) -> (y (B,1,ch), new_state)."""
    k = w.shape[0]
    win = jnp.concatenate([conv_state, xnew], axis=1)        # (B, k, ch)
    y = jnp.einsum("bkc,kc->bc", win, w.astype(xnew.dtype))[:, None, :]
    y = y + b.astype(xnew.dtype)
    return y, win[:, 1:, :]


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------
def mamba_init(key, cfg: ArchConfig):
    s = cfg.ssm
    d, di = cfg.d_model, cfg.d_inner
    H, G, N, k = cfg.ssm_heads, s.ngroups, s.d_state, s.conv_kernel
    dtype = jnp.dtype(cfg.param_dtype)
    kz, kx, kb, kc, kdt, kcx, kcb, kcc, ko = split(key, 9)
    # A in [1, 16): standard mamba2 init; dt bias st softplus(dt_bias)~[1e-3,1e-1]
    a = np.linspace(1.0, 16.0, H)
    dt0 = np.exp(np.linspace(np.log(1e-3), np.log(1e-1), H))
    return {
        "wz": linear_init(kz, d, di, dtype),
        "wx": linear_init(kx, d, di, dtype),
        "wb": linear_init(kb, d, G * N, dtype),
        "wc": linear_init(kc, d, G * N, dtype),
        "wdt": linear_init(kdt, d, H, dtype),
        "conv_x": {"w": jax.random.normal(kcx, (k, di), dtype) * 0.1,
                   "b": jnp.zeros((di,), dtype)},
        "conv_b": {"w": jax.random.normal(kcb, (k, G * N), dtype) * 0.1,
                   "b": jnp.zeros((G * N,), dtype)},
        "conv_c": {"w": jax.random.normal(kcc, (k, G * N), dtype) * 0.1,
                   "b": jnp.zeros((G * N,), dtype)},
        "A_log": jnp.asarray(np.log(a), dtype),
        "D": jnp.ones((H,), dtype),
        "dt_bias": jnp.asarray(np.log(np.expm1(dt0)), dtype),
        "norm": {"scale": jnp.ones((di,), dtype)},
        "out_proj": linear_init(ko, di, d, dtype),
    }


def ssm_cache_shape(cfg: ArchConfig, batch: int):
    s = cfg.ssm
    dt = jnp.dtype(cfg.dtype)
    G, N = s.ngroups, s.d_state
    return {
        "conv_x": jax.ShapeDtypeStruct((batch, s.conv_kernel - 1, cfg.d_inner), dt),
        "conv_b": jax.ShapeDtypeStruct((batch, s.conv_kernel - 1, G * N), dt),
        "conv_c": jax.ShapeDtypeStruct((batch, s.conv_kernel - 1, G * N), dt),
        "state": jax.ShapeDtypeStruct(
            (batch, cfg.ssm_heads, N, s.head_dim), jnp.float32),
    }


# --------------------------------------------------------------------------
# chunked SSD core (pure jnp; repro.kernels.ssd provides the Pallas version)
# --------------------------------------------------------------------------
def ssd_chunked(x, dt, A, B_, C_, chunk: int, initial_state=None):
    """x (B,L,H,P); dt (B,L,H) post-softplus; A (H,) negative;
    B_, C_ (B,L,G,N).  Returns (y (B,L,H,P), final_state (B,H,N,P))."""
    Bb, L, H, P = x.shape
    G, N = B_.shape[2], B_.shape[3]
    Hg = H // G
    Q = min(chunk, L)
    pad = (-L) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = x.shape[1] // Q

    xg = x.reshape(Bb, nc, Q, G, Hg, P)
    dtg = dt.reshape(Bb, nc, Q, G, Hg)
    Bg = B_.reshape(Bb, nc, Q, G, N)
    Cg = C_.reshape(Bb, nc, Q, G, N)
    dtA = (dtg * A.reshape(G, Hg)).astype(jnp.float32)          # (B,nc,Q,G,Hg) <=0
    cums = jnp.cumsum(dtA, axis=2)                              # inclusive

    # intra-chunk (quadratic, MXU-friendly)
    CB = jnp.einsum("bcqgn,bckgn->bcgqk", Cg, Bg,
                    preferred_element_type=jnp.float32)          # (B,nc,G,Q,Q)
    # (B,nc,G,Hg,Q,K) causal decay matrix
    t = cums.transpose(0, 1, 3, 4, 2)                            # (B,nc,G,Hg,Q)
    Ld = jnp.exp(jnp.clip(t[..., :, None] - t[..., None, :], -60.0, 0.0))
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    Ld = jnp.where(causal, Ld, 0.0)
    dtx = (dtg[..., None] * xg).astype(x.dtype)                  # (B,nc,Q,G,Hg,P)
    M = CB[:, :, :, None, :, :] * Ld                             # (B,nc,G,Hg,Q,K)
    y1 = jnp.einsum("bcghqk,bckghp->bcqghp", M.astype(x.dtype), dtx,
                    preferred_element_type=jnp.float32)

    # chunk states
    dte = jnp.exp(jnp.clip(cums[:, :, -1:, :, :] - cums, -60.0, 0.0))
    states = jnp.einsum("bckgn,bckgh,bckghp->bcghnp",
                        Bg.astype(jnp.float32), (dte * dtg).astype(jnp.float32),
                        xg.astype(jnp.float32))                  # (B,nc,G,Hg,N,P)

    # inter-chunk associative scan
    lam = jnp.exp(jnp.clip(cums[:, :, -1, :, :], -60.0, 0.0))    # (B,nc,G,Hg)

    def op(a, b):
        (la, sa), (lb, sb) = a, b
        return la * lb, sa * lb[..., None, None] + sb

    lam_in, st_in = lam, states
    if initial_state is not None:
        st0 = initial_state.reshape(Bb, 1, G, Hg, N, P).astype(jnp.float32)
        lam_in = jnp.concatenate([jnp.ones_like(lam[:, :1]), lam], axis=1)
        st_in = jnp.concatenate([st0, states], axis=1)
    _, pref = jax.lax.associative_scan(op, (lam_in, st_in), axis=1)
    if initial_state is not None:
        final_state, h_prev = pref[:, -1], pref[:, :-1]
    else:
        final_state = pref[:, -1]
        h_prev = jnp.concatenate(
            [jnp.zeros_like(pref[:, :1]), pref[:, :-1]], axis=1)

    y2 = jnp.einsum("bcqgn,bcghnp,bcqgh->bcqghp",
                    Cg.astype(jnp.float32), h_prev,
                    jnp.exp(jnp.clip(cums, -60.0, 0.0)))

    y = (y1 + y2).reshape(Bb, nc * Q, H, P)[:, :L]
    return y.astype(x.dtype), final_state.reshape(Bb, H, N, P)


def ssd_decode_step(x, dt, A, B_, C_, state):
    """One-token recurrence. x (B,1,H,P); dt (B,1,H); B_,C_ (B,1,G,N);
    state (B,H,N,P) f32."""
    Bb, _, H, P = x.shape
    G, N = B_.shape[2], B_.shape[3]
    Hg = H // G
    dA = jnp.exp((dt[:, 0] * A).astype(jnp.float32))             # (B,H)
    dtx = (dt[..., None] * x)[:, 0].astype(jnp.float32)          # (B,H,P)
    Bgr = B_[:, 0].astype(jnp.float32)                           # (B,G,N)
    Bh = jnp.repeat(Bgr, Hg, axis=1) if G > 1 else jnp.broadcast_to(
        Bgr, (Bb, H, N)) if G == 1 else Bgr
    new_state = state * dA[..., None, None] + \
        jnp.einsum("bhn,bhp->bhnp", Bh, dtx)
    Cgr = C_[:, 0].astype(jnp.float32)
    Ch = jnp.repeat(Cgr, Hg, axis=1) if G > 1 else jnp.broadcast_to(
        Cgr, (Bb, H, N))
    y = jnp.einsum("bhn,bhnp->bhp", Ch, new_state)
    return y[:, None].astype(x.dtype), new_state


# --------------------------------------------------------------------------
# full mixer
# --------------------------------------------------------------------------
def mamba_apply(p, cfg: ArchConfig, x, *, mode: str, cache=None,
                use_winograd: bool = True):
    s = cfg.ssm
    Bb, S, _ = x.shape
    H, P, G, N = cfg.ssm_heads, s.head_dim, s.ngroups, s.d_state

    z = linear(p["wz"], x)
    xs = linear(p["wx"], x)
    bs = linear(p["wb"], x)
    cs = linear(p["wc"], x)
    dt = linear(p["wdt"], x)
    xs = constrain(xs, ("batch", "seq", "ssm_inner"))

    new_cache = cache
    if mode == "decode":
        xs, conv_x = conv_decode_step(p["conv_x"]["w"], p["conv_x"]["b"],
                                      cache["conv_x"], xs)
        bs, conv_b = conv_decode_step(p["conv_b"]["w"], p["conv_b"]["b"],
                                      cache["conv_b"], bs)
        cs, conv_c = conv_decode_step(p["conv_c"]["w"], p["conv_c"]["b"],
                                      cache["conv_c"], cs)
    else:
        raw_x, raw_b, raw_c = xs, bs, cs
        xs = causal_conv1d(p["conv_x"]["w"], p["conv_x"]["b"], xs,
                           use_winograd=use_winograd and mode != "decode")
        bs = causal_conv1d(p["conv_b"]["w"], p["conv_b"]["b"], bs)
        cs = causal_conv1d(p["conv_c"]["w"], p["conv_c"]["b"], cs)
    xs, bs, cs = jax.nn.silu(xs), jax.nn.silu(bs), jax.nn.silu(cs)

    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))
    xh = xs.reshape(Bb, S, H, P)
    bg = bs.reshape(Bb, S, G, N)
    cg = cs.reshape(Bb, S, G, N)

    if mode == "decode":
        y, state = ssd_decode_step(xh, dt, A, bg, cg, cache["state"])
        new_cache = dict(cache, conv_x=conv_x, conv_b=conv_b, conv_c=conv_c,
                         state=state)
    else:
        y, state = ssd_chunked(xh, dt, A, bg, cg, s.chunk)
        if mode == "prefill" and cache is not None:
            k = s.conv_kernel
            new_cache = dict(
                cache,
                conv_x=raw_x[:, S - (k - 1):, :].astype(cache["conv_x"].dtype),
                conv_b=raw_b[:, S - (k - 1):, :].astype(cache["conv_b"].dtype),
                conv_c=raw_c[:, S - (k - 1):, :].astype(cache["conv_c"].dtype),
                state=state)

    y = y + p["D"].astype(y.dtype)[None, None, :, None] * xh
    y = y.reshape(Bb, S, cfg.d_inner)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z))
    return linear(p["out_proj"], y).astype(x.dtype), new_cache
