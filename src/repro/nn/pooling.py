"""Post-conv layer epilogues: cross-channel LRN + spatial max-pool.

The paper's DLA runs *every* AlexNet stage on-chip — conv, ReLU, norm, pool
(§2.2, §3.5) — so feature maps never round-trip external memory between
layers.  These are the shared reference implementations of the two non-conv
stages; the layer-level :class:`~repro.nn.conv.ConvSpec` fuses both into the
conv call (in-kernel on the Pallas route, in-function on the jnp/direct
routes), and this module is the single numerical definition all three routes
and the tests compare against.

This module is import-bottom (jax only) so the kernel/core layers below
``nn.conv`` can use it without an import cycle.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class LrnParams:
    """Krizhevsky cross-channel local response normalization constants.

    y[c] = x[c] / (k + alpha/n * sum_{|d| <= n//2} x[c+d]^2)^beta
    """
    n: int = 5
    k: float = 2.0
    alpha: float = 1e-4
    beta: float = 0.75

    def __post_init__(self):
        assert self.n >= 1 and self.n % 2 == 1, self.n


def lrn(x, p: LrnParams = LrnParams()):
    """Cross-channel LRN on NHWC via one ``reduce_window`` squared-sum.

    The window runs over the channel axis only; SAME padding contributes
    zeros at the channel boundaries, exactly like the explicit zero-pad of
    the textbook formulation.
    """
    win = jax.lax.reduce_window(jnp.square(x), 0.0, jax.lax.add,
                                (1, 1, 1, p.n), (1, 1, 1, 1), "SAME")
    return x / jnp.power(p.k + p.alpha / p.n * win, p.beta)


def maxpool2d(x, window: int = 3, stride: int = 2):
    """VALID spatial max-pool on NHWC (AlexNet: overlapping 3x3/stride-2)."""
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, window, window, 1),
                                 (1, stride, stride, 1), "VALID")


def pooled_hw(h: int, window: int = 3, stride: int = 2) -> int:
    """Output extent of a VALID ``window``/``stride`` pool over ``h``."""
    return (h - window) // stride + 1


def apply_epilogue(y, lrn_params=None, pool=None):
    """Post-conv layer epilogue: LRN (LrnParams or None) then max-pool
    ((window, stride) or None) — the unfused reference the fused routes
    must match, shared by all conv routes, benchmarks, and tests."""
    if lrn_params is not None:
        y = lrn(y, lrn_params)
    if pool is not None:
        y = maxpool2d(y, *pool)
    return y
