from . import (attention, blocks, conv, flash, layers, mlp, module, moe,  # noqa: F401
               pooling, ssd)
