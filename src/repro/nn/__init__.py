from . import attention, blocks, flash, layers, mlp, module, moe, ssd  # noqa: F401
