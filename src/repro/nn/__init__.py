from . import attention, blocks, conv, flash, layers, mlp, module, moe, ssd  # noqa: F401
