"""Residual blocks and the scan-over-layers stack.

The stack is the TPU analogue of the DLA's time-multiplexed PE array: one
compiled block body (one *pattern period* for hybrids) is reused for every
layer group via ``lax.scan`` over stacked parameters, keeping the HLO O(1) in
depth.  Hybrid (jamba) patterns scan over 8-layer super-blocks; MoE/dense
interleave and dense-prefix layers (deepseek) are unrolled prefix blocks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..config import ArchConfig
from .attention import attn_apply, attn_cache_shape, attn_init
from .layers import norm, norm_init
from .mlp import mlp_apply, mlp_init
from .moe import moe_apply, moe_init
from .module import split
from .ssd import mamba_apply, mamba_init, ssm_cache_shape


# --------------------------------------------------------------------------
# single block
# --------------------------------------------------------------------------
def block_init(key, cfg: ArchConfig, mixer: str, ffn: str):
    ks = split(key, 4)
    dtype = cfg.param_dtype
    p = {"norm1": norm_init(cfg.norm_type, cfg.d_model, jnp.dtype(dtype))}
    if mixer == "attn":
        p["attn"] = attn_init(ks[0], cfg)
    else:
        p["ssm"] = mamba_init(ks[0], cfg)
    if cfg.cross_attention:
        p["normx"] = norm_init(cfg.norm_type, cfg.d_model, jnp.dtype(dtype))
        p["xattn"] = attn_init(ks[2], cfg, cross=True)
    if ffn != "none":
        p["norm2"] = norm_init(cfg.norm_type, cfg.d_model, jnp.dtype(dtype))
        p["mlp" if ffn == "mlp" else "moe"] = (
            mlp_init(ks[1], cfg) if ffn == "mlp" else moe_init(ks[1], cfg))
    return p


def block_cache_shape(cfg: ArchConfig, mixer: str, batch: int, max_len: int,
                      cross_len: int = 0):
    c = {}
    if mixer == "attn":
        c["attn"] = attn_cache_shape(cfg, batch, max_len)
    else:
        c["ssm"] = ssm_cache_shape(cfg, batch)
    if cfg.cross_attention and cross_len:
        kv, hd = cfg.num_kv_heads, cfg.d_head
        dt = jnp.dtype(cfg.dtype)
        c["xattn"] = {
            "ck": jax.ShapeDtypeStruct((batch, cross_len, kv, hd), dt),
            "cv": jax.ShapeDtypeStruct((batch, cross_len, kv, hd), dt),
        }
    return c


def block_apply(p, cfg: ArchConfig, x, *, mixer: str, ffn: str, mode: str,
                length=None, cache=None, enc_out=None, collect_aux=False):
    from ..parallel.sharding import constrain
    new_cache = dict(cache) if cache is not None else None
    h = norm(cfg.norm_type, p["norm1"], x)
    if mixer == "attn":
        h, c = attn_apply(p["attn"], cfg, h, mode=mode, length=length,
                          cache=None if cache is None else cache.get("attn"))
        if new_cache is not None and c is not None:
            new_cache["attn"] = c
    else:
        h, c = mamba_apply(p["ssm"], cfg, h,
                           mode="train" if mode == "bidir" else mode,
                           cache=None if cache is None else cache.get("ssm"))
        if new_cache is not None and c is not None:
            new_cache["ssm"] = c
    # Megatron-SP: the sublayer output joins a seq-sharded residual, so the
    # TP partial-sum reduction lowers to reduce-scatter (half the wire bytes
    # of all-reduce) instead of AR + local slice.
    h = constrain(h, ("batch", "seq_res", "embed"))
    x = x + h

    if cfg.cross_attention and "xattn" in p and (enc_out is not None or
                                                 (cache or {}).get("xattn")):
        h = norm(cfg.norm_type, p["normx"], x)
        h, c = attn_apply(p["xattn"], cfg, h,
                          mode="decode" if mode == "decode" else "prefill",
                          length=length, enc_out=enc_out,
                          cache=None if cache is None else cache.get("xattn"))
        if new_cache is not None and c is not None:
            new_cache["xattn"] = c
        x = x + h

    aux = jnp.zeros((), jnp.float32)
    if ffn != "none":
        h = norm(cfg.norm_type, p["norm2"], x)
        if ffn == "mlp":
            h = mlp_apply(p["mlp"], cfg, h)
        else:
            h, a = moe_apply(p["moe"], cfg, h, return_aux=collect_aux)
            if collect_aux and a is not None:
                aux = a
        h = constrain(h, ("batch", "seq_res", "embed"))
        x = x + h
    return x, new_cache, aux


# --------------------------------------------------------------------------
# stack
# --------------------------------------------------------------------------
def stack_pattern(cfg: ArchConfig):
    """(prefix_kinds, period_kinds, n_groups) — and verify periodicity."""
    prefix_n = cfg.moe.first_k_dense if cfg.moe else 0
    period = cfg.pattern_period()
    body_layers = cfg.num_layers - prefix_n
    assert body_layers % period == 0, (cfg.num_layers, prefix_n, period)
    n_groups = body_layers // period
    prefix = [cfg.layer_kind(i) for i in range(prefix_n)]
    kinds = [cfg.layer_kind(prefix_n + j) for j in range(period)]
    for m in range(n_groups):
        for j in range(period):
            assert cfg.layer_kind(prefix_n + m * period + j) == kinds[j], \
                "layer pattern is not periodic"
    return prefix, kinds, n_groups


def stack_init(key, cfg: ArchConfig):
    prefix, kinds, n_groups = stack_pattern(cfg)
    kp, ks = split(key, 2)
    params = {"prefix": []}
    for i, (mixer, ffn) in enumerate(prefix):
        kp, ki = jax.random.split(kp)
        params["prefix"].append(block_init(ki, cfg, mixer, ffn))

    def group_init(gkey):
        gkeys = split(gkey, len(kinds))
        return {f"b{j}": block_init(gkeys[j], cfg, *kinds[j])
                for j in range(len(kinds))}

    keys = jax.random.split(ks, n_groups)
    params["scan"] = jax.vmap(group_init)(keys)
    return params


def stack_cache_shape(cfg: ArchConfig, batch: int, max_len: int,
                      cross_len: int = 0):
    prefix, kinds, n_groups = stack_pattern(cfg)
    cache = {"prefix": [block_cache_shape(cfg, mixer, batch, max_len, cross_len)
                        for (mixer, _) in prefix]}
    group = {f"b{j}": block_cache_shape(cfg, kinds[j][0], batch, max_len,
                                        cross_len)
             for j in range(len(kinds))}
    cache["scan"] = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct((n_groups,) + s.shape, s.dtype), group)
    return cache


def stack_apply(params, cfg: ArchConfig, x, *, mode: str, length=None,
                caches=None, enc_out=None, collect_aux=False):
    prefix, kinds, n_groups = stack_pattern(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    new_prefix_caches = []
    for i, bp in enumerate(params["prefix"]):
        c = None if caches is None else caches["prefix"][i]
        x, c, aux = block_apply(bp, cfg, x, mixer=prefix[i][0], ffn=prefix[i][1],
                                mode=mode, length=length, cache=c,
                                enc_out=enc_out, collect_aux=collect_aux)
        new_prefix_caches.append(c)
        aux_total = aux_total + aux

    def group_body(carry, xs):
        x, aux_acc = carry
        gp, gc = xs
        # Megatron-style sequence parallelism for the layer-boundary residual:
        # the scan carry (and remat-saved activation) is sharded along seq
        # over the TP axis; GSPMD inserts the all-gather/reduce-scatter pair
        # around the TP regions.  Dropped automatically when indivisible
        # (e.g. decode S=1) or when rules map "seq_res" to None.
        from ..parallel.sharding import constrain
        x = constrain(x, ("batch", "seq_res", "embed"))
        new_gc = {} if gc is not None else None

        def one_block(j_mixer_ffn, bp, x, c):
            mixer, ffn = j_mixer_ffn
            return block_apply(bp, cfg, x, mixer=mixer, ffn=ffn,
                               mode=mode, length=length, cache=c,
                               enc_out=enc_out, collect_aux=collect_aux)

        for j, (mixer, ffn) in enumerate(kinds):
            c = None if gc is None else gc[f"b{j}"]
            blk = one_block
            if cfg.remat and len(kinds) > 1:
                # nested per-block remat: backward materializes one layer's
                # transients at a time instead of the whole period group
                # (jamba: 8 layers/group -> ~8x lower peak)
                blk = jax.checkpoint(one_block, static_argnums=(0,))
            x, c, aux = blk((mixer, ffn), gp[f"b{j}"], x, c)
            aux_acc = aux_acc + aux
            if new_gc is not None:
                new_gc[f"b{j}"] = c
        return (x, aux_acc), new_gc

    if cfg.remat:
        policy = None
        if cfg.remat_policy == "save_attn":
            # keep the (seq-sharded) attention outputs: the backward pass
            # skips the flash-forward recompute entirely
            policy = jax.checkpoint_policies.save_only_these_names("attn_out")
        body = jax.checkpoint(group_body, policy=policy)
    else:
        body = group_body
    scan_caches = None if caches is None else caches["scan"]
    if scan_caches is None:
        (x, aux_total), _ = jax.lax.scan(
            lambda carry, gp: body(carry, (gp, None)),
            (x, aux_total), params["scan"])
        new_caches = None
    else:
        (x, aux_total), new_scan = jax.lax.scan(
            body, (x, aux_total), (params["scan"], scan_caches))
        new_caches = {"prefix": new_prefix_caches, "scan": new_scan}
    return x, new_caches, aux_total
