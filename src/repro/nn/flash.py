"""Blockwise (flash-style) attention in pure JAX, with a flash backward pass.

This is the paper's stream-buffer idea applied to attention on TPU: the
working set is a (q_chunk x k_chunk) tile resident in VMEM, with online
softmax so the (S x S) score matrix is never materialized in HBM — in either
direction.  The custom VJP recomputes probability tiles blockwise in the
backward pass (saving only (q, k, v, o, lse)); without it, differentiating a
scanned forward stacks per-chunk probability residuals and peak memory
reverts to the full O(S^2) score matrix (measured: ~4 GiB/device on the
smollm train_4k cell).

GQA is handled by broadcasting KV heads to Q heads *inside* the k-chunk loop;
dk/dv fold the group dimension back down, so KV-head tensors never
materialize at Q-head width.

Layouts: q (B, Sq, H, D); k, v (B, Skv, KV, D) with H % KV == 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _repeat_kv(k, n_rep: int):
    if n_rep == 1:
        return k
    b, s, kv, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, n_rep, d)).reshape(
        b, s, kv * n_rep, d)


def _fold_kv(dk, n_rep: int):
    """(B, s, H, D) grads -> (B, s, KV, D) by summing the repeat group."""
    if n_rep == 1:
        return dk
    b, s, h, d = dk.shape
    return dk.reshape(b, s, h // n_rep, n_rep, d).sum(axis=3)


def _pad_to(x, n, axis):
    pad = n - x.shape[axis]
    if pad <= 0:
        return x
    cfgs = [(0, 0)] * x.ndim
    cfgs[axis] = (0, pad)
    return jnp.pad(x, cfgs)


def _mask(q_pos, k_pos, causal, kv_valid):
    m = k_pos[None, :] < kv_valid
    if causal:
        m = m & (q_pos[:, None] >= k_pos[None, :])
    return m[None, None]            # (1, 1, qc, kc)


def _fwd(q, k, v, causal, q_offset, q_chunk, k_chunk, kv_valid):
    """Returns (o (B,Sq,H,D) f32, lse (B,Sq,H) f32).  Shapes pre-padded."""
    B, Sq, H, D = q.shape
    _, Skv, KV, _ = k.shape
    n_rep = H // KV
    scale = D ** -0.5
    nq, nk = Sq // q_chunk, Skv // k_chunk
    qr = (q * scale).reshape(B, nq, q_chunk, H, D)
    kr = k.reshape(B, nk, k_chunk, KV, D)
    vr = v.reshape(B, nk, k_chunk, KV, D)

    def q_block(qi, qb):
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def k_step(carry, xs):
            o, m, l = carry
            kb, vb, ki = xs
            kb = _repeat_kv(kb, n_rep)
            vb = _repeat_kv(vb, n_rep)
            s = jnp.einsum("bqhd,bkhd->bhqk", qb, kb,
                           preferred_element_type=jnp.float32)
            k_pos = ki * k_chunk + jnp.arange(k_chunk)
            s = jnp.where(_mask(q_pos, k_pos, causal, kv_valid), s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1).transpose(0, 2, 1))
            # probability tiles in v.dtype (bf16): halves tile traffic; the
            # row-sum and PV products still accumulate in f32
            p = jnp.exp(s - m_new.transpose(0, 2, 1)[:, :, :, None]
                        ).astype(vb.dtype)
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1,
                                       dtype=jnp.float32).transpose(0, 2, 1)
            pv = jnp.einsum("bhqk,bkhd->bqhd", p, vb,
                            preferred_element_type=jnp.float32)
            o_new = o * corr[..., None] + pv
            return (o_new, m_new, l_new), None

        o0 = jnp.zeros((B, q_chunk, H, D), jnp.float32)
        m0 = jnp.full((B, q_chunk, H), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, q_chunk, H), jnp.float32)
        (o, m, l), _ = jax.lax.scan(
            k_step, (o0, m0, l0),
            (kr.transpose(1, 0, 2, 3, 4), vr.transpose(1, 0, 2, 3, 4),
             jnp.arange(nk)))
        l = jnp.maximum(l, 1e-30)
        return o / l[..., None], m + jnp.log(l)

    _, (o, lse) = jax.lax.scan(
        lambda _, xs: (None, q_block(*xs)), None,
        (jnp.arange(nq), qr.transpose(1, 0, 2, 3, 4)))
    o = o.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, D)
    lse = lse.transpose(1, 0, 2, 3).reshape(B, Sq, H)
    return o, lse


def _bwd(q, k, v, o, lse, do, causal, q_offset, q_chunk, k_chunk, kv_valid):
    B, Sq, H, D = q.shape
    _, Skv, KV, _ = k.shape
    n_rep = H // KV
    scale = D ** -0.5
    nq, nk = Sq // q_chunk, Skv // k_chunk

    delta = jnp.sum(do.astype(jnp.float32) * o, axis=-1)       # (B,Sq,H)
    qr = (q * scale).reshape(B, nq, q_chunk, H, D).transpose(1, 0, 2, 3, 4)
    dor = do.astype(jnp.float32).reshape(
        B, nq, q_chunk, H, D).transpose(1, 0, 2, 3, 4)
    lser = lse.reshape(B, nq, q_chunk, H).transpose(1, 0, 2, 3)
    der = delta.reshape(B, nq, q_chunk, H).transpose(1, 0, 2, 3)
    kr = k.reshape(B, nk, k_chunk, KV, D).transpose(1, 0, 2, 3, 4)
    vr = v.reshape(B, nk, k_chunk, KV, D).transpose(1, 0, 2, 3, 4)

    def k_block(_, xs):
        kb, vb, ki = xs
        kbr = _repeat_kv(kb, n_rep)                            # (B,kc,H,D)
        vbr = _repeat_kv(vb, n_rep)
        k_pos = ki * k_chunk + jnp.arange(k_chunk)

        def q_step(carry, qs):
            dk_acc, dv_acc = carry
            qb, dob, lseb, deb, qi = qs
            q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)
            s = jnp.einsum("bqhd,bkhd->bhqk", qb, kbr,
                           preferred_element_type=jnp.float32)
            s = jnp.where(_mask(q_pos, k_pos, causal, kv_valid), s, NEG_INF)
            # bf16 probability/ds tiles (f32 accumulation in the einsums)
            p = jnp.exp(s - lseb.transpose(0, 2, 1)[..., None]
                        ).astype(vbr.dtype)                      # (B,H,qc,kc)
            dv_acc = dv_acc + jnp.einsum("bhqk,bqhd->bkhd", p, dob,
                                         preferred_element_type=jnp.float32)
            dp = jnp.einsum("bqhd,bkhd->bhqk", dob, vbr,
                            preferred_element_type=jnp.float32)
            ds = (p.astype(jnp.float32)
                  * (dp - deb.transpose(0, 2, 1)[..., None])).astype(vbr.dtype)
            # qb is pre-scaled by D^-0.5, which is exactly dk's scale factor
            dk_acc = dk_acc + jnp.einsum("bhqk,bqhd->bkhd", ds, qb,
                                         preferred_element_type=jnp.float32)
            dq_part = jnp.einsum("bhqk,bkhd->bqhd", ds, kbr,
                                 preferred_element_type=jnp.float32) * scale
            return (dk_acc, dv_acc), dq_part

        dk0 = jnp.zeros((B, k_chunk, H, D), jnp.float32)
        dv0 = jnp.zeros((B, k_chunk, H, D), jnp.float32)
        (dk, dv), dq_parts = jax.lax.scan(
            q_step, (dk0, dv0), (qr, dor, lser, der, jnp.arange(nq)))
        return None, (_fold_kv(dk, n_rep), _fold_kv(dv, n_rep), dq_parts)

    _, (dk, dv, dq_parts) = jax.lax.scan(
        k_block, None, (kr, vr, jnp.arange(nk)))
    # dq_parts: (nk, nq, B, qc, H, D) -> sum over nk, reassemble Sq
    dq = dq_parts.sum(axis=0).transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, D)
    dk = dk.transpose(1, 0, 2, 3, 4).reshape(B, Skv, KV, D)
    dv = dv.transpose(1, 0, 2, 3, 4).reshape(B, Skv, KV, D)
    return dq, dk, dv


# ---------------------------------------------------------------------------
# banded causal variant: only lower-triangle (qi >= ki) chunk pairs are ever
# computed — ~2x fewer attention FLOPs than masking a full rectangle (the
# Winograd philosophy applied to attention: don't spend multiplies on zeros).
# Requires Sq == Skv, q_offset == 0, one chunk size.
# ---------------------------------------------------------------------------
def _band_pairs(n: int):
    import numpy as np
    qis, kis, last = [], [], []
    for qi in range(n):
        for ki in range(qi + 1):
            qis.append(qi)
            kis.append(ki)
            last.append(ki == qi)
    emit_idx = [qi * (qi + 1) // 2 + qi for qi in range(n)]
    return (jnp.asarray(qis), jnp.asarray(kis),
            jnp.asarray(last), jnp.asarray(emit_idx))


def _fwd_banded(q, k, v, c: int, kv_valid):
    B, S, H, D = q.shape
    KV = k.shape[2]
    n_rep = H // KV
    n = S // c
    scale = D ** -0.5
    qr = (q * scale).reshape(B, n, c, H, D).transpose(1, 0, 2, 3, 4)
    kr = k.reshape(B, n, c, KV, D).transpose(1, 0, 2, 3, 4)
    vr = v.reshape(B, n, c, KV, D).transpose(1, 0, 2, 3, 4)
    qis, kis, last, emit_idx = _band_pairs(n)

    def step(carry, xs):
        o, m, l = carry
        qi, ki, is_last = xs
        qb = jax.lax.dynamic_index_in_dim(qr, qi, 0, keepdims=False)
        kb = _repeat_kv(jax.lax.dynamic_index_in_dim(kr, ki, 0,
                                                     keepdims=False), n_rep)
        vb = _repeat_kv(jax.lax.dynamic_index_in_dim(vr, ki, 0,
                                                     keepdims=False), n_rep)
        s = jnp.einsum("bqhd,bkhd->bhqk", qb, kb,
                       preferred_element_type=jnp.float32)
        q_pos = qi * c + jnp.arange(c)
        k_pos = ki * c + jnp.arange(c)
        s = jnp.where(_mask(q_pos, k_pos, True, kv_valid), s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1).transpose(0, 2, 1))
        p = jnp.exp(s - m_new.transpose(0, 2, 1)[:, :, :, None]
                    ).astype(vb.dtype)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1,
                                   dtype=jnp.float32).transpose(0, 2, 1)
        pv = jnp.einsum("bhqk,bkhd->bqhd", p, vb,
                        preferred_element_type=jnp.float32)
        o_new = o * corr[..., None] + pv
        lf = jnp.maximum(l_new, 1e-30)
        emit_o = o_new / lf[..., None]
        emit_lse = m_new + jnp.log(lf)
        # reset the running stats after emitting a finished row
        o0 = jnp.zeros_like(o)
        m0 = jnp.full_like(m, NEG_INF)
        l0 = jnp.zeros_like(l)
        keep = ~is_last
        return ((jnp.where(keep, o_new, o0), jnp.where(keep, m_new, m0),
                 jnp.where(keep, l_new, l0)),
                (emit_o, emit_lse))

    o0 = jnp.zeros((B, c, H, D), jnp.float32)
    m0 = jnp.full((B, c, H), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, c, H), jnp.float32)
    _, (eo, else_) = jax.lax.scan(step, (o0, m0, l0), (qis, kis, last))
    o = jnp.take(eo, emit_idx, axis=0)           # (n, B, c, H, D)
    lse = jnp.take(else_, emit_idx, axis=0)
    o = o.transpose(1, 0, 2, 3, 4).reshape(B, S, H, D)
    lse = lse.transpose(1, 0, 2, 3).reshape(B, S, H)
    return o, lse


def _bwd_banded(q, k, v, o, lse, do, c: int, kv_valid):
    B, S, H, D = q.shape
    KV = k.shape[2]
    n_rep = H // KV
    n = S // c
    scale = D ** -0.5
    delta = jnp.sum(do.astype(jnp.float32) * o, axis=-1)
    qr = (q * scale).reshape(B, n, c, H, D).transpose(1, 0, 2, 3, 4)
    dor = do.astype(jnp.float32).reshape(B, n, c, H, D).transpose(1, 0, 2, 3, 4)
    lser = lse.reshape(B, n, c, H).transpose(1, 0, 2, 3)
    der = delta.reshape(B, n, c, H).transpose(1, 0, 2, 3)
    kr = k.reshape(B, n, c, KV, D).transpose(1, 0, 2, 3, 4)
    vr = v.reshape(B, n, c, KV, D).transpose(1, 0, 2, 3, 4)
    # iterate pairs grouped by ki (k-outer): (ki, qi >= ki)
    import numpy as np
    kis, qis, last = [], [], []
    for ki in range(n):
        for qi in range(ki, n):
            kis.append(ki)
            qis.append(qi)
            last.append(qi == n - 1)
    emit_idx = [0] * n
    p = 0
    for ki in range(n):
        p += n - ki
        emit_idx[ki] = p - 1
    kis, qis, last = (jnp.asarray(kis), jnp.asarray(qis), jnp.asarray(last))
    emit_idx = jnp.asarray(emit_idx)

    def step(carry, xs):
        dk, dv, dq_all = carry
        ki, qi, is_last = xs
        qb = jax.lax.dynamic_index_in_dim(qr, qi, 0, keepdims=False)
        dob = jax.lax.dynamic_index_in_dim(dor, qi, 0, keepdims=False)
        lseb = jax.lax.dynamic_index_in_dim(lser, qi, 0, keepdims=False)
        deb = jax.lax.dynamic_index_in_dim(der, qi, 0, keepdims=False)
        kb = _repeat_kv(jax.lax.dynamic_index_in_dim(kr, ki, 0,
                                                     keepdims=False), n_rep)
        vb = _repeat_kv(jax.lax.dynamic_index_in_dim(vr, ki, 0,
                                                     keepdims=False), n_rep)
        s = jnp.einsum("bqhd,bkhd->bhqk", qb, kb,
                       preferred_element_type=jnp.float32)
        q_pos = qi * c + jnp.arange(c)
        k_pos = ki * c + jnp.arange(c)
        s = jnp.where(_mask(q_pos, k_pos, True, kv_valid), s, NEG_INF)
        pm = jnp.exp(s - lseb.transpose(0, 2, 1)[..., None]).astype(vb.dtype)
        dv_new = dv + jnp.einsum("bhqk,bqhd->bkhd", pm, dob,
                                 preferred_element_type=jnp.float32)
        dp = jnp.einsum("bqhd,bkhd->bhqk", dob, vb,
                        preferred_element_type=jnp.float32)
        ds = (pm.astype(jnp.float32)
              * (dp - deb.transpose(0, 2, 1)[..., None])).astype(vb.dtype)
        dk_new = dk + jnp.einsum("bhqk,bqhd->bkhd", ds, qb,
                                 preferred_element_type=jnp.float32)
        dq_part = jnp.einsum("bhqk,bkhd->bqhd", ds, kb,
                             preferred_element_type=jnp.float32) * scale
        dq_all = jax.lax.dynamic_update_index_in_dim(
            dq_all, jax.lax.dynamic_index_in_dim(dq_all, qi, 0,
                                                 keepdims=False) + dq_part,
            qi, 0)
        emit_dk, emit_dv = dk_new, dv_new
        keep = ~is_last
        z = jnp.zeros_like(dk)
        return ((jnp.where(keep, dk_new, z), jnp.where(keep, dv_new, z),
                 dq_all), (emit_dk, emit_dv))

    dk0 = jnp.zeros((B, c, H, D), jnp.float32)
    dv0 = jnp.zeros((B, c, H, D), jnp.float32)
    dq0 = jnp.zeros((n, B, c, H, D), jnp.float32)
    (_, _, dq_all), (edk, edv) = jax.lax.scan(step, (dk0, dv0, dq0),
                                              (kis, qis, last))
    dq = dq_all.transpose(1, 0, 2, 3, 4).reshape(B, S, H, D)
    dk = _fold_kv(jnp.take(edk, emit_idx, axis=0)
                  .transpose(1, 0, 2, 3, 4).reshape(B, S, H, D), n_rep)
    dv = _fold_kv(jnp.take(edv, emit_idx, axis=0)
                  .transpose(1, 0, 2, 3, 4).reshape(B, S, H, D), n_rep)
    return dq, dk, dv


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash(q, k, v, causal, q_offset, q_chunk, k_chunk, kv_valid):
    o, _ = _fwd(q, k, v, causal, q_offset, q_chunk, k_chunk, kv_valid)
    return o


def _flash_fwd(q, k, v, causal, q_offset, q_chunk, k_chunk, kv_valid):
    o, lse = _fwd(q, k, v, causal, q_offset, q_chunk, k_chunk, kv_valid)
    return o, (q, k, v, o, lse)


def _flash_bwd(causal, q_offset, q_chunk, k_chunk, kv_valid, res, g):
    q, k, v, o, lse = res
    dq, dk, dv = _bwd(q, k, v, o, lse, g, causal, q_offset, q_chunk, k_chunk,
                      kv_valid)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash.defvjp(_flash_fwd, _flash_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _flash_band(q, k, v, c, kv_valid):
    o, _ = _fwd_banded(q, k, v, c, kv_valid)
    return o


def _flash_band_fwd(q, k, v, c, kv_valid):
    o, lse = _fwd_banded(q, k, v, c, kv_valid)
    return o, (q, k, v, o, lse)


def _flash_band_bwd(c, kv_valid, res, g):
    q, k, v, o, lse = res
    dq, dk, dv = _bwd_banded(q, k, v, o, lse, g, c, kv_valid)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash_band.defvjp(_flash_band_fwd, _flash_band_bwd)


def flash_attention(q, k, v, *, causal: bool, q_offset: int = 0,
                    q_chunk: int = 512, k_chunk: int = 1024,
                    kv_valid_len=None, banded: bool = False):
    """Online-softmax blockwise attention with flash backward.

    q_offset: absolute position of q[0] relative to k[0].  kv_valid_len:
    mask kv positions >= this (ragged cache).  banded=True computes only
    lower-triangle chunk pairs for causal self-attention (~2x fewer FLOPs).
    Returns (B, Sq, H, D) in q.dtype."""
    B, Sq, H, D = q.shape
    _, Skv, KV, _ = k.shape
    if banded and causal and q_offset == 0 and Sq == Skv:
        c = min(q_chunk, Sq)
        n = -(-Sq // c)
        kv_valid = Skv if kv_valid_len is None else kv_valid_len
        qp = _pad_to(q, n * c, 1)
        kp = _pad_to(k, n * c, 1)
        vp = _pad_to(v, n * c, 1)
        o = _flash_band(qp, kp, vp, c, kv_valid)
        return o[:, :Sq].astype(q.dtype)
    q_chunk = min(q_chunk, Sq)
    k_chunk = min(k_chunk, Skv)
    nq = -(-Sq // q_chunk)
    nk = -(-Skv // k_chunk)
    kv_valid = Skv if kv_valid_len is None else kv_valid_len
    qp = _pad_to(q, nq * q_chunk, 1)
    kp = _pad_to(k, nk * k_chunk, 1)
    vp = _pad_to(v, nk * k_chunk, 1)
    o = _flash(qp, kp, vp, causal, q_offset, q_chunk, k_chunk, kv_valid)
    return o[:, :Sq].astype(q.dtype)


def decode_attention(q, k_cache, v_cache, length):
    """Single-step decode: q (B,1,H,D) against a (possibly seq-sharded) cache
    (B,S,KV,D); positions >= length are masked.  Grouped einsum — KV heads are
    never repeated, so indivisible KV-head counts stay replicated while the
    score reduction still distributes over a sequence-sharded cache."""
    B, _, H, D = q.shape
    _, S, KV, _ = k_cache.shape
    g = H // KV
    qg = q.reshape(B, KV, g, D)
    s = jnp.einsum("bkgd,bskd->bkgs", qg * (D ** -0.5), k_cache,
                   preferred_element_type=jnp.float32)
    valid_to = length if jnp.ndim(length) == 0 else length[:, None, None, None]
    mask = jnp.arange(S)[None, None, None, :] < valid_to
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, 1, H, D).astype(q.dtype)
