"""Elementary layers: linear, norms, embeddings, rotary position encoding."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .module import param


# --- linear ----------------------------------------------------------------
def linear_init(key, d_in: int, d_out: int, dtype, bias: bool = False):
    p = {"w": param(key, (d_in, d_out), dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p, x, dtype=None):
    """Matmul in the activation dtype: f32 master params are cast to x.dtype
    (mixed precision); without the cast, bf16 @ f32 silently promotes the
    whole matmul to f32 (measured: ~2x on the memory roofline term)."""
    if "w_q" in p:
        # shared-exponent BFP weights (paper §3.6): int8 mantissas stream
        # from HBM; dequant fuses into the consumer matmul.
        from ..core.bfp import dequantize_linear
        w = dequantize_linear(p)
    else:
        w = p["w"]
    dt = jnp.dtype(dtype) if dtype is not None else x.dtype
    y = x.astype(dt) @ w.astype(dt)
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


# --- norms -----------------------------------------------------------------
def rmsnorm_init(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * p["scale"].astype(jnp.float32)).astype(dt)


def layernorm_init(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p, x, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    x = (x - mean) * jax.lax.rsqrt(var + eps)
    y = x * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(dt)


def norm_init(kind: str, d: int, dtype):
    return rmsnorm_init(d, dtype) if kind == "rmsnorm" else layernorm_init(d, dtype)


def norm(kind: str, p, x):
    return rmsnorm(p, x) if kind == "rmsnorm" else layernorm(p, x)


# --- embedding ---------------------------------------------------------------
def embed_init(key, vocab: int, d: int, dtype):
    return {"embedding": param(key, (vocab, d), dtype, scale=1.0)}


def embed(p, tokens, dtype):
    return jnp.take(p["embedding"].astype(dtype), tokens, axis=0)


def embed_attend(p, x):
    """Tied readout: logits in f32 (softmax stability)."""
    return x.astype(jnp.float32) @ p["embedding"].astype(jnp.float32).T


# --- rotary ------------------------------------------------------------------
def rope(x, positions, theta: float = 10_000.0):
    """Apply rotary embedding.

    x: (..., seq, heads, head_dim) or (..., seq, head_dim); positions
    broadcastable to (..., seq).
    """
    head_dim = x.shape[-1]
    half = head_dim // 2
    freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions[..., None].astype(jnp.float32) * freq  # (..., seq, half)
    if x.ndim == angles.ndim + 1:       # insert heads axis
        angles = angles[..., None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def softcap(logits, cap: float):
    if not cap:
        return logits
    return cap * jnp.tanh(logits / cap)
