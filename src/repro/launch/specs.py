"""Abstract input specs + step functions for every (arch x shape) cell.

``input_specs`` returns ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, zero allocation) for the inputs of the step that the cell lowers:
train -> train_step(state, batch); prefill/decode -> serve steps over caches.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..config import ArchConfig, ShapeCfg
from ..models import model_for
from ..optim import adamw_step, lr_schedule
from ..parallel import sharding as shlib

AUDIO_FRAMES = 1500      # whisper 30s encoder length (stub embeddings)


def batch_specs(cfg: ArchConfig, shape: ShapeCfg) -> dict:
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        out = {"inputs": jax.ShapeDtypeStruct((B, S), i32),
               "targets": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.family == "audio":
            out["frames"] = jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                 jnp.float32)
        if cfg.family == "vlm":
            out["patches"] = jax.ShapeDtypeStruct((B, cfg.num_patches, 1024),
                                                  jnp.float32)
        return out
    if shape.kind == "prefill":
        out = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.family == "audio":
            # encoder consumes its natural frame count (cross cache size);
            # the 32k prefill stresses the DECODER token length.
            out["frames"] = jax.ShapeDtypeStruct((B, AUDIO_FRAMES, cfg.d_model),
                                                 jnp.float32)
        if cfg.family == "vlm":
            out["patches"] = jax.ShapeDtypeStruct((B, cfg.num_patches, 1024),
                                                  jnp.float32)
        return out
    # decode: one new token against a seq_len cache
    return {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}


def cache_specs(cfg: ArchConfig, shape: ShapeCfg) -> dict:
    mod = model_for(cfg)
    kw = {}
    if cfg.family == "audio":
        kw["cross_len"] = AUDIO_FRAMES
    return mod.cache_shape(cfg, shape.global_batch, shape.seq_len, **kw)


def state_specs(cfg: ArchConfig, seed: int = 0) -> dict:
    mod = model_for(cfg)
    params = jax.eval_shape(lambda k: mod.init(k, cfg), jax.random.PRNGKey(seed))
    f32 = lambda t: jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, jnp.float32), t)
    return {"step": jax.ShapeDtypeStruct((), jnp.int32), "params": params,
            "m": f32(params), "v": f32(params)}


def serve_param_specs(cfg: ArchConfig, serve_dtype: str = "bf16",
                      seed: int = 0):
    """Abstract serving weights: f32 master copies, bf16 inference copies,
    or BFP-int8 shared-exponent streams (paper §3.6)."""
    mod = model_for(cfg)
    params = jax.eval_shape(lambda k: mod.init(k, cfg),
                            jax.random.PRNGKey(seed))
    if serve_dtype == "f32":
        return params
    if serve_dtype == "bf16":
        return jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(
                x.shape, jnp.bfloat16 if jnp.issubdtype(x.dtype, jnp.floating)
                else x.dtype), params)
    if serve_dtype == "bfp8":
        from ..core.bfp import quantize_linear_tree
        bf16 = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(
                x.shape, jnp.bfloat16 if jnp.issubdtype(x.dtype, jnp.floating)
                else x.dtype), params)
        return jax.eval_shape(quantize_linear_tree, bf16)
    raise ValueError(serve_dtype)


# ---------------------------------------------------------------------------
# shardings
# ---------------------------------------------------------------------------
def _data_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def batch_shardings(cfg, shape, mesh, specs):
    da = _data_axes(mesh)
    dspec = da if len(da) > 1 else (da[0] if da else None)

    def one(leaf):
        spec = [None] * len(leaf.shape)
        if leaf.shape and leaf.shape[0] % max(
                1, _prod(mesh.shape[a] for a in da)) == 0:
            spec[0] = dspec
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map(one, specs)


def _prod(it):
    n = 1
    for v in it:
        n *= v
    return n


_CACHE_AXES = {
    "k": ("batch", "cache_seq", "cache_kv_heads", "head_dim"),
    "v": ("batch", "cache_seq", "cache_kv_heads", "head_dim"),
    "ck": ("batch", "cache_seq", "cache_kv_heads", "head_dim"),
    "cv": ("batch", "cache_seq", "cache_kv_heads", "head_dim"),
    "ckv": ("batch", "cache_seq", "kv_lora"),
    "kpe": ("batch", "cache_seq", None),
    "conv_x": ("batch", None, "ssm_inner"),
    "conv_b": ("batch", None, None),
    "conv_c": ("batch", None, None),
    "state": ("batch", "ssm_heads", "state", None),
}


def cache_shardings(cfg, cache_spec, mesh):
    def one(path, leaf):
        name = shlib.path_str(path).split("/")[-1]
        axes = _CACHE_AXES.get(name, (None,) * leaf.ndim)
        pad = leaf.ndim - len(axes)
        axes = ("layers",) * pad + tuple(axes)
        return shlib.logical_sharding(leaf.shape, axes, mesh)
    with shlib.use_mesh_rules(mesh, None):
        return jax.tree_util.tree_map_with_path(one, cache_spec)


def state_shardings(cfg, state_spec, mesh, *, zero1: bool = True,
                    fsdp: bool = False):
    """zero1: optimizer moments additionally sharded over 'data' (ZeRO-1).
    fsdp: parameters (and thus gradients) too — ZeRO-3 style; GSPMD inserts
    the per-layer param all-gathers and grad reduce-scatters."""
    z1 = shlib.zero1_shardings(state_spec["params"], mesh)
    pshard = z1 if fsdp else shlib.param_shardings(state_spec["params"], mesh)
    moments = z1 if (zero1 or fsdp) else pshard
    return {"step": NamedSharding(mesh, P()), "params": pshard,
            "m": moments, "v": moments}


# ---------------------------------------------------------------------------
# step functions (what the dry-run lowers; train.py/serve.py use them too)
# ---------------------------------------------------------------------------
def make_train_step(cfg: ArchConfig, *, base_lr: float = 1e-4,
                    total_steps: int = 10_000):
    mod = model_for(cfg)

    def train_step(state, batch):
        lr = lr_schedule(state["step"], base_lr=base_lr, total=total_steps)
        if cfg.family == "audio":
            b = {"inputs": batch["inputs"], "targets": batch["targets"],
                 "frames": batch["frames"]}
        elif cfg.family == "vlm":
            b = {"inputs": batch["inputs"], "targets": batch["targets"],
                 "patches": batch["patches"]}
        else:
            b = {"inputs": batch["inputs"], "targets": batch["targets"]}
        (loss, metrics), grads = jax.value_and_grad(
            mod.loss_fn, has_aux=True)(state["params"], cfg, b)
        state, om = adamw_step(state, grads, lr=lr, weight_decay=0.01,
                               clip_norm=1.0)
        return state, {**metrics, **om}

    return train_step


def make_prefill_step(cfg: ArchConfig):
    mod = model_for(cfg)

    def prefill_step(params, batch, caches):
        kw = {}
        if cfg.family == "audio":
            kw["frames"] = batch["frames"]
        if cfg.family == "vlm":
            kw["patches"] = batch["patches"]
        logits, caches, _ = mod.apply(params, cfg, batch["tokens"],
                                      mode="prefill", caches=caches, **kw)
        return logits[:, -1].argmax(-1).astype(jnp.int32), caches

    return prefill_step


def make_decode_step(cfg: ArchConfig, shape: ShapeCfg):
    mod = model_for(cfg)
    length = shape.seq_len - 1      # cache holds seq_len-1 tokens; write 1

    def decode_step(params, batch, caches):
        logits, caches, _ = mod.apply(params, cfg, batch["tokens"],
                                      mode="decode",
                                      length=jnp.int32(length), caches=caches)
        return logits[:, -1].argmax(-1).astype(jnp.int32), caches

    return decode_step
