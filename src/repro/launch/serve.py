"""Serving launcher: continuous-batching engine over synthetic requests.

``python -m repro.launch.serve --arch llama3.2-3b --requests 16``
"""
from __future__ import annotations

import argparse

import numpy as np

from ..configs import ASSIGNED, get_config
from ..serving import Engine, Request, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b", choices=ASSIGNED)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    scfg = ServeConfig(max_batch=args.max_batch, max_len=args.max_len,
                       cross_len=128 if cfg.family == "audio" else 0)
    eng = Engine(cfg, scfg, seed=args.seed)

    rng = np.random.default_rng(args.seed)
    reqs = []
    for i in range(args.requests):
        plen = int(rng.integers(4, min(64, args.max_len - args.max_new)))
        req = Request(prompt=list(rng.integers(1, cfg.vocab_size,
                                               size=plen)),
                      max_new=args.max_new)
        if cfg.family == "audio":
            req.frames = rng.standard_normal(
                (128, cfg.d_model)).astype(np.float32) * 0.1
        if cfg.family == "vlm":
            req.patches = rng.standard_normal(
                (cfg.num_patches, 1024)).astype(np.float32) * 0.1
        reqs.append(req)
        eng.submit(req)

    eng.run_until_done()
    done = sum(r.done for r in reqs)
    print(f"finished {done}/{len(reqs)} requests; "
          f"{eng.tokens_generated} tokens; "
          f"decode throughput {eng.decode_tokens_per_s:.1f} tok/s "
          f"({eng.decode_steps} batched decode steps)")


if __name__ == "__main__":
    main()
