"""Serving launcher: continuous-batching engine over synthetic requests.

``python -m repro.launch.serve --arch llama3.2-3b --requests 16``   (decode)
``python -m repro.launch.serve --arch alexnet --requests 32``       (images)

LM archs go through the token-decode :class:`Engine`; ``alexnet`` (the
paper's own workload) goes through the bucketed, double-buffered
:class:`CnnEngine` and reports img/s + latency percentiles (Tables 5-6).
"""
from __future__ import annotations

import argparse
import dataclasses

import numpy as np

from ..configs import ASSIGNED, CNN_ARCHS, get_config
from ..serving import (CnnEngine, CnnServeConfig, Engine, FaultInjector,
                       FaultSpec, ImageRequest, Request, ServeConfig,
                       Supervisor, SupervisorConfig, WorkerModel,
                       derive_seed)

CNN_ROUTES = ("auto", "direct", "winograd", "pallas")


def apply_cnn_route(cfg, route: str):
    """Map a conv route name onto the CNN model config's route knobs.

    ``auto`` keeps the config's own preference; the explicit routes force
    every eligible conv through direct / pure-jnp Winograd / the Pallas
    kernel (interpret mode off-TPU), so the serving path can exercise the
    stream-buffered kernel end-to-end through :class:`CnnEngine`.
    """
    assert route in CNN_ROUTES, route
    if route == "auto" or getattr(cfg, "family", None) != "cnn":
        return cfg
    return dataclasses.replace(cfg, use_winograd=route != "direct",
                               use_pallas=route == "pallas")


def serve_supervised(cfg, args) -> int:
    """Supervised multi-process path: N worker processes behind one
    :class:`Supervisor` (heartbeats, failover re-dispatch, crash-consistent
    restart).  ``--kill-worker`` SIGKILLs worker w0 mid-run to demonstrate
    zero-loss failover; ``--chaos`` arms seeded per-worker process chaos."""
    cfg = apply_cnn_route(cfg, getattr(args, "route", "auto"))
    scfg = CnnServeConfig(max_batch=args.max_batch,
                          slo_ms=getattr(args, "slo_ms", None))
    chaos = None
    if getattr(args, "chaos", False):
        chaos = {"worker.crash": FaultSpec(rate=0.02, limit=1),
                 "worker.stall": FaultSpec(rate=0.05, delay_ms=50.0,
                                           limit=3)}
    sup = Supervisor((WorkerModel(cfg.name, cfg, scfg, seed=args.seed),),
                     SupervisorConfig(n_workers=args.workers,
                                      checkpoint_on_start=False),
                     seed=args.seed, chaos=chaos)
    rng = np.random.default_rng(args.seed)
    deadline_ms = getattr(args, "deadline_ms", None)
    reqs = [ImageRequest(image=rng.standard_normal(
                (cfg.image_size, cfg.image_size, cfg.in_channels))
                .astype(np.float32),
                deadline_ms=deadline_ms,
                retries=getattr(args, "retries", 2))
            for _ in range(args.requests)]
    # kill right after an even-indexed submit: round-robin puts those on
    # w0, so the SIGKILL demonstrably orphans an in-flight request
    kill_at = ((len(reqs) // 2) & ~1 if getattr(args, "kill_worker", False)
               else None)
    with sup:
        for i, r in enumerate(reqs):
            sup.submit(cfg.name, r)
            if kill_at is not None and i == kill_at:
                sup.kill_worker("w0", "operator:--kill-worker")
                kill_at = None
            sup.step()
        sup.run_until_done()
        acc = sup.accounting()
        lat = sup.latency.percentiles_ms()
        print(f"supervised fleet: {args.workers} workers, "
              f"completed {acc['completed']}/{acc['submitted']} "
              f"(shed={acc['shed']} expired={acc['expired']} "
              f"failed_over={acc['failed_over']}) "
              f"balanced={'yes' if acc['balanced'] else 'NO'}")
        print(f"latency p50={lat['p50']:.1f}ms p90={lat['p90']:.1f}ms "
              f"p99={lat['p99']:.1f}ms")
        if sup.failover_uids:
            par = sup.verify_bit_parity()
            print(f"failover bit-parity: {par['checked']} checked, "
                  f"{par['mismatched']} mismatched")
        deaths = [e for e in sup.events if e["event"] == "death"]
        if deaths:
            print("worker deaths: " + "; ".join(
                f"{e['worker']}({e['reason']})" for e in deaths))
    return acc["completed"]


def serve_images(cfg, args) -> int:
    """Image-classification serving path (paper §3.5/§3.7 regime)."""
    cfg = apply_cnn_route(cfg, getattr(args, "route", "auto"))
    if hasattr(cfg, "weight_prefetch"):
        prefetch = getattr(args, "prefetch", "on") == "on"
        cfg = dataclasses.replace(cfg, weight_prefetch=prefetch)
    sdc = getattr(args, "sdc", False) and hasattr(cfg, "sdc_abft")
    if sdc:
        # full SDC defense: ABFT checksums through the conv datapath,
        # pre-dispatch slab fingerprints, magnitude-bounded screen
        cfg = dataclasses.replace(cfg, sdc_abft=True)
    if hasattr(cfg, "conv_channels"):
        # per-layer resolved datapaths — `--route pallas` must show every
        # layer on a Pallas kernel, not a silent lax fallback — plus the
        # resolved §3.5 weight-stream mode (double-buffered DMA vs
        # synchronous fetches; lax/jnp routes have no in-kernel stream)
        from ..models.alexnet import layer_routes
        routes = layer_routes(cfg)
        pallas_any = any(r.startswith("pallas") for _, r in routes)
        mode = (("on(dma-double-buffer)" if cfg.weight_prefetch
                 else "off(dma-sync)") if pallas_any else "n/a(no-dma-route)")
        print("conv routes: " + " ".join(f"{n}={r}" for n, r in routes)
              + f" | weight_prefetch={mode}")
    slo_ms = getattr(args, "slo_ms", None)
    scfg = CnnServeConfig(max_batch=args.max_batch,
                          data_parallel=args.data_parallel,
                          slo_ms=slo_ms,
                          dynamic_buckets=bool(
                              slo_ms and getattr(args, "dynamic_buckets",
                                                 False)),
                          admission=bool(slo_ms and getattr(args, "admission",
                                                            False)),
                          verify_slabs=sdc,
                          screen_abs_max=1e6 if sdc else None)
    faults = None
    if getattr(args, "chaos", False):
        # light seeded schedule: transient launches + non-finite logits,
        # enough to exercise retry/screen/health without stalling the run
        specs = {"launch.transient": FaultSpec(rate=0.1),
                 "retire.nonfinite": FaultSpec(rate=0.05)}
        if sdc:
            # SDC chaos: slab bit flips + plausible (finite) logit
            # corruption, exercised against the armed defense
            specs["slab.bitflip"] = FaultSpec(rate=0.1)
            specs["retire.plausible"] = FaultSpec(rate=0.05,
                                                  magnitude=1e8)
        faults = FaultInjector(
            seed=derive_seed(args.seed, cfg.name), specs=specs)
    eng = CnnEngine(cfg, scfg, seed=args.seed, faults=faults)
    rng = np.random.default_rng(args.seed)
    deadline_ms = getattr(args, "deadline_ms", None)
    retries = getattr(args, "retries", 2)
    reqs = [ImageRequest(image=rng.standard_normal(
                (cfg.image_size, cfg.image_size, cfg.in_channels))
                .astype(np.float32),
                deadline_ms=deadline_ms, retries=retries)
            for _ in range(args.requests)]
    for r in reqs:
        if scfg.admission:
            eng.try_submit(r)
        else:
            eng.submit(r)
    eng.run_until_done()
    s = eng.stats()
    done = sum(r.done for r in reqs)
    lat = s["latency_ms"]
    print(f"completed {done}/{len(reqs)} requests; "
          f"{s['imgs_per_s']:.1f} img/s over {s['batches_run']} batches "
          f"(avg occupancy {s['avg_occupancy']:.2f}, "
          f"buckets {s['bucket_counts']})")
    print(f"latency p50={lat['p50']:.1f}ms p90={lat['p90']:.1f}ms "
          f"p99={lat['p99']:.1f}ms")
    if slo_ms:
        print(f"slo={slo_ms:.1f}ms goodput={s['goodput_imgs_per_s']:.1f} "
              f"img/s shed={s['images_shed']} ladder={s['buckets']}")
    acc = s["accounting"]
    print(f"accounting submitted={acc['submitted']} "
          f"completed={acc['completed']} shed={acc['shed']} "
          f"expired={acc['expired']} "
          f"balanced={'yes' if acc['balanced'] else 'NO'} | "
          f"health={s['health']['state']} retried={s['images_retried']}"
          + (f" faults_fired={faults.total_fired}" if faults else ""))
    if sdc:
        d = s["sdc"]
        print(f"sdc abft=on verify_slabs=on detections={d['detections']} "
              f"slab_integrity_failures={d['slab_integrity_failures']} "
              f"screen_nonfinite={d['screen_nonfinite']} "
              f"screen_magnitude={d['screen_magnitude']}")
    if faults is not None:
        # per-point opportunity/fire audit — replays can be checked
        # against this line without parsing the full stats dump
        audit = " ".join(
            f"{p}={c['fired']}/{c['opportunities']}"
            for p, c in sorted(faults.summary().items()))
        print(f"fault audit (fired/opportunities): {audit}")
    return done


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b",
                    choices=ASSIGNED + CNN_ARCHS)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--data-parallel", action="store_true",
                    help="CNN path: shard buckets over all JAX devices")
    ap.add_argument("--route", default="auto", choices=CNN_ROUTES,
                    help="CNN path: conv route (pallas = stream-buffered "
                         "kernel, interpret mode off-TPU)")
    ap.add_argument("--prefetch", default="on", choices=("on", "off"),
                    help="CNN path: Pallas weight stream — double-buffered "
                         "manual-DMA filter prefetch (on) vs the same "
                         "copies run synchronously (off; bit-equal)")
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="CNN path: p99 latency SLO enabling the serving "
                         "policy layer (goodput accounting; see also "
                         "--dynamic-buckets / --admission)")
    ap.add_argument("--dynamic-buckets", action="store_true",
                    help="CNN path: SLO-driven bucket-ladder resizing")
    ap.add_argument("--admission", action="store_true",
                    help="CNN path: SLO-driven load shedding at submit")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="CNN path: per-request deadline; overdue requests "
                         "retire as expired (reported, never dropped)")
    ap.add_argument("--retries", type=int, default=2,
                    help="CNN path: per-request transient-failure retry "
                         "budget (exponential backoff)")
    ap.add_argument("--chaos", action="store_true",
                    help="CNN path: arm a seeded FaultInjector (transient "
                         "launch failures + non-finite logits) to exercise "
                         "the retry/screen/health machinery")
    ap.add_argument("--sdc", action="store_true",
                    help="CNN path: arm the silent-data-corruption defense "
                         "(ABFT checksums on the conv weight stream, "
                         "pre-dispatch slab fingerprints, magnitude-bounded "
                         "logit screen); with --chaos also injects slab bit "
                         "flips and plausible logit corruption")
    ap.add_argument("--workers", type=int, default=0,
                    help="CNN path: >0 serves through a Supervisor owning "
                         "this many worker processes (heartbeats, failover "
                         "re-dispatch, crash-consistent restart)")
    ap.add_argument("--kill-worker", action="store_true",
                    help="CNN path (--workers): SIGKILL worker w0 mid-run "
                         "to demonstrate zero-loss failover")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()

    if cfg.family == "cnn":
        if args.workers > 0:
            serve_supervised(cfg, args)
        else:
            serve_images(cfg, args)
        return

    scfg = ServeConfig(max_batch=args.max_batch, max_len=args.max_len,
                       cross_len=128 if cfg.family == "audio" else 0)
    eng = Engine(cfg, scfg, seed=args.seed)

    rng = np.random.default_rng(args.seed)
    reqs = []
    for i in range(args.requests):
        plen = int(rng.integers(4, min(64, args.max_len - args.max_new)))
        req = Request(prompt=list(rng.integers(1, cfg.vocab_size,
                                               size=plen)),
                      max_new=args.max_new)
        if cfg.family == "audio":
            req.frames = rng.standard_normal(
                (128, cfg.d_model)).astype(np.float32) * 0.1
        if cfg.family == "vlm":
            req.patches = rng.standard_normal(
                (cfg.num_patches, 1024)).astype(np.float32) * 0.1
        reqs.append(req)
        eng.submit(req)

    eng.run_until_done()
    done = sum(r.done for r in reqs)
    print(f"finished {done}/{len(reqs)} requests; "
          f"{eng.tokens_generated} tokens; "
          f"decode throughput {eng.decode_tokens_per_s:.1f} tok/s "
          f"({eng.decode_steps} batched decode steps)")


if __name__ == "__main__":
    main()
