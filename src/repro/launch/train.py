"""Training launcher: ``python -m repro.launch.train --arch smollm-360m ...``

Runs real steps on the available devices (reduced config by default on CPU;
full config with --full on a real fleet).  The production path is identical
to the dry-run's: same step function, same shardings — only array allocation
differs.
"""
from __future__ import annotations

import argparse
import json

import jax

from ..configs import ASSIGNED, get_config
from ..parallel import sharding as shlib
from ..runtime import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m", choices=ASSIGNED)
    ap.add_argument("--full", action="store_true",
                    help="full config (needs a real fleet); default reduced")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--mesh", default="",
                    help="'DxM' data x model mesh over available devices")
    ap.add_argument("--rules", default="", help="JSON logical-rule overrides")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    mesh = None
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = jax.make_mesh((d, m), ("data", "model"))
    rules = json.loads(args.rules) if args.rules else None

    tcfg = TrainerConfig(steps=args.steps, batch=args.batch,
                         seq_len=args.seq_len, base_lr=args.lr,
                         ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                         log_every=max(args.steps // 20, 1))
    tr = Trainer(cfg, tcfg, mesh=mesh, rules=rules)
    # resume if a checkpoint exists
    if args.ckpt_dir:
        if tr.restore_latest():
            print(f"resumed from step {int(jax.device_get(tr.state['step']))}")
    hist = tr.run()
    for h in hist:
        print(f"step {h['step']:6d} loss {h['loss']:8.4f} "
              f"acc {h['accuracy']:6.3f} gnorm {h['grad_norm']:8.3f} "
              f"dt {h['dt']*1e3:7.1f}ms")
    if tr.events.stragglers:
        print(f"stragglers detected: {len(tr.events.stragglers)}")
    if tr.events.recoveries:
        print(f"failure recoveries: {tr.events.recoveries}")


if __name__ == "__main__":
    main()
