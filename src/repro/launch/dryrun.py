import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below may import jax ----------------------------------------
import argparse        # noqa: E402
import json            # noqa: E402
import time            # noqa: E402
import traceback       # noqa: E402

import jax             # noqa: E402
import jax.numpy as jnp  # noqa: E402

from ..config import SHAPES, shape_applicable          # noqa: E402
from ..configs import ASSIGNED, get_config             # noqa: E402
from ..core import roofline as rl                      # noqa: E402
from ..nn.blocks import stack_pattern                  # noqa: E402
from ..parallel import sharding as shlib               # noqa: E402
from . import specs as sp                              # noqa: E402
from .mesh import make_production_mesh                 # noqa: E402

"""Multi-pod dry run: .lower().compile() every (arch x shape x mesh) cell on
the production mesh (16x16 single-pod / 2x16x16 multi-pod forced host
devices) and record memory analysis, cost analysis, and the collective
schedule for §Dry-run / §Roofline of EXPERIMENTS.md.  No arrays are ever
allocated at model scale — inputs are ShapeDtypeStructs."""


def _layer_trips(cfg) -> int:
    _, kinds, n_groups = stack_pattern(cfg)
    return max(n_groups, 1)


def apply_cfg_overrides(cfg, overrides: dict | None):
    """dataclasses.replace on ArchConfig; 'moe.x'/'ssm.x' reach sub-configs."""
    if not overrides:
        return cfg
    import dataclasses
    top, nested = {}, {}
    for k, v in overrides.items():
        if "." in k:
            head, tail = k.split(".", 1)
            nested.setdefault(head, {})[tail] = v
        else:
            top[k] = v
    for head, kv in nested.items():
        sub = getattr(cfg, head)
        if sub is not None:
            top[head] = dataclasses.replace(sub, **kv)
    return dataclasses.replace(cfg, **top)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             rules: dict | None = None, zero1: bool = True,
             fsdp: bool = False, keep_hlo: bool = False,
             serve_dtype: str = "bf16",
             cfg_overrides: dict | None = None) -> dict:
    cfg = apply_cfg_overrides(get_config(arch), cfg_overrides)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    base = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
            "kind": shape.kind}
    if not ok:
        return dict(base, status="skipped", reason=why)

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = 1
    for v in mesh.shape.values():
        chips *= v
    try:
        with shlib.use_mesh_rules(mesh, rules):
            if shape.kind == "train":
                state_spec = sp.state_specs(cfg)
                batch_spec = sp.batch_specs(cfg, shape)
                in_sh = (sp.state_shardings(cfg, state_spec, mesh,
                                            zero1=zero1, fsdp=fsdp),
                         sp.batch_shardings(cfg, shape, mesh, batch_spec))
                out_sh = (in_sh[0], None)
                step = sp.make_train_step(cfg)
                jitted = jax.jit(step, in_shardings=in_sh,
                                 out_shardings=out_sh, donate_argnums=(0,))
                lowered = jitted.lower(state_spec, batch_spec)
            else:
                params_spec = sp.serve_param_specs(cfg, serve_dtype)
                batch_spec = sp.batch_specs(cfg, shape)
                cache_spec = sp.cache_specs(cfg, shape)
                p_sh = shlib.param_shardings(params_spec, mesh)
                b_sh = sp.batch_shardings(cfg, shape, mesh, batch_spec)
                c_sh = sp.cache_shardings(cfg, cache_spec, mesh)
                step = (sp.make_prefill_step(cfg) if shape.kind == "prefill"
                        else sp.make_decode_step(cfg, shape))
                jitted = jax.jit(step, in_shardings=(p_sh, b_sh, c_sh),
                                 out_shardings=(None, c_sh),
                                 donate_argnums=(2,))
                lowered = jitted.lower(params_spec, batch_spec, cache_spec)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        hlo = compiled.as_text()
        terms = rl.from_compiled(
            compiled, hlo, arch=arch, shape=shape_name, mesh=mesh_name,
            chips=chips,
            model_flops=rl.model_flops_estimate(cfg, shape),
            loop_trip_count=_layer_trips(cfg))
        mem = compiled.memory_analysis()
        rec = dict(base, status="ok", t_lower_s=round(t_lower, 1),
                   t_compile_s=round(t_compile, 1),
                   hlo_bytes=len(hlo), chips=chips,
                   memory={
                       "argument_size": getattr(mem, "argument_size_in_bytes", 0),
                       "output_size": getattr(mem, "output_size_in_bytes", 0),
                       "temp_size": getattr(mem, "temp_size_in_bytes", 0),
                       "alias_size": getattr(mem, "alias_size_in_bytes", 0),
                       "generated_code_size": getattr(
                           mem, "generated_code_size_in_bytes", 0),
                   },
                   roofline=terms.to_json())
        if keep_hlo:
            rec["hlo_path"] = _dump_hlo(arch, shape_name, mesh_name, hlo)
        return rec
    except Exception as e:  # a failure here is a bug in the system
        return dict(base, status="error", error=f"{type(e).__name__}: {e}",
                    traceback=traceback.format_exc()[-2000:])


def _dump_hlo(arch, shape_name, mesh_name, hlo) -> str:
    d = os.path.join("results", "hlo")
    os.makedirs(d, exist_ok=True)
    p = os.path.join(d, f"{arch}_{shape_name}_{mesh_name}.hlo.txt")
    with open(p, "w") as f:
        f.write(hlo)
    return p


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help=f"one of {ASSIGNED} or 'all'")
    ap.add_argument("--shape", default="all",
                    help=f"one of {list(SHAPES)} or 'all'")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--rules", default="",
                    help="JSON dict of logical-axis rule overrides")
    ap.add_argument("--no-zero1", action="store_true")
    ap.add_argument("--keep-hlo", action="store_true")
    ap.add_argument("--serve-dtype", default="bf16",
                    choices=["f32", "bf16", "bfp8"],
                    help="weight stream dtype for prefill/decode cells")
    args = ap.parse_args()

    archs = ASSIGNED if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    rules = json.loads(args.rules) if args.rules else None

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    n_ok = n_skip = n_err = 0
    with open(args.out, "a") as f:
        for arch in archs:
            for shape in shapes:
                for mp in meshes:
                    rec = run_cell(arch, shape, multi_pod=mp, rules=rules,
                                   keep_hlo=args.keep_hlo,
                                   serve_dtype=args.serve_dtype,
                                   zero1=not args.no_zero1)
                    f.write(json.dumps(rec) + "\n")
                    f.flush()
                    st = rec["status"]
                    n_ok += st == "ok"
                    n_skip += st == "skipped"
                    n_err += st == "error"
                    if st == "ok":
                        r = rec["roofline"]
                        print(f"[{st:7s}] {arch:22s} {shape:12s} "
                              f"{rec['mesh']:8s} "
                              f"compile={rec['t_compile_s']:6.1f}s "
                              f"bound={r['bound']:10s} "
                              f"step={r['step_time']*1e3:8.2f}ms "
                              f"mem/dev={rec['memory']['argument_size']/2**30:6.2f}+"
                              f"{rec['memory']['temp_size']/2**30:5.2f}GiB",
                              flush=True)
                    else:
                        print(f"[{st:7s}] {arch:22s} {shape:12s} "
                              f"{rec['mesh']:8s} "
                              f"{rec.get('reason') or rec.get('error','')}",
                              flush=True)
    print(f"done: ok={n_ok} skipped={n_skip} error={n_err}")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
