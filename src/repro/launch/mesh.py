"""Production mesh definitions.

Functions, not module-level constants: importing this module never touches
jax device state (required so smoke tests see 1 CPU device while the dry-run
sees 512 forced host devices).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_pipeline_mesh():
    """Multi-pod with the pod axis re-purposed as a pipeline-stage axis
    (inter-pod ICI carries only microbatch activations per tick)."""
    return jax.make_mesh((2, 16, 16), ("pipe", "data", "model"))


def make_host_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU multi-device tests (forced host devices)."""
    return jax.make_mesh(shape, axes)
