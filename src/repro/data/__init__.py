from .pipeline import synthetic_batches  # noqa: F401
