"""Deterministic synthetic data pipeline (host-sharded, learnable).

Sequences follow per-row affine recurrences x_{t+1} = (a*x_t + c) mod V with
(a, c) drawn from a small pattern set — fully learnable transitions, so smoke
training runs show real loss descent.  Generation is keyed by
(seed, step, process_index): restart-safe and multi-host shardable.

``frames`` / ``patches`` stubs for the audio/vlm families are deterministic
low-amplitude embeddings derived from the token stream.
"""
from __future__ import annotations

from typing import Iterator, Optional

import numpy as np

N_PATTERNS = 8


def _make_patterns(vocab: int, seed: int):
    rng = np.random.default_rng(seed)
    a = rng.integers(2, min(vocab - 1, 97), size=N_PATTERNS)
    c = rng.integers(1, vocab - 1, size=N_PATTERNS)
    return a.astype(np.int64), c.astype(np.int64)


def synthetic_batches(*, batch: int, seq_len: int, vocab: int,
                      seed: int = 0, steps: Optional[int] = None,
                      family: str = "dense", d_model: int = 0,
                      num_patches: int = 0, frames_len: int = 0,
                      process_index: int = 0,
                      process_count: int = 1) -> Iterator[dict]:
    """Yields {"inputs","targets"(B,S)} (+ frames/patches for audio/vlm).

    ``batch`` is the per-process batch; different ``process_index`` values
    yield disjoint streams (host data sharding)."""
    a_pat, c_pat = _make_patterns(vocab, seed)
    step = 0
    while steps is None or step < steps:
        rng = np.random.default_rng(
            np.random.SeedSequence([seed, step, process_index, process_count]))
        pat = rng.integers(0, N_PATTERNS, size=batch)
        a, c = a_pat[pat], c_pat[pat]
        x = np.empty((batch, seq_len + 1), np.int64)
        x[:, 0] = rng.integers(0, vocab, size=batch)
        for t in range(seq_len):
            x[:, t + 1] = (a * x[:, t] + c) % vocab
        out = {"inputs": x[:, :-1].astype(np.int32),
               "targets": x[:, 1:].astype(np.int32)}
        if family == "audio":
            f = rng.standard_normal((batch, frames_len or seq_len, d_model))
            out["frames"] = (f * 0.1).astype(np.float32)
        if family == "vlm":
            p = rng.standard_normal((batch, num_patches, 1024))
            out["patches"] = (p * 0.1).astype(np.float32)
        yield out
        step += 1


def synthetic_images(*, batch: int, image_size: int, num_classes: int,
                     seed: int = 0, steps: Optional[int] = None):
    """Class-conditional gaussian blobs for the AlexNet example."""
    rng0 = np.random.default_rng(seed)
    protos = rng0.standard_normal((num_classes, 8, 8, 3)).astype(np.float32)
    step = 0
    while steps is None or step < steps:
        rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
        labels = rng.integers(0, num_classes, size=batch)
        base = protos[labels]
        up = np.repeat(np.repeat(base, image_size // 8 + 1, 1),
                       image_size // 8 + 1, 2)[:, :image_size, :image_size]
        noise = rng.standard_normal(up.shape).astype(np.float32)
        yield {"images": up + 0.3 * noise,
               "labels": labels.astype(np.int32)}
        step += 1
