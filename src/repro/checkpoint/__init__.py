from .checkpoint import (AsyncCheckpointer, CheckpointCorrupt,  # noqa: F401
                         latest_intact_step, latest_step, restore, save,
                         verify_step)
