from .checkpoint import (AsyncCheckpointer, latest_step, restore,  # noqa: F401
                         save)
