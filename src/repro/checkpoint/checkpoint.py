"""Sharded, atomic, async-capable checkpointing.

Layout: <dir>/step_<N>/ with one .npy per pytree leaf + manifest.json
(tree structure, shapes, dtypes, step, per-file crc32).  Writes go to a
tmp dir + os.replace (atomic on POSIX): a killed writer never corrupts the
latest checkpoint.  Restore re-places leaves onto provided shardings
(elastic restarts: the new mesh may differ from the one that saved).

Integrity: every leaf file's crc32 is recorded in the manifest at save
time, and :func:`restore` verifies it on load (``verify=True``).  A torn
or bit-rotted *latest* checkpoint — crc mismatch, missing leaf, unreadable
manifest — makes restore fall back to the newest step that verifies
intact instead of loading bad weights (the crash-consistent-restart
contract of the supervised serving fleet); an *explicitly requested* step
that fails verification raises :class:`CheckpointCorrupt` (the caller
named it, so silently substituting another step would be worse than
failing).  Manifests written before checksums existed verify by presence
+ loadability only.
"""
from __future__ import annotations

import json
import os
import queue
import re
import shutil
import threading
import warnings
import zlib
from typing import List, Optional, Tuple

import jax
import numpy as np


class CheckpointCorrupt(RuntimeError):
    """An explicitly requested checkpoint step failed integrity checks."""


def _leaf_name(path) -> str:
    parts = []
    for k in path:
        key = getattr(k, "key", getattr(k, "idx", None))
        parts.append(str(key))
    return "__".join(parts) or "leaf"


def save(ckpt_dir: str, state, *, keep: int = 3) -> str:
    step = int(jax.device_get(state["step"])) if isinstance(state, dict) and \
        "step" in state else 0
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves = jax.tree_util.tree_flatten_with_path(state)[0]
    manifest = {"step": step, "leaves": []}
    for path, leaf in leaves:
        name = _leaf_name(path)
        arr = np.asarray(jax.device_get(leaf))
        fpath = os.path.join(tmp, name + ".npy")
        np.save(fpath, arr)
        manifest["leaves"].append({
            "name": name, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "crc32": _file_crc32(fpath)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)            # atomic publish
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(_list_steps(ckpt_dir))
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:010d}"),
                      ignore_errors=True)


def _list_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", d)
        if m and os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")):
            out.append(int(m.group(1)))
    return out


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = _list_steps(ckpt_dir)
    return max(steps) if steps else None


def _file_crc32(path: str, chunk: int = 1 << 20) -> int:
    crc = 0
    with open(path, "rb") as f:
        while True:
            buf = f.read(chunk)
            if not buf:
                return crc
            crc = zlib.crc32(buf, crc)


def verify_step(ckpt_dir: str, step: int) -> Tuple[bool, List[str]]:
    """Integrity-check one checkpoint step against its manifest.

    Returns ``(ok, problems)``: a readable manifest, every leaf file
    present, and — when the manifest records checksums — every file's
    crc32 matching.  Legacy manifests (no ``crc32`` fields) verify by
    presence only, so old checkpoints remain restorable.
    """
    d = os.path.join(ckpt_dir, f"step_{step:010d}")
    problems: List[str] = []
    try:
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
    except (OSError, ValueError) as e:
        return False, [f"manifest unreadable: {e}"]
    for leaf in manifest.get("leaves", []):
        fpath = os.path.join(d, leaf["name"] + ".npy")
        if not os.path.exists(fpath):
            problems.append(f"missing leaf file {leaf['name']}.npy")
            continue
        want = leaf.get("crc32")
        if want is not None and _file_crc32(fpath) != want:
            problems.append(f"crc mismatch on {leaf['name']}.npy")
    return not problems, problems


def latest_intact_step(ckpt_dir: str) -> Optional[int]:
    """Newest step that passes :func:`verify_step`, scanning backward past
    torn/corrupt checkpoints (each skip is warned, never silent)."""
    for step in sorted(_list_steps(ckpt_dir), reverse=True):
        ok, problems = verify_step(ckpt_dir, step)
        if ok:
            return step
        warnings.warn(
            f"checkpoint step {step} under {ckpt_dir} failed integrity "
            f"checks ({'; '.join(problems)}); falling back to the previous "
            f"step", stacklevel=2)
    return None


def restore(ckpt_dir: str, state_like, *, step: Optional[int] = None,
            shardings=None, verify: bool = True):
    """Restore into the structure of ``state_like``.  ``shardings``: optional
    matching pytree of NamedShardings (elastic reshard on load).

    With ``verify`` (default), leaf files are checked against the
    manifest's crc32 before any load: when ``step`` is None the newest
    *intact* checkpoint is restored (a torn latest falls back to the
    previous step, with a warning); an explicitly requested corrupt step
    raises :class:`CheckpointCorrupt`.
    """
    if step is None:
        step = latest_intact_step(ckpt_dir) if verify else latest_step(
            ckpt_dir)
        if step is None:
            raise FileNotFoundError(
                f"no {'intact ' if verify else ''}checkpoints under "
                f"{ckpt_dir}")
    elif verify:
        ok, problems = verify_step(ckpt_dir, step)
        if not ok:
            raise CheckpointCorrupt(
                f"checkpoint step {step} under {ckpt_dir} failed integrity "
                f"checks: {'; '.join(problems)}")
    d = os.path.join(ckpt_dir, f"step_{step:010d}")
    paths, tdef = jax.tree_util.tree_flatten_with_path(state_like)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(paths))
    leaves = []
    for (path, like), sh in zip(paths, shard_leaves):
        arr = np.load(os.path.join(d, _leaf_name(path) + ".npy"))
        if sh is not None:
            leaves.append(jax.device_put(arr, sh))
        else:
            leaves.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(tdef, [l for l in leaves])


class AsyncCheckpointer:
    """Background-thread writer; ``wait()`` drains before exit/restore."""

    def __init__(self, ckpt_dir: str, *, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._q: "queue.Queue" = queue.Queue(maxsize=2)
        self._err: Optional[BaseException] = None
        self._t = threading.Thread(target=self._run, daemon=True)
        self._t.start()

    def _run(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            try:
                save(self.ckpt_dir, item, keep=self.keep)
            except BaseException as e:
                self._err = e
            finally:
                self._q.task_done()

    def submit(self, state):
        # snapshot to host first so the donated buffers can be reused
        host_state = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), state)
        self._q.put(host_state)
        if self._err:
            raise self._err

    def wait(self):
        self._q.join()
        if self._err:
            raise self._err

    def close(self):
        self.wait()
        self._q.put(None)
        self._t.join(timeout=10)
