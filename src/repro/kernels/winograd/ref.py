"""Pure-jnp oracles for the Winograd kernels: direct convolution."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def conv1d_depthwise_causal_ref(x, w, b=None):
    """Direct (shift-multiply) causal depthwise conv; x (B,L,C), w (r,C)."""
    r = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (r - 1, 0), (0, 0)))
    y = sum(xp[:, i:i + x.shape[1], :] * w[i].astype(x.dtype)
            for i in range(r))
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def conv2d_ref(x, w, *, stride: int = 1, padding: str = "SAME"):
    """lax direct conv; x (B,H,W,C), w (r,r,C,K)."""
    return jax.lax.conv_general_dilated(
        x.astype(jnp.float32), w.astype(jnp.float32),
        window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC")).astype(x.dtype)
