"""Pallas TPU kernels for Winograd-domain convolution (paper §3.3 + §3.5).

Hardware adaptation (docs/DESIGN.md): the paper's PEs do scalar Winograd-
domain dot products on DSP blocks; on TPU the Winograd-domain multiply must
feed the MXU, so we use the Lavin formulation — each of the n^2 transform
positions becomes an independent (tiles x C) @ (C x K) GEMM.

Stream-buffered dataflow (paper §3.5): the kernels read *raw* feature-map
slabs from HBM — no host-side tile gather, so the ~(n/m)^2-inflated
overlapping-tile tensor never materializes in HBM.  The Pallas grid
pipeline's double-buffered HBM->VMEM DMA plays the role of the DLA's stream
buffer; overlapping n x n tiles are built *in VMEM* from strided slices of
the slab.  A `c_block` grid dimension streams channel blocks with in-kernel
accumulation into a VMEM scratch (the PE "daisy-chained" partial sums), so
large-C layers never need all of C resident at once.  Bias + ReLU fuse into
the kernel epilogue (the DLA's post-PE activation stage) behind a flag.

Grouped convolution folds groups into the batch grid dimension — the weight
BlockSpec picks the group as `bb // B` — so conv2/4/5 of AlexNet run as one
kernel launch with no host loop or concatenate.

VMEM budget per grid step (2D): slab Hp*Wp*Cb + filters n^2*Cb*Kb + tiles
Rb*tw*n^2*Cb + acc n^2*Rb*tw*Kb floats; defaults keep this < 16 MB for
AlexNet-sized layers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...core.winograd import winograd_transform
from ..compat import ARBITRARY, PARALLEL, tpu_compiler_params


# ---------------------------------------------------------------------------
# 1D depthwise causal (Mamba conv, k=4 -> F(3,4))
# ---------------------------------------------------------------------------
def _dw1d_kernel(x_ref, w_ref, b_ref, bt_ref, g_ref, at_ref, out_ref):
    mm, n = at_ref.shape
    Tb = out_ref.shape[1] // mm
    jb = pl.program_id(1)
    # raw slab -> overlapping tiles in VMEM (stride-m strided slices)
    seg = x_ref[0, pl.ds(jb * Tb * mm, Tb * mm + n - mm)]  # (Tb*m + r - 1, Cb)
    Cb = seg.shape[-1]
    tiles = jnp.stack(
        [jax.lax.slice(seg, (di, 0), (di + (Tb - 1) * mm + 1, Cb), (mm, 1))
         for di in range(n)], axis=0).astype(jnp.float32)   # (n, Tb, Cb)
    w = w_ref[...].astype(jnp.float32)              # (r, Cb)
    BT = bt_ref[...]                                # (n, n)
    G = g_ref[...]                                  # (n, r)
    AT = at_ref[...]                                # (m, n)
    u = jnp.einsum("tn,njc->tjc", BT, tiles)        # input transform
    v = jnp.einsum("tr,rc->tc", G, w)               # filter transform
    y = jnp.einsum("mt,tjc->jmc", AT, u * v[:, None])  # mult + inverse
    y = y.reshape(Tb * mm, Cb) + b_ref[0]
    out_ref[0] = y.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("m", "tile_block", "c_block",
                                             "interpret"))
def conv1d_depthwise_causal(x, w, b=None, *, m: int | None = None,
                            tile_block: int = 128, c_block: int = 128,
                            interpret: bool = True):
    """x (B,L,C); w (r,C); left-padded causal depthwise conv via F(m,r).

    The kernel reads the raw padded sequence; overlapping n-tiles are built
    in VMEM (no host-side ``jnp.take`` tile materialization).  Stream-buffer
    residency: one (Lp, c_block) sequence slab stays in VMEM — ``c_block``
    bounds the footprint (Lp * c_block * 4 B must fit; e.g. L=8k, Cb=128
    -> ~4 MB).  Shrink ``c_block`` for very long sequences.
    """
    r = w.shape[0]
    m = m or {3: 4, 4: 3}.get(r, 2)
    t = winograd_transform(m, r)
    B, L, C = x.shape
    nt = -(-L // t.m)
    Tb = min(tile_block, nt)
    ntp = -(-nt // Tb) * Tb
    # left halo r-1; right pad so every tile block has a full slab
    xp = jnp.pad(x, ((0, 0), (r - 1, ntp * t.m - L + (t.n - t.m) - (r - 1)),
                     (0, 0)))
    Cb = min(c_block, C)
    padc = (-C) % Cb
    if padc:
        xp = jnp.pad(xp, ((0, 0), (0, 0), (0, padc)))
        w = jnp.pad(w, ((0, 0), (0, padc)))
    Cp = C + padc
    bias = jnp.zeros((Cp,), x.dtype) if b is None else (
        jnp.pad(b, (0, padc)) if padc else b)
    Lp = xp.shape[1]

    out = pl.pallas_call(
        _dw1d_kernel,
        grid=(B, ntp // Tb, Cp // Cb),
        in_specs=[
            pl.BlockSpec((1, Lp, Cb), lambda bb, j, c: (bb, 0, c)),
            pl.BlockSpec((r, Cb), lambda bb, j, c: (0, c)),
            pl.BlockSpec((1, Cb), lambda bb, j, c: (0, c)),
            pl.BlockSpec((t.n, t.n), lambda bb, j, c: (0, 0)),
            pl.BlockSpec((t.n, r), lambda bb, j, c: (0, 0)),
            pl.BlockSpec((t.m, t.n), lambda bb, j, c: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, Tb * t.m, Cb), lambda bb, j, c: (bb, j, c)),
        out_shape=jax.ShapeDtypeStruct((B, ntp * t.m, Cp), x.dtype),
        compiler_params=tpu_compiler_params(PARALLEL, PARALLEL, PARALLEL),
        interpret=interpret,
    )(xp, w, bias.reshape(1, Cp), jnp.asarray(t.BT, jnp.float32),
      jnp.asarray(t.G, jnp.float32), jnp.asarray(t.AT, jnp.float32))

    return out[:, :L, :C]


# ---------------------------------------------------------------------------
# 2D conv (AlexNet 3x3 -> F(4,3) x F(4,3))
# ---------------------------------------------------------------------------
def _conv2d_kernel(x_ref, wt_ref, b_ref, bt_ref, at_ref, out_ref, acc_ref, *,
                   relu: bool):
    mm, n = at_ref.shape
    Rb = out_ref.shape[1] // mm
    tw = out_ref.shape[2] // mm
    ib = pl.program_id(1)
    c = pl.program_id(3)
    nc = pl.num_programs(3)

    @pl.when(c == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # raw slab rows for this tile-row block (halo overlap r-1 stays in VMEM)
    rows = x_ref[0, pl.ds(ib * Rb * mm, Rb * mm + n - mm)]  # (rows, Wp, Cb)
    Cb = rows.shape[-1]
    # overlapping n x n tiles via n^2 strided slices: plane (di, dj) holds
    # element (di, dj) of every tile -> (n, n, Rb, tw, Cb)
    tiles = jnp.stack(
        [jnp.stack(
            [jax.lax.slice(rows, (di, dj, 0),
                           (di + (Rb - 1) * mm + 1, dj + (tw - 1) * mm + 1,
                            Cb), (mm, mm, 1))
             for dj in range(n)], axis=0)
         for di in range(n)], axis=0).astype(jnp.float32)
    BT = bt_ref[...]
    v = wt_ref[0].astype(jnp.float32)               # (n, n, Cb, Kb)
    u = jnp.einsum("in,jm,nmrwc->ijrwc", BT, BT, tiles)
    # n^2 batched GEMMs on the MXU: (Rb*tw, Cb) @ (Cb, Kb) per (i, j);
    # accumulated over channel blocks in VMEM scratch (PE partial sums)
    acc_ref[...] += jnp.einsum("ijrwc,ijck->ijrwk", u, v)

    @pl.when(c == nc - 1)
    def _epilogue():
        AT = at_ref[...]
        y = jnp.einsum("pi,ijrwk->pjrwk", AT, acc_ref[...])
        y = jnp.einsum("qj,pjrwk->rpwqk", AT, y)    # (Rb, m, tw, m, Kb)
        y = y.reshape(Rb * mm, tw * mm, -1) + b_ref[0]
        if relu:
            y = jnp.maximum(y, 0.0)
        out_ref[0] = y.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("m", "padding", "relu", "groups",
                                             "row_block", "c_block", "k_block",
                                             "interpret"))
def conv2d_winograd(x, w, b=None, *, m: int = 4, padding: str = "SAME",
                    relu: bool = False, groups: int = 1, row_block: int = 8,
                    c_block: int = 128, k_block: int = 128,
                    interpret: bool = True):
    """x (B,H,W,C); w (r,r,C//groups,K); stride-1 conv via F(m,r) x F(m,r).

    Fused pipeline: raw (halo-padded) feature map slabs stream HBM->VMEM via
    the grid pipeline; tiles, transforms, Winograd GEMMs, channel-block
    accumulation, and the bias+ReLU epilogue all happen in-kernel.  Groups
    fold into the batch grid dimension (weight block picked by ``bb // B``).

    Stream-buffer residency (paper §3.5): like the DLA — whose stream
    buffers hold whole AlexNet feature-map planes in M20K — one full
    (Hp, Wp, c_block) image plane is VMEM-resident per step; ``c_block``
    bounds the channel footprint (large C never fully resident), while the
    spatial plane must fit (13x13..56x56-class layers do; ~224x224 at
    c_block=128 would not — shrink ``c_block`` there).  ``row_block`` tiles
    the *compute* (tiles/scratch), not input residency; smaller row_block
    trades VMEM scratch for slab re-fetches (see ``conv2d_hbm_bytes``).
    """
    r = w.shape[0]
    t = winograd_transform(m, r)
    B, H, W, Ct = x.shape
    Kt = w.shape[-1]
    g = groups
    assert Ct % g == 0 and Kt % g == 0 and w.shape[2] == Ct // g, (
        "grouped conv shape mismatch")
    C, K = Ct // g, Kt // g
    if padding == "SAME":
        ph = r // 2
        out_h, out_w = H, W
    else:
        ph = 0
        out_h, out_w = H - r + 1, W - r + 1
    th, tw = -(-out_h // t.m), -(-out_w // t.m)
    Rb = min(row_block, th)
    thp = -(-th // Rb) * Rb
    Hp = thp * t.m + r - 1
    Wp = tw * t.m + r - 1

    # groups -> leading (batch) axis; raw zero-pad only, no tile gather
    xg = jnp.moveaxis(x.reshape(B, H, W, g, C), 3, 0).reshape(g * B, H, W, C)
    xg = jnp.pad(xg, ((0, 0), (ph, Hp - H - ph), (ph, Wp - W - ph), (0, 0)))
    wg = jnp.moveaxis(w.reshape(r, r, C, g, K), 3, 0)       # (g, r, r, C, K)

    # filter transform host-side (tiny): V = G w G^T per group
    Gj = jnp.asarray(t.G, jnp.float32)
    wt = jnp.einsum("in,gnmck,jm->gijck", Gj, wg.astype(jnp.float32), Gj)

    Cb = min(c_block, C)
    padc = (-C) % Cb
    if padc:
        xg = jnp.pad(xg, ((0, 0), (0, 0), (0, 0), (0, padc)))
        wt = jnp.pad(wt, ((0, 0), (0, 0), (0, 0), (0, padc), (0, 0)))
    Kb = min(k_block, K)
    padk = (-K) % Kb
    if padk:
        wt = jnp.pad(wt, ((0, 0), (0, 0), (0, 0), (0, 0), (0, padk)))
    Cp, Kp = C + padc, K + padk
    bias = jnp.zeros((Kt,), x.dtype) if b is None else b
    bg = bias.reshape(g, K)
    if padk:
        bg = jnp.pad(bg, ((0, 0), (0, padk)))

    kernel = functools.partial(_conv2d_kernel, relu=relu)
    out = pl.pallas_call(
        kernel,
        grid=(g * B, thp // Rb, Kp // Kb, Cp // Cb),
        in_specs=[
            pl.BlockSpec((1, Hp, Wp, Cb),
                         lambda bb, i, k, c: (bb, 0, 0, c)),
            pl.BlockSpec((1, t.n, t.n, Cb, Kb),
                         lambda bb, i, k, c: (bb // B, 0, 0, c, k)),
            pl.BlockSpec((1, Kb), lambda bb, i, k, c: (bb // B, k)),
            pl.BlockSpec((t.n, t.n), lambda bb, i, k, c: (0, 0)),
            pl.BlockSpec((t.m, t.n), lambda bb, i, k, c: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, Rb * t.m, tw * t.m, Kb),
                               lambda bb, i, k, c: (bb, i, 0, k)),
        out_shape=jax.ShapeDtypeStruct((g * B, thp * t.m, tw * t.m, Kp),
                                       x.dtype),
        scratch_shapes=[pltpu.VMEM((t.n, t.n, Rb, tw, Kb), jnp.float32)],
        compiler_params=tpu_compiler_params(PARALLEL, PARALLEL, PARALLEL,
                                            ARBITRARY),
        interpret=interpret,
    )(xg, wt, bg, jnp.asarray(t.BT, jnp.float32),
      jnp.asarray(t.AT, jnp.float32))

    y = out[:, :out_h, :out_w, :K].reshape(g, B, out_h, out_w, K)
    return y.transpose(1, 2, 3, 0, 4).reshape(B, out_h, out_w, g * K)
