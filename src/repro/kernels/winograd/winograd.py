"""Pallas TPU kernels for Winograd-domain convolution (paper §3.3).

Hardware adaptation (DESIGN.md): the paper's PEs do scalar Winograd-domain
dot products on DSP blocks; on TPU the Winograd-domain multiply must feed the
MXU, so we use the Lavin formulation — the 2D kernel turns each of the n^2
transform positions into an independent (tiles x C) @ (C x K) GEMM, and the
1D depthwise kernel maps channels onto VPU lanes.  Tiles are extracted
host-side (XLA gather); the kernel owns transforms + multiply + inverse
transform so the Winograd-domain tensor U never round-trips HBM.

VMEM budget per grid step (2D): Tb*n^2*C*4 + n^2*C*Kb*4 + Tb*n^2*Kb*4 bytes —
Tb/Kb defaults keep this < 16 MB for AlexNet-sized C.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...core.winograd import winograd_transform


# ---------------------------------------------------------------------------
# 1D depthwise causal (Mamba conv, k=4 -> F(3,4))
# ---------------------------------------------------------------------------
def _dw1d_kernel(tiles_ref, w_ref, bt_ref, g_ref, at_ref, out_ref):
    tiles = tiles_ref[0].astype(jnp.float32)        # (Tb, n, Cb)
    w = w_ref[...].astype(jnp.float32)              # (r, Cb)
    BT = bt_ref[...]                                # (n, n)
    G = g_ref[...]                                  # (n, r)
    AT = at_ref[...]                                # (m, n)
    u = jnp.einsum("tn,jnc->jtc", BT, tiles)        # input transform
    v = jnp.einsum("tr,rc->tc", G, w)               # filter transform
    y = jnp.einsum("mt,jtc->jmc", AT, u * v[None])  # winograd mult + inverse
    out_ref[0] = y.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("m", "tile_block", "interpret"))
def conv1d_depthwise_causal(x, w, b=None, *, m: int | None = None,
                            tile_block: int = 128, interpret: bool = True):
    """x (B,L,C); w (r,C); left-padded causal depthwise conv via F(m,r)."""
    r = w.shape[0]
    m = m or {3: 4, 4: 3}.get(r, 2)
    t = winograd_transform(m, r)
    B, L, C = x.shape
    nt = -(-L // t.m)
    # host-side tile extraction (overlap r-1); kernel owns the transforms
    xp = jnp.pad(x, ((0, 0), (r - 1, nt * t.m - L + (t.n - t.m) - (r - 1)),
                     (0, 0)))
    idx = (jnp.arange(nt) * t.m)[:, None] + jnp.arange(t.n)[None, :]
    tiles = jnp.take(xp, idx, axis=1)               # (B, nt, n, C)

    Tb = min(tile_block, nt)
    padt = (-nt) % Tb
    if padt:
        tiles = jnp.pad(tiles, ((0, 0), (0, padt), (0, 0), (0, 0)))
    ntp = nt + padt

    out = pl.pallas_call(
        _dw1d_kernel,
        grid=(B, ntp // Tb),
        in_specs=[
            pl.BlockSpec((1, Tb, t.n, C), lambda b, j: (b, j, 0, 0)),
            pl.BlockSpec((r, C), lambda b, j: (0, 0)),
            pl.BlockSpec((t.n, t.n), lambda b, j: (0, 0)),
            pl.BlockSpec((t.n, r), lambda b, j: (0, 0)),
            pl.BlockSpec((t.m, t.n), lambda b, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, Tb, t.m, C), lambda b, j: (b, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, ntp, t.m, C), x.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=(pltpu.GridDimensionSemantics.PARALLEL,
                                 pltpu.GridDimensionSemantics.PARALLEL)),
        interpret=interpret,
    )(tiles, w, jnp.asarray(t.BT, jnp.float32), jnp.asarray(t.G, jnp.float32),
      jnp.asarray(t.AT, jnp.float32))

    y = out.reshape(B, ntp * t.m, C)[:, :L]
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# 2D conv (AlexNet 3x3 -> F(4,3) x F(4,3))
# ---------------------------------------------------------------------------
def _conv2d_kernel(tiles_ref, wt_ref, bt_ref, at_ref, out_ref):
    d = tiles_ref[...].astype(jnp.float32)          # (Tb, n, n, C)
    v = wt_ref[...].astype(jnp.float32)             # (n, n, C, Kb)
    BT = bt_ref[...]
    AT = at_ref[...]
    u = jnp.einsum("in,tnmc->timc", BT, d)
    u = jnp.einsum("timc,jm->tijc", u, BT)          # (Tb, n, n, C)
    # n^2 batched GEMMs on the MXU: (Tb, C) @ (C, Kb) per (i, j)
    yw = jnp.einsum("tijc,ijck->tijk", u, v)
    y = jnp.einsum("pi,tijk->tpjk", AT, yw)
    y = jnp.einsum("tpjk,qj->tpqk", y, AT)          # (Tb, m, m, Kb)
    out_ref[...] = y.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("m", "padding", "tile_block",
                                             "k_block", "interpret"))
def conv2d_winograd(x, w, *, m: int = 4, padding: str = "SAME",
                    tile_block: int = 128, k_block: int = 128,
                    interpret: bool = True):
    """x (B,H,W,C); w (r,r,C,K); stride-1 conv via F(m,r) x F(m,r)."""
    r = w.shape[0]
    t = winograd_transform(m, r)
    B, H, W, C = x.shape
    K = w.shape[-1]
    if padding == "SAME":
        ph = r // 2
        out_h, out_w = H, W
    else:
        ph = 0
        out_h, out_w = H - r + 1, W - r + 1
    th, tw = -(-out_h // t.m), -(-out_w // t.m)
    xp = jnp.pad(x, ((0, 0), (ph, th * t.m + r - 1 - H - ph),
                     (ph, tw * t.m + r - 1 - W - ph), (0, 0)))
    ih = (jnp.arange(th) * t.m)[:, None] + jnp.arange(t.n)[None, :]
    iw = (jnp.arange(tw) * t.m)[:, None] + jnp.arange(t.n)[None, :]
    tiles = jnp.take(xp, ih, axis=1)
    tiles = jnp.take(tiles, iw, axis=3)             # (B,th,n,tw,n,C)
    tiles = tiles.transpose(0, 1, 3, 2, 4, 5).reshape(B * th * tw, t.n, t.n, C)

    # filter transform host-side (tiny): V = G w G^T
    Gj = jnp.asarray(t.G, jnp.float32)
    wt = jnp.einsum("in,nmck,jm->ijck", Gj, w.astype(jnp.float32), Gj)

    T = tiles.shape[0]
    Tb = min(tile_block, T)
    padt = (-T) % Tb
    if padt:
        tiles = jnp.pad(tiles, ((0, padt), (0, 0), (0, 0), (0, 0)))
    Kb = min(k_block, K)
    padk = (-K) % Kb
    if padk:
        wt = jnp.pad(wt, ((0, 0), (0, 0), (0, 0), (0, padk)))
    Tp, Kp = T + padt, K + padk

    out = pl.pallas_call(
        _conv2d_kernel,
        grid=(Tp // Tb, Kp // Kb),
        in_specs=[
            pl.BlockSpec((Tb, t.n, t.n, C), lambda i, j: (i, 0, 0, 0)),
            pl.BlockSpec((t.n, t.n, C, Kb), lambda i, j: (0, 0, 0, j)),
            pl.BlockSpec((t.n, t.n), lambda i, j: (0, 0)),
            pl.BlockSpec((t.m, t.n), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((Tb, t.m, t.m, Kb), lambda i, j: (i, 0, 0, j)),
        out_shape=jax.ShapeDtypeStruct((Tp, t.m, t.m, Kp), x.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=(pltpu.GridDimensionSemantics.PARALLEL,
                                 pltpu.GridDimensionSemantics.PARALLEL)),
        interpret=interpret,
    )(tiles, wt, jnp.asarray(t.BT, jnp.float32), jnp.asarray(t.AT, jnp.float32))

    y = out[:T, :, :, :K].reshape(B, th, tw, t.m, t.m, K)
    y = y.transpose(0, 1, 3, 2, 4, 5).reshape(B, th * t.m, tw * t.m, K)
    return y[:, :out_h, :out_w]
