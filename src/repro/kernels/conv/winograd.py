"""Pallas TPU kernels for Winograd-domain convolution (paper §3.3 + §3.5).

Hardware adaptation (docs/DESIGN.md): the paper's PEs do scalar Winograd-
domain dot products on DSP blocks; on TPU the Winograd-domain multiply must
feed the MXU, so we use the Lavin formulation — each of the n^2 transform
positions becomes an independent (tiles x C) @ (C x K) GEMM.

Stream-buffered dataflow (paper §3.5): the kernels read *raw* feature-map
slabs from HBM — no host-side tile gather, so the ~(n/m)^2-inflated
overlapping-tile tensor never materializes in HBM.  The Pallas grid
pipeline's double-buffered HBM->VMEM DMA plays the role of the DLA's stream
buffer; overlapping n x n tiles are built *in VMEM* from strided slices of
the slab.  A `c_block` grid dimension streams channel blocks with in-kernel
accumulation into a VMEM scratch (the PE "daisy-chained" partial sums), so
large-C layers never need all of C resident at once.  Bias + ReLU fuse into
the kernel epilogue (the DLA's post-PE activation stage) behind a flag.

Weight path (paper §3.5 filter prefetch — shared machinery in ``dma.py``):
the transformed filters arrive *tile-packed* in an ANY/HBM-space ref and
move by explicit ``pltpu.make_async_copy`` into a 2-slot VMEM scratch.  At
each (k, c) weight-tile transition the next tile's copy is issued before
this step's GEMMs and the only wait is the slot swap, so the filter stream
is double-buffered under MXU compute — the DLA's filter-cache data mover.
The grid still iterates ``batch_block`` images innermost with the tile
held constant (the §3.5 filter cache: one fetch per ``batch_block``
images), and ``plan``/``pack_weights`` expose the packing — including the
G w G^T filter transform — as a pure function of shapes so a model can
stage layer N+1's slab while layer N computes
(``nn/conv.py::pack_conv_weights``).

Grouped convolution folds groups into the K grid dimension (weight tile
``k * ncb + c`` on the group-major channel layout), so conv2/4/5 of
AlexNet run as one kernel launch with no host loop or concatenate — and
the fused epilogue sees the full concatenated channel dim (LRN windows
cross group seams).

The in-kernel LRN + max-pool epilogue lives in ``epilogue.py``, shared with
the strided direct kernel (``direct.py``).
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...core.winograd import auto_pool_rows, winograd_transform
from ..compat import PARALLEL, tpu_compiler_params
from . import dma
from .epilogue import batch_blocks, channel_blocks, fused_epilogue, \
    grouped_channel_pad, k_blocks


# ---------------------------------------------------------------------------
# 1D depthwise causal (Mamba conv, k=4 -> F(3,4))
# ---------------------------------------------------------------------------
def _dw1d_kernel(x_ref, w_ref, b_ref, bt_ref, g_ref, at_ref, out_ref):
    mm, n = at_ref.shape
    Tb = out_ref.shape[1] // mm
    jb = pl.program_id(1)
    # raw slab -> overlapping tiles in VMEM (stride-m strided slices)
    seg = x_ref[0, pl.ds(jb * Tb * mm, Tb * mm + n - mm)]  # (Tb*m + r - 1, Cb)
    Cb = seg.shape[-1]
    tiles = jnp.stack(
        [jax.lax.slice(seg, (di, 0), (di + (Tb - 1) * mm + 1, Cb), (mm, 1))
         for di in range(n)], axis=0).astype(jnp.float32)   # (n, Tb, Cb)
    w = w_ref[...].astype(jnp.float32)              # (r, Cb)
    BT = bt_ref[...]                                # (n, n)
    G = g_ref[...]                                  # (n, r)
    AT = at_ref[...]                                # (m, n)
    u = jnp.einsum("tn,njc->tjc", BT, tiles)        # input transform
    v = jnp.einsum("tr,rc->tc", G, w)               # filter transform
    y = jnp.einsum("mt,tjc->jmc", AT, u * v[:, None])  # mult + inverse
    y = y.reshape(Tb * mm, Cb) + b_ref[0]
    out_ref[0] = y.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("m", "tile_block", "c_block",
                                             "interpret"))
def conv1d_depthwise_causal(x, w, b=None, *, m: int | None = None,
                            tile_block: int = 128, c_block: int = 128,
                            interpret: bool = True):
    """x (B,L,C); w (r,C); left-padded causal depthwise conv via F(m,r).

    The kernel reads the raw padded sequence; overlapping n-tiles are built
    in VMEM (no host-side ``jnp.take`` tile materialization).  Stream-buffer
    residency: one (Lp, c_block) sequence slab stays in VMEM — ``c_block``
    bounds the footprint (Lp * c_block * 4 B must fit; e.g. L=8k, Cb=128
    -> ~4 MB).  Shrink ``c_block`` for very long sequences.
    """
    r = w.shape[0]
    m = m or {3: 4, 4: 3}.get(r, 2)
    t = winograd_transform(m, r)
    B, L, C = x.shape
    nt = -(-L // t.m)
    Tb = min(tile_block, nt)
    ntp = -(-nt // Tb) * Tb
    # left halo r-1; right pad so every tile block has a full slab
    xp = jnp.pad(x, ((0, 0), (r - 1, ntp * t.m - L + (t.n - t.m) - (r - 1)),
                     (0, 0)))
    Cb = min(c_block, C)
    padc = (-C) % Cb
    if padc:
        xp = jnp.pad(xp, ((0, 0), (0, 0), (0, padc)))
        w = jnp.pad(w, ((0, 0), (0, padc)))
    Cp = C + padc
    bias = jnp.zeros((Cp,), x.dtype) if b is None else (
        jnp.pad(b, (0, padc)) if padc else b)
    Lp = xp.shape[1]

    out = pl.pallas_call(
        _dw1d_kernel,
        grid=(B, ntp // Tb, Cp // Cb),
        in_specs=[
            pl.BlockSpec((1, Lp, Cb), lambda bb, j, c: (bb, 0, c)),
            pl.BlockSpec((r, Cb), lambda bb, j, c: (0, c)),
            pl.BlockSpec((1, Cb), lambda bb, j, c: (0, c)),
            pl.BlockSpec((t.n, t.n), lambda bb, j, c: (0, 0)),
            pl.BlockSpec((t.n, r), lambda bb, j, c: (0, 0)),
            pl.BlockSpec((t.m, t.n), lambda bb, j, c: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, Tb * t.m, Cb), lambda bb, j, c: (bb, j, c)),
        out_shape=jax.ShapeDtypeStruct((B, ntp * t.m, Cp), x.dtype),
        compiler_params=tpu_compiler_params(PARALLEL, PARALLEL, PARALLEL),
        interpret=interpret,
    )(xp, w, bias.reshape(1, Cp), jnp.asarray(t.BT, jnp.float32),
      jnp.asarray(t.G, jnp.float32), jnp.asarray(t.AT, jnp.float32))

    return out[:, :L, :C]


# ---------------------------------------------------------------------------
# 2D conv (AlexNet 3x3 -> F(4,3) x F(4,3))
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class WinogradPlan:
    """Host-side launch plan for one 2D Winograd kernel call.

    Pure function of shapes + static params (``plan``), so the weight
    packing — including the G w G^T filter transform — can run ahead of
    the input tensor (the cross-layer staging hook).  ``fused`` selects
    the layer-fused grid (in-VMEM LRN/pool epilogue, exact K tiling) vs
    the plain conv grid (bias+ReLU only, K padded up to the block).
    """
    fused: bool
    m: int
    r: int
    g: int
    C: int                  # channels per group
    K: int                  # out channels per group
    out_h: int
    out_w: int
    ph_pad: int             # SAME halo pad (both sides)
    tw: int                 # width tiles
    Rt: int                 # tile rows per row step
    row_step: int           # tile rows advanced per row step
    npr: int                # row steps
    rows_out: int           # output rows written per row step
    w_out: int              # output cols written per row step
    thp: int                # total tile rows the slab must cover
    Hp: int
    Wp: int
    Bb: int
    Bp: int
    Cb: int
    Cp: int
    ncb: int
    Kb: int
    Kp: int                 # K per group incl. pad (== K when fused)
    nkb: int
    ph_out: int             # pooled rows (== out_h when no pool)
    pw_out: int
    checksum: bool = False  # ABFT checksum row on every weight tile

    @property
    def n(self) -> int:
        return self.m + self.r - 1

    @property
    def Kfull(self) -> int:
        return self.g * self.K

    @property
    def weights(self) -> dma.WeightPlan:
        return dma.WeightPlan(g=self.g, nkb=self.nkb, ncb=self.ncb,
                              Cb=self.Cb, Kb=self.Kb,
                              spatial=(self.n, self.n),
                              checksum=self.checksum)


def plan(x_shape, w_shape, *, m: int = 4, padding: str = "SAME",
         groups: int = 1, lrn=None, pool=None, row_block: int = 8,
         pool_row_block: int | None = None, c_block: int | None = None,
         k_block: int = 128, batch_block: int = 8,
         checksum: bool = False) -> WinogradPlan:
    """Derive the full launch plan from shapes + static params."""
    r = w_shape[0]
    t = winograd_transform(m, r)
    mm = t.m
    B, H, W, Ct = x_shape
    g = groups
    Kt = w_shape[-1]
    assert Ct % g == 0 and Kt % g == 0 and w_shape[2] == Ct // g, (
        "grouped conv shape mismatch")
    C, K = Ct // g, Kt // g
    if padding == "SAME":
        ph_pad = r // 2
        out_h, out_w = H, W
    else:
        ph_pad = 0
        out_h, out_w = H - r + 1, W - r + 1
    tw = -(-out_w // mm)
    Bb, Bp = batch_blocks(B, batch_block)
    fused = lrn is not None or pool is not None

    ph_out, pw_out = out_h, out_w
    if fused and pool is not None:
        pwin, ps = pool
        ph_out = (out_h - pwin) // ps + 1
        pw_out = (out_w - pwin) // ps + 1
        assert ph_out >= 1 and pw_out >= 1, (
            f"pool {pool} larger than conv output {out_h}x{out_w}")
        # alignment: each step's first conv row ps*Pb*i must be tile-aligned
        q = mm // math.gcd(ps, mm)
        if pool_row_block is None:
            # own the whole pooled extent when the epilogue scratch fits —
            # one row step, so grouped layers never re-fetch their slab
            Pb = auto_pool_rows(ph_out, pwin, ps, align=q, row_align=mm,
                                cols=tw * mm, kfull=g * K, batch=Bb)
        else:
            Pb = q * (-(-min(pool_row_block, ph_out) // q))
        row_step = ps * Pb // mm
        Rt = -(-(ps * (Pb - 1) + pwin) // mm)
        npr = -(-ph_out // Pb)
        rows_out, w_out = Pb, pw_out
        thp = (npr - 1) * row_step + Rt         # last step's read must fit
    else:
        th = -(-out_h // mm)
        Rt = row_step = min(row_block, th)
        npr = -(-th // Rt)
        rows_out, w_out = Rt * mm, tw * mm
        thp = (npr - 1) * row_step + Rt if fused else npr * Rt
    Hp = thp * mm + r - 1
    Wp = tw * mm + r - 1

    Cb = channel_blocks(C, c_block, Hp, Wp, Bb)
    Cp = C + (-C) % Cb
    if fused:
        # no K padding: zero pad channels inside an LRN window would shadow
        # the real cross-seam neighbours, so blocks must tile K exactly
        Kb = k_blocks(K, k_block)
        Kp = K
    else:
        Kb = min(k_block, K)
        Kp = K + (-K) % Kb
    return WinogradPlan(fused=fused, m=m, r=r, g=g, C=C, K=K, out_h=out_h,
                        out_w=out_w, ph_pad=ph_pad, tw=tw, Rt=Rt,
                        row_step=row_step, npr=npr, rows_out=rows_out,
                        w_out=w_out, thp=thp, Hp=Hp, Wp=Wp, Bb=Bb, Bp=Bp,
                        Cb=Cb, Cp=Cp, ncb=Cp // Cb, Kb=Kb, Kp=Kp,
                        nkb=Kp // Kb, ph_out=ph_out, pw_out=pw_out,
                        checksum=checksum)


def pack_weights(w, p: WinogradPlan):
    """(r, r, C, g*K) raw filters -> (n_tiles, n, n, Cb, Kb) transformed
    DMA tiles: per-group G w G^T (host-side, tiny), channel/K pad, and the
    tile layout of ``dma.pack_weight_tiles``."""
    r, g, C, K = p.r, p.g, p.C, p.K
    t = winograd_transform(p.m, r)
    wg = jnp.moveaxis(w.reshape(r, r, C, g, K), 3, 0)       # (g, r, r, C, K)
    Gj = jnp.asarray(t.G, jnp.float32)
    wt = jnp.einsum("in,gnmck,jm->gijck", Gj, wg.astype(jnp.float32), Gj)
    if p.Cp > C or p.Kp > K:
        wt = jnp.pad(wt, ((0, 0), (0, 0), (0, 0), (0, p.Cp - C),
                          (0, p.Kp - K)))
    return dma.pack_weight_tiles(wt, p.weights)


def _tiles_from_rows(rows, n: int, mm: int, nr: int, nw: int):
    """Overlapping n x n tiles from a VMEM row slab via n^2 strided slices:
    plane (di, dj) holds element (di, dj) of every tile -> (n,n,nr,nw,Cb)."""
    Cb = rows.shape[-1]
    return jnp.stack(
        [jnp.stack(
            [jax.lax.slice(rows, (di, dj, 0),
                           (di + (nr - 1) * mm + 1, dj + (nw - 1) * mm + 1,
                            Cb), (mm, mm, 1))
             for dj in range(n)], axis=0)
         for di in range(n)], axis=0).astype(jnp.float32)


def _conv2d_kernel(x_ref, w_tiles, b_ref, bt_ref, at_ref, out_ref, *refs,
                   relu: bool, checksum: bool, prefetch: bool, single: bool,
                   row_parallel: bool):
    if checksum:
        sdc_ref, acc_ref, wbuf, sem = refs
    else:
        acc_ref, wbuf, sem = refs
    mm, n = at_ref.shape
    _, _, _, Rb, tw, Kb = acc_ref.shape
    ib = pl.program_id(1)
    c = pl.program_id(3)
    nc = pl.num_programs(3)
    bi = pl.program_id(4)                           # filter-cache image slot
    v = dma.fetch_weight_tile(w_tiles, wbuf, sem, prefetch=prefetch,
                              single=single, row_parallel=row_parallel)
    if checksum:
        # ABFT: verify the resident tile's checksum row, then strip it —
        # the GEMMs below consume exactly the same Cb rows as an unarmed
        # launch, so clean armed output is bit-identical
        dma.verify_tile_checksum(sdc_ref, v)
        v = v[..., :-1, :]
    v = v.astype(jnp.float32)

    @pl.when(c == 0)
    def _init():
        acc_ref[bi] = jnp.zeros(acc_ref.shape[1:], acc_ref.dtype)

    # raw slab rows for this tile-row block (halo overlap r-1 stays in VMEM)
    rows = x_ref[bi, pl.ds(ib * Rb * mm, Rb * mm + n - mm)]  # (rows, Wp, Cb)
    tiles = _tiles_from_rows(rows, n, mm, Rb, tw)
    BT = bt_ref[...]
    u = jnp.einsum("in,jm,nmrwc->ijrwc", BT, BT, tiles)
    # n^2 batched GEMMs on the MXU: (Rb*tw, Cb) @ (Cb, Kb) per (i, j);
    # accumulated over channel blocks in VMEM scratch (PE partial sums)
    acc_ref[bi] += jnp.einsum("ijrwc,ijck->ijrwk", u, v)

    @pl.when(c == nc - 1)
    def _epilogue():
        AT = at_ref[...]
        y = jnp.einsum("pi,ijrwk->pjrwk", AT, acc_ref[bi])
        y = jnp.einsum("qj,pjrwk->rpwqk", AT, y)    # (Rb, m, tw, m, Kb)
        y = y.reshape(Rb * mm, tw * mm, -1) + b_ref[0]
        if relu:
            y = jnp.maximum(y, 0.0)
        out_ref[bi] = y.astype(out_ref.dtype)


def _conv2d_fused_kernel(x_ref, w_tiles, b_ref, bt_ref, at_ref, out_ref,
                         *refs, relu: bool, checksum: bool, lrn,
                         pool, row_step: int, prefetch: bool, single: bool,
                         row_parallel: bool):
    """Layer-fused variant: conv + bias + ReLU + LRN + max-pool in VMEM.

    The k grid dimension spans *all* g*K output channels (groups included);
    each (k, c=last) step deposits its channel block into the full-channel
    ``y_ref`` scratch, and the very last (k, c) step runs the cross-channel
    LRN + spatial max-pool epilogue (``epilogue.fused_epilogue``) and writes
    only the pooled, normalized slab to HBM — the conv-resolution feature
    map never leaves VMEM (§3.5).
    """
    if checksum:
        sdc_ref, acc_ref, y_ref, wbuf, sem = refs
    else:
        acc_ref, y_ref, wbuf, sem = refs
    mm, n = at_ref.shape
    _, _, _, Rt, tw, Kb = acc_ref.shape
    ib = pl.program_id(1)
    k = pl.program_id(2)
    nk = pl.num_programs(2)
    c = pl.program_id(3)
    nc = pl.num_programs(3)
    bi = pl.program_id(4)                           # filter-cache image slot
    v = dma.fetch_weight_tile(w_tiles, wbuf, sem, prefetch=prefetch,
                              single=single, row_parallel=row_parallel)
    if checksum:
        dma.verify_tile_checksum(sdc_ref, v)
        v = v[..., :-1, :]
    v = v.astype(jnp.float32)

    @pl.when(c == 0)
    def _init():
        acc_ref[bi] = jnp.zeros(acc_ref.shape[1:], acc_ref.dtype)

    # raw slab rows for this output-owning block; successive blocks overlap
    # by Rt - row_step tile rows (the output-side pool halo, kept in VMEM)
    rows = x_ref[bi, pl.ds(ib * row_step * mm, Rt * mm + n - mm)]
    tiles = _tiles_from_rows(rows, n, mm, Rt, tw)
    BT = bt_ref[...]
    u = jnp.einsum("in,jm,nmrwc->ijrwc", BT, BT, tiles)
    acc_ref[bi] += jnp.einsum("ijrwc,ijck->ijrwk", u, v)

    @pl.when(c == nc - 1)
    def _store_kblock():
        AT = at_ref[...]
        y = jnp.einsum("pi,ijrwk->pjrwk", AT, acc_ref[bi])
        y = jnp.einsum("qj,pjrwk->rpwqk", AT, y)    # (Rt, m, tw, m, Kb)
        y = y.reshape(Rt * mm, tw * mm, Kb) + b_ref[0]
        if relu:
            y = jnp.maximum(y, 0.0)
        # channel blocks are group-major contiguous, so block k lands at
        # offset k*Kb of the full concatenated channel dim
        y_ref[bi, :, :, pl.ds(k * Kb, Kb)] = y

    @pl.when((c == nc - 1) & (k == nk - 1))
    def _epilogue():
        out_ref[bi] = fused_epilogue(
            y_ref[bi], lrn, pool, out_ref.shape[1],
            out_ref.shape[2]).astype(out_ref.dtype)


def _conv2d_fused_call(x, w, b, w_packed, *, t, p: WinogradPlan, relu,
                       lrn, pool, weight_prefetch, row_parallel, interpret):
    """pallas_call setup for the layer-fused kernel (lrn and/or pool set).

    Grid (B/Bb, pooled-row blocks, g*K blocks, C blocks, Bb): groups move
    into the k dim so the epilogue sees the full concatenated channel dim —
    LRN windows legitimately cross group seams, as in Krizhevsky conv2 —
    and ``Bb = batch_block`` images iterate innermost so weight tiles stay
    VMEM-resident across images (the filter cache).  Each row step *owns a
    pooled output region*: it computes the Rt = ceil((ps*(Pb-1)+w)/m)
    Winograd tile rows its Pb pooled rows need, advancing only
    row_step = ps*Pb/m tile rows per step, so the pool window never crosses
    a grid step's slab.
    """
    mm = t.m
    B, H, W, _ = x.shape
    g = p.g

    xg, _ = grouped_channel_pad(x, g, p.Cb)
    # a pool with stride > window skips trailing conv rows, so the pooled
    # row plan may read fewer rows than the conv extent — crop, then pad
    used_h = min(H, p.Hp - p.ph_pad)
    xg = xg[:, :used_h]
    xg = jnp.pad(xg, ((0, p.Bp - B), (p.ph_pad, p.Hp - used_h - p.ph_pad),
                      (p.ph_pad, p.Wp - W - p.ph_pad), (0, 0)))

    w_tiles = dma.resolve_slab(w, w_packed, p.weights,
                               lambda w: pack_weights(w, p))
    bias = jnp.zeros((p.Kfull,), x.dtype) if b is None else b
    bg = bias.reshape(g * p.nkb, p.Kb)

    single = p.weights.n_tiles == 1
    row_par = bool(row_parallel) and not single
    kernel = functools.partial(_conv2d_fused_kernel, relu=relu,
                               checksum=p.checksum, lrn=lrn,
                               pool=pool, row_step=p.row_step,
                               prefetch=weight_prefetch, single=single,
                               row_parallel=row_par)
    out_specs = [pl.BlockSpec((p.Bb, p.rows_out, p.w_out, p.Kfull),
                              lambda bo, i, k, c, bi: (bo, i, 0, 0))]
    out_shape = [jax.ShapeDtypeStruct(
        (p.Bp, p.npr * p.rows_out, p.w_out, p.Kfull), x.dtype)]
    if p.checksum:
        # per-(batch, row) ABFT verdict: mismatched checksum lanes seen by
        # that block's weight stream (0 everywhere == clean launch)
        out_specs.append(pl.BlockSpec((1, 1),
                                      lambda bo, i, k, c, bi: (bo, i)))
        out_shape.append(jax.ShapeDtypeStruct((p.Bp // p.Bb, p.npr),
                                              jnp.int32))
    res = pl.pallas_call(
        kernel,
        grid=(p.Bp // p.Bb, p.npr, g * p.nkb, p.ncb, p.Bb),
        in_specs=[
            pl.BlockSpec((p.Bb, p.Hp, p.Wp, p.Cb),
                         lambda bo, i, k, c, bi, nkb=p.nkb, ncb=p.ncb:
                         (bo, 0, 0, (k // nkb) * ncb + c)),
            # tile-packed weights: a single tile rides the BlockSpec
            # pipeline (fetched once, resident); a multi-tile stream stays
            # in ANY space and moves by manual double-buffered DMA
            (dma.single_tile_spec(p.weights) if single
             else pl.BlockSpec(memory_space=pltpu.ANY)),
            pl.BlockSpec((1, p.Kb), lambda bo, i, k, c, bi: (k, 0)),
            pl.BlockSpec((t.n, t.n), lambda bo, i, k, c, bi: (0, 0)),
            pl.BlockSpec((t.m, t.n), lambda bo, i, k, c, bi: (0, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((p.Bb, t.n, t.n, p.Rt, p.tw, p.Kb), jnp.float32),
            pltpu.VMEM((p.Bb, p.Rt * mm, p.tw * mm, p.Kfull), jnp.float32),
            *dma.weight_dma_scratch(p.weights, w_tiles.dtype,
                                    single=single),
        ],
        compiler_params=tpu_compiler_params(
            *dma.grid_semantics(single, row_par)),
        interpret=interpret,
    )(xg, w_tiles, bg, jnp.asarray(t.BT, jnp.float32),
      jnp.asarray(t.AT, jnp.float32))

    out = res[0]
    if pool is not None:
        y = out[:B, :p.ph_out]
    else:
        y = out[:B, :p.out_h, :p.out_w]
    return (y, jnp.sum(res[1])) if p.checksum else y


@functools.partial(jax.jit, static_argnames=("m", "padding", "relu", "groups",
                                             "lrn", "pool", "row_block",
                                             "c_block", "k_block",
                                             "pool_row_block", "batch_block",
                                             "weight_prefetch", "row_parallel",
                                             "checksum", "interpret"))
def conv2d_winograd(x, w, b=None, w_packed=None, *, m: int = 4,
                    padding: str = "SAME", relu: bool = False,
                    groups: int = 1, lrn=None, pool=None, row_block: int = 8,
                    pool_row_block: int | None = None,
                    c_block: int | None = None, k_block: int = 128,
                    batch_block: int = 8, weight_prefetch: bool = True,
                    row_parallel: bool = False, checksum: bool = False,
                    interpret: bool = True):
    """x (B,H,W,C); w (r,r,C//groups,K); stride-1 conv via F(m,r) x F(m,r).

    Fused pipeline: raw (halo-padded) feature map slabs stream HBM->VMEM via
    the grid pipeline; tiles, transforms, Winograd GEMMs, channel-block
    accumulation, and the bias+ReLU epilogue all happen in-kernel.  Groups
    fold into the K grid dimension on a group-major channel layout.

    Filter cache + prefetch (paper §3.5): ``batch_block`` images ride the
    innermost grid dimension with the weight tile constant, so each
    transformed filter tile is fetched once per ``batch_block`` images; the
    fetch itself is a manual 2-slot double-buffered async copy — the next
    tile's DMA is in flight while this tile's GEMMs run
    (``weight_prefetch=True``; ``False`` runs the same copies synchronously,
    bit-equal but exposed).  Pass ``w_packed`` — ``pack_weights(w, plan)``
    staged while the previous layer computed — to skip in-trace packing.

    Layer fusion (paper §3.5): with ``lrn`` (an LrnParams-like object) and/or
    ``pool`` ((window, stride)) the cross-channel LRN and VALID max-pool run
    in the kernel epilogue too — the grid is restructured so each row step
    owns a pooled output region (``_conv2d_fused_call``), the k loop
    deposits all g*K channel blocks into a full-channel VMEM scratch (LRN is
    cross-channel, spanning group seams), and only the pooled, normalized
    feature map is ever written to HBM.

    Stream-buffer residency (paper §3.5): like the DLA — whose stream
    buffers hold whole AlexNet feature-map planes in M20K — one full
    (Hp, Wp, c_block) image plane is VMEM-resident per image slot;
    ``c_block=None`` auto-sizes the channel block so the slab fits the VMEM
    budget (AlexNet layers get all of C resident — no slab re-fetch over the
    channel-block reduction), and ``row_block`` tiles the *compute*
    (tiles/scratch), not input residency (see ``conv2d_hbm_bytes``).

    ABFT (``checksum=True``): the packed slab carries one extra bit-pattern
    checksum row per tile (``dma.append_checksum_row``); the kernel verifies
    each resident tile after the DMA slot swap and the call returns
    ``(y, verdict)`` — verdict 0 means every tile streamed intact, > 0
    counts mismatched checksum lanes.  The GEMMs consume the same Cb rows
    either way, so a clean armed launch is bit-identical to unarmed.
    """
    r = w.shape[0]
    t = winograd_transform(m, r)
    p = plan(x.shape, w.shape, m=m, padding=padding, groups=groups,
             lrn=lrn, pool=pool, row_block=row_block,
             pool_row_block=pool_row_block, c_block=c_block,
             k_block=k_block, batch_block=batch_block, checksum=checksum)
    if p.fused:
        return _conv2d_fused_call(x, w, b, w_packed, t=t, p=p, relu=relu,
                                  lrn=lrn, pool=pool,
                                  weight_prefetch=weight_prefetch,
                                  row_parallel=row_parallel,
                                  interpret=interpret)
    B, H, W, _ = x.shape
    g = p.g

    # group-major channel layout, raw zero-pad only — no tile gather
    xg, _ = grouped_channel_pad(x, g, p.Cb)
    xg = jnp.pad(xg, ((0, p.Bp - B), (p.ph_pad, p.Hp - H - p.ph_pad),
                      (p.ph_pad, p.Wp - W - p.ph_pad), (0, 0)))

    w_tiles = dma.resolve_slab(w, w_packed, p.weights,
                               lambda w: pack_weights(w, p))
    bias = jnp.zeros((g * p.K,), x.dtype) if b is None else b
    bg = bias.reshape(g, p.K)
    if p.Kp > p.K:
        bg = jnp.pad(bg, ((0, 0), (0, p.Kp - p.K)))
    bg = bg.reshape(g * p.nkb, p.Kb)

    single = p.weights.n_tiles == 1
    row_par = bool(row_parallel) and not single
    kernel = functools.partial(_conv2d_kernel, relu=relu,
                               checksum=p.checksum,
                               prefetch=weight_prefetch, single=single,
                               row_parallel=row_par)
    out_specs = [pl.BlockSpec((p.Bb, p.Rt * t.m, p.tw * t.m, p.Kb),
                              lambda bo, i, k, c, bi: (bo, i, 0, k))]
    out_shape = [jax.ShapeDtypeStruct(
        (p.Bp, p.thp * t.m, p.tw * t.m, g * p.Kp), x.dtype)]
    if p.checksum:
        out_specs.append(pl.BlockSpec((1, 1),
                                      lambda bo, i, k, c, bi: (bo, i)))
        out_shape.append(jax.ShapeDtypeStruct((p.Bp // p.Bb, p.npr),
                                              jnp.int32))
    res = pl.pallas_call(
        kernel,
        grid=(p.Bp // p.Bb, p.npr, g * p.nkb, p.ncb, p.Bb),
        in_specs=[
            pl.BlockSpec((p.Bb, p.Hp, p.Wp, p.Cb),
                         lambda bo, i, k, c, bi, nkb=p.nkb, ncb=p.ncb:
                         (bo, 0, 0, (k // nkb) * ncb + c)),
            # tile-packed weights: a single tile rides the BlockSpec
            # pipeline (fetched once, resident); a multi-tile stream stays
            # in ANY space and moves by manual double-buffered DMA
            (dma.single_tile_spec(p.weights) if single
             else pl.BlockSpec(memory_space=pltpu.ANY)),
            pl.BlockSpec((1, p.Kb), lambda bo, i, k, c, bi: (k, 0)),
            pl.BlockSpec((t.n, t.n), lambda bo, i, k, c, bi: (0, 0)),
            pl.BlockSpec((t.m, t.n), lambda bo, i, k, c, bi: (0, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((p.Bb, t.n, t.n, p.Rt, p.tw, p.Kb), jnp.float32),
            *dma.weight_dma_scratch(p.weights, w_tiles.dtype,
                                    single=single),
        ],
        compiler_params=tpu_compiler_params(
            *dma.grid_semantics(single, row_par)),
        interpret=interpret,
    )(xg, w_tiles, bg, jnp.asarray(t.BT, jnp.float32),
      jnp.asarray(t.AT, jnp.float32))

    y = res[0][:B, :p.out_h, :p.out_w]
    if p.Kp > p.K:
        y = y.reshape(B, p.out_h, p.out_w, g, p.Kp)[..., :p.K]
        y = y.reshape(B, p.out_h, p.out_w, g * p.K)
    return (y, jnp.sum(res[1])) if p.checksum else y
