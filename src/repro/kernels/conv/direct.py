"""Stream-buffered Pallas direct conv kernel — the paper's non-Winograd
first-layer datapath (§3.3, §3.5), generalized.

The DLA runs AlexNet's 11x11 stride-4 conv1 through the *same* stream-
buffer pipeline as the Winograd layers: the PE array is fed raw feature-map
slabs from on-chip buffers and the filters come from the filter cache —
no layer ever detours through external memory just because its geometry is
not F(4,3)-shaped.  This kernel is that datapath on TPU: arbitrary kernel
size, stride, groups, and SAME/VALID padding, with the identical fused
bias + ReLU + cross-channel-LRN + max-pool epilogue (``epilogue.py``,
shared with the Winograd kernel) and the identical
(B/Bb, row blocks, g*K blocks, C blocks, Bb) filter-cache grid.

Compute shape: the conv is phrased as r GEMMs per grid step — for each
filter row ``di`` the r width-taps are stacked into the contraction dim, so
the MXU sees (rows*cols, r*Cb) @ (r*Cb, Kb) — rather than r^2 scalar-tap
multiplies (PipeCNN's flattened-window trick, MXU-shaped like the Winograd
formulation's n^2 GEMMs).

Weight path (§3.5 filter prefetch, shared machinery in ``dma.py``): the
filters arrive *tile-packed* in an ANY/HBM-space ref and move by explicit
``pltpu.make_async_copy`` into a 2-slot VMEM scratch — at each (k, c) tile
transition the next tile's copy is issued before this step's GEMMs and the
only wait is the slot swap, so the weight stream is double-buffered under
MXU compute.  ``pack_weights``/``weight_plan`` expose the packing as a pure
function of shapes so a model can stage layer N+1's slab while layer N
computes (``nn/conv.py::pack_conv_weights``).

Dataflow per grid step (image slot ``bi`` of the ``batch_block`` in
flight):

* the halo-padded input plane (Bb, Hp, Wp, Cb) is VMEM-resident; the step
  slices its ``in_rows = s*(Rc-1)+r`` raw rows with stride-s strided
  slices (no im2col tensor in HBM),
* channel blocks accumulate into a per-image VMEM scratch
  (``acc_ref[bi]``, the PE daisy-chain),
* the last c block deposits bias+ReLU'd channels into the full-channel
  ``y_ref[bi]`` scratch, and the last (k, c) step runs LRN + pool in VMEM
  and writes only the pooled map (§3.5 — the conv-resolution feature map
  never reaches HBM).

With ``pool`` set, each row step owns ``Pb`` pooled rows: it computes the
``Rc = ps*(Pb-1)+pwin`` conv rows those need but advances only
``s*ps*Pb`` input rows, keeping the pool's output-side halo in VMEM (the
direct analogue of the Winograd kernel's tile-aligned pooled-row blocks —
no tile-alignment constraint here, since rows are computed directly).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...core.winograd import auto_pool_rows
from ..compat import tpu_compiler_params
from . import dma
from .epilogue import batch_blocks, channel_blocks, fused_epilogue, \
    grouped_channel_pad, k_blocks


def same_pad(extent: int, r: int, stride: int) -> tuple[int, int, int]:
    """(out, pad_lo, pad_hi) for SAME padding, matching lax.conv semantics."""
    out = -(-extent // stride)
    total = max((out - 1) * stride + r - extent, 0)
    return out, total // 2, total - total // 2


@dataclass(frozen=True)
class DirectPlan:
    """Host-side launch plan: every derived extent of one kernel call.

    Pure function of shapes + static params (``plan``), so the weight
    packing (``pack_weights``) can run ahead of the input tensor — the
    cross-layer staging hook.
    """
    r: int
    s: int
    g: int
    C: int                  # channels per group
    K: int                  # out channels per group
    out_h: int
    out_w: int
    ph_lo: int
    pw_lo: int
    ph_out: int             # pooled output rows (== out_h when no pool)
    pw_out: int
    Rc: int                 # conv rows each row step computes
    step_in: int            # input rows advanced per row step
    in_rows: int            # raw rows read per step (with halo)
    npr: int                # row steps
    rows_out: int
    w_out: int
    Hp: int
    Wp: int
    Bb: int
    Bp: int
    Cb: int
    Cp: int
    ncb: int
    Kb: int
    nkb: int
    checksum: bool = False  # ABFT checksum row on every weight tile

    @property
    def Kfull(self) -> int:
        return self.g * self.K

    @property
    def weights(self) -> dma.WeightPlan:
        return dma.WeightPlan(g=self.g, nkb=self.nkb, ncb=self.ncb,
                              Cb=self.Cb, Kb=self.Kb,
                              spatial=(self.r, self.r),
                              checksum=self.checksum)


def plan(x_shape, w_shape, *, stride: int = 1, padding: str = "SAME",
         pool=None, groups: int = 1, row_block: int = 8,
         pool_row_block: int | None = None, c_block: int | None = None,
         k_block: int = 128, batch_block: int = 8,
         checksum: bool = False) -> DirectPlan:
    """Derive the full launch plan from shapes + static params."""
    r, s, g = w_shape[0], stride, groups
    assert w_shape[0] == w_shape[1], "square filters only"
    B, H, W, Ct = x_shape
    Kt = w_shape[-1]
    assert Ct % g == 0 and Kt % g == 0 and w_shape[2] == Ct // g, (
        "grouped conv shape mismatch")
    C, K = Ct // g, Kt // g
    if padding == "SAME":
        out_h, ph_lo, _ = same_pad(H, r, s)
        out_w, pw_lo, _ = same_pad(W, r, s)
    else:
        ph_lo = pw_lo = 0
        out_h, out_w = (H - r) // s + 1, (W - r) // s + 1
    assert out_h >= 1 and out_w >= 1, (H, W, r, s, padding)

    Bb, Bp = batch_blocks(B, batch_block)
    if pool is not None:
        pwin, ps = pool
        ph_out = (out_h - pwin) // ps + 1
        pw_out = (out_w - pwin) // ps + 1
        assert ph_out >= 1 and pw_out >= 1, (
            f"pool {pool} larger than conv output {out_h}x{out_w}")
        if pool_row_block is None:
            # own the whole pooled extent when the epilogue scratch fits —
            # one row step, so grouped layers never re-fetch their slab
            Pb = auto_pool_rows(ph_out, pwin, ps, cols=out_w, kfull=g * K,
                                batch=Bb)
        else:
            Pb = min(pool_row_block, ph_out)
        Rc = ps * (Pb - 1) + pwin               # conv rows each step owns
        step_in = s * ps * Pb                   # input rows advanced per step
        npr = -(-ph_out // Pb)
        rows_out, w_out = Pb, pw_out
    else:
        ph_out, pw_out = out_h, out_w
        Rc = min(row_block, out_h)
        step_in = s * Rc
        npr = -(-out_h // Rc)
        rows_out, w_out = Rc, out_w
    in_rows = s * (Rc - 1) + r                  # raw rows per step (w/ halo)
    Hp = (npr - 1) * step_in + in_rows
    Wp = s * (out_w - 1) + r

    Cb = channel_blocks(C, c_block, Hp, Wp, Bb)
    Cp = C + (-C) % Cb
    Kb = k_blocks(K, k_block)
    return DirectPlan(r=r, s=s, g=g, C=C, K=K, out_h=out_h, out_w=out_w,
                      ph_lo=ph_lo, pw_lo=pw_lo, ph_out=ph_out, pw_out=pw_out,
                      Rc=Rc, step_in=step_in, in_rows=in_rows, npr=npr,
                      rows_out=rows_out, w_out=w_out, Hp=Hp, Wp=Wp,
                      Bb=Bb, Bp=Bp, Cb=Cb, Cp=Cp, ncb=Cp // Cb,
                      Kb=Kb, nkb=K // Kb, checksum=checksum)


def pack_weights(w, p: DirectPlan):
    """(r, r, C, g*K) -> (n_tiles, r, r, Cb, Kb) DMA tile layout."""
    r, g, C, K = p.r, p.g, p.C, p.K
    wg = jnp.moveaxis(w.reshape(r, r, C, g, K), 3, 0)       # (g, r, r, C, K)
    if p.Cp > C:
        wg = jnp.pad(wg, ((0, 0), (0, 0), (0, 0), (0, p.Cp - C), (0, 0)))
    return dma.pack_weight_tiles(wg, p.weights)


def _direct_kernel(x_ref, w_tiles, b_ref, out_ref, *refs, stride: int,
                   relu: bool, checksum: bool, lrn, pool, step_in: int,
                   in_rows: int, prefetch: bool, single: bool,
                   row_parallel: bool):
    if checksum:
        sdc_ref, acc_ref, y_ref, wbuf, sem = refs
    else:
        acc_ref, y_ref, wbuf, sem = refs
    s = stride
    _, Rc, wo, Kb = acc_ref.shape
    ib = pl.program_id(1)
    k = pl.program_id(2)
    nk = pl.num_programs(2)
    c = pl.program_id(3)
    nc = pl.num_programs(3)
    bi = pl.program_id(4)                           # filter-cache image slot
    w = dma.fetch_weight_tile(w_tiles, wbuf, sem, prefetch=prefetch,
                              single=single, row_parallel=row_parallel)
    if checksum:
        # ABFT: verify the resident tile's checksum row, then strip it —
        # the GEMMs consume the same Cb rows as an unarmed launch
        dma.verify_tile_checksum(sdc_ref, w)
        w = w[..., :-1, :]
    w = w.astype(jnp.float32)

    @pl.when(c == 0)
    def _init():
        acc_ref[bi] = jnp.zeros(acc_ref.shape[1:], acc_ref.dtype)

    rows = x_ref[bi, pl.ds(ib * step_in, in_rows)]  # (in_rows, Wp, Cb)
    _, Wp, Cb = rows.shape
    r = w.shape[0]
    acc = jnp.zeros((Rc, wo, Kb), jnp.float32)
    for di in range(r):
        # conv rows hit by filter row di, still at full input width
        sub = jax.lax.slice(rows, (di, 0, 0),
                            (di + s * (Rc - 1) + 1, Wp, Cb), (s, 1, 1))
        # r width-taps stacked into the contraction dim: one
        # (Rc*wo, r*Cb) @ (r*Cb, Kb) MXU GEMM per filter row
        taps = jnp.stack(
            [jax.lax.slice(sub, (0, dj, 0),
                           (Rc, dj + s * (wo - 1) + 1, Cb), (1, s, 1))
             for dj in range(r)], axis=0).astype(jnp.float32)
        acc += jnp.einsum("jrwc,jck->rwk", taps, w[di])
    acc_ref[bi] += acc                              # one scratch RMW per step

    @pl.when(c == nc - 1)
    def _store_kblock():
        y = acc_ref[bi] + b_ref[0].astype(jnp.float32)
        if relu:
            y = jnp.maximum(y, 0.0)
        # channel blocks are group-major contiguous: block k -> offset k*Kb
        y_ref[bi, :, :, pl.ds(k * Kb, Kb)] = y

    @pl.when((c == nc - 1) & (k == nk - 1))
    def _epilogue():
        out_ref[bi] = fused_epilogue(
            y_ref[bi], lrn, pool, out_ref.shape[1],
            out_ref.shape[2]).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("stride", "padding", "relu",
                                             "groups", "lrn", "pool",
                                             "row_block", "pool_row_block",
                                             "c_block", "k_block",
                                             "batch_block", "weight_prefetch",
                                             "row_parallel", "checksum",
                                             "interpret"))
def conv2d_direct(x, w, b=None, w_packed=None, *, stride: int = 1,
                  padding: str = "SAME", relu: bool = False, groups: int = 1,
                  lrn=None, pool=None, row_block: int = 8,
                  pool_row_block: int | None = None,
                  c_block: int | None = None, k_block: int = 128,
                  batch_block: int = 8, weight_prefetch: bool = True,
                  row_parallel: bool = False, checksum: bool = False,
                  interpret: bool = True):
    """x (B,H,W,C); w (r,r,C//groups,K); any r/stride/groups, fused layer.

    Same contract as the Winograd kernel (``winograd.conv2d_winograd``):
    optional bias ``b (K,)``, fused ``relu``, grouped conv on the
    group-major channel layout, and the in-VMEM ``lrn``/``pool`` epilogue —
    so ``nn.conv.dispatch_conv`` can send *any* ConvSpec here and every
    AlexNet layer (conv1's 11x11 stride 4 included) runs fully in-VMEM on
    the ``pallas`` route.

    Weight stream: ``pack_weights(w, plan(...))`` tiles the filters; the
    kernel double-buffers them HBM->VMEM by manual async copy
    (``weight_prefetch=True``; ``False`` runs the same copies synchronously
    — bit-equal, every fetch exposed).  Pass ``w_packed`` (a slab staged by
    ``nn.conv.pack_conv_weights`` while the previous layer computed) to
    skip the in-trace packing.

    ``c_block=None`` auto-sizes the channel block so the whole resident
    (batch_block, Hp, Wp, Cb) input block fits the VMEM slab budget, and
    ``pool_row_block=None`` grows the pooled-row block to the whole pooled
    extent while the epilogue scratch fits — AlexNet layers keep all of C
    resident and (grouped layers included, whose slab block index cycles
    per row block) stream the slab HBM->VMEM once per image.

    ``row_parallel`` restarts the DMA weight stream per row block so the
    row grid dimension runs ``parallel`` instead of ``arbitrary``
    (bit-equal; one extra exposed warmup tile per row block) — the
    row-parallel regime the autotuner searches.

    ABFT (``checksum=True``): the packed slab carries one extra bit-pattern
    checksum row per tile; the kernel verifies each resident tile after the
    DMA slot swap and the call returns ``(y, verdict)`` — verdict 0 means
    every tile streamed intact.  Clean armed output is bit-identical to
    unarmed (the GEMMs read the same Cb rows either way).
    """
    p = plan(x.shape, w.shape, stride=stride, padding=padding, pool=pool,
             groups=groups, row_block=row_block,
             pool_row_block=pool_row_block, c_block=c_block,
             k_block=k_block, batch_block=batch_block, checksum=checksum)
    B, H, W, _ = x.shape
    s, r, g = p.s, p.r, p.g

    xg, _ = grouped_channel_pad(x, g, p.Cb)
    # strided convs can leave trailing rows/cols no output window reads —
    # crop them before padding up to the slab extent; a pool with
    # stride > window additionally skips trailing *conv* rows, so the row
    # plan may read fewer rows than the conv extent (Hp < padded H)
    used_h = min(H, s * (p.out_h - 1) + r - p.ph_lo, p.Hp - p.ph_lo)
    used_w = min(W, s * (p.out_w - 1) + r - p.pw_lo)
    xg = xg[:, :used_h, :used_w]
    xg = jnp.pad(xg, ((0, p.Bp - B), (p.ph_lo, p.Hp - used_h - p.ph_lo),
                      (p.pw_lo, p.Wp - used_w - p.pw_lo), (0, 0)))
    w_tiles = dma.resolve_slab(w, w_packed, p.weights,
                               lambda w: pack_weights(w, p))
    bias = jnp.zeros((p.Kfull,), x.dtype) if b is None else b
    bg = bias.reshape(g * p.nkb, p.Kb)

    single = p.weights.n_tiles == 1
    row_par = bool(row_parallel) and not single
    kernel = functools.partial(_direct_kernel, stride=s, relu=relu,
                               checksum=p.checksum, lrn=lrn,
                               pool=pool, step_in=p.step_in,
                               in_rows=p.in_rows, prefetch=weight_prefetch,
                               single=single, row_parallel=row_par)
    out_specs = [pl.BlockSpec((p.Bb, p.rows_out, p.w_out, p.Kfull),
                              lambda bo, i, k, c, bi: (bo, i, 0, 0))]
    out_shape = [jax.ShapeDtypeStruct(
        (p.Bp, p.npr * p.rows_out, p.w_out, p.Kfull), x.dtype)]
    if p.checksum:
        # per-(batch, row) ABFT verdict (0 everywhere == clean launch)
        out_specs.append(pl.BlockSpec((1, 1),
                                      lambda bo, i, k, c, bi: (bo, i)))
        out_shape.append(jax.ShapeDtypeStruct((p.Bp // p.Bb, p.npr),
                                              jnp.int32))
    res = pl.pallas_call(
        kernel,
        grid=(p.Bp // p.Bb, p.npr, g * p.nkb, p.ncb, p.Bb),
        in_specs=[
            pl.BlockSpec((p.Bb, p.Hp, p.Wp, p.Cb),
                         lambda bo, i, k, c, bi, nkb=p.nkb, ncb=p.ncb:
                         (bo, 0, 0, (k // nkb) * ncb + c)),
            # tile-packed weights: a single tile rides the BlockSpec
            # pipeline (fetched once, resident); a multi-tile stream stays
            # in ANY space and moves by manual double-buffered DMA
            (dma.single_tile_spec(p.weights) if single
             else pl.BlockSpec(memory_space=pltpu.ANY)),
            pl.BlockSpec((1, p.Kb), lambda bo, i, k, c, bi: (k, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((p.Bb, p.Rc, p.out_w, p.Kb), jnp.float32),
            pltpu.VMEM((p.Bb, p.Rc, p.out_w, p.Kfull), jnp.float32),
            *dma.weight_dma_scratch(p.weights, w_tiles.dtype,
                                    single=single),
        ],
        compiler_params=tpu_compiler_params(
            *dma.grid_semantics(single, row_par)),
        interpret=interpret,
    )(xg, w_tiles, bg)

    out = res[0]
    y = out[:B, :p.ph_out] if pool is not None else out[:B, :p.out_h]
    return (y, jnp.sum(res[1])) if p.checksum else y
