"""Shared in-kernel layer-epilogue machinery for the fused conv kernels.

Both Pallas conv kernels — the Winograd-domain kernel (``winograd.py``) and
the strided direct kernel (``direct.py``) — end the same way (paper §3.5):
per-K-block bias+ReLU results are deposited into a full-channel VMEM
scratch, and the very last (k, c) grid step runs the cross-channel LRN and
VALID max-pool entirely in VMEM before writing only the pooled, normalized
feature map to HBM.  This module is that shared tail — the epilogue math
exists exactly once — plus the host-side channel/batch block helpers both
``pallas_call`` setups use.

Everything here runs *inside* a kernel (on VMEM-resident arrays) except the
``*_blocks`` helpers, which are host-side setup.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core.winograd import auto_c_block


# ---------------------------------------------------------------------------
# in-kernel epilogue stages
# ---------------------------------------------------------------------------
def lrn_banded(yf, lrn):
    """Cross-channel LRN on a VMEM-resident (rows, cols, K) f32 slab.

    The squared-sum over the +/- n//2 channel window is phrased as one
    (rows*cols, K) @ (K, K) banded matmul — MXU-shaped, like the conv GEMMs
    themselves — instead of a K-step reduce loop.
    """
    Kf = yf.shape[-1]
    half = lrn.n // 2
    ci = jax.lax.broadcasted_iota(jnp.int32, (Kf, Kf), 0)
    cj = jax.lax.broadcasted_iota(jnp.int32, (Kf, Kf), 1)
    band = (jnp.abs(ci - cj) <= half).astype(jnp.float32)
    win = jax.lax.dot_general(
        (yf * yf).reshape(-1, Kf), band, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).reshape(yf.shape)
    return yf / jnp.power(lrn.k + lrn.alpha / lrn.n * win, lrn.beta)


def maxpool_strided(yf, pool, pr: int, pw: int):
    """VALID max-pool of (rows, cols, K) via window**2 strided slices."""
    pwin, ps = pool
    Kf = yf.shape[-1]
    yp = None
    for di in range(pwin):
        for dj in range(pwin):
            sl = jax.lax.slice(
                yf, (di, dj, 0),
                (di + ps * (pr - 1) + 1, dj + ps * (pw - 1) + 1, Kf),
                (ps, ps, 1))
            yp = sl if yp is None else jnp.maximum(yp, sl)
    return yp


def fused_epilogue(yf, lrn, pool, pr: int, pw: int):
    """LRN (or None) then max-pool (or None) on the full-channel VMEM slab.

    ``yf`` is (rows, cols, K) f32 with rows >= the rows this grid step owns;
    returns the (pr, pw, K) block to write (pool) or the first ``pr`` rows
    (no pool — trailing rows belong to the next step or are padding).
    """
    if lrn is not None:
        yf = lrn_banded(yf, lrn)
    if pool is not None:
        return maxpool_strided(yf, pool, pr, pw)
    return yf[:pr]


# ---------------------------------------------------------------------------
# host-side block helpers shared by both pallas_call setups
# ---------------------------------------------------------------------------
def channel_blocks(C: int, c_block: int | None, hp: int, wp: int,
                   batch: int = 1, *, dtype_bytes: int = 4) -> int:
    """Channel block size: explicit, or auto-sized so the whole resident
    (batch, hp, wp, Cb) input block fits the VMEM slab budget."""
    if c_block is None:
        return auto_c_block(hp, wp, C, batch=batch, dtype_bytes=dtype_bytes)
    return min(c_block, C)


def k_blocks(K: int, k_block: int) -> int:
    """Output-channel block.  Blocks must tile K *exactly*: zero-pad channels
    inside an LRN window would shadow the real cross-seam neighbours, so a
    non-dividing ``k_block`` widens to K."""
    Kb = min(k_block, K)
    return K if K % Kb else Kb


def batch_blocks(B: int, batch_block: int) -> tuple[int, int]:
    """(Bb, Bp): filter-cache depth and the zero-padded batch extent.

    ``Bb`` images ride in the innermost grid dimension with the weight-block
    index held constant, so each weight tile streams HBM->VMEM once per
    ``Bb`` images — the paper's §3.5 filter cache (weights reused across the
    batch) rather than once per image.
    """
    Bb = max(1, min(batch_block, B))
    return Bb, -(-B // Bb) * Bb


def grouped_channel_pad(x, g: int, Cb: int):
    """(B,H,W,g*C) -> (B,H,W,g*Cp) with each group's channels zero-padded to
    a ``Cb`` multiple (group-major layout, so the kernel's channel-block
    index ``(k // nkb) * ncb + c`` lands on the right group)."""
    B, H, W, Ct = x.shape
    C = Ct // g
    padc = (-C) % Cb
    if not padc:
        return x, C
    x5 = x.reshape(B, H, W, g, C)
    x5 = jnp.pad(x5, ((0, 0), (0, 0), (0, 0), (0, 0), (0, padc)))
    return x5.reshape(B, H, W, g * (C + padc)), C
