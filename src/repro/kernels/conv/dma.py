"""Manual-DMA double-buffered weight pipeline shared by both conv kernels.

Paper §3.5: "filters for the next convolution layer are prefetched while the
current layer is computed" — the DLA's filter cache is fed by a dedicated
data mover that runs *ahead* of the PE array, so the PEs never stall on a
weight fetch.  PR-4's filter-cache grid already reused a weight tile across
``batch_block`` images, but every weight-tile *transition* was still a
synchronous Pallas pipeline fetch serialized against the GEMMs.  This module
replaces that with the DLA's scheme at both levels:

In-kernel (this module + ``winograd.py``/``direct.py``): weights enter the
kernel as a *tile-packed* array left in HBM/ANY memory space — no BlockSpec
pipelining — and move via explicit ``pltpu.make_async_copy`` into a 2-slot
VMEM scratch.  At each tile transition the copy for the *next* tile is
issued into the spare slot before the current step's GEMMs run, and a
transition only ever waits on the copy issued one transition earlier — the
slot swap.  The filter stream is therefore fully double-buffered under MXU
compute; with ``prefetch=False`` the same DMA runs start+wait synchronously
at each transition (the exposed baseline the benchmarks compare against).
Both modes move identical bytes to identical slots, so outputs are
bit-equal (``tests/test_fused_pipeline.py``).

Cross-layer (``WeightStager`` + ``nn/conv.py::pack_conv_weights`` +
``models/alexnet.py``): the host-side packing — Winograd filter transform,
group/channel blocking, tile layout, optional §3.6 BFP quantization — is a
pure function of the layer spec and input *shape*, so layer N+1's slab can
be staged (async-dispatched and cached) while layer N computes.

Tile order contract: tile ``lin = k * ncb + c`` for grid indices
``k in [0, g*nkb)`` (group-major K blocks) and ``c in [0, ncb)`` — exactly
the (k, c) loop order of the shared
``(B/Bb, row blocks, g*K blocks, C blocks, Bb)`` kernel grid, so the
stream advances one tile per (k, c) step and wraps to tile 0 when the row
block (or batch block) increments.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..compat import ARBITRARY, PARALLEL


@dataclass(frozen=True)
class WeightPlan:
    """Blocking of one layer's weight slab into DMA tiles.

    ``spatial`` is the per-tile filter extent — ``(n, n)`` Winograd-domain
    or ``(r, r)`` direct.  The packed array is
    ``(n_tiles, *spatial, Cb, Kb)`` with tile ``lin = k * ncb + c``.

    ``checksum`` arms the ABFT weight stream: every tile carries one extra
    ``Cb`` row holding the bit-pattern column checksum of the rows above it
    (:func:`append_checksum_row`), so ``tile_shape`` grows to
    ``(*spatial, Cb + 1, Kb)`` and the kernels can verify each resident
    tile after the DMA slot swap (:func:`verify_tile_checksum`).
    """
    g: int                  # groups
    nkb: int                # K blocks per group
    ncb: int                # C blocks
    Cb: int                 # channel block
    Kb: int                 # output-channel block
    spatial: tuple          # per-tile filter dims
    checksum: bool = False  # ABFT checksum row appended to every tile

    @property
    def n_tiles(self) -> int:
        return self.g * self.nkb * self.ncb

    @property
    def tile_shape(self) -> tuple:
        return (*self.spatial, self.Cb + (1 if self.checksum else 0),
                self.Kb)


def pack_weight_tiles(wg, plan: WeightPlan):
    """(g, *spatial, ncb*Cb, nkb*Kb) blocked weights -> (n_tiles, *tile).

    The (g, kb, cb) tile index must match the kernel grid's weight walk —
    group ``k // nkb``, K block ``k % nkb``, C block ``c`` — so the packed
    order is (g, nkb, ncb): ``lin = k * ncb + c``.
    """
    g, ncb, Cb, nkb, Kb = plan.g, plan.ncb, plan.Cb, plan.nkb, plan.Kb
    ns = len(plan.spatial)
    assert wg.shape == (g, *plan.spatial, ncb * Cb, nkb * Kb), (
        wg.shape, plan)
    w7 = wg.reshape(g, *plan.spatial, ncb, Cb, nkb, Kb)
    # (g, *spatial, ncb, Cb, nkb, Kb) -> (g, nkb, ncb, *spatial, Cb, Kb)
    perm = (0, ns + 3, ns + 1, *range(1, ns + 1), ns + 2, ns + 4)
    tiles = w7.transpose(perm).reshape(plan.n_tiles, *plan.spatial, Cb, Kb)
    if plan.checksum:
        tiles = append_checksum_row(tiles)
    assert tiles.shape == (plan.n_tiles, *plan.tile_shape)
    return tiles


# ---------------------------------------------------------------------------
# ABFT tile checksums (SDC defense)
# ---------------------------------------------------------------------------
# Checksums are computed over the *bit patterns* of the packed tile, not its
# float values: bitcast each lane to a same-width integer and take the
# wraparound column sum (mod 2**width) along the Cb axis.  A float sum
# cannot guarantee detection of a low-mantissa-bit flip (the delta is
# absorbed by rounding); an integer wraparound sum changes by exactly
# +/- 2**k mod 2**width != 0 for any single flipped bit, so every 1-bit
# corruption anywhere in the tile — weight rows, zero padding, or the
# checksum row itself — is detected, with zero false positives on clean
# data (integer addition is exact and order-independent).
_CHECKSUM_INT = {4: jnp.int32, 2: jnp.int16}


def checksum_int_dtype(dtype):
    """Same-width integer dtype the ABFT checksum runs in."""
    return _CHECKSUM_INT[jnp.dtype(dtype).itemsize]


def tile_checksum(tiles):
    """Bit-pattern column checksum of ``(..., Cb, Kb)`` tiles: bitcast to
    same-width int, wraparound-sum along the Cb axis (sub-32-bit dtypes
    accumulate in int32 and truncate back — consistent at pack and verify
    time, so the comparison is exact)."""
    itype = checksum_int_dtype(tiles.dtype)
    bits = jax.lax.bitcast_convert_type(tiles, itype)
    return jnp.sum(bits.astype(jnp.int32), axis=-2,
                   dtype=jnp.int32).astype(itype)


def append_checksum_row(tiles):
    """Append the checksum as one extra Cb row, bitcast back into the tile
    dtype so the slab stays a single homogeneous array for DMA (the GEMMs
    never read it — kernels slice ``[..., :-1, :]``)."""
    row = tile_checksum(tiles)[..., None, :]
    row = jax.lax.bitcast_convert_type(row, tiles.dtype)
    return jnp.concatenate([tiles, row], axis=-2)


def checksum_mismatches(tile):
    """int32 count of checksum lanes disagreeing with a recomputed sum in
    one ``(..., Cb + 1, Kb)`` checksummed tile (0 == intact)."""
    itype = checksum_int_dtype(tile.dtype)
    want = jax.lax.bitcast_convert_type(tile[..., -1:, :], itype)
    got = tile_checksum(tile[..., :-1, :])[..., None, :]
    return jnp.sum((want != got).astype(jnp.int32), dtype=jnp.int32)


def verify_tile_checksum(sdc_ref, tile):
    """Accumulate the resident tile's checksum mismatches into the
    per-(batch, row) corruption-verdict ref on the shared conv grid.

    Runs once per weight tile (first image slot only), off the GEMM
    critical path — one bitcast + integer reduction per (k, c) transition.
    The verdict block is initialised on the first tile of each (batch,
    row) block, so the output is total mismatched checksum lanes seen by
    that block's weight stream (0 == clean launch).
    """
    k, c, bi = pl.program_id(2), pl.program_id(3), pl.program_id(4)

    @pl.when((k == 0) & (c == 0) & (bi == 0))
    def _init():
        sdc_ref[0, 0] = 0

    @pl.when(bi == 0)
    def _count():
        sdc_ref[0, 0] += checksum_mismatches(tile)


def weight_dma_scratch(plan: WeightPlan, dtype, *, single: bool = False):
    """The two scratch allocations the 2-slot pipeline needs, in the order
    the kernels append them: (2-slot VMEM tile buffer, 2 DMA semaphores).
    Single-tile mode keeps the kernel signature (the BlockSpec path never
    touches either) but shrinks the buffer to a degenerate element — a
    full 2-slot copy of the whole resident slab would be dead VMEM."""
    shape = (2,) + ((1,) * len(plan.tile_shape) if single
                    else plan.tile_shape)
    return (pltpu.VMEM(shape, dtype), pltpu.SemaphoreType.DMA((2,)))


def single_tile_spec(plan: WeightPlan):
    """BlockSpec for a single-tile weight stream: the one tile rides the
    ordinary Pallas pipeline at a constant block index (fetched once,
    resident for the launch) instead of the manual-DMA path."""
    nd = len(plan.tile_shape) + 1
    return pl.BlockSpec((1, *plan.tile_shape), lambda *_, nd=nd: (0,) * nd)


def resolve_slab(w, w_packed, plan: WeightPlan, pack_fn):
    """The weight slab a kernel launch will stream: the staged array when
    one was handed in, else packed in-trace — with the one shape check
    that keeps a stale slab from ever reaching the DMA (shared by every
    pallas_call site so the contract cannot diverge between kernels)."""
    w_tiles = pack_fn(w) if w_packed is None else w_packed
    assert w_tiles.shape == (plan.n_tiles, *plan.tile_shape), (
        "staged weight slab does not match this call's plan",
        w_tiles.shape, plan)
    return w_tiles


def grid_semantics(single: bool, row_parallel: bool = False):
    """Dimension semantics for the shared (batch, rows, k, c, images) conv
    grid under the DMA weight stream: the stream restarts per batch-outer
    block, so the batch dim is always parallel; the slot state spanning
    the row/k/c walk keeps those dims arbitrary on multi-tile launches —
    unless ``row_parallel`` restarts the stream per *row block* too
    (:func:`stream_positions`), in which case no DMA state crosses row
    steps and the row dim is freed.  A single-tile launch (no slot state
    at all) frees the row dim unconditionally.  The image-slot dim stays
    arbitrary (filter-cache accumulators).
    """
    return (PARALLEL, PARALLEL if (single or row_parallel) else ARBITRARY,
            ARBITRARY, ARBITRARY, ARBITRARY)


def stream_positions(ib, k, c, *, npr: int, nk: int, nc: int,
                     row_restart: bool = False):
    """Weight-stream coordinates of one grid step.

    The stream is self-contained *per batch-outer block*: the transition
    counter restarts at every filter-cache generation, so the batch grid
    dimension carries no cross-block DMA state and can stay ``parallel``
    (each core's slice warms up its own stream; one exposed warmup tile
    per generation instead of per launch).

    ``row_restart`` applies the same restart at every *row block*: the
    transition counter (and with it the slot parity, which always starts
    at slot 0 for a fresh generation — the parity bookkeeping that made
    the global counter necessary when a generation spanned odd-length
    row-block streams) becomes ``k * nc + c``, each row block warms up its
    own tile-0 copy and drains fully by its last transition, so no DMA
    slot state crosses row steps and the row grid dimension can be marked
    ``parallel`` (:func:`grid_semantics`).  Cost: one exposed warmup tile
    per (batch-outer, row) generation instead of per batch-outer block —
    the trade the autotuner measures (``core/autotune.py``).

    Returns ``(trans, lin, lin_next, last)``: the in-generation transition
    counter (slot parity rides this, not ``lin`` — the per-row-block
    stream length ``nk*nc`` may be odd when the generation spans row
    blocks), the current/next tile indices (the stream wraps to tile 0
    when the row block advances), and whether this is the generation's
    final transition (no further copy to issue).
    """
    lin = k * nc + c
    lin_next = jax.lax.rem(lin + 1, nk * nc)
    if row_restart:
        return lin, lin, lin_next, lin + 1 >= nk * nc
    trans = (ib * nk + k) * nc + c
    last = trans + 1 >= npr * nk * nc
    return trans, lin, lin_next, last


def weight_stream_transition(w_tiles, wbuf, sem, *, trans, lin, lin_next,
                             last, prefetch: bool):
    """Run the 2-slot DMA schedule at one weight-tile transition.

    ``prefetch=True`` (double-buffered): the very first transition warms up
    its own copy; every non-final transition issues the *next* tile's copy
    into the spare slot before the caller's GEMMs; the only wait is on the
    copy issued one transition earlier (the slot swap), so steady-state
    fetches overlap MXU compute entirely.  ``prefetch=False`` start+waits
    the same copy synchronously — same bytes, same slots, bit-equal output,
    but every fetch is exposed.  Call under ``pl.when(bi == 0)`` (the first
    image slot of the tile); later image slots read the resident slot.
    """
    slot = jax.lax.rem(trans, 2)
    if prefetch:
        @pl.when(trans == 0)
        def _warmup():
            pltpu.make_async_copy(w_tiles.at[lin], wbuf.at[slot],
                                  sem.at[slot]).start()

        @pl.when(jnp.logical_not(last))
        def _issue_next():
            nxt = jax.lax.rem(trans + 1, 2)
            pltpu.make_async_copy(w_tiles.at[lin_next], wbuf.at[nxt],
                                  sem.at[nxt]).start()

        pltpu.make_async_copy(w_tiles.at[lin], wbuf.at[slot],
                              sem.at[slot]).wait()
    else:
        cp = pltpu.make_async_copy(w_tiles.at[lin], wbuf.at[slot],
                                   sem.at[slot])
        cp.start()
        cp.wait()


def current_slot(trans):
    """VMEM slot holding the resident tile for transition counter ``trans``
    (valid at every image slot of the tile, not just the transition step)."""
    return jax.lax.rem(trans, 2)


def fetch_weight_tile(w_tiles, wbuf, sem, *, prefetch: bool, single: bool,
                      row_parallel: bool = False):
    """Drive the weight stream for one step of the shared
    ``(B/Bb, row blocks, g*K blocks, C blocks, Bb)`` conv grid and return
    the resident (raw-dtype) tile — the whole per-step bookkeeping both
    kernels share: stream coordinates from the grid ids, the 2-slot
    transition on the first image slot of each tile, the slot read
    elsewhere.

    ``single`` (static): the stream has exactly one tile, so there is no
    rotation to drive — the host passed the tile through a constant-index
    BlockSpec instead of the ANY-space ref (``single_tile_spec``), Pallas's
    pipeline fetches it once and keeps it resident (its usual elision for
    an unchanged block index), and the grid keeps its parallel batch/row
    semantics because no DMA slot state spans steps.  ``wbuf``/``sem`` are
    unused in that mode.

    ``row_parallel`` (static): restart the stream per row block
    (``stream_positions(row_restart=True)``) so the row grid dimension can
    run ``parallel`` — same tiles, same slots, bit-equal output, one extra
    exposed warmup tile per row block.
    """
    if single:
        return w_tiles[0]

    trans, lin, lin_next, last = stream_positions(
        pl.program_id(1), pl.program_id(2), pl.program_id(3),
        npr=pl.num_programs(1), nk=pl.num_programs(2),
        nc=pl.num_programs(3), row_restart=row_parallel)

    @pl.when(pl.program_id(4) == 0)
    def _fetch():
        weight_stream_transition(w_tiles, wbuf, sem, trans=trans, lin=lin,
                                 lin_next=lin_next, last=last,
                                 prefetch=prefetch)

    return wbuf[current_slot(trans)]


# ---------------------------------------------------------------------------
# cross-layer staging
# ---------------------------------------------------------------------------
def _has_tracer(tree) -> bool:
    return any(isinstance(leaf, jax.core.Tracer)
               for leaf in jax.tree_util.tree_leaves(tree))


class WeightStager:
    """Cross-layer weight staging: dispatch layer N+1's (pure, jittable)
    weight packing while layer N computes, and cache the packed slab.

    JAX dispatch is asynchronous, so ``stage`` returns immediately — the
    packing work overlaps whatever device work is already queued (the
    current layer's conv).  Keys are caller-chosen (AlexNet uses layer
    names); a stager is bound to one parameter set — reuse it across
    forward passes of the same params (serving) and the slab packs once,
    the host-level twin of the in-kernel filter cache.

    Tracer-safe: under ``jax.jit`` the packed value would be a tracer, so
    staging computes inline and caches nothing (XLA already schedules the
    inlined pack; caching tracers across traces would be unsound).

    ``verify=True`` arms slab-integrity checking on the cache-hit path:
    instead of trusting the cache key, a hit whose value carries a
    pack-time fingerprint (``nn/conv.py::SlabFingerprint``) is re-verified
    — shape, dtype, content crc32, and (when the caller passes ``expect``)
    the pack context the slab was built under.  A mismatch counts in
    ``integrity_failures``, evicts the entry, and repacks through the miss
    path — so a corrupted cached slab, or a stale one reused after the
    layer was repacked under different fusion flags, never reaches a
    kernel.
    """

    def __init__(self, *, verify: bool = False):
        self._cache: dict = {}
        self.hits = 0
        self.misses = 0
        self.verify = verify
        self.integrity_failures = 0

    @staticmethod
    def _intact(val, expect) -> bool:
        """Duck-typed fingerprint check: values without one (plain arrays,
        slabs packed unfingerprinted) have nothing to verify against."""
        fp = getattr(val, "fingerprint", None)
        return fp is None or fp.matches(val, expect=expect)

    def stage(self, key, fn, *args, expect=None, **kwargs):
        """Compute (or recall) ``fn(*args)`` for ``key``; returns the value."""
        if key in self._cache:
            val = self._cache[key]
            if not self.verify or self._intact(val, expect):
                self.hits += 1
                return val
            self.integrity_failures += 1
            del self._cache[key]        # fall through: repack from pristine
        val = fn(*args, **kwargs)
        self.misses += 1
        if key is not None and not _has_tracer((args, kwargs, val)):
            self._cache[key] = val
        return val

    def get(self, key, default=None):
        if key in self._cache:
            self.hits += 1
            return self._cache[key]
        return default

    def clear(self):
        self._cache.clear()
