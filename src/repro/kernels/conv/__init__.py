"""Stream-buffered Pallas conv kernels (paper §3.3/§3.5).

``winograd.py`` — Winograd-domain F(m,r) kernel (stride-1 layers);
``direct.py`` — strided direct kernel (any kernel size / stride / groups,
AlexNet conv1's 11x11 s4 datapath); ``dma.py`` — the manual-DMA
double-buffered weight pipeline (2-slot filter prefetch, tile packing,
cross-layer ``WeightStager``) shared by both kernels; ``epilogue.py`` —
the shared in-VMEM bias/ReLU/LRN/max-pool layer epilogue and block
helpers; ``ops.py`` — the public entry points; ``ref.py`` — the lax
oracles.
"""
from . import dma, direct, epilogue, ops, ref, winograd  # noqa: F401
from .dma import WeightStager  # noqa: F401
