"""Public entry points for the conv kernel family.

Two Pallas datapaths share one fused-layer contract (bias, ReLU, groups,
in-VMEM LRN + max-pool epilogue):

* :func:`conv2d` — the Winograd-domain kernel (``winograd.py``) for
  stride-1 layers; ``pallas=False`` falls back to the differentiable
  pure-jnp Winograd path in ``repro.core.winograd``.
* :func:`conv2d_direct` — the strided direct kernel (``direct.py``) for
  any kernel size / stride / groups (AlexNet conv1's 11x11 stride 4);
  ``pallas=False`` falls back to the ``lax.conv_general_dilated`` oracle.

The depthwise-causal op carries a custom VJP (Pallas kernels have no
autodiff rule): dx is the same Winograd kernel run on the time-reversed
cotangent, so the backward pass also hits the MXU kernel; dw/db are cheap
shifted reductions.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...core import winograd as wg
from . import direct as _d
from . import winograd as _k
from .ref import conv2d_ref


def _interp(interpret):
    return jax.default_backend() != "tpu" if interpret is None else interpret


# ---------------------------------------------------------------------------
# depthwise causal conv1d
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _dw1d(x, w, b, interpret):
    return _k.conv1d_depthwise_causal(x, w, b, interpret=interpret)


def _dw1d_fwd(x, w, b, interpret):
    return _dw1d(x, w, b, interpret), (x, w)


def _dw1d_bwd(interpret, res, dy):
    x, w = res
    r = w.shape[0]
    # dx[s] = sum_k w[k] dy[s + r-1-k]  == reverse(conv(reverse(dy), w))
    dy_rev = dy[:, ::-1, :]
    dx = _k.conv1d_depthwise_causal(dy_rev, w, None,
                                    interpret=interpret)[:, ::-1, :]
    # dw[k] = sum_{b,t} dy[t] * x[t - r + 1 + k]
    xp = jnp.pad(x, ((0, 0), (r - 1, 0), (0, 0)))
    L = x.shape[1]
    dw = jnp.stack([jnp.einsum("blc,blc->c", dy.astype(jnp.float32),
                               xp[:, k:k + L, :].astype(jnp.float32))
                    for k in range(r)], axis=0).astype(w.dtype)
    db = dy.sum(axis=(0, 1)).astype(w.dtype)
    return dx.astype(x.dtype), dw, db


_dw1d.defvjp(_dw1d_fwd, _dw1d_bwd)


def conv1d_depthwise_causal(x, w, b=None, *, pallas: bool = True,
                            interpret: bool | None = None):
    if pallas:
        bb = jnp.zeros((w.shape[1],), w.dtype) if b is None else b
        return _dw1d(x, w, bb, _interp(interpret))
    return wg.conv1d_depthwise_causal(x, w, b)


# ---------------------------------------------------------------------------
# 2D conv (inference path; training uses the differentiable jnp route)
# ---------------------------------------------------------------------------
def conv2d(x, w, b=None, w_packed=None, *, m: int = 4, padding: str = "SAME",
           relu: bool = False, groups: int = 1, lrn=None, pool=None,
           c_block: int | None = None, pool_row_block: int | None = None,
           k_block: int = 128, batch_block: int = 8,
           weight_prefetch: bool = True, row_parallel: bool = False,
           checksum: bool = False, pallas: bool = True,
           interpret: bool | None = None):
    """Fused stride-1 Winograd conv layer: bias, ReLU, groups, LRN, pool.

    Both routes share one signature so they stay numerically
    interchangeable: ``pallas=True`` runs the stream-buffered Pallas kernel
    (in-kernel tiling + channel-block reduction + in-VMEM LRN/pool
    epilogue + filter-cache batch grid + double-buffered manual-DMA weight
    stream), ``pallas=False`` the differentiable pure-jnp Winograd path.
    ``lrn`` is an :class:`repro.nn.pooling.LrnParams` (or None); ``pool``
    is a (window, stride) pair for a VALID max-pool (or None).
    ``w_packed``/``weight_prefetch`` reach the Pallas weight pipeline only
    (the jnp route has no weight stream to stage).

    ``checksum=True`` arms the ABFT weight-stream verification and both
    routes return ``(y, verdict)`` — the jnp route has no DMA stream to
    corrupt, so its verdict is the constant 0 (the contract stays uniform
    for ``nn.conv.dispatch_conv``).
    """
    if pallas:
        return _k.conv2d_winograd(x, w, b, w_packed, m=m, padding=padding,
                                  relu=relu, groups=groups, lrn=lrn,
                                  pool=pool, c_block=c_block,
                                  pool_row_block=pool_row_block,
                                  k_block=k_block,
                                  batch_block=batch_block,
                                  weight_prefetch=weight_prefetch,
                                  row_parallel=row_parallel,
                                  checksum=checksum,
                                  interpret=_interp(interpret))
    y = wg.conv2d_winograd(x, w, b, m=m, padding=padding, relu=relu,
                           groups=groups, lrn=lrn, pool=pool)
    return (y, jnp.zeros((), jnp.int32)) if checksum else y


def conv2d_direct(x, w, b=None, w_packed=None, *, stride: int = 1,
                  padding: str = "SAME", relu: bool = False, groups: int = 1,
                  lrn=None, pool=None, c_block: int | None = None,
                  pool_row_block: int | None = None, k_block: int = 128,
                  batch_block: int = 8,
                  weight_prefetch: bool = True, row_parallel: bool = False,
                  checksum: bool = False, pallas: bool = True,
                  interpret: bool | None = None):
    """Fused direct conv layer for any kernel/stride geometry.

    ``pallas=True`` runs the strided stream-buffered kernel (``direct.py``)
    — AlexNet's conv1/conv2 datapath on the ``pallas`` route;
    ``pallas=False`` is the ``lax.conv_general_dilated`` oracle with the
    same fused-layer signature (``ref.conv2d_ref``).  ``checksum=True``
    returns ``(y, verdict)`` on both routes (constant 0 off-Pallas).
    """
    if pallas:
        return _d.conv2d_direct(x, w, b, w_packed, stride=stride,
                                padding=padding, relu=relu, groups=groups,
                                lrn=lrn, pool=pool, c_block=c_block,
                                pool_row_block=pool_row_block,
                                k_block=k_block,
                                batch_block=batch_block,
                                weight_prefetch=weight_prefetch,
                                row_parallel=row_parallel,
                                checksum=checksum,
                                interpret=_interp(interpret))
    y = conv2d_ref(x, w, b, stride=stride, padding=padding, groups=groups,
                   relu=relu, lrn=lrn, pool=pool)
    return (y, jnp.zeros((), jnp.int32)) if checksum else y
