"""Pure-jnp oracles for the Winograd kernels: direct convolution."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def conv1d_depthwise_causal_ref(x, w, b=None):
    """Direct (shift-multiply) causal depthwise conv; x (B,L,C), w (r,C)."""
    r = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (r - 1, 0), (0, 0)))
    y = sum(xp[:, i:i + x.shape[1], :] * w[i].astype(x.dtype)
            for i in range(r))
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def conv2d_ref(x, w, b=None, *, stride: int = 1, padding: str = "SAME",
               groups: int = 1, relu: bool = False, lrn=None, pool=None):
    """lax direct conv with the fused-layer signature.

    x (B,H,W,C), w (r,r,C//groups,K); optional bias (K,), fused ReLU,
    grouped convolution via ``feature_group_count``, and the layer epilogue
    — cross-channel LRN (``lrn``: LrnParams) then VALID max-pool (``pool``:
    (window, stride)) — the oracle for every route of
    ``repro.nn.conv.dispatch_conv``.
    """
    y = jax.lax.conv_general_dilated(
        x.astype(jnp.float32), w.astype(jnp.float32),
        window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)
    if b is not None:
        y = y + b.astype(y.dtype)
    if relu:
        y = jnp.maximum(y, 0.0)
    if lrn is not None or pool is not None:
        # function-level import: nn.pooling sits above this module in the
        # package graph (nn.conv imports this file at import time)
        from ...nn.pooling import apply_epilogue
        y = apply_epilogue(y, lrn, pool)
    return y.astype(x.dtype)
