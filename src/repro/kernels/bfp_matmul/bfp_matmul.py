"""Pallas TPU kernel: shared-exponent block-FP matmul (paper §3.6).

The paper fractures Arria-10 DSPs into 18x18 integer multipliers by aligning
each operand group to its max exponent.  TPU adaptation: the MXU natively
multiplies int8, so shared-exponent int8 mantissas let the *weight stream*
(the decode/FC-regime bottleneck) move at 1 byte/value — the bandwidth
benefit survives even though bf16 compute is free.

Dataflow per (Mb, Nb) output block: activations are quantized **in-kernel**
per K-block (exponent of the block max — exactly the paper's scheme);
pre-quantized weight mantissas/exponents stream in; each K-block contributes
an int8 x int8 -> int32 MXU matmul rescaled by 2^(ex + ew) into an f32
accumulator.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..compat import ARBITRARY, PARALLEL, tpu_compiler_params

from ...core import bfp


def _bfp_kernel(x_ref, wm_ref, we_ref, out_ref, *, block: int, bits: int):
    x = x_ref[...].astype(jnp.float32)              # (Mb, K)
    Mb, K = x.shape
    KB = K // block
    qmax = float(2 ** (bits - 1) - 1)

    # in-kernel shared-exponent quantization of the activation K-blocks
    xb = x.reshape(Mb, KB, block)
    amax = jnp.max(jnp.abs(xb), axis=-1)            # (Mb, KB)
    e = jnp.where(amax > 0,
                  jnp.floor(jnp.log2(jnp.where(amax > 0, amax, 1.0))) + 1.0,
                  0.0)                              # exponent of max (2^(e-1)<=amax<2^e)
    scale = jnp.exp2((bits - 1.0) - e)
    mx = jnp.clip(jnp.round(xb * scale[..., None]), -qmax, qmax)

    wm = wm_ref[...]                                # (KB, block, Nb) int8
    we = we_ref[...].astype(jnp.float32)            # (KB, Nb)
    Nb = wm.shape[-1]

    def body(kb, acc):
        a = mx[:, kb, :].astype(jnp.int8)           # (Mb, block)
        b = wm[kb]                                  # (block, Nb) int8
        prod = jax.lax.dot_general(
            a, b, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32).astype(jnp.float32)
        s = jnp.exp2(e[:, kb][:, None] + we[kb][None, :]
                     - 2.0 * (bits - 1.0))
        return acc + prod * s

    acc = jax.lax.fori_loop(0, KB, body,
                            jnp.zeros((Mb, Nb), jnp.float32))
    out_ref[...] = acc.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "bits", "m_block",
                                             "n_block", "interpret"))
def bfp_matmul_pallas(x, wm, we, *, block: int = 32, bits: int = 8,
                      m_block: int = 128, n_block: int = 256,
                      interpret: bool = True):
    """x (M,K) f32/bf16; wm (KB,block,N) int8 mantissas; we (KB,N) int8
    exponents (from repro.core.bfp.quantize(w, axis=0)).  -> (M,N) f32."""
    M, K = x.shape
    KB, blk, N = wm.shape
    assert blk == block and KB * block == K, (wm.shape, x.shape)
    Mb = min(m_block, M)
    Nb = min(n_block, N)
    padm, padn = (-M) % Mb, (-N) % Nb
    if padm:
        x = jnp.pad(x, ((0, padm), (0, 0)))
    if padn:
        wm = jnp.pad(wm, ((0, 0), (0, 0), (0, padn)))
        we = jnp.pad(we, ((0, 0), (0, padn)))
    Mp, Np = M + padm, N + padn

    out = pl.pallas_call(
        functools.partial(_bfp_kernel, block=block, bits=bits),
        grid=(Mp // Mb, Np // Nb),
        in_specs=[
            pl.BlockSpec((Mb, K), lambda i, j: (i, 0)),
            pl.BlockSpec((KB, block, Nb), lambda i, j: (0, 0, j)),
            pl.BlockSpec((KB, Nb), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((Mb, Nb), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.float32),
        compiler_params=tpu_compiler_params(PARALLEL, PARALLEL),
        interpret=interpret,
    )(x, wm, we)
    return out[:M, :N]


def quantize_weights(w, *, block: int = 32, bits: int = 8):
    """Host-side weight quantization -> (mantissa (KB,block,N) int8,
    exponent (KB,N) int8).  Done once; decode steps stream 1B/value."""
    m, e, _ = bfp.quantize(w, block=block, bits=bits, axis=0)
    return m, e
