"""Pure-jnp oracle for the BFP matmul kernel — repro.core.bfp.bfp_matmul is
itself pure jnp and bit-matches the kernel's quantize->int-MAC->rescale
semantics; exact-f32 matmul is also provided for error-bound checks."""
from __future__ import annotations

import jax.numpy as jnp

from ...core.bfp import bfp_matmul as bfp_matmul_ref  # noqa: F401


def exact_matmul(x, w):
    return x.astype(jnp.float32) @ w.astype(jnp.float32)
