"""Public entry for the shared-exponent BFP matmul."""
from __future__ import annotations

import jax

from ...core import bfp
from . import bfp_matmul as _k


def bfp_matmul(x, w, *, block: int = 32, bits: int = 8, pallas: bool = True,
               interpret: bool | None = None):
    """(M,K) @ (K,N) in shared-exponent block floating point."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if not pallas:
        return bfp.bfp_matmul(x, w, block=block, bits=bits)
    wm, we = _k.quantize_weights(w, block=block, bits=bits)
    return _k.bfp_matmul_pallas(x, wm, we, block=block, bits=bits,
                                interpret=interpret)
