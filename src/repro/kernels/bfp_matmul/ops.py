"""Public entry for the shared-exponent BFP matmul."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ...core import bfp
from . import bfp_matmul as _k


def bfp_matmul(x, w, *, block: int = 32, bits: int = 8, pallas: bool = True,
               interpret: bool | None = None):
    """(M,K) @ (K,N) in shared-exponent block floating point."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if not pallas:
        return bfp.bfp_matmul(x, w, block=block, bits=bits)
    wm, we = _k.quantize_weights(w, block=block, bits=bits)
    return _k.bfp_matmul_pallas(x, wm, we, block=block, bits=bits,
                                interpret=interpret)


def bfp_linear(x, w, *, block: int = 32):
    """(..., K) @ (K, N) f32 with the weight stream in int8 BFP (§3.6).

    The FC-layer form both weight-bandwidth-bound readouts share
    (``models/alexnet.py::classifier``, ``models/lm.py::_readout``): the
    exponent block must tile the contraction dim, so a non-dividing
    ``block`` shrinks to the gcd (reduced configs have small FC widths;
    32 is the paper-faithful group size).
    """
    k = x.shape[-1]
    y = bfp_matmul(x.reshape(-1, k).astype(jnp.float32),
                   w.astype(jnp.float32), block=math.gcd(k, block))
    return y.reshape(*x.shape[:-1], w.shape[-1])
