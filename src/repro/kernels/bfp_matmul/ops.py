"""Public entry for the shared-exponent BFP matmul."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ...core import bfp
from . import bfp_matmul as _k


def fc_block(k: int, block: int = 32) -> int:
    """The exponent-block size ``bfp_linear`` resolves for contraction dim
    ``k`` — must tile ``k`` exactly, so a non-dividing block shrinks to the
    gcd (reduced configs have small FC widths; 32 is paper-faithful)."""
    return math.gcd(k, block)


def quantize_weights(w, *, block: int = 32, bits: int = 8):
    """Pre-quantize an FC weight stream: (K,N) f32 -> (int8 mantissas,
    per-block exponents).  A pure function of the weights, so a model can
    stage the next layer's quantized stream while the current layer
    computes (§3.5's cross-layer prefetch applied to the §3.6 BFP FC
    path) — pass the pair to :func:`bfp_matmul` / :func:`bfp_linear` as
    ``quantized``."""
    return _k.quantize_weights(w.astype(jnp.float32), block=block, bits=bits)


def bfp_matmul(x, w, *, block: int = 32, bits: int = 8, pallas: bool = True,
               quantized=None, interpret: bool | None = None):
    """(M,K) @ (K,N) in shared-exponent block floating point."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if not pallas:
        return bfp.bfp_matmul(x, w, block=block, bits=bits)
    wm, we = (quantized if quantized is not None
              else _k.quantize_weights(w, block=block, bits=bits))
    return _k.bfp_matmul_pallas(x, wm, we, block=block, bits=bits,
                                interpret=interpret)


def bfp_linear(x, w, *, block: int = 32, quantized=None):
    """(..., K) @ (K, N) f32 with the weight stream in int8 BFP (§3.6).

    The FC-layer form both weight-bandwidth-bound readouts share
    (``models/alexnet.py::classifier``, ``models/lm.py::_readout``): the
    exponent block resolves via :func:`fc_block`.  ``quantized`` is a
    staged ``quantize_weights(w, block=fc_block(K, block))`` pair — the
    quantization is then skipped in-trace (cross-layer weight staging).
    """
    k = x.shape[-1]
    y = bfp_matmul(x.reshape(-1, k).astype(jnp.float32),
                   w.astype(jnp.float32), block=fc_block(k, block),
                   quantized=quantized)
    return y.reshape(*x.shape[:-1], w.shape[-1])
