"""Pure-jnp oracle for the SSD kernel: token-by-token recurrence.

h_t = h_{t-1} * exp(dt_t * A) + B_t^T (dt_t x_t);   y_t = C_t h_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_reference(x, dt, A, B_, C_):
    """x (B,L,H,P); dt (B,L,H); A (H,); B_,C_ (B,L,G,N) ->
    (y (B,L,H,P), final_state (B,H,N,P)).  O(L) sequential scan."""
    Bb, L, H, P = x.shape
    G, N = B_.shape[2], B_.shape[3]
    Hg = H // G

    def head_group(h):
        return h // Hg

    gmap = jnp.arange(H) // Hg

    def step(h_state, inp):
        xt, dtt, bt, ct = inp                       # (H,P),(H,),(G,N),(G,N)
        dA = jnp.exp(dtt * A)                       # (H,)
        bh = bt[gmap]                               # (H,N)
        ch = ct[gmap]
        h_state = h_state * dA[:, None, None] + \
            jnp.einsum("hn,hp->hnp", bh, dtt[:, None] * xt)
        y = jnp.einsum("hn,hnp->hp", ch, h_state)
        return h_state, y

    def per_batch(xb, dtb, bb, cb):
        h0 = jnp.zeros((H, N, P), jnp.float32)
        hT, ys = jax.lax.scan(step, h0,
                              (xb.astype(jnp.float32),
                               dtb.astype(jnp.float32),
                               bb.astype(jnp.float32),
                               cb.astype(jnp.float32)))
        return ys, hT

    ys, hT = jax.vmap(per_batch)(x, dt, B_, C_)
    return ys.astype(x.dtype), hT
