"""Pallas TPU kernel for the Mamba-2 SSD chunked scan.

Stream-buffer dataflow (paper §3.5 adapted): one (Q, P) chunk of tokens per
head is the VMEM working set; the (N, P) recurrent state lives in VMEM
scratch and persists across the sequential chunk dimension of the grid, so
HBM traffic is exactly one read of the inputs and one write of the outputs —
the SSM analogue of "all intermediate feature maps stay on chip".

Grid: (B, H, nc); (B, H) are PARALLEL, nc is ARBITRARY (sequential, carries
the state).  Intra-chunk work is two MXU matmuls (C·Bᵀ and M·x) plus the
state update/emission matmuls.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..compat import ARBITRARY, PARALLEL, tpu_compiler_params


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref,
                state_scratch, *, nc: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scratch[...] = jnp.zeros_like(state_scratch)

    x = x_ref[0, 0, 0].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)        # (Q,)
    a = a_ref[0].astype(jnp.float32)                # scalar A_h (negative)
    bm = b_ref[0, 0, 0].astype(jnp.float32)         # (Q, N)
    cm = c_ref[0, 0, 0].astype(jnp.float32)         # (Q, N)
    Q = x.shape[0]

    dta = dt * a                                     # (Q,) <= 0
    cums = jnp.cumsum(dta)                           # (Q,)
    # intra-chunk: M[q,k] = (C_q . B_k) * exp(cums_q - cums_k) * dt_k, k<=q
    cb = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q,Q)
    qi = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    ki = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    dec = jnp.exp(jnp.clip(cums[:, None] - cums[None, :], -60.0, 0.0))
    m = jnp.where(qi >= ki, cb * dec, 0.0) * dt[None, :]
    y = jnp.dot(m, x, preferred_element_type=jnp.float32)          # (Q,P)

    # inter-chunk: y += (C ⊙ exp(cums)) @ state
    state = state_scratch[...]
    c_dec = cm * jnp.exp(jnp.clip(cums, -60.0, 0.0))[:, None]
    y = y + jnp.dot(c_dec, state, preferred_element_type=jnp.float32)

    # state update: state = lam * state + B_decᵀ @ x
    lam = jnp.exp(jnp.clip(cums[-1], -60.0, 0.0))
    b_dec = bm * (jnp.exp(jnp.clip(cums[-1] - cums, -60.0, 0.0)) * dt)[:, None]
    new_state = lam * state + jax.lax.dot_general(
        b_dec, x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)          # (N, P)
    state_scratch[...] = new_state

    y_ref[0, 0, 0] = y.astype(y_ref.dtype)
    state_ref[0, 0] = new_state


@functools.partial(jax.jit,
                   static_argnames=("chunk", "interpret"))
def ssd_chunked_pallas(x, dt, A, B_, C_, *, chunk: int = 256,
                       interpret: bool = True):
    """x (B,L,H,P); dt (B,L,H) post-softplus; A (H,); B_,C_ (B,L,G,N).
    Returns (y (B,L,H,P), final_state (B,H,N,P))."""
    Bb, L, H, P = x.shape
    G, N = B_.shape[2], B_.shape[3]
    Hg = H // G
    Q = min(chunk, L)
    pad = (-L) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = x.shape[1] // Q

    xr = x.reshape(Bb, nc, Q, H, P).transpose(0, 3, 1, 2, 4)    # (B,H,nc,Q,P)
    dtr = dt.reshape(Bb, nc, Q, H).transpose(0, 3, 1, 2)        # (B,H,nc,Q)
    br = B_.reshape(Bb, nc, Q, G, N).transpose(0, 3, 1, 2, 4)   # (B,G,nc,Q,N)
    cr = C_.reshape(Bb, nc, Q, G, N).transpose(0, 3, 1, 2, 4)

    kernel = functools.partial(_ssd_kernel, nc=nc)
    y, state = pl.pallas_call(
        kernel,
        grid=(Bb, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, Q, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, 1, 1, Q, N), lambda b, h, c: (b, h // Hg, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q, N), lambda b, h, c: (b, h // Hg, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, Q, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, N, P), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bb, H, nc, Q, P), x.dtype),
            jax.ShapeDtypeStruct((Bb, H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        compiler_params=tpu_compiler_params(PARALLEL, PARALLEL, ARBITRARY),
        interpret=interpret,
    )(xr, dtr, A.astype(jnp.float32), br, cr)

    y = y.transpose(0, 2, 3, 1, 4).reshape(Bb, nc * Q, H, P)[:, :L]
    return y, state
