"""Public entry for the SSD scan: Pallas kernel (interpret on CPU) or the
pure-jnp chunked implementation from repro.nn.ssd (same math, no kernel)."""
from __future__ import annotations

import jax

from . import ssd as _k


def ssd_chunked(x, dt, A, B_, C_, *, chunk: int = 256, pallas: bool = True,
                interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if pallas:
        return _k.ssd_chunked_pallas(x, dt, A, B_, C_, chunk=chunk,
                                     interpret=interpret)
    from ...nn.ssd import ssd_chunked as jnp_impl
    return jnp_impl(x, dt, A, B_, C_, chunk)
