"""Public entry for batched decode attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import decode_attn as _k
from .ref import decode_attention_ref


def decode_attention(q, k_cache, v_cache, lengths, *, pallas: bool = True,
                     interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if jnp.ndim(lengths) == 0:
        lengths = jnp.full((q.shape[0],), lengths, jnp.int32)
    if pallas:
        return _k.decode_attention_pallas(q, k_cache, v_cache, lengths,
                                          interpret=interpret)
    return decode_attention_ref(q, k_cache, v_cache, lengths)
