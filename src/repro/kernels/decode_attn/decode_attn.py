"""Pallas TPU kernel: batched single-token decode attention (GQA).

The serving engine's hot spot — the paper's FC regime: the KV cache streams
from HBM once per step while the tiny q block stays resident; batched slots
amortize nothing here (unlike weights) but share the grid.  Online softmax
over sequence chunks keeps VMEM at one (Sc, D) cache tile per head.

Grid: (B, KV, nS) with the sequence dimension sequential; scratch carries the
running (max, denom, acc) per (batch, kv-head).  Per-slot valid lengths are
prefetched to SMEM so padded cache tail and empty slots contribute nothing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..compat import ARBITRARY, PARALLEL, tpu_compiler_params

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *, s_chunk: int, n_s: int):
    b = pl.program_id(0)
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)             # (G, D) pre-scaled
    k = k_ref[0, :, 0, :].astype(jnp.float32)       # (Sc, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    length = len_ref[b]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (G, Sc)
    pos = si * s_chunk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < length, s, NEG_INF)

    m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=1)
    acc_new = acc_prev * corr[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...], l_ref[...], acc_ref[...] = m_new, l_new, acc_new

    @pl.when(si == n_s - 1)
    def _emit():
        o_ref[0, 0] = (acc_new / jnp.maximum(l_new, 1e-30)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("s_chunk", "interpret"))
def decode_attention_pallas(q, k_cache, v_cache, lengths, *,
                            s_chunk: int = 512, interpret: bool = True):
    """q (B,1,H,D); caches (B,S,KV,D); lengths (B,) int32 -> (B,1,H,D)."""
    B, _, H, D = q.shape
    _, S, KV, _ = k_cache.shape
    G = H // KV
    s_chunk = min(s_chunk, S)
    pad = (-S) % s_chunk
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_s = (S + pad) // s_chunk
    qg = (q.reshape(B, KV, G, D) * (D ** -0.5)).astype(q.dtype)

    out = pl.pallas_call(
        functools.partial(_decode_kernel, s_chunk=s_chunk, n_s=n_s),
        grid=(B, KV, n_s),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),     # lengths (prefetched)
            pl.BlockSpec((1, 1, G, D), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, s_chunk, 1, D), lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec((1, s_chunk, 1, D), lambda b, h, s: (b, s, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((G,), jnp.float32),
                        pltpu.VMEM((G,), jnp.float32),
                        pltpu.VMEM((G, D), jnp.float32)],
        compiler_params=tpu_compiler_params(PARALLEL, PARALLEL, ARBITRARY),
        interpret=interpret,
    )(lengths.astype(jnp.int32), qg, k_cache, v_cache)
    return out.reshape(B, 1, H, D)
