"""Oracle for the decode-attention kernel: the pure-jnp grouped-einsum
implementation used inside the models (nn.flash.decode_attention)."""
from ...nn.flash import decode_attention as decode_attention_ref  # noqa: F401
