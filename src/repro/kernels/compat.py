"""Version compatibility shims for Pallas TPU APIs.

The Pallas TPU compiler-params API was renamed across JAX releases
(``TPUCompilerParams`` with string dimension semantics -> ``CompilerParams``
with a ``GridDimensionSemantics`` enum).  Kernels call
:func:`tpu_compiler_params` with ``"parallel"`` / ``"arbitrary"`` strings and
this module translates to whatever the installed JAX expects.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

PARALLEL = "parallel"
ARBITRARY = "arbitrary"


def tpu_compiler_params(*dimension_semantics: str):
    """Build compiler params with per-grid-dim semantics for any JAX version."""
    if hasattr(pltpu, "TPUCompilerParams"):
        return pltpu.TPUCompilerParams(
            dimension_semantics=tuple(dimension_semantics))
    sem = []
    for s in dimension_semantics:
        enum = getattr(pltpu, "GridDimensionSemantics", None)
        sem.append(getattr(enum, s.upper()) if enum is not None else s)
    return pltpu.CompilerParams(dimension_semantics=tuple(sem))
