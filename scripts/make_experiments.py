"""Generate EXPERIMENTS.md §Dry-run and §Roofline tables from dry-run JSONL.

    PYTHONPATH=src python scripts/make_experiments.py results/dryrun.jsonl
"""
import json
import sys
from collections import OrderedDict

HW = "v5e-class: 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI"


def load(path):
    recs = OrderedDict()
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def dryrun_table(recs):
    out = ["| arch | shape | mesh | status | compile s | args GiB/dev | "
           "temp GiB/dev | HLO flops/dev | HBM bytes/dev | coll bytes/dev | "
           "#colls (in-loop) |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    for (a, s, m), r in recs.items():
        if r["status"] != "ok":
            why = r.get("reason", r.get("error", ""))[:60]
            out.append(f"| {a} | {s} | {m} | {r['status']}: {why} "
                       "| | | | | | | |")
            continue
        t = r["roofline"]
        cb = t["coll_breakdown"]
        out.append(
            f"| {a} | {s} | {m} | ok | {r['t_compile_s']} "
            f"| {fmt_bytes(r['memory']['argument_size'])} "
            f"| {fmt_bytes(r['memory']['temp_size'])} "
            f"| {t['flops_per_device']:.2e} "
            f"| {t['hbm_bytes_per_device']:.2e} "
            f"| {t['coll_bytes_per_device']:.2e} "
            f"| {cb.get('count',0)} ({cb.get('in_loop_count',0)}) |")
    return "\n".join(out)


def roofline_table(recs):
    out = ["| arch | shape | mesh | t_comp ms | t_mem ms | t_coll ms | "
           "bound | MODEL_FLOPS | useful/HLO | roofline frac | "
           "what would move the dominant term |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    for (a, s, m), r in recs.items():
        if r["status"] != "ok":
            continue
        t = r["roofline"]
        hint = _hint(t, r)
        out.append(
            f"| {a} | {s} | {m} "
            f"| {t['t_compute']*1e3:.2f} | {t['t_memory']*1e3:.2f} "
            f"| {t['t_collective']*1e3:.2f} | **{t['bound']}** "
            f"| {t['model_flops']:.2e} "
            f"| {t['useful_flops_ratio']*100:.0f}% "
            f"| {t['roofline_fraction']*100:.1f}% | {hint} |")
    return "\n".join(out)


def _hint(t, r):
    b = t["bound"]
    if b == "memory":
        if r["kind"] == "decode":
            return "BFP-int8 weight/cache streaming (~2x fewer bytes)"
        return "reduce remat re-reads / fuse transients (smaller MoE groups, bf16 dispatch)"
    if b == "collective":
        return "BFP-compressed grad reduce-scatter; fewer per-layer all-gathers (SP rules)"
    return "Winograd-style arithmetic reduction / skip masked attention tiles"


def main(path):
    recs = load(path)
    ok = sum(1 for r in recs.values() if r["status"] == "ok")
    sk = sum(1 for r in recs.values() if r["status"] == "skipped")
    er = len(recs) - ok - sk
    print(f"## §Dry-run\n")
    print(f"Hardware model: {HW}.  Meshes: 16x16 (256 chips/pod) and "
          f"2x16x16 (512 chips, multi-pod).  Cells: {ok} ok, {sk} skipped "
          f"(documented), {er} errors.\n")
    print(dryrun_table(recs))
    print(f"\n## §Roofline\n")
    print("Terms per the assignment: compute = HLO_FLOPs/(chips*peak); "
          "memory = HLO_bytes/(chips*HBM_bw); collective = "
          "coll_bytes/(chips*link_bw).  FLOPs/bytes are re-derived "
          "loop-aware from the partitioned HLO (XLA cost_analysis counts "
          "while bodies once; see core/roofline.analyze_hlo).  All values "
          "are per-device (the partitioned module), so the chips factor is "
          "already applied.\n")
    print(roofline_table(recs))


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun.jsonl")
