"""Measured per-layer autotune run over AlexNet's conv layers.

The live analog of the paper's §4 DSE (there: analytic ranking of
(C_vec, K_vec) ASIC configs; here: wall-clock measurement of the real
Pallas launch knobs through the full dispatch path — see
``core/autotune.py``; ``scripts/hillclimb.py`` is the sibling harness for
the LM roofline cells).  Writes two artifacts:

* ``results/plans/<name>.json`` — the persisted best-plan cache that
  ``models/alexnet.py`` / ``serving/cnn.py`` auto-load at engine build;
* ``BENCH_autotune.json`` — per-layer default-vs-tuned wall-clock, the
  perf-trajectory record CI gates on (tuned must never measure slower
  than default: the default plan is always a candidate, so the gate can
  only fail if the artifact was edited by hand or measured inconsistently).

    PYTHONPATH=src python scripts/autotune_alexnet.py [--full] [--fast]
        [--batch N] [--budget N] [--iters N] [--hill-climb] [--check-equal]
        [--cache PATH] [--out BENCH_autotune.json] [--check]

``--fast`` is the CI smoke mode: reduced config, small batch, a handful
of candidates per layer, single timing iteration.  ``--check`` exits
nonzero if any layer's recorded tuned_us exceeds its default_us.
"""
import argparse
import dataclasses
import json
import os
import sys

sys.path.insert(0, "src")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                                                  # noqa: E402

from repro.core.autotune import (PlanCache, autotune_alexnet,  # noqa: E402
                                 backend_kind, default_cache_path)
from repro.models import alexnet                            # noqa: E402


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full 227px AlexNet (default: reduced config)")
    ap.add_argument("--fast", action="store_true",
                    help="CI smoke mode: small batch, few candidates, "
                         "1 timing iteration")
    ap.add_argument("--batch", type=int, default=None,
                    help="batch size to tune at (default 4; 2 with --fast)")
    ap.add_argument("--budget", type=int, default=None,
                    help="max measured candidates per layer "
                         "(default: unlimited; 6 with --fast)")
    ap.add_argument("--iters", type=int, default=None,
                    help="timing iterations per candidate (default 3; "
                         "1 with --fast)")
    ap.add_argument("--image-size", type=int, default=None)
    ap.add_argument("--hill-climb", action="store_true",
                    help="halve/double neighborhood walk past the knob "
                         "grids from the measured winner")
    ap.add_argument("--check-equal", action="store_true",
                    help="assert every measured candidate's output is "
                         "bit-equal to the default plan's (~2x cost)")
    ap.add_argument("--cache", default=None,
                    help="plan-cache path (default results/plans/<name>.json)")
    ap.add_argument("--out", default="BENCH_autotune.json")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 if any layer's tuned_us > default_us")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    cfg = alexnet.AlexNetConfig(use_pallas=True)
    if not args.full:
        # reduced channels at 131px, as in benchmarks/fused_pipeline.py:
        # the stock 67px reduction degenerates conv3-5 to 3x3 maps
        cfg = dataclasses.replace(cfg.reduced(), image_size=131,
                                  use_pallas=True)
    if args.image_size:
        cfg = dataclasses.replace(cfg, image_size=args.image_size)
    batch = args.batch or (2 if args.fast else 4)
    budget = args.budget or (6 if args.fast else None)
    iters = args.iters or (1 if args.fast else 3)

    cache_path = args.cache or default_cache_path(cfg.name)
    cache = PlanCache.load(cache_path)
    log = None if args.quiet else (lambda s: print(s, flush=True))
    if log:
        log(f"autotune: {cfg.name} batch={batch} backend={backend_kind()} "
            f"budget={budget} iters={iters}")
    results = autotune_alexnet(cfg, batch, iters=iters,
                               max_candidates=budget,
                               hill_climb=args.hill_climb,
                               check_equal=args.check_equal,
                               cache=cache, log=log)
    cache.save(cache_path)

    artifact = {
        "config": dataclasses.asdict(cfg),
        "batch": batch,
        "backend": backend_kind(),
        "jax_backend": jax.default_backend(),
        "budget": budget,
        "iters": iters,
        "cache": cache_path,
        "layers": results,
    }
    with open(args.out, "w") as f:
        json.dump(artifact, f, indent=2)
        f.write("\n")

    bad = []
    for r in results:
        speed = r["default_us"] / r["tuned_us"]
        print(f"autotune/{r['layer']},{r['tuned_us']:.1f},"
              f"default_us={r['default_us']:.0f};speedup={speed:.2f}x"
              f";candidates={r['candidates']};plan={r['plan']}")
        if r["tuned_us"] > r["default_us"]:
            bad.append(r["layer"])

    print(f"autotune/cache,0,path={cache_path};"
          f"entries={len(cache.entries)}")
    if args.check:
        if bad:
            print(f"autotune/CHECK_FAILED,0,layers={bad}")
            return 1
        print("autotune/CHECK_OK,0,tuned<=default_all_layers")
    return 0


if __name__ == "__main__":
    sys.exit(main())
