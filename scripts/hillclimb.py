import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimbing harness: run one (arch x shape x mesh) cell under a
named variant (rules / cfg overrides / serve dtype), print the three
roofline terms vs the recorded baseline, and append to
results/hillclimb.jsonl.

For the CNN side, ``scripts/autotune_alexnet.py`` is the measured
counterpart: instead of hand-named variants it enumerates the Pallas conv
launch knobs per layer, times each through dispatch_conv, and persists
the winners to ``results/plans/`` (see ``core/autotune.py``).

    PYTHONPATH=src python scripts/hillclimb.py \
        --arch starcoder2-15b --shape train_4k --mesh single \
        --name banded_attn --cfg '{"banded_attention": true}'
"""
import argparse     # noqa: E402
import json         # noqa: E402
import sys          # noqa: E402

sys.path.insert(0, "src")

from repro.launch.dryrun import run_cell     # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--name", required=True, help="variant name for the log")
    ap.add_argument("--cfg", default="", help="JSON ArchConfig overrides")
    ap.add_argument("--rules", default="", help="JSON sharding-rule overrides")
    ap.add_argument("--serve-dtype", default="bf16")
    ap.add_argument("--no-zero1", action="store_true")
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--baseline", default="results/dryrun.jsonl")
    ap.add_argument("--out", default="results/hillclimb.jsonl")
    args = ap.parse_args()

    rec = run_cell(args.arch, args.shape,
                   multi_pod=args.mesh == "multi",
                   rules=json.loads(args.rules) if args.rules else None,
                   cfg_overrides=json.loads(args.cfg) if args.cfg else None,
                   serve_dtype=args.serve_dtype,
                   zero1=not args.no_zero1, fsdp=args.fsdp)
    rec["variant"] = args.name
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "a") as f:
        f.write(json.dumps(rec) + "\n")

    if rec["status"] != "ok":
        print(f"[{rec['status']}] {rec.get('error') or rec.get('reason')}")
        return 1

    t = rec["roofline"]
    mesh_name = rec["mesh"]
    base = None
    try:
        with open(args.baseline) as f:
            for line in f:
                r = json.loads(line)
                if (r["arch"], r["shape"], r["mesh"]) == \
                        (args.arch, args.shape, mesh_name) and \
                        r["status"] == "ok":
                    base = r["roofline"]
    except FileNotFoundError:
        pass

    def row(tag, tt):
        print(f"  {tag:10s} comp={tt['t_compute']*1e3:9.2f}ms "
              f"mem={tt['t_memory']*1e3:9.2f}ms "
              f"coll={tt['t_collective']*1e3:9.2f}ms "
              f"bound={tt['bound']:10s} step={tt['step_time']*1e3:9.2f}ms "
              f"frac={tt['roofline_fraction']*100:5.1f}%")

    print(f"{args.arch} {args.shape} {mesh_name} variant={args.name}")
    if base:
        row("baseline", base)
    row("variant", t)
    if base:
        d = base["step_time"] / t["step_time"]
        print(f"  step-time speedup vs baseline: {d:.2f}x  "
              f"temp={rec['memory']['temp_size']/2**30:.2f}GiB")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
